//! On-disk result cache for campaign runs.
//!
//! Each scenario is content-addressed: its fingerprint hashes the full
//! node spec, model config, workload config, and engine parameters (via
//! their canonical `Debug` renderings, which include every field, so any
//! new mechanism parameter automatically invalidates stale entries) plus a
//! schema version. Summaries persist as one JSON artifact per scenario at
//! `<dir>/<name>-<fingerprint:016x>.json`; a re-run with an unchanged grid
//! loads every summary from disk and executes zero engine runs.

use crate::campaign::grid::Scenario;
use crate::campaign::runner::ScenarioSummary;
use crate::config::NodeSpec;
use std::io;
use std::path::{Path, PathBuf};

/// Bump when [`ScenarioSummary`]'s JSON schema changes **or** when engine
/// semantics change in a way not reflected in any config/parameter struct —
/// invalidates every existing cache entry. (The crate version is also
/// folded into fingerprints, so released engine changes invalidate
/// automatically; this constant covers same-version development.)
///
/// v2: scenarios carry a topology (node count + NIC), workloads carry a
/// sharding strategy, and summaries grew per-node rollup fields.
///
/// v3: engine parameters carry a power-management policy
/// (`governor`/`margin_k`/`fixed_cap_ratio`) and summaries grew the
/// governor/energy fields (`governor`, `energy_per_iter_j`,
/// `tokens_per_j`).
///
/// v4: scenarios may carry a serving workload (`Scenario::serving`) and
/// summaries grew the serving fields (`offered_qps`, `ttft_p99_ms`,
/// `tpot_p99_ms`, `goodput_rps`, `energy_per_request_j`).
///
/// v5: engine parameters carry injected faults (`faults`) and summaries
/// grew the fault/robustness fields (`faults`, `lost_ms`, `blocked_ms`,
/// `status`).
///
/// v6: campaigns can persist binary trace stores next to summaries
/// (`<name>-<fp:016x>.ctrc`, `campaign --trace-store`), and `--resume`
/// may rebuild a summary from a finalized (non-salvaged) store instead of
/// re-running the engine.
///
/// v7: scenarios may carry a replica fold factor (`Scenario::fold`,
/// DESIGN.md §13), summaries grew the `fold` field, and store/summary
/// rebuilds expand folded per-class totals to logical-cluster figures.
///
/// v8: engine parameters carry an optional thermal-coupling model
/// (`EngineParams::thermal`, DESIGN.md §14) — `{params:?}` in the
/// fingerprint changed shape for *every* scenario, thermal or not — and
/// summaries grew the thermal fields (`peak_temp_c`, `throttle_loss_ms`).
pub const SCHEMA_VERSION: u32 = 8;

pub use crate::util::prng::fnv1a;

/// Content fingerprint of one scenario on one per-node hardware spec.
/// Hashes the crate version, schema version, and the full `Debug`
/// renderings of the node / topology / model / workload /
/// engine-parameter state, so any new field is picked up automatically.
pub fn fingerprint(node: &NodeSpec, sc: &Scenario) -> u64 {
    let mut canon = format!(
        "chopper-{}-campaign-v{SCHEMA_VERSION}|{node:?}|N{}|{:?}|{:?}|{:?}|{:?}",
        env!("CARGO_PKG_VERSION"),
        sc.num_nodes,
        sc.nic,
        sc.model,
        sc.wl,
        sc.params
    );
    // The serving block is folded in only when present, so training
    // fingerprints keep their serving-free canonical form.
    if let Some(scfg) = &sc.serving {
        canon.push_str(&format!("|serve{scfg:?}"));
    }
    // Same rule for the replica fold factor: exact-mode fingerprints keep
    // their fold-free canonical form.
    if sc.fold > 1 {
        canon.push_str(&format!("|fold{}", sc.fold));
    }
    fnv1a(canon.as_bytes())
}

fn sanitize(name: &str) -> String {
    name.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.') {
                c
            } else {
                '_'
            }
        })
        .collect()
}

/// A directory of per-scenario summary artifacts.
#[derive(Debug, Clone)]
pub struct Cache {
    dir: PathBuf,
}

impl Cache {
    /// Open (creating if needed) a cache directory.
    pub fn open(dir: impl Into<PathBuf>) -> io::Result<Cache> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(Cache { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Artifact path for a scenario name + fingerprint.
    pub fn path_for(&self, name: &str, fp: u64) -> PathBuf {
        self.dir.join(format!("{}-{fp:016x}.json", sanitize(name)))
    }

    /// Binary trace-store path for a scenario name + fingerprint
    /// (`campaign --trace-store` artifacts, same content addressing as the
    /// JSON summaries). A `.tmp` sibling of this path is a torn store left
    /// by a crashed run — `chopper fsck` can salvage it.
    pub fn store_path_for(&self, name: &str, fp: u64) -> PathBuf {
        self.dir.join(format!(
            "{}-{fp:016x}.{}",
            sanitize(name),
            crate::trace::store::STORE_EXT
        ))
    }

    /// Load a cached summary if one exists for exactly this fingerprint.
    /// Corrupt or mismatched artifacts are treated as misses: an entry
    /// that exists but fails to parse (truncated by a crash predating
    /// atomic writes, or hand-edited) is logged and recomputed, never a
    /// panic that takes the whole sweep down.
    pub fn load(&self, name: &str, fp: u64) -> Option<ScenarioSummary> {
        let path = self.path_for(name, fp);
        let text = std::fs::read_to_string(&path).ok()?;
        match ScenarioSummary::from_json_str(&text) {
            Ok(s) if s.fingerprint == fp => Some(s),
            Ok(_) => None,
            Err(e) => {
                eprintln!(
                    "cache: corrupt entry {} ({e}); recomputing",
                    path.display()
                );
                None
            }
        }
    }

    /// Persist a summary; returns the artifact path.
    ///
    /// Crash-safe: the JSON goes through [`crate::util::atomic_write`]
    /// (tmp sibling + fsync + rename — the pattern this cache originated,
    /// now shared by every artifact writer), so a process killed mid-write
    /// can never leave a truncated artifact under the final
    /// content-addressed name — `campaign --resume` then sees either the
    /// complete entry or none.
    pub fn store(&self, s: &ScenarioSummary) -> io::Result<PathBuf> {
        let path = self.path_for(&s.name, s.fingerprint);
        crate::util::atomic_write(&path, s.to_json_str().as_bytes())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::grid::GridSpec;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("chopper_cache_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn fnv_is_stable_and_discriminating() {
        assert_eq!(fnv1a(b"abc"), fnv1a(b"abc"));
        assert_ne!(fnv1a(b"abc"), fnv1a(b"abd"));
        // Spot-check against the reference value of FNV-1a("a").
        assert_eq!(fnv1a(b"a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn fingerprint_changes_with_any_input() {
        let node = NodeSpec::mi300x_node();
        let scs = GridSpec::paper(2, 2, 1).expand();
        let base = fingerprint(&node, &scs[0]);
        assert_eq!(base, fingerprint(&node, &scs[0]));
        assert_ne!(base, fingerprint(&node, &scs[1]));
        let mut tweaked = scs[0].clone();
        tweaked.params.spin_penalty += 0.01;
        assert_ne!(base, fingerprint(&node, &tweaked));
        let mut tweaked = scs[0].clone();
        tweaked.wl.iterations += 1;
        assert_ne!(base, fingerprint(&node, &tweaked));
        // Topology inputs fingerprint too.
        let mut tweaked = scs[0].clone();
        tweaked.num_nodes = 2;
        assert_ne!(base, fingerprint(&node, &tweaked));
        let mut tweaked = scs[0].clone();
        tweaked.nic.nic_bw /= 2.0;
        assert_ne!(base, fingerprint(&node, &tweaked));
        let mut tweaked = scs[0].clone();
        tweaked.wl.sharding = crate::config::Sharding::Hsdp;
        assert_ne!(base, fingerprint(&node, &tweaked));
        // Serving presence and serving knobs fingerprint too.
        let mut serving = scs[0].clone();
        serving.serving = Some(crate::config::ServingConfig::new(8.0, 32));
        let sfp = fingerprint(&node, &serving);
        assert_ne!(base, sfp);
        let mut tweaked = serving.clone();
        tweaked.serving.as_mut().unwrap().max_batch += 1;
        assert_ne!(sfp, fingerprint(&node, &tweaked));
        let mut tweaked = serving.clone();
        tweaked.serving.as_mut().unwrap().arrival =
            crate::config::ArrivalProcess::Poisson { qps: 9.0 };
        assert_ne!(sfp, fingerprint(&node, &tweaked));
        // The replica fold factor fingerprints too (fold 1 == the exact
        // canonical form, so legacy entries stay addressable).
        let mut folded = scs[0].clone();
        folded.num_nodes = 8;
        folded.fold = 4;
        let ffp = fingerprint(&node, &folded);
        let mut exact = folded.clone();
        exact.fold = 1;
        assert_ne!(ffp, fingerprint(&node, &exact));
    }

    #[test]
    fn sanitize_keeps_safe_chars() {
        assert_eq!(sanitize("L2-b1s4-FSDPv1"), "L2-b1s4-FSDPv1");
        assert_eq!(sanitize("a/b c"), "a_b_c");
    }

    #[test]
    fn missing_and_corrupt_entries_are_misses() {
        let cache = Cache::open(tmpdir("miss")).unwrap();
        assert!(cache.load("nope", 7).is_none());
        std::fs::write(cache.path_for("bad", 9), "{not json").unwrap();
        assert!(cache.load("bad", 9).is_none());
        std::fs::remove_dir_all(cache.dir()).ok();
    }

    #[test]
    fn store_is_atomic_and_leaves_no_tmp_sibling() {
        let cache = Cache::open(tmpdir("atomic")).unwrap();
        let mut s = ScenarioSummary::default();
        s.name = "L2-b1s4-FSDPv1".into();
        s.fingerprint = 0xABCD;
        let path = cache.store(&s).unwrap();
        assert!(path.exists());
        // The rename consumed the temp sibling.
        let leftovers: Vec<_> = std::fs::read_dir(cache.dir())
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.path().to_string_lossy().ends_with(".tmp")
            })
            .collect();
        assert!(leftovers.is_empty(), "stray tmp files: {leftovers:?}");
        let back = cache.load(&s.name, s.fingerprint).unwrap();
        assert_eq!(back.name, s.name);
        std::fs::remove_dir_all(cache.dir()).ok();
    }

    #[test]
    fn truncated_entry_is_a_logged_miss_and_recoverable() {
        let cache = Cache::open(tmpdir("trunc")).unwrap();
        let mut s = ScenarioSummary::default();
        s.name = "L2-b1s4-FSDPv1".into();
        s.fingerprint = 0x1234;
        let path = cache.store(&s).unwrap();
        // Simulate a crash mid-write under a non-atomic scheme: truncate
        // the artifact in place.
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert!(cache.load(&s.name, s.fingerprint).is_none());
        // A fresh store heals the entry.
        cache.store(&s).unwrap();
        assert!(cache.load(&s.name, s.fingerprint).is_some());
        std::fs::remove_dir_all(cache.dir()).ok();
    }
}
