//! Cross-scenario comparison reports: the campaign counterpart of the
//! per-figure generators in `chopper::report`. Pure functions of
//! [`ScenarioSummary`] rows, so cached and freshly executed campaigns
//! render byte-identically.

use crate::campaign::runner::ScenarioSummary;
use crate::chopper::report::Figure;
use crate::util::ascii;
use std::fmt::Write as _;

/// The headline comparison table: throughput (absolute and relative to the
/// first scenario), iteration cost, launch share, DVFS frequency loss,
/// overlap efficiency, and the energy columns (joules per iteration,
/// tokens per joule) for every scenario in grid order.
pub fn campaign_table(summaries: &[ScenarioSummary]) -> Figure {
    let base_tp = summaries
        .first()
        .map(|s| s.tokens_per_sec)
        .unwrap_or(1.0)
        .max(1e-9);
    // Topology / governor columns appear only when some scenario uses
    // them, so classic campaigns keep their column set.
    let multi = summaries
        .iter()
        .any(|s| s.num_nodes > 1 || s.sharding != "FSDP");
    // The fold column appears only when some scenario actually folded
    // replicas, so exact-mode campaigns (folded or not in topology) keep
    // their pre-fold bytes.
    let folded = summaries.iter().any(|s| s.fold > 1);
    let gov = summaries.iter().any(|s| s.governor != "reactive");
    let mut rows: Vec<Vec<String>> = Vec::with_capacity(summaries.len());
    let mut csv = String::from(
        "scenario,label,fsdp,layers,batch,seq,tokens_per_sec,rel_throughput,\
         iter_ms,launch_ms,launch_pct,freq_mhz,freq_loss_pct,power_w,overlap_fa,\
         energy_per_iter_j,tokens_per_j",
    );
    if multi {
        csv.push_str(",sharding,num_nodes");
        if folded {
            csv.push_str(",fold");
        }
    }
    if gov {
        csv.push_str(",governor");
    }
    csv.push('\n');
    for s in summaries {
        let rel = s.tokens_per_sec / base_tp;
        let launch_pct = 100.0 * s.launch_ms / s.iter_ms.max(1e-9);
        let mut row = vec![
            s.name.clone(),
            format!("{:.0}", s.tokens_per_sec),
            format!("{rel:.2}x"),
            format!("{:.2}", s.iter_ms),
            format!("{launch_pct:.1}%"),
            format!("{:.0}", s.freq_mhz),
            format!("{:.1}%", 100.0 * s.freq_loss),
            format!("{:.0}", s.power_w),
            format!("{:.2}", s.overlap_fa),
            format!("{:.1}", s.energy_per_iter_j),
            format!("{:.2}", s.tokens_per_j),
        ];
        if multi {
            row.push(topo_tag(s));
        }
        if gov {
            row.push(s.governor.clone());
        }
        rows.push(row);
        let _ = write!(
            csv,
            "{},{},{},{},{},{},{:.2},{:.4},{:.4},{:.4},{:.2},{:.1},{:.2},{:.1},{:.4},{:.4},{:.4}",
            s.name,
            s.label,
            s.fsdp,
            s.layers,
            s.batch,
            s.seq,
            s.tokens_per_sec,
            rel,
            s.iter_ms,
            s.launch_ms,
            launch_pct,
            s.freq_mhz,
            100.0 * s.freq_loss,
            s.power_w,
            s.overlap_fa,
            s.energy_per_iter_j,
            s.tokens_per_j
        );
        if multi {
            let _ = write!(csv, ",{},{}", s.sharding, s.num_nodes);
            if folded {
                let _ = write!(csv, ",{}", s.fold);
            }
        }
        if gov {
            let _ = write!(csv, ",{}", s.governor);
        }
        csv.push('\n');
    }
    let mut out = String::from(
        "Campaign — cross-scenario comparison (relative to first scenario)\n\n",
    );
    let mut headers = vec![
        "scenario", "tok/s", "rel", "iter ms", "launch", "MHz", "DVFS loss",
        "W", "ovl(fa)", "J/iter", "tok/J",
    ];
    if multi {
        headers.push("topo");
    }
    if gov {
        headers.push("gov");
    }
    out.push_str(&ascii::table(&headers, &rows));
    Figure {
        id: "campaign",
        title: "Campaign — cross-scenario comparison".into(),
        ascii: out,
        csv,
        svg: None,
    }
}

/// Topology cell for the ASCII tables: "HSDPx64" in exact mode,
/// "HSDPx64 (folded /32)" shorthand "HSDPx64/f32" when the scenario
/// simulated `num_nodes / fold` representative nodes (DESIGN.md §13).
fn topo_tag(s: &ScenarioSummary) -> String {
    if s.fold > 1 {
        format!("{}x{}/f{}", s.sharding, s.num_nodes, s.fold)
    } else {
        format!("{}x{}", s.sharding, s.num_nodes)
    }
}

/// Node-grouped comparison: one row per (scenario, node) with the node's
/// median iteration span and its skew against the scenario's fastest
/// node — the cross-scenario view of the per-node figure rollups. Only
/// meaningful on campaigns with multi-node scenarios; single-node rows
/// report their scenario-wide iteration median as node 0.
pub fn campaign_by_nodes(summaries: &[ScenarioSummary]) -> Figure {
    let mut csv =
        String::from("scenario,sharding,num_nodes,node,iter_ms,skew_pct\n");
    let mut out = String::from(
        "Campaign — per-node iteration medians (skew vs fastest node)\n\n",
    );
    let mut rows: Vec<Vec<String>> = Vec::new();
    for s in summaries {
        let per_node: Vec<f64> = if s.node_iter_ms.is_empty() {
            vec![s.iter_ms]
        } else {
            s.node_iter_ms.clone()
        };
        let fastest = per_node
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min)
            .max(1e-9);
        for (n, &ms) in per_node.iter().enumerate() {
            let skew = 100.0 * (ms / fastest - 1.0);
            rows.push(vec![
                s.name.clone(),
                topo_tag(s),
                format!("node{n}"),
                format!("{ms:.2}"),
                format!("{skew:+.1}%"),
            ]);
            let _ = writeln!(
                csv,
                "{},{},{},{},{:.4},{:.2}",
                s.name, s.sharding, s.num_nodes, n, ms, skew
            );
        }
    }
    out.push_str(&ascii::table(
        &["scenario", "topo", "node", "iter ms", "skew"],
        &rows,
    ));
    Figure {
        id: "campaign_nodes",
        title: "Campaign — per-node iteration medians".into(),
        ascii: out,
        csv,
        svg: None,
    }
}

/// Cross-policy energy/perf comparison: one row per scenario, grouped by
/// workload (everything but the governor), with Δ iteration time and Δ
/// energy against the group's `reactive` row — the campaign-wide view of
/// `chopper whatif`. Meaningful on grids with a `--governor` axis;
/// governor-less groups report zero deltas against themselves.
pub fn campaign_by_governor(summaries: &[ScenarioSummary]) -> Figure {
    // Group key: the full scenario identity with only the governor tag
    // stripped. The name carries every axis the grid varied (incl. NIC
    // and ablation-knob tags that individual summary fields don't), so
    // siblings differing in anything but the policy never collapse into
    // one group.
    let key = |s: &ScenarioSummary| -> String {
        s.name.replace(&format!("-gov_{}", s.governor), "")
    };
    // Baseline per group: the reactive row if present, else the group's
    // first row in grid order.
    let mut base: std::collections::BTreeMap<_, (f64, f64)> =
        std::collections::BTreeMap::new();
    for s in summaries {
        let k = key(s);
        let e = base.entry(k).or_insert((s.iter_ms, s.energy_per_iter_j));
        if s.governor == "reactive" {
            *e = (s.iter_ms, s.energy_per_iter_j);
        }
    }
    let mut csv = String::from(
        "scenario,governor,iter_ms,delta_iter_pct,energy_per_iter_j,\
         delta_energy_pct,power_w,tokens_per_j\n",
    );
    let mut rows: Vec<Vec<String>> = Vec::with_capacity(summaries.len());
    for s in summaries {
        let (bi, be) = base[&key(s)];
        let di = 100.0 * (s.iter_ms / bi.max(1e-9) - 1.0);
        let de = 100.0 * (s.energy_per_iter_j / be.max(1e-9) - 1.0);
        rows.push(vec![
            s.name.clone(),
            s.governor.clone(),
            format!("{:.2}", s.iter_ms),
            format!("{di:+.1}%"),
            format!("{:.1}", s.energy_per_iter_j),
            format!("{de:+.1}%"),
            format!("{:.0}", s.power_w),
            format!("{:.2}", s.tokens_per_j),
        ]);
        let _ = writeln!(
            csv,
            "{},{},{:.4},{:.2},{:.4},{:.2},{:.1},{:.4}",
            s.name, s.governor, s.iter_ms, di, s.energy_per_iter_j, de,
            s.power_w, s.tokens_per_j
        );
    }
    let mut out = String::from(
        "Campaign — governor policies (Δ vs each workload's reactive row)\n\n",
    );
    out.push_str(&ascii::table(
        &[
            "scenario", "governor", "iter ms", "Δiter", "J/iter", "ΔJ", "W",
            "tok/J",
        ],
        &rows,
    ));
    Figure {
        id: "campaign_governors",
        title: "Campaign — governor energy/perf comparison".into(),
        ascii: out,
        csv,
        svg: None,
    }
}

/// Phase/communication breakdown: stacked fwd/bwd/opt bars per scenario
/// plus the collective-duration columns — how iteration time redistributes
/// across the grid.
pub fn campaign_breakdown(summaries: &[ScenarioSummary]) -> Figure {
    let mut csv = String::from(
        "scenario,fwd_ms,bwd_ms,opt_ms,allgather_ms,reduce_scatter_ms,span_ms,events\n",
    );
    let mut out =
        String::from("Campaign — phase and communication breakdown\n\n");
    let width = summaries.iter().map(|s| s.name.len()).max().unwrap_or(8);
    let max_total = summaries
        .iter()
        .map(|s| s.fwd_ms + s.bwd_ms + s.opt_ms)
        .fold(0.0_f64, f64::max)
        .max(1e-9);
    for s in summaries {
        out.push_str(&ascii::stacked_bar(
            &format!("{:>width$}", s.name, width = width),
            &[
                ("fwd".into(), s.fwd_ms),
                ("bwd".into(), s.bwd_ms),
                ("opt".into(), s.opt_ms),
            ],
            44,
            max_total,
        ));
        let _ = writeln!(
            out,
            "  {:>width$}  ag {:.3} ms  rs {:.3} ms",
            "",
            s.allgather_ms,
            s.reduce_scatter_ms,
            width = width
        );
        let _ = writeln!(
            csv,
            "{},{:.4},{:.4},{:.4},{:.4},{:.4},{:.3},{}",
            s.name,
            s.fwd_ms,
            s.bwd_ms,
            s.opt_ms,
            s.allgather_ms,
            s.reduce_scatter_ms,
            s.span_ms,
            s.events
        );
    }
    out.push_str("\n  bars: fwd █  bwd ▓  opt ▒ (scaled to slowest scenario)\n");
    Figure {
        id: "campaign_breakdown",
        title: "Campaign — phase/communication breakdown".into(),
        ascii: out,
        csv,
        svg: None,
    }
}

/// Serving comparison: one row per serving scenario with the
/// latency/goodput/energy block of the summary (the `--workload serving`
/// campaign counterpart of [`campaign_table`]). Training rows carry no
/// serving block and are skipped.
pub fn campaign_serving(summaries: &[ScenarioSummary]) -> Figure {
    let mut csv = String::from(
        "scenario,label,offered_qps,ttft_p99_ms,tpot_p99_ms,goodput_rps,\
         output_tok_s,energy_per_request_j,tokens_per_j,power_w\n",
    );
    let mut rows: Vec<Vec<String>> = Vec::new();
    for s in summaries.iter().filter(|s| s.offered_qps > 0.0) {
        rows.push(vec![
            s.name.clone(),
            format!("{:.2}", s.offered_qps),
            format!("{:.2}", s.ttft_p99_ms),
            format!("{:.3}", s.tpot_p99_ms),
            format!("{:.3}", s.goodput_rps),
            format!("{:.0}", s.tokens_per_sec),
            format!("{:.2}", s.energy_per_request_j),
            format!("{:.2}", s.tokens_per_j),
        ]);
        let _ = writeln!(
            csv,
            "{},{},{:.4},{:.4},{:.4},{:.4},{:.2},{:.4},{:.4},{:.1}",
            s.name,
            s.label,
            s.offered_qps,
            s.ttft_p99_ms,
            s.tpot_p99_ms,
            s.goodput_rps,
            s.tokens_per_sec,
            s.energy_per_request_j,
            s.tokens_per_j,
            s.power_w,
        );
    }
    let mut out = String::from(
        "Campaign — serving latency/goodput/energy by offered load\n\n",
    );
    out.push_str(&ascii::table(
        &[
            "scenario",
            "qps",
            "ttft p99 ms",
            "tpot p99 ms",
            "goodput rps",
            "out tok/s",
            "J/req",
            "tok/J",
        ],
        &rows,
    ));
    Figure {
        id: "campaign_serving",
        title: "Campaign — serving comparison".into(),
        ascii: out,
        csv,
        svg: None,
    }
}

/// Fault/robustness comparison: one row per scenario that injected faults
/// or failed outright, with Δ iteration time and Δ energy against the
/// scenario's healthy sibling (the same grid point with the `-flt_` tag
/// stripped), plus the time lost to restarts and the time ranks spent
/// blocked on slower peers. Healthy `ok` rows serve only as baselines and
/// are skipped; failed rows render with their status so a crashed
/// scenario is visible in the report rather than silently absent.
pub fn campaign_faults(summaries: &[ScenarioSummary]) -> Figure {
    // Group key: the scenario identity with the fault tag stripped — the
    // healthy sibling shares every other axis tag (and, by grid
    // construction, every jitter draw).
    let key = |s: &ScenarioSummary| -> String {
        if s.faults.is_empty() {
            s.name.clone()
        } else {
            s.name.replace(&format!("-flt_{}", s.faults), "")
        }
    };
    // Baseline per group: the healthy (fault-less, ok) row if present,
    // else the group's first row in grid order.
    let mut base: std::collections::BTreeMap<_, (f64, f64)> =
        std::collections::BTreeMap::new();
    for s in summaries {
        let k = key(s);
        let e = base.entry(k).or_insert((s.iter_ms, s.energy_per_iter_j));
        if s.faults.is_empty() && s.status == "ok" {
            *e = (s.iter_ms, s.energy_per_iter_j);
        }
    }
    let mut csv = String::from(
        "scenario,faults,status,iter_ms,delta_iter_pct,energy_per_iter_j,\
         delta_energy_pct,lost_ms,blocked_ms,tokens_per_j\n",
    );
    let mut rows: Vec<Vec<String>> = Vec::new();
    for s in summaries
        .iter()
        .filter(|s| !s.faults.is_empty() || s.status != "ok")
    {
        let (bi, be) = base[&key(s)];
        let di = 100.0 * (s.iter_ms / bi.max(1e-9) - 1.0);
        let de = 100.0 * (s.energy_per_iter_j / be.max(1e-9) - 1.0);
        rows.push(vec![
            s.name.clone(),
            if s.faults.is_empty() {
                "none".into()
            } else {
                s.faults.clone()
            },
            s.status.clone(),
            format!("{:.2}", s.iter_ms),
            format!("{di:+.1}%"),
            format!("{:.1}", s.energy_per_iter_j),
            format!("{de:+.1}%"),
            format!("{:.2}", s.lost_ms),
            format!("{:.2}", s.blocked_ms),
            format!("{:.2}", s.tokens_per_j),
        ]);
        let _ = writeln!(
            csv,
            "{},{},{},{:.4},{:.2},{:.4},{:.2},{:.4},{:.4},{:.4}",
            s.name,
            s.faults,
            s.status,
            s.iter_ms,
            di,
            s.energy_per_iter_j,
            de,
            s.lost_ms,
            s.blocked_ms,
            s.tokens_per_j
        );
    }
    let mut out = String::from(
        "Campaign — fault injection (Δ vs each scenario's healthy sibling)\n\n",
    );
    out.push_str(&ascii::table(
        &[
            "scenario", "faults", "status", "iter ms", "Δiter", "J/iter",
            "ΔJ", "lost ms", "blocked ms", "tok/J",
        ],
        &rows,
    ));
    Figure {
        id: "campaign_faults",
        title: "Campaign — fault injection comparison".into(),
        ascii: out,
        csv,
        svg: None,
    }
}

/// Thermal table: peak die temperature and throttle loss per
/// thermal-enabled scenario, Δ vs the thermal-disabled sibling when the
/// grid carries one. Rendered only when the grid has a thermal axis
/// (any `peak_temp_c != 0.0`, DESIGN.md §14).
pub fn campaign_thermal(summaries: &[ScenarioSummary]) -> Figure {
    // Group key: the scenario identity with the thermal tag stripped.
    // The `-therm_*` tag is the last name component (grid.rs appends it
    // after every other axis tag), so truncating at it recovers the
    // sibling that shares every jitter draw.
    let key = |s: &ScenarioSummary| -> String {
        match s.name.find("-therm_") {
            Some(i) => s.name[..i].to_string(),
            None => s.name.clone(),
        }
    };
    // Baseline per group: the thermal-disabled row if present, else the
    // group's first row in grid order.
    let mut base: std::collections::BTreeMap<_, (f64, f64)> =
        std::collections::BTreeMap::new();
    for s in summaries {
        let k = key(s);
        let e = base.entry(k).or_insert((s.iter_ms, s.energy_per_iter_j));
        if s.peak_temp_c == 0.0 {
            *e = (s.iter_ms, s.energy_per_iter_j);
        }
    }
    let mut csv = String::from(
        "scenario,peak_temp_c,throttle_loss_ms,iter_ms,delta_iter_pct,\
         energy_per_iter_j,delta_energy_pct,tokens_per_j\n",
    );
    let mut rows: Vec<Vec<String>> = Vec::new();
    for s in summaries.iter().filter(|s| s.peak_temp_c != 0.0) {
        let (bi, be) = base[&key(s)];
        let di = 100.0 * (s.iter_ms / bi.max(1e-9) - 1.0);
        let de = 100.0 * (s.energy_per_iter_j / be.max(1e-9) - 1.0);
        rows.push(vec![
            s.name.clone(),
            format!("{:.1}", s.peak_temp_c),
            format!("{:.2}", s.throttle_loss_ms),
            format!("{:.2}", s.iter_ms),
            format!("{di:+.1}%"),
            format!("{:.1}", s.energy_per_iter_j),
            format!("{de:+.1}%"),
            format!("{:.2}", s.tokens_per_j),
        ]);
        let _ = writeln!(
            csv,
            "{},{:.2},{:.4},{:.4},{:.2},{:.4},{:.2},{:.4}",
            s.name,
            s.peak_temp_c,
            s.throttle_loss_ms,
            s.iter_ms,
            di,
            s.energy_per_iter_j,
            de,
            s.tokens_per_j
        );
    }
    let mut out = String::from(
        "Campaign — thermal coupling (Δ vs each scenario's \
         thermal-disabled sibling)\n\n",
    );
    out.push_str(&ascii::table(
        &[
            "scenario", "peak C", "thr ms", "iter ms", "Δiter", "J/iter",
            "ΔJ", "tok/J",
        ],
        &rows,
    ));
    Figure {
        id: "campaign_thermal",
        title: "Campaign — thermal coupling comparison".into(),
        ascii: out,
        csv,
        svg: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake(name: &str, tp: f64) -> ScenarioSummary {
        ScenarioSummary {
            name: name.into(),
            fingerprint: 1,
            label: "b1s4".into(),
            fsdp: "FSDPv1".into(),
            governor: "reactive".into(),
            sharding: "FSDP".into(),
            num_nodes: 1,
            fold: 1,
            node_iter_ms: Vec::new(),
            layers: 2,
            batch: 1,
            seq: 4096,
            tokens_per_sec: tp,
            iter_ms: 10.0,
            launch_ms: 1.0,
            fwd_ms: 3.0,
            bwd_ms: 6.0,
            opt_ms: 1.0,
            allgather_ms: 0.4,
            reduce_scatter_ms: 0.6,
            overlap_fa: 0.8,
            freq_mhz: 1900.0,
            freq_loss: 0.09,
            power_w: 700.0,
            energy_per_iter_j: 56.0,
            tokens_per_j: 120.0,
            span_ms: 25.0,
            events: 1234,
            offered_qps: 0.0,
            ttft_p99_ms: 0.0,
            tpot_p99_ms: 0.0,
            goodput_rps: 0.0,
            energy_per_request_j: 0.0,
            faults: String::new(),
            lost_ms: 0.0,
            blocked_ms: 0.0,
            peak_temp_c: 0.0,
            throttle_loss_ms: 0.0,
            status: "ok".into(),
        }
    }

    #[test]
    fn serving_table_keeps_only_serving_rows() {
        let mut sv = fake("L2-b1s4-FSDPv2-serve_q16", 900.0);
        sv.fsdp = "serving".into();
        sv.offered_qps = 16.0;
        sv.ttft_p99_ms = 120.5;
        sv.tpot_p99_ms = 5.25;
        sv.goodput_rps = 14.0;
        sv.energy_per_request_j = 250.0;
        let f = campaign_serving(&[fake("a", 1000.0), sv]);
        assert_eq!(f.id, "campaign_serving");
        // Header + exactly one serving row; the training row is skipped.
        assert_eq!(f.csv.lines().count(), 2);
        assert!(f.csv.contains("serve_q16"));
        assert!(!f.csv.lines().nth(1).unwrap().starts_with("a,"));
        assert!(f.ascii.contains("ttft p99"));
    }

    #[test]
    fn table_normalizes_to_first_scenario() {
        let f = campaign_table(&[fake("a", 1000.0), fake("b", 2000.0)]);
        assert!(f.ascii.contains("1.00x"));
        assert!(f.ascii.contains("2.00x"));
        let row_b = f.csv.lines().find(|l| l.starts_with("b,")).unwrap();
        let rel: f64 = row_b.split(',').nth(7).unwrap().parse().unwrap();
        assert!((rel - 2.0).abs() < 1e-9);
    }

    #[test]
    fn figures_render_nonempty() {
        let rows = vec![fake("a", 1000.0), fake("b", 1500.0)];
        for f in [campaign_table(&rows), campaign_breakdown(&rows)] {
            assert!(!f.ascii.trim().is_empty(), "{} ascii empty", f.id);
            assert!(f.csv.lines().count() >= 3, "{} csv short", f.id);
        }
    }

    #[test]
    fn topology_columns_only_when_multi_node() {
        let flat = campaign_table(&[fake("a", 1000.0)]);
        assert!(!flat.csv.contains("num_nodes"));
        assert!(!flat.ascii.contains("topo"));
        let mut h = fake("b-hsdp", 1500.0);
        h.sharding = "HSDP".into();
        h.num_nodes = 2;
        h.node_iter_ms = vec![9.5, 10.5];
        let multi = campaign_table(&[fake("a", 1000.0), h.clone()]);
        assert!(multi.csv.lines().next().unwrap().contains("num_nodes"));
        assert!(multi.ascii.contains("HSDPx2"));

        let nodes = campaign_by_nodes(&[fake("a", 1000.0), h]);
        // One row for the flat scenario, two for the 2-node one.
        assert_eq!(nodes.csv.lines().count(), 1 + 1 + 2);
        assert!(nodes.ascii.contains("node1"));
        // Slow node skews positive against the fastest.
        assert!(nodes.csv.contains("10.53"), "{}", nodes.csv);
    }

    #[test]
    fn fold_column_gated_and_topo_cell_tagged() {
        // Exact multi-node campaigns keep their pre-fold bytes: no fold
        // column, no /f tag.
        let mut h = fake("b-hsdp-N2", 1500.0);
        h.sharding = "HSDP".into();
        h.num_nodes = 2;
        let exact = campaign_table(&[fake("a", 1000.0), h.clone()]);
        assert!(!exact.csv.lines().next().unwrap().contains(",fold"));
        assert!(!exact.ascii.contains("/f"));
        // A folded scenario turns the column on and tags its topo cell.
        let mut fl = fake("c-hsdp-N64-fold32", 1400.0);
        fl.sharding = "HSDP".into();
        fl.num_nodes = 64;
        fl.fold = 32;
        fl.node_iter_ms = vec![10.0, 10.2];
        let tbl = campaign_table(&[fake("a", 1000.0), h.clone(), fl.clone()]);
        assert!(tbl.csv.lines().next().unwrap().contains(",fold"));
        assert!(tbl.ascii.contains("HSDPx64/f32"));
        // The exact sibling's row carries fold 1 in the CSV, no tag.
        let row_h = tbl.csv.lines().find(|l| l.starts_with("b-hsdp")).unwrap();
        assert!(row_h.ends_with(",HSDP,2,1"), "{row_h}");
        let row_f = tbl.csv.lines().find(|l| l.starts_with("c-hsdp")).unwrap();
        assert!(row_f.ends_with(",HSDP,64,32"), "{row_f}");
        // The per-node rollup tags the folded row too (its two entries
        // are the *simulated* representative nodes of 64 logical).
        let nodes = campaign_by_nodes(&[fl]);
        assert!(nodes.ascii.contains("HSDPx64/f32"));
        assert!(nodes.ascii.contains("node1"));
        assert!(!nodes.ascii.contains("node2"));
    }

    #[test]
    fn energy_columns_always_present_governor_column_gated() {
        let flat = campaign_table(&[fake("a", 1000.0)]);
        assert!(flat.csv.contains("energy_per_iter_j"));
        assert!(flat.ascii.contains("J/iter"));
        assert!(!flat.csv.contains("governor"));
        let mut o = fake("a-gov_oracle", 1200.0);
        o.governor = "oracle".into();
        o.iter_ms = 8.0;
        o.energy_per_iter_j = 70.0;
        let multi = campaign_table(&[fake("a", 1000.0), o.clone()]);
        assert!(multi.csv.lines().next().unwrap().ends_with(",governor"));
        assert!(multi.ascii.contains("oracle"));
    }

    #[test]
    fn governor_table_deltas_vs_reactive_sibling() {
        let mut o = fake("a-gov_oracle", 1200.0);
        o.governor = "oracle".into();
        o.iter_ms = 8.0; // 20% faster than the reactive 10.0
        o.energy_per_iter_j = 70.0; // 25% more energy than 56.0
        let f = campaign_by_governor(&[fake("a", 1000.0), o]);
        let oracle_row = f.csv.lines().find(|l| l.contains("oracle")).unwrap();
        let cols: Vec<&str> = oracle_row.split(',').collect();
        assert_eq!(cols[1], "oracle");
        let di: f64 = cols[3].parse().unwrap();
        let de: f64 = cols[5].parse().unwrap();
        assert!((di + 20.0).abs() < 1e-9, "Δiter {di}");
        assert!((de - 25.0).abs() < 1e-9, "Δenergy {de}");
        // The reactive row is its own baseline: zero deltas.
        let base_row = f.csv.lines().find(|l| l.starts_with("a,")).unwrap();
        let cols: Vec<&str> = base_row.split(',').collect();
        assert_eq!(cols[3], "0.00");
        assert_eq!(cols[5], "0.00");
    }

    #[test]
    fn fault_table_deltas_vs_healthy_sibling_and_shows_failures() {
        let healthy = fake("L2-b1s4-FSDPv1", 1000.0);
        let mut strag = fake("L2-b1s4-FSDPv1-flt_strag_f0_8", 800.0);
        strag.faults = "strag_f0_8".into();
        strag.iter_ms = 12.5; // 25% slower than the healthy 10.0
        strag.energy_per_iter_j = 70.0; // 25% more energy than 56.0
        strag.lost_ms = 0.0;
        strag.blocked_ms = 1.75;
        let mut dead = fake("L2-b1s4-FSDPv1-flt_panic", 0.0);
        dead.faults = "panic".into();
        dead.status = "failed".into();
        dead.iter_ms = 0.0;
        let f = campaign_faults(&[healthy, strag, dead]);
        assert_eq!(f.id, "campaign_faults");
        // Healthy baseline row is skipped; fault + failed rows render.
        assert_eq!(f.csv.lines().count(), 3);
        let row = f.csv.lines().find(|l| l.contains("strag")).unwrap();
        let cols: Vec<&str> = row.split(',').collect();
        assert_eq!(cols[2], "ok");
        let di: f64 = cols[4].parse().unwrap();
        let de: f64 = cols[6].parse().unwrap();
        assert!((di - 25.0).abs() < 1e-9, "Δiter {di}");
        assert!((de - 25.0).abs() < 1e-9, "Δenergy {de}");
        assert!(f.csv.contains("failed"));
        assert!(f.ascii.contains("panic"));
    }

    #[test]
    fn thermal_table_deltas_vs_disabled_sibling() {
        let cool = fake("L2-b1s4-FSDPv1", 1000.0);
        let mut hot = fake("L2-b1s4-FSDPv1-therm_a85", 900.0);
        hot.peak_temp_c = 96.5;
        hot.throttle_loss_ms = 1.25;
        hot.iter_ms = 12.0; // 20% slower than the disabled 10.0
        hot.energy_per_iter_j = 63.0; // 12.5% more energy than 56.0
        let f = campaign_thermal(&[cool, hot]);
        assert_eq!(f.id, "campaign_thermal");
        // Thermal-disabled baseline row is skipped; one thermal row.
        assert_eq!(f.csv.lines().count(), 2);
        let cols: Vec<&str> =
            f.csv.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(cols[1], "96.50");
        assert_eq!(cols[2], "1.2500");
        let di: f64 = cols[4].parse().unwrap();
        let de: f64 = cols[6].parse().unwrap();
        assert!((di - 20.0).abs() < 1e-9, "Δiter {di}");
        assert!((de - 12.5).abs() < 1e-9, "Δenergy {de}");
        assert!(f.ascii.contains("peak C"));
    }

    #[test]
    fn rendering_is_deterministic() {
        let rows = vec![fake("a", 1000.0), fake("b", 1500.0)];
        let x = campaign_table(&rows);
        let y = campaign_table(&rows);
        assert_eq!(x.ascii, y.ascii);
        assert_eq!(x.csv, y.csv);
    }
}
