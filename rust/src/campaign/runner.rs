//! Deterministic parallel fan-out over scenarios, plus the per-scenario
//! summary the cache persists and the comparison layer consumes.
//!
//! Workers are `std::thread::scope` threads pulling scenario indices from a
//! shared atomic counter; each result lands in its grid-order slot, so the
//! collected output is identical — byte for byte once rendered — to a
//! serial run. Per-scenario determinism comes from the engine itself (every
//! stochastic mechanism draws from seeded substreams, never from global
//! state), which `tests/campaign.rs` asserts end to end.

use crate::campaign::cache::{fingerprint, Cache};
use crate::campaign::grid::Scenario;
use crate::chopper::index::TraceIndex;
use crate::chopper::overlap::summarize_op_overlap;
use crate::chopper::throughput::throughput;
use crate::config::{NodeSpec, Topology};
use crate::model::ops::{OpRef, OpType, Phase};
use crate::sim::{run_workload_topo_with, ProfiledRun};
use crate::util::json::Json;
use crate::util::stats;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count: one per available hardware thread.
pub fn default_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `f` over `items` on up to `jobs` scoped threads; results come back
/// in input order regardless of completion order. `jobs <= 1` runs inline.
pub fn run_ordered<I, T, F>(items: &[I], jobs: usize, f: F) -> Vec<T>
where
    I: Sync,
    T: Send,
    F: Fn(usize, &I) -> T + Sync,
{
    let jobs = jobs.min(items.len()).max(1);
    if jobs <= 1 {
        return items.iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> =
        items.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let out = f(i, &items[i]);
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker filled every slot"))
        .collect()
}

/// The persisted per-scenario record: everything the comparison tables
/// need, small enough to keep thousands on disk. Durations in ms.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSummary {
    pub name: String,
    pub fingerprint: u64,
    pub label: String,
    pub fsdp: String,
    /// Power-management policy name (`sim::power::GovernorKind::name`).
    pub governor: String,
    /// Sharding strategy ("FSDP"/"HSDP").
    pub sharding: String,
    /// Nodes in the scenario topology (1 = classic single node). Always
    /// the *logical* cluster size — a folded scenario (DESIGN.md §13)
    /// reports the full cluster it stands for, not the simulated subset.
    pub num_nodes: u64,
    /// Replica fold factor (1 = exact mode). `num_nodes / fold` nodes
    /// were actually simulated; totals below are expanded to the logical
    /// cluster.
    pub fold: u64,
    /// Median per-iteration wall span of each *simulated* node, ms, node
    /// order (`num_nodes / fold` entries on folded scenarios).
    /// Empty on single-node scenarios (the rollup equals `iter_ms`).
    pub node_iter_ms: Vec<f64>,
    pub layers: u64,
    pub batch: u64,
    pub seq: u64,
    pub tokens_per_sec: f64,
    /// Median per-iteration cost of the slowest GPU.
    pub iter_ms: f64,
    pub launch_ms: f64,
    /// Median per-(gpu,iter) summed compute duration by phase.
    pub fwd_ms: f64,
    pub bwd_ms: f64,
    pub opt_ms: f64,
    /// Median communication kernel durations (sampled iterations).
    pub allgather_ms: f64,
    pub reduce_scatter_ms: f64,
    /// Median overlap ratio of f_attn_fa (the paper's Fig. 9 quantity).
    pub overlap_fa: f64,
    /// Mean GPU frequency over active windows (power > 400 W).
    pub freq_mhz: f64,
    /// DVFS overhead: fraction of peak frequency lost, (peak-f)/peak.
    pub freq_loss: f64,
    pub power_w: f64,
    /// Joules per sampled iteration, summed over every rank (the
    /// governor's window-sum of power × dt).
    pub energy_per_iter_j: f64,
    /// Perf per watt: tokens per joule at this scenario's energy cost.
    pub tokens_per_j: f64,
    pub span_ms: f64,
    pub events: u64,
    /// Offered load in requests/s — 0 on training scenarios, where the
    /// serving block below stays off the wire entirely (training summary
    /// JSON keeps its pre-serving bytes).
    pub offered_qps: f64,
    /// p99 time-to-first-token, ms.
    pub ttft_p99_ms: f64,
    /// p99 time-per-output-token, ms.
    pub tpot_p99_ms: f64,
    /// Completed requests per second of makespan.
    pub goodput_rps: f64,
    /// Sampled energy divided by completed requests, joules.
    pub energy_per_request_j: f64,
    /// Injected fault-set label ("" = healthy scenario; the fault block
    /// below stays off the wire so healthy summary JSON keeps its
    /// pre-fault bytes).
    pub faults: String,
    /// Wall-clock lost to GPU dropout + checkpoint-restart, ms.
    pub lost_ms: f64,
    /// Time ranks spent blocked at collectives waiting on slower peers
    /// (straggler drag), summed over ranks and sampled iterations, ms.
    pub blocked_ms: f64,
    /// Peak die temperature across simulated GPUs, °C (0.0 = the thermal
    /// model was off; the thermal block below stays off the wire so
    /// thermal-off summary JSON keeps its pre-thermal bytes).
    pub peak_temp_c: f64,
    /// Clock capacity lost to thermal throttling per sampled iteration,
    /// summed over the logical cluster, ms (throttle loss × fold, the
    /// same expansion as energy).
    pub throttle_loss_ms: f64,
    /// "ok", or "failed" when the scenario panicked and was isolated by
    /// the runner (numeric columns are zero; the entry is not cached, so
    /// `--resume` retries it).
    pub status: String,
}

impl Default for ScenarioSummary {
    fn default() -> Self {
        Self {
            name: String::new(),
            fingerprint: 0,
            label: String::new(),
            fsdp: String::new(),
            governor: "reactive".into(),
            sharding: "FSDP".into(),
            num_nodes: 1,
            fold: 1,
            node_iter_ms: Vec::new(),
            layers: 0,
            batch: 0,
            seq: 0,
            tokens_per_sec: 0.0,
            iter_ms: 0.0,
            launch_ms: 0.0,
            fwd_ms: 0.0,
            bwd_ms: 0.0,
            opt_ms: 0.0,
            allgather_ms: 0.0,
            reduce_scatter_ms: 0.0,
            overlap_fa: 0.0,
            freq_mhz: 0.0,
            freq_loss: 0.0,
            power_w: 0.0,
            energy_per_iter_j: 0.0,
            tokens_per_j: 0.0,
            span_ms: 0.0,
            events: 0,
            offered_qps: 0.0,
            ttft_p99_ms: 0.0,
            tpot_p99_ms: 0.0,
            goodput_rps: 0.0,
            energy_per_request_j: 0.0,
            faults: String::new(),
            lost_ms: 0.0,
            blocked_ms: 0.0,
            peak_temp_c: 0.0,
            throttle_loss_ms: 0.0,
            status: "ok".into(),
        }
    }
}

fn num(j: &Json, k: &str) -> Result<f64, String> {
    j.get(k)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| format!("summary missing number `{k}`"))
}

fn text(j: &Json, k: &str) -> Result<String, String> {
    j.get(k)
        .and_then(|v| v.as_str())
        .map(|s| s.to_string())
        .ok_or_else(|| format!("summary missing string `{k}`"))
}

impl ScenarioSummary {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(self.name.clone())),
            // u64 doesn't round-trip through f64 above 2^53; store as hex.
            ("fingerprint", Json::str(format!("{:016x}", self.fingerprint))),
            ("label", Json::str(self.label.clone())),
            ("fsdp", Json::str(self.fsdp.clone())),
            ("governor", Json::str(self.governor.clone())),
        ];
        // Topology fields serialize only when non-degenerate, so classic
        // single-node FSDP summaries keep their pre-topology JSON bytes
        // (asserted against the vendored baseline in tests/pipeline.rs).
        if self.num_nodes > 1 || self.sharding != "FSDP" {
            fields.push(("sharding", Json::str(self.sharding.clone())));
            fields.push(("num_nodes", Json::num(self.num_nodes as f64)));
            // The fold factor serializes only when folding actually
            // happened, so exact-mode summaries keep their pre-fold bytes
            // (same discipline as the topology block itself).
            if self.fold > 1 {
                fields.push(("fold", Json::num(self.fold as f64)));
            }
            fields.push((
                "node_iter_ms",
                Json::Arr(self.node_iter_ms.iter().map(|&v| Json::num(v)).collect()),
            ));
        }
        fields.extend(vec![
            ("layers", Json::num(self.layers as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("seq", Json::num(self.seq as f64)),
            ("tokens_per_sec", Json::num(self.tokens_per_sec)),
            ("iter_ms", Json::num(self.iter_ms)),
            ("launch_ms", Json::num(self.launch_ms)),
            ("fwd_ms", Json::num(self.fwd_ms)),
            ("bwd_ms", Json::num(self.bwd_ms)),
            ("opt_ms", Json::num(self.opt_ms)),
            ("allgather_ms", Json::num(self.allgather_ms)),
            ("reduce_scatter_ms", Json::num(self.reduce_scatter_ms)),
            ("overlap_fa", Json::num(self.overlap_fa)),
            ("freq_mhz", Json::num(self.freq_mhz)),
            ("freq_loss", Json::num(self.freq_loss)),
            ("power_w", Json::num(self.power_w)),
            ("energy_per_iter_j", Json::num(self.energy_per_iter_j)),
            ("tokens_per_j", Json::num(self.tokens_per_j)),
            ("span_ms", Json::num(self.span_ms)),
            ("events", Json::num(self.events as f64)),
        ]);
        // Serving fields serialize only on serving scenarios, so training
        // summaries keep their pre-serving JSON bytes (same discipline as
        // the topology block above).
        if self.offered_qps > 0.0 {
            fields.extend(vec![
                ("offered_qps", Json::num(self.offered_qps)),
                ("ttft_p99_ms", Json::num(self.ttft_p99_ms)),
                ("tpot_p99_ms", Json::num(self.tpot_p99_ms)),
                ("goodput_rps", Json::num(self.goodput_rps)),
                (
                    "energy_per_request_j",
                    Json::num(self.energy_per_request_j),
                ),
            ]);
        }
        // Fault/robustness fields serialize only on faulted or failed
        // scenarios, so healthy summaries keep their pre-fault JSON bytes
        // (same discipline as the topology and serving blocks above).
        if !self.faults.is_empty() || self.status != "ok" {
            fields.extend(vec![
                ("faults", Json::str(self.faults.clone())),
                ("lost_ms", Json::num(self.lost_ms)),
                ("blocked_ms", Json::num(self.blocked_ms)),
                ("status", Json::str(self.status.clone())),
            ]);
        }
        // Thermal fields serialize only when the RC model ran (peak die
        // temperature 0.0 doubles as the "no thermal data" marker, the
        // same convention as `PowerSample::temp_c`), so thermal-off
        // summaries keep their pre-thermal JSON bytes.
        if self.peak_temp_c != 0.0 {
            fields.extend(vec![
                ("peak_temp_c", Json::num(self.peak_temp_c)),
                ("throttle_loss_ms", Json::num(self.throttle_loss_ms)),
            ]);
        }
        Json::obj(fields)
    }

    pub fn to_json_str(&self) -> String {
        // Summaries serialize to ~700 bytes; one reservation, zero regrows.
        self.to_json().to_string_with_capacity(1024)
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let fp_hex = text(j, "fingerprint")?;
        let fingerprint = u64::from_str_radix(&fp_hex, 16)
            .map_err(|_| format!("bad fingerprint `{fp_hex}`"))?;
        // Governor / energy fields default so pre-power-subsystem
        // artifacts still parse (their fingerprints differ, so they read
        // as cache misses anyway — this keeps the parser total).
        let governor = j
            .get("governor")
            .and_then(|v| v.as_str())
            .unwrap_or("reactive")
            .to_string();
        let energy_per_iter_j = j
            .get("energy_per_iter_j")
            .and_then(|v| v.as_f64())
            .unwrap_or(0.0);
        let tokens_per_j =
            j.get("tokens_per_j").and_then(|v| v.as_f64()).unwrap_or(0.0);
        // Topology fields default to the degenerate single-node shape so
        // pre-topology artifacts still parse (their fingerprints differ,
        // so they read as cache misses anyway — this keeps the parser
        // total, not the cache warm).
        let sharding = j
            .get("sharding")
            .and_then(|v| v.as_str())
            .unwrap_or("FSDP")
            .to_string();
        let num_nodes = j
            .get("num_nodes")
            .and_then(|v| v.as_f64())
            .unwrap_or(1.0) as u64;
        // Pre-fold artifacts (and all exact-mode summaries) carry no fold
        // field; 1 is the exact-mode identity.
        let fold = j.get("fold").and_then(|v| v.as_f64()).unwrap_or(1.0) as u64;
        let node_iter_ms = j
            .get("node_iter_ms")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
            .unwrap_or_default();
        // Serving fields default to zero on training artifacts (the block
        // is only written for serving scenarios).
        let serving_num =
            |k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        // Fault/robustness fields default to the healthy shape on
        // pre-fault artifacts (the block is only written when faulted or
        // failed).
        let faults = j
            .get("faults")
            .and_then(|v| v.as_str())
            .unwrap_or("")
            .to_string();
        let status = j
            .get("status")
            .and_then(|v| v.as_str())
            .unwrap_or("ok")
            .to_string();
        Ok(Self {
            name: text(j, "name")?,
            fingerprint,
            label: text(j, "label")?,
            fsdp: text(j, "fsdp")?,
            governor,
            sharding,
            num_nodes,
            fold,
            node_iter_ms,
            layers: num(j, "layers")? as u64,
            batch: num(j, "batch")? as u64,
            seq: num(j, "seq")? as u64,
            tokens_per_sec: num(j, "tokens_per_sec")?,
            iter_ms: num(j, "iter_ms")?,
            launch_ms: num(j, "launch_ms")?,
            fwd_ms: num(j, "fwd_ms")?,
            bwd_ms: num(j, "bwd_ms")?,
            opt_ms: num(j, "opt_ms")?,
            allgather_ms: num(j, "allgather_ms")?,
            reduce_scatter_ms: num(j, "reduce_scatter_ms")?,
            overlap_fa: num(j, "overlap_fa")?,
            freq_mhz: num(j, "freq_mhz")?,
            freq_loss: num(j, "freq_loss")?,
            power_w: num(j, "power_w")?,
            energy_per_iter_j,
            tokens_per_j,
            span_ms: num(j, "span_ms")?,
            events: num(j, "events")? as u64,
            offered_qps: serving_num("offered_qps"),
            ttft_p99_ms: serving_num("ttft_p99_ms"),
            tpot_p99_ms: serving_num("tpot_p99_ms"),
            goodput_rps: serving_num("goodput_rps"),
            energy_per_request_j: serving_num("energy_per_request_j"),
            faults,
            lost_ms: serving_num("lost_ms"),
            blocked_ms: serving_num("blocked_ms"),
            // Thermal fields default to the thermal-off shape on
            // pre-thermal artifacts (the block is only written when the
            // RC model ran).
            peak_temp_c: serving_num("peak_temp_c"),
            throttle_loss_ms: serving_num("throttle_loss_ms"),
            status,
        })
    }

    pub fn from_json_str(s: &str) -> Result<Self, String> {
        Self::from_json(&crate::util::json::parse(s)?)
    }
}

/// Reduce one profiled run to its persisted summary. Builds the shared
/// [`TraceIndex`] once; every summarized quantity is a query against it.
pub fn summarize(
    node: &NodeSpec,
    sc: &Scenario,
    fp: u64,
    run: &ProfiledRun,
) -> ScenarioSummary {
    summarize_indexed(node, sc, fp, run, TraceIndex::build(&run.trace))
}

/// [`summarize`] against a caller-supplied index. The chunk-wise store
/// restore path builds its index incrementally ([`IndexBuilder`] fed while
/// the store streams in canonical order) and hands it here, skipping the
/// second full-trace pass `TraceIndex::build` would cost; both index
/// construction paths aggregate identically, so the summaries are
/// byte-identical.
pub fn summarize_indexed<'t>(
    node: &NodeSpec,
    sc: &Scenario,
    fp: u64,
    run: &'t ProfiledRun,
    idx: TraceIndex<'t>,
) -> ScenarioSummary {
    let trace = &run.trace;
    // Logical-cluster accounting under replica folding (DESIGN.md §13):
    // the trace holds `num_gpus` *simulated* ranks standing for
    // `logical_gpus()` logical ones, and per-rank totals expand by the
    // fold factor. In exact mode both factors are the identity, so every
    // expression below is bit-identical to the pre-fold pipeline.
    let fold = trace.meta.fold_factor() as f64;
    let tokens =
        sc.wl.tokens_per_iteration(trace.meta.logical_gpus() as u64) as f64;
    let tp = throughput(&idx, tokens);

    // Per-(gpu, iter) summed compute duration by phase → median
    // (precomputed by the index in event order, sampled iters only).
    let phase_median = |ph: Phase| -> f64 {
        let xs: Vec<f64> = idx
            .phase_dur()
            .iter()
            .filter(|((p, _, _), _)| *p == ph)
            .map(|(_, v)| *v)
            .collect();
        if xs.is_empty() {
            0.0
        } else {
            stats::median(&xs) / 1e6
        }
    };

    let comm_median = |op: OpType| -> f64 {
        let xs = idx.comm_durations(op);
        if xs.is_empty() {
            0.0
        } else {
            stats::median(xs) / 1e6
        }
    };

    let fa = summarize_op_overlap(&idx, OpRef::fwd(OpType::AttnFa));

    // Active-window telemetry, the paper's Fig. 14 averaging
    // (PowerTrace::active_samples — same filter, same order, as the
    // pre-refactor inline scan, so the means are bit-identical).
    let freqs: Vec<f64> =
        run.power.active_samples().map(|s| s.freq_mhz).collect();
    let powers: Vec<f64> =
        run.power.active_samples().map(|s| s.power_w).collect();
    let freq_mhz = finite(stats::mean(&freqs));
    let peak = node.gpu.freq_peak_mhz.max(1.0);
    // No active windows (degenerate workload): report zero DVFS loss
    // rather than "100% of peak lost" to a frequency that never existed.
    let freq_loss = if freqs.is_empty() {
        0.0
    } else {
        ((peak - freq_mhz) / peak).max(0.0)
    };

    // Energy integration (sim::power): joules per sampled iteration
    // summed over every rank — the governor's window-sum of power × dt —
    // and the perf-per-watt it implies. Computed directly over the power
    // samples in emission order (bit-stable; the vendored baseline
    // summarize accumulates identically).
    let warmup = trace.meta.warmup;
    let sampled_iters =
        trace.meta.iterations.saturating_sub(warmup).max(1) as f64;
    // Folded scenarios simulate one replica class; every class draws the
    // same power (replicas are exact copies), so the logical cluster's
    // energy is the simulated total × fold (×1.0 is exact in IEEE 754,
    // preserving fold-1 byte identity).
    let energy_per_iter_j =
        finite(run.power.sampled_energy_j(warmup) * fold / sampled_iters);
    let tokens_per_j = if energy_per_iter_j > 0.0 {
        finite(tokens / energy_per_iter_j)
    } else {
        0.0
    };

    // Per-node rollup: only materialized on multi-node topologies (on one
    // node it duplicates `iter_ms`, and omitting it keeps the summary
    // JSON byte-identical to the pre-topology schema). The reported node
    // count is the *logical* cluster; the rollup entries are the
    // simulated (representative) nodes.
    let num_nodes = trace.meta.logical_nodes() as u64;
    let node_iter_ms: Vec<f64> = if num_nodes > 1 {
        idx.node_iter_medians()
            .iter()
            .map(|&v| finite(v / 1e6))
            .collect()
    } else {
        Vec::new()
    };

    // Blocked-on-straggler drag is only materialized on faulted runs:
    // healthy runs have (jitter-scale) blocked time too, but keeping the
    // field at 0.0 there means cached and freshly-computed healthy
    // summaries stay identical (the fault block is off the wire).
    let blocked_ms = if trace.meta.faults.is_empty() {
        0.0
    } else {
        // Summed over ranks, so it expands to the logical cluster like
        // energy does (only fold-compatible faults reach a folded run).
        finite(idx.blocked_on_straggler_ns() * fold / 1e6)
    };

    // Thermal telemetry (sim::thermal, DESIGN.md §14): only materialized
    // when the run carried thermal samples, so thermal-off summaries stay
    // bit-identical to the pre-thermal pipeline. Throttle loss is summed
    // over ranks, so it expands to the logical cluster like energy does
    // (each replica class's siblings carry the representative's envelope).
    let (peak_temp_c, throttle_loss_ms) = if run.power.has_thermal() {
        (
            finite(run.power.peak_temp_c()),
            finite(
                run.power.sampled_throttle_loss_ns(warmup) * fold
                    / sampled_iters
                    / 1e6,
            ),
        )
    } else {
        (0.0, 0.0)
    };

    ScenarioSummary {
        name: sc.name.clone(),
        fingerprint: fp,
        label: sc.wl.label(),
        fsdp: sc.wl.fsdp.to_string(),
        governor: sc.params.governor.name().to_string(),
        sharding: sc.wl.sharding.to_string(),
        num_nodes,
        fold: trace.meta.fold_factor() as u64,
        node_iter_ms,
        layers: sc.model.layers,
        batch: sc.wl.batch,
        seq: sc.wl.seq,
        tokens_per_sec: finite(tp.tokens_per_sec),
        iter_ms: finite(tp.iter_ns / 1e6),
        launch_ms: finite(tp.launch_ns / 1e6),
        fwd_ms: phase_median(Phase::Forward),
        bwd_ms: phase_median(Phase::Backward),
        opt_ms: phase_median(Phase::Optimizer),
        allgather_ms: comm_median(OpType::AllGather),
        reduce_scatter_ms: comm_median(OpType::ReduceScatter),
        overlap_fa: finite(fa.ratio_q[2]),
        freq_mhz,
        freq_loss,
        power_w: finite(stats::mean(&powers)),
        energy_per_iter_j,
        tokens_per_j,
        span_ms: finite(trace.span_ns() / 1e6),
        events: trace.events.len() as u64,
        offered_qps: 0.0,
        ttft_p99_ms: 0.0,
        tpot_p99_ms: 0.0,
        goodput_rps: 0.0,
        energy_per_request_j: 0.0,
        faults: trace.meta.faults.clone(),
        lost_ms: finite(trace.meta.fault_lost_ns / 1e6),
        blocked_ms,
        peak_temp_c,
        throttle_loss_ms,
        status: "ok".into(),
    }
}

/// Reduce one serving run to its persisted summary — the serving
/// counterpart of [`summarize`]. Training columns with no serving meaning
/// (phase/communication medians, launch overhead, overlap) summarize to
/// zero; an "iteration" is one continuous-batching step, and the serving
/// block carries the latency/goodput/energy quantities the comparison
/// layer and CLI tables consume.
pub fn summarize_serving(
    node: &NodeSpec,
    sc: &Scenario,
    fp: u64,
    out: &crate::serve::ServingOutput,
) -> ScenarioSummary {
    let rep = &out.report;
    let trace = &out.trace;
    let steps = rep.steps.max(1) as f64;

    // Active-window telemetry, identical averaging to the training path.
    let freqs: Vec<f64> =
        out.power.active_samples().map(|s| s.freq_mhz).collect();
    let powers: Vec<f64> =
        out.power.active_samples().map(|s| s.power_w).collect();
    let freq_mhz = finite(stats::mean(&freqs));
    let peak = node.gpu.freq_peak_mhz.max(1.0);
    let freq_loss = if freqs.is_empty() {
        0.0
    } else {
        ((peak - freq_mhz) / peak).max(0.0)
    };

    ScenarioSummary {
        name: sc.name.clone(),
        fingerprint: fp,
        label: rep.label.clone(),
        fsdp: "serving".into(),
        governor: sc.params.governor.name().to_string(),
        sharding: sc.wl.sharding.to_string(),
        num_nodes: trace.meta.nodes() as u64,
        fold: 1,
        node_iter_ms: Vec::new(),
        layers: sc.model.layers,
        batch: sc.wl.batch,
        seq: sc.wl.seq,
        // Generated-token throughput (prefill tokens are not counted).
        tokens_per_sec: finite(rep.output_tok_s),
        iter_ms: finite(rep.makespan_s * 1e3 / steps),
        launch_ms: 0.0,
        fwd_ms: 0.0,
        bwd_ms: 0.0,
        opt_ms: 0.0,
        allgather_ms: 0.0,
        reduce_scatter_ms: 0.0,
        overlap_fa: 0.0,
        freq_mhz,
        freq_loss,
        power_w: finite(stats::mean(&powers)),
        energy_per_iter_j: finite(out.power.sampled_energy_j(0) / steps),
        tokens_per_j: finite(rep.tok_per_joule),
        span_ms: finite(trace.span_ns() / 1e6),
        events: trace.events.len() as u64,
        offered_qps: finite(rep.offered_qps),
        ttft_p99_ms: finite(rep.ttft_ms.p99),
        tpot_p99_ms: finite(rep.tpot_ms.p99),
        goodput_rps: finite(rep.goodput_rps),
        energy_per_request_j: finite(rep.energy_per_request_j),
        faults: trace.meta.faults.clone(),
        lost_ms: finite(trace.meta.fault_lost_ns / 1e6),
        blocked_ms: 0.0,
        peak_temp_c: if out.power.has_thermal() {
            finite(out.power.peak_temp_c())
        } else {
            0.0
        },
        throttle_loss_ms: if out.power.has_thermal() {
            finite(out.power.sampled_throttle_loss_ns(0) / steps / 1e6)
        } else {
            0.0
        },
        status: "ok".into(),
    }
}

/// NaN/inf would serialize as invalid JSON (and poison the cache with
/// permanently-missing artifacts); degenerate inputs summarize to 0.
fn finite(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

/// Outcome of one campaign run.
#[derive(Debug)]
pub struct CampaignOutcome {
    /// Per-scenario summaries in grid order.
    pub summaries: Vec<ScenarioSummary>,
    /// Scenarios that actually ran the engine.
    pub executed: usize,
    /// Scenarios served from the on-disk cache.
    pub cached: usize,
    /// Scenarios whose summary was rebuilt from a finalized on-disk trace
    /// store (`campaign --trace-store` + `--resume`) instead of re-running
    /// the engine. Salvaged (partially recovered) stores never count here —
    /// they are reported and the scenario re-runs.
    pub restored: usize,
    /// Scenarios that panicked and were isolated (status "failed").
    pub failed: usize,
}

/// The placeholder summary of a scenario whose engine run panicked: name
/// and grid coordinates survive (so the comparison tables keep their
/// row), numeric columns are zero, and `status` is "failed". It is never
/// written to the cache, so `campaign --resume` retries exactly these.
fn failed_summary(sc: &Scenario, fp: u64) -> ScenarioSummary {
    ScenarioSummary {
        name: sc.name.clone(),
        fingerprint: fp,
        label: sc.wl.label(),
        fsdp: sc.wl.fsdp.to_string(),
        governor: sc.params.governor.name().to_string(),
        sharding: sc.wl.sharding.to_string(),
        num_nodes: sc.num_nodes as u64,
        fold: sc.fold.max(1) as u64,
        layers: sc.model.layers,
        batch: sc.wl.batch,
        seq: sc.wl.seq,
        // "" = healthy, matching `TraceMeta::faults` on normal runs.
        faults: if sc.params.faults.is_empty() {
            String::new()
        } else {
            crate::config::faults::set_label(&sc.params.faults)
        },
        status: "failed".into(),
        ..ScenarioSummary::default()
    }
}

/// Run every scenario (parallel fan-out, grid-order results). With a cache,
/// scenarios whose fingerprint already has an artifact are loaded instead
/// of executed — unless `force` bypasses lookups (results are still
/// re-stored, refreshing the artifacts). Each scenario's topology is
/// composed from the campaign's per-node hardware and the scenario's node
/// count + NIC axes.
pub fn run_campaign(
    node: &NodeSpec,
    scenarios: &[Scenario],
    jobs: usize,
    cache: Option<&Cache>,
    force: bool,
) -> CampaignOutcome {
    run_campaign_stored(node, scenarios, jobs, cache, force, false, false)
}

/// Rebuild a scenario summary from a previously finalized trace store on
/// disk, if one exists. Only a clean, finalized, never-salvaged store
/// qualifies: [`summarize`] is a pure function of the trace and power
/// telemetry, so a summary rebuilt from a complete store is identical to
/// the one the original run produced — while a salvaged prefix is not, so
/// it is reported on stderr and the scenario re-runs instead.
///
/// The default read path is chunk-wise ([`read_store_visit`]): the
/// [`IndexBuilder`] is fed every event as the store streams in canonical
/// order, so the index is finished in the same pass that materializes the
/// trace. `chopper campaign --in-memory` flips this to the materialized
/// `read_store` + `TraceIndex::build` path; both produce byte-identical
/// summaries (`tests/store.rs` pins the underlying trace equality).
fn restore_from_store(
    node: &NodeSpec,
    sc: &Scenario,
    fp: u64,
    cache: &Cache,
    in_memory: bool,
) -> Option<ScenarioSummary> {
    let path = cache.store_path_for(&sc.name, fp);
    if !path.exists() {
        return None;
    }
    let mut builder: Option<crate::chopper::IndexBuilder> = None;
    let loaded = if in_memory {
        crate::trace::store::read_store(&path)
    } else {
        crate::trace::store::read_store_visit(&path, |m, e| {
            builder
                .get_or_insert_with(|| {
                    crate::chopper::IndexBuilder::new(m.warmup)
                })
                .push(e);
        })
    };
    let loaded = match loaded {
        Ok(l) => l,
        Err(e) => {
            eprintln!(
                "campaign: unreadable store {} ({e}); re-running scenario",
                path.display()
            );
            return None;
        }
    };
    if !loaded.report.clean() || loaded.report.salvaged_upstream {
        eprintln!(
            "campaign: store {} is {}; re-running scenario",
            path.display(),
            loaded.report.describe()
        );
        return None;
    }
    let run = ProfiledRun {
        trace: loaded.trace,
        power: loaded.power,
        counters: Default::default(),
        cpu: Default::default(),
        alloc: Default::default(),
        iter_bounds: loaded.iter_bounds,
    };
    let idx = match builder {
        Some(b) => b.finish(&run.trace),
        // `--in-memory` (or an event-free store): the classic full-pass
        // build over the materialized trace.
        None => TraceIndex::build(&run.trace),
    };
    Some(summarize_indexed(node, sc, fp, &run, idx))
}

/// Execute one training scenario with the engine streaming events straight
/// into an on-disk trace store (bounded memory: chunks flush at iteration
/// boundaries), then reload the finalized store and summarize from the
/// reloaded copy. Summarizing from the bytes on disk — not the in-memory
/// trace — means every `--trace-store` campaign continuously verifies the
/// round trip; a format defect can never hide behind the original vector.
fn run_streamed(
    topo: &Topology,
    sc: &Scenario,
    store_path: &std::path::Path,
) -> Result<ProfiledRun, String> {
    use crate::trace::store::{read_store, SharedSink, StoreWriter};
    use std::cell::RefCell;
    use std::rc::Rc;
    let meta = crate::sim::provisional_meta(topo, &sc.wl);
    let w = StoreWriter::create(store_path, &meta)
        .map_err(|e| crate::util::io_ctx("creating", store_path, e))?;
    let shared = Rc::new(RefCell::new(w));
    let mut run = crate::sim::run_workload_topo_sink(
        topo,
        &sc.model,
        &sc.wl,
        sc.params.clone(),
        Box::new(SharedSink(shared.clone())),
    );
    // The engine dropped its sink handle when the run ended, so the Rc is
    // unique again and the writer can be finalized by value.
    let w = Rc::try_unwrap(shared)
        .map_err(|_| "store writer still shared after run".to_string())?
        .into_inner();
    w.finalize(&run.trace.meta, &run.power, &run.iter_bounds)
        .map_err(|e| crate::util::io_ctx("finalizing", store_path, e))?;
    let loaded = read_store(store_path)?;
    if !loaded.report.clean() {
        return Err(format!(
            "freshly finalized store is {}",
            loaded.report.describe()
        ));
    }
    run.trace = loaded.trace;
    run.power = loaded.power;
    run.iter_bounds = loaded.iter_bounds;
    Ok(run)
}

/// [`run_campaign`] with an explicit trace-store switch (`campaign
/// --trace-store`). With it on (and a cache present), training scenarios
/// stream their events to `<cache>/<name>-<fp:016x>.ctrc` while running and
/// are summarized from the reloaded store; on resume, a finalized store can
/// rebuild a missing summary without re-running the engine. Store failures
/// of any kind degrade to the plain in-memory path — the sweep's results
/// never depend on disk health, only its speed does.
///
/// `in_memory` selects the store *read* path on those rebuilds: the
/// default (`false`) streams chunk-wise through [`read_store_visit`] with
/// the index built in the same pass; `campaign --in-memory` materializes
/// first and indexes after, the pre-chunk-wise behavior.
pub fn run_campaign_stored(
    node: &NodeSpec,
    scenarios: &[Scenario],
    jobs: usize,
    cache: Option<&Cache>,
    force: bool,
    trace_store: bool,
    in_memory: bool,
) -> CampaignOutcome {
    let executed = AtomicUsize::new(0);
    let cached = AtomicUsize::new(0);
    let restored = AtomicUsize::new(0);
    let failed = AtomicUsize::new(0);
    let summaries = run_ordered(scenarios, jobs, |_, sc| {
        let fp = fingerprint(node, sc);
        if !force {
            if let Some(hit) = cache.and_then(|c| c.load(&sc.name, fp)) {
                cached.fetch_add(1, Ordering::Relaxed);
                return hit;
            }
            // Summary artifact missing (crashed before the write, or
            // deleted) but the trace store survived: rebuild the summary
            // from disk instead of burning an engine run.
            if trace_store && sc.serving.is_none() {
                if let Some(c) = cache {
                    if let Some(summary) =
                        restore_from_store(node, sc, fp, c, in_memory)
                    {
                        // Heal the summary artifact so the next resume is
                        // a plain cache hit.
                        let _ = c.store(&summary);
                        restored.fetch_add(1, Ordering::Relaxed);
                        return summary;
                    }
                }
            }
        }
        // Per-scenario panic isolation: one scenario blowing up (an
        // engine bug on some corner of the grid, or the deliberate
        // `panic` fault) must not lose the rest of a long sweep. The
        // closure only touches per-scenario state, so unwinding cannot
        // leave shared state inconsistent (AssertUnwindSafe is sound).
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || {
                let topo = Topology {
                    node: node.clone(),
                    num_nodes: sc.num_nodes,
                    nic: sc.nic.clone(),
                    fold: sc.fold.max(1),
                };
                if let Some(scfg) = &sc.serving {
                    let out = crate::serve::run_serving(
                        &topo,
                        &sc.model,
                        scfg,
                        sc.params.clone(),
                    );
                    summarize_serving(node, sc, fp, &out)
                } else {
                    let run = match (trace_store, cache) {
                        (true, Some(c)) => {
                            let sp = c.store_path_for(&sc.name, fp);
                            match run_streamed(&topo, sc, &sp) {
                                Ok(run) => run,
                                Err(e) => {
                                    eprintln!(
                                        "campaign: trace store for {} \
                                         unusable ({e}); re-running \
                                         in memory",
                                        sc.name
                                    );
                                    run_workload_topo_with(
                                        &topo,
                                        &sc.model,
                                        &sc.wl,
                                        sc.params.clone(),
                                    )
                                }
                            }
                        }
                        _ => run_workload_topo_with(
                            &topo,
                            &sc.model,
                            &sc.wl,
                            sc.params.clone(),
                        ),
                    };
                    summarize(node, sc, fp, &run)
                }
            },
        ));
        match result {
            Ok(summary) => {
                if let Some(c) = cache {
                    // Best-effort: a failed write only costs a re-run.
                    let _ = c.store(&summary);
                }
                executed.fetch_add(1, Ordering::Relaxed);
                summary
            }
            Err(_) => {
                // Deliberately not cached: `--resume` must retry it.
                failed.fetch_add(1, Ordering::Relaxed);
                failed_summary(sc, fp)
            }
        }
    });
    CampaignOutcome {
        summaries,
        executed: executed.load(Ordering::Relaxed),
        cached: cached.load(Ordering::Relaxed),
        restored: restored.load(Ordering::Relaxed),
        failed: failed.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_ordered_preserves_input_order() {
        let items: Vec<usize> = (0..37).collect();
        let serial = run_ordered(&items, 1, |i, x| i * 1000 + *x);
        let parallel = run_ordered(&items, 4, |i, x| i * 1000 + *x);
        assert_eq!(serial, parallel);
        assert_eq!(serial[5], 5005);
    }

    #[test]
    fn run_ordered_handles_empty_and_oversubscribed() {
        let empty: Vec<u32> = Vec::new();
        assert!(run_ordered(&empty, 8, |_, x| *x).is_empty());
        let one = vec![7u32];
        assert_eq!(run_ordered(&one, 8, |_, x| *x + 1), vec![8]);
    }

    #[test]
    fn summary_json_roundtrip_is_exact() {
        let s = ScenarioSummary {
            name: "L2-b1s4-FSDPv1".into(),
            fingerprint: 0xdeadbeef12345678,
            label: "b1s4".into(),
            fsdp: "FSDPv1".into(),
            governor: "reactive".into(),
            sharding: "FSDP".into(),
            num_nodes: 1,
            fold: 1,
            node_iter_ms: Vec::new(),
            layers: 2,
            batch: 1,
            seq: 4096,
            tokens_per_sec: 12345.6789012345,
            iter_ms: 3.14159,
            launch_ms: 0.25,
            fwd_ms: 1.0 / 3.0,
            bwd_ms: 2.0 / 3.0,
            opt_ms: 0.1,
            allgather_ms: 0.5,
            reduce_scatter_ms: 0.75,
            overlap_fa: 0.875,
            freq_mhz: 1870.123456,
            freq_loss: 0.1234567890123,
            power_w: 698.7,
            energy_per_iter_j: 42.125,
            tokens_per_j: 97.53,
            span_ms: 123.456,
            events: 9999,
            offered_qps: 0.0,
            ttft_p99_ms: 0.0,
            tpot_p99_ms: 0.0,
            goodput_rps: 0.0,
            energy_per_request_j: 0.0,
            faults: String::new(),
            lost_ms: 0.0,
            blocked_ms: 0.0,
            peak_temp_c: 0.0,
            throttle_loss_ms: 0.0,
            status: "ok".into(),
        };
        let back = ScenarioSummary::from_json_str(&s.to_json_str()).unwrap();
        assert_eq!(s, back);
        // Twice through the wire must be byte-stable.
        assert_eq!(s.to_json_str(), back.to_json_str());
        // Degenerate topology fields stay off the wire entirely.
        assert!(!s.to_json_str().contains("num_nodes"));
        // Training summaries carry no serving block at all.
        assert!(!s.to_json_str().contains("offered_qps"));
        // Healthy summaries carry no fault/status block at all.
        assert!(!s.to_json_str().contains("faults"));
        assert!(!s.to_json_str().contains("status"));
        // Thermal-off summaries carry no thermal block at all.
        assert!(!s.to_json_str().contains("peak_temp_c"));
        assert!(!s.to_json_str().contains("throttle_loss_ms"));
        // Governor/energy fields are always on the wire (cached and fresh
        // campaigns must render identically).
        assert!(s.to_json_str().contains("\"governor\""));
        assert!(s.to_json_str().contains("energy_per_iter_j"));

        // Multi-node HSDP summaries carry the rollup and round-trip too.
        let mut m = s.clone();
        m.sharding = "HSDP".into();
        m.num_nodes = 2;
        m.node_iter_ms = vec![3.25, 3.5];
        let j = m.to_json_str();
        assert!(j.contains("num_nodes"));
        assert!(j.contains("node_iter_ms"));
        // Exact-mode multi-node summaries carry no fold field at all.
        assert!(!j.contains("\"fold\""));
        let back = ScenarioSummary::from_json_str(&j).unwrap();
        assert_eq!(m, back);
        assert_eq!(back.to_json_str(), j);

        // Folded summaries carry the fold factor and round-trip too.
        let mut fl = m.clone();
        fl.num_nodes = 64;
        fl.fold = 32;
        let j = fl.to_json_str();
        assert!(j.contains("\"fold\":32"));
        let back = ScenarioSummary::from_json_str(&j).unwrap();
        assert_eq!(fl, back);
        assert_eq!(back.to_json_str(), j);

        // Serving summaries carry the serving block and round-trip too.
        let mut v = s.clone();
        v.fsdp = "serving".into();
        v.offered_qps = 16.0;
        v.ttft_p99_ms = 87.5;
        v.tpot_p99_ms = 4.25;
        v.goodput_rps = 14.75;
        v.energy_per_request_j = 321.0625;
        let j = v.to_json_str();
        assert!(j.contains("offered_qps"));
        assert!(j.contains("energy_per_request_j"));
        let back = ScenarioSummary::from_json_str(&j).unwrap();
        assert_eq!(v, back);
        assert_eq!(back.to_json_str(), j);

        // Faulted summaries carry the fault block and round-trip too.
        let mut f = s.clone();
        f.faults = "strag_f0_8".into();
        f.lost_ms = 12.5;
        f.blocked_ms = 3.25;
        let j = f.to_json_str();
        assert!(j.contains("\"faults\""));
        assert!(j.contains("lost_ms"));
        assert!(j.contains("blocked_ms"));
        let back = ScenarioSummary::from_json_str(&j).unwrap();
        assert_eq!(f, back);
        assert_eq!(back.to_json_str(), j);

        // Failed summaries carry the block even with no declared faults.
        let mut x = s.clone();
        x.status = "failed".into();
        let j = x.to_json_str();
        assert!(j.contains("\"status\":\"failed\""));
        let back = ScenarioSummary::from_json_str(&j).unwrap();
        assert_eq!(x, back);

        // Thermal summaries carry the thermal block and round-trip too.
        let mut t = s.clone();
        t.peak_temp_c = 96.625;
        t.throttle_loss_ms = 1.4375;
        let j = t.to_json_str();
        assert!(j.contains("peak_temp_c"));
        assert!(j.contains("throttle_loss_ms"));
        let back = ScenarioSummary::from_json_str(&j).unwrap();
        assert_eq!(t, back);
        assert_eq!(back.to_json_str(), j);
    }

    #[test]
    fn run_campaign_isolates_a_panicking_scenario() {
        use crate::campaign::grid::GridSpec;
        use crate::config::FaultSpec;
        let node = NodeSpec::mi300x_node();
        let mut spec = GridSpec::paper(2, 2, 1);
        spec.batches = vec![1];
        spec.seqs = vec![4];
        spec.fsdp = vec![crate::config::FsdpVersion::V1];
        spec.faults = vec![vec![], vec![FaultSpec::Panic]];
        let scenarios = spec.expand();
        assert_eq!(scenarios.len(), 2);
        for jobs in [1, 2] {
            let out = run_campaign(&node, &scenarios, jobs, None, false);
            assert_eq!(out.failed, 1);
            assert_eq!(out.executed, 1);
            let failed: Vec<_> = out
                .summaries
                .iter()
                .filter(|s| s.status == "failed")
                .collect();
            assert_eq!(failed.len(), 1);
            assert!(failed[0].name.contains("flt_panic"), "{}", failed[0].name);
            assert_eq!(failed[0].iter_ms, 0.0);
            // The healthy sibling still produced real numbers.
            assert!(out
                .summaries
                .iter()
                .any(|s| s.status == "ok" && s.iter_ms > 0.0));
        }
    }

    #[test]
    fn summary_parse_rejects_missing_fields() {
        assert!(ScenarioSummary::from_json_str("{}").is_err());
        assert!(ScenarioSummary::from_json_str("not json").is_err());
    }
}
