//! Declarative scenario grids: cartesian products of model configuration ×
//! workload configuration × engine-parameter ablations, expanded into
//! named, seeded scenarios in a deterministic order.

use crate::config::{
    ArrivalProcess, FaultSpec, FsdpVersion, ModelConfig, NicSpec,
    ServingConfig, Sharding, WorkloadConfig,
};
use crate::sim::{EngineParams, GovernorKind};

pub use crate::config::faults::parse_list_faults;
pub use crate::sim::power::parse_list_governor;
pub use crate::sim::thermal::{parse_list_ambient, parse_list_thermal};

use crate::sim::thermal::ThermalConfig;

/// One fully specified simulation scenario — everything the engine needs,
/// plus a stable human-readable name that doubles as the cache key prefix.
/// The sharding strategy lives in `wl.sharding`; the topology shape is the
/// node count + NIC here, composed with the campaign's per-node hardware
/// by `run_campaign`.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub model: ModelConfig,
    pub wl: WorkloadConfig,
    pub params: EngineParams,
    /// Nodes in the scenario's topology (1 = the classic single node).
    pub num_nodes: u32,
    /// Inter-node NIC of the scenario's topology.
    pub nic: NicSpec,
    /// Serving workload (continuous batching over open-loop arrivals).
    /// `None` = the classic training scenario; `Some` scenarios run
    /// through `serve::run_serving` instead of the training schedule.
    pub serving: Option<ServingConfig>,
    /// Replica fold factor (DESIGN.md §13): 1 = exact, F > 1 simulates
    /// `num_nodes / F` representative nodes and folds the replicas.
    pub fold: u32,
}

/// An [`EngineParams`] knob a grid can ablate (DESIGN.md §5 mechanisms).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Knob {
    SpinPenalty,
    TransferPenalty,
    CommStretch,
    RankJitter,
    ComputeJitter,
    DispatchJitter,
    CommDelaySigmaNs,
    FarRankDelayNs,
    DvfsWindowNs,
    MarginK,
    FixedCapRatio,
}

impl Knob {
    pub const ALL: [Knob; 11] = [
        Knob::SpinPenalty,
        Knob::TransferPenalty,
        Knob::CommStretch,
        Knob::RankJitter,
        Knob::ComputeJitter,
        Knob::DispatchJitter,
        Knob::CommDelaySigmaNs,
        Knob::FarRankDelayNs,
        Knob::DvfsWindowNs,
        Knob::MarginK,
        Knob::FixedCapRatio,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Knob::SpinPenalty => "spin_penalty",
            Knob::TransferPenalty => "transfer_penalty",
            Knob::CommStretch => "comm_stretch",
            Knob::RankJitter => "rank_jitter",
            Knob::ComputeJitter => "compute_jitter",
            Knob::DispatchJitter => "dispatch_jitter",
            Knob::CommDelaySigmaNs => "comm_delay_sigma_ns",
            Knob::FarRankDelayNs => "far_rank_delay_ns",
            Knob::DvfsWindowNs => "dvfs_window_ns",
            Knob::MarginK => "margin_k",
            Knob::FixedCapRatio => "fixed_cap_ratio",
        }
    }

    pub fn parse(s: &str) -> Option<Knob> {
        Knob::ALL.iter().copied().find(|k| k.name() == s)
    }

    pub fn apply(&self, p: &mut EngineParams, v: f64) {
        match self {
            Knob::SpinPenalty => p.spin_penalty = v,
            Knob::TransferPenalty => p.transfer_penalty = v,
            Knob::CommStretch => p.comm_stretch = v,
            Knob::RankJitter => p.rank_jitter = v,
            Knob::ComputeJitter => p.compute_jitter = v,
            Knob::DispatchJitter => p.dispatch_jitter = v,
            Knob::CommDelaySigmaNs => p.comm_delay_sigma_ns = v,
            Knob::FarRankDelayNs => p.far_rank_delay_ns = v,
            Knob::DvfsWindowNs => p.dvfs_window_ns = v,
            Knob::MarginK => p.margin_k = v,
            Knob::FixedCapRatio => p.fixed_cap_ratio = v,
        }
    }

    pub fn get(&self, p: &EngineParams) -> f64 {
        match self {
            Knob::SpinPenalty => p.spin_penalty,
            Knob::TransferPenalty => p.transfer_penalty,
            Knob::CommStretch => p.comm_stretch,
            Knob::RankJitter => p.rank_jitter,
            Knob::ComputeJitter => p.compute_jitter,
            Knob::DispatchJitter => p.dispatch_jitter,
            Knob::CommDelaySigmaNs => p.comm_delay_sigma_ns,
            Knob::FarRankDelayNs => p.far_rank_delay_ns,
            Knob::DvfsWindowNs => p.dvfs_window_ns,
            Knob::MarginK => p.margin_k,
            Knob::FixedCapRatio => p.fixed_cap_ratio,
        }
    }
}

/// A cartesian scenario grid. Every axis is a list; [`GridSpec::expand`]
/// produces the product in declared order (layers, then batch, then seq,
/// then FSDP version, then each ablation axis — innermost last), which is
/// the order results are reported in.
#[derive(Debug, Clone)]
pub struct GridSpec {
    pub base_model: ModelConfig,
    pub base_params: EngineParams,
    pub layers: Vec<u64>,
    pub batches: Vec<u64>,
    /// Sequence lengths in tokens.
    pub seqs: Vec<u64>,
    pub fsdp: Vec<FsdpVersion>,
    /// Sharding-strategy axis (default `[Fsdp]`; HSDP scenarios get a
    /// `-HSDP` name tag).
    pub shardings: Vec<Sharding>,
    /// Node-count axis (default `[1]`; multi-node scenarios get a `-N<n>`
    /// name tag).
    pub nodes: Vec<u32>,
    /// NIC-bandwidth axis in GB/s per direction per GPU. Empty = the
    /// default NIC with no name tag; explicit values get `-nic<gbs>`.
    pub nic_gbs: Vec<f64>,
    /// Power-management policy axis (default `[Reactive]`; non-default
    /// policies get a `-gov_<name>` name tag, so classic grids keep their
    /// names, derived seeds and cache keys).
    pub governors: Vec<GovernorKind>,
    /// Serving base configuration (default `None` = a training grid).
    /// When set, every scenario becomes a serving scenario tagged
    /// `-serve_q<qps>` and the [`qps`](Self::qps) axis sweeps offered
    /// load over the base config.
    pub serving: Option<ServingConfig>,
    /// Offered-load axis in requests/s (only meaningful with `serving`;
    /// empty = the base config's arrival process, unswept).
    pub qps: Vec<f64>,
    /// Fault-injection axis: each entry is one fault *set*
    /// (`config::faults`). Default `[[]]` = the healthy cluster with no
    /// name tag; non-empty sets get a `-flt_<label>` tag.
    pub faults: Vec<Vec<FaultSpec>>,
    /// Replica-fold axis (DESIGN.md §13). Default `[1]` = exact mode with
    /// no name tag; folded scenarios get a `-fold<F>` tag. Each factor
    /// must divide every node count it is crossed with.
    pub folds: Vec<u32>,
    /// Thermal-coupling axis (DESIGN.md §14): each entry is one thermal
    /// configuration. Default `[None]` = the RC model off with no name
    /// tag (byte-identical to pre-thermal grids); `Some` entries get a
    /// `-therm_<label>` tag.
    pub thermals: Vec<Option<ThermalConfig>>,
    pub iterations: u32,
    pub warmup: u32,
    /// Base seed; each scenario derives its own seed from this and its name.
    pub seed: u64,
    /// Engine-parameter ablation axes: (knob, values). A knob value equal
    /// to the base default still counts as a grid point.
    pub ablations: Vec<(Knob, Vec<f64>)>,
}

impl GridSpec {
    /// The paper's Fig. 4 axes as a proper cartesian grid: b×{1,2,4} ×
    /// s×{4K,8K} × {v1,v2} at the given layer count — 12 scenarios.
    pub fn paper(layers: u64, iterations: u32, warmup: u32) -> Self {
        Self {
            base_model: ModelConfig::llama3_8b(),
            base_params: EngineParams::default(),
            layers: vec![layers],
            batches: vec![1, 2, 4],
            seqs: vec![4096, 8192],
            fsdp: vec![FsdpVersion::V1, FsdpVersion::V2],
            shardings: vec![Sharding::Fsdp],
            nodes: vec![1],
            nic_gbs: Vec::new(),
            governors: vec![GovernorKind::Reactive],
            serving: None,
            qps: Vec::new(),
            faults: vec![Vec::new()],
            folds: vec![1],
            thermals: vec![None],
            iterations,
            warmup,
            seed: 0xC0FFEE,
            ablations: Vec::new(),
        }
    }

    /// Number of scenarios [`expand`](Self::expand) will produce.
    pub fn len(&self) -> usize {
        let mut n = self.layers.len()
            * self.batches.len()
            * self.seqs.len()
            * self.fsdp.len()
            * self.shardings.len()
            * self.nodes.len()
            * self.nic_gbs.len().max(1)
            * self.governors.len()
            * if self.serving.is_some() {
                self.qps.len().max(1)
            } else {
                1
            }
            * self.faults.len().max(1)
            * self.folds.len().max(1)
            * self.thermals.len().max(1);
        for (_, vals) in &self.ablations {
            n *= vals.len().max(1);
        }
        n
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand the cartesian product into named scenarios, deterministic in
    /// both order and content. Topology axes (sharding, nodes, NIC) tag
    /// the scenario name only when non-default, so default grids keep
    /// their pre-topology names (and therefore their derived seeds and
    /// cache keys).
    pub fn expand(&self) -> Vec<Scenario> {
        let mut out = Vec::with_capacity(self.len());
        let nics: Vec<Option<f64>> = if self.nic_gbs.is_empty() {
            vec![None]
        } else {
            self.nic_gbs.iter().map(|&g| Some(g)).collect()
        };
        // Serving axis: outer `Some` marks a serving scenario, inner
        // `Some(q)` overrides the base arrival rate (empty QPS list = the
        // base config's own arrival process, unswept).
        let loads: Vec<Option<Option<f64>>> = if self.serving.is_none() {
            vec![None]
        } else if self.qps.is_empty() {
            vec![Some(None)]
        } else {
            self.qps.iter().map(|&q| Some(Some(q))).collect()
        };
        // Fault axis: empty list = the one healthy (empty) fault set.
        let empty_set: Vec<FaultSpec> = Vec::new();
        let fault_sets: Vec<&[FaultSpec]> = if self.faults.is_empty() {
            vec![empty_set.as_slice()]
        } else {
            self.faults.iter().map(|f| f.as_slice()).collect()
        };
        // Fold axis: empty list = exact mode only.
        let folds: Vec<u32> = if self.folds.is_empty() {
            vec![1]
        } else {
            self.folds.clone()
        };
        // Thermal axis: empty list = the one thermal-off point.
        let thermals: Vec<Option<&ThermalConfig>> = if self.thermals.is_empty()
        {
            vec![None]
        } else {
            self.thermals.iter().map(|t| t.as_ref()).collect()
        };
        for &layers in &self.layers {
            for &batch in &self.batches {
                for &seq in &self.seqs {
                    for &fsdp in &self.fsdp {
                        for &sharding in &self.shardings {
                            for &nodes in &self.nodes {
                                for &nic in &nics {
                                    for &gov in &self.governors {
                                        for &load in &loads {
                                            for &fset in &fault_sets {
                                                for &fold in &folds {
                                                    for &thermal in &thermals {
                                                        self.expand_ablations(
                                                            layers, batch,
                                                            seq, fsdp,
                                                            sharding, nodes,
                                                            nic, gov, load,
                                                            fset, fold,
                                                            thermal, &mut out,
                                                        );
                                                    }
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }

    #[allow(clippy::too_many_arguments)]
    fn expand_ablations(
        &self,
        layers: u64,
        batch: u64,
        seq: u64,
        fsdp: FsdpVersion,
        sharding: Sharding,
        nodes: u32,
        nic_gbs: Option<f64>,
        governor: GovernorKind,
        load: Option<Option<f64>>,
        fset: &[FaultSpec],
        fold: u32,
        thermal: Option<&ThermalConfig>,
        out: &mut Vec<Scenario>,
    ) {
        // Odometer over the ablation axes (empty product = one scenario).
        let axes: Vec<(Knob, &[f64])> = self
            .ablations
            .iter()
            .filter(|(_, vals)| !vals.is_empty())
            .map(|(k, vals)| (*k, vals.as_slice()))
            .collect();
        let mut idx = vec![0usize; axes.len()];
        loop {
            let mut model = self.base_model.clone();
            model.layers = layers;
            let mut params = self.base_params.clone();
            let mut name = format!("L{layers}-b{batch}s{}-{fsdp}", seq / 1024);
            if sharding != Sharding::Fsdp {
                name.push_str(&format!("-{sharding}"));
            }
            if nodes != 1 {
                name.push_str(&format!("-N{nodes}"));
            }
            let mut nic = NicSpec::default();
            if let Some(gbs) = nic_gbs {
                nic.nic_bw = gbs * 1e9;
                let tag = format!("{gbs}").replace('.', "_");
                name.push_str(&format!("-nic{tag}"));
            }
            for (pos, (knob, vals)) in axes.iter().enumerate() {
                let v = vals[idx[pos]];
                knob.apply(&mut params, v);
                let mut tag = format!("{v}");
                // Keep names filesystem-friendly.
                tag = tag.replace('.', "_").replace('+', "_").replace('-', "m");
                name.push_str(&format!("-{}{}", knob.name(), tag));
            }
            let mut wl = WorkloadConfig::new(batch, seq, fsdp);
            wl.sharding = sharding;
            wl.iterations = self.iterations;
            wl.warmup = self.warmup;
            // Per-scenario seed: stable under grid reordering because it
            // depends only on the scenario name and the base seed. The
            // governor tag is appended *after* the seed is derived, so
            // policy siblings share every jitter draw — a cross-policy
            // Δ in `campaign_by_governor` measures the policy, not seed
            // noise (the same fixed-workload semantics as `whatif`).
            wl.seed = self.seed ^ crate::campaign::cache::fnv1a(name.as_bytes());
            params.governor = governor;
            if governor != GovernorKind::Reactive {
                name.push_str(&format!("-gov_{}", governor.name()));
            }
            // The serving tag is appended *after* the seed is derived,
            // the same rule as the governor tag: QPS siblings share every
            // arrival/length draw, so the goodput-vs-load curve measures
            // offered load, not seed noise.
            let serving = load.map(|qps| {
                let mut scfg = self
                    .serving
                    .clone()
                    .expect("QPS axis requires a serving base config");
                if let Some(q) = qps {
                    scfg.arrival = ArrivalProcess::Poisson { qps: q };
                }
                scfg.seed = wl.seed;
                let tag = format!("{}", scfg.arrival.mean_qps())
                    .replace('.', "_");
                name.push_str(&format!("-serve_q{tag}"));
                scfg
            });
            // The fault tag is appended *after* the seed is derived, the
            // same rule as the governor/serving tags: fault siblings share
            // every jitter draw with the healthy scenario of the same
            // name, so a fault Δ measures the fault, not seed noise.
            params.faults = fset.to_vec();
            if !fset.is_empty() {
                name.push_str(&format!(
                    "-flt_{}",
                    crate::config::faults::set_label(fset)
                ));
            }
            // The fold tag is appended *after* the seed is derived, the
            // same rule as the governor/serving/fault tags: a folded
            // scenario shares every per-class jitter draw with its exact
            // sibling of the same name, which is what makes the
            // folded-vs-exact cross-check (DESIGN.md §13) an apples-to-
            // apples comparison rather than a reseeded rerun.
            if fold > 1 {
                name.push_str(&format!("-fold{fold}"));
            }
            // The thermal tag is appended *after* the seed is derived, the
            // same rule as every post-seed tag: a thermal scenario shares
            // every jitter draw with its thermal-off sibling of the same
            // name, so a thermal Δ measures the RC model alone (the
            // thermal substreams are derived separately, DESIGN.md §14).
            params.thermal = thermal.cloned();
            if let Some(tc) = thermal {
                name.push_str(&format!("-therm_{}", tc.label()));
            }
            out.push(Scenario {
                name,
                model,
                wl,
                params,
                num_nodes: nodes.max(1),
                nic,
                serving,
                fold: fold.max(1),
            });
            // Advance the odometer; done when it wraps.
            let mut pos = axes.len();
            loop {
                if pos == 0 {
                    return;
                }
                pos -= 1;
                idx[pos] += 1;
                if idx[pos] < axes[pos].1.len() {
                    break;
                }
                idx[pos] = 0;
            }
        }
    }
}

/// Parse a comma-separated list of integers ("1,2,4").
pub fn parse_list_u64(s: &str) -> Result<Vec<u64>, String> {
    s.split(',')
        .filter(|t| !t.trim().is_empty())
        .map(|t| {
            t.trim()
                .parse()
                .map_err(|_| format!("bad integer `{t}` in list `{s}`"))
        })
        .collect()
}

/// Parse a comma-separated list of floats ("0.05,0.2").
pub fn parse_list_f64(s: &str) -> Result<Vec<f64>, String> {
    s.split(',')
        .filter(|t| !t.trim().is_empty())
        .map(|t| {
            t.trim()
                .parse()
                .map_err(|_| format!("bad number `{t}` in list `{s}`"))
        })
        .collect()
}

/// Parse a comma-separated FSDP-version list ("v1,v2").
pub fn parse_list_fsdp(s: &str) -> Result<Vec<FsdpVersion>, String> {
    s.split(',')
        .filter(|t| !t.trim().is_empty())
        .map(|t| match t.trim() {
            "v1" | "V1" | "fsdpv1" | "FSDPv1" => Ok(FsdpVersion::V1),
            "v2" | "V2" | "fsdpv2" | "FSDPv2" => Ok(FsdpVersion::V2),
            other => Err(format!("bad FSDP version `{other}` (use v1/v2)")),
        })
        .collect()
}

/// Parse a comma-separated sharding-strategy list ("fsdp,hsdp").
pub fn parse_list_sharding(s: &str) -> Result<Vec<Sharding>, String> {
    s.split(',')
        .filter(|t| !t.trim().is_empty())
        .map(|t| {
            Sharding::parse(t.trim())
                .ok_or_else(|| format!("bad sharding `{t}` (use fsdp/hsdp)"))
        })
        .collect()
}

/// Parse a comma-separated node-count list ("1,2,4"), rejecting zero and
/// values that would not survive the u32 topology representation.
pub fn parse_list_nodes(s: &str) -> Result<Vec<u32>, String> {
    let v = parse_list_u64(s)?;
    if let Some(&bad) = v.iter().find(|&&n| n == 0 || n > u32::MAX as u64) {
        return Err(format!("bad node count {bad} in list `{s}`"));
    }
    Ok(v.into_iter().map(|n| n as u32).collect())
}

/// Parse a comma-separated fold-factor list ("1,8"), rejecting zero and
/// values past the u32 topology representation. Divisibility against the
/// node axis is checked at campaign start, where both axes are known.
pub fn parse_list_folds(s: &str) -> Result<Vec<u32>, String> {
    let v = parse_list_u64(s)?;
    if let Some(&bad) = v.iter().find(|&&n| n == 0 || n > u32::MAX as u64) {
        return Err(format!("bad fold factor {bad} in list `{s}`"));
    }
    Ok(v.into_iter().map(|n| n as u32).collect())
}

/// Parse an ablation spec: `knob=v1,v2[;knob2=v3,v4]`.
pub fn parse_ablations(s: &str) -> Result<Vec<(Knob, Vec<f64>)>, String> {
    let mut out = Vec::new();
    for part in s.split(';').filter(|p| !p.trim().is_empty()) {
        let (k, vals) = part
            .split_once('=')
            .ok_or_else(|| format!("bad ablation `{part}` (want knob=v1,v2)"))?;
        let knob = Knob::parse(k.trim()).ok_or_else(|| {
            let names: Vec<&str> = Knob::ALL.iter().map(|k| k.name()).collect();
            format!("unknown knob `{}` (have: {})", k.trim(), names.join(", "))
        })?;
        out.push((knob, parse_list_f64(vals)?));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_grid_is_twelve_scenarios() {
        let g = GridSpec::paper(2, 2, 1);
        let scs = g.expand();
        assert_eq!(scs.len(), 12);
        assert_eq!(scs.len(), g.len());
        assert!(scs.iter().any(|s| s.name == "L2-b1s4-FSDPv1"));
        assert!(scs.iter().any(|s| s.name == "L2-b4s8-FSDPv2"));
    }

    #[test]
    fn names_are_unique_and_order_is_stable() {
        let mut g = GridSpec::paper(2, 2, 1);
        g.ablations = vec![(Knob::SpinPenalty, vec![0.05, 0.2])];
        let a = g.expand();
        let b = g.expand();
        let names: Vec<&str> = a.iter().map(|s| s.name.as_str()).collect();
        let mut uniq = names.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), names.len(), "duplicate scenario names");
        assert_eq!(a.len(), 24);
        let names_b: Vec<&str> = b.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, names_b);
    }

    #[test]
    fn ablation_values_are_applied() {
        let mut g = GridSpec::paper(2, 2, 1);
        g.batches = vec![1];
        g.seqs = vec![4096];
        g.fsdp = vec![FsdpVersion::V1];
        g.ablations = vec![
            (Knob::SpinPenalty, vec![0.5]),
            (Knob::DvfsWindowNs, vec![5e5, 1e6]),
        ];
        let scs = g.expand();
        assert_eq!(scs.len(), 2);
        for sc in &scs {
            assert_eq!(sc.params.spin_penalty, 0.5);
        }
        assert_eq!(scs[0].params.dvfs_window_ns, 5e5);
        assert_eq!(scs[1].params.dvfs_window_ns, 1e6);
    }

    #[test]
    fn default_topology_axes_keep_legacy_names_and_seeds() {
        // The topology axes must be invisible on default grids: same
        // names (hence same derived seeds and cache keys) as before.
        let scs = GridSpec::paper(2, 2, 1).expand();
        assert_eq!(scs.len(), 12);
        for sc in &scs {
            assert!(!sc.name.contains("-N"), "{}", sc.name);
            assert!(!sc.name.contains("HSDP"), "{}", sc.name);
            assert!(!sc.name.contains("nic"), "{}", sc.name);
            assert_eq!(sc.num_nodes, 1);
            assert_eq!(sc.wl.sharding, crate::config::Sharding::Fsdp);
        }
        assert!(scs.iter().any(|s| s.name == "L2-b1s4-FSDPv1"));
    }

    #[test]
    fn topology_axes_expand_and_tag_names() {
        use crate::config::Sharding;
        let mut g = GridSpec::paper(2, 2, 1);
        g.batches = vec![1];
        g.seqs = vec![4096];
        g.fsdp = vec![FsdpVersion::V1];
        g.shardings = vec![Sharding::Fsdp, Sharding::Hsdp];
        g.nodes = vec![1, 2];
        g.nic_gbs = vec![50.0, 12.5];
        let scs = g.expand();
        assert_eq!(scs.len(), g.len());
        assert_eq!(scs.len(), 2 * 2 * 2);
        assert!(scs.iter().any(|s| s.name == "L2-b1s4-FSDPv1-nic50"));
        assert!(scs
            .iter()
            .any(|s| s.name == "L2-b1s4-FSDPv1-HSDP-N2-nic12_5"));
        let hsdp2 = scs
            .iter()
            .find(|s| s.name == "L2-b1s4-FSDPv1-HSDP-N2-nic12_5")
            .unwrap();
        assert_eq!(hsdp2.num_nodes, 2);
        assert_eq!(hsdp2.wl.sharding, Sharding::Hsdp);
        assert_eq!(hsdp2.nic.nic_bw, 12.5e9);
        // Names are unique across the topology product.
        let mut names: Vec<&str> = scs.iter().map(|s| s.name.as_str()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), scs.len());
    }

    #[test]
    fn topology_list_parsers() {
        use crate::config::Sharding;
        assert_eq!(
            parse_list_sharding("fsdp,hsdp").unwrap(),
            vec![Sharding::Fsdp, Sharding::Hsdp]
        );
        assert!(parse_list_sharding("zero").is_err());
        assert_eq!(parse_list_nodes("1,2,4").unwrap(), vec![1, 2, 4]);
        assert!(parse_list_nodes("0,2").is_err());
        // Values past u32 must error, not truncate (4294967296 would
        // silently become 0 nodes under a bare `as u32`).
        assert!(parse_list_nodes("4294967296").is_err());
    }

    #[test]
    fn governor_axis_expands_and_tags_non_default_only() {
        let mut g = GridSpec::paper(2, 2, 1);
        g.batches = vec![1];
        g.seqs = vec![4096];
        g.fsdp = vec![FsdpVersion::V1];
        g.governors = GovernorKind::ALL.to_vec();
        let scs = g.expand();
        assert_eq!(scs.len(), g.len());
        assert_eq!(scs.len(), GovernorKind::ALL.len());
        // The reactive scenario keeps its legacy name (seed/cache-key
        // stability); every other policy is tagged.
        assert!(scs.iter().any(|s| s.name == "L2-b1s4-FSDPv1"));
        assert!(scs.iter().any(|s| s.name == "L2-b1s4-FSDPv1-gov_oracle"));
        assert!(scs.iter().any(|s| s.name == "L2-b1s4-FSDPv1-gov_fixed_cap"));
        assert!(scs.iter().any(|s| s.name == "L2-b1s4-FSDPv1-gov_det_aware"));
        assert!(scs
            .iter()
            .any(|s| s.name == "L2-b1s4-FSDPv1-gov_thermal_aware"));
        for sc in &scs {
            let tagged = sc.name.contains("-gov_");
            assert_eq!(tagged, sc.params.governor != GovernorKind::Reactive);
        }
        // Policy siblings share the seed (the tag is excluded from the
        // seed basis), so cross-policy deltas measure the policy alone.
        let seed_of = |n: &str| scs.iter().find(|s| s.name == n).unwrap().wl.seed;
        let base_seed = seed_of("L2-b1s4-FSDPv1");
        for tagged in ["oracle", "fixed_cap", "det_aware", "thermal_aware"] {
            assert_eq!(
                seed_of(&format!("L2-b1s4-FSDPv1-gov_{tagged}")),
                base_seed,
                "{tagged} sibling drew a different seed"
            );
        }
        // Default grids carry no governor tag at all.
        for sc in GridSpec::paper(2, 2, 1).expand() {
            assert!(!sc.name.contains("-gov_"), "{}", sc.name);
            assert_eq!(sc.params.governor, GovernorKind::Reactive);
        }
    }

    #[test]
    fn serving_axis_tags_after_seed_derivation() {
        let mut g = GridSpec::paper(2, 2, 1);
        g.batches = vec![1];
        g.seqs = vec![4096];
        g.fsdp = vec![FsdpVersion::V2];
        g.serving = Some(ServingConfig::new(8.0, 32));
        g.qps = vec![8.0, 32.0];
        let scs = g.expand();
        assert_eq!(scs.len(), g.len());
        assert_eq!(scs.len(), 2);
        assert!(scs.iter().any(|s| s.name == "L2-b1s4-FSDPv2-serve_q8"));
        assert!(scs.iter().any(|s| s.name == "L2-b1s4-FSDPv2-serve_q32"));
        // The serving tag is excluded from the seed basis (same rule as
        // the governor tag): QPS siblings share the seed with each other
        // and with the untagged training scenario of the same name.
        let mut base = GridSpec::paper(2, 2, 1);
        base.batches = vec![1];
        base.seqs = vec![4096];
        base.fsdp = vec![FsdpVersion::V2];
        let base_seed = base.expand()[0].wl.seed;
        for sc in &scs {
            assert_eq!(sc.wl.seed, base_seed, "{}", sc.name);
            let scfg = sc.serving.as_ref().expect("serving scenario");
            // The serving config inherits the scenario-derived seed, so
            // arrivals are pinned per scenario name.
            assert_eq!(scfg.seed, sc.wl.seed);
        }
        let q_of = |n: &str| {
            scs.iter()
                .find(|s| s.name == n)
                .unwrap()
                .serving
                .as_ref()
                .unwrap()
                .arrival
                .mean_qps()
        };
        assert_eq!(q_of("L2-b1s4-FSDPv2-serve_q8"), 8.0);
        assert_eq!(q_of("L2-b1s4-FSDPv2-serve_q32"), 32.0);
        // An empty QPS list keeps the base arrival process, unswept.
        g.qps = Vec::new();
        let unswept = g.expand();
        assert_eq!(unswept.len(), 1);
        assert_eq!(unswept[0].serving.as_ref().unwrap().arrival.mean_qps(), 8.0);
        // Training grids carry no serving config and no tag.
        for sc in GridSpec::paper(2, 2, 1).expand() {
            assert!(sc.serving.is_none());
            assert!(!sc.name.contains("serve_q"), "{}", sc.name);
        }
    }

    #[test]
    fn fault_axis_expands_and_tags_non_empty_only() {
        let mut g = GridSpec::paper(2, 2, 1);
        g.batches = vec![1];
        g.seqs = vec![4096];
        g.fsdp = vec![FsdpVersion::V1];
        g.faults =
            parse_list_faults("none;straggler(factor=0.8)+stalls(rate=0.02)")
                .unwrap();
        let scs = g.expand();
        assert_eq!(scs.len(), g.len());
        assert_eq!(scs.len(), 2);
        // The healthy set keeps its legacy name (seed/cache-key
        // stability); the faulted sibling is tagged.
        assert!(scs.iter().any(|s| s.name == "L2-b1s4-FSDPv1"));
        let tagged = scs
            .iter()
            .find(|s| s.name == "L2-b1s4-FSDPv1-flt_strag_f0_8+stall_p0_02_m500")
            .unwrap_or_else(|| {
                panic!(
                    "missing tagged fault scenario, have: {:?}",
                    scs.iter().map(|s| &s.name).collect::<Vec<_>>()
                )
            });
        assert_eq!(tagged.params.faults.len(), 2);
        // Fault siblings share the seed (the tag is excluded from the
        // seed basis), so a fault delta measures the fault alone.
        let base = scs.iter().find(|s| s.name == "L2-b1s4-FSDPv1").unwrap();
        assert!(base.params.faults.is_empty());
        assert_eq!(tagged.wl.seed, base.wl.seed);
        // Default grids carry no fault tag at all.
        for sc in GridSpec::paper(2, 2, 1).expand() {
            assert!(!sc.name.contains("-flt_"), "{}", sc.name);
            assert!(sc.params.faults.is_empty());
        }
    }

    #[test]
    fn fold_axis_expands_and_tags_non_default_only() {
        use crate::config::Sharding;
        let mut g = GridSpec::paper(2, 2, 1);
        g.batches = vec![1];
        g.seqs = vec![4096];
        g.fsdp = vec![FsdpVersion::V1];
        g.shardings = vec![Sharding::Hsdp];
        g.nodes = vec![8];
        g.folds = vec![1, 4];
        let scs = g.expand();
        assert_eq!(scs.len(), g.len());
        assert_eq!(scs.len(), 2);
        // The exact scenario keeps its legacy name (seed/cache-key
        // stability); the folded sibling is tagged.
        let exact = scs
            .iter()
            .find(|s| s.name == "L2-b1s4-FSDPv1-HSDP-N8")
            .expect("exact scenario");
        let folded = scs
            .iter()
            .find(|s| s.name == "L2-b1s4-FSDPv1-HSDP-N8-fold4")
            .expect("folded scenario");
        assert_eq!(exact.fold, 1);
        assert_eq!(folded.fold, 4);
        // Both report the same *logical* node count; only the simulated
        // world shrinks (in the engine, not here).
        assert_eq!(folded.num_nodes, 8);
        // Fold siblings share the seed (the tag is excluded from the
        // seed basis), so the folded-vs-exact cross-check compares the
        // same jitter draws, not two reseeded runs.
        assert_eq!(folded.wl.seed, exact.wl.seed);
        // Default grids carry no fold tag at all.
        for sc in GridSpec::paper(2, 2, 1).expand() {
            assert!(!sc.name.contains("-fold"), "{}", sc.name);
            assert_eq!(sc.fold, 1);
        }
        // An empty fold axis behaves like `[1]`.
        g.folds = Vec::new();
        let unswept = g.expand();
        assert_eq!(unswept.len(), 1);
        assert_eq!(unswept.len(), g.len());
        assert_eq!(unswept[0].fold, 1);
    }

    #[test]
    fn thermal_axis_expands_and_tags_enabled_only() {
        let mut g = GridSpec::paper(2, 2, 1);
        g.batches = vec![1];
        g.seqs = vec![4096];
        g.fsdp = vec![FsdpVersion::V1];
        g.thermals = parse_list_thermal("none;thermal(ambient=45)").unwrap();
        let scs = g.expand();
        assert_eq!(scs.len(), g.len());
        assert_eq!(scs.len(), 2);
        // The thermal-off point keeps its legacy name (seed/cache-key
        // stability); the thermal sibling is tagged.
        let off = scs.iter().find(|s| s.name == "L2-b1s4-FSDPv1").unwrap();
        let hot = scs
            .iter()
            .find(|s| s.name == "L2-b1s4-FSDPv1-therm_a45")
            .unwrap_or_else(|| {
                panic!(
                    "missing tagged thermal scenario, have: {:?}",
                    scs.iter().map(|s| &s.name).collect::<Vec<_>>()
                )
            });
        assert!(off.params.thermal.is_none());
        assert_eq!(hot.params.thermal.as_ref().unwrap().ambient_c, 45.0);
        // Thermal siblings share the seed (the tag is excluded from the
        // seed basis), so a thermal delta measures the RC model alone.
        assert_eq!(hot.wl.seed, off.wl.seed);
        // Default grids carry no thermal tag at all.
        for sc in GridSpec::paper(2, 2, 1).expand() {
            assert!(!sc.name.contains("-therm_"), "{}", sc.name);
            assert!(sc.params.thermal.is_none());
        }
        // The `--ambient` sugar expands to default configs at each value.
        let amb = parse_list_ambient("none;45").unwrap();
        assert_eq!(amb.len(), 2);
        assert!(amb[0].is_none());
        assert_eq!(amb[1].as_ref().unwrap().ambient_c, 45.0);
    }

    #[test]
    fn fold_list_parser() {
        assert_eq!(parse_list_folds("1,8").unwrap(), vec![1, 8]);
        assert!(parse_list_folds("0,2").is_err());
        assert!(parse_list_folds("4294967296").is_err());
    }

    #[test]
    fn governor_list_parser() {
        assert_eq!(
            parse_list_governor("reactive,oracle").unwrap(),
            vec![GovernorKind::Reactive, GovernorKind::Oracle]
        );
        assert!(parse_list_governor("powersave").is_err());
    }

    #[test]
    fn seeds_differ_between_scenarios() {
        let scs = GridSpec::paper(2, 2, 1).expand();
        let mut seeds: Vec<u64> = scs.iter().map(|s| s.wl.seed).collect();
        seeds.sort();
        seeds.dedup();
        assert_eq!(seeds.len(), scs.len());
    }

    #[test]
    fn knob_roundtrip() {
        let p = EngineParams::default();
        for k in Knob::ALL {
            assert_eq!(Knob::parse(k.name()), Some(k));
            let mut q = p.clone();
            k.apply(&mut q, 123.5);
            assert_eq!(k.get(&q), 123.5);
        }
        assert_eq!(Knob::parse("nope"), None);
    }

    #[test]
    fn list_parsers() {
        assert_eq!(parse_list_u64("1,2,4").unwrap(), vec![1, 2, 4]);
        assert!(parse_list_u64("1,x").is_err());
        assert_eq!(parse_list_f64("0.5, 2").unwrap(), vec![0.5, 2.0]);
        assert_eq!(
            parse_list_fsdp("v1,v2").unwrap(),
            vec![FsdpVersion::V1, FsdpVersion::V2]
        );
        let ab = parse_ablations("spin_penalty=0.1,0.2;dvfs_window_ns=5e5")
            .unwrap();
        assert_eq!(ab.len(), 2);
        assert_eq!(ab[0].0, Knob::SpinPenalty);
        assert_eq!(ab[0].1, vec![0.1, 0.2]);
        assert!(parse_ablations("bogus=1").is_err());
    }
}
