//! Scenario-campaign subsystem: declarative scenario grids, a parallel
//! deterministic fan-out runner, an on-disk result cache, and
//! cross-scenario comparison reports.
//!
//! The paper's insights come from comparing many workload configurations
//! side by side (the Fig. 4/6 b×s × FSDP sweeps). This module generalizes
//! that pattern: a [`GridSpec`] expands cartesian products of model /
//! workload / [`EngineParams`](crate::sim::EngineParams) axes into named,
//! seeded [`Scenario`]s; [`runner`] fans them out over scoped threads while
//! guaranteeing results come back in grid order (so parallel output is
//! byte-identical to a serial run); [`cache`] fingerprints each scenario
//! and persists its [`ScenarioSummary`] as a JSON artifact so re-running a
//! campaign only executes changed scenarios; [`compare`] renders the
//! cross-scenario tables as [`Figure`](crate::chopper::report::Figure)s.
//!
//! Driven by `chopper campaign` (see cli::commands) and
//! `examples/campaign.rs`; `report::run_sweep` rides the same runner.

pub mod cache;
pub mod compare;
pub mod grid;
pub mod runner;

pub use cache::{fingerprint, fnv1a, Cache};
pub use compare::{
    campaign_breakdown, campaign_by_governor, campaign_by_nodes,
    campaign_faults, campaign_serving, campaign_table, campaign_thermal,
};
pub use grid::{GridSpec, Knob, Scenario};
pub use runner::{
    default_jobs, run_campaign, run_campaign_stored, run_ordered, summarize,
    summarize_serving, CampaignOutcome, ScenarioSummary,
};
