//! Minimal SVG chart emitter for the report files (reports/*.svg).
//!
//! Supports exactly the chart families the paper's figures need: grouped
//! bars (Fig. 4, 11, 14), scatter (Fig. 7, 9), step-CDF lines (Fig. 8),
//! stacked bars (Fig. 15) and heatmaps (Fig. 13). No external deps.

use std::fmt::Write as _;

pub const PALETTE: &[&str] = &[
    "#4e79a7", "#f28e2b", "#e15759", "#76b7b2", "#59a14f", "#edc948",
    "#b07aa1", "#ff9da7", "#9c755f", "#bab0ac",
];

pub struct Svg {
    width: f64,
    height: f64,
    body: String,
}

impl Svg {
    pub fn new(width: f64, height: f64) -> Self {
        Self {
            width,
            height,
            body: String::new(),
        }
    }

    pub fn rect(&mut self, x: f64, y: f64, w: f64, h: f64, fill: &str) {
        let _ = write!(
            self.body,
            r#"<rect x="{x:.1}" y="{y:.1}" width="{w:.1}" height="{h:.1}" fill="{fill}"/>"#
        );
    }

    pub fn line(&mut self, x1: f64, y1: f64, x2: f64, y2: f64, stroke: &str, w: f64) {
        let _ = write!(
            self.body,
            r#"<line x1="{x1:.1}" y1="{y1:.1}" x2="{x2:.1}" y2="{y2:.1}" stroke="{stroke}" stroke-width="{w}"/>"#
        );
    }

    pub fn circle(&mut self, cx: f64, cy: f64, r: f64, fill: &str) {
        let _ = write!(
            self.body,
            r#"<circle cx="{cx:.1}" cy="{cy:.1}" r="{r:.1}" fill="{fill}"/>"#
        );
    }

    pub fn text(&mut self, x: f64, y: f64, size: f64, s: &str) {
        let esc = s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;");
        let _ = write!(
            self.body,
            r#"<text x="{x:.1}" y="{y:.1}" font-size="{size}" font-family="monospace">{esc}</text>"#
        );
    }

    pub fn text_rotated(&mut self, x: f64, y: f64, size: f64, s: &str) {
        let esc = s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;");
        let _ = write!(
            self.body,
            r#"<text x="{x:.1}" y="{y:.1}" font-size="{size}" font-family="monospace" transform="rotate(-45 {x:.1} {y:.1})" text-anchor="end">{esc}</text>"#
        );
    }

    pub fn polyline(&mut self, pts: &[(f64, f64)], stroke: &str, w: f64) {
        let mut s = String::new();
        for (x, y) in pts {
            let _ = write!(s, "{x:.1},{y:.1} ");
        }
        let _ = write!(
            self.body,
            r#"<polyline points="{}" fill="none" stroke="{stroke}" stroke-width="{w}"/>"#,
            s.trim_end()
        );
    }

    pub fn finish(self) -> String {
        format!(
            "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{:.0}\" height=\"{:.0}\" viewBox=\"0 0 {:.0} {:.0}\">\n<rect width=\"100%\" height=\"100%\" fill=\"white\"/>\n{}\n</svg>\n",
            self.width, self.height, self.width, self.height, self.body
        )
    }
}

/// Grouped bar chart: `groups` along x, `series` per group.
/// data[group][series] = value.
pub fn grouped_bars(title: &str, groups: &[String], series: &[String],
                    data: &[Vec<f64>]) -> String {
    let (w, h) = (900.0, 420.0);
    let (ml, mr, mt, mb) = (70.0, 20.0, 40.0, 90.0);
    let mut svg = Svg::new(w, h);
    svg.text(ml, 24.0, 16.0, title);
    let plot_w = w - ml - mr;
    let plot_h = h - mt - mb;
    let maxv = data
        .iter()
        .flat_map(|g| g.iter())
        .cloned()
        .fold(f64::MIN, f64::max)
        .max(1e-12);
    // y axis + gridlines
    for i in 0..=4 {
        let frac = i as f64 / 4.0;
        let y = mt + plot_h * (1.0 - frac);
        svg.line(ml, y, w - mr, y, "#dddddd", 1.0);
        svg.text(4.0, y + 4.0, 11.0, &format!("{:.3}", maxv * frac));
    }
    let gw = plot_w / groups.len().max(1) as f64;
    let bw = gw * 0.8 / series.len().max(1) as f64;
    for (gi, g) in groups.iter().enumerate() {
        let gx = ml + gi as f64 * gw;
        for (si, _) in series.iter().enumerate() {
            let v = data.get(gi).and_then(|r| r.get(si)).copied().unwrap_or(0.0);
            let bh = (v / maxv) * plot_h;
            svg.rect(
                gx + gw * 0.1 + si as f64 * bw,
                mt + plot_h - bh,
                bw.max(1.0) - 1.0,
                bh,
                PALETTE[si % PALETTE.len()],
            );
        }
        svg.text_rotated(gx + gw * 0.5, h - mb + 16.0, 11.0, g);
    }
    // legend
    for (si, s) in series.iter().enumerate() {
        let lx = ml + si as f64 * 130.0;
        svg.rect(lx, h - 24.0, 12.0, 12.0, PALETTE[si % PALETTE.len()]);
        svg.text(lx + 16.0, h - 14.0, 11.0, s);
    }
    svg.finish()
}

/// Stacked bar chart: data[group][segment] stacked vertically.
pub fn stacked_bars(title: &str, groups: &[String], segments: &[String],
                    data: &[Vec<f64>]) -> String {
    let (w, h) = (900.0, 420.0);
    let (ml, mr, mt, mb) = (70.0, 20.0, 40.0, 90.0);
    let mut svg = Svg::new(w, h);
    svg.text(ml, 24.0, 16.0, title);
    let plot_w = w - ml - mr;
    let plot_h = h - mt - mb;
    let maxv = data
        .iter()
        .map(|g| g.iter().sum::<f64>())
        .fold(f64::MIN, f64::max)
        .max(1e-12);
    for i in 0..=4 {
        let frac = i as f64 / 4.0;
        let y = mt + plot_h * (1.0 - frac);
        svg.line(ml, y, w - mr, y, "#dddddd", 1.0);
        svg.text(4.0, y + 4.0, 11.0, &format!("{:.3}", maxv * frac));
    }
    let gw = plot_w / groups.len().max(1) as f64;
    for (gi, g) in groups.iter().enumerate() {
        let gx = ml + gi as f64 * gw + gw * 0.2;
        let mut acc = 0.0;
        for (si, _) in segments.iter().enumerate() {
            let v = data.get(gi).and_then(|r| r.get(si)).copied().unwrap_or(0.0);
            let y0 = mt + plot_h * (1.0 - (acc + v) / maxv);
            let bh = plot_h * v / maxv;
            svg.rect(gx, y0, gw * 0.6, bh, PALETTE[si % PALETTE.len()]);
            acc += v;
        }
        svg.text_rotated(gx + gw * 0.3, h - mb + 16.0, 11.0, g);
    }
    for (si, s) in segments.iter().enumerate() {
        let lx = ml + si as f64 * 130.0;
        svg.rect(lx, h - 24.0, 12.0, 12.0, PALETTE[si % PALETTE.len()]);
        svg.text(lx + 16.0, h - 14.0, 11.0, s);
    }
    svg.finish()
}

/// Scatter plot with multiple series: series_points[(name, [(x, y)])].
pub fn scatter(title: &str, xlabel: &str, ylabel: &str,
               series_points: &[(String, Vec<(f64, f64)>)]) -> String {
    let (w, h) = (720.0, 480.0);
    let (ml, mr, mt, mb) = (70.0, 20.0, 40.0, 60.0);
    let mut svg = Svg::new(w, h);
    svg.text(ml, 24.0, 16.0, title);
    let all: Vec<(f64, f64)> = series_points
        .iter()
        .flat_map(|s| s.1.iter().copied())
        .collect();
    if all.is_empty() {
        return svg.finish();
    }
    let (xmin, xmax) = all.iter().fold((f64::MAX, f64::MIN), |(lo, hi), p| {
        (lo.min(p.0), hi.max(p.0))
    });
    let (ymin, ymax) = all.iter().fold((f64::MAX, f64::MIN), |(lo, hi), p| {
        (lo.min(p.1), hi.max(p.1))
    });
    let xs = (xmax - xmin).max(1e-12);
    let ys = (ymax - ymin).max(1e-12);
    let px = |x: f64| ml + (x - xmin) / xs * (w - ml - mr);
    let py = |y: f64| mt + (1.0 - (y - ymin) / ys) * (h - mt - mb);
    svg.line(ml, h - mb, w - mr, h - mb, "#333333", 1.0);
    svg.line(ml, mt, ml, h - mb, "#333333", 1.0);
    svg.text(w / 2.0 - 30.0, h - 16.0, 12.0, xlabel);
    svg.text(4.0, mt - 8.0, 12.0, ylabel);
    svg.text(ml - 10.0, h - mb + 14.0, 10.0, &format!("{xmin:.3}"));
    svg.text(w - mr - 40.0, h - mb + 14.0, 10.0, &format!("{xmax:.3}"));
    svg.text(4.0, h - mb, 10.0, &format!("{ymin:.3}"));
    svg.text(4.0, mt + 10.0, 10.0, &format!("{ymax:.3}"));
    for (si, (name, pts)) in series_points.iter().enumerate() {
        let color = PALETTE[si % PALETTE.len()];
        for (x, y) in pts {
            svg.circle(px(*x), py(*y), 3.0, color);
        }
        let lx = ml + 8.0 + si as f64 * 120.0;
        svg.circle(lx, mt + 8.0, 4.0, color);
        svg.text(lx + 8.0, mt + 12.0, 11.0, name);
    }
    svg.finish()
}

/// Step-CDF plot: one line per series of raw values.
pub fn cdf_lines(title: &str, xlabel: &str, series: &[(String, Vec<f64>)]) -> String {
    let (w, h) = (720.0, 480.0);
    let (ml, mr, mt, mb) = (70.0, 20.0, 40.0, 60.0);
    let mut svg = Svg::new(w, h);
    svg.text(ml, 24.0, 16.0, title);
    let all: Vec<f64> = series.iter().flat_map(|s| s.1.iter().copied()).collect();
    if all.is_empty() {
        return svg.finish();
    }
    let xmin = all.iter().cloned().fold(f64::MAX, f64::min);
    let xmax = all.iter().cloned().fold(f64::MIN, f64::max).max(xmin + 1e-12);
    let px = |x: f64| ml + (x - xmin) / (xmax - xmin) * (w - ml - mr);
    let py = |p: f64| mt + (1.0 - p) * (h - mt - mb);
    svg.line(ml, h - mb, w - mr, h - mb, "#333333", 1.0);
    svg.line(ml, mt, ml, h - mb, "#333333", 1.0);
    svg.text(w / 2.0 - 30.0, h - 16.0, 12.0, xlabel);
    svg.text(4.0, mt - 8.0, 12.0, "CDF");
    for (si, (name, xs)) in series.iter().enumerate() {
        let mut v = xs.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len().max(1);
        let mut pts = Vec::with_capacity(n + 1);
        pts.push((px(v.first().copied().unwrap_or(xmin)), py(0.0)));
        for (i, x) in v.iter().enumerate() {
            pts.push((px(*x), py((i + 1) as f64 / n as f64)));
        }
        let color = PALETTE[si % PALETTE.len()];
        svg.polyline(&pts, color, 1.5);
        let lx = ml + 8.0 + si as f64 * 90.0;
        svg.line(lx, mt + 8.0, lx + 14.0, mt + 8.0, color, 2.0);
        svg.text(lx + 18.0, mt + 12.0, 11.0, name);
    }
    svg.finish()
}

/// Heatmap: matrix[r][c] in [0,1], rendered as shaded cells.
pub fn heatmap(title: &str, matrix: &[Vec<f64>], row_labels: &[String]) -> String {
    let rows = matrix.len().max(1);
    let cols = matrix.iter().map(|r| r.len()).max().unwrap_or(1).max(1);
    let cell = (820.0 / cols as f64).min(14.0);
    let (ml, mt) = (90.0, 40.0);
    let w = ml + cols as f64 * cell + 20.0;
    let h = mt + rows as f64 * cell + 20.0;
    let mut svg = Svg::new(w, h);
    svg.text(ml, 24.0, 16.0, title);
    for (r, row) in matrix.iter().enumerate() {
        if let Some(label) = row_labels.get(r) {
            svg.text(4.0, mt + r as f64 * cell + cell * 0.8, 10.0, label);
        }
        for (c, &v) in row.iter().enumerate() {
            let shade = (255.0 * (1.0 - v.clamp(0.0, 1.0))) as u8;
            let color = format!("#{shade:02x}{shade:02x}ff");
            svg.rect(ml + c as f64 * cell, mt + r as f64 * cell, cell - 0.5,
                     cell - 0.5, &color);
        }
    }
    svg.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn svg_is_well_formed_ish() {
        let s = grouped_bars(
            "t",
            &["a".into(), "b".into()],
            &["s1".into()],
            &[vec![1.0], vec![2.0]],
        );
        assert!(s.starts_with("<svg"));
        assert!(s.trim_end().ends_with("</svg>"));
        assert_eq!(s.matches("<rect").count(), s.matches("/>").count() - s.matches("<line").count() - s.matches("<circle").count() - s.matches("<polyline").count());
    }

    #[test]
    fn scatter_handles_empty() {
        let s = scatter("t", "x", "y", &[]);
        assert!(s.contains("</svg>"));
    }

    #[test]
    fn text_is_escaped() {
        let mut svg = Svg::new(10.0, 10.0);
        svg.text(0.0, 0.0, 10.0, "a<b&c");
        let s = svg.finish();
        assert!(s.contains("a&lt;b&amp;c"));
    }

    #[test]
    fn cdf_lines_renders_series() {
        let s = cdf_lines("t", "dur", &[("g".into(), vec![1.0, 2.0, 3.0])]);
        assert!(s.contains("<polyline"));
    }

    #[test]
    fn heatmap_cells() {
        let s = heatmap("t", &[vec![0.0, 1.0]], &["r0".into()]);
        assert!(s.matches("<rect").count() >= 3); // bg + 2 cells
    }
}
