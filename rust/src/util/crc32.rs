//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320), table-driven.
//!
//! The trace store frames every chunk with a CRC so the reader can tell
//! truncation and bit-rot apart from valid data (DESIGN.md §12). Hand-rolled
//! because the repo takes no external dependencies; the table is built once
//! at first use.

use std::sync::OnceLock;

static TABLE: OnceLock<[u32; 256]> = OnceLock::new();

fn table() -> &'static [u32; 256] {
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        let mut i = 0usize;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            t[i] = c;
            i += 1;
        }
        t
    })
}

/// CRC-32 of `data` (init 0xFFFFFFFF, final xor 0xFFFFFFFF), matching
/// zlib/`cksum -o3`/Python `zlib.crc32`.
pub fn crc32(data: &[u8]) -> u32 {
    let t = table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::crc32;

    #[test]
    fn reference_vectors() {
        // The canonical CRC-32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn detects_single_byte_flip() {
        let base = b"chopper trace chunk payload".to_vec();
        let c0 = crc32(&base);
        for i in 0..base.len() {
            for bit in 0..8 {
                let mut m = base.clone();
                m[i] ^= 1 << bit;
                assert_ne!(crc32(&m), c0, "flip at byte {i} bit {bit} undetected");
            }
        }
    }
}
