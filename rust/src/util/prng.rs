//! Deterministic PRNG for the simulator and the property-test kit.
//!
//! The `rand` crate is not vendored in this environment (see DESIGN.md
//! substitution table); more importantly the simulator *must* be exactly
//! reproducible across runs for trace-alignment tests, so we ship our own
//! SplitMix64 (seeding) + xoshiro256** (bulk) generators. Algorithms by
//! Blackman & Vigna (public domain reference implementations).

/// FNV-1a 64-bit hash: keys PRNG substreams by label and content-addresses
/// campaign cache entries — one shared implementation so the keying scheme
/// can never desynchronize between the two.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// SplitMix64: used to expand a single u64 seed into xoshiro state and to
/// derive independent substreams (one per GPU, per subsystem) that stay
/// stable when unrelated code adds draws.
#[derive(Debug, Clone, Copy)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from Box-Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Derive an independent substream keyed by a label. Stable: adding
    /// draws to the parent does not perturb children.
    pub fn substream(seed: u64, label: &str) -> Self {
        Self::new(seed ^ fnv1a(label.as_bytes()))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi) via Lemire-style rejection-free mapping
    /// (bias negligible for our ranges; documented).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo, "empty range");
        let span = hi - lo;
        lo + (((self.next_u64() as u128 * span as u128) >> 64) as u64)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        // Avoid ln(0).
        let u1 = (1.0 - self.f64()).max(1e-300);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/std.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Log-normal-ish positive jitter around 1.0: exp(N(0, sigma)).
    /// Used for kernel-duration noise (durations can never go negative).
    pub fn jitter(&mut self, sigma: f64) -> f64 {
        (self.gauss() * sigma).exp()
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.range_usize(0, items.len())]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        // Fisher-Yates.
        for i in (1..items.len()).rev() {
            let j = self.range_usize(0, i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn substreams_differ_and_are_stable() {
        let mut a = Rng::substream(1, "gpu0");
        let mut b = Rng::substream(1, "gpu1");
        let mut a2 = Rng::substream(1, "gpu0");
        assert_ne!(a.next_u64(), b.next_u64());
        let _ = a2.next_u64();
        // a already consumed one draw; a2 should agree on the first draw.
        let mut a3 = Rng::substream(1, "gpu0");
        assert_eq!(a3.next_u64(), {
            let mut fresh = Rng::substream(1, "gpu0");
            fresh.next_u64()
        });
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_u64_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.range_u64(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn gauss_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gauss();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn jitter_always_positive() {
        let mut r = Rng::new(13);
        for _ in 0..10_000 {
            assert!(r.jitter(0.3) > 0.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
