//! Human-readable formatting for durations, rates and percentages.

/// Format nanoseconds with an adaptive unit (ns / µs / ms / s).
pub fn dur_ns(ns: f64) -> String {
    let abs = ns.abs();
    if abs < 1e3 {
        format!("{ns:.0} ns")
    } else if abs < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if abs < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Format a ratio as a percentage with one decimal.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Engineering notation for counts (K/M/G/T).
pub fn eng(x: f64) -> String {
    let abs = x.abs();
    if abs < 1e3 {
        format!("{x:.0}")
    } else if abs < 1e6 {
        format!("{:.2}K", x / 1e3)
    } else if abs < 1e9 {
        format!("{:.2}M", x / 1e6)
    } else if abs < 1e12 {
        format!("{:.2}G", x / 1e9)
    } else {
        format!("{:.2}T", x / 1e12)
    }
}

/// Bytes with binary units.
pub fn bytes(x: f64) -> String {
    let abs = x.abs();
    if abs < 1024.0 {
        format!("{x:.0} B")
    } else if abs < 1024.0 * 1024.0 {
        format!("{:.2} KiB", x / 1024.0)
    } else if abs < 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} MiB", x / (1024.0 * 1024.0))
    } else {
        format!("{:.2} GiB", x / (1024.0 * 1024.0 * 1024.0))
    }
}

/// Fixed-width left padding helper for tables.
pub fn pad(s: &str, width: usize) -> String {
    if s.len() >= width {
        s.to_string()
    } else {
        format!("{s}{}", " ".repeat(width - s.len()))
    }
}

pub fn pad_left(s: &str, width: usize) -> String {
    if s.len() >= width {
        s.to_string()
    } else {
        format!("{}{s}", " ".repeat(width - s.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_units() {
        assert_eq!(dur_ns(500.0), "500 ns");
        assert_eq!(dur_ns(1500.0), "1.50 µs");
        assert_eq!(dur_ns(2.5e6), "2.50 ms");
        assert_eq!(dur_ns(3.0e9), "3.000 s");
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.256), "25.6%");
    }

    #[test]
    fn eng_format() {
        assert_eq!(eng(950.0), "950");
        assert_eq!(eng(1.3e15), "1300.00T");
        assert_eq!(eng(2.0e6), "2.00M");
    }

    #[test]
    fn bytes_format() {
        assert_eq!(bytes(512.0), "512 B");
        assert_eq!(bytes(2048.0), "2.00 KiB");
    }

    #[test]
    fn padding() {
        assert_eq!(pad("ab", 4), "ab  ");
        assert_eq!(pad_left("ab", 4), "  ab");
        assert_eq!(pad("abcdef", 4), "abcdef");
    }
}
