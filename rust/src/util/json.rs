//! Minimal JSON writer + parser.
//!
//! serde/serde_json are not vendored in this environment (DESIGN.md
//! substitution table). Chopper needs JSON for exactly two things:
//! exporting traces in the Chrome trace-event format (so they open in
//! Perfetto / chrome://tracing, like roctracer output does) and reading
//! them back in tests. A few hundred lines of recursive-descent is plenty.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    // BTreeMap for deterministic serialization (trace diffs in tests).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize without whitespace.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize into a pre-reserved buffer. Callers that know the rough
    /// output size (traces: ~bytes-per-event × events; summaries: a few
    /// hundred bytes) avoid the repeated grow-and-copy of an unsized
    /// `String` — the dominant cost of serializing large artifacts.
    pub fn to_string_with_capacity(&self, capacity: usize) -> String {
        let mut out = String::with_capacity(capacity);
        self.write(&mut out);
        out
    }

    /// Append the serialized form to an existing buffer (no whitespace).
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Recursive-descent parser. Returns a descriptive error with byte offset.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain chars (UTF-8 safe: operate on str).
                    let rest = &self.bytes[self.pos..];
                    let end = rest
                        .iter()
                        .position(|&b| b == b'"' || b == b'\\')
                        .unwrap_or(rest.len());
                    s.push_str(
                        std::str::from_utf8(&rest[..end]).map_err(|e| e.to_string())?,
                    );
                    self.pos += end;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected ',' or ']', got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected ',' or '}}', got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let j = Json::obj(vec![
            ("name", Json::str("kernel \"x\"\n")),
            ("ts", Json::num(123.5)),
            ("args", Json::Arr(vec![Json::Bool(true), Json::Null, Json::num(7)])),
        ]);
        let s = j.to_string();
        let back = parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parses_whitespace_and_unicode() {
        let j = parse(" { \"a\" : [ 1 , -2.5e3 ], \"b\":\"\\u00e9\\t\" } ").unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(-2500.0));
        assert_eq!(j.get("b").unwrap().as_str(), Some("é\t"));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"open").is_err());
    }

    #[test]
    fn buffered_writer_matches_to_string() {
        let j = Json::obj(vec![("a", Json::num(1.5)), ("b", Json::str("x"))]);
        assert_eq!(j.to_string(), j.to_string_with_capacity(256));
        let mut out = String::from("prefix:");
        j.write(&mut out);
        assert_eq!(out, format!("prefix:{}", j.to_string()));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
