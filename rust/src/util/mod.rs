//! Shared utilities: deterministic PRNG, statistics, JSON, chart rendering
//! and formatting. These replace crates that are unavailable in the offline
//! build environment (see DESIGN.md substitution table) and keep the
//! simulator bit-reproducible.

pub mod ascii;
pub mod atomic_write;
pub mod crc32;
pub mod fmt;
pub mod hash;
pub mod intern;
pub mod json;
pub mod prng;
pub mod stats;
pub mod svg;

pub use atomic_write::{atomic_write, io_ctx, tmp_sibling};
