//! Fast non-cryptographic hashing for hot tuple-keyed maps.
//!
//! `std::collections::HashMap` defaults to SipHash-1-3, which is DoS-safe
//! but costs ~1ns/byte with a per-hash finalization — measurable on the
//! engine's per-event maps (`fwd_ids`, `op_kernel_idx`) and the alignment
//! join. This is an FxHash-style multiply-rotate hasher (the firefox /
//! rustc-hash scheme; the external crate is not vendored, per the DESIGN.md
//! §6 substitution table). All keys here are program-derived, never
//! attacker-controlled, so hash-flooding resistance is irrelevant.
//!
//! Determinism note: `FxHasher` is fully deterministic (no per-process
//! random state, unlike SipHash's `RandomState`), but map *iteration*
//! order is still arbitrary — only use these maps where lookups, not
//! iteration order, feed results (outputs must stay byte-stable).

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// The FxHash multiplier (64-bit golden-ratio-derived odd constant).
const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiply-rotate hasher; one rotate+xor+mul per 8-byte word.
#[derive(Debug, Clone, Copy, Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, mut bytes: &[u8]) {
        while bytes.len() >= 8 {
            let (word, rest) = bytes.split_at(8);
            self.add(u64::from_le_bytes(word.try_into().unwrap()));
            bytes = rest;
        }
        if bytes.len() >= 4 {
            let (word, rest) = bytes.split_at(4);
            self.add(u32::from_le_bytes(word.try_into().unwrap()) as u64);
            bytes = rest;
        }
        for &b in bytes {
            self.add(b as u64);
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u16(&mut self, n: u16) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_u128(&mut self, n: u128) {
        self.add(n as u64);
        self.add((n >> 64) as u64);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// `HashMap` with the fast deterministic hasher.
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;

/// `HashSet` with the fast deterministic hasher.
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::{BuildHasher, Hash};

    fn hash_of<T: Hash>(v: &T) -> u64 {
        FxBuildHasher::default().hash_one(v)
    }

    #[test]
    fn deterministic_across_instances() {
        let k = (3usize, 7u32, Some(2u32), 9u8);
        assert_eq!(hash_of(&k), hash_of(&k));
        assert_eq!(hash_of(&"kernel_name"), hash_of(&"kernel_name"));
    }

    #[test]
    fn discriminates_nearby_keys() {
        assert_ne!(hash_of(&(0u32, 1u32)), hash_of(&(1u32, 0u32)));
        assert_ne!(hash_of(&42u64), hash_of(&43u64));
        assert_ne!(hash_of(&"abc"), hash_of(&"abd"));
    }

    #[test]
    fn map_and_set_work_with_tuple_keys() {
        let mut m: FxHashMap<(u32, u32, Option<u32>), u64> = FxHashMap::default();
        m.insert((1, 2, None), 10);
        m.insert((1, 2, Some(0)), 20);
        assert_eq!(m.get(&(1, 2, None)), Some(&10));
        assert_eq!(m.get(&(1, 2, Some(0))), Some(&20));
        assert_eq!(m.get(&(2, 1, None)), None);

        let mut s: FxHashSet<&str> = FxHashSet::default();
        assert!(s.insert("a"));
        assert!(!s.insert("a"));
    }

    #[test]
    fn write_handles_odd_lengths() {
        // 0..16-byte slices all hash without panicking and differ.
        let mut seen = std::collections::HashSet::new();
        for len in 0..16 {
            let bytes: Vec<u8> = (0..len as u8).collect();
            let mut h = FxHasher::default();
            h.write(&bytes);
            seen.insert(h.finish());
        }
        assert_eq!(seen.len(), 16, "collision among trivial slices");
    }
}
