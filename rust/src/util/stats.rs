//! Statistics helpers used throughout the trace analysis: quantiles,
//! correlation, CDFs, histograms. All operate on `f64` slices; `NaN`s are
//! rejected by debug assertions (the analysis layer filters them upstream).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than 2 points.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolation quantile (type-7, numpy default). `q` in [0, 1].
/// Sorts a copy; use `quantile_sorted` on pre-sorted data in hot paths.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in quantile input"));
    quantile_sorted(&v, q)
}

/// Quantile on pre-sorted data.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Pearson correlation coefficient. Returns `None` when either side has
/// (near-)zero variance — the paper reports these as "nan" in Fig. 7 for
/// constant-overlap operations, and we preserve that semantics.
pub fn pearson(xs: &[f64], ys: &[f64]) -> Option<f64> {
    assert_eq!(xs.len(), ys.len(), "pearson: length mismatch");
    if xs.len() < 2 {
        return None;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let (mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0);
    for (x, y) in xs.iter().zip(ys) {
        let dx = x - mx;
        let dy = y - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    let denom = (sxx * syy).sqrt();
    if denom < 1e-12 * xs.len() as f64 {
        return None; // constant series -> undefined correlation
    }
    Some(sxy / denom)
}

/// Empirical CDF: returns (sorted values, cumulative probability in (0,1]).
pub fn ecdf(xs: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in ecdf input"));
    let n = v.len();
    let probs = (1..=n).map(|i| i as f64 / n as f64).collect();
    (v, probs)
}

/// Value of the empirical CDF's inverse at probability `p` — i.e. the value
/// below which a fraction `p` of the data falls (used for the D_50% / D_0%
/// overlap-overhead extraction of Eq. 9).
pub fn ecdf_value_at(xs: &[f64], p: f64) -> f64 {
    quantile(xs, p)
}

/// Five-number-style summary used by the fill plots in Figs. 7 and 9.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub min: f64,
    pub q25: f64,
    pub median: f64,
    pub q75: f64,
    pub max: f64,
    pub mean: f64,
    pub std: f64,
    pub n: usize,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self {
                min: 0.0,
                q25: 0.0,
                median: 0.0,
                q75: 0.0,
                max: 0.0,
                mean: 0.0,
                std: 0.0,
                n: 0,
            };
        }
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in summary input"));
        Self {
            min: v[0],
            q25: quantile_sorted(&v, 0.25),
            median: quantile_sorted(&v, 0.5),
            q75: quantile_sorted(&v, 0.75),
            max: v[v.len() - 1],
            mean: mean(&v),
            std: std(&v),
            n: v.len(),
        }
    }
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets; values outside
/// are clamped into the edge buckets.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let idx = (((x - lo) / w) as i64).clamp(0, bins as i64 - 1) as usize;
        h[idx] += 1;
    }
    h
}

/// Exponential moving average state (used by the DVFS governor).
#[derive(Debug, Clone, Copy)]
pub struct Ema {
    pub alpha: f64,
    pub value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Self { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> f64 {
        self.value.unwrap_or(0.0)
    }
}

/// Online mean/variance (Welford) — used for window power statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn count(&self) -> u64 {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[1.0, 2.0, 9.0]), 2.0);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(quantile(&xs, 0.25), 2.5);
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 1.0), 10.0);
    }

    #[test]
    fn quantile_empty_is_zero() {
        assert_eq!(quantile(&[], 0.5), 0.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn pearson_perfect_correlation() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        let r = pearson(&xs, &ys).unwrap();
        assert!((r - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &neg).unwrap() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_none() {
        // Matches the paper's "nan" correlations for constant overlap.
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), None);
    }

    #[test]
    fn ecdf_monotone() {
        let (vals, probs) = ecdf(&[3.0, 1.0, 2.0]);
        assert_eq!(vals, vec![1.0, 2.0, 3.0]);
        assert_eq!(probs.last().copied(), Some(1.0));
        assert!(probs.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn summary_fields() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.n, 5);
        assert!(s.std > 0.0);
    }

    #[test]
    fn histogram_clamps_outliers() {
        let h = histogram(&[-5.0, 0.1, 0.9, 99.0], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 2]);
    }

    #[test]
    fn welford_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.std() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..50 {
            e.update(10.0);
        }
        assert!((e.get() - 10.0).abs() < 1e-6);
    }
}
