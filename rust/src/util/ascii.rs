//! ASCII chart rendering for terminal reports.
//!
//! Chopper's visualization layer has two backends: SVG (util::svg) for the
//! report files, and these ASCII renderers so `chopper figure N` is useful
//! over ssh — the way the paper's authors drive rocprof output through
//! notebooks, we drive traces through the terminal.

use super::fmt;

const BLOCKS: &[char] = &[' ', '▏', '▎', '▍', '▌', '▋', '▊', '▉', '█'];

/// Horizontal bar chart. `rows` are (label, value); bars are scaled to
/// `width` columns against max(values) unless `max_value` is given.
pub fn bar_chart(title: &str, rows: &[(String, f64)], width: usize,
                 max_value: Option<f64>) -> String {
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    if rows.is_empty() {
        out.push_str("  (no data)\n");
        return out;
    }
    let maxv = max_value
        .unwrap_or_else(|| rows.iter().map(|r| r.1).fold(f64::MIN, f64::max))
        .max(1e-12);
    let label_w = rows.iter().map(|r| r.0.len()).max().unwrap_or(0);
    for (label, value) in rows {
        let frac = (value / maxv).clamp(0.0, 1.0);
        out.push_str(&format!(
            "  {} {} {:.4}\n",
            fmt::pad(label, label_w),
            solid_bar(frac, width),
            value
        ));
    }
    out
}

/// A stacked horizontal bar: segments are (name, value); the legend maps
/// segment glyphs to names. Used for the Fig. 4 duration breakdowns.
pub fn stacked_bar(label: &str, segments: &[(String, f64)], total_width: usize,
                   scale_max: f64) -> String {
    const GLYPHS: &[char] = &['█', '▓', '▒', '░', '◆', '●', '○', '■'];
    let total: f64 = segments.iter().map(|s| s.1).sum();
    let mut bar = String::new();
    let scale = scale_max.max(1e-12);
    for (i, (_, v)) in segments.iter().enumerate() {
        let cols = ((v / scale) * total_width as f64).round() as usize;
        let g = GLYPHS[i % GLYPHS.len()];
        for _ in 0..cols {
            bar.push(g);
        }
    }
    format!("  {label} |{bar}| total={total:.4}\n")
}

/// Unicode sub-character horizontal bar of fractional `frac` over `width`.
fn solid_bar(frac: f64, width: usize) -> String {
    let cells = frac * width as f64;
    let full = cells.floor() as usize;
    let rem = cells - full as f64;
    let mut s = String::new();
    for _ in 0..full {
        s.push('█');
    }
    if full < width {
        let idx = (rem * 8.0).round() as usize;
        s.push(BLOCKS[idx.min(8)]);
        for _ in full + 1..width {
            s.push(' ');
        }
    }
    s
}

/// Box/fill row for quantile plots (Figs. 7/9): renders min..max as light
/// fill, q25..q75 as dark fill, median as a marker, on a [lo, hi] axis.
pub fn quantile_row(label: &str, min: f64, q25: f64, med: f64, q75: f64, max: f64,
                    lo: f64, hi: f64, width: usize) -> String {
    let pos = |x: f64| -> usize {
        (((x - lo) / (hi - lo).max(1e-12)) * (width - 1) as f64)
            .round()
            .clamp(0.0, (width - 1) as f64) as usize
    };
    let mut row = vec![' '; width];
    for cell in row.iter_mut().take(pos(max) + 1).skip(pos(min)) {
        *cell = '░';
    }
    for cell in row.iter_mut().take(pos(q75) + 1).skip(pos(q25)) {
        *cell = '▓';
    }
    row[pos(med)] = '┃';
    format!("  {label} |{}|\n", row.iter().collect::<String>())
}

/// Render an empirical CDF as a fixed-size grid of braille-ish dots.
pub fn cdf_plot(title: &str, series: &[(String, Vec<f64>)], width: usize,
                height: usize) -> String {
    let mut out = format!("{title}\n");
    let all: Vec<f64> = series.iter().flat_map(|s| s.1.iter().copied()).collect();
    if all.is_empty() {
        out.push_str("  (no data)\n");
        return out;
    }
    let lo = all.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = all.iter().cloned().fold(f64::NEG_INFINITY, f64::max).max(lo + 1e-12);
    let marks = ['*', '+', 'o', 'x', '#', '@', '%', '&'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, xs)) in series.iter().enumerate() {
        let mut v = xs.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        for (i, x) in v.iter().enumerate() {
            let p = (i + 1) as f64 / n as f64;
            let col = (((x - lo) / (hi - lo)) * (width - 1) as f64) as usize;
            let row = ((1.0 - p) * (height - 1) as f64) as usize;
            grid[row.min(height - 1)][col.min(width - 1)] = marks[si % marks.len()];
        }
    }
    for (ri, row) in grid.iter().enumerate() {
        let y = 1.0 - ri as f64 / (height - 1) as f64;
        out.push_str(&format!("  {y:4.2} |{}|\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!("       {lo:<12.4}{:>width$.4}\n", hi, width = width - 11));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("       {} = {name}\n", marks[si % marks.len()]));
    }
    out
}

/// Heatmap over a [rows][cols] matrix of values in [0,1] (Fig. 13 SMT map).
pub fn heatmap(title: &str, matrix: &[Vec<f64>]) -> String {
    const SHADES: &[char] = &[' ', '·', '░', '▒', '▓', '█'];
    let mut out = format!("{title}\n");
    for row in matrix {
        out.push_str("  |");
        for &v in row {
            let idx = (v.clamp(0.0, 1.0) * (SHADES.len() - 1) as f64).round() as usize;
            out.push(SHADES[idx]);
        }
        out.push_str("|\n");
    }
    out
}

/// Simple fixed-width table.
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let mut line = String::from("  ");
    for (h, w) in headers.iter().zip(&widths) {
        line.push_str(&fmt::pad(h, w + 2));
    }
    out.push_str(line.trim_end());
    out.push('\n');
    out.push_str("  ");
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
    out.push('\n');
    for row in rows {
        let mut line = String::from("  ");
        for (c, w) in row.iter().zip(&widths) {
            line.push_str(&fmt::pad(c, w + 2));
        }
        out.push_str(line.trim_end());
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bar_chart_scales_to_max() {
        let rows = vec![("a".into(), 1.0), ("bb".into(), 2.0)];
        let s = bar_chart("t", &rows, 10, None);
        assert!(s.contains("t\n"));
        assert!(s.contains("bb"));
        // The max row should have a full-width bar.
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[2].matches('█').count() >= 10);
    }

    #[test]
    fn bar_chart_empty() {
        assert!(bar_chart("t", &[], 10, None).contains("(no data)"));
    }

    #[test]
    fn quantile_row_orders_glyphs() {
        let s = quantile_row("x", 0.0, 0.25, 0.5, 0.75, 1.0, 0.0, 1.0, 41);
        assert!(s.contains('░'));
        assert!(s.contains('▓'));
        assert!(s.contains('┃'));
    }

    #[test]
    fn table_aligns() {
        let t = table(&["op", "dur"], &[vec!["attn_fa".into(), "1.0".into()]]);
        assert!(t.contains("attn_fa"));
        assert!(t.contains("op"));
    }

    #[test]
    fn heatmap_renders_all_rows() {
        let m = vec![vec![0.0, 0.5, 1.0], vec![1.0, 0.0, 0.2]];
        let h = heatmap("smt", &m);
        assert_eq!(h.lines().count(), 3);
    }

    #[test]
    fn cdf_plot_contains_series_marks() {
        let s = cdf_plot(
            "cdf",
            &[("g0".into(), vec![1.0, 2.0, 3.0]), ("g1".into(), vec![2.0, 4.0])],
            20,
            5,
        );
        assert!(s.contains('*'));
        assert!(s.contains('+'));
        assert!(s.contains("g0"));
    }
}
