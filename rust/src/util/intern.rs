//! String interning for kernel symbol names.
//!
//! The engine used to carry an owned `String` in every `TraceEvent` —
//! one heap allocation per simulated kernel on the hottest path, plus a
//! clone into every serialization. Kernel names are drawn from a tiny,
//! program-determined vocabulary (a few dozen rocBLAS/CK-style symbols per
//! model configuration), so they are interned once at program-build time
//! and events carry a 4-byte [`Sym`] handle that resolves back to
//! `&'static str` at serialization/display time.
//!
//! The table is global, thread-safe (campaign workers intern from scoped
//! threads), and append-only; interned strings are leaked deliberately —
//! the vocabulary is bounded by the set of distinct kernel names across
//! all scenarios of a process, not by event count.
//!
//! Determinism: handle *ids* depend on interning order and are therefore
//! not stable across runs or thread interleavings — which is why [`Sym`]
//! deliberately implements neither `Ord` nor `Hash`. Equality is safe
//! (same string ⇔ same id within a process), and every serialized output
//! resolves handles back to their strings, so rendered artifacts stay
//! byte-identical regardless of interning order.

use crate::util::hash::FxHashMap;
use std::fmt;
use std::sync::{OnceLock, RwLock};

/// Interned string handle. `Copy`, 4 bytes, resolves via [`Sym::as_str`].
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct Sym(u32);

struct Interner {
    map: FxHashMap<&'static str, u32>,
    table: Vec<&'static str>,
}

static INTERNER: OnceLock<RwLock<Interner>> = OnceLock::new();

fn interner() -> &'static RwLock<Interner> {
    INTERNER.get_or_init(|| {
        RwLock::new(Interner {
            map: FxHashMap::default(),
            table: Vec::new(),
        })
    })
}

/// Intern a string, returning its handle. Read-locks on the (overwhelmingly
/// common) hit path; write-locks only when a new name first appears.
pub fn intern(s: &str) -> Sym {
    let lock = interner();
    if let Some(&id) = lock.read().unwrap().map.get(s) {
        return Sym(id);
    }
    let mut inner = lock.write().unwrap();
    // Re-check: another thread may have interned it between the locks.
    if let Some(&id) = inner.map.get(s) {
        return Sym(id);
    }
    let leaked: &'static str = Box::leak(s.to_owned().into_boxed_str());
    let id = u32::try_from(inner.table.len()).expect("interner overflow");
    inner.table.push(leaked);
    inner.map.insert(leaked, id);
    Sym(id)
}

impl Sym {
    /// Resolve back to the interned string. Takes an uncontended RwLock
    /// read (~tens of ns) — intentional: resolution happens once per event
    /// at serialization/display time, never on the engine hot path, and a
    /// lock-free read of the append-only table would require `unsafe`.
    pub fn as_str(self) -> &'static str {
        interner().read().unwrap().table[self.0 as usize]
    }
}

impl fmt::Display for Sym {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl fmt::Debug for Sym {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}", self.as_str())
    }
}

impl From<&str> for Sym {
    fn from(s: &str) -> Sym {
        intern(s)
    }
}

impl From<String> for Sym {
    fn from(s: String) -> Sym {
        intern(&s)
    }
}

impl PartialEq<&str> for Sym {
    fn eq(&self, other: &&str) -> bool {
        self.as_str() == *other
    }
}

impl PartialEq<str> for Sym {
    fn eq(&self, other: &str) -> bool {
        self.as_str() == other
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_string_same_handle() {
        let a = intern("rmsnorm_fwd_kernel_test");
        let b = intern("rmsnorm_fwd_kernel_test");
        assert_eq!(a, b);
        assert_eq!(a.as_str(), "rmsnorm_fwd_kernel_test");
    }

    #[test]
    fn distinct_strings_distinct_handles() {
        assert_ne!(intern("intern_test_a"), intern("intern_test_b"));
    }

    #[test]
    fn from_and_compare_with_str() {
        let s: Sym = "intern_test_from".into();
        assert_eq!(s, "intern_test_from");
        let owned: Sym = String::from("intern_test_owned").into();
        assert_eq!(owned.to_string(), "intern_test_owned");
        assert_eq!(format!("{owned:?}"), "\"intern_test_owned\"");
    }

    #[test]
    fn concurrent_interning_converges() {
        let handles: Vec<_> = (0..8)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut out = Vec::new();
                    for i in 0..64 {
                        out.push(intern(&format!("intern_race_{}", i)));
                    }
                    let _ = t;
                    out
                })
            })
            .collect();
        let results: Vec<Vec<Sym>> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        for w in results.windows(2) {
            assert_eq!(w[0], w[1], "threads disagree on handles");
        }
    }
}
