//! Atomic artifact writes: tmp sibling + fsync + rename.
//!
//! Extracted from `campaign::cache` so every artifact the tool emits —
//! cache entries, figures, chrome exports, serving summaries, BENCH_*.json,
//! trace stores — lands either whole or not at all. A reader never observes
//! a half-written file: the bytes go to `<path>.tmp` in the same directory,
//! are fsynced, and only then renamed over the destination (rename within a
//! directory is atomic on every platform we target).

use std::io::{self, Write};
use std::path::{Path, PathBuf};

/// Sibling temp path used during an atomic write: `<file_name>.tmp` in the
/// same directory (same filesystem, so the final rename cannot cross
/// devices). Public so crash-safety tooling can recognize torn leftovers.
pub fn tmp_sibling(path: &Path) -> PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Write `contents` to `path` atomically. On success the destination holds
/// exactly `contents`; on failure the destination is untouched (a `.tmp`
/// sibling may remain and is safe to delete or salvage).
pub fn atomic_write(path: &Path, contents: &[u8]) -> io::Result<()> {
    let tmp = tmp_sibling(path);
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(contents)?;
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)
}

/// Attach the offending path to an io error, for user-facing messages
/// (`action` is a short verb phrase, e.g. "writing"). The IO-path audit
/// routes CLI/benchkit error strings through this so a permission error or
/// full disk names the file instead of panicking.
pub fn io_ctx(action: &str, path: &Path, e: io::Error) -> String {
    format!("{action} {}: {e}", path.display())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_contents_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join(format!("chopper-aw-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("artifact.json");
        atomic_write(&p, b"{\"ok\":true}").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"{\"ok\":true}");
        assert!(!tmp_sibling(&p).exists());
        // Overwrite is atomic too.
        atomic_write(&p, b"v2").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"v2");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_parent_is_an_error_not_a_panic() {
        let p = Path::new("/nonexistent-chopper-dir/x.json");
        let e = atomic_write(p, b"x").unwrap_err();
        assert!(!io_ctx("writing", p, e).is_empty());
    }

    #[test]
    fn tmp_sibling_appends_suffix() {
        assert_eq!(
            tmp_sibling(Path::new("/a/b/c.json")),
            PathBuf::from("/a/b/c.json.tmp")
        );
        assert_eq!(tmp_sibling(Path::new("t.ctrc")), PathBuf::from("t.ctrc.tmp"));
    }
}
