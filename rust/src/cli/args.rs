//! Tiny argument parser: `prog subcommand [positional...] [--flag value]
//! [--switch]`. Unknown flags are errors; every consumed flag is tracked so
//! commands can reject leftovers.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    consumed: Vec<String>,
    pos_consumed: usize,
}

/// Is this token a flag (as opposed to a value)? Anything not starting
/// with `--` is a value — including `-0.01`-style negative numbers — and
/// so is a `--`-prefixed token that parses as a number, so flag values can
/// never be swallowed as switches.
fn looks_like_flag(tok: &str) -> bool {
    match tok.strip_prefix("--") {
        Some(rest) => rest.is_empty() || rest.parse::<f64>().is_err(),
        None => false,
    }
}

impl Args {
    pub fn parse(argv: Vec<String>) -> Result<Args, String> {
        let mut a = Args::default();
        let mut it = argv.into_iter();
        let _prog = it.next();
        if let Some(sub) = it.next() {
            a.subcommand = sub;
        }
        let mut it = it.peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare `--` is not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !looks_like_flag(n))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.flags.insert(name.to_string(), v);
                } else {
                    // Boolean switch.
                    a.flags.insert(name.to_string(), "true".into());
                }
            } else {
                a.positional.push(tok);
            }
        }
        Ok(a)
    }

    /// Consume the next positional argument, in order. Positionals not
    /// consumed by a command are rejected by [`Args::finish`].
    pub fn take_positional(&mut self) -> Option<String> {
        let v = self.positional.get(self.pos_consumed).cloned();
        if v.is_some() {
            self.pos_consumed += 1;
        }
        v
    }

    pub fn flag(&mut self, name: &str) -> Option<String> {
        self.consumed.push(name.to_string());
        self.flags.get(name).cloned()
    }

    pub fn flag_or(&mut self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or_else(|| default.to_string())
    }

    pub fn flag_u32(&mut self, name: &str, default: u32) -> Result<u32, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad number {v}")),
        }
    }

    pub fn flag_u64(&mut self, name: &str, default: u64) -> Result<u64, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad number {v}")),
        }
    }

    pub fn flag_f32(&mut self, name: &str, default: f32) -> Result<f32, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad number {v}")),
        }
    }

    pub fn flag_f64(&mut self, name: &str, default: f64) -> Result<f64, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad number {v}")),
        }
    }

    pub fn switch(&mut self, name: &str) -> bool {
        self.flag(name).map(|v| v != "false").unwrap_or(false)
    }

    /// Error on flags nobody consumed and on leftover positional
    /// arguments (catches typos). Every subcommand calls this after it has
    /// taken what it needs, so unknown input fails uniformly.
    pub fn finish(&self) -> Result<(), String> {
        for k in self.flags.keys() {
            if !self.consumed.contains(k) {
                return Err(format!("unknown flag --{k}"));
            }
        }
        if let Some(extra) = self.positional.get(self.pos_consumed) {
            return Err(format!("unexpected argument `{extra}`"));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from).collect()).unwrap()
    }

    #[test]
    fn subcommand_positional_flags() {
        let mut a = parse("chopper figure fig4 --layers 8 --out /tmp/x --fast");
        assert_eq!(a.subcommand, "figure");
        assert_eq!(a.positional, vec!["fig4"]);
        assert_eq!(a.take_positional().as_deref(), Some("fig4"));
        assert_eq!(a.flag_u32("layers", 32).unwrap(), 8);
        assert_eq!(a.flag_or("out", "."), "/tmp/x");
        assert!(a.switch("fast"));
        assert!(a.finish().is_ok());
        assert_eq!(a.take_positional(), None);
    }

    #[test]
    fn negative_numbers_are_values_not_switches() {
        let mut a = parse("chopper train --lr -0.01 --seed 7");
        assert_eq!(a.flag_f32("lr", 2.0).unwrap(), -0.01);
        assert_eq!(a.flag_u64("seed", 0).unwrap(), 7);
        assert!(a.finish().is_ok());
        let mut c = parse("chopper whatif --cap-ratio 0.65");
        assert_eq!(c.flag_f64("cap-ratio", 0.7).unwrap(), 0.65);
        assert_eq!(c.flag_f64("other", 1.5).unwrap(), 1.5);
        // Even a doubled-dash numeric token is a value, not a flag.
        let mut b = parse("chopper train --lr --0.5");
        assert_eq!(b.flag_or("lr", "x"), "--0.5");
    }

    #[test]
    fn leftover_positionals_rejected_by_finish() {
        let a = parse("chopper sweep stray");
        assert!(a.finish().is_err());
        let mut b = parse("chopper figure fig4 extra");
        assert_eq!(b.take_positional().as_deref(), Some("fig4"));
        assert!(b.finish().is_err());
    }

    #[test]
    fn equals_style_flags() {
        let mut a = parse("chopper sweep --iters=6");
        assert_eq!(a.flag_u32("iters", 20).unwrap(), 6);
    }

    #[test]
    fn unknown_flags_rejected_by_finish() {
        let a = parse("chopper sweep --whoops 3");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_number_is_an_error() {
        let mut a = parse("chopper sweep --iters banana");
        assert!(a.flag_u32("iters", 20).is_err());
    }

    #[test]
    fn missing_flags_use_defaults() {
        let mut a = parse("chopper sweep");
        assert_eq!(a.flag_u32("iters", 20).unwrap(), 20);
        assert!(!a.switch("fast"));
    }
}
