//! Tiny argument parser: `prog subcommand [positional...] [--flag value]
//! [--switch]`. Unknown flags are errors; every consumed flag is tracked so
//! commands can reject leftovers.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    consumed: Vec<String>,
}

impl Args {
    pub fn parse(argv: Vec<String>) -> Result<Args, String> {
        let mut a = Args::default();
        let mut it = argv.into_iter();
        let _prog = it.next();
        if let Some(sub) = it.next() {
            a.subcommand = sub;
        }
        let mut it = it.peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare `--` is not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.flags.insert(name.to_string(), v);
                } else {
                    // Boolean switch.
                    a.flags.insert(name.to_string(), "true".into());
                }
            } else {
                a.positional.push(tok);
            }
        }
        Ok(a)
    }

    pub fn flag(&mut self, name: &str) -> Option<String> {
        self.consumed.push(name.to_string());
        self.flags.get(name).cloned()
    }

    pub fn flag_or(&mut self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or_else(|| default.to_string())
    }

    pub fn flag_u32(&mut self, name: &str, default: u32) -> Result<u32, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad number {v}")),
        }
    }

    pub fn flag_u64(&mut self, name: &str, default: u64) -> Result<u64, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad number {v}")),
        }
    }

    pub fn flag_f32(&mut self, name: &str, default: f32) -> Result<f32, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name}: bad number {v}")),
        }
    }

    pub fn switch(&mut self, name: &str) -> bool {
        self.flag(name).map(|v| v != "false").unwrap_or(false)
    }

    /// Error on flags nobody consumed (catches typos).
    pub fn finish(&self) -> Result<(), String> {
        for k in self.flags.keys() {
            if !self.consumed.contains(k) {
                return Err(format!("unknown flag --{k}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from).collect()).unwrap()
    }

    #[test]
    fn subcommand_positional_flags() {
        let mut a = parse("chopper figure fig4 --layers 8 --out /tmp/x --fast");
        assert_eq!(a.subcommand, "figure");
        assert_eq!(a.positional, vec!["fig4"]);
        assert_eq!(a.flag_u32("layers", 32).unwrap(), 8);
        assert_eq!(a.flag_or("out", "."), "/tmp/x");
        assert!(a.switch("fast"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn equals_style_flags() {
        let mut a = parse("chopper sweep --iters=6");
        assert_eq!(a.flag_u32("iters", 20).unwrap(), 6);
    }

    #[test]
    fn unknown_flags_rejected_by_finish() {
        let a = parse("chopper sweep --whoops 3");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_number_is_an_error() {
        let mut a = parse("chopper sweep --iters banana");
        assert!(a.flag_u32("iters", 20).is_err());
    }

    #[test]
    fn missing_flags_use_defaults() {
        let mut a = parse("chopper sweep");
        assert_eq!(a.flag_u32("iters", 20).unwrap(), 20);
        assert!(!a.switch("fast"));
    }
}
