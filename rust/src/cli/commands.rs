//! CLI subcommand implementations.

use crate::campaign::{self, grid, Cache, GridSpec};
use crate::chopper::report;
use crate::chopper::{CpuUtilAnalysis, Filter};
use crate::cli::Args;
use crate::config::{
    FaultSpec, FsdpVersion, ModelConfig, NodeSpec, Sharding, Topology,
    WorkloadConfig,
};
use crate::sim::run_workload_topo;
use crate::trace::chrome;
use crate::util::fmt;
use std::path::PathBuf;

pub const USAGE: &str = "\
chopper — multi-level GPU characterization of LLM training (simulated
MI300X node + real PJRT mini-Llama path)

USAGE: chopper <subcommand> [options]

  sweep    [--layers N] [--iters N] [--warmup N] [--thermal SPEC]
           [--out DIR]
           Profile the paper sweep (b1s4 b2s4 b4s4 b1s8 b2s8 × v1,v2) and
           write every figure (txt/csv/svg) to DIR (default: figures/).
           --thermal couples the RC die-temperature model into the
           governor loop (grammar under campaign) and additionally
           writes the thermal figures (thermal, throttle); without it
           the output is byte-identical to pre-thermal builds.
  campaign [--layers 2,4] [--batch 1,2,4] [--seq 4,8 (K tokens)]
           [--fsdp v1,v2] [--nodes 1,2,4] [--sharding fsdp,hsdp]
           [--nic-gbs 50,12.5]
           [--governor reactive,fixed_cap,det_aware,oracle,thermal_aware]
           [--workload training|serving] [--qps 4,8,16] [--requests N]
           [--iters N] [--warmup N] [--seed N]
           [--ablate knob=v1,v2[;knob2=...]]
           [--faults 'none;straggler(factor=0.8)+stalls(rate=0.02)']
           [--thermal 'none;thermal(ambient=45,tau=2)'] [--ambient 35;85]
           [--fold 1,32] [--jobs N] [--cache-dir DIR] [--force]
           [--no-cache] [--resume] [--trace-store] [--in-memory]
           [--out DIR]
           Expand the scenario grid (model × workload × topology ×
           governor policy × engine-parameter ablations × injected fault
           sets), fan scenarios out over worker threads, reuse cached
           results, and print cross-scenario comparison tables incl.
           energy columns (plus per-node rollups on multi-node grids, a
           cross-policy energy/perf table on --governor grids, a
           latency/goodput table on --workload serving grids with a --qps
           axis, and a fault-impact table on --faults grids). A scenario
           that panics is isolated: marked `failed`, the sweep continues,
           and --resume retries exactly the missing/failed scenarios of an
           interrupted or partly-failed campaign from the cache.
           --trace-store streams each training scenario's events to a
           checksummed binary store (<cache>/<name>-<fp>.ctrc) while it
           runs; --resume rebuilds missing summaries from finalized
           stores without re-running (chunk-wise indexed by default;
           --in-memory materializes first), and `chopper fsck` salvages
           the torn .ctrc.tmp a killed run leaves behind.
           --fold F simulates num_nodes/F representative replica nodes
           per scenario and folds results back to the logical cluster
           (rank-symmetry folding, DESIGN.md §13) — 10k-GPU sweeps at
           the cost of the distinct groups. Training + HSDP grids only;
           every --nodes value must be a multiple of every fold factor;
           replica-pinned faults (straggler/linkdown/dropout) are
           rejected under folding.
           Knobs: spin_penalty transfer_penalty comm_stretch rank_jitter
           compute_jitter dispatch_jitter comm_delay_sigma_ns
           far_rank_delay_ns dvfs_window_ns margin_k fixed_cap_ratio.
           Faults: straggler(rank,factor) linkdown(node,bw)
           stalls(rate,mean_us) dropout(rank,at_ms,restart_ms) panic;
           sets separated by `;`, faults within a set joined by `+`,
           `none` = healthy baseline.
           Thermal: thermal(ambient,tau,r,throttle,limit,floor,sigma,
           skew,hbm), `;`-separated axis values, `none` = RC model off
           (the default — byte-identical to pre-thermal output);
           --ambient 35;85 is sugar for default configs at those
           ambients. Thermal scenarios add a peak-temperature /
           throttle-loss comparison table.
  serve    [--qps 4,8,16] [--requests N] [--layers N] [--nodes N]
           [--max-batch N] [--prefill-chunk N] [--kv-frac 0.30]
           [--slo-ttft-ms 200] [--seed N] [--jobs N] [--out DIR]
           Run the continuous-batching serving workload (open-loop
           Poisson arrivals) over an offered-load sweep; print and write
           the serving figures (latency percentiles, goodput-vs-load,
           energy per request) plus serving_summary.json.
  whatif   [--workload b2s4|serving] [--fsdp v1|v2] [--layers N] [--iters N]
           [--warmup N]
           [--governor reactive,fixed_cap,det_aware,oracle,thermal_aware]
           [--cap-ratio 0.7] [--thermal SPEC] [--faults SETS] [--nodes N]
           [--fold F] [--jobs N] [--out DIR]
           Replay one workload under a set of power-management policies
           and print the ranked advisor report: Δ iteration time,
           Δ energy, and the perf-per-watt (time × energy) frontier.
           With --thermal (same grammar as campaign), every replay runs
           under the RC thermal model and the report prices per-policy
           throttle loss alongside time and energy.
           With --workload serving ([--qps X] [--requests N] [--seed N]),
           policies are ranked by joules per request alongside
           tokens-per-joule, p99 latency and goodput.
           With --faults (same grammar as campaign; training only), the
           dimension is injected fault sets instead of policies: each set
           replays against the healthy `none` baseline with Δ iteration
           time, Δ energy, restart-lost and blocked-on-straggler time.
           --nodes replays a multi-node HSDP cluster; --fold F folds its
           replica nodes (F must divide N) so policy what-ifs scale to
           10k-GPU clusters; faults and serving do not fold.
  figure   <table2|fig4..fig15|all> [--layers N] [--iters N] [--out DIR]
           Regenerate one figure; prints the ASCII rendering.
  collect  [--workload b2s4] [--fsdp v1|v2] [--nodes N] [--sharding
           fsdp|hsdp] [--layers N] [--iters N] [--store] [--out PATH]
           Runtime-profile one workload and write a chrome trace
           (trace.json). With --store, stream events out-of-core into the
           checksummed binary columnar store instead (trace.ctrc; bounded
           memory, crash-safe, `chopper analyze` reads both).
  analyze  <trace.json|trace.ctrc> [--in-memory]
           Aggregate statistics from a trace file (chrome JSON from any
           source, or a binary .ctrc store — damaged stores are salvaged
           and the loss is reported). Stores are indexed chunk-wise as
           they stream in; --in-memory materializes the whole trace
           first (identical output, the pre-chunk-wise path).
  fsck     <trace.ctrc[.tmp]> [--repair]
           Validate a binary trace store chunk by chunk (magic, framing,
           CRC32, footer). Damage exits nonzero and reports exactly what
           survives; --repair rewrites the longest valid prefix as a
           finalized store (a torn `x.ctrc.tmp` repairs to `x.ctrc`).
  train    [--steps N] [--lr X] [--seed N] [--artifacts DIR]
           Train the executable mini-Llama via the PJRT runtime.
  config   [--model llama3-8b|mini]
           Print the model configuration (Table II).
";

fn model_with_layers(args: &mut Args) -> Result<ModelConfig, String> {
    let mut cfg = ModelConfig::llama3_8b();
    let layers = args.flag_u64("layers", cfg.layers)?;
    cfg.layers = layers;
    Ok(cfg)
}

fn parse_fsdp(s: &str) -> Result<FsdpVersion, String> {
    match s {
        "v1" | "V1" | "fsdpv1" => Ok(FsdpVersion::V1),
        "v2" | "V2" | "fsdpv2" => Ok(FsdpVersion::V2),
        _ => Err(format!("bad --fsdp {s} (use v1 or v2)")),
    }
}

pub fn cmd_sweep(args: &mut Args) -> Result<(), String> {
    let cfg = model_with_layers(args)?;
    let iters = args.flag_u32("iters", 20)?;
    let warmup = args.flag_u32("warmup", iters / 2)?;
    let thermal = match args.flag("thermal") {
        Some(s) => crate::sim::parse_thermal(&s)?,
        None => None,
    };
    let out: PathBuf = args.flag_or("out", "figures").into();
    args.finish()?;
    let node = NodeSpec::mi300x_node();
    eprintln!(
        "sweep: {} layers, {iters} iterations ({warmup} warmup), 10 runs…",
        cfg.layers
    );
    // Default params keep this byte-identical to the pre-thermal sweep.
    let mut params = crate::sim::EngineParams::default();
    params.thermal = thermal;
    let jobs = campaign::default_jobs();
    let runs = report::run_sweep_topo_params(
        &Topology::single(node.clone()),
        &cfg,
        &[FsdpVersion::V1, FsdpVersion::V2],
        iters,
        warmup,
        &params,
    );
    let mut figs = report::render_all(&node, &cfg, &runs, jobs)?;
    // Thermal figures exist only when the runs carry thermal telemetry.
    figs.extend(report::render_thermal(&runs, jobs));
    for f in &figs {
        f.save(&out).map_err(|e| e.to_string())?;
        eprintln!("wrote {}/{}.{{txt,csv}}", out.display(), f.id);
    }
    println!("{} figures written to {}", figs.len(), out.display());
    Ok(())
}

/// `campaign` — expand a scenario grid, run it in parallel with caching,
/// and render the cross-scenario comparison figures.
pub fn cmd_campaign(args: &mut Args) -> Result<(), String> {
    let layers = grid::parse_list_u64(&args.flag_or("layers", "2"))?;
    let batches = grid::parse_list_u64(&args.flag_or("batch", "1,2,4"))?;
    // Sequence lengths are given in K tokens, like the paper's labels.
    let seqs: Vec<u64> = grid::parse_list_u64(&args.flag_or("seq", "4,8"))?
        .into_iter()
        .map(|k| k * 1024)
        .collect();
    let fsdp = grid::parse_list_fsdp(&args.flag_or("fsdp", "v1,v2"))?;
    let nodes = grid::parse_list_nodes(&args.flag_or("nodes", "1"))?;
    let shardings = grid::parse_list_sharding(&args.flag_or("sharding", "fsdp"))?;
    let nic_gbs = match args.flag("nic-gbs") {
        Some(s) => grid::parse_list_f64(&s)?,
        None => Vec::new(),
    };
    let governors = grid::parse_list_governor(&args.flag_or("governor", "reactive"))?;
    if governors.is_empty() {
        return Err("campaign: --governor needs at least one policy".into());
    }
    let workload = args.flag_or("workload", "training");
    let qps = match args.flag("qps") {
        Some(s) => grid::parse_list_f64(&s)?,
        None => Vec::new(),
    };
    let requests = args.flag_u32("requests", 32)?;
    let iters = args.flag_u32("iters", 4)?;
    let warmup = args.flag_u32("warmup", iters / 2)?;
    let seed = args.flag_u64("seed", 0xC0FFEE)?;
    let ablations = match args.flag("ablate") {
        Some(s) => grid::parse_ablations(&s)?,
        None => Vec::new(),
    };
    let faults = match args.flag("faults") {
        Some(s) => grid::parse_list_faults(&s)?,
        None => Vec::new(),
    };
    let folds = match args.flag("fold") {
        Some(s) => grid::parse_list_folds(&s)?,
        None => Vec::new(),
    };
    let thermals = match args.flag("thermal") {
        Some(s) => grid::parse_list_thermal(&s)?,
        None => Vec::new(),
    };
    let ambients = match args.flag("ambient") {
        Some(s) => grid::parse_list_ambient(&s)?,
        None => Vec::new(),
    };
    if !thermals.is_empty() && !ambients.is_empty() {
        return Err(
            "campaign: --ambient is sugar for --thermal (give one axis, \
             not both)"
                .into(),
        );
    }
    let thermals = if thermals.is_empty() { ambients } else { thermals };
    let jobs = args.flag_u32("jobs", campaign::default_jobs() as u32)? as usize;
    let cache_dir: PathBuf = args.flag_or("cache-dir", ".chopper-cache").into();
    let force = args.switch("force");
    let no_cache = args.switch("no-cache");
    let resume = args.switch("resume");
    let trace_store = args.switch("trace-store");
    let in_memory = args.switch("in-memory");
    let out = args.flag("out").map(PathBuf::from);
    args.finish()?;
    // Replica folding (DESIGN.md §13) composes with the other axes only
    // where the fold is semantically sound; every rejection here names the
    // offending input rather than silently producing a wrong simulation.
    if folds.iter().any(|&f| f > 1) {
        if workload == "serving" {
            return Err(
                "campaign: --fold folds symmetric training replicas \
                 (serving requests are not rank-symmetric; drop \
                 --workload serving)"
                    .into(),
            );
        }
        if !shardings.iter().all(|s| matches!(s, Sharding::Hsdp)) {
            return Err(
                "campaign: --fold exploits the data-parallel replica \
                 symmetry of HSDP node groups (use --sharding hsdp)"
                    .into(),
            );
        }
        for &f in folds.iter().filter(|&&f| f > 1) {
            if let Some(&n) = nodes.iter().find(|&&n| n % f != 0) {
                return Err(format!(
                    "campaign: fold {f} does not divide --nodes {n} \
                     (every node count must be a multiple of every fold \
                     factor)"
                ));
            }
        }
        // A fault pinned to one replica (straggler rank, linkdown node,
        // dropout rank) inside a folded class would silently replay on
        // *every* replica the representative stands for — reject it with
        // the fault's name instead (run it exact, or drop the fault).
        for spec in faults.iter().flatten() {
            if !spec.fold_compatible() {
                return Err(format!(
                    "campaign: fault `{}` pins a specific replica and \
                     cannot run under --fold (it would multiply across \
                     every folded copy); drop --fold or the fault",
                    spec.label()
                ));
            }
        }
    }
    if resume && no_cache {
        return Err("campaign: --resume needs the cache (drop --no-cache)".into());
    }
    if trace_store && no_cache {
        return Err(
            "campaign: --trace-store writes stores into the cache directory \
             (drop --no-cache)"
                .into(),
        );
    }
    if resume && force {
        return Err(
            "campaign: --resume conflicts with --force (resume reuses, force re-runs)"
                .into(),
        );
    }

    let mut spec = GridSpec::paper(2, iters, warmup);
    spec.layers = layers;
    spec.batches = batches;
    spec.seqs = seqs;
    spec.fsdp = fsdp;
    spec.nodes = nodes;
    spec.shardings = shardings;
    spec.nic_gbs = nic_gbs;
    spec.governors = governors;
    spec.seed = seed;
    spec.ablations = ablations;
    if !faults.is_empty() {
        spec.faults = faults;
    }
    if !folds.is_empty() {
        spec.folds = folds;
    }
    if !thermals.is_empty() {
        spec.thermals = thermals;
    }
    match workload.as_str() {
        "training" => {
            if !qps.is_empty() {
                return Err(
                    "campaign: --qps needs --workload serving".into()
                );
            }
        }
        "serving" => {
            if requests == 0 {
                return Err("campaign: --requests needs at least 1".into());
            }
            if qps.iter().any(|&q| !(q > 0.0 && q.is_finite())) {
                return Err("campaign: --qps rates must be positive".into());
            }
            let base = crate::config::ServingConfig::new(8.0, requests);
            spec.serving = Some(base);
            spec.qps = qps;
        }
        other => {
            return Err(format!(
                "campaign: bad --workload {other} (use training or serving)"
            ))
        }
    }
    let scenarios = spec.expand();
    if scenarios.is_empty() {
        return Err("campaign: empty grid (every axis needs ≥1 value)".into());
    }
    let cache = if no_cache {
        None
    } else {
        Some(Cache::open(&cache_dir).map_err(|e| {
            format!("campaign: cannot open cache {}: {e}", cache_dir.display())
        })?)
    };
    eprintln!(
        "campaign: {} scenarios × {} iterations, {jobs} worker(s), cache {}…",
        scenarios.len(),
        iters,
        if no_cache { "off".to_string() } else { cache_dir.display().to_string() },
    );
    let node = NodeSpec::mi300x_node();
    if resume {
        // Pre-scan so an interrupted campaign says up front how much of
        // the grid survives (the run itself reuses the same cache hits).
        let c = cache
            .as_ref()
            .ok_or("campaign: --resume needs an open cache")?;
        let done = scenarios
            .iter()
            .filter(|sc| {
                c.load(&sc.name, campaign::fingerprint(&node, sc)).is_some()
            })
            .count();
        eprintln!(
            "campaign: resuming — {done} of {} scenarios already cached",
            scenarios.len()
        );
    }
    let t0 = std::time::Instant::now();
    let outcome = campaign::run_campaign_stored(
        &node,
        &scenarios,
        jobs,
        cache.as_ref(),
        force,
        trace_store,
        in_memory,
    );
    eprintln!(
        "campaign: {} executed, {} cached in {:.2}s",
        outcome.executed,
        outcome.cached,
        t0.elapsed().as_secs_f64()
    );
    if outcome.restored > 0 {
        eprintln!(
            "campaign: {} summary(ies) rebuilt from finalized trace stores \
             (no engine re-run)",
            outcome.restored
        );
    }
    if outcome.failed > 0 {
        eprintln!(
            "campaign: {} scenario(s) failed and were isolated (not cached; \
             re-run with --resume to retry them)",
            outcome.failed
        );
    }
    let mut figs = vec![
        campaign::campaign_table(&outcome.summaries),
        campaign::campaign_breakdown(&outcome.summaries),
    ];
    // Per-node rollup table when the grid has any multi-node scenario.
    if outcome.summaries.iter().any(|s| s.num_nodes > 1) {
        figs.push(campaign::campaign_by_nodes(&outcome.summaries));
    }
    // Cross-policy energy/perf table when the grid has a governor axis.
    if outcome.summaries.iter().any(|s| s.governor != "reactive") {
        figs.push(campaign::campaign_by_governor(&outcome.summaries));
    }
    // Latency/goodput/energy table on serving grids.
    if outcome.summaries.iter().any(|s| s.offered_qps > 0.0) {
        figs.push(campaign::campaign_serving(&outcome.summaries));
    }
    // Peak-temperature / throttle-loss table on thermal grids.
    if outcome.summaries.iter().any(|s| s.peak_temp_c != 0.0) {
        figs.push(campaign::campaign_thermal(&outcome.summaries));
    }
    // Fault-impact table when the grid injected faults or a scenario
    // failed (a crash must be visible in the report, not just stderr).
    if outcome
        .summaries
        .iter()
        .any(|s| !s.faults.is_empty() || s.status != "ok")
    {
        figs.push(campaign::campaign_faults(&outcome.summaries));
    }
    for f in &figs {
        println!("{}", f.ascii);
        if let Some(dir) = &out {
            f.save(dir).map_err(|e| e.to_string())?;
            eprintln!("wrote {}/{}.{{txt,csv}}", dir.display(), f.id);
        }
    }
    Ok(())
}

/// `whatif` — replay one workload under a set of power-management
/// policies and print the ranked advisor report (chopper::whatif).
pub fn cmd_whatif(args: &mut Args) -> Result<(), String> {
    let mut cfg = ModelConfig::llama3_8b();
    cfg.layers = args.flag_u64("layers", 8)?;
    let label = args.flag_or("workload", "b2s4");
    let fsdp = parse_fsdp(&args.flag_or("fsdp", "v1"))?;
    let iters = args.flag_u32("iters", 6)?;
    let warmup = args.flag_u32("warmup", iters / 2)?;
    let nodes = args.flag_u32("nodes", 1)?.max(1);
    let fold = args.flag_u32("fold", 1)?;
    if fold == 0 {
        return Err("whatif: --fold needs at least 1 (1 = exact)".into());
    }
    // Same flag spelling as `campaign --governor` (one axis, one name).
    let governors = crate::sim::parse_list_governor(
        &args.flag_or("governor", "reactive,fixed_cap,det_aware,oracle"),
    )?;
    let cap_ratio = args.flag_f64("cap-ratio", 0.7)?;
    // Same spec grammar as `campaign --thermal` (one model, one spelling);
    // a single spec here — the replay dimension is policies, not climates.
    let thermal = match args.flag("thermal") {
        Some(s) => crate::sim::parse_thermal(&s)?,
        None => None,
    };
    let fault_sets = match args.flag("faults") {
        Some(s) => Some(crate::config::parse_list_faults(&s)?),
        None => None,
    };
    if let Some(sets) = &fault_sets {
        // The `panic` fault exists to exercise the campaign runner's
        // isolation; a direct replay has nothing to catch it with.
        if sets.iter().flatten().any(|f| matches!(f, FaultSpec::Panic)) {
            return Err(
                "whatif: the `panic` fault is a campaign-runner test hook \
                 (use it under `chopper campaign`)"
                    .into(),
            );
        }
    }
    let jobs = args.flag_u32("jobs", campaign::default_jobs() as u32)? as usize;
    let out = args.flag("out").map(PathBuf::from);
    if label == "serving" {
        if fault_sets.is_some() {
            return Err(
                "whatif: --faults replays a training workload (drop \
                 --workload serving)"
                    .into(),
            );
        }
        if nodes > 1 || fold > 1 {
            return Err(
                "whatif: --nodes/--fold replay a training workload (drop \
                 --workload serving)"
                    .into(),
            );
        }
        // Serving replay: rank the policies by joules per request.
        let qps = args.flag_f64("qps", 8.0)?;
        let requests = args.flag_u32("requests", 32)?;
        let seed = args.flag_u64("seed", 0xC0FFEE)?;
        args.finish()?;
        if governors.is_empty() {
            return Err("whatif: --governor needs at least one policy".into());
        }
        if !(cap_ratio > 0.0 && cap_ratio.is_finite()) {
            return Err(format!("whatif: bad --cap-ratio {cap_ratio}"));
        }
        if !(qps > 0.0 && qps.is_finite()) {
            return Err(format!("whatif: bad --qps {qps}"));
        }
        if requests == 0 {
            return Err("whatif: --requests needs at least 1".into());
        }
        let mut scfg = crate::config::ServingConfig::new(qps, requests);
        scfg.seed = seed;
        let mut params = crate::sim::EngineParams::default();
        params.fixed_cap_ratio = cap_ratio;
        params.thermal = thermal;
        let topo = Topology::mi300x_cluster(1);
        eprintln!(
            "whatif: {} × {} layers under {} policies, {jobs} worker(s)…",
            scfg.label(),
            cfg.layers,
            governors.len()
        );
        let report = crate::chopper::whatif::replay_serving(
            &topo, &cfg, &scfg, &params, &governors, jobs,
        );
        let fig = crate::chopper::whatif::render_serving(&report);
        println!("{}", fig.ascii);
        if let Some(dir) = &out {
            fig.save(dir).map_err(|e| e.to_string())?;
            eprintln!("wrote {}/{}.{{txt,csv}}", dir.display(), fig.id);
        }
        return Ok(());
    }
    args.finish()?;
    if governors.is_empty() {
        return Err("whatif: --governor needs at least one policy".into());
    }
    if !(cap_ratio > 0.0 && cap_ratio.is_finite()) {
        return Err(format!("whatif: bad --cap-ratio {cap_ratio}"));
    }
    if fold > 1 {
        if nodes % fold != 0 {
            return Err(format!(
                "whatif: --fold {fold} does not divide --nodes {nodes}"
            ));
        }
        if fault_sets.is_some() {
            // The fault replay dimension measures per-replica damage —
            // the one thing folding cannot represent (DESIGN.md §13).
            return Err(
                "whatif: --faults measures per-replica damage, which \
                 folding cannot represent (drop --fold)"
                    .into(),
            );
        }
    }
    let mut wl = WorkloadConfig::parse_label(&label, fsdp)
        .ok_or_else(|| format!("bad --workload {label}"))?;
    wl.iterations = iters;
    wl.warmup = warmup;
    if nodes > 1 {
        // Multi-node replay shards within the node and replicates across
        // nodes — the symmetry --fold exploits.
        wl.sharding = Sharding::Hsdp;
    }
    let mut params = crate::sim::EngineParams::default();
    params.fixed_cap_ratio = cap_ratio;
    params.thermal = thermal;
    let node = NodeSpec::mi300x_node();
    if let Some(sets) = &fault_sets {
        if nodes > 1 {
            return Err(
                "whatif: --faults replay is single-node (drop --nodes)"
                    .into(),
            );
        }
        // Fault dimension: replay the identical workload per fault set
        // against the always-present healthy baseline.
        eprintln!(
            "whatif: {} × {} layers × {iters} iters under {} fault set(s), \
             {jobs} worker(s)…",
            wl.label_with_fsdp(),
            cfg.layers,
            sets.len()
        );
        let report = crate::chopper::whatif::replay_faults(
            &node, &cfg, &wl, &params, sets, jobs,
        );
        let fig = crate::chopper::whatif::render_faults(&report);
        println!("{}", fig.ascii);
        if let Some(dir) = &out {
            fig.save(dir).map_err(|e| e.to_string())?;
            eprintln!("wrote {}/{}.{{txt,csv}}", dir.display(), fig.id);
        }
        return Ok(());
    }
    // Exact single-node replays take the identical code path as before
    // --nodes/--fold existed: `Topology::single` is what `replay` wraps.
    let topo = if nodes > 1 {
        Topology::mi300x_cluster(nodes).with_fold(fold)
    } else {
        Topology::single(node.clone()).with_fold(fold)
    };
    eprintln!(
        "whatif: {} × {} layers × {iters} iters under {} policies{}, \
         {jobs} worker(s)…",
        wl.label_with_fsdp(),
        cfg.layers,
        governors.len(),
        if fold > 1 {
            format!(" ({nodes} logical nodes folded ×{fold})")
        } else if nodes > 1 {
            format!(" ({nodes} nodes)")
        } else {
            String::new()
        }
    );
    let report = crate::chopper::whatif::replay_topo(
        &topo, &cfg, &wl, &params, &governors, jobs,
    );
    let fig = crate::chopper::whatif::render(&report);
    println!("{}", fig.ascii);
    if let Some(dir) = &out {
        fig.save(dir).map_err(|e| e.to_string())?;
        eprintln!("wrote {}/{}.{{txt,csv}}", dir.display(), fig.id);
    }
    Ok(())
}

/// `serve` — run the continuous-batching serving workload over an
/// offered-load sweep and render the serving figures (chopper::serving).
/// The sweep fans out over `run_ordered`, so `--jobs N` output is
/// byte-identical to a serial run (the serving determinism contract).
pub fn cmd_serve(args: &mut Args) -> Result<(), String> {
    let cfg = model_with_layers(args)?;
    let qps = grid::parse_list_f64(&args.flag_or("qps", "8"))?;
    let requests = args.flag_u32("requests", 64)?;
    let nodes = args.flag_u32("nodes", 1)?.max(1);
    let max_batch = args.flag_u32("max-batch", 64)?;
    let prefill_chunk = args.flag_u64("prefill-chunk", 8192)?;
    let kv_frac = args.flag_f64("kv-frac", 0.30)?;
    let slo_ttft_ms = args.flag_f64("slo-ttft-ms", 200.0)?;
    let seed = args.flag_u64("seed", 0xC0FFEE)?;
    let jobs = args.flag_u32("jobs", campaign::default_jobs() as u32)? as usize;
    let out = args.flag("out").map(PathBuf::from);
    args.finish()?;
    if qps.is_empty() || qps.iter().any(|&q| !(q > 0.0 && q.is_finite())) {
        return Err("serve: --qps needs positive offered loads".into());
    }
    if requests == 0 {
        return Err("serve: --requests needs at least 1".into());
    }
    if !(kv_frac > 0.0 && kv_frac <= 1.0) {
        return Err(format!("serve: bad --kv-frac {kv_frac} (use (0,1])"));
    }
    if max_batch == 0 || prefill_chunk == 0 {
        return Err("serve: --max-batch/--prefill-chunk need at least 1".into());
    }
    let topo = Topology::mi300x_cluster(nodes);
    let params = crate::sim::EngineParams::default();
    eprintln!(
        "serve: {requests} requests × {} offered load(s), {} layers, \
         {jobs} worker(s)…",
        qps.len(),
        cfg.layers
    );
    // QPS siblings share the seed (the campaign sibling rule): the sweep
    // measures offered load, not seed noise.
    let reports: Vec<crate::serve::ServingReport> =
        campaign::run_ordered(&qps, jobs, |_, &q| {
            let mut scfg = crate::config::ServingConfig::new(q, requests);
            scfg.max_batch = max_batch;
            scfg.prefill_chunk = prefill_chunk;
            scfg.kv_frac = kv_frac;
            scfg.slo_ttft_ms = slo_ttft_ms;
            scfg.seed = seed;
            crate::serve::run_serving(&topo, &cfg, &scfg, params.clone())
                .report
        });
    let figs = vec![
        crate::chopper::serving_latency(&reports),
        crate::chopper::serving_goodput(&reports),
        crate::chopper::serving_energy(&reports),
    ];
    for f in &figs {
        println!("{}", f.ascii);
        if let Some(dir) = &out {
            f.save(dir).map_err(|e| e.to_string())?;
            eprintln!("wrote {}/{}.{{txt,csv,svg}}", dir.display(), f.id);
        }
    }
    if let Some(dir) = &out {
        let mut json = String::from("[\n");
        for (i, r) in reports.iter().enumerate() {
            json.push_str("  ");
            json.push_str(&r.to_json());
            json.push_str(if i + 1 < reports.len() { ",\n" } else { "\n" });
        }
        json.push_str("]\n");
        std::fs::create_dir_all(dir)
            .map_err(|e| crate::util::io_ctx("creating", dir, e))?;
        let path = dir.join("serving_summary.json");
        crate::util::atomic_write(&path, json.as_bytes())
            .map_err(|e| crate::util::io_ctx("writing", &path, e))?;
        eprintln!("wrote {}", path.display());
    }
    Ok(())
}

pub fn cmd_figure(args: &mut Args) -> Result<(), String> {
    let id = args
        .take_positional()
        .ok_or("figure: missing id (table2, fig4…fig15, all)")?;
    if id == "fig10" {
        args.finish()?;
        println!("{}", report::fig10().ascii);
        return Ok(());
    }
    if id == "table2" {
        let cfg = model_with_layers(args)?;
        args.finish()?;
        println!("{}", report::table2(&cfg).ascii);
        return Ok(());
    }
    let cfg = model_with_layers(args)?;
    let iters = args.flag_u32("iters", 4)?;
    let warmup = args.flag_u32("warmup", iters / 2)?;
    let out = args.flag("out").map(PathBuf::from);
    args.finish()?;
    if !report::ALL_FIGURES.contains(&id.as_str()) && id != "all" {
        return Err(format!(
            "unknown figure `{id}` (have: {} or all)",
            report::ALL_FIGURES.join(", ")
        ));
    }
    let node = NodeSpec::mi300x_node();
    eprintln!("profiling sweep ({} layers, {iters} iters)…", cfg.layers);
    let runs = report::run_sweep(
        &node,
        &cfg,
        &[FsdpVersion::V1, FsdpVersion::V2],
        iters,
        warmup,
    );
    let figs =
        report::render_all(&node, &cfg, &runs, campaign::default_jobs())?;
    for f in figs {
        if id == "all" || f.id == id {
            println!("{}", f.ascii);
            if let Some(dir) = &out {
                f.save(dir).map_err(|e| e.to_string())?;
            }
        }
    }
    Ok(())
}

/// `collect --store`: stream the workload's events straight into an
/// on-disk trace store (bounded memory — chunks flush at iteration
/// boundaries), finalize it, and reload it. The analysis `collect` prints
/// afterwards runs on the reloaded copy, so every invocation exercises the
/// full write→read round trip.
fn collect_streamed(
    topo: &Topology,
    cfg: &ModelConfig,
    wl: &WorkloadConfig,
    out: &std::path::Path,
) -> Result<crate::sim::ProfiledRun, String> {
    use crate::trace::store::{read_store, SharedSink, StoreWriter};
    use std::cell::RefCell;
    use std::rc::Rc;
    let meta = crate::sim::provisional_meta(topo, wl);
    let w = StoreWriter::create(out, &meta)
        .map_err(|e| crate::util::io_ctx("creating", out, e))?;
    let shared = Rc::new(RefCell::new(w));
    let mut run = crate::sim::run_workload_topo_sink(
        topo,
        cfg,
        wl,
        crate::sim::EngineParams::default(),
        Box::new(SharedSink(shared.clone())),
    );
    let w = Rc::try_unwrap(shared)
        .map_err(|_| "store writer still shared after run".to_string())?
        .into_inner();
    let info = w
        .finalize(&run.trace.meta, &run.power, &run.iter_bounds)
        .map_err(|e| crate::util::io_ctx("finalizing", out, e))?;
    eprintln!(
        "store: {} chunk(s), {} power samples, {} bytes ({:.1} B/event)",
        info.chunks,
        info.samples,
        info.bytes,
        info.bytes as f64 / info.events.max(1) as f64
    );
    let loaded = read_store(out)?;
    run.trace = loaded.trace;
    run.power = loaded.power;
    run.iter_bounds = loaded.iter_bounds;
    Ok(run)
}

pub fn cmd_collect(args: &mut Args) -> Result<(), String> {
    let cfg = model_with_layers(args)?;
    let label = args.flag_or("workload", "b2s4");
    let fsdp = parse_fsdp(&args.flag_or("fsdp", "v1"))?;
    let nodes = args.flag_u32("nodes", 1)?.max(1);
    let sharding_s = args.flag_or("sharding", "fsdp");
    let sharding = Sharding::parse(&sharding_s)
        .ok_or_else(|| format!("bad --sharding {sharding_s} (use fsdp/hsdp)"))?;
    let iters = args.flag_u32("iters", 20)?;
    let warmup = args.flag_u32("warmup", iters / 2)?;
    let store = args.switch("store");
    let out: PathBuf = args
        .flag_or("out", if store { "trace.ctrc" } else { "trace.json" })
        .into();
    args.finish()?;
    let mut wl = WorkloadConfig::parse_label(&label, fsdp)
        .ok_or_else(|| format!("bad --workload {label}"))?;
    wl.sharding = sharding;
    wl.iterations = iters;
    wl.warmup = warmup;
    let topo = Topology::mi300x_cluster(nodes);
    let run = if store {
        collect_streamed(&topo, &cfg, &wl, &out)?
    } else {
        let run = run_workload_topo(&topo, &cfg, &wl);
        chrome::write_chrome_trace(&run.trace, &out)
            .map_err(|e| crate::util::io_ctx("writing", &out, e))?;
        run
    };
    println!(
        "wrote {} ({} events, span {})",
        out.display(),
        run.trace.events.len(),
        fmt::dur_ns(run.trace.span_ns())
    );
    let cpu = CpuUtilAnalysis::analyze(&run.cpu);
    println!(
        "cpu: median active {:.0} cores, min bound {:.1}",
        cpu.median_active(),
        cpu.median_min_cores()
    );
    // Energy rollups: join the power telemetry onto the trace index and
    // report where the joules went (sim::power / DESIGN.md §9).
    let mut idx = crate::chopper::TraceIndex::build(&run.trace);
    idx.attach_power(&run.power);
    let by_phase = idx.energy_by_phase();
    let phase_j = |ph: crate::model::ops::Phase| -> f64 {
        by_phase
            .iter()
            .filter(|((p, _), _)| *p == ph)
            .map(|(_, v)| *v)
            .sum()
    };
    println!(
        "energy: {:.1} J total ({:.1} fwd / {:.1} bwd / {:.1} opt attributed)",
        idx.total_energy_j(),
        phase_j(crate::model::ops::Phase::Forward),
        phase_j(crate::model::ops::Phase::Backward),
        phase_j(crate::model::ops::Phase::Optimizer),
    );
    Ok(())
}

pub fn cmd_analyze(args: &mut Args) -> Result<(), String> {
    let path = args
        .take_positional()
        .ok_or("analyze: missing trace path")?;
    let in_memory = args.switch("in-memory");
    args.finish()?;
    let p = std::path::Path::new(&path);
    // Sniff the 8-byte magic: `analyze` takes chrome JSON and binary
    // stores through the same front door. A damaged store is salvaged,
    // never fatal — the status line says exactly what was lost.
    //
    // Stores default to the chunk-wise read path: the index builder is
    // fed every event while the store streams in canonical order, so by
    // the time the trace is materialized the index only needs its
    // finishing pass. `--in-memory` is the escape hatch back to
    // materialize-then-index; both paths are byte-identical
    // (tests/store.rs pins the trace, the builder docs pin the index).
    let mut builder: Option<crate::chopper::IndexBuilder> = None;
    let trace = if crate::trace::store::is_store_file(p) {
        let loaded = if in_memory {
            crate::trace::store::read_store(p)?
        } else {
            crate::trace::store::read_store_visit(p, |m, e| {
                builder
                    .get_or_insert_with(|| {
                        crate::chopper::IndexBuilder::new(m.warmup)
                    })
                    .push(e);
            })?
        };
        println!("store: {}", loaded.report.describe());
        loaded.trace
    } else {
        chrome::read_chrome_trace(p)?
    };
    println!(
        "trace: {} events, {} GPUs, workload {} ({}), source {}",
        trace.events.len(),
        trace.meta.num_gpus.max(1),
        trace.meta.workload,
        trace.meta.fsdp,
        trace.meta.source
    );
    if trace.meta.multi_node() {
        println!(
            "topology: {} nodes x {} GPUs ({})",
            trace.meta.nodes(),
            trace.meta.node_gpus(),
            if trace.meta.sharding.is_empty() {
                "FSDP"
            } else {
                trace.meta.sharding.as_str()
            }
        );
    }
    println!("span: {}", fmt::dur_ns(trace.span_ns()));
    // The shared index: finished from the chunk-fed builder when the
    // store streamed one in, built from scratch otherwise (chrome JSON,
    // --in-memory, or an event-free store).
    let idx = match builder {
        Some(b) => b.finish(&trace),
        None => crate::chopper::TraceIndex::build(&trace),
    };
    let medians = crate::chopper::aggregate::op_medians(&idx);
    let mut rows: Vec<(String, f64)> = medians
        .into_iter()
        .map(|(op, d)| (op.paper_name(), d))
        .collect();
    rows.sort_by(|a, b| b.1.total_cmp(&a.1));
    println!("\ntop operations by median duration:");
    for (name, d) in rows.iter().take(12) {
        println!("  {:>12}  {}", name, fmt::dur_ns(*d));
    }
    let samples = crate::chopper::overlap_samples(&idx, &Filter::sampled());
    if !samples.is_empty() {
        let overlapped =
            samples.iter().filter(|s| s.ratio > 0.5).count() as f64
                / samples.len() as f64;
        println!(
            "\nC3: {:.0}% of {} op instances are >50% overlapped by comm",
            overlapped * 100.0,
            samples.len()
        );
    }
    Ok(())
}

/// `fsck` — validate a binary trace store chunk by chunk and optionally
/// repair it. Clean stores exit 0; damage without `--repair` exits
/// nonzero (so CI and scripts can gate on store health); `--repair`
/// rewrites the longest checksum-valid prefix as a finalized store whose
/// footer is marked salvaged. A torn `x.ctrc.tmp` (left by a killed
/// writer) repairs to `x.ctrc`; anything else repairs in place.
pub fn cmd_fsck(args: &mut Args) -> Result<(), String> {
    let path = args
        .take_positional()
        .ok_or("fsck: missing store path (trace.ctrc or trace.ctrc.tmp)")?;
    let repair = args.switch("repair");
    args.finish()?;
    let p = std::path::Path::new(&path);
    let report = crate::trace::store::check_store(p)?;
    println!("{}: {}", p.display(), report.describe());
    if report.clean() {
        return Ok(());
    }
    if !repair {
        return Err(format!(
            "{} is damaged ({} of {} bytes valid; re-run with --repair to \
             salvage {} events into a finalized store)",
            p.display(),
            report.valid_bytes,
            report.file_bytes,
            report.events
        ));
    }
    let dst = match p.extension().and_then(|e| e.to_str()) {
        Some("tmp") => p.with_extension(""),
        _ => p.to_path_buf(),
    };
    let info = crate::trace::store::repair_store(p, &dst)?;
    println!(
        "repaired {} -> {} ({} events, {} chunk(s), {} power samples; \
         {} bytes lost)",
        p.display(),
        info.dst.display(),
        info.events,
        info.chunks,
        info.samples,
        info.lost_bytes
    );
    Ok(())
}

pub fn cmd_train(args: &mut Args) -> Result<(), String> {
    let steps = args.flag_u32("steps", 100)?;
    let lr = args.flag_f32("lr", 2.0)?;
    let seed = args.flag_u64("seed", 42)?;
    let dir: PathBuf = args.flag_or(
        "artifacts",
        crate::runtime::default_artifact_dir().to_str().unwrap_or("artifacts"),
    )
    .into();
    args.finish()?;
    let mut rt =
        crate::runtime::Runtime::open(&dir).map_err(|e| format!("{e:#}"))?;
    let mc = rt.manifest().config.clone();
    println!(
        "mini-Llama: {} layers, hidden {}, vocab {}, {} params — PJRT {}",
        mc.layers,
        mc.hidden,
        mc.vocab,
        mc.params,
        rt.platform()
    );
    let cfg = crate::train::TrainConfig {
        steps,
        lr,
        seed,
        log_every: (steps / 10).max(1),
    };
    let r = crate::train::train(&mut rt, &cfg).map_err(|e| format!("{e:#}"))?;
    for l in &r.losses {
        println!("step {:>5}  loss {:.4}  ({:.0} ms)", l.step, l.loss, l.wall_ms);
    }
    println!("throughput: {:.0} tokens/s", r.tokens_per_sec);
    Ok(())
}

pub fn cmd_config(args: &mut Args) -> Result<(), String> {
    let name = args.flag_or("model", "llama3-8b");
    args.finish()?;
    let cfg = ModelConfig::by_name(&name)
        .ok_or_else(|| format!("unknown model `{name}`"))?;
    println!("{}", report::table2(&cfg).ascii);
    println!("parameters: {}", cfg.param_count());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_cli(s: &str) -> i32 {
        crate::cli::run(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn help_and_unknown() {
        assert_eq!(run_cli("chopper help"), 0);
        assert_eq!(run_cli("chopper frobnicate"), 1);
    }

    #[test]
    fn config_prints_table2() {
        assert_eq!(run_cli("chopper config --model llama3-8b"), 0);
        assert_eq!(run_cli("chopper config --model nope"), 1);
    }

    #[test]
    fn fig10_is_static() {
        assert_eq!(run_cli("chopper figure fig10"), 0);
    }

    #[test]
    fn collect_analyze_roundtrip() {
        let dir = std::env::temp_dir().join("chopper_cli_test");
        std::fs::create_dir_all(&dir).unwrap();
        let trace = dir.join("t.json");
        let cmd = format!(
            "chopper collect --workload b1s4 --fsdp v2 --layers 2 --iters 2 --warmup 1 --out {}",
            trace.display()
        );
        assert_eq!(run_cli(&cmd), 0);
        assert!(trace.exists());
        assert_eq!(run_cli(&format!("chopper analyze {}", trace.display())), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_flag_fails() {
        assert_eq!(run_cli("chopper config --bogus 1"), 1);
    }

    #[test]
    fn stray_positional_fails() {
        assert_eq!(run_cli("chopper config extra"), 1);
    }

    #[test]
    fn campaign_runs_small_grid_and_caches() {
        let dir = std::env::temp_dir()
            .join(format!("chopper_cli_campaign_{}", std::process::id()));
        let cache = dir.join("cache");
        let cmd = format!(
            "chopper campaign --layers 2 --batch 1 --seq 4 --fsdp v1,v2 \
             --iters 2 --warmup 1 --jobs 2 --cache-dir {}",
            cache.display()
        );
        assert_eq!(run_cli(&cmd), 0);
        // Second run is served from cache; still exits cleanly.
        assert_eq!(run_cli(&cmd), 0);
        assert!(cache.exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn collect_multinode_hsdp_roundtrip() {
        let dir = std::env::temp_dir().join(format!(
            "chopper_cli_multinode_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let trace = dir.join("t2.json");
        let cmd = format!(
            "chopper collect --workload b1s4 --fsdp v2 --nodes 2 --sharding hsdp \
             --layers 2 --iters 2 --warmup 1 --out {}",
            trace.display()
        );
        assert_eq!(run_cli(&cmd), 0);
        let t = chrome::read_chrome_trace(&trace).unwrap();
        assert_eq!(t.meta.num_nodes, 2);
        assert_eq!(t.meta.num_gpus, 16);
        assert_eq!(t.meta.sharding, "HSDP");
        assert_eq!(run_cli(&format!("chopper analyze {}", trace.display())), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn campaign_accepts_topology_axes() {
        assert_eq!(
            run_cli(
                "chopper campaign --layers 1 --batch 1 --seq 4 --fsdp v1 \
                 --nodes 1,2 --sharding hsdp --iters 2 --warmup 1 --jobs 2 \
                 --no-cache"
            ),
            0
        );
        assert_eq!(
            run_cli("chopper campaign --no-cache --sharding zero3 --iters 2"),
            1
        );
        assert_eq!(
            run_cli("chopper campaign --no-cache --nodes 0 --iters 2"),
            1
        );
    }

    #[test]
    fn whatif_runs_and_rejects_bad_inputs() {
        assert_eq!(
            run_cli(
                "chopper whatif --workload b1s4 --layers 1 --iters 2 \
                 --warmup 1 --governor reactive,oracle --jobs 2"
            ),
            0
        );
        assert_eq!(run_cli("chopper whatif --governor turbo --iters 2"), 1);
        assert_eq!(
            run_cli("chopper whatif --iters 2 --cap-ratio -1 --layers 1"),
            1
        );
        assert_eq!(run_cli("chopper whatif --workload bogus --iters 2"), 1);
    }

    #[test]
    fn campaign_accepts_governor_axis() {
        assert_eq!(
            run_cli(
                "chopper campaign --layers 1 --batch 1 --seq 4 --fsdp v1 \
                 --governor reactive,oracle --iters 2 --warmup 1 --jobs 2 \
                 --no-cache"
            ),
            0
        );
        assert_eq!(
            run_cli("chopper campaign --no-cache --governor warp9 --iters 2"),
            1
        );
    }

    #[test]
    fn serve_runs_qps_sweep_and_writes_artifacts() {
        let dir = std::env::temp_dir()
            .join(format!("chopper_cli_serve_{}", std::process::id()));
        let cmd = format!(
            "chopper serve --layers 2 --qps 4,16 --requests 6 --jobs 2 \
             --seed 11 --out {}",
            dir.display()
        );
        assert_eq!(run_cli(&cmd), 0);
        for id in ["serving_latency", "serving_goodput", "serving_energy"] {
            assert!(dir.join(format!("{id}.csv")).exists(), "{id}");
        }
        let json =
            std::fs::read_to_string(dir.join("serving_summary.json")).unwrap();
        assert!(json.contains("serve-q4.000-r6"));
        assert!(json.contains("serve-q16.000-r6"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_rejects_bad_inputs() {
        assert_eq!(run_cli("chopper serve --qps 0"), 1);
        assert_eq!(run_cli("chopper serve --requests 0"), 1);
        assert_eq!(run_cli("chopper serve --kv-frac 2.0"), 1);
    }

    #[test]
    fn campaign_accepts_serving_workload() {
        assert_eq!(
            run_cli(
                "chopper campaign --layers 2 --batch 1 --seq 4 --fsdp v2 \
                 --workload serving --qps 4,16 --requests 4 --jobs 2 \
                 --no-cache"
            ),
            0
        );
        // --qps is a serving-only axis.
        assert_eq!(
            run_cli("chopper campaign --no-cache --qps 4 --iters 2"),
            1
        );
        assert_eq!(
            run_cli("chopper campaign --no-cache --workload batch --iters 2"),
            1
        );
    }

    #[test]
    fn whatif_serving_ranks_policies() {
        assert_eq!(
            run_cli(
                "chopper whatif --workload serving --layers 2 --qps 8 \
                 --requests 4 --governor reactive,oracle --jobs 2"
            ),
            0
        );
        assert_eq!(
            run_cli("chopper whatif --workload serving --qps -3"),
            1
        );
    }

    #[test]
    fn campaign_accepts_fault_axis_and_survives_panics() {
        // A `panic` fault set is isolated by the runner: exit stays 0 and
        // the healthy sibling still renders.
        assert_eq!(
            run_cli(
                "chopper campaign --layers 1 --batch 1 --seq 4 --fsdp v1 \
                 --faults none;straggler(factor=0.8);panic --iters 2 \
                 --warmup 1 --jobs 2 --no-cache"
            ),
            0
        );
        assert_eq!(
            run_cli("chopper campaign --no-cache --faults meteor --iters 2"),
            1
        );
    }

    #[test]
    fn campaign_resume_validates_flag_combinations() {
        assert_eq!(
            run_cli("chopper campaign --resume --no-cache --iters 2"),
            1
        );
        assert_eq!(run_cli("chopper campaign --resume --force --iters 2"), 1);
        let dir = std::env::temp_dir()
            .join(format!("chopper_cli_resume_{}", std::process::id()));
        let cache = dir.join("cache");
        // Warm the cache, then resume: the pre-scan finds everything.
        let base = format!(
            "chopper campaign --layers 1 --batch 1 --seq 4 --fsdp v1 \
             --iters 2 --warmup 1 --jobs 1 --cache-dir {}",
            cache.display()
        );
        assert_eq!(run_cli(&base), 0);
        assert_eq!(run_cli(&format!("{base} --resume")), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn whatif_fault_replay_runs_and_rejects_bad_combos() {
        assert_eq!(
            run_cli(
                "chopper whatif --workload b1s4 --layers 1 --iters 2 \
                 --warmup 1 --faults straggler(factor=0.8) --jobs 2"
            ),
            0
        );
        // The panic fault only means something under the campaign runner.
        assert_eq!(
            run_cli("chopper whatif --layers 1 --iters 2 --faults panic"),
            1
        );
        // Fault replay is training-only.
        assert_eq!(
            run_cli(
                "chopper whatif --workload serving --qps 8 --requests 4 \
                 --faults straggler"
            ),
            1
        );
        assert_eq!(
            run_cli("chopper whatif --layers 1 --iters 2 --faults meteor"),
            1
        );
    }

    #[test]
    fn campaign_fold_axis_runs_and_validates() {
        // Exact + folded siblings on one grid (fold 2 of 2 HSDP nodes).
        assert_eq!(
            run_cli(
                "chopper campaign --layers 1 --batch 1 --seq 4 --fsdp v1 \
                 --nodes 2 --sharding hsdp --fold 1,2 --iters 2 --warmup 1 \
                 --jobs 2 --no-cache"
            ),
            0
        );
        // Fold must divide every node count.
        assert_eq!(
            run_cli(
                "chopper campaign --no-cache --nodes 2 --sharding hsdp \
                 --fold 3 --iters 2"
            ),
            1
        );
        // Folding exploits HSDP replica symmetry; FSDP grids are exact.
        assert_eq!(
            run_cli("chopper campaign --no-cache --nodes 2 --fold 2 --iters 2"),
            1
        );
        // Serving requests are not rank-symmetric.
        assert_eq!(
            run_cli(
                "chopper campaign --no-cache --workload serving --qps 4 \
                 --requests 2 --nodes 2 --sharding hsdp --fold 2"
            ),
            1
        );
        // Fold 0 is rejected by the axis parser.
        assert_eq!(
            run_cli("chopper campaign --no-cache --fold 0 --iters 2"),
            1
        );
    }

    #[test]
    fn campaign_fold_rejects_replica_pinned_faults() {
        // A straggler pins one replica — folding would silently multiply
        // it across every folded copy, so the combination is an error
        // that names the fault.
        assert_eq!(
            run_cli(
                "chopper campaign --no-cache --nodes 2 --sharding hsdp \
                 --fold 2 --faults straggler(factor=0.8) --iters 2"
            ),
            1
        );
        assert_eq!(
            run_cli(
                "chopper campaign --no-cache --nodes 2 --sharding hsdp \
                 --fold 2 --faults dropout(at_ms=10,restart_ms=50) --iters 2"
            ),
            1
        );
        // Replica-agnostic faults (uniform stalls) compose with folding.
        assert_eq!(
            run_cli(
                "chopper campaign --layers 1 --batch 1 --seq 4 --fsdp v1 \
                 --nodes 2 --sharding hsdp --fold 2 --faults none;stalls \
                 --iters 2 --warmup 1 --jobs 2 --no-cache"
            ),
            0
        );
    }

    #[test]
    fn campaign_thermal_axis_runs_and_validates() {
        // Disabled + hot siblings on one grid; the thermal table renders.
        assert_eq!(
            run_cli(
                "chopper campaign --layers 1 --batch 1 --seq 4 --fsdp v1 \
                 --thermal none;thermal(ambient=85,tau=0.005) --iters 2 \
                 --warmup 1 --jobs 2 --no-cache"
            ),
            0
        );
        // --ambient is sugar for --thermal: one axis, not both.
        assert_eq!(
            run_cli(
                "chopper campaign --no-cache --thermal thermal --ambient 45 \
                 --iters 2"
            ),
            1
        );
        // Unknown spec kinds and malformed ambients are named errors.
        assert_eq!(
            run_cli("chopper campaign --no-cache --thermal cryo --iters 2"),
            1
        );
        assert_eq!(
            run_cli("chopper campaign --no-cache --ambient warm --iters 2"),
            1
        );
    }

    #[test]
    fn whatif_thermal_replay_runs_and_validates() {
        assert_eq!(
            run_cli(
                "chopper whatif --workload b1s4 --layers 1 --iters 2 \
                 --warmup 1 --governor reactive,thermal_aware \
                 --thermal thermal(ambient=85,tau=0.005) --jobs 2"
            ),
            0
        );
        assert_eq!(
            run_cli("chopper whatif --layers 1 --iters 2 --thermal warm"),
            1
        );
    }

    #[test]
    fn whatif_fold_replays_and_validates() {
        assert_eq!(
            run_cli(
                "chopper whatif --workload b1s4 --layers 1 --iters 2 \
                 --warmup 1 --nodes 2 --fold 2 --governor reactive,oracle \
                 --jobs 2"
            ),
            0
        );
        // Fold must divide the node count.
        assert_eq!(
            run_cli("chopper whatif --layers 1 --iters 2 --nodes 2 --fold 3"),
            1
        );
        // Fault replays measure per-replica damage: never folded.
        assert_eq!(
            run_cli(
                "chopper whatif --layers 1 --iters 2 --nodes 2 --fold 2 \
                 --faults stalls"
            ),
            1
        );
        // Serving replays don't fold either.
        assert_eq!(
            run_cli(
                "chopper whatif --workload serving --qps 8 --requests 4 \
                 --fold 2"
            ),
            1
        );
    }

    #[test]
    fn analyze_store_default_and_in_memory_paths_both_work() {
        let dir = std::env::temp_dir().join(format!(
            "chopper_cli_analyze_mem_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let store = dir.join("t.ctrc");
        let cmd = format!(
            "chopper collect --workload b1s4 --fsdp v1 --layers 2 --iters 2 \
             --warmup 1 --store --out {}",
            store.display()
        );
        assert_eq!(run_cli(&cmd), 0);
        // Default: chunk-wise streamed index. Escape hatch: --in-memory.
        assert_eq!(
            run_cli(&format!("chopper analyze {}", store.display())),
            0
        );
        assert_eq!(
            run_cli(&format!(
                "chopper analyze {} --in-memory",
                store.display()
            )),
            0
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn campaign_rejects_unknown_knob() {
        assert_eq!(
            run_cli("chopper campaign --no-cache --ablate bogus=1 --iters 2"),
            1
        );
    }

    #[test]
    fn figure_validates_id() {
        assert_eq!(run_cli("chopper figure nope --layers 1 --iters 2"), 1);
    }

    #[test]
    fn collect_store_analyze_fsck_roundtrip() {
        let dir = std::env::temp_dir()
            .join(format!("chopper_cli_store_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let store = dir.join("t.ctrc");
        let cmd = format!(
            "chopper collect --workload b1s4 --fsdp v2 --layers 2 --iters 2 \
             --warmup 1 --store --out {}",
            store.display()
        );
        assert_eq!(run_cli(&cmd), 0);
        assert!(store.exists());
        // analyze sniffs the magic and reads the binary store directly.
        assert_eq!(
            run_cli(&format!("chopper analyze {}", store.display())),
            0
        );
        // fsck: clean store exits 0.
        assert_eq!(run_cli(&format!("chopper fsck {}", store.display())), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fsck_flags_torn_store_and_repairs_it() {
        let dir = std::env::temp_dir()
            .join(format!("chopper_cli_fsck_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let store = dir.join("t.ctrc");
        let cmd = format!(
            "chopper collect --workload b1s4 --fsdp v1 --layers 2 --iters 2 \
             --warmup 1 --store --out {}",
            store.display()
        );
        assert_eq!(run_cli(&cmd), 0);
        // Tear it like a kill -9 mid-write: keep a prefix under the torn
        // `.tmp` name the writer uses.
        let bytes = std::fs::read(&store).unwrap();
        let torn = dir.join("t2.ctrc.tmp");
        std::fs::write(&torn, &bytes[..bytes.len() / 2]).unwrap();
        // Damaged without --repair: nonzero.
        assert_eq!(run_cli(&format!("chopper fsck {}", torn.display())), 1);
        // --repair strips the .tmp and finalizes the salvaged prefix.
        assert_eq!(
            run_cli(&format!("chopper fsck {} --repair", torn.display())),
            0
        );
        let fixed = dir.join("t2.ctrc");
        assert!(fixed.exists());
        assert_eq!(run_cli(&format!("chopper fsck {}", fixed.display())), 0);
        assert_eq!(
            run_cli(&format!("chopper analyze {}", fixed.display())),
            0
        );
        // Not-a-store input is a clean error, not a panic.
        let junk = dir.join("junk.ctrc");
        std::fs::write(&junk, b"not a store at all").unwrap();
        assert_eq!(run_cli(&format!("chopper fsck {}", junk.display())), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn campaign_trace_store_writes_stores_and_validates_flags() {
        // --trace-store writes into the cache, so --no-cache conflicts.
        assert_eq!(
            run_cli("chopper campaign --trace-store --no-cache --iters 2"),
            1
        );
        let dir = std::env::temp_dir().join(format!(
            "chopper_cli_tstore_{}",
            std::process::id()
        ));
        let cache = dir.join("cache");
        let base = format!(
            "chopper campaign --layers 1 --batch 1 --seq 4 --fsdp v1 \
             --iters 2 --warmup 1 --jobs 1 --trace-store --cache-dir {}",
            cache.display()
        );
        assert_eq!(run_cli(&base), 0);
        let stores: Vec<_> = std::fs::read_dir(&cache)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.path().extension().and_then(|x| x.to_str()) == Some("ctrc")
            })
            .collect();
        assert_eq!(stores.len(), 1, "one scenario, one store");
        // Resume after deleting the summary: rebuilt from the store.
        for e in std::fs::read_dir(&cache).unwrap().filter_map(|e| e.ok()) {
            if e.path().extension().and_then(|x| x.to_str()) == Some("json") {
                std::fs::remove_file(e.path()).unwrap();
            }
        }
        assert_eq!(run_cli(&format!("{base} --resume")), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
