//! Command-line interface: `chopper <subcommand>`.
//!
//! Subcommands
//!   sweep     — profile the paper's b×s × {v1,v2} sweep, write every figure
//!   campaign  — expand a scenario grid, run it in parallel with caching,
//!               and print cross-scenario comparison tables
//!   serve     — run the continuous-batching serving workload over an
//!               offered-load sweep and write the serving figures
//!   whatif    — replay one workload (training or serving) under several
//!               power-management policies and print the ranked advisor
//!               report
//!   figure    — regenerate one table/figure (fig4…fig15, table2)
//!   collect   — profile one workload, write a chrome trace (+ telemetry)
//!               or, with --store, a crash-safe binary trace store
//!   analyze   — aggregate statistics from a trace file (chrome JSON or
//!               binary .ctrc store)
//!   fsck      — validate / repair a binary trace store (checksummed
//!               chunks, truncation salvage)
//!   train     — train the executable mini-Llama end to end via PJRT
//!   config    — print the model configuration (Table II)
//!
//! A tiny in-repo arg parser (clap is unavailable offline; DESIGN.md
//! substitution table).

pub mod args;
pub mod commands;

pub use args::Args;

/// Entry point used by `main.rs`. Returns the process exit code.
pub fn run(argv: Vec<String>) -> i32 {
    let mut args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprintln!("{}", commands::USAGE);
            return 2;
        }
    };
    let cmd = args.subcommand.clone();
    let result = match cmd.as_str() {
        "sweep" => commands::cmd_sweep(&mut args),
        "campaign" => commands::cmd_campaign(&mut args),
        "serve" => commands::cmd_serve(&mut args),
        "whatif" => commands::cmd_whatif(&mut args),
        "figure" => commands::cmd_figure(&mut args),
        "collect" => commands::cmd_collect(&mut args),
        "analyze" => commands::cmd_analyze(&mut args),
        "fsck" => commands::cmd_fsck(&mut args),
        "train" => commands::cmd_train(&mut args),
        "config" => commands::cmd_config(&mut args),
        "help" | "" => {
            println!("{}", commands::USAGE);
            Ok(())
        }
        other => Err(format!("unknown subcommand `{other}`")),
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}
