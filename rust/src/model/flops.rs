//! Analytical FLOP/byte model per operation (the F_gemm of Eq. 6 and the
//! memory-side inputs to the roofline duration model).
//!
//! Conventions:
//!  * GEMM flops = 2·m·n·k (theoretical, un-padded — padding is applied by
//!    the simulator's kernel-selection model and surfaces as the paper's
//!    *instruction overhead*, Eq. 7).
//!  * Backward GEMMs cost 2× forward (dgrad + wgrad).
//!  * FlashAttention forward = 4·b·hq·s²·hd (QKᵀ and PV), halved when
//!    causal; backward = 2.5× forward (FA2 recomputation).
//!  * Vector/copy ops are byte-dominated; flops ≈ a few per element.

use super::ops::{OpType, Phase};
use crate::config::ModelConfig;

/// Cost of one operation instance on one GPU.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpCost {
    /// Theoretical useful flops (F_gemm in Eq. 6). 0 for pure-copy ops.
    pub flops: f64,
    /// Bytes moved to/from HBM (reads + writes).
    pub bytes: f64,
    /// GEMM logical dims when the op is a single logical GEMM family.
    pub gemm_mnk: Option<(u64, u64, u64)>,
}

impl OpCost {
    fn gemm(m: u64, n: u64, k: u64, dtype: u64) -> Self {
        OpCost {
            flops: 2.0 * m as f64 * n as f64 * k as f64,
            bytes: ((m * k + k * n + m * n) * dtype) as f64,
            gemm_mnk: Some((m, n, k)),
        }
    }

    fn vector(flops_per_elem: f64, elems: f64, bytes: f64) -> Self {
        OpCost {
            flops: flops_per_elem * elems,
            bytes,
            gemm_mnk: None,
        }
    }

    fn scaled(self, f: f64) -> Self {
        OpCost {
            flops: self.flops * f,
            bytes: self.bytes * f,
            gemm_mnk: self.gemm_mnk,
        }
    }
}

/// Compute the analytical cost of `op` in `phase` for micro-batch `b` and
/// sequence `s` on a model sharded over `ranks` GPUs (relevant only to the
/// optimizer-phase ops, which operate on the local shard).
pub fn op_cost(
    cfg: &ModelConfig,
    op: OpType,
    phase: Phase,
    b: u64,
    s: u64,
    ranks: u64,
) -> OpCost {
    let h = cfg.hidden;
    let f = cfg.ffn;
    let v = cfg.vocab;
    let hd = cfg.head_dim();
    let hq = cfg.q_heads;
    let kvw = cfg.kv_heads * hd;
    let dt = cfg.dtype_bytes;
    let bs = b * s;
    let bwd_gemm = 2.0; // dgrad + wgrad

    let fwd = match op {
        OpType::IE => OpCost::vector(0.0, 0.0, (bs * h * dt + bs * 4) as f64),
        OpType::AttnN | OpType::MlpN | OpType::Ln => OpCost::vector(
            4.0,
            (bs * h) as f64,
            (2 * bs * h * dt + h * dt) as f64,
        ),
        OpType::QkvIp => {
            // Three GEMMs: q [bs,h]x[h,h], k/v [bs,h]x[h,kvw].
            let q = OpCost::gemm(bs, h, h, dt);
            let k = OpCost::gemm(bs, kvw, h, dt);
            OpCost {
                flops: q.flops + 2.0 * k.flops,
                bytes: q.bytes + 2.0 * k.bytes,
                gemm_mnk: Some((bs, h + 2 * kvw, h)),
            }
        }
        OpType::QkvS | OpType::QkvT | OpType::QkvC => {
            let elems = (bs * (hq * hd + 2 * kvw)) as f64;
            OpCost::vector(0.0, 0.0, 2.0 * elems * dt as f64)
        }
        OpType::QkvRe => {
            let elems = (bs * (hq * hd + kvw)) as f64;
            OpCost::vector(6.0, elems, 2.0 * elems * dt as f64)
        }
        OpType::AttnFa => {
            // Causal FA: 2 GEMMs over the lower triangle.
            let full = 4.0 * (b * hq) as f64 * (s as f64) * (s as f64) * hd as f64;
            OpCost {
                flops: 0.5 * full,
                bytes: (3.0 * (bs * hq * hd) as f64 + (bs * hq * hd) as f64)
                    * dt as f64,
                gemm_mnk: None,
            }
        }
        OpType::AttnOr => OpCost::vector(0.0, 0.0, (2 * bs * hq * hd * dt) as f64),
        OpType::AttnOp => OpCost::gemm(bs, h, hq * hd, dt),
        OpType::AttnRa | OpType::MlpRa => {
            OpCost::vector(1.0, (bs * h) as f64, (3 * bs * h * dt) as f64)
        }
        OpType::MlpGp | OpType::MlpUp => OpCost::gemm(bs, f, h, dt),
        OpType::MlpGs => OpCost::vector(4.0, (bs * f) as f64, (2 * bs * f * dt) as f64),
        OpType::MlpGu => OpCost::vector(1.0, (bs * f) as f64, (3 * bs * f * dt) as f64),
        OpType::MlpDp => OpCost::gemm(bs, h, f, dt),
        OpType::Lp => OpCost::gemm(bs, v, h, dt),
        OpType::GradAccum => {
            // Accumulate the full local gradient shard once per iteration.
            let shard = cfg.param_count() as f64 / ranks as f64;
            OpCost::vector(1.0, shard, 3.0 * shard * dt as f64)
        }
        OpType::OptStep => {
            // AdamW-style update on the local shard with fp32 master
            // weights + two moments: r/w weights, grads, m, v.
            let shard = cfg.param_count() as f64 / ranks as f64;
            OpCost::vector(10.0, shard, shard * (4.0 * 4.0 + 3.0 * 4.0))
        }
        OpType::AllGather => OpCost {
            flops: 0.0,
            bytes: cfg.layer_weight_bytes() as f64,
            gemm_mnk: None,
        },
        OpType::ReduceScatter => OpCost {
            flops: cfg.params_per_layer() as f64, // the reduction adds
            bytes: cfg.layer_weight_bytes() as f64,
            gemm_mnk: None,
        },
        // HSDP cross-node all-reduce of one rank's gradient shard.
        OpType::AllReduce => OpCost {
            flops: cfg.params_per_layer() as f64 / ranks as f64,
            bytes: cfg.layer_weight_bytes() as f64 / ranks as f64,
            gemm_mnk: None,
        },
        OpType::ParamCopy => OpCost::vector(
            0.0,
            0.0,
            2.0 * cfg.layer_weight_bytes() as f64 / ranks as f64,
        ),
    };

    match (phase, op) {
        // Optimizer-phase ops are already per-iteration totals.
        (_, OpType::GradAccum) | (_, OpType::OptStep) => fwd,
        (Phase::Forward, _) | (Phase::Optimizer, _) => fwd,
        (Phase::Backward, OpType::AttnFa) => fwd.scaled(2.5),
        (Phase::Backward, o) if o.kind() == super::ops::OpKind::Gemm => {
            fwd.scaled(bwd_gemm)
        }
        // Backward vector/copy ops move roughly 2x the data (grads in+out).
        (Phase::Backward, _) => fwd.scaled(2.0),
    }
}

/// Total theoretical GEMM+FA flops of one full iteration on one GPU —
/// used for the setup-validation FLOPS numbers (Section IV-E).
pub fn iteration_flops(cfg: &ModelConfig, b: u64, s: u64, ranks: u64) -> f64 {
    use OpType::*;
    let mut total = 0.0;
    for layer_op in [QkvIp, AttnFa, AttnOp, MlpGp, MlpUp, MlpDp] {
        total += op_cost(cfg, layer_op, Phase::Forward, b, s, ranks).flops
            * cfg.layers as f64;
        total += op_cost(cfg, layer_op, Phase::Backward, b, s, ranks).flops
            * cfg.layers as f64;
    }
    total += op_cost(cfg, Lp, Phase::Forward, b, s, ranks).flops;
    total += op_cost(cfg, Lp, Phase::Backward, b, s, ranks).flops;
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ops::OpKind;

    fn cfg() -> ModelConfig {
        ModelConfig::llama3_8b()
    }

    #[test]
    fn gemm_flops_scale_with_batch_times_seq() {
        // Section V-B1: "All GEMMs scale with b*s".
        for op in [OpType::QkvIp, OpType::AttnOp, OpType::MlpGp, OpType::MlpDp] {
            let c1 = op_cost(&cfg(), op, Phase::Forward, 1, 4096, 8);
            let c2 = op_cost(&cfg(), op, Phase::Forward, 2, 4096, 8);
            let c3 = op_cost(&cfg(), op, Phase::Forward, 1, 8192, 8);
            assert!((c2.flops / c1.flops - 2.0).abs() < 1e-9, "{op}");
            assert!((c3.flops / c1.flops - 2.0).abs() < 1e-9, "{op}");
        }
    }

    #[test]
    fn fa_flops_scale_with_b_s_squared() {
        // Section V-B2: FlashAttention scales with b*s^2.
        let c1 = op_cost(&cfg(), OpType::AttnFa, Phase::Forward, 1, 4096, 8);
        let c2 = op_cost(&cfg(), OpType::AttnFa, Phase::Forward, 1, 8192, 8);
        assert!((c2.flops / c1.flops - 4.0).abs() < 1e-9);
        let c3 = op_cost(&cfg(), OpType::AttnFa, Phase::Forward, 2, 4096, 8);
        assert!((c3.flops / c1.flops - 2.0).abs() < 1e-9);
    }

    #[test]
    fn backward_fa_does_more_flops_than_forward() {
        let f = op_cost(&cfg(), OpType::AttnFa, Phase::Forward, 2, 4096, 8);
        let b = op_cost(&cfg(), OpType::AttnFa, Phase::Backward, 2, 4096, 8);
        assert!(b.flops > f.flops * 2.0);
    }

    #[test]
    fn optimizer_ops_invariant_to_batch_and_seq() {
        // Section V-B3: b_ga and opt_step constant across b and s.
        for op in [OpType::GradAccum, OpType::OptStep] {
            let a = op_cost(&cfg(), op, Phase::Optimizer, 1, 4096, 8);
            let b = op_cost(&cfg(), op, Phase::Optimizer, 4, 8192, 8);
            assert_eq!(a.flops, b.flops, "{op}");
            assert_eq!(a.bytes, b.bytes, "{op}");
        }
    }

    #[test]
    fn comm_bytes_invariant_to_batch_and_seq() {
        // Insight 2's premise: only weights/grads are communicated.
        let a = op_cost(&cfg(), OpType::AllGather, Phase::Forward, 1, 4096, 8);
        let b = op_cost(&cfg(), OpType::AllGather, Phase::Forward, 4, 8192, 8);
        assert_eq!(a.bytes, b.bytes);
    }

    #[test]
    fn gemm_dims_recorded() {
        let c = op_cost(&cfg(), OpType::MlpDp, Phase::Forward, 2, 4096, 8);
        assert_eq!(c.gemm_mnk, Some((8192, 4096, 14336)));
    }

    #[test]
    fn iteration_flops_match_6nd_rule() {
        // Dense-transformer rule of thumb: ~6 * params * tokens per
        // fwd+bwd (2N fwd + 4N bwd), GEMM-dominated. Allow generous slack
        // since embeddings don't do GEMM flops and FA adds extra.
        let c = cfg();
        let (b, s) = (2u64, 4096u64);
        let flops = iteration_flops(&c, b, s, 8);
        let approx = 6.0 * c.param_count() as f64 * (b * s) as f64;
        let ratio = flops / approx;
        assert!(ratio > 0.7 && ratio < 1.4, "ratio {ratio}");
    }

    #[test]
    fn vector_ops_have_positive_bytes() {
        for op in [
            OpType::AttnN,
            OpType::MlpGs,
            OpType::MlpGu,
            OpType::AttnRa,
            OpType::QkvRe,
        ] {
            let c = op_cost(&cfg(), op, Phase::Forward, 1, 4096, 8);
            assert!(c.bytes > 0.0, "{op}");
            assert_eq!(c.gemm_mnk, None, "{op}");
            assert_eq!(c.kind_is_gemm(), false, "{op}");
        }
    }

    impl OpCost {
        fn kind_is_gemm(&self) -> bool {
            self.gemm_mnk.is_some()
        }
    }
}
