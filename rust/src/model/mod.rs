//! Application model: the paper's Fig. 1 operation taxonomy, the analytical
//! FLOP/byte cost model, and the per-iteration program builder.

pub mod flops;
pub mod graph;
pub mod ops;

pub use flops::{iteration_flops, op_cost, OpCost};
pub use graph::{build_iteration, param_tensor_count, IterationProgram, KernelDesc, OpInstance};
pub use ops::{OpKind, OpRef, OpType, Phase};
