//! The paper's operation taxonomy (Fig. 1) and phase vocabulary.
//!
//! Every kernel in a trace is annotated with (OpType, Phase, layer,
//! iteration, gpu) — this is what lets Chopper aggregate from kernels up
//! through operations, layers, phases, iterations, GPUs, and the workload.

use std::fmt;

/// Training phase (Section II-B / Fig. 4 notation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Phase {
    Forward,
    Backward,
    Optimizer,
}

impl Phase {
    pub fn prefix(&self) -> &'static str {
        match self {
            Phase::Forward => "f",
            Phase::Backward => "b",
            Phase::Optimizer => "opt",
        }
    }

    pub const ALL: [Phase; 3] = [Phase::Forward, Phase::Backward, Phase::Optimizer];
}

impl fmt::Display for Phase {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Phase::Forward => write!(f, "fwd"),
            Phase::Backward => write!(f, "bwd"),
            Phase::Optimizer => write!(f, "opt"),
        }
    }
}

/// Coarse kernel/operation class used in the Fig. 4 breakdown.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpKind {
    /// Matrix-multiply (MFMA/rocBLAS) kernels.
    Gemm,
    /// FlashAttention fused kernels.
    FlashAttn,
    /// Element-wise / reduction vector kernels.
    Vector,
    /// Memory copies (FSDPv2 per-parameter copies, contiguous() etc.).
    Copy,
    /// Collective communication kernels (all gather / reduce scatter).
    Comm,
}

impl fmt::Display for OpKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OpKind::Gemm => write!(f, "gemm"),
            OpKind::FlashAttn => write!(f, "fa"),
            OpKind::Vector => write!(f, "vec"),
            OpKind::Copy => write!(f, "copy"),
            OpKind::Comm => write!(f, "comm"),
        }
    }
}

/// Operation types, straight from the paper's Fig. 1 (plus the optimizer
/// ops b_ga / opt_step from Section V-B, the collectives, and the FSDPv2
/// parameter-copy op from Section V-D3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[allow(clippy::upper_case_acronyms)]
pub enum OpType {
    /// i_e: input embedding.
    IE,
    /// attn_n: attention RMSNorm.
    AttnN,
    /// qkv_ip: QKV input projections.
    QkvIp,
    /// qkv_s: head split.
    QkvS,
    /// qkv_t: transpose to attention layout.
    QkvT,
    /// qkv_re: rotary embedding.
    QkvRe,
    /// qkv_c: contiguous memory copy.
    QkvC,
    /// attn_fa: FlashAttention.
    AttnFa,
    /// attn_or: output reshape.
    AttnOr,
    /// attn_op: output projection.
    AttnOp,
    /// attn_ra: attention residual add.
    AttnRa,
    /// mlp_n: MLP RMSNorm.
    MlpN,
    /// mlp_gp: gate projection.
    MlpGp,
    /// mlp_gs: SiLU.
    MlpGs,
    /// mlp_up: up projection.
    MlpUp,
    /// mlp_gu: gate-up elementwise multiply.
    MlpGu,
    /// mlp_dp: down projection.
    MlpDp,
    /// mlp_ra: MLP residual add.
    MlpRa,
    /// ln: final RMSNorm.
    Ln,
    /// lp: logits projection.
    Lp,
    /// b_ga: gradient accumulate feeding the optimizer phase.
    GradAccum,
    /// opt_step: optimizer step.
    OptStep,
    /// ag: FSDP all gather.
    AllGather,
    /// rs: FSDP reduce scatter.
    ReduceScatter,
    /// ar: HSDP cross-node all-reduce of gradient shards.
    AllReduce,
    /// FSDPv2 per-parameter copy around collectives.
    ParamCopy,
    /// prefill: serving prompt ingestion (step-fused, compute-bound).
    Prefill,
    /// decode: serving token generation (step-fused, memory-bound).
    Decode,
}

impl OpType {
    pub fn short(&self) -> &'static str {
        use OpType::*;
        match self {
            IE => "i_e",
            AttnN => "attn_n",
            QkvIp => "qkv_ip",
            QkvS => "qkv_s",
            QkvT => "qkv_t",
            QkvRe => "qkv_re",
            QkvC => "qkv_c",
            AttnFa => "attn_fa",
            AttnOr => "attn_or",
            AttnOp => "attn_op",
            AttnRa => "attn_ra",
            MlpN => "mlp_n",
            MlpGp => "mlp_gp",
            MlpGs => "mlp_gs",
            MlpUp => "mlp_up",
            MlpGu => "mlp_gu",
            MlpDp => "mlp_dp",
            MlpRa => "mlp_ra",
            Ln => "ln",
            Lp => "lp",
            GradAccum => "ga",
            OptStep => "opt_step",
            AllGather => "ag",
            ReduceScatter => "rs",
            AllReduce => "ar",
            ParamCopy => "param_copy",
            Prefill => "prefill",
            Decode => "decode",
        }
    }

    pub fn kind(&self) -> OpKind {
        use OpType::*;
        match self {
            QkvIp | AttnOp | MlpGp | MlpUp | MlpDp | Lp | Prefill => OpKind::Gemm,
            AttnFa => OpKind::FlashAttn,
            IE | AttnN | QkvRe | AttnRa | MlpN | MlpGs | MlpGu | MlpRa | Ln
            | GradAccum | OptStep | Decode => OpKind::Vector,
            QkvS | QkvT | QkvC | AttnOr | ParamCopy => OpKind::Copy,
            AllGather | ReduceScatter | AllReduce => OpKind::Comm,
        }
    }

    pub fn is_comm(&self) -> bool {
        self.kind() == OpKind::Comm
    }

    /// All per-layer decoder operations in forward execution order (Fig. 1).
    pub const LAYER_FWD_ORDER: [OpType; 17] = [
        OpType::AttnN,
        OpType::QkvIp,
        OpType::QkvS,
        OpType::QkvT,
        OpType::QkvRe,
        OpType::QkvC,
        OpType::AttnFa,
        OpType::AttnOr,
        OpType::AttnOp,
        OpType::AttnRa,
        OpType::MlpN,
        OpType::MlpGp,
        OpType::MlpGs,
        OpType::MlpUp,
        OpType::MlpGu,
        OpType::MlpDp,
        OpType::MlpRa,
    ];

    pub fn parse(s: &str) -> Option<OpType> {
        use OpType::*;
        Some(match s {
            "i_e" => IE,
            "attn_n" => AttnN,
            "qkv_ip" => QkvIp,
            "qkv_s" => QkvS,
            "qkv_t" => QkvT,
            "qkv_re" => QkvRe,
            "qkv_c" => QkvC,
            "attn_fa" => AttnFa,
            "attn_or" => AttnOr,
            "attn_op" => AttnOp,
            "attn_ra" => AttnRa,
            "mlp_n" => MlpN,
            "mlp_gp" => MlpGp,
            "mlp_gs" => MlpGs,
            "mlp_up" => MlpUp,
            "mlp_gu" => MlpGu,
            "mlp_dp" => MlpDp,
            "mlp_ra" => MlpRa,
            "ln" => Ln,
            "lp" => Lp,
            "ga" => GradAccum,
            "opt_step" => OptStep,
            "ag" => AllGather,
            "rs" => ReduceScatter,
            "ar" => AllReduce,
            "param_copy" => ParamCopy,
            "prefill" => Prefill,
            "decode" => Decode,
            _ => return None,
        })
    }
}

impl fmt::Display for OpType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.short())
    }
}

/// A fully-qualified operation reference: op type + phase (the paper's
/// f_/b_ prefixes) — e.g. `f_attn_fa`, `b_mlp_up`, `b_ga`, `opt_step`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpRef {
    pub op: OpType,
    pub phase: Phase,
}

impl OpRef {
    pub fn new(op: OpType, phase: Phase) -> Self {
        Self { op, phase }
    }

    pub fn fwd(op: OpType) -> Self {
        Self::new(op, Phase::Forward)
    }

    pub fn bwd(op: OpType) -> Self {
        Self::new(op, Phase::Backward)
    }

    /// Paper naming: f_attn_fa, b_mlp_up, b_ga, opt_step. Communication
    /// ops and optimizer ops are not phase-prefixed in the paper's plots.
    pub fn paper_name(&self) -> String {
        match (self.op, self.phase) {
            (OpType::OptStep, _) => "opt_step".into(),
            (OpType::GradAccum, _) => "b_ga".into(),
            (OpType::AllGather, _)
            | (OpType::ReduceScatter, _)
            | (OpType::AllReduce, _)
            // Serving phases are not the paper's f_/b_ vocabulary: the
            // step-fused kernels keep their bare names in every rollup.
            | (OpType::Prefill, _)
            | (OpType::Decode, _) => self.op.short().into(),
            (op, Phase::Forward) => format!("f_{}", op.short()),
            (op, Phase::Backward) => format!("b_{}", op.short()),
            (op, Phase::Optimizer) => format!("opt_{}", op.short()),
        }
    }

    pub fn parse(s: &str) -> Option<OpRef> {
        if s == "opt_step" {
            return Some(OpRef::new(OpType::OptStep, Phase::Optimizer));
        }
        if s == "b_ga" {
            return Some(OpRef::new(OpType::GradAccum, Phase::Optimizer));
        }
        if let Some(op) = OpType::parse(s) {
            // bare comm names
            return Some(OpRef::new(op, Phase::Forward));
        }
        if let Some(rest) = s.strip_prefix("f_") {
            return OpType::parse(rest).map(OpRef::fwd);
        }
        if let Some(rest) = s.strip_prefix("b_") {
            return OpType::parse(rest).map(OpRef::bwd);
        }
        None
    }
}

impl fmt::Display for OpRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.paper_name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_names_roundtrip() {
        use OpType::*;
        for op in [
            IE, AttnN, QkvIp, QkvS, QkvT, QkvRe, QkvC, AttnFa, AttnOr, AttnOp,
            AttnRa, MlpN, MlpGp, MlpGs, MlpUp, MlpGu, MlpDp, MlpRa, Ln, Lp,
            GradAccum, OptStep, AllGather, ReduceScatter, AllReduce, ParamCopy,
            Prefill, Decode,
        ] {
            assert_eq!(OpType::parse(op.short()), Some(op), "{op}");
        }
    }

    #[test]
    fn paper_names_match_figures() {
        assert_eq!(OpRef::fwd(OpType::AttnFa).paper_name(), "f_attn_fa");
        assert_eq!(OpRef::bwd(OpType::MlpUp).paper_name(), "b_mlp_up");
        assert_eq!(
            OpRef::new(OpType::GradAccum, Phase::Optimizer).paper_name(),
            "b_ga"
        );
        assert_eq!(
            OpRef::new(OpType::OptStep, Phase::Optimizer).paper_name(),
            "opt_step"
        );
        assert_eq!(OpRef::fwd(OpType::AllGather).paper_name(), "ag");
    }

    #[test]
    fn opref_parse_roundtrip() {
        for name in [
            "f_attn_fa", "b_mlp_up", "b_ga", "opt_step", "ag", "rs", "ar",
            "prefill", "decode",
        ] {
            let r = OpRef::parse(name).unwrap();
            assert_eq!(r.paper_name(), name);
        }
        assert!(OpRef::parse("nonsense").is_none());
    }

    #[test]
    fn kinds_match_paper_categories() {
        assert_eq!(OpType::MlpUp.kind(), OpKind::Gemm);
        assert_eq!(OpType::AttnFa.kind(), OpKind::FlashAttn);
        assert_eq!(OpType::AttnN.kind(), OpKind::Vector);
        assert_eq!(OpType::QkvC.kind(), OpKind::Copy);
        assert!(OpType::AllGather.is_comm());
        // Serving: prefill is compute-shaped, decode is bandwidth-shaped.
        assert_eq!(OpType::Prefill.kind(), OpKind::Gemm);
        assert_eq!(OpType::Decode.kind(), OpKind::Vector);
        assert!(!OpType::Prefill.is_comm());
    }

    #[test]
    fn layer_order_is_fig1() {
        assert_eq!(OpType::LAYER_FWD_ORDER.len(), 17);
        assert_eq!(OpType::LAYER_FWD_ORDER[0], OpType::AttnN);
        assert_eq!(OpType::LAYER_FWD_ORDER[6], OpType::AttnFa);
        assert_eq!(OpType::LAYER_FWD_ORDER[16], OpType::MlpRa);
    }
}
