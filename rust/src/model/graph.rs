//! Iteration program builder: expands the model configuration into the
//! ordered list of operations (and their constituent kernels) that one
//! training iteration executes on one GPU — forward, backward, optimizer.
//!
//! This is the application-side half of the trace schema: the simulator
//! executes these kernels, and the trace collectors annotate every kernel
//! event with the (op, layer, phase) it came from, exactly like the paper's
//! runtime profiling records "annotations for kernels, operations, layers,
//! and iterations" (Section III-B1).

use super::flops::{op_cost, OpCost};
use super::ops::{OpKind, OpRef, OpType, Phase};
use crate::config::ModelConfig;
use crate::util::intern::{intern, Sym};

/// Static description of one kernel inside an operation.
#[derive(Debug, Clone)]
pub struct KernelDesc {
    /// Kernel symbol name (rocBLAS/CK-style, for trace realism). Interned
    /// once at program-build time; the engine copies the 4-byte handle
    /// into every trace event instead of cloning a `String`.
    pub name: Sym,
    pub op: OpRef,
    /// Decoder layer index; None for embedding/head/optimizer ops.
    pub layer: Option<u32>,
    pub kind: OpKind,
    /// Theoretical useful flops for this kernel.
    pub flops: f64,
    /// HBM bytes moved.
    pub bytes: f64,
    /// GEMM dims if this kernel is a GEMM.
    pub gemm_mnk: Option<(u64, u64, u64)>,
}

/// One operation instance (one or more kernels, Section III: "operation
/// (which consists of one or more kernels)").
#[derive(Debug, Clone)]
pub struct OpInstance {
    pub op: OpRef,
    pub layer: Option<u32>,
    pub kernels: Vec<KernelDesc>,
}

impl OpInstance {
    pub fn flops(&self) -> f64 {
        self.kernels.iter().map(|k| k.flops).sum()
    }

    pub fn bytes(&self) -> f64 {
        self.kernels.iter().map(|k| k.bytes).sum()
    }
}

/// The ordered op list of one iteration (compute only; FSDP comm is woven
/// in by `fsdp::schedule`).
#[derive(Debug, Clone)]
pub struct IterationProgram {
    pub fwd: Vec<OpInstance>,
    pub bwd: Vec<OpInstance>,
    pub opt: Vec<OpInstance>,
}

impl IterationProgram {
    pub fn all_ops(&self) -> impl Iterator<Item = &OpInstance> {
        self.fwd.iter().chain(self.bwd.iter()).chain(self.opt.iter())
    }

    pub fn kernel_count(&self) -> usize {
        self.all_ops().map(|o| o.kernels.len()).sum()
    }
}

/// How many parameter tensors the optimizer touches (per-layer tensors +
/// embed + final norm + head) — drives the many-small-kernels structure of
/// opt_step (Section V-D3).
pub fn param_tensor_count(cfg: &ModelConfig) -> u64 {
    cfg.layers * 9 + 3
}

fn gemm_kernel_name(m: u64, n: u64, k: u64, phase: Phase) -> Sym {
    // rocBLAS-flavored naming so traces look like the real thing. Names
    // depend only on (dims, phase), so interning collapses the per-layer /
    // per-iteration repetition to a handful of table entries.
    let suffix = match phase {
        Phase::Forward => "NN",
        Phase::Backward => "NT",
        Phase::Optimizer => "NN",
    };
    intern(&format!("Cijk_Alik_Bljk_BF16_MT128x128x32_{suffix}_m{m}n{n}k{k}"))
}

fn expand_kernels(
    cfg: &ModelConfig,
    op: OpType,
    phase: Phase,
    layer: Option<u32>,
    cost: OpCost,
) -> Vec<KernelDesc> {
    let opref = OpRef::new(op, phase);
    let kind = op.kind();
    let mk = |name: Sym, flops: f64, bytes: f64, mnk: Option<(u64, u64, u64)>| {
        KernelDesc {
            name,
            op: opref,
            layer,
            kind,
            flops,
            bytes,
            gemm_mnk: mnk,
        }
    };

    match (op, phase) {
        // QKV projection: three GEMM kernels (q, k, v).
        (OpType::QkvIp, ph) => {
            let hd = cfg.head_dim();
            let kvw = cfg.kv_heads * hd;
            let (m, _, kk) = cost.gemm_mnk.expect("qkv_ip is a gemm");
            let mult = if ph == Phase::Backward { 2.0 } else { 1.0 };
            let per = |n: u64| {
                (
                    2.0 * m as f64 * n as f64 * kk as f64 * mult,
                    ((m * kk + kk * n + m * n) * cfg.dtype_bytes) as f64 * mult,
                )
            };
            let (fq, bq) = per(cfg.hidden);
            let (fk, bk) = per(kvw);
            vec![
                mk(gemm_kernel_name(m, cfg.hidden, kk, ph), fq, bq,
                   Some((m, cfg.hidden, kk))),
                mk(gemm_kernel_name(m, kvw, kk, ph), fk, bk, Some((m, kvw, kk))),
                mk(gemm_kernel_name(m, kvw, kk, ph), fk, bk, Some((m, kvw, kk))),
            ]
        }
        // Other GEMMs: forward = 1 kernel; backward = dgrad + wgrad kernels.
        (_, Phase::Forward) if kind == OpKind::Gemm => {
            let (m, n, k) = cost.gemm_mnk.expect("gemm has dims");
            vec![mk(gemm_kernel_name(m, n, k, phase), cost.flops, cost.bytes,
                    Some((m, n, k)))]
        }
        (_, Phase::Backward) if kind == OpKind::Gemm => {
            let (m, n, k) = cost.gemm_mnk.expect("gemm has dims");
            // dgrad: [m,n] x [n,k]^T -> [m,k]; wgrad: [m,k]^T x [m,n] -> [k,n]
            vec![
                mk(gemm_kernel_name(m, k, n, phase), cost.flops / 2.0,
                   cost.bytes / 2.0, Some((m, k, n))),
                mk(gemm_kernel_name(k, n, m, phase), cost.flops / 2.0,
                   cost.bytes / 2.0, Some((k, n, m))),
            ]
        }
        // FlashAttention: fused kernel forward; FA2 backward is the
        // delta / dKdV / dQ triple (mirrors our Pallas implementation).
        (OpType::AttnFa, Phase::Forward) => {
            vec![mk(
                intern(&format!("fmha_fwd_d{}_bf16_causal", cfg.head_dim())),
                cost.flops,
                cost.bytes,
                None,
            )]
        }
        (OpType::AttnFa, Phase::Backward) => {
            let d = cfg.head_dim();
            vec![
                mk(intern(&format!("fmha_bwd_delta_d{d}_bf16")), cost.flops * 0.02,
                   cost.bytes * 0.2, None),
                mk(intern(&format!("fmha_bwd_dkdv_d{d}_bf16_causal")), cost.flops * 0.56,
                   cost.bytes * 0.4, None),
                mk(intern(&format!("fmha_bwd_dq_d{d}_bf16_causal")), cost.flops * 0.42,
                   cost.bytes * 0.4, None),
            ]
        }
        // RMSNorm: 1 fused kernel forward, dx + dw kernels backward.
        (OpType::AttnN | OpType::MlpN | OpType::Ln, Phase::Forward) => {
            vec![mk("rmsnorm_fwd_kernel".into(), cost.flops, cost.bytes, None)]
        }
        (OpType::AttnN | OpType::MlpN | OpType::Ln, Phase::Backward) => {
            vec![
                mk("rmsnorm_bwd_dx_kernel".into(), cost.flops * 0.7,
                   cost.bytes * 0.7, None),
                mk("rmsnorm_bwd_dw_kernel".into(), cost.flops * 0.3,
                   cost.bytes * 0.3, None),
            ]
        }
        // Optimizer-phase ops: chunked foreach kernels — many small
        // launches, the structural cause of opt_step's launch overhead.
        (OpType::GradAccum, _) => {
            let n = param_tensor_count(cfg).div_ceil(8).max(1);
            (0..n)
                .map(|i| {
                    mk(
                        intern(&format!("multi_tensor_accum_chunk{i}")),
                        cost.flops / n as f64,
                        cost.bytes / n as f64,
                        None,
                    )
                })
                .collect()
        }
        (OpType::OptStep, _) => {
            // foreach AdamW: ~2 kernels per bucket of tensors.
            let buckets = param_tensor_count(cfg).div_ceil(4).max(1);
            (0..buckets * 2)
                .map(|i| {
                    mk(
                        intern(&format!("multi_tensor_adamw_chunk{i}")),
                        cost.flops / (buckets * 2) as f64,
                        cost.bytes / (buckets * 2) as f64,
                        None,
                    )
                })
                .collect()
        }
        // Everything else: one kernel.
        (o, _) => {
            let name = match kind {
                OpKind::Copy => intern("copy_kernel"),
                OpKind::Vector => intern(&format!("elementwise_{}", o.short())),
                _ => intern(o.short()),
            };
            vec![mk(name, cost.flops, cost.bytes, cost.gemm_mnk)]
        }
    }
}

fn op_instance(
    cfg: &ModelConfig,
    op: OpType,
    phase: Phase,
    layer: Option<u32>,
    b: u64,
    s: u64,
    ranks: u64,
) -> OpInstance {
    let cost = op_cost(cfg, op, phase, b, s, ranks);
    OpInstance {
        op: OpRef::new(op, phase),
        layer,
        kernels: expand_kernels(cfg, op, phase, layer, cost),
    }
}

/// Build the compute-op program of one iteration.
pub fn build_iteration(
    cfg: &ModelConfig,
    b: u64,
    s: u64,
    ranks: u64,
    optimizer: bool,
) -> IterationProgram {
    let mut fwd = Vec::new();
    fwd.push(op_instance(cfg, OpType::IE, Phase::Forward, None, b, s, ranks));
    for layer in 0..cfg.layers as u32 {
        for &op in OpType::LAYER_FWD_ORDER.iter() {
            fwd.push(op_instance(cfg, op, Phase::Forward, Some(layer), b, s, ranks));
        }
    }
    fwd.push(op_instance(cfg, OpType::Ln, Phase::Forward, None, b, s, ranks));
    fwd.push(op_instance(cfg, OpType::Lp, Phase::Forward, None, b, s, ranks));

    // Backward: reverse order (autograd spawns backward kernels from their
    // forward counterparts — Section III-B1).
    let mut bwd = Vec::new();
    bwd.push(op_instance(cfg, OpType::Lp, Phase::Backward, None, b, s, ranks));
    bwd.push(op_instance(cfg, OpType::Ln, Phase::Backward, None, b, s, ranks));
    for layer in (0..cfg.layers as u32).rev() {
        for &op in OpType::LAYER_FWD_ORDER.iter().rev() {
            bwd.push(op_instance(cfg, op, Phase::Backward, Some(layer), b, s, ranks));
        }
    }
    bwd.push(op_instance(cfg, OpType::IE, Phase::Backward, None, b, s, ranks));

    // Optimizer phase: gradient accumulate always runs (it feeds the
    // optimizer); opt_step only on optimizer iterations.
    let mut opt = Vec::new();
    opt.push(op_instance(
        cfg,
        OpType::GradAccum,
        Phase::Optimizer,
        None,
        b,
        s,
        ranks,
    ));
    if optimizer {
        opt.push(op_instance(
            cfg,
            OpType::OptStep,
            Phase::Optimizer,
            None,
            b,
            s,
            ranks,
        ));
    }

    IterationProgram { fwd, bwd, opt }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig::llama3_8b()
    }

    #[test]
    fn forward_has_expected_structure() {
        let p = build_iteration(&cfg(), 2, 4096, 8, true);
        // i_e + 32 layers * 17 ops + ln + lp
        assert_eq!(p.fwd.len(), 1 + 32 * 17 + 2);
        assert_eq!(p.fwd[0].op.op, OpType::IE);
        assert_eq!(p.fwd.last().unwrap().op.op, OpType::Lp);
    }

    #[test]
    fn backward_is_reversed() {
        let p = build_iteration(&cfg(), 2, 4096, 8, true);
        assert_eq!(p.bwd[0].op.op, OpType::Lp);
        assert_eq!(p.bwd[0].op.phase, Phase::Backward);
        // First layer-op of backward is the last of forward order.
        assert_eq!(p.bwd[2].op.op, OpType::MlpRa);
        assert_eq!(p.bwd[2].layer, Some(31));
        assert_eq!(p.bwd.last().unwrap().op.op, OpType::IE);
    }

    #[test]
    fn optimizer_phase_toggles() {
        let with = build_iteration(&cfg(), 1, 4096, 8, true);
        let without = build_iteration(&cfg(), 1, 4096, 8, false);
        assert_eq!(with.opt.len(), 2);
        assert_eq!(without.opt.len(), 1);
        assert_eq!(without.opt[0].op.op, OpType::GradAccum);
    }

    #[test]
    fn qkv_ip_expands_to_three_gemm_kernels() {
        let p = build_iteration(&cfg(), 1, 4096, 8, false);
        let qkv = p
            .fwd
            .iter()
            .find(|o| o.op.op == OpType::QkvIp)
            .expect("qkv_ip present");
        assert_eq!(qkv.kernels.len(), 3);
        assert!(qkv.kernels.iter().all(|k| k.gemm_mnk.is_some()));
    }

    #[test]
    fn backward_gemms_have_two_kernels() {
        let p = build_iteration(&cfg(), 1, 4096, 8, false);
        let up = p
            .bwd
            .iter()
            .find(|o| o.op.op == OpType::MlpUp)
            .expect("b_mlp_up present");
        assert_eq!(up.kernels.len(), 2);
    }

    #[test]
    fn fa_backward_is_three_kernels_matching_pallas_split() {
        let p = build_iteration(&cfg(), 1, 4096, 8, false);
        let fa = p.bwd.iter().find(|o| o.op.op == OpType::AttnFa).unwrap();
        assert_eq!(fa.kernels.len(), 3);
        let total: f64 = fa.kernels.iter().map(|k| k.flops).sum();
        let cost = op_cost(&cfg(), OpType::AttnFa, Phase::Backward, 1, 4096, 8);
        assert!((total / cost.flops - 1.0).abs() < 1e-9);
    }

    #[test]
    fn opt_step_is_many_small_kernels() {
        let p = build_iteration(&cfg(), 1, 4096, 8, true);
        let opt = p.opt.iter().find(|o| o.op.op == OpType::OptStep).unwrap();
        assert!(opt.kernels.len() > 100, "got {}", opt.kernels.len());
    }

    #[test]
    fn kernel_count_scales_with_layers() {
        let mut small = cfg();
        small.layers = 4;
        let p4 = build_iteration(&small, 1, 4096, 8, false);
        let p32 = build_iteration(&cfg(), 1, 4096, 8, false);
        assert!(p32.kernel_count() > p4.kernel_count() * 4);
    }

    #[test]
    fn layer_annotations_present() {
        let p = build_iteration(&cfg(), 1, 4096, 8, false);
        for o in &p.fwd {
            match o.op.op {
                OpType::IE | OpType::Ln | OpType::Lp => assert!(o.layer.is_none()),
                _ => assert!(o.layer.is_some(), "{}", o.op),
            }
        }
    }
}
