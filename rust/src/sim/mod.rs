//! The node simulator substrate: everything the paper's testbed did, as
//! mechanisms — kernel timing (roofline + tile selection), the
//! discrete-event multi-GPU engine with C3 contention, the interconnect
//! rendezvous model, the pluggable power-management subsystem
//! ([`power`]: governor policies + energy accounting; [`dvfs`] holds the
//! stock reactive mechanism), the seeded fault-injection model
//! ([`faults`]: stragglers, degraded links, transient stalls, GPU
//! dropout + checkpoint-restart), the host-CPU model, the per-GPU RC
//! thermal model with throttle feedback ([`thermal`]), and the serialized
//! hardware-profiling pass.

pub mod cpu;
pub mod duration;
pub mod dvfs;
pub mod engine;
pub mod faults;
pub mod hwprof;
pub mod interconnect;
pub mod power;
pub mod thermal;

pub use cpu::{cpu_trace, HostModelParams};
pub use duration::{DurationModel, KernelTiming};
pub use dvfs::{DvfsGovernor, WindowActivity};
pub use engine::{Engine, EngineParams, HostActivity, SimOutput};
pub use faults::{build_fault_model, DropoutPlan, FaultModel, NoFaults};
pub use power::{
    package_power_w, parse_list_governor, GovCtx, GovernorKind, GovernorPolicy,
};
pub use thermal::{
    parse_list_ambient, parse_list_thermal, parse_thermal, ThermalConfig,
    ThermalCtx, ThermalState,
};
pub use hwprof::{align_key, collect_counters, collect_counters_topo};
pub use interconnect::{
    collective_base_ns, cross_node_allreduce_ns, group_collective_base_ns,
    hierarchical_collective_ns, inter_node_phase_ns, CollPhase, CollState,
};

use crate::config::{ModelConfig, NodeSpec, Topology, WorkloadConfig};
use crate::counters::{Counter, CounterTrace};
use crate::trace::event::{CpuTrace, PowerTrace, Trace};

/// One fully profiled training run: the runtime trace (concurrent
/// timestamps), the hardware-counter trace (serialized passes), and the
/// power / CPU telemetry — i.e., everything Chopper's trace-processing
/// stage consumes (Fig. 3).
#[derive(Debug)]
pub struct ProfiledRun {
    pub trace: Trace,
    pub counters: CounterTrace,
    pub power: PowerTrace,
    pub cpu: CpuTrace,
    pub alloc: crate::fsdp::AllocStats,
    pub iter_bounds: Vec<(f64, f64)>,
}

/// Simulate + profile one workload end to end (runtime pass + counter
/// passes + host telemetry) with default mechanism parameters.
pub fn run_workload(
    node: &NodeSpec,
    cfg: &ModelConfig,
    wl: &WorkloadConfig,
) -> ProfiledRun {
    run_workload_with(node, cfg, wl, EngineParams::default())
}

/// Same, with explicit engine parameters (used by the ablation benches).
pub fn run_workload_with(
    node: &NodeSpec,
    cfg: &ModelConfig,
    wl: &WorkloadConfig,
    params: EngineParams,
) -> ProfiledRun {
    let out = Engine::new(node, cfg, wl, params).run();
    let counters = collect_counters(node, cfg, wl, &Counter::ALL, 3);
    let cpu = cpu_trace(node, &out.host, wl.seed, &HostModelParams::default());
    ProfiledRun {
        trace: out.trace,
        counters,
        power: out.power,
        cpu,
        alloc: out.alloc,
        iter_bounds: out.iter_bounds,
    }
}

/// Simulate + profile one workload on a full cluster [`Topology`] with
/// default mechanism parameters. `Topology::single(node)` is byte-identical
/// to [`run_workload`] (pinned by `tests/pipeline.rs`).
pub fn run_workload_topo(
    topo: &Topology,
    cfg: &ModelConfig,
    wl: &WorkloadConfig,
) -> ProfiledRun {
    run_workload_topo_with(topo, cfg, wl, EngineParams::default())
}

/// [`run_workload_topo`] with explicit engine parameters.
pub fn run_workload_topo_with(
    topo: &Topology,
    cfg: &ModelConfig,
    wl: &WorkloadConfig,
    params: EngineParams,
) -> ProfiledRun {
    let out = Engine::with_topology(topo.clone(), cfg, wl, params).run();
    let counters = collect_counters_topo(topo, cfg, wl, &Counter::ALL, 3);
    // The CPU model covers node 0's host complex (every node is
    // statistically identical; on one node this is the full activity —
    // the byte-identical degenerate case).
    let host0 = out.host.node0(topo.gpus_per_node() as usize);
    let cpu = cpu_trace(&topo.node, &host0, wl.seed, &HostModelParams::default());
    ProfiledRun {
        trace: out.trace,
        counters,
        power: out.power,
        cpu,
        alloc: out.alloc,
        iter_bounds: out.iter_bounds,
    }
}

/// [`run_workload_topo_with`] with a streaming trace sink attached: the
/// engine hands events to `sink` at emission (bounded memory, chunks leave
/// the process as iterations complete), so the returned run's
/// `trace.events` is empty — read the events back from the sink's store.
/// Everything else (metadata, counters, power, cpu, iter_bounds) is
/// identical to the buffered run.
pub fn run_workload_topo_sink(
    topo: &Topology,
    cfg: &ModelConfig,
    wl: &WorkloadConfig,
    params: EngineParams,
    sink: Box<dyn crate::trace::store::TraceSink>,
) -> ProfiledRun {
    let mut eng = Engine::with_topology(topo.clone(), cfg, wl, params);
    eng.set_sink(sink);
    let out = eng.run();
    let counters = collect_counters_topo(topo, cfg, wl, &Counter::ALL, 3);
    let host0 = out.host.node0(topo.gpus_per_node() as usize);
    let cpu = cpu_trace(&topo.node, &host0, wl.seed, &HostModelParams::default());
    ProfiledRun {
        trace: out.trace,
        counters,
        power: out.power,
        cpu,
        alloc: out.alloc,
        iter_bounds: out.iter_bounds,
    }
}

/// The static trace metadata known *before* a run starts — what a
/// streaming store writer stamps into its provisional META frame so even a
/// torn file identifies its run. The engine's `finish()` rewrites the same
/// fields (plus the fault fields that only settle at the end) into the
/// store footer, which the reader prefers.
pub fn provisional_meta(topo: &Topology, wl: &WorkloadConfig) -> crate::trace::TraceMeta {
    let mut m = crate::trace::TraceMeta::default();
    m.workload = wl.label();
    m.fsdp = wl.fsdp.to_string();
    // Matches the engine's `finish()`: folded traces carry the simulated
    // shape plus the fold factor (fold 1 = exact, serializers omit it).
    m.num_gpus = topo.sim_world();
    m.num_nodes = topo.sim_nodes();
    m.gpus_per_node = topo.gpus_per_node();
    m.fold = topo.fold_factor();
    m.sharding = wl.sharding.to_string();
    m.iterations = wl.iterations;
    m.warmup = wl.warmup;
    m.seed = wl.seed;
    m.source = "sim".into();
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FsdpVersion;
    use crate::model::ops::{OpKind, OpType, Phase};
    use crate::trace::event::Stream;

    /// A scaled-down model so engine tests stay fast.
    fn small() -> (NodeSpec, ModelConfig, WorkloadConfig) {
        let node = NodeSpec::mi300x_node();
        let mut cfg = ModelConfig::llama3_8b();
        cfg.layers = 4;
        let mut wl = WorkloadConfig::new(2, 4096, FsdpVersion::V1);
        wl.iterations = 2;
        wl.warmup = 1;
        (node, cfg, wl)
    }

    fn sim(fsdp: FsdpVersion) -> SimOutput {
        let (node, cfg, mut wl) = small();
        wl.fsdp = fsdp;
        Engine::new(&node, &cfg, &wl, EngineParams::default()).run()
    }

    #[test]
    fn every_dispatched_kernel_appears_in_trace() {
        let (node, cfg, wl) = small();
        let program = crate::fsdp::build_program(&cfg, &wl, node.num_gpus as u64);
        let expect_compute = program.kernels().count();
        let expect_comm = program.collectives().count();
        let out = Engine::new(&node, &cfg, &wl, EngineParams::default()).run();
        let per_gpu_compute = out
            .trace
            .events
            .iter()
            .filter(|e| e.gpu == 0 && e.stream == Stream::Compute)
            .count();
        let per_gpu_comm = out
            .trace
            .events
            .iter()
            .filter(|e| e.gpu == 0 && e.stream == Stream::Comm)
            .count();
        assert_eq!(per_gpu_compute, expect_compute);
        assert_eq!(per_gpu_comm, expect_comm);
        assert_eq!(
            out.trace.events.len(),
            (expect_compute + expect_comm) * node.num_gpus as usize
        );
    }

    #[test]
    fn timestamps_are_well_formed() {
        let out = sim(FsdpVersion::V1);
        for e in &out.trace.events {
            assert!(e.t_end > e.t_start, "{}: end before start", e.name);
            assert!(e.t_start >= 0.0);
            assert!(e.t_launch <= e.t_start + 1e-6, "{}: launched after start", e.name);
        }
    }

    #[test]
    fn compute_stream_is_serial_per_gpu() {
        let out = sim(FsdpVersion::V1);
        for gpu in 0..8 {
            let mut evs: Vec<_> = out
                .trace
                .events
                .iter()
                .filter(|e| e.gpu == gpu && e.stream == Stream::Compute)
                .collect();
            evs.sort_by(|a, b| a.seq.cmp(&b.seq));
            for w in evs.windows(2) {
                assert!(
                    w[1].t_start >= w[0].t_end - 1e-6,
                    "compute kernels overlap on gpu {gpu}"
                );
            }
        }
    }

    #[test]
    fn comm_and_compute_do_overlap() {
        // The C3 premise: collectives overlap compute on the same GPU.
        let out = sim(FsdpVersion::V1);
        let comm: Vec<_> = out
            .trace
            .events
            .iter()
            .filter(|e| e.gpu == 0 && e.stream == Stream::Comm)
            .collect();
        let compute: Vec<_> = out
            .trace
            .events
            .iter()
            .filter(|e| e.gpu == 0 && e.stream == Stream::Compute)
            .collect();
        let mut overlap_ns = 0.0;
        for c in &comm {
            for k in &compute {
                let lo = c.t_start.max(k.t_start);
                let hi = c.t_end.min(k.t_end);
                if hi > lo {
                    overlap_ns += hi - lo;
                }
            }
        }
        assert!(overlap_ns > 0.0, "no C3 overlap at all");
    }

    #[test]
    fn iterations_are_ordered_and_bounded() {
        let out = sim(FsdpVersion::V1);
        assert_eq!(out.iter_bounds.len(), 2);
        let (s0, e0) = out.iter_bounds[0];
        let (s1, e1) = out.iter_bounds[1];
        assert!(s0 < e0 && s1 < e1);
        assert!(e0 <= s1 + 1e-3, "iterations overlap: {e0} vs {s1}");
    }

    #[test]
    fn backward_kernels_link_to_forward() {
        let out = sim(FsdpVersion::V1);
        let linked = out
            .trace
            .events
            .iter()
            .filter(|e| e.op.phase == Phase::Backward && e.fwd_link.is_some())
            .count();
        assert!(linked > 0, "no fwd->bwd links recorded");
        // Each link points at a real forward kernel of the same op type.
        let by_id: std::collections::HashMap<u64, &crate::trace::event::TraceEvent> =
            out.trace.events.iter().map(|e| (e.kernel_id, e)).collect();
        for e in out.trace.events.iter().filter(|e| e.fwd_link.is_some()) {
            let f = by_id[&e.fwd_link.unwrap()];
            assert_eq!(f.op.phase, Phase::Forward);
            assert_eq!(f.op.op, e.op.op);
            assert_eq!(f.gpu, e.gpu);
            assert_eq!(f.layer, e.layer);
        }
    }

    #[test]
    fn v2_runs_faster_than_v1() {
        // Observation 5/6: FSDPv2 achieves higher throughput.
        let v1 = sim(FsdpVersion::V1);
        let v2 = sim(FsdpVersion::V2);
        assert!(
            v2.trace.span_ns() < v1.trace.span_ns(),
            "v2 {} !< v1 {}",
            v2.trace.span_ns(),
            v1.trace.span_ns()
        );
    }

    #[test]
    fn v2_sustains_higher_frequency_same_power() {
        let v1 = sim(FsdpVersion::V1);
        let v2 = sim(FsdpVersion::V2);
        // Compare over *active* windows (compute in flight), the way the
        // paper's Fig. 14 averages over training activity; idle fill/empty
        // windows would otherwise dilute the comparison.
        let avg = |p: &crate::trace::event::PowerTrace,
                   f: fn(&crate::trace::event::PowerSample) -> f64| {
            let xs: Vec<f64> = p
                .samples
                .iter()
                .filter(|s| s.power_w > 400.0)
                .map(f)
                .collect();
            crate::util::stats::mean(&xs)
        };
        let f1 = avg(&v1.power, |s| s.freq_mhz);
        let f2 = avg(&v2.power, |s| s.freq_mhz);
        assert!(f2 > f1 * 1.05, "v2 freq {f2:.0} !>> v1 freq {f1:.0}");
        let p1 = avg(&v1.power, |s| s.power_w);
        let p2 = avg(&v2.power, |s| s.power_w);
        assert!(
            (p2 - p1).abs() / p1 < 0.15,
            "power differs: {p1:.0} vs {p2:.0}"
        );
    }

    #[test]
    fn v2_has_param_copy_kernels_v1_does_not() {
        let v1 = sim(FsdpVersion::V1);
        let v2 = sim(FsdpVersion::V2);
        let copies = |o: &SimOutput| {
            o.trace
                .events
                .iter()
                .filter(|e| e.op.op == OpType::ParamCopy)
                .count()
        };
        assert_eq!(copies(&v1), 0);
        assert!(copies(&v2) > 0);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = sim(FsdpVersion::V1);
        let b = sim(FsdpVersion::V1);
        assert_eq!(a.trace.events.len(), b.trace.events.len());
        assert_eq!(a.trace.span_ns(), b.trace.span_ns());
        let ta: Vec<f64> = a.trace.events.iter().map(|e| e.t_start).collect();
        let tb: Vec<f64> = b.trace.events.iter().map(|e| e.t_start).collect();
        assert_eq!(ta, tb);
    }

    #[test]
    fn gpus_finish_at_slightly_different_times() {
        // Per-GPU heterogeneity exists but stays small.
        let out = sim(FsdpVersion::V1);
        let mut last_end = vec![0.0f64; 8];
        for e in &out.trace.events {
            last_end[e.gpu as usize] = last_end[e.gpu as usize].max(e.t_end);
        }
        let lo = last_end.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = last_end.iter().cloned().fold(0.0, f64::max);
        assert!(hi > lo, "no skew at all");
        assert!((hi - lo) / hi < 0.05, "skew too large: {lo} vs {hi}");
    }

    #[test]
    fn profiled_run_has_all_artifacts() {
        let (node, cfg, wl) = small();
        let run = run_workload(&node, &cfg, &wl);
        assert!(!run.trace.events.is_empty());
        assert!(!run.power.samples.is_empty());
        assert!(!run.cpu.samples.is_empty());
        // Counters align with the first compute kernel.
        let v = run.counters.get(0, align_key(Stream::Compute, 0));
        assert!(v.is_some());
    }

    #[test]
    fn single_node_topology_matches_nodespec_engine_bitwise() {
        let (node, cfg, wl) = small();
        let flat = Engine::new(&node, &cfg, &wl, EngineParams::default()).run();
        let topo = crate::config::Topology::single(node.clone());
        let t = Engine::with_topology(topo, &cfg, &wl, EngineParams::default()).run();
        assert_eq!(flat.trace.events.len(), t.trace.events.len());
        for (a, b) in flat.trace.events.iter().zip(&t.trace.events) {
            assert_eq!(a.kernel_id, b.kernel_id);
            assert_eq!(a.t_start.to_bits(), b.t_start.to_bits());
            assert_eq!(a.t_end.to_bits(), b.t_end.to_bits());
            assert_eq!(a.seq, b.seq);
        }
        assert_eq!(t.trace.meta.num_nodes, 1);
        assert_eq!(t.trace.meta.gpus_per_node, 8);
    }

    fn multi(nodes: u32, sharding: crate::config::Sharding) -> SimOutput {
        let (_, cfg, mut wl) = small();
        wl.sharding = sharding;
        let topo = crate::config::Topology::mi300x_cluster(nodes);
        Engine::with_topology(topo, &cfg, &wl, EngineParams::default()).run()
    }

    #[test]
    fn multinode_trace_covers_every_rank_and_comm() {
        use crate::config::Sharding;
        let (_, cfg, wl) = small();
        let topo = crate::config::Topology::mi300x_cluster(2);
        let program = crate::fsdp::build_program_topo(&cfg, &wl, &topo);
        let out = multi(2, Sharding::Fsdp);
        assert_eq!(out.trace.meta.num_gpus, 16);
        assert_eq!(out.trace.meta.num_nodes, 2);
        for gpu in 0..16u32 {
            let comm = out
                .trace
                .events
                .iter()
                .filter(|e| e.gpu == gpu && e.stream == Stream::Comm)
                .count();
            assert_eq!(comm, program.collectives().count(), "gpu {gpu}");
        }
    }

    #[test]
    fn hsdp_emits_allreduces_and_fsdp_does_not() {
        use crate::config::Sharding;
        let fsdp = multi(2, Sharding::Fsdp);
        let hsdp = multi(2, Sharding::Hsdp);
        let ars = |o: &SimOutput| {
            o.trace
                .events
                .iter()
                .filter(|e| e.op.op == OpType::AllReduce)
                .count()
        };
        assert_eq!(ars(&fsdp), 0);
        assert!(ars(&hsdp) > 0);
        assert_eq!(hsdp.trace.meta.sharding, "HSDP");
    }

    #[test]
    fn hsdp_intra_node_comm_overlaps_across_nodes() {
        // Node-scoped rendezvous groups progress independently: comm
        // occupancy on node 0 overlaps comm occupancy on node 1 in wall
        // time, which world-scoped collectives can never do.
        use crate::config::Sharding;
        let out = multi(2, Sharding::Hsdp);
        let spans = |node: u32| -> Vec<(f64, f64)> {
            out.trace
                .events
                .iter()
                .filter(|e| {
                    e.stream == Stream::Comm
                        && e.op.op == OpType::AllGather
                        && e.gpu / 8 == node
                })
                .map(|e| (e.t_start, e.t_end))
                .collect()
        };
        let (a, b) = (spans(0), spans(1));
        let overlapping = a.iter().any(|(s0, e0)| {
            b.iter().any(|(s1, e1)| s0.max(*s1) < e0.min(*e1))
        });
        assert!(overlapping, "no cross-node comm concurrency under HSDP");
    }

    #[test]
    fn multinode_fsdp_pays_the_inter_node_phase() {
        // Same per-rank workload, same per-node hardware: adding a second
        // node makes every world collective strictly more expensive, so
        // the run gets slower end to end.
        use crate::config::Sharding;
        let one = multi(1, Sharding::Fsdp);
        let two = multi(2, Sharding::Fsdp);
        assert!(
            two.trace.span_ns() > one.trace.span_ns(),
            "2-node span {} !> 1-node span {}",
            two.trace.span_ns(),
            one.trace.span_ns()
        );
    }

    #[test]
    fn multinode_runs_are_deterministic() {
        use crate::config::Sharding;
        let a = multi(2, Sharding::Hsdp);
        let b = multi(2, Sharding::Hsdp);
        assert_eq!(a.trace.events.len(), b.trace.events.len());
        let ta: Vec<u64> = a.trace.events.iter().map(|e| e.t_start.to_bits()).collect();
        let tb: Vec<u64> = b.trace.events.iter().map(|e| e.t_start.to_bits()).collect();
        assert_eq!(ta, tb);
    }

    #[test]
    fn topo_profiled_run_has_all_artifacts() {
        use crate::config::Sharding;
        let (_, cfg, mut wl) = small();
        wl.sharding = Sharding::Hsdp;
        let topo = crate::config::Topology::mi300x_cluster(2);
        let run = run_workload_topo(&topo, &cfg, &wl);
        assert!(!run.trace.events.is_empty());
        assert!(!run.power.samples.is_empty());
        assert!(!run.cpu.samples.is_empty());
        // Counters cover a far rank on node 1 as well.
        let v = run.counters.get(15, align_key(Stream::Compute, 0));
        assert!(v.is_some());
    }

    #[test]
    fn gemm_events_dominate_compute_time() {
        // Fig. 4: GEMMs ≈ 60% of fwd+bwd duration.
        let out = sim(FsdpVersion::V1);
        let mut gemm = 0.0;
        let mut total = 0.0;
        for e in out.trace.events.iter().filter(|e| {
            e.stream == Stream::Compute && e.op.phase != Phase::Optimizer
        }) {
            let d = e.duration();
            total += d;
            if e.kind() == OpKind::Gemm {
                gemm += d;
            }
        }
        let frac = gemm / total;
        assert!(frac > 0.40 && frac < 0.85, "gemm fraction {frac}");
    }
}
