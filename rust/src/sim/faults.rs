//! Seeded fault model: turns declarative [`FaultSpec`]s into the concrete,
//! deterministic fault events the engine prices into a run.
//!
//! Seeding contract: fault `i` in the spec list derives **all** of its
//! randomness from `Rng::substream(seed, "fault<i>")` — which rank
//! straggles, which node's link degrades, when stalls fire. Per-rank stall
//! streams are further split as `Rng::substream(seed ^ fnv1a("fault<i>"),
//! "rank<g>")` so each rank consumes its own draw sequence in its own
//! deterministic kernel-dispatch order. Crucially, no fault ever draws
//! from the engine's per-rank jitter streams (`substream(seed,
//! "rank<g>")`): those are consumed in strict program order by the
//! healthy pipeline, so stealing a draw would silently reshuffle every
//! downstream jitter value and break the empty-set byte-identity
//! guarantee. With an empty spec list [`NoFaults`] is installed and no
//! fault code touches a single random draw or float — the run is
//! bit-identical to a build without this module.

use crate::config::FaultSpec;
use crate::util::prng::{fnv1a, Rng};

/// Resolved GPU-dropout plan: `rank` dies at `at_ns`; the schedule
/// replays from the last checkpoint boundary with `restart_ns` of
/// restart cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DropoutPlan {
    pub rank: u32,
    pub at_ns: f64,
    pub restart_ns: f64,
}

/// Object-safe fault model the engine consults at each pricing point.
/// All methods are exact no-ops on the empty model.
pub trait FaultModel: std::fmt::Debug + Send {
    /// True iff no fault is active (engine skips all fault paths).
    fn is_empty(&self) -> bool;
    /// Persistent compute-throughput multiplier for `rank` (1.0 = healthy,
    /// < 1.0 = straggler).
    fn compute_factor(&self, rank: usize) -> f64;
    /// Transfer-time multiplier (>= 1.0) for a collective instance whose
    /// rendezvous group is `participants`: the slowest degraded link any
    /// participant sits behind dominates the whole group.
    fn link_time_factor(&self, participants: &[usize]) -> f64;
    /// Transient stall (ns of extra nominal work) charged to the kernel
    /// now starting on `rank`; 0.0 almost always. Draws, when they
    /// happen, come from this model's own per-rank substreams.
    fn stall_ns(&mut self, rank: usize) -> f64;
    /// The resolved dropout event, if any (first `Dropout` spec wins).
    fn dropout(&self) -> Option<DropoutPlan>;
    /// Per-rank compute multipliers for the whole world (for
    /// `TraceMeta::fault_slowdown`); empty on the empty model.
    fn slowdowns(&self) -> Vec<f64>;
}

/// The empty model: installed when `EngineParams::faults` is empty.
#[derive(Debug, Default)]
pub struct NoFaults;

impl FaultModel for NoFaults {
    fn is_empty(&self) -> bool {
        true
    }
    fn compute_factor(&self, _rank: usize) -> f64 {
        1.0
    }
    fn link_time_factor(&self, _participants: &[usize]) -> f64 {
        1.0
    }
    fn stall_ns(&mut self, _rank: usize) -> f64 {
        0.0
    }
    fn dropout(&self) -> Option<DropoutPlan> {
        None
    }
    fn slowdowns(&self) -> Vec<f64> {
        Vec::new()
    }
}

/// One resolved transient-stall source: per-rank substreams drawn in the
/// rank's own kernel-dispatch order.
#[derive(Debug)]
struct StallSource {
    rate: f64,
    mean_ns: f64,
    rngs: Vec<Rng>,
}

/// Faults resolved against a concrete `(seed, world, gpus_per_node)`.
#[derive(Debug)]
pub struct SeededFaults {
    gpus_per_node: usize,
    /// Per-rank persistent compute multiplier (product of stragglers).
    compute: Vec<f64>,
    /// (node, 1/bw) per degraded link.
    bad_links: Vec<(usize, f64)>,
    stalls: Vec<StallSource>,
    dropout: Option<DropoutPlan>,
}

impl FaultModel for SeededFaults {
    fn is_empty(&self) -> bool {
        false
    }

    fn compute_factor(&self, rank: usize) -> f64 {
        self.compute[rank]
    }

    fn link_time_factor(&self, participants: &[usize]) -> f64 {
        let mut f = 1.0f64;
        for &(node, slow) in &self.bad_links {
            if participants
                .iter()
                .any(|&p| p / self.gpus_per_node == node)
            {
                f = f.max(slow);
            }
        }
        f
    }

    fn stall_ns(&mut self, rank: usize) -> f64 {
        let mut total = 0.0;
        for src in &mut self.stalls {
            let r = &mut src.rngs[rank];
            if r.f64() < src.rate {
                // Exponentially distributed retry burst; 1 - u keeps the
                // argument of ln strictly positive.
                total += -src.mean_ns * (1.0 - r.f64()).ln();
            }
        }
        total
    }

    fn dropout(&self) -> Option<DropoutPlan> {
        self.dropout
    }

    fn slowdowns(&self) -> Vec<f64> {
        self.compute.clone()
    }
}

/// Resolve `specs` into a concrete model for a `world`-rank run.
///
/// Panics on [`FaultSpec::Panic`] — the documented test hook for the
/// campaign runner's per-scenario panic isolation.
pub fn build_fault_model(
    specs: &[FaultSpec],
    seed: u64,
    world: usize,
    gpus_per_node: usize,
) -> Box<dyn FaultModel> {
    if specs.is_empty() {
        return Box::new(NoFaults);
    }
    let num_nodes = world.div_ceil(gpus_per_node.max(1));
    let mut model = SeededFaults {
        gpus_per_node: gpus_per_node.max(1),
        compute: vec![1.0; world],
        bad_links: Vec::new(),
        stalls: Vec::new(),
        dropout: None,
    };
    for (i, spec) in specs.iter().enumerate() {
        let label = format!("fault{i}");
        let mut rng = Rng::substream(seed, &label);
        match spec {
            FaultSpec::Straggler { rank, factor } => {
                let g = resolve_rank(*rank, world, &mut rng);
                model.compute[g] *= factor;
            }
            FaultSpec::LinkDown { node, bw } => {
                let n = match node {
                    Some(n) => (*n as usize).min(num_nodes - 1),
                    None => rng.range_usize(0, num_nodes),
                };
                model.bad_links.push((n, 1.0 / bw.clamp(0.05, 1.0)));
            }
            FaultSpec::Stalls { rate, mean_us } => {
                let sub = seed ^ fnv1a(label.as_bytes());
                model.stalls.push(StallSource {
                    rate: *rate,
                    mean_ns: mean_us * 1e3,
                    rngs: (0..world)
                        .map(|g| Rng::substream(sub, &format!("rank{g}")))
                        .collect(),
                });
            }
            FaultSpec::Dropout {
                rank,
                at_ms,
                restart_ms,
            } => {
                if model.dropout.is_none() {
                    let g = resolve_rank(*rank, world, &mut rng);
                    model.dropout = Some(DropoutPlan {
                        rank: g as u32,
                        at_ns: at_ms * 1e6,
                        restart_ns: restart_ms * 1e6,
                    });
                }
            }
            FaultSpec::Panic => {
                panic!("fault injection: deliberate `panic` fault (runner isolation test hook)")
            }
        }
    }
    Box::new(model)
}

fn resolve_rank(rank: Option<u32>, world: usize, rng: &mut Rng) -> usize {
    match rank {
        Some(r) => (r as usize).min(world - 1),
        None => rng.range_usize(0, world),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::faults::parse_fault_set;

    #[test]
    fn empty_specs_build_the_empty_model() {
        let mut m = build_fault_model(&[], 7, 8, 8);
        assert!(m.is_empty());
        assert_eq!(m.compute_factor(3), 1.0);
        assert_eq!(m.link_time_factor(&[0, 1, 2]), 1.0);
        assert_eq!(m.stall_ns(0), 0.0);
        assert!(m.dropout().is_none());
        assert!(m.slowdowns().is_empty());
    }

    #[test]
    fn resolution_is_deterministic_in_seed() {
        let set = parse_fault_set("straggler(factor=0.8)+dropout").unwrap();
        let a = build_fault_model(&set, 42, 8, 8);
        let b = build_fault_model(&set, 42, 8, 8);
        assert_eq!(a.slowdowns(), b.slowdowns());
        assert_eq!(a.dropout(), b.dropout());
        // A different seed picks (with high probability over the world
        // size) a different straggler rank — at minimum the resolved
        // model is still well-formed.
        let c = build_fault_model(&set, 43, 8, 8);
        assert_eq!(c.slowdowns().len(), 8);
        assert_eq!(
            c.slowdowns().iter().filter(|&&f| f < 1.0).count(),
            1,
            "exactly one straggler"
        );
    }

    #[test]
    fn stall_streams_replay_per_rank() {
        let set = parse_fault_set("stalls(rate=1.0,mean_us=100)").unwrap();
        let mut a = build_fault_model(&set, 9, 2, 2);
        let mut b = build_fault_model(&set, 9, 2, 2);
        let draws_a: Vec<f64> = (0..4).map(|_| a.stall_ns(0)).collect();
        let draws_b: Vec<f64> = (0..4).map(|_| b.stall_ns(0)).collect();
        assert_eq!(draws_a, draws_b);
        assert!(draws_a.iter().all(|&d| d > 0.0));
        // Rank 1's stream is independent of rank 0's consumption.
        assert_eq!(a.stall_ns(1), b.stall_ns(1));
    }

    #[test]
    fn link_factor_hits_only_touched_groups() {
        let set = parse_fault_set("linkdown(node=1,bw=0.5)").unwrap();
        let m = build_fault_model(&set, 5, 16, 8);
        // Group entirely on node 0: untouched.
        assert_eq!(m.link_time_factor(&[0, 1, 7]), 1.0);
        // Any group touching node 1 pays 1/bw.
        assert_eq!(m.link_time_factor(&[0, 8]), 2.0);
        assert_eq!(m.link_time_factor(&[9, 10]), 2.0);
    }

    #[test]
    fn explicit_ranks_and_clamps() {
        let set =
            parse_fault_set("straggler(rank=99,factor=0.5)+dropout(rank=1,at_ms=10,restart_ms=20)")
                .unwrap();
        let m = build_fault_model(&set, 0, 4, 4);
        // Out-of-range rank clamps to the last rank.
        assert_eq!(m.compute_factor(3), 0.5);
        let d = m.dropout().unwrap();
        assert_eq!(d.rank, 1);
        assert_eq!(d.at_ns, 10.0e6);
        assert_eq!(d.restart_ns, 20.0e6);
    }

    #[test]
    #[should_panic(expected = "deliberate")]
    fn panic_fault_panics_at_build() {
        let _ = build_fault_model(&[FaultSpec::Panic], 0, 2, 2);
    }
}
