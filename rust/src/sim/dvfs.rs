//! The stock DVFS governor mechanism — the behaviour behind Observation 6
//! and Insight 8. Since the power-management refactor this is one policy
//! among several: `sim::power` wraps it as `Reactive` (bit-identical) and
//! offers alternatives behind the [`GovernorPolicy`](crate::sim::power::
//! GovernorPolicy) trait; the package-power model itself lives in
//! [`power::package_power_w`](crate::sim::power::package_power_w) so every
//! policy prices watts identically.
//!
//! Per window the model computes package power from engine activity
//! (MFMA-weighted compute busy fraction), HBM traffic, and an HBM power
//! *noise* term driven by the caching allocator's behaviour: FSDPv1's
//! non-deterministic block reuse produces bursty page-touch traffic, i.e. a
//! noisy power signal. The governor maximizes frequency under the board
//! power cap but must leave headroom proportional to the observed power
//! variability — noisy power (v1) ⇒ bigger margin ⇒ lower sustained clocks
//! at the *same average power*, exactly the paper's Fig. 14.

use crate::config::GpuSpec;
use crate::util::prng::Rng;
use crate::util::stats::Ema;

/// Activity observed on one GPU during one window.
#[derive(Debug, Clone, Copy, Default)]
pub struct WindowActivity {
    /// Fraction of the window with a compute kernel running, [0,1].
    pub compute_busy: f64,
    /// Mean MFMA utilization of running compute kernels, [0,1].
    pub mfma_util: f64,
    /// HBM bytes moved this window.
    pub hbm_bytes: f64,
    /// Fraction of the window with a comm kernel running.
    pub comm_busy: f64,
}

/// Governor state for one GPU.
#[derive(Debug, Clone)]
pub struct DvfsGovernor {
    gpu: GpuSpec,
    /// Current engine clock, MHz.
    pub freq_mhz: f64,
    /// Current memory clock, MHz.
    pub mem_freq_mhz: f64,
    /// Window length, ns.
    pub window_ns: f64,
    /// Extra HBM power noise sigma (W) injected by allocator behaviour.
    pub hbm_noise_w: f64,
    /// Required margin = margin_k * observed power sigma.
    margin_k: f64,
    power_ema: Ema,
    power_var_ema: Ema,
    last_power_w: f64,
    rng: Rng,
}

impl DvfsGovernor {
    /// The legacy 1 ms / 0.3-margin constructor, kept with this exact
    /// signature for the verbatim pre-refactor engine in
    /// `benches/engine_baseline.rs`. New code routes the window and margin
    /// explicitly via [`with_window`](Self::with_window) — the engine's
    /// `EngineParams::dvfs_window_ns` / `margin_k` are the single source
    /// of truth (previously `window_ns` was duplicated here and silently
    /// disagreed with the engine's tick period on non-default windows).
    pub fn new(gpu: GpuSpec, seed: u64, gpu_idx: u32, hbm_noise_w: f64) -> Self {
        Self::with_window(gpu, seed, gpu_idx, hbm_noise_w, 1_000_000.0, 0.3)
    }

    /// Construct with an explicit governor window (ns) and margin
    /// coefficient — what [`sim::power::Reactive`](crate::sim::power::
    /// Reactive) builds from `EngineParams`.
    pub fn with_window(
        gpu: GpuSpec,
        seed: u64,
        gpu_idx: u32,
        hbm_noise_w: f64,
        window_ns: f64,
        margin_k: f64,
    ) -> Self {
        Self {
            freq_mhz: gpu.freq_peak_mhz * 0.85,
            mem_freq_mhz: gpu.mem_freq_peak_mhz * 0.9,
            window_ns,
            hbm_noise_w,
            margin_k,
            power_ema: Ema::new(0.2),
            power_var_ema: Ema::new(0.1),
            last_power_w: gpu.idle_power_w,
            rng: Rng::substream(seed, &format!("dvfs{gpu_idx}")),
            gpu,
        }
    }

    /// Package power at frequency `f` for the given activity — the shared
    /// model in [`power::package_power_w`](crate::sim::power::
    /// package_power_w), evaluated at this governor's window.
    fn power_at(&self, f_mhz: f64, act: &WindowActivity, noise_w: f64) -> f64 {
        crate::sim::power::package_power_w(
            &self.gpu,
            f_mhz,
            self.window_ns,
            act,
            noise_w,
        )
    }

    /// Advance one window: observe activity, update the power telemetry,
    /// pick the next window's frequency. Returns (power_w, freq_mhz).
    ///
    /// Firmware behaviour modelled: cap *violations* cause an immediate
    /// hard throttle; recovery is slow (small up-slew) and aims below the
    /// cap by a margin proportional to the observed power variability. A
    /// noisy power signal therefore costs frequency twice — via frequent
    /// throttles and via the bigger margin — while contributing extra
    /// power itself, which keeps the *average* power of noisy and quiet
    /// workloads nearly identical (Observation 6).
    pub fn step(&mut self, act: &WindowActivity) -> (f64, f64) {
        // Allocator-driven HBM power noise (shared draw — see
        // power::hbm_noise_draw for the physics).
        let busy = act.compute_busy.max(act.comm_busy);
        let noise = crate::sim::power::hbm_noise_draw(
            &mut self.rng,
            self.hbm_noise_w,
            act,
        );
        // The in-window fast regulator bounds transient overshoot to ~10%
        // above the cap (the slow per-window loop below handles the rest).
        let power = self
            .power_at(self.freq_mhz, act, noise)
            .clamp(self.gpu.idle_power_w, self.gpu.power_cap_w * 1.10);
        self.last_power_w = power;

        // Telemetry: EMA of power and of squared deviation (variance).
        let mean = self.power_ema.update(power);
        let dev = power - mean;
        let var = self.power_var_ema.update(dev * dev);
        let sigma = var.sqrt();

        if power > self.gpu.power_cap_w {
            // Hard throttle on a cap violation.
            self.freq_mhz = (self.freq_mhz - 250.0).max(self.gpu.freq_min_mhz);
        } else {
            // Climb toward the highest frequency whose predicted power
            // fits under cap minus the variability margin. Recovery slew
            // is slow (firmware does not jump the full range at once).
            let margin = self.margin_k * sigma;
            let budget = self.gpu.power_cap_w - margin;
            // Closed-form inversion of power_at: dynamic = dyn_w * fr^2.2,
            // so the highest admissible ratio is ((budget-static)/dyn)^(1/2.2);
            // snap down to the 50 MHz grid the firmware uses. Coefficients
            // are the shared power-model constants (sim::power).
            use crate::sim::power::{FREQ_POWER_EXP, MFMA_PEAK_W, VALU_PEAK_W};
            let dyn_w = MFMA_PEAK_W * act.compute_busy * act.mfma_util
                + VALU_PEAK_W * act.compute_busy * (1.0 - act.mfma_util);
            // power_at(0) = idle + comm + hbm (the fr^2.2 term vanishes).
            let static_w = self.power_at(0.0, act, 0.0);
            let headroom = budget - static_w;
            let mut target = if dyn_w <= 1e-9 {
                self.gpu.freq_peak_mhz
            } else if headroom <= 0.0 {
                self.gpu.freq_min_mhz
            } else {
                let fr = (headroom / dyn_w).powf(1.0 / FREQ_POWER_EXP);
                let f = fr * self.gpu.freq_peak_mhz;
                (f / 50.0).floor() * 50.0
            };
            target = target.clamp(self.gpu.freq_min_mhz, self.gpu.freq_peak_mhz);
            // Idle windows drift toward a mid clock (no demand).
            if busy < 0.05 {
                target = self.gpu.freq_peak_mhz * 0.6;
            }
            let delta = (target - self.freq_mhz).clamp(-250.0, 150.0);
            self.freq_mhz = (self.freq_mhz + delta)
                .clamp(self.gpu.freq_min_mhz, self.gpu.freq_peak_mhz);
        }
        // Memory clock tracks the engine clock's headroom situation.
        let mem_target = self.gpu.mem_freq_peak_mhz
            * (0.72 + 0.28 * (self.freq_mhz / self.gpu.freq_peak_mhz));
        self.mem_freq_mhz += (mem_target - self.mem_freq_mhz) * 0.5;
        (power, self.freq_mhz)
    }

    pub fn freq_ratio(&self) -> f64 {
        self.freq_mhz / self.gpu.freq_peak_mhz
    }

    pub fn mem_freq_ratio(&self) -> f64 {
        self.mem_freq_mhz / self.gpu.mem_freq_peak_mhz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy_window() -> WindowActivity {
        WindowActivity {
            compute_busy: 0.95,
            mfma_util: 0.6,
            hbm_bytes: 3.5e9, // 3.5 GB per ms ~ 3.5 TB/s
            comm_busy: 0.3,
        }
    }

    fn run(noise_w: f64, windows: u32) -> (f64, f64) {
        let mut g = DvfsGovernor::new(GpuSpec::mi300x(), 42, 0, noise_w);
        let mut freq_sum = 0.0;
        let mut power_sum = 0.0;
        let act = busy_window();
        for _ in 0..windows {
            let (p, f) = g.step(&act);
            power_sum += p;
            freq_sum += f;
        }
        (power_sum / windows as f64, freq_sum / windows as f64)
    }

    #[test]
    fn noisy_power_lowers_sustained_frequency() {
        // Observation 6: v1 (noisy) runs ~20-25% below v2 (quiet) at
        // nearly the same average power.
        let (p_quiet, f_quiet) = run(4.0, 400);
        let (p_noisy, f_noisy) = run(150.0, 400);
        assert!(
            f_noisy < f_quiet * 0.88,
            "noisy {f_noisy:.0} MHz vs quiet {f_quiet:.0} MHz"
        );
        // Average power roughly equal (within 12%).
        let rel = (p_noisy - p_quiet).abs() / p_quiet;
        assert!(rel < 0.12, "power mismatch {rel}");
    }

    #[test]
    fn power_never_exceeds_cap_by_much() {
        let mut g = DvfsGovernor::new(GpuSpec::mi300x(), 7, 1, 40.0);
        let act = busy_window();
        for _ in 0..500 {
            let (p, _) = g.step(&act);
            assert!(p < g.gpu.power_cap_w * 1.15, "power {p}");
        }
    }

    #[test]
    fn frequency_stays_in_range() {
        let mut g = DvfsGovernor::new(GpuSpec::mi300x(), 9, 2, 80.0);
        for i in 0..300 {
            let act = if i % 3 == 0 {
                WindowActivity::default()
            } else {
                busy_window()
            };
            g.step(&act);
            assert!(g.freq_mhz >= g.gpu.freq_min_mhz - 1.0);
            assert!(g.freq_mhz <= g.gpu.freq_peak_mhz + 1.0);
        }
    }

    #[test]
    fn quiet_workload_reaches_high_clocks() {
        let (_, f) = run(2.0, 400);
        assert!(f > GpuSpec::mi300x().freq_peak_mhz * 0.8, "freq {f}");
    }

    #[test]
    fn deterministic_for_same_seed() {
        let a = run(30.0, 100);
        let b = run(30.0, 100);
        assert_eq!(a, b);
    }

    #[test]
    fn legacy_constructor_equals_with_window_defaults() {
        let mut a = DvfsGovernor::new(GpuSpec::mi300x(), 11, 3, 25.0);
        let mut b = DvfsGovernor::with_window(
            GpuSpec::mi300x(),
            11,
            3,
            25.0,
            1_000_000.0,
            0.3,
        );
        let act = busy_window();
        for _ in 0..200 {
            let (pa, fa) = a.step(&act);
            let (pb, fb) = b.step(&act);
            assert_eq!(pa.to_bits(), pb.to_bits());
            assert_eq!(fa.to_bits(), fb.to_bits());
        }
    }

    #[test]
    fn window_length_feeds_the_power_model() {
        // Same byte traffic in a half-length window = twice the HBM rate =
        // more HBM power — the disagreement the routed window fixes.
        let mut short = DvfsGovernor::with_window(
            GpuSpec::mi300x(),
            5,
            0,
            0.0,
            500_000.0,
            0.3,
        );
        let mut long = DvfsGovernor::with_window(
            GpuSpec::mi300x(),
            5,
            0,
            0.0,
            1_000_000.0,
            0.3,
        );
        let mut act = busy_window();
        act.hbm_bytes = 1.0e9; // keep both windows below HBM saturation
        let (p_short, _) = short.step(&act);
        let (p_long, _) = long.step(&act);
        assert!(p_short > p_long, "{p_short} !> {p_long}");
    }
}
