//! DVFS governor and power model — the mechanism behind Observation 6 and
//! Insight 8.
//!
//! Per window the model computes package power from engine activity
//! (MFMA-weighted compute busy fraction), HBM traffic, and an HBM power
//! *noise* term driven by the caching allocator's behaviour: FSDPv1's
//! non-deterministic block reuse produces bursty page-touch traffic, i.e. a
//! noisy power signal. The governor maximizes frequency under the board
//! power cap but must leave headroom proportional to the observed power
//! variability — noisy power (v1) ⇒ bigger margin ⇒ lower sustained clocks
//! at the *same average power*, exactly the paper's Fig. 14.

use crate::config::GpuSpec;
use crate::util::prng::Rng;
use crate::util::stats::Ema;

/// Activity observed on one GPU during one window.
#[derive(Debug, Clone, Copy, Default)]
pub struct WindowActivity {
    /// Fraction of the window with a compute kernel running, [0,1].
    pub compute_busy: f64,
    /// Mean MFMA utilization of running compute kernels, [0,1].
    pub mfma_util: f64,
    /// HBM bytes moved this window.
    pub hbm_bytes: f64,
    /// Fraction of the window with a comm kernel running.
    pub comm_busy: f64,
}

/// Governor state for one GPU.
#[derive(Debug, Clone)]
pub struct DvfsGovernor {
    gpu: GpuSpec,
    /// Current engine clock, MHz.
    pub freq_mhz: f64,
    /// Current memory clock, MHz.
    pub mem_freq_mhz: f64,
    /// Window length, ns.
    pub window_ns: f64,
    /// Extra HBM power noise sigma (W) injected by allocator behaviour.
    pub hbm_noise_w: f64,
    /// Required margin = margin_k * observed power sigma.
    margin_k: f64,
    power_ema: Ema,
    power_var_ema: Ema,
    last_power_w: f64,
    rng: Rng,
}

impl DvfsGovernor {
    pub fn new(gpu: GpuSpec, seed: u64, gpu_idx: u32, hbm_noise_w: f64) -> Self {
        Self {
            freq_mhz: gpu.freq_peak_mhz * 0.85,
            mem_freq_mhz: gpu.mem_freq_peak_mhz * 0.9,
            window_ns: 1_000_000.0, // 1 ms governor tick
            hbm_noise_w,
            margin_k: 0.3,
            power_ema: Ema::new(0.2),
            power_var_ema: Ema::new(0.1),
            last_power_w: gpu.idle_power_w,
            rng: Rng::substream(seed, &format!("dvfs{gpu_idx}")),
            gpu,
        }
    }

    /// Package power at frequency `f` for the given activity.
    ///
    /// The coefficients make a fully-busy MFMA workload *power-limited* at
    /// peak clock (≈775 W > the 750 W cap) — the regime the MI300X actually
    /// operates in during GEMM-heavy training, and the precondition for
    /// DVFS to matter at all (Insight 8).
    fn power_at(&self, f_mhz: f64, act: &WindowActivity, noise_w: f64) -> f64 {
        let g = &self.gpu;
        let fr = f_mhz / g.freq_peak_mhz;
        // Dynamic power ~ f^2.2 (voltage scales with f); split into MFMA
        // (dominant), generic compute, and comm-engine terms.
        let mfma_w = 760.0 * act.compute_busy * act.mfma_util;
        let valu_w = 150.0 * act.compute_busy * (1.0 - act.mfma_util);
        let comm_w = 40.0 * act.comm_busy;
        let hbm_rate = act.hbm_bytes / (self.window_ns * 1e-9) / g.hbm_bw;
        let hbm_w = 200.0 * hbm_rate.min(1.2);
        g.idle_power_w + (mfma_w + valu_w) * fr.powf(2.2) + comm_w + hbm_w + noise_w
    }

    /// Advance one window: observe activity, update the power telemetry,
    /// pick the next window's frequency. Returns (power_w, freq_mhz).
    ///
    /// Firmware behaviour modelled: cap *violations* cause an immediate
    /// hard throttle; recovery is slow (small up-slew) and aims below the
    /// cap by a margin proportional to the observed power variability. A
    /// noisy power signal therefore costs frequency twice — via frequent
    /// throttles and via the bigger margin — while contributing extra
    /// power itself, which keeps the *average* power of noisy and quiet
    /// workloads nearly identical (Observation 6).
    pub fn step(&mut self, act: &WindowActivity) -> (f64, f64) {
        // Allocator-driven HBM power noise: bursty page touches mostly
        // *shift* HBM power between windows (the pages get touched either
        // way), with a smaller genuinely-extra component (fresh-page
        // writes). Only manifests while the GPU is actually moving memory.
        let busy = act.compute_busy.max(act.comm_busy);
        let n = self.rng.normal(0.0, self.hbm_noise_w) * busy;
        let noise = n + 1.5 * n.abs();
        // The in-window fast regulator bounds transient overshoot to ~10%
        // above the cap (the slow per-window loop below handles the rest).
        let power = self
            .power_at(self.freq_mhz, act, noise)
            .clamp(self.gpu.idle_power_w, self.gpu.power_cap_w * 1.10);
        self.last_power_w = power;

        // Telemetry: EMA of power and of squared deviation (variance).
        let mean = self.power_ema.update(power);
        let dev = power - mean;
        let var = self.power_var_ema.update(dev * dev);
        let sigma = var.sqrt();

        if power > self.gpu.power_cap_w {
            // Hard throttle on a cap violation.
            self.freq_mhz = (self.freq_mhz - 250.0).max(self.gpu.freq_min_mhz);
        } else {
            // Climb toward the highest frequency whose predicted power
            // fits under cap minus the variability margin. Recovery slew
            // is slow (firmware does not jump the full range at once).
            let margin = self.margin_k * sigma;
            let budget = self.gpu.power_cap_w - margin;
            // Closed-form inversion of power_at: dynamic = dyn_w * fr^2.2,
            // so the highest admissible ratio is ((budget-static)/dyn)^(1/2.2);
            // snap down to the 50 MHz grid the firmware uses.
            let dyn_w = 760.0 * act.compute_busy * act.mfma_util
                + 150.0 * act.compute_busy * (1.0 - act.mfma_util);
            // power_at(0) = idle + comm + hbm (the fr^2.2 term vanishes).
            let static_w = self.power_at(0.0, act, 0.0);
            let headroom = budget - static_w;
            let mut target = if dyn_w <= 1e-9 {
                self.gpu.freq_peak_mhz
            } else if headroom <= 0.0 {
                self.gpu.freq_min_mhz
            } else {
                let fr = (headroom / dyn_w).powf(1.0 / 2.2);
                let f = fr * self.gpu.freq_peak_mhz;
                (f / 50.0).floor() * 50.0
            };
            target = target.clamp(self.gpu.freq_min_mhz, self.gpu.freq_peak_mhz);
            // Idle windows drift toward a mid clock (no demand).
            if busy < 0.05 {
                target = self.gpu.freq_peak_mhz * 0.6;
            }
            let delta = (target - self.freq_mhz).clamp(-250.0, 150.0);
            self.freq_mhz = (self.freq_mhz + delta)
                .clamp(self.gpu.freq_min_mhz, self.gpu.freq_peak_mhz);
        }
        // Memory clock tracks the engine clock's headroom situation.
        let mem_target = self.gpu.mem_freq_peak_mhz
            * (0.72 + 0.28 * (self.freq_mhz / self.gpu.freq_peak_mhz));
        self.mem_freq_mhz += (mem_target - self.mem_freq_mhz) * 0.5;
        (power, self.freq_mhz)
    }

    pub fn freq_ratio(&self) -> f64 {
        self.freq_mhz / self.gpu.freq_peak_mhz
    }

    pub fn mem_freq_ratio(&self) -> f64 {
        self.mem_freq_mhz / self.gpu.mem_freq_peak_mhz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy_window() -> WindowActivity {
        WindowActivity {
            compute_busy: 0.95,
            mfma_util: 0.6,
            hbm_bytes: 3.5e9, // 3.5 GB per ms ~ 3.5 TB/s
            comm_busy: 0.3,
        }
    }

    fn run(noise_w: f64, windows: u32) -> (f64, f64) {
        let mut g = DvfsGovernor::new(GpuSpec::mi300x(), 42, 0, noise_w);
        let mut freq_sum = 0.0;
        let mut power_sum = 0.0;
        let act = busy_window();
        for _ in 0..windows {
            let (p, f) = g.step(&act);
            power_sum += p;
            freq_sum += f;
        }
        (power_sum / windows as f64, freq_sum / windows as f64)
    }

    #[test]
    fn noisy_power_lowers_sustained_frequency() {
        // Observation 6: v1 (noisy) runs ~20-25% below v2 (quiet) at
        // nearly the same average power.
        let (p_quiet, f_quiet) = run(4.0, 400);
        let (p_noisy, f_noisy) = run(150.0, 400);
        assert!(
            f_noisy < f_quiet * 0.88,
            "noisy {f_noisy:.0} MHz vs quiet {f_quiet:.0} MHz"
        );
        // Average power roughly equal (within 12%).
        let rel = (p_noisy - p_quiet).abs() / p_quiet;
        assert!(rel < 0.12, "power mismatch {rel}");
    }

    #[test]
    fn power_never_exceeds_cap_by_much() {
        let mut g = DvfsGovernor::new(GpuSpec::mi300x(), 7, 1, 40.0);
        let act = busy_window();
        for _ in 0..500 {
            let (p, _) = g.step(&act);
            assert!(p < g.gpu.power_cap_w * 1.15, "power {p}");
        }
    }

    #[test]
    fn frequency_stays_in_range() {
        let mut g = DvfsGovernor::new(GpuSpec::mi300x(), 9, 2, 80.0);
        for i in 0..300 {
            let act = if i % 3 == 0 {
                WindowActivity::default()
            } else {
                busy_window()
            };
            g.step(&act);
            assert!(g.freq_mhz >= g.gpu.freq_min_mhz - 1.0);
            assert!(g.freq_mhz <= g.gpu.freq_peak_mhz + 1.0);
        }
    }

    #[test]
    fn quiet_workload_reaches_high_clocks() {
        let (_, f) = run(2.0, 400);
        assert!(f > GpuSpec::mi300x().freq_peak_mhz * 0.8, "freq {f}");
    }

    #[test]
    fn deterministic_for_same_seed() {
        let a = run(30.0, 100);
        let b = run(30.0, 100);
        assert_eq!(a, b);
    }
}
