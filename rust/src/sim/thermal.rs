//! Thermal coupling: per-GPU RC thermal state, throttle feedback into the
//! governor loop, and the proactive [`ThermalAware`] policy.
//!
//! The power subsystem (PR 5) made clocks a function of instantaneous
//! power alone; "Characterizing the Efficiency of Distributed Training: A
//! Power, Performance, and Thermal Perspective" (PAPERS.md) shows
//! *temperature* is the hidden state that actually drives sustained
//! throttling. This module adds that state:
//!
//! - [`ThermalState`] — a first-order RC model per GPU: die and HBM
//!   temperatures relax exponentially toward `ambient + R × cool_eff × P`
//!   with time constant `tau` (`T += (T_ss − T)(1 − e^{−dt/τ})`), stepped
//!   once per governor window from the window's package power.
//! - [`ThermallyCoupled`] — a decorator over any [`GovernorPolicy`]: the
//!   temperature maps to a throttle factor (linear ramp from 1.0 at
//!   `throttle_c` down to `floor` at `limit_c`) that derates the clocks
//!   the decorated policy exposes and rescales its window power by the
//!   f^2.2 voltage-frequency law. The engine keeps consuming clocks
//!   through the same trait accessors, so thermal feedback costs nothing
//!   in the hot loop and *nothing at all* when disabled.
//! - [`ThermalAware`] — the fifth governor: a reactive core whose power
//!   cap is pre-derated to the steady-state budget that keeps the die
//!   below `throttle_c − guard` at this GPU's cooling efficiency —
//!   proactively trading clocks for temperature headroom instead of
//!   oscillating against the throttle ramp.
//!
//! Determinism contract (DESIGN.md §3/§9/§13): per-GPU cooling-efficiency
//! variation is drawn from `Rng::substream(seed, "therm<logical rank>")` —
//! a dedicated channel, never the engine's jitter streams — so enabling
//! thermal perturbs no existing draw and thermal-disabled runs stay
//! byte-identical to the pre-thermal pipeline. Under replica folding a hot
//! node is replica-asymmetric, so the engine folds a per-class *envelope*:
//! each representative rank carries the worst (hottest) cooling efficiency
//! across the logical siblings it stands for, re-derived from the same
//! fresh substreams the expanded run would use (DESIGN.md §14).

use std::fmt;

use crate::config::parse::{num_label, parse_kv, reject_leftovers, split_kind, take};
use crate::config::GpuSpec;
use crate::sim::power::{
    GovCtx, GovernorKind, GovernorPolicy, Reactive, WindowActivity, FREQ_POWER_EXP,
};
use crate::util::prng::Rng;

/// The grammar noun thermal specs pass to the shared spec parser
/// (`config::parse`) — errors read `bad thermal spec …`.
const WHAT: &str = "thermal spec";

/// Headroom (°C) the [`ThermalAware`] policy keeps below the throttle
/// onset when deriving its steady-state power budget.
pub const THERMAL_GUARD_C: f64 = 5.0;

/// HBM time constant multiplier: the stack has more thermal mass than the
/// die, so it heats and cools slower.
const HBM_TAU_MULT: f64 = 1.6;

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Thermal-model knobs. `None` at the engine level (the default) disables
/// the subsystem entirely — no substream draws, no decorator, no columns.
///
/// CLI grammar (campaign/whatif `--thermal`, sugar `--ambient`):
///
/// ```text
/// axis := spec (";" spec)*
/// spec := "none" | "thermal" | "thermal" "(" key "=" value ("," key "=" value)* ")"
/// keys := ambient | tau | r | throttle | limit | floor | sigma | skew | hbm
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ThermalConfig {
    /// Inlet/ambient temperature, °C.
    pub ambient_c: f64,
    /// Die RC time constant, seconds (HBM uses `tau × 1.6`).
    pub tau_s: f64,
    /// Junction-to-ambient thermal resistance, °C per watt.
    pub r_c_per_w: f64,
    /// Throttle onset: die/HBM temperature at which clocks start derating.
    pub throttle_c: f64,
    /// Hard limit: temperature at which the throttle ramp bottoms out.
    pub limit_c: f64,
    /// Throttle floor — the clock fraction held at/above `limit_c`.
    pub floor: f64,
    /// Per-GPU cooling-efficiency sigma (multiplier on `r_c_per_w`,
    /// drawn from the `"therm<rank>"` substream).
    pub cool_sigma: f64,
    /// Deterministic per-node hot-aisle gradient: the last logical node
    /// runs `1 + skew` × the thermal resistance of the first.
    pub node_skew: f64,
    /// Fraction of package power the HBM steady-state rise sees.
    pub hbm_frac: f64,
}

impl Default for ThermalConfig {
    fn default() -> Self {
        Self {
            ambient_c: 35.0,
            tau_s: 2.0,
            r_c_per_w: 0.08,
            throttle_c: 90.0,
            limit_c: 105.0,
            floor: 0.5,
            cool_sigma: 0.05,
            node_skew: 0.0,
            hbm_frac: 0.6,
        }
    }
}

impl ThermalConfig {
    /// Compact filesystem-safe label (scenario-name tag material):
    /// ambient always, non-default tau/throttle when present —
    /// `a35`, `a85_t0_05`, `a45_th80`.
    pub fn label(&self) -> String {
        let d = ThermalConfig::default();
        let mut s = format!("a{}", num_label(self.ambient_c));
        if self.tau_s != d.tau_s {
            s.push_str(&format!("_t{}", num_label(self.tau_s)));
        }
        if self.throttle_c != d.throttle_c {
            s.push_str(&format!("_th{}", num_label(self.throttle_c)));
        }
        s
    }

    /// Steady-state power budget (W) that holds the die at `target_c`
    /// under cooling efficiency `cool_eff` — the closed-form inversion of
    /// the RC steady state `T_ss = ambient + R × cool_eff × P`.
    pub fn power_budget_w(&self, target_c: f64, cool_eff: f64) -> f64 {
        let r = self.r_c_per_w * cool_eff;
        if r <= 0.0 {
            return f64::INFINITY;
        }
        ((target_c - self.ambient_c) / r).max(0.0)
    }
}

impl fmt::Display for ThermalConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.label())
    }
}

/// Per-GPU cooling efficiency (a multiplier on thermal resistance; > 1 ⇒
/// worse cooling ⇒ hotter at the same power) for the GPU standing at
/// `logical_rank`: a seeded lognormal-ish draw from the dedicated
/// `"therm<logical rank>"` substream composed with the deterministic
/// per-node hot-aisle gradient. Pure function of `(cfg, seed, identity)` —
/// the folded envelope re-derives it for ranks the engine never simulates.
pub fn cool_eff(
    cfg: &ThermalConfig,
    seed: u64,
    logical_rank: u32,
    logical_node: u32,
    logical_nodes: u32,
) -> f64 {
    let mut rng = Rng::substream(seed, &format!("therm{logical_rank}"));
    let jitter = 1.0 + cfg.cool_sigma * rng.gauss();
    let grad = if logical_nodes > 1 {
        1.0 + cfg.node_skew * logical_node as f64 / (logical_nodes - 1) as f64
    } else {
        1.0
    };
    (jitter * grad).clamp(0.5, 2.0)
}

/// What one rank's governor needs to run thermally coupled: the shared
/// config plus this rank's resolved cooling efficiency (fold envelope
/// already applied by the engine).
#[derive(Debug, Clone, PartialEq)]
pub struct ThermalCtx {
    pub cfg: ThermalConfig,
    pub cool_eff: f64,
}

// ---------------------------------------------------------------------------
// RC state + throttle ramp
// ---------------------------------------------------------------------------

/// First-order RC thermal state of one GPU: die and HBM temperatures, °C.
#[derive(Debug, Clone, PartialEq)]
pub struct ThermalState {
    pub die_c: f64,
    pub hbm_c: f64,
}

impl ThermalState {
    /// Both domains start at ambient (cold plate, idle machine).
    pub fn new(ambient_c: f64) -> Self {
        Self {
            die_c: ambient_c,
            hbm_c: ambient_c,
        }
    }

    /// Integrate one window of package power: each domain relaxes toward
    /// its steady state `ambient + R × cool_eff × P` (HBM sees
    /// `hbm_frac × P` and a 1.6× slower time constant) by the exact
    /// exponential step `T += (T_ss − T)(1 − e^{−dt/τ})`.
    pub fn step(&mut self, cfg: &ThermalConfig, cool_eff: f64, power_w: f64, dt_s: f64) {
        let r = cfg.r_c_per_w * cool_eff;
        let die_ss = cfg.ambient_c + r * power_w;
        let a_die = 1.0 - (-dt_s / cfg.tau_s).exp();
        self.die_c += (die_ss - self.die_c) * a_die;
        let hbm_ss = cfg.ambient_c + r * power_w * cfg.hbm_frac;
        let a_hbm = 1.0 - (-dt_s / (cfg.tau_s * HBM_TAU_MULT)).exp();
        self.hbm_c += (hbm_ss - self.hbm_c) * a_hbm;
    }
}

/// Clock fraction the firmware allows at `temp_c`: 1.0 below the throttle
/// onset, a linear ramp down to `floor` at the hard limit, `floor` beyond.
pub fn throttle_factor(cfg: &ThermalConfig, temp_c: f64) -> f64 {
    if temp_c <= cfg.throttle_c {
        1.0
    } else if temp_c >= cfg.limit_c {
        cfg.floor
    } else {
        let span = (cfg.limit_c - cfg.throttle_c).max(1e-9);
        1.0 - (1.0 - cfg.floor) * (temp_c - cfg.throttle_c) / span
    }
}

// ---------------------------------------------------------------------------
// ThermallyCoupled — the decorator every policy runs under when enabled
// ---------------------------------------------------------------------------

/// Wraps any [`GovernorPolicy`] with the thermal feedback loop. Each
/// window: the throttle factor that was in effect *during* the elapsed
/// window derates the inner policy's clock and rescales its package power
/// by the f^2.2 voltage-frequency law (never below idle; the inner
/// policy's RNG stream is untouched); the effective power integrates the
/// RC state; the updated die/HBM temperatures set the throttle the
/// accessors expose for the *next* window. The engine needs no new calls —
/// it already consumes clocks only through the trait accessors.
#[derive(Debug)]
pub struct ThermallyCoupled {
    inner: Box<dyn GovernorPolicy>,
    cfg: ThermalConfig,
    cool_eff: f64,
    state: ThermalState,
    idle_w: f64,
    window_s: f64,
    /// Engine-clock throttle for the next window (what accessors expose).
    throttle: f64,
    /// Memory-clock throttle (driven by the HBM temperature).
    mem_throttle: f64,
    /// Throttle that governed the window most recently stepped — what
    /// [`GovernorPolicy::thermal_sample`] reports, so trace-derived
    /// throttle loss matches the integration exactly.
    applied: f64,
    energy_j: f64,
    throttle_loss_ns: f64,
}

impl ThermallyCoupled {
    pub fn new(inner: Box<dyn GovernorPolicy>, tc: &ThermalCtx, ctx: &GovCtx<'_>) -> Self {
        Self {
            inner,
            cfg: tc.cfg.clone(),
            cool_eff: tc.cool_eff,
            state: ThermalState::new(tc.cfg.ambient_c),
            idle_w: ctx.gpu.idle_power_w,
            window_s: ctx.window_ns * 1e-9,
            throttle: 1.0,
            mem_throttle: 1.0,
            applied: 1.0,
            energy_j: 0.0,
            throttle_loss_ns: 0.0,
        }
    }

    /// Current RC state (tests, figures).
    pub fn state(&self) -> &ThermalState {
        &self.state
    }

    /// Nanoseconds of clock capacity lost to throttling so far:
    /// `Σ window × (1 − throttle applied)`.
    pub fn throttle_loss_ns(&self) -> f64 {
        self.throttle_loss_ns
    }
}

impl GovernorPolicy for ThermallyCoupled {
    fn step(&mut self, act: &WindowActivity) -> (f64, f64) {
        let (p_raw, _f_raw) = self.inner.step(act);
        // The factor that actually governed the elapsed window is the one
        // the accessors exposed while it ran — i.e. the previous step's.
        let th = self.throttle;
        self.applied = th;
        let scale = th.powf(FREQ_POWER_EXP);
        let p_eff = (self.idle_w + (p_raw - self.idle_w) * scale).max(self.idle_w);
        self.energy_j += p_eff * self.window_s;
        self.throttle_loss_ns += self.window_s * 1e9 * (1.0 - th);
        self.state.step(&self.cfg, self.cool_eff, p_eff, self.window_s);
        self.throttle = throttle_factor(&self.cfg, self.state.die_c);
        self.mem_throttle = throttle_factor(&self.cfg, self.state.hbm_c);
        (p_eff, self.inner.freq_mhz() * self.throttle)
    }

    fn freq_mhz(&self) -> f64 {
        self.inner.freq_mhz() * self.throttle
    }

    fn mem_freq_mhz(&self) -> f64 {
        self.inner.mem_freq_mhz() * self.mem_throttle
    }

    fn freq_ratio(&self) -> f64 {
        self.inner.freq_ratio() * self.throttle
    }

    fn mem_freq_ratio(&self) -> f64 {
        self.inner.mem_freq_ratio() * self.mem_throttle
    }

    fn energy_j(&self) -> f64 {
        self.energy_j
    }

    fn kind(&self) -> GovernorKind {
        self.inner.kind()
    }

    fn thermal_sample(&self) -> Option<(f64, f64)> {
        Some((self.state.die_c, self.applied))
    }
}

// ---------------------------------------------------------------------------
// ThermalAware — the fifth governor
// ---------------------------------------------------------------------------

/// Proactive thermal management: a reactive core whose power cap is
/// pre-derated to the steady-state budget that keeps this GPU's die at
/// `throttle_c − guard` given its cooling efficiency — it *spends* clocks
/// up front to buy temperature headroom, instead of running hot and
/// oscillating against the reactive throttle ramp. With thermal disabled
/// there is no temperature to manage and it degenerates to [`Reactive`]
/// exactly (same substream, same margin, same cap).
#[derive(Debug)]
pub struct ThermalAware {
    inner: Reactive,
}

impl ThermalAware {
    pub fn build(ctx: &GovCtx<'_>) -> Box<dyn GovernorPolicy> {
        match ctx.thermal.clone() {
            None => Box::new(ThermalAware {
                inner: Reactive::new(ctx),
            }),
            Some(tc) => {
                let target_c = tc.cfg.throttle_c - THERMAL_GUARD_C;
                let budget = tc
                    .cfg
                    .power_budget_w(target_c, tc.cool_eff)
                    // A hostile config (ambient above the throttle line)
                    // must not zero the cap — idle survives regardless.
                    .max(ctx.gpu.idle_power_w * 1.05);
                let mut derated: GpuSpec = ctx.gpu.clone();
                derated.power_cap_w = derated.power_cap_w.min(budget);
                let dctx = GovCtx {
                    gpu: &derated,
                    seed: ctx.seed,
                    gpu_idx: ctx.gpu_idx,
                    hbm_noise_w: ctx.hbm_noise_w,
                    window_ns: ctx.window_ns,
                    margin_k: ctx.margin_k,
                    fixed_cap_ratio: ctx.fixed_cap_ratio,
                    spike_var: ctx.spike_var,
                    thermal: ctx.thermal.clone(),
                };
                let core = ThermalAware {
                    inner: Reactive::new(&dctx),
                };
                Box::new(ThermallyCoupled::new(Box::new(core), &tc, ctx))
            }
        }
    }
}

impl GovernorPolicy for ThermalAware {
    fn step(&mut self, act: &WindowActivity) -> (f64, f64) {
        self.inner.step(act)
    }

    fn freq_mhz(&self) -> f64 {
        self.inner.freq_mhz()
    }

    fn mem_freq_mhz(&self) -> f64 {
        self.inner.mem_freq_mhz()
    }

    fn freq_ratio(&self) -> f64 {
        self.inner.freq_ratio()
    }

    fn mem_freq_ratio(&self) -> f64 {
        self.inner.mem_freq_ratio()
    }

    fn energy_j(&self) -> f64 {
        self.inner.energy_j()
    }

    fn kind(&self) -> GovernorKind {
        GovernorKind::ThermalAware
    }
}

// ---------------------------------------------------------------------------
// Spec grammar (shared tokenizer in config::parse)
// ---------------------------------------------------------------------------

/// Parse one thermal spec: `none`, `thermal`, or `thermal(key=value,…)`.
pub fn parse_thermal(s: &str) -> Result<Option<ThermalConfig>, String> {
    let s = s.trim();
    if s.is_empty() || s == "none" {
        return Ok(None);
    }
    let (kind, body) = split_kind(s, WHAT)?;
    match kind {
        "thermal" | "therm" => {}
        other => {
            return Err(format!(
                "unknown thermal spec `{other}` (have: none, thermal)"
            ))
        }
    }
    let mut kvs = parse_kv(body, s, WHAT)?;
    let mut cfg = ThermalConfig::default();
    if let Some(v) = take(&mut kvs, "ambient") {
        cfg.ambient_c = v;
    }
    if let Some(v) = take(&mut kvs, "tau") {
        cfg.tau_s = v;
    }
    if let Some(v) = take(&mut kvs, "r") {
        cfg.r_c_per_w = v;
    }
    if let Some(v) = take(&mut kvs, "throttle") {
        cfg.throttle_c = v;
    }
    if let Some(v) = take(&mut kvs, "limit") {
        cfg.limit_c = v;
    }
    if let Some(v) = take(&mut kvs, "floor") {
        cfg.floor = v;
    }
    if let Some(v) = take(&mut kvs, "sigma") {
        cfg.cool_sigma = v;
    }
    if let Some(v) = take(&mut kvs, "skew") {
        cfg.node_skew = v;
    }
    if let Some(v) = take(&mut kvs, "hbm") {
        cfg.hbm_frac = v;
    }
    reject_leftovers(
        &kvs,
        s,
        WHAT,
        &[
            "ambient", "tau", "r", "throttle", "limit", "floor", "sigma", "skew", "hbm",
        ],
    )?;
    for (key, v, ok) in [
        ("ambient", cfg.ambient_c, cfg.ambient_c.is_finite()),
        ("tau", cfg.tau_s, cfg.tau_s > 0.0 && cfg.tau_s.is_finite()),
        (
            "r",
            cfg.r_c_per_w,
            cfg.r_c_per_w > 0.0 && cfg.r_c_per_w.is_finite(),
        ),
        (
            "floor",
            cfg.floor,
            cfg.floor > 0.0 && cfg.floor <= 1.0,
        ),
        (
            "sigma",
            cfg.cool_sigma,
            cfg.cool_sigma >= 0.0 && cfg.cool_sigma <= 0.5,
        ),
        (
            "skew",
            cfg.node_skew,
            cfg.node_skew >= 0.0 && cfg.node_skew <= 1.0,
        ),
        (
            "hbm",
            cfg.hbm_frac,
            cfg.hbm_frac > 0.0 && cfg.hbm_frac <= 1.0,
        ),
    ] {
        if !ok {
            return Err(format!("bad value `{v}` for `{key}` in `{s}` (out of range)"));
        }
    }
    if !(cfg.throttle_c < cfg.limit_c) {
        return Err(format!(
            "bad value `{}` for `throttle` in `{s}` (want throttle < limit)",
            cfg.throttle_c
        ));
    }
    Ok(Some(cfg))
}

/// Parse a `;`-separated thermal axis — the campaign `--thermal` flag.
/// `none;thermal(ambient=85)` sweeps disabled vs a hot datacenter.
pub fn parse_list_thermal(s: &str) -> Result<Vec<Option<ThermalConfig>>, String> {
    let out: Vec<Option<ThermalConfig>> = s
        .split(';')
        .filter(|t| !t.trim().is_empty())
        .map(parse_thermal)
        .collect::<Result<_, _>>()?;
    if out.is_empty() {
        return Err(format!("empty thermal list `{s}` (use `none`)"));
    }
    Ok(out)
}

/// Parse the `--ambient` sugar: a `;`-separated list of ambient
/// temperatures, each expanding to a default thermal config at that
/// ambient (`45;85` ≡ `thermal(ambient=45);thermal(ambient=85)`).
pub fn parse_list_ambient(s: &str) -> Result<Vec<Option<ThermalConfig>>, String> {
    let out: Vec<Option<ThermalConfig>> = s
        .split(';')
        .filter(|t| !t.trim().is_empty())
        .map(|t| {
            let t = t.trim();
            if t == "none" {
                return Ok(None);
            }
            let v: f64 = t
                .parse()
                .map_err(|_| format!("bad ambient `{t}` (want °C or `none`)"))?;
            parse_thermal(&format!("thermal(ambient={v})"))
        })
        .collect::<Result<_, _>>()?;
    if out.is_empty() {
        return Err(format!("empty ambient list `{s}` (use `none`)"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hot_cfg() -> ThermalConfig {
        // Low headroom + fast tau so a handful of 1 ms windows throttles.
        ThermalConfig {
            ambient_c: 85.0,
            tau_s: 0.005,
            ..ThermalConfig::default()
        }
    }

    #[test]
    fn state_relaxes_exactly_exponentially() {
        let cfg = ThermalConfig::default();
        let mut st = ThermalState::new(cfg.ambient_c);
        // Constant 500 W for 1 τ in 1 ms steps ⇒ 1 − e⁻¹ of the rise.
        let steps = (cfg.tau_s / 1e-3) as usize;
        for _ in 0..steps {
            st.step(&cfg, 1.0, 500.0, 1e-3);
        }
        let rise = cfg.r_c_per_w * 500.0;
        let want = cfg.ambient_c + rise * (1.0 - (-1.0f64).exp());
        assert!((st.die_c - want).abs() < 0.05, "{} vs {want}", st.die_c);
        assert!(st.hbm_c < st.die_c, "HBM sees a fraction of the power");
    }

    #[test]
    fn zero_load_decays_to_ambient() {
        let cfg = hot_cfg();
        let mut st = ThermalState::new(cfg.ambient_c);
        st.die_c = 104.0;
        st.hbm_c = 100.0;
        for _ in 0..10_000 {
            st.step(&cfg, 1.0, 0.0, 1e-3);
        }
        assert!((st.die_c - cfg.ambient_c).abs() < 1e-6);
        assert!((st.hbm_c - cfg.ambient_c).abs() < 1e-6);
    }

    #[test]
    fn throttle_ramp_is_linear_and_clamped() {
        let cfg = ThermalConfig::default();
        assert_eq!(throttle_factor(&cfg, 20.0), 1.0);
        assert_eq!(throttle_factor(&cfg, cfg.throttle_c), 1.0);
        let mid = (cfg.throttle_c + cfg.limit_c) / 2.0;
        let want = 1.0 - (1.0 - cfg.floor) * 0.5;
        assert!((throttle_factor(&cfg, mid) - want).abs() < 1e-12);
        assert_eq!(throttle_factor(&cfg, cfg.limit_c + 40.0), cfg.floor);
    }

    #[test]
    fn cool_eff_is_seeded_and_skewed() {
        let cfg = ThermalConfig {
            node_skew: 0.1,
            ..ThermalConfig::default()
        };
        let a = cool_eff(&cfg, 42, 7, 0, 4);
        assert_eq!(a, cool_eff(&cfg, 42, 7, 0, 4), "not deterministic");
        assert_ne!(a, cool_eff(&cfg, 42, 8, 0, 4), "substream not per-rank");
        // Same draw, hotter aisle: the gradient strictly raises resistance.
        assert!(cool_eff(&cfg, 42, 7, 3, 4) > a);
        for lr in 0..64 {
            let e = cool_eff(&cfg, 42, lr, 0, 4);
            assert!((0.5..=2.0).contains(&e));
        }
    }

    #[test]
    fn spec_grammar_parses_and_rejects() {
        assert_eq!(parse_thermal("none").unwrap(), None);
        assert_eq!(
            parse_thermal("thermal").unwrap(),
            Some(ThermalConfig::default())
        );
        let c = parse_thermal("thermal(ambient=85,tau=0.05)").unwrap().unwrap();
        assert_eq!(c.ambient_c, 85.0);
        assert_eq!(c.tau_s, 0.05);
        let e = parse_thermal("thermal(tau=-1)").unwrap_err();
        assert!(e.contains("tau"), "{e}");
        let e = parse_thermal("thermal(watts=5)").unwrap_err();
        assert!(e.contains("watts") && e.contains("thermal spec"), "{e}");
        let e = parse_thermal("fusion(ambient=1)").unwrap_err();
        assert!(e.contains("fusion"), "{e}");
        assert!(parse_thermal("thermal(ambient=85").is_err());
        assert!(parse_thermal("thermal(throttle=110,limit=105)").is_err());
        let axis = parse_list_thermal("none;thermal(ambient=85)").unwrap();
        assert_eq!(axis.len(), 2);
        assert!(axis[0].is_none() && axis[1].is_some());
        assert!(parse_list_thermal(";").is_err());
        let sugar = parse_list_ambient("none;45;85").unwrap();
        assert_eq!(sugar.len(), 3);
        assert_eq!(sugar[1].as_ref().unwrap().ambient_c, 45.0);
        assert!(parse_list_ambient("warm").is_err());
    }

    #[test]
    fn labels_are_compact_and_filesystem_safe() {
        assert_eq!(ThermalConfig::default().label(), "a35");
        let c = parse_thermal("thermal(ambient=85,tau=0.05)").unwrap().unwrap();
        assert_eq!(c.label(), "a85_t0_05");
        for ch in c.label().chars() {
            assert!(ch.is_ascii_alphanumeric() || ch == '_', "unsafe {ch}");
        }
    }

    #[test]
    fn power_budget_inverts_the_steady_state() {
        let cfg = ThermalConfig::default();
        let p = cfg.power_budget_w(cfg.throttle_c - THERMAL_GUARD_C, 1.0);
        // Running exactly the budget forever settles exactly at the target.
        let mut st = ThermalState::new(cfg.ambient_c);
        for _ in 0..200_000 {
            st.step(&cfg, 1.0, p, 1e-3);
        }
        assert!((st.die_c - (cfg.throttle_c - THERMAL_GUARD_C)).abs() < 1e-6);
    }
}
