//! Interconnect model: ring all-gather / reduce-scatter over the fully
//! connected Infinity Fabric mesh, plus the collective rendezvous state the
//! event loop tracks.
//!
//! RCCL semantics reproduced here (and why they matter to the paper):
//!  * a collective kernel starts on a rank's comm stream as soon as that
//!    rank dispatches it and the stream is free — it then *spins*, holding
//!    CUs, until every rank has arrived (this is the local occupancy that
//!    shows up as C3 overlap in traces);
//!  * the actual transfer begins at the last arrival and completes on all
//!    ranks at (approximately) the same time;
//!  * while the transfer is in flight it contends with compute for HBM
//!    bandwidth on every rank — and compute contends back, stretching the
//!    transfer (Insight 2's "median comm scales with compute time").

use crate::config::{NodeSpec, Topology};
use crate::fsdp::{CollectiveDesc, CommGroup};

/// Fixed RCCL launch/rendezvous cost per collective (ns).
pub const COLL_FIXED_NS: f64 = 15_000.0;

/// Base (uncontended) transfer time of a ring collective, ns.
pub fn collective_base_ns(node: &NodeSpec, bytes: f64) -> f64 {
    node.ring_collective_ns(bytes) + COLL_FIXED_NS
}

/// Inter-node phase of a world-scoped hierarchical collective, ns.
/// **Exactly zero at one node** — the degenerate-topology guarantee
/// (DESIGN.md §8) reduces [`hierarchical_collective_ns`] to
/// [`collective_base_ns`] bit for bit.
///
/// Model: a two-level ring. Level 1 is the intra-node ring over the xGMI
/// mesh (priced by [`collective_base_ns`]); level 2 runs G concurrent
/// cross-node rings — one per local GPU index, each over its own
/// rail-optimized NIC — moving each rank's `bytes / world` shard through
/// `N - 1` steps, plus a second rendezvous (each level synchronizes
/// independently in RCCL's hierarchical algorithms).
pub fn inter_node_phase_ns(topo: &Topology, bytes: f64) -> f64 {
    if topo.num_nodes <= 1 {
        return 0.0;
    }
    let n = topo.num_nodes as f64;
    let world = topo.world_size() as f64;
    let steps = n - 1.0;
    let chunk = bytes / world;
    let eff_bw = (topo.nic.nic_bw * topo.nic.eff).max(1.0);
    steps * (chunk / eff_bw * 1e9 + topo.nic.latency_ns) + COLL_FIXED_NS
}

/// Base (uncontended) time of a world-scoped collective over the whole
/// topology: intra-node ring + inter-node NIC phase.
pub fn hierarchical_collective_ns(topo: &Topology, bytes: f64) -> f64 {
    collective_base_ns(&topo.node, bytes) + inter_node_phase_ns(topo, bytes)
}

/// Base time of a cross-node ring all-reduce of one rank's `shard_bytes`
/// among its `num_nodes` same-local-index peers (HSDP gradient sync):
/// reduce-scatter + all-gather over the ring, `2(N-1)` steps of
/// `shard_bytes / N` each over the rank's NIC.
pub fn cross_node_allreduce_ns(topo: &Topology, shard_bytes: f64) -> f64 {
    let n = topo.num_nodes as f64;
    let steps = 2.0 * (n - 1.0).max(0.0);
    let chunk = shard_bytes / n.max(1.0);
    let eff_bw = (topo.nic.nic_bw * topo.nic.eff).max(1.0);
    steps * (chunk / eff_bw * 1e9 + topo.nic.latency_ns) + COLL_FIXED_NS
}

/// Base duration of a collective by its communication scope (the engine's
/// per-instance cost oracle).
pub fn group_collective_base_ns(topo: &Topology, group: CommGroup, bytes: f64) -> f64 {
    match group {
        CommGroup::World => hierarchical_collective_ns(topo, bytes),
        CommGroup::IntraNode => collective_base_ns(&topo.node, bytes),
        CommGroup::CrossNode => cross_node_allreduce_ns(topo, bytes),
    }
}

/// Lifecycle phase of one collective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollPhase {
    /// Not yet reached by any rank's comm stream.
    Pending,
    /// Some ranks have arrived and are spinning.
    Arriving,
    /// All ranks arrived; transfer in flight.
    Transfer,
    Done,
}

/// Rendezvous + fluid-progress state of one collective instance.
///
/// One *instance* spans one rendezvous group: the whole world for FSDP
/// collectives, one node's ranks for an HSDP intra-node collective, or
/// one cross-node shard group for an HSDP all-reduce. Per-rank arrays stay
/// world-sized (indexed by flat rank); `participants` defines who must
/// arrive before the transfer starts.
#[derive(Debug, Clone)]
pub struct CollState {
    pub desc: CollectiveDesc,
    pub phase: CollPhase,
    /// Flat ranks participating in this instance, ascending.
    pub participants: Vec<usize>,
    /// Local comm-stream occupancy start per rank (NaN = not arrived).
    pub local_start: Vec<f64>,
    pub arrived: u32,
    /// Host dispatch timestamp per rank.
    pub t_launch: Vec<f64>,
    /// Absolute time the rank's comm engine may begin (gate time + its
    /// static dispatch delay); NaN until the gate is first satisfied.
    pub ready_at: Vec<f64>,
    /// Remaining transfer work, expressed in seconds-at-base-rate.
    pub work_s: f64,
    /// Current progress rate (1.0 = base rate).
    pub rate: f64,
    pub last_update: f64,
    /// Generation counter to invalidate stale end events.
    pub gen: u64,
    pub end_time: f64,
    /// Compute kernels parked on this collective (rank ids).
    pub kernel_waiters: Vec<usize>,
    /// Hosts blocked on this collective (rank ids).
    pub host_waiters: Vec<usize>,
}

impl CollState {
    /// World-scoped instance: every rank `0..ranks` participates (the
    /// single-node / FSDP shape).
    pub fn new(desc: CollectiveDesc, ranks: usize, base_ns: f64) -> Self {
        Self::for_group(desc, (0..ranks).collect(), ranks, base_ns)
    }

    /// Instance over an explicit participant subset of a `world`-rank
    /// cluster (HSDP node-scoped / cross-node-scoped collectives).
    pub fn for_group(
        desc: CollectiveDesc,
        participants: Vec<usize>,
        world: usize,
        base_ns: f64,
    ) -> Self {
        debug_assert!(participants.iter().all(|&r| r < world));
        Self {
            desc,
            phase: CollPhase::Pending,
            participants,
            local_start: vec![f64::NAN; world],
            arrived: 0,
            t_launch: vec![f64::NAN; world],
            ready_at: vec![f64::NAN; world],
            work_s: base_ns * 1e-9,
            rate: 1.0,
            last_update: 0.0,
            gen: 0,
            end_time: f64::INFINITY,
            kernel_waiters: Vec::new(),
            host_waiters: Vec::new(),
        }
    }

    /// Record a rank's arrival. Returns true when this was the last
    /// participant (transfer may begin).
    pub fn arrive(&mut self, rank: usize, t: f64) -> bool {
        debug_assert!(self.local_start[rank].is_nan(), "double arrival");
        self.local_start[rank] = t;
        self.arrived += 1;
        self.phase = CollPhase::Arriving;
        if self.arrived as usize == self.participants.len() {
            self.phase = CollPhase::Transfer;
            self.last_update = t;
            true
        } else {
            false
        }
    }

    /// Advance fluid progress to `now` and return whether work remains.
    pub fn advance(&mut self, now: f64) {
        if self.phase != CollPhase::Transfer {
            return;
        }
        let dt = (now - self.last_update).max(0.0) * 1e-9;
        self.work_s = (self.work_s - dt * self.rate).max(0.0);
        self.last_update = now;
    }

    /// Time at which the transfer finishes at the current rate.
    pub fn projected_end(&self) -> f64 {
        self.last_update + self.work_s / self.rate.max(1e-12) * 1e9
    }

    pub fn is_done(&self) -> bool {
        self.phase == CollPhase::Done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fsdp::CommScope;
    use crate::model::ops::{OpRef, OpType};

    fn desc() -> CollectiveDesc {
        CollectiveDesc {
            id: 0,
            op: OpRef::fwd(OpType::AllGather),
            scope: CommScope::Layer(0),
            group: CommGroup::World,
            iter: 0,
            bytes: 1e9,
            wait_seq: 0,
        }
    }

    #[test]
    fn base_duration_scales_with_bytes() {
        let node = NodeSpec::mi300x_node();
        let t1 = collective_base_ns(&node, 1e9);
        let t2 = collective_base_ns(&node, 4e9);
        assert!(t2 > t1 * 3.0 && t2 < t1 * 4.5);
    }

    #[test]
    fn rendezvous_completes_on_last_arrival() {
        let node = NodeSpec::mi300x_node();
        let mut c = CollState::new(desc(), 4, collective_base_ns(&node, 1e9));
        assert!(!c.arrive(0, 10.0));
        assert!(!c.arrive(2, 20.0));
        assert!(!c.arrive(3, 30.0));
        assert_eq!(c.phase, CollPhase::Arriving);
        assert!(c.arrive(1, 40.0));
        assert_eq!(c.phase, CollPhase::Transfer);
        assert_eq!(c.last_update, 40.0);
    }

    #[test]
    fn fluid_progress_halves_at_half_rate() {
        let node = NodeSpec::mi300x_node();
        let base = collective_base_ns(&node, 1e9);
        let mut c = CollState::new(desc(), 1, base);
        c.arrive(0, 0.0);
        // Full rate: projected end == base.
        assert!((c.projected_end() - base).abs() < 1.0);
        // Run half the work at rate 1, then drop to rate 0.5.
        c.advance(base / 2.0);
        c.rate = 0.5;
        let end = c.projected_end();
        assert!((end - (base / 2.0 + base)).abs() < 1.0, "end {end}");
    }

    #[test]
    fn hierarchical_degenerates_at_one_node() {
        use crate::config::Topology;
        let topo = Topology::single(NodeSpec::mi300x_node());
        for bytes in [1e6, 1e8, 4e9] {
            let flat = collective_base_ns(&topo.node, bytes);
            let hier = hierarchical_collective_ns(&topo, bytes);
            assert_eq!(flat.to_bits(), hier.to_bits(), "bytes {bytes}");
            assert_eq!(inter_node_phase_ns(&topo, bytes), 0.0);
        }
    }

    #[test]
    fn hierarchical_never_cheaper_than_intra() {
        use crate::config::Topology;
        for n in [2u32, 4, 8] {
            let topo = Topology::mi300x_cluster(n);
            for bytes in [1e6, 1e8, 4e9] {
                assert!(
                    hierarchical_collective_ns(&topo, bytes)
                        >= collective_base_ns(&topo.node, bytes),
                    "N{n} bytes {bytes}"
                );
            }
        }
    }

    #[test]
    fn group_costs_dispatch_by_scope() {
        use crate::config::Topology;
        let topo = Topology::mi300x_cluster(2);
        let b = 1e9;
        assert_eq!(
            group_collective_base_ns(&topo, CommGroup::World, b).to_bits(),
            hierarchical_collective_ns(&topo, b).to_bits()
        );
        assert_eq!(
            group_collective_base_ns(&topo, CommGroup::IntraNode, b).to_bits(),
            collective_base_ns(&topo.node, b).to_bits()
        );
        assert_eq!(
            group_collective_base_ns(&topo, CommGroup::CrossNode, b).to_bits(),
            cross_node_allreduce_ns(&topo, b).to_bits()
        );
    }

    #[test]
    fn subgroup_rendezvous_ignores_outsiders() {
        let node = NodeSpec::mi300x_node();
        // Ranks {1, 3} of a 4-rank world: the transfer starts when both
        // arrive, regardless of ranks 0/2.
        let mut c = CollState::for_group(
            desc(),
            vec![1, 3],
            4,
            collective_base_ns(&node, 1e9),
        );
        assert!(!c.arrive(1, 10.0));
        assert_eq!(c.phase, CollPhase::Arriving);
        assert!(c.arrive(3, 25.0));
        assert_eq!(c.phase, CollPhase::Transfer);
        assert_eq!(c.last_update, 25.0);
    }

    #[test]
    fn advance_is_monotone_and_clamps() {
        let node = NodeSpec::mi300x_node();
        let mut c = CollState::new(desc(), 1, collective_base_ns(&node, 1e6));
        c.arrive(0, 0.0);
        c.advance(1e12); // way past the end
        assert_eq!(c.work_s, 0.0);
        c.advance(0.0); // time going backwards must not panic or add work
        assert_eq!(c.work_s, 0.0);
    }
}
