//! The discrete-event engine: executes the per-rank FSDP dispatch program
//! on the simulated node and emits the runtime-profiling trace plus the
//! power and host-activity telemetry.
//!
//! Fluid-flow execution model: at most one compute kernel and one
//! collective are in flight per GPU (streams are FIFO, depth-1 execution);
//! their progress rates change when the DVFS governor retunes the clocks,
//! when a collective transfer starts/ends (C3 contention), or when a rank's
//! comm stream occupancy changes (RCCL spin kernels hold CUs). Every rate
//! change advances the in-flight work and reschedules the end event under a
//! fresh generation number; stale events are ignored.
//!
//! Hot-path design (campaigns multiply simulations per invocation, so the
//! per-event constant factor is the dominant wall-clock term):
//!  * termination is O(1) per event — outstanding-work counters
//!    (`hosts_unfinished`, `device_work`, `live_events`) replace the old
//!    full rank scan plus `heap.iter().any(..)` after every popped event;
//!  * kernel names are interned [`Sym`] handles (`util::intern`), so event
//!    emission allocates nothing;
//!  * kernel timings are precomputed per program item (the duration model
//!    is deterministic per descriptor), not re-derived per dispatch;
//!  * the tuple-keyed per-event maps (`fwd_ids`, `op_kernel_idx`) use the
//!    fast deterministic hasher (`util::hash`);
//!  * host-activity windows are dense per-rank vectors, not hash maps;
//!  * output vectors are pre-reserved from program shape.
//! `benches/engine_baseline.rs` keeps the pre-refactor loop verbatim;
//! `benches/engine_hot.rs` A/Bs the two and `tests/pipeline.rs` asserts
//! bitwise-identical event streams.

use std::collections::{BinaryHeap, VecDeque};
use std::sync::Arc;

use crate::config::{ModelConfig, NodeSpec, Topology, WorkloadConfig};
use crate::fsdp::{
    build_program_topo, simulate_gather_pattern, AllocStats, CommGroup,
    DispatchItem, HostSync, ProgKernel,
};
use crate::model::ops::OpType;
use crate::sim::duration::{DurationModel, KernelTiming};
use crate::sim::dvfs::WindowActivity;
use crate::sim::interconnect::{group_collective_base_ns, CollPhase, CollState};
use crate::sim::power::{GovCtx, GovernorKind, GovernorPolicy};
use crate::trace::event::{PowerSample, PowerTrace, Stream, Trace, TraceEvent};
use crate::trace::store::TraceSink;
use crate::util::hash::FxHashMap;
use crate::util::intern::{intern, Sym};
use crate::util::prng::Rng;

/// Tunable mechanism parameters (DESIGN.md §5). Defaults are calibrated so
/// the paper's qualitative results emerge; the ablation benches sweep them.
#[derive(Debug, Clone)]
pub struct EngineParams {
    /// Compute slowdown from a spinning RCCL kernel holding CUs.
    pub spin_penalty: f64,
    /// Extra compute slowdown while a transfer contends for HBM.
    pub transfer_penalty: f64,
    /// Transfer slowdown at 100% of ranks running compute.
    pub comm_stretch: f64,
    /// Per-rank static host-speed jitter (sigma, fraction).
    pub rank_jitter: f64,
    /// Per-rank static compute-speed jitter (sigma, fraction) — silicon /
    /// thermal heterogeneity. This is what makes ranks arrive at
    /// collectives at different times, so early ranks spin (long comm
    /// kernels) — the mechanism behind Insight 2's "median comm scales
    /// with compute" and Fig. 8's per-GPU overlap spread.
    pub compute_jitter: f64,
    /// Per-dispatch lognormal-ish jitter (sigma, fraction).
    pub dispatch_jitter: f64,
    /// Per-rank comm-stream dispatch delay (half-normal sigma, ns) —
    /// small doorbell-latency differences between GPUs.
    pub comm_delay_sigma_ns: f64,
    /// Extra comm dispatch delay of the one NUMA-far GPU (ns): in a
    /// two-socket chassis one GPU's doorbell path crosses the socket
    /// interconnect, so its collectives consistently arrive late — it
    /// sees minimal overlap while everyone else spins longer (Fig. 8's
    /// low-overlap GPU).
    pub far_rank_delay_ns: f64,
    /// HBM power noise floor (W) — FSDPv2's deterministic allocator.
    pub hbm_noise_quiet_w: f64,
    /// HBM power noise (W) per unit of allocator memory-spike variability
    /// (per-iteration peak σ normalized by the layer weight size) — the
    /// FSDPv1 non-determinism channel (Observation 6).
    pub hbm_noise_scale_w: f64,
    /// DVFS governor window (ns) — the single source of truth for both
    /// the engine's tick period and the policy's internal power model.
    pub dvfs_window_ns: f64,
    /// Governor margin coefficient: required power headroom =
    /// `margin_k` × observed power sigma (previously hard-coded 0.3
    /// inside the governor).
    pub margin_k: f64,
    /// Power-management policy (`sim::power`); `Reactive` is the stock
    /// governor and reproduces the pre-refactor pipeline byte for byte.
    pub governor: GovernorKind,
    /// Clock ratio the `FixedCap` policy pins (fraction of peak).
    pub fixed_cap_ratio: f64,
    /// Injected faults (`sim::faults`), resolved deterministically from
    /// the workload seed. Empty = healthy cluster, byte-identical to the
    /// pre-fault pipeline.
    pub faults: Vec<crate::config::FaultSpec>,
    /// Thermal coupling (`sim::thermal`): per-GPU RC temperature state
    /// feeding a throttle factor back into the governor each window.
    /// `None` (the default) disables the subsystem — no substream draws,
    /// no decorator, byte-identical to the pre-thermal pipeline.
    pub thermal: Option<crate::sim::thermal::ThermalConfig>,
}

impl Default for EngineParams {
    fn default() -> Self {
        Self {
            spin_penalty: 0.07,
            transfer_penalty: 0.65,
            comm_stretch: 0.3,
            rank_jitter: 0.05,
            compute_jitter: 0.004,
            dispatch_jitter: 0.35,
            comm_delay_sigma_ns: 150_000.0,
            far_rank_delay_ns: 2_200_000.0,
            hbm_noise_quiet_w: 6.0,
            hbm_noise_scale_w: 185.0,
            dvfs_window_ns: 1_000_000.0,
            margin_k: 0.3,
            governor: GovernorKind::Reactive,
            fixed_cap_ratio: 0.7,
            faults: Vec::new(),
            thermal: None,
        }
    }
}

/// Per-rank host busy time bucketed into fixed windows — input to the CPU
/// utilization model (sim::cpu).
#[derive(Debug, Clone, Default)]
pub struct HostActivity {
    /// Window length (ns).
    pub window_ns: f64,
    /// busy\[rank\]\[window\] = busy ns within that window. Dense per-rank
    /// vectors (windows are contiguous from t=0); a window index past the
    /// end of a rank's vector simply means zero busy time there.
    pub busy: Vec<Vec<f64>>,
    /// Total wall-clock span simulated.
    pub span_ns: f64,
}

impl HostActivity {
    /// Busy ns of `rank` in window `widx` (0 where never touched).
    pub fn busy_ns(&self, rank: usize, widx: u64) -> f64 {
        self.busy
            .get(rank)
            .and_then(|w| w.get(widx as usize))
            .copied()
            .unwrap_or(0.0)
    }

    /// The activity of node 0's ranks only (the first `gpus_per_node`),
    /// for feeding the single-host CPU model on multi-node runs. On one
    /// node this is a plain copy of the full activity.
    pub fn node0(&self, gpus_per_node: usize) -> HostActivity {
        HostActivity {
            window_ns: self.window_ns,
            busy: self.busy.iter().take(gpus_per_node).cloned().collect(),
            span_ns: self.span_ns,
        }
    }
}

/// Everything one simulated training run produces.
#[derive(Debug)]
pub struct SimOutput {
    pub trace: Trace,
    pub power: PowerTrace,
    pub host: HostActivity,
    pub alloc: AllocStats,
    /// Wall-clock boundaries of each iteration (start, end), ns.
    pub iter_bounds: Vec<(f64, f64)>,
    /// Per-rank joules integrated by the power-management policy — the
    /// window-sum of power × dt over every DVFS tick (`tests/pipeline.rs`
    /// pins it against the per-sample sum of the power trace).
    pub gov_energy_j: Vec<f64>,
}

// ---------------------------------------------------------------------------
// Event heap
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq)]
enum EvKind {
    /// Try to start the front of a rank's compute queue.
    TryCompute { rank: usize },
    /// Try to start the front of a rank's comm queue.
    TryComm { rank: usize },
    KernelEnd { rank: usize, gen: u64 },
    CollEnd { coll: usize, gen: u64 },
    DvfsTick { rank: usize },
}

#[derive(Debug, Clone, Copy)]
struct Ev {
    t: f64,
    seq: u64,
    kind: EvKind,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Min-heap via reversed compare; ties broken by insertion order.
        // total_cmp: a NaN timestamp (impossible today, but float math
        // upstream) can never silently collapse the ordering to Equal.
        other.t.total_cmp(&self.t).then(other.seq.cmp(&self.seq))
    }
}

// ---------------------------------------------------------------------------
// Per-rank state
// ---------------------------------------------------------------------------

/// A dispatched kernel, referenced by its index in the (shared, immutable)
/// program — avoids cloning the KernelDesc per rank on the hot path.
#[derive(Debug, Clone, Copy)]
struct QueuedKernel {
    item_idx: usize,
    t_launch: f64,
}

#[derive(Debug)]
struct InflightKernel {
    q: QueuedKernel,
    bytes_total: f64,
    timing: KernelTiming,
    t_start: f64,
    /// Remaining work in nominal-seconds.
    work_s: f64,
    rate: f64,
    last_update: f64,
    /// Portion of HBM bytes not yet attributed to a DVFS window.
    bytes_left: f64,
    gen: u64,
    freq_at_start: f64,
}

#[derive(Debug)]
enum HostBlock {
    None,
    /// Waiting for a collective id to complete.
    Collective(u64),
    /// Waiting for both local streams (and pending queues) to drain.
    Device,
}

struct RankState {
    // Host.
    item_idx: usize,
    host_time: f64,
    block: HostBlock,
    host_scale: f64,
    /// Host program ran to completion (counted once in `hosts_unfinished`).
    host_done: bool,
    /// Static compute-throughput multiplier of this GPU (~1.0).
    compute_scale: f64,
    /// Static comm-dispatch delay of this GPU (ns, >= 0).
    comm_delay_ns: f64,
    // Streams.
    compute_q: VecDeque<QueuedKernel>,
    comm_q: VecDeque<(u64, f64)>, // (collective id, t_launch)
    inflight: Option<InflightKernel>,
    /// Collective currently occupying this rank's comm stream.
    comm_occupied: Option<usize>,
    /// True when the front compute kernel is parked on a collective.
    parked: bool,
    /// Pending TryCompute timer already scheduled for a future time.
    compute_timer: f64,
    comm_timer: f64,
    // Power management + accounting.
    gov: Box<dyn GovernorPolicy>,
    win_start: f64,
    win: WindowActivity,
    comm_accounted: f64,
    // Trace bookkeeping.
    seq_compute: u64,
    seq_comm: u64,
    /// Compute kernels fully completed (gates comm stream-event waits).
    completed_kernels: u64,
    cur_iter: u32,
    rng: Rng,
}

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

pub struct Engine<'a> {
    topo: Topology,
    wl: &'a WorkloadConfig,
    params: EngineParams,
    ranks: Vec<RankState>,
    /// Collective *instances*: one per rendezvous group of each program
    /// collective (one instance for world-scoped collectives, one per
    /// node for intra-node HSDP collectives, one per local-GPU index for
    /// cross-node HSDP all-reduces).
    colls: Vec<CollState>,
    /// First instance index of each program collective id.
    coll_base: Vec<usize>,
    /// Rendezvous group of each program collective id.
    coll_group: Vec<CommGroup>,
    /// Instance indices currently in the Transfer phase. At most one on a
    /// single node (world-scoped collectives serialize on the comm
    /// streams); under HSDP, disjoint node groups transfer concurrently.
    active_transfers: Vec<usize>,
    heap: BinaryHeap<Ev>,
    ev_seq: u64,
    now: f64,
    program: Arc<crate::fsdp::Program>,
    /// Kernel timing per program item (None for non-kernel items). The
    /// duration model is deterministic per descriptor, so timings are
    /// derived once here instead of once per dispatch per rank.
    timings: Vec<Option<KernelTiming>>,
    // O(1) termination counters (see `done`).
    /// Non-DvfsTick events currently in the heap (incl. stale ones — the
    /// loop must drain them before it may stop, exactly as the old
    /// heap-scan did).
    live_events: usize,
    /// Ranks whose host program has not yet run to completion.
    hosts_unfinished: usize,
    /// Device-side outstanding work across ranks: queued + in-flight
    /// compute kernels, queued + stream-occupying collectives.
    device_work: usize,
    // Interned comm-kernel names (one per collective flavor).
    name_allgather: Sym,
    name_reduce_scatter: Sym,
    name_allreduce: Sym,
    // Output.
    events: Vec<TraceEvent>,
    power: PowerTrace,
    host: HostActivity,
    next_kernel_id: u64,
    /// fwd kernel id lookup for fwd→bwd links:
    /// (rank, iter, layer, op, kernel index within op) → kernel_id.
    fwd_ids: FxHashMap<(u32, u32, u32, OpType, u32), u64>,
    /// Running kernel-index-within-op while dispatch proceeds.
    op_kernel_idx: FxHashMap<(usize, u32, Option<u32>, OpType, u8), u32>,
    iter_bounds: Vec<(f64, f64)>,
    alloc: AllocStats,
    /// Resolved fault model (`NoFaults` when `params.faults` is empty).
    faults: Box<dyn crate::sim::faults::FaultModel>,
    /// Optional streaming trace sink (trace::store). When attached, events
    /// go to the sink instead of accumulating in `events`, so the full
    /// event vector is never materialized.
    sink: Option<Box<dyn TraceSink>>,
    /// Whether events stream to the sink at emission. False under dropout
    /// faults, whose global time-shift rewrite in `finish()` needs the
    /// buffered vector — the sink is then fed after the rewrite.
    sink_streams: bool,
}

impl<'a> Engine<'a> {
    /// Single-node engine over a plain [`NodeSpec`] — the original entry
    /// point, byte-identical to [`Engine::with_topology`] on
    /// [`Topology::single`] (pinned by `tests/pipeline.rs`).
    pub fn new(
        node: &'a NodeSpec,
        cfg: &ModelConfig,
        wl: &'a WorkloadConfig,
        params: EngineParams,
    ) -> Self {
        Self::with_topology(Topology::single(node.clone()), cfg, wl, params)
    }

    /// Engine over a full cluster topology: `topo.world_size()` flat
    /// ranks, hierarchical collective costs, and (under
    /// [`Sharding::Hsdp`](crate::config::Sharding)) node-scoped
    /// rendezvous groups whose transfers overlap across nodes.
    pub fn with_topology(
        topo: Topology,
        cfg: &ModelConfig,
        wl: &'a WorkloadConfig,
        params: EngineParams,
    ) -> Self {
        let program = Arc::new(build_program_topo(cfg, wl, &topo));

        // Allocator behaviour decides the HBM power-noise level (Obs. 6).
        let alloc = simulate_gather_pattern(
            wl.fsdp,
            cfg.layer_weight_bytes(),
            cfg.layers as u32,
            wl.iterations,
            wl.seed,
        );
        Self::with_program(topo, cfg, wl, params, program, alloc)
    }

    /// Engine over an explicit dispatch [`Program`](crate::fsdp::Program)
    /// and allocator profile — the entry point for non-training programs
    /// (the serving path builds its own continuous-batching program).
    /// [`Engine::with_topology`] is exactly this with the FSDP training
    /// program and gather-pattern allocator plugged in, so the training
    /// path stays byte-identical.
    pub fn with_program(
        topo: Topology,
        cfg: &ModelConfig,
        wl: &'a WorkloadConfig,
        params: EngineParams,
        program: Arc<crate::fsdp::Program>,
        alloc: AllocStats,
    ) -> Self {
        // Replica folding (DESIGN.md §13): the engine sizes every per-rank
        // structure to the *simulated* world (`sim_world()` representative
        // ranks) while collective pricing below keeps reading the logical
        // `topo.num_nodes` / `world_size()`. In exact mode (fold 1) the two
        // coincide and every line here is byte-identical to the unfolded
        // engine.
        let r = topo.sim_world() as usize;
        if topo.is_folded() {
            topo.validate_fold().expect("folded topology");
            if let Some(f) =
                params.faults.iter().find(|f| !f.fold_compatible())
            {
                // Backstop for the CLI-level rejection: a rank/node-targeted
                // fault inside a folded class would silently be multiplied
                // across every replica the representative stands for.
                panic!(
                    "fault `{}` targets specific ranks/nodes and cannot run \
                     under replica folding (fold {}): drop --fold or the fault",
                    f.label(),
                    topo.fold_factor()
                );
            }
        }
        let spike_var =
            alloc.peak_sigma_bytes / cfg.layer_weight_bytes().max(1) as f64;
        let noise_w =
            params.hbm_noise_quiet_w + params.hbm_noise_scale_w * spike_var;

        // One NUMA-far GPU per node (each chassis has its own two-socket
        // doorbell asymmetry). Node 0 keeps the original substream label so
        // the single-node trace is bit-identical to the pre-topology path.
        // Folded representatives draw from the substream of the *logical*
        // node leading their equivalence class, so at any fold the
        // representative is bitwise the node it stands for.
        let gpn = topo.gpus_per_node() as usize;
        let far_local_of = |logical_node: u32| -> usize {
            let label = if logical_node == 0 {
                "far_rank".to_string()
            } else {
                format!("far_rank_node{logical_node}")
            };
            Rng::substream(wl.seed, &label).range_usize(0, gpn)
        };
        let far_locals: Vec<usize> = (0..topo.sim_nodes())
            .map(|n| far_local_of(topo.logical_node_of(n)))
            .collect();
        // Fault model: resolved from its own `(seed, "fault<i>")`
        // substreams so it never consumes a draw from the per-rank jitter
        // streams below — the empty set stays byte-identical.
        let faults =
            crate::sim::faults::build_fault_model(&params.faults, wl.seed, r, gpn);

        // Static per-rank comm dispatch delay of a *logical* rank, drawn
        // from its own substream exactly the way the rank loop below draws
        // it (two leading gausses are the host/compute jitter draws).
        let static_comm_delay = |logical_rank: u32, far_local: usize| -> f64 {
            let mut rng =
                Rng::substream(wl.seed, &format!("rank{logical_rank}"));
            let _ = rng.gauss();
            let _ = rng.gauss();
            rng.gauss().abs() * params.comm_delay_sigma_ns
                + if logical_rank as usize % gpn == far_local {
                    params.far_rank_delay_ns
                } else {
                    0.0
                }
        };

        // Thermal cooling-efficiency resolution (DESIGN.md §14): each
        // rank's efficiency is a fresh `"therm<logical rank>"` substream
        // draw (never one of the engine's jitter streams). Under folding a
        // hot node is replica-asymmetric, so each representative carries
        // the *worst* (hottest) efficiency across the logical siblings of
        // its equivalence class — the same envelope shape as the
        // cross-node comm tail below, re-derived from the substreams of
        // ranks the engine never simulates. `None` when disabled: no
        // draws, no decorator, nothing in the hot loop.
        let thermal_ctx: Vec<Option<crate::sim::thermal::ThermalCtx>> =
            match &params.thermal {
                None => vec![None; r],
                Some(tc) => {
                    let fold = topo.fold_factor();
                    (0..r as u32)
                        .map(|g| {
                            let local = g % gpn as u32;
                            let lead = topo.logical_node_of(g / gpn as u32);
                            let worst = (lead..lead + fold)
                                .map(|ln| {
                                    crate::sim::thermal::cool_eff(
                                        tc,
                                        wl.seed,
                                        topo.rank_of(ln, local),
                                        ln,
                                        topo.num_nodes,
                                    )
                                })
                                .fold(f64::NEG_INFINITY, f64::max);
                            Some(crate::sim::thermal::ThermalCtx {
                                cfg: tc.clone(),
                                cool_eff: worst,
                            })
                        })
                        .collect()
                }
            };

        let mut ranks = Vec::with_capacity(r);
        for g in 0..r {
            let lg = topo.logical_rank_of(g as u32);
            let mut rng = Rng::substream(wl.seed, &format!("rank{lg}"));
            let host_scale = (1.0 + params.rank_jitter * rng.gauss()).clamp(0.8, 1.3);
            let mut compute_scale =
                (1.0 + params.compute_jitter * rng.gauss()).clamp(0.9, 1.1);
            if !faults.is_empty() {
                // Persistent straggler: a hot/slow GPU's throughput deficit.
                compute_scale *= faults.compute_factor(g);
            }
            let is_far = g % gpn == far_locals[g / gpn];
            let comm_delay_ns = rng.gauss().abs() * params.comm_delay_sigma_ns
                + if is_far { params.far_rank_delay_ns } else { 0.0 };
            ranks.push(RankState {
                item_idx: 0,
                host_time: 0.0,
                block: HostBlock::None,
                host_scale,
                host_done: false,
                compute_scale,
                comm_delay_ns,
                compute_q: VecDeque::new(),
                comm_q: VecDeque::new(),
                inflight: None,
                comm_occupied: None,
                parked: false,
                compute_timer: f64::NAN,
                comm_timer: f64::NAN,
                // HBM power noise is common-mode across ranks (every GPU
                // runs the identical allocator pattern), so all governors
                // share one noise stream; divergence between ranks comes
                // from their (slightly) different activity histories.
                gov: params.governor.build(&GovCtx {
                    gpu: &topo.node.gpu,
                    seed: wl.seed,
                    gpu_idx: 0,
                    hbm_noise_w: noise_w,
                    window_ns: params.dvfs_window_ns,
                    margin_k: params.margin_k,
                    fixed_cap_ratio: params.fixed_cap_ratio,
                    spike_var,
                    thermal: thermal_ctx[g].clone(),
                }),
                win_start: 0.0,
                win: WindowActivity::default(),
                comm_accounted: 0.0,
                seq_compute: 0,
                seq_comm: 0,
                completed_kernels: 0,
                cur_iter: 0,
                rng,
            });
        }

        let dur = DurationModel::new(topo.node.gpu.clone(), wl.batch, cfg.q_heads);

        // One pass over the program: per-item timings (the duration model
        // is a pure function of the descriptor) and output capacities.
        let mut compute_kernels = 0usize;
        let mut fwd_kernels = 0usize;
        let mut comm_count = 0usize;
        let mut timings = Vec::with_capacity(program.items.len());
        for item in program.items.iter() {
            match item {
                DispatchItem::Kernel(k) => {
                    compute_kernels += 1;
                    if k.desc.op.phase == crate::model::ops::Phase::Forward {
                        fwd_kernels += 1;
                    }
                    timings.push(Some(dur.timing(&k.desc)));
                }
                DispatchItem::Comm(_) => {
                    comm_count += 1;
                    timings.push(None);
                }
                _ => timings.push(None),
            }
        }

        // Folded cross-node tail envelope: a cross-node rendezvous is
        // gated by its slowest participant, and folding removes the
        // unsimulated replicas' arrivals from the event stream. Recover
        // the *static* part of that tail by re-deriving every logical
        // rank's comm dispatch delay from its substream (fresh substreams,
        // zero draws from the engine streams) and charging each local's
        // cross-node instances the delay gap between the slowest logical
        // replica and the slowest represented one. Exactly empty in exact
        // mode, so fold 1 adds nothing — not even a `+ 0.0`.
        let cross_tail_ns: Vec<f64> = if topo.is_folded() {
            let fold = topo.fold_factor();
            let mut tails = Vec::with_capacity(gpn);
            let far_all: Vec<usize> =
                (0..topo.num_nodes).map(far_local_of).collect();
            for local in 0..gpn as u32 {
                let mut max_all = f64::NEG_INFINITY;
                let mut max_rep = f64::NEG_INFINITY;
                for n in 0..topo.num_nodes {
                    let d = static_comm_delay(
                        topo.rank_of(n, local),
                        far_all[n as usize],
                    );
                    max_all = max_all.max(d);
                    if n % fold == 0 {
                        max_rep = max_rep.max(d);
                    }
                }
                tails.push((max_all - max_rep).max(0.0));
            }
            tails
        } else {
            Vec::new()
        };

        // Expand each program collective into its rendezvous-group
        // instances. On one node (or flat FSDP) every collective is
        // world-scoped: exactly one instance whose index equals the
        // program id, so instance lookups reduce to the old `colls[cid]`.
        // Under folding, instances span the representative ranks only
        // (one node per class, disjoint intra-node groups for unsimulated
        // replicas never materialize) while `base_ns` keeps pricing the
        // full logical topology.
        let mut colls: Vec<CollState> = Vec::with_capacity(comm_count);
        let mut coll_base: Vec<usize> = Vec::with_capacity(comm_count);
        let mut coll_group: Vec<CommGroup> = Vec::with_capacity(comm_count);
        for c in program.collectives() {
            debug_assert_eq!(c.id as usize, coll_base.len(), "dense comm ids");
            coll_base.push(colls.len());
            coll_group.push(c.group);
            let base_ns = group_collective_base_ns(&topo, c.group, c.bytes);
            // A degraded xGMI/NIC link stretches the base transfer time of
            // every collective instance whose rendezvous group touches the
            // slow node — one bad link drags the whole group.
            match c.group {
                CommGroup::World => {
                    let mut b = base_ns;
                    if !faults.is_empty() {
                        let parts: Vec<usize> = (0..r).collect();
                        b *= faults.link_time_factor(&parts);
                    }
                    colls.push(CollState::new(c.clone(), r, b));
                }
                CommGroup::IntraNode => {
                    for n in 0..topo.sim_nodes() {
                        let parts: Vec<usize> =
                            topo.node_ranks(n).map(|x| x as usize).collect();
                        let mut b = base_ns;
                        if !faults.is_empty() {
                            b *= faults.link_time_factor(&parts);
                        }
                        colls.push(CollState::for_group(c.clone(), parts, r, b));
                    }
                }
                CommGroup::CrossNode => {
                    for local in 0..topo.gpus_per_node() {
                        let parts: Vec<usize> = (0..topo.sim_nodes())
                            .map(|n| topo.rank_of(n, local) as usize)
                            .collect();
                        let mut b = base_ns;
                        if topo.is_folded() {
                            b += cross_tail_ns[local as usize];
                        }
                        if !faults.is_empty() {
                            b *= faults.link_time_factor(&parts);
                        }
                        colls.push(CollState::for_group(c.clone(), parts, r, b));
                    }
                }
            }
        }

        let mut eng = Self {
            topo,
            wl,
            ranks,
            colls,
            coll_base,
            coll_group,
            active_transfers: Vec::new(),
            heap: BinaryHeap::with_capacity(8 * r + 64),
            ev_seq: 0,
            now: 0.0,
            program,
            timings,
            live_events: 0,
            hosts_unfinished: r,
            device_work: 0,
            name_allgather: intern("rccl_AllGather_bf16"),
            name_reduce_scatter: intern("rccl_ReduceScatter_bf16"),
            name_allreduce: intern("rccl_AllReduce_bf16"),
            events: Vec::with_capacity((compute_kernels + comm_count) * r),
            power: PowerTrace::default(),
            host: HostActivity {
                window_ns: params.dvfs_window_ns,
                busy: vec![Vec::new(); r],
                span_ns: 0.0,
            },
            next_kernel_id: 0,
            fwd_ids: FxHashMap::with_capacity_and_hasher(
                fwd_kernels * r,
                Default::default(),
            ),
            op_kernel_idx: FxHashMap::default(),
            iter_bounds: vec![(f64::INFINITY, 0.0); wl.iterations as usize],
            alloc,
            params,
            faults,
            sink: None,
            sink_streams: false,
        };
        for g in 0..r {
            eng.push(eng.params.dvfs_window_ns, EvKind::DvfsTick { rank: g });
        }
        eng
    }

    /// The collective *instance* rank `rank` rendezvouses on for program
    /// collective `cid`. With world-scoped collectives (any single-node
    /// program) this is exactly the old `colls[cid]` lookup.
    fn coll_inst(&self, rank: usize, cid: u64) -> usize {
        let base = self.coll_base[cid as usize];
        match self.coll_group[cid as usize] {
            CommGroup::World => base,
            CommGroup::IntraNode => base + self.topo.node_of(rank as u32) as usize,
            CommGroup::CrossNode => base + self.topo.local_of(rank as u32) as usize,
        }
    }

    fn push(&mut self, t: f64, kind: EvKind) {
        self.ev_seq += 1;
        if !matches!(kind, EvKind::DvfsTick { .. }) {
            self.live_events += 1;
        }
        self.heap.push(Ev {
            t,
            seq: self.ev_seq,
            kind,
        });
    }

    // ------------------------------------------------------------------
    // Host actor
    // ------------------------------------------------------------------

    /// Run the host of `rank` until it blocks or the program ends.
    fn run_host(&mut self, rank: usize) {
        let program = Arc::clone(&self.program);
        loop {
            let idx = self.ranks[rank].item_idx;
            if idx >= program.items.len() {
                if !self.ranks[rank].host_done {
                    self.ranks[rank].host_done = true;
                    self.hosts_unfinished -= 1;
                }
                return;
            }
            match &program.items[idx] {
                DispatchItem::HostWork { ns, tag } => {
                    let r = &mut self.ranks[rank];
                    if *tag == "serve_wait_until" {
                        // Serving open-loop wait: `ns` is an absolute
                        // wall-clock deadline (the next arrival), not CPU
                        // work — unscaled by host speed and not accounted
                        // as host busy time. Training programs never emit
                        // this tag.
                        r.host_time = r.host_time.max(*ns);
                    } else {
                        let cost = ns * r.host_scale;
                        Self::host_busy(&mut self.host, rank, r.host_time, cost);
                        r.host_time += cost;
                    }
                    r.item_idx += 1;
                }
                DispatchItem::Kernel(_) => {
                    let r = &mut self.ranks[rank];
                    let jit = 1.0
                        + self.params.dispatch_jitter * r.rng.f64().powi(3);
                    let cost = self.topo.node.cpu.dispatch_ns * r.host_scale * jit;
                    Self::host_busy(&mut self.host, rank, r.host_time, cost);
                    r.host_time += cost;
                    let t_launch = r.host_time;
                    r.compute_q.push_back(QueuedKernel {
                        item_idx: idx,
                        t_launch,
                    });
                    r.item_idx += 1;
                    self.device_work += 1;
                    self.try_compute(rank);
                }
                DispatchItem::Comm(c) => {
                    let id = c.id;
                    let inst = self.coll_inst(rank, id);
                    let r = &mut self.ranks[rank];
                    // Collective dispatch is cheaper than a kernel launch.
                    let cost = self.topo.node.cpu.dispatch_ns * 0.6 * r.host_scale;
                    Self::host_busy(&mut self.host, rank, r.host_time, cost);
                    r.host_time += cost;
                    let t_launch = r.host_time;
                    self.colls[inst].t_launch[rank] = t_launch;
                    r.comm_q.push_back((id, t_launch));
                    r.item_idx += 1;
                    self.device_work += 1;
                    self.try_comm(rank);
                }
                DispatchItem::Sync(HostSync::Collective(id)) => {
                    let id = *id;
                    let inst = self.coll_inst(rank, id);
                    if self.colls[inst].is_done() {
                        let end = self.colls[inst].end_time;
                        let r = &mut self.ranks[rank];
                        r.host_time = r.host_time.max(end);
                        r.item_idx += 1;
                    } else {
                        self.colls[inst].host_waiters.push(rank);
                        self.ranks[rank].block = HostBlock::Collective(id);
                        return;
                    }
                }
                DispatchItem::Sync(HostSync::Device) => {
                    if self.rank_idle(rank) {
                        let r = &mut self.ranks[rank];
                        r.host_time = r.host_time.max(self.now);
                        r.item_idx += 1;
                    } else {
                        self.ranks[rank].block = HostBlock::Device;
                        return;
                    }
                }
            }
        }
    }

    fn host_busy(host: &mut HostActivity, rank: usize, t0: f64, dur: f64) {
        // Attribute busy time to windows (a dispatch can straddle one).
        let w = host.window_ns;
        let busy = &mut host.busy[rank];
        let mut t = t0;
        let end = t0 + dur;
        while t < end {
            let widx = (t / w) as usize;
            if busy.len() <= widx {
                busy.resize(widx + 1, 0.0);
            }
            let wend = (widx + 1) as f64 * w;
            let chunk = end.min(wend) - t;
            busy[widx] += chunk;
            t = end.min(wend);
        }
    }

    fn rank_idle(&self, rank: usize) -> bool {
        let r = &self.ranks[rank];
        r.compute_q.is_empty()
            && r.inflight.is_none()
            && r.comm_q.is_empty()
            && r.comm_occupied.is_none()
    }

    /// Re-check a blocked host after device progress.
    fn wake_host(&mut self, rank: usize) {
        let ready = match self.ranks[rank].block {
            HostBlock::None => false,
            HostBlock::Collective(id) => {
                self.colls[self.coll_inst(rank, id)].is_done()
            }
            HostBlock::Device => self.rank_idle(rank),
        };
        if ready {
            {
                let r = &mut self.ranks[rank];
                r.block = HostBlock::None;
                r.host_time = r.host_time.max(self.now);
                r.item_idx += 1;
            }
            self.run_host(rank);
        }
    }

    // ------------------------------------------------------------------
    // Compute stream
    // ------------------------------------------------------------------

    /// Current progress rate for an in-flight kernel on `rank`.
    fn compute_rate(&self, rank: usize, timing: &KernelTiming) -> f64 {
        let r = &self.ranks[rank];
        // Clamped accessors: the policy (not each call site) guarantees
        // the ratios can never reach the divide-by-zero regime.
        let fr = r.gov.freq_ratio_clamped();
        let mfr = r.gov.mem_freq_ratio_clamped();
        let mbf = timing.mem_bound_frac.clamp(0.0, 1.0);
        let freq_factor = 1.0 / ((1.0 - mbf) / fr + mbf / mfr);
        let mem_sens = 0.25 + 0.75 * mbf;
        let occupied = r.comm_occupied.is_some();
        // HBM contention applies while the collective occupying *this
        // rank's* comm stream is in its transfer phase. (On one node this
        // is exactly the old global `occupied && active_transfer` check:
        // world-scoped collectives serialize, so the only possible
        // transfer is the one occupying every rank.)
        let in_transfer = r
            .comm_occupied
            .map(|ci| self.colls[ci].phase == CollPhase::Transfer)
            .unwrap_or(false);
        let cont = 1.0
            + mem_sens
                * (self.params.spin_penalty * occupied as u8 as f64
                    + self.params.transfer_penalty * in_transfer as u8 as f64);
        freq_factor * r.compute_scale / cont
    }

    fn try_compute(&mut self, rank: usize) {
        if self.ranks[rank].inflight.is_some() || self.ranks[rank].parked {
            return;
        }
        let Some(&front) = self.ranks[rank].compute_q.front() else {
            return;
        };
        let wait_comm = self.prog_kernel(front.item_idx).wait_comm;
        // Collective dependency?
        if let Some(cid) = wait_comm {
            let inst = self.coll_inst(rank, cid);
            let c = &mut self.colls[inst];
            if !c.is_done() {
                c.kernel_waiters.push(rank);
                self.ranks[rank].parked = true;
                return;
            }
        }
        let ready = front
            .t_launch
            .max(self.colls_ready_time(rank, wait_comm))
            + self.topo.node.cpu.launch_latency_ns;
        if ready > self.now {
            // Schedule a wake-up; dedupe timers.
            if self.ranks[rank].compute_timer.is_nan()
                || self.ranks[rank].compute_timer > ready
            {
                self.ranks[rank].compute_timer = ready;
                self.push(ready, EvKind::TryCompute { rank });
            }
            return;
        }
        self.ranks[rank].compute_timer = f64::NAN;
        // Start it.
        let q = self.ranks[rank].compute_q.pop_front().unwrap();
        let pk = self.prog_kernel(q.item_idx);
        let (bytes, iter) = (pk.desc.bytes, pk.iter);
        let timing = self.timings[q.item_idx]
            .expect("compute queue holds only kernels");
        let rate = self.compute_rate(rank, &timing);
        let gen = self.next_gen();
        let freq = self.ranks[rank].gov.freq_mhz();
        // Transient ECC-retry-style stall: extra nominal work charged at
        // kernel start (0.0 and draw-free on the empty fault model).
        let mut work_s = timing.nominal_ns * 1e-9;
        let stall_ns = self.faults.stall_ns(rank);
        if stall_ns > 0.0 {
            work_s += stall_ns * 1e-9;
        }
        let inflight = InflightKernel {
            work_s,
            bytes_left: bytes,
            bytes_total: bytes,
            q,
            timing,
            t_start: self.now,
            rate,
            last_update: self.now,
            gen,
            freq_at_start: freq,
        };
        let end = self.now + inflight.work_s / rate * 1e9;
        self.ranks[rank].cur_iter = iter;
        self.ranks[rank].inflight = Some(inflight);
        self.push(end, EvKind::KernelEnd { rank, gen });
        // Compute starting changes collective contention.
        self.retune_transfers(rank);
    }

    /// The program kernel behind a queue entry.
    fn prog_kernel(&self, item_idx: usize) -> &ProgKernel {
        match &self.program.items[item_idx] {
            DispatchItem::Kernel(k) => k,
            _ => unreachable!("compute queue holds only kernels"),
        }
    }

    fn colls_ready_time(&self, rank: usize, wait: Option<u64>) -> f64 {
        match wait {
            Some(id) => self.colls[self.coll_inst(rank, id)].end_time,
            None => 0.0,
        }
    }

    fn next_gen(&mut self) -> u64 {
        self.ev_seq += 1;
        self.ev_seq
    }

    /// Advance the in-flight kernel of `rank` to `now`, attributing window
    /// activity; does not finish it.
    fn account_inflight(&mut self, rank: usize) {
        let now = self.now;
        let r = &mut self.ranks[rank];
        if let Some(k) = r.inflight.as_mut() {
            let dt = (now - k.last_update).max(0.0);
            if dt > 0.0 {
                let done_s = (dt * 1e-9 * k.rate).min(k.work_s);
                let total_s = k.timing.nominal_ns * 1e-9;
                let frac = if total_s > 0.0 { done_s / total_s } else { 0.0 };
                let bytes = k.bytes_total * frac;
                k.bytes_left = (k.bytes_left - bytes).max(0.0);
                k.work_s -= done_s;
                k.last_update = now;
                r.win.compute_busy += dt;
                r.win.mfma_util += dt * k.timing.mfma_util;
                r.win.hbm_bytes += bytes;
            }
        }
        // Comm occupancy accounting.
        if r.comm_occupied.is_some() {
            let dt = (now - r.comm_accounted).max(0.0);
            r.win.comm_busy += dt;
            r.comm_accounted = now;
        }
    }

    /// Rescale the in-flight compute kernel of `rank` after a rate change.
    fn rescale_compute(&mut self, rank: usize) {
        let Some((timing, old_rate)) = self.ranks[rank]
            .inflight
            .as_ref()
            .map(|k| (k.timing, k.rate))
        else {
            return;
        };
        let rate = self.compute_rate(rank, &timing);
        if (rate - old_rate).abs() < 1e-9 * old_rate {
            return; // no change — keep the scheduled end event
        }
        self.account_inflight(rank);
        let gen = self.next_gen();
        let now = self.now;
        let k = self.ranks[rank].inflight.as_mut().unwrap();
        k.rate = rate;
        k.gen = gen;
        let end = now + k.work_s / rate * 1e9;
        self.push(end, EvKind::KernelEnd { rank, gen });
    }

    fn on_kernel_end(&mut self, rank: usize, gen: u64) {
        let valid = self.ranks[rank]
            .inflight
            .as_ref()
            .map(|k| k.gen == gen)
            .unwrap_or(false);
        if !valid {
            return;
        }
        self.account_inflight(rank);
        let k = self.ranks[rank].inflight.take().unwrap();
        debug_assert!(k.work_s < 1e-9, "kernel ended with work left: {}", k.work_s);
        self.ranks[rank].completed_kernels += 1;
        self.device_work -= 1;
        self.emit_compute_event(rank, k);
        self.retune_transfers(rank);
        self.try_compute(rank);
        self.try_comm(rank); // a stream-event wait may now be satisfied
        self.wake_host(rank);
    }

    fn emit_compute_event(&mut self, rank: usize, k: InflightKernel) {
        let id = self.next_kernel_id;
        self.next_kernel_id += 1;
        let program = Arc::clone(&self.program);
        let pk = match &program.items[k.q.item_idx] {
            DispatchItem::Kernel(pk) => pk,
            _ => unreachable!(),
        };
        let d = &pk.desc;
        let iter = pk.iter;
        let op = d.op;
        // fwd→bwd link (Section III-B1): backward kernels are spawned from
        // their forward counterparts.
        let layer_key = d.layer.unwrap_or(u32::MAX);
        let ph = match op.phase {
            crate::model::ops::Phase::Forward => 0u8,
            crate::model::ops::Phase::Backward => 1,
            crate::model::ops::Phase::Optimizer => 2,
        };
        let pidx = {
            let key = (rank, iter, d.layer, op.op, ph);
            let e = self.op_kernel_idx.entry(key).or_insert(0);
            let v = *e;
            *e += 1;
            v
        };
        let fwd_link = match ph {
            0 => {
                self.fwd_ids
                    .insert((rank as u32, iter, layer_key, op.op, pidx), id);
                None
            }
            1 => self
                .fwd_ids
                .get(&(rank as u32, iter, layer_key, op.op, pidx))
                .copied(),
            _ => None,
        };
        let seq = self.ranks[rank].seq_compute;
        self.ranks[rank].seq_compute += 1;
        let b = self.iter_bounds.get_mut(iter as usize);
        if let Some((s, e)) = b {
            *s = s.min(k.t_start);
            *e = e.max(self.now);
        }
        self.record_event(TraceEvent {
            kernel_id: id,
            gpu: rank as u32,
            stream: Stream::Compute,
            name: d.name,
            op,
            layer: d.layer,
            iter,
            t_launch: k.q.t_launch,
            t_start: k.t_start,
            t_end: self.now,
            seq,
            fwd_link,
            freq_mhz: k.freq_at_start,
            flops: d.flops,
            bytes: d.bytes,
        });
    }

    /// Route a finished kernel's event to the buffered vector or, when a
    /// streaming sink is attached, straight to it (bounded memory). The
    /// flush watermark is the slowest rank's current iteration — every
    /// iteration below it is complete and can leave the sink's buffer.
    fn record_event(&mut self, ev: TraceEvent) {
        if self.sink_streams {
            if let Some(s) = self.sink.as_mut() {
                s.event(&ev);
                let w = self
                    .ranks
                    .iter()
                    .map(|r| r.cur_iter)
                    .min()
                    .unwrap_or(0);
                s.advance(w);
                return;
            }
        }
        self.events.push(ev);
    }

    // ------------------------------------------------------------------
    // Comm stream
    // ------------------------------------------------------------------

    fn try_comm(&mut self, rank: usize) {
        if self.ranks[rank].comm_occupied.is_some() {
            return;
        }
        let Some(&(cid, t_launch)) = self.ranks[rank].comm_q.front() else {
            return;
        };
        let inst = self.coll_inst(rank, cid);
        // Cross-stream event dependency: the collective may not start
        // until the compute kernels enqueued before it have completed on
        // this rank (re-checked from on_kernel_end).
        if self.ranks[rank].completed_kernels < self.colls[inst].desc.wait_seq {
            return;
        }
        // The rank's comm-dispatch delay applies from the moment the
        // stream-event gate is satisfied (now), not from the (far-ahead)
        // host launch time; memoize so rescheduling stays idempotent.
        let ready = {
            let c = &mut self.colls[inst];
            if c.ready_at[rank].is_nan() {
                c.ready_at[rank] = self
                    .now
                    .max(t_launch + self.topo.node.cpu.launch_latency_ns)
                    + self.ranks[rank].comm_delay_ns;
            }
            c.ready_at[rank]
        };
        if ready > self.now {
            if self.ranks[rank].comm_timer.is_nan()
                || self.ranks[rank].comm_timer > ready
            {
                self.ranks[rank].comm_timer = ready;
                self.push(ready, EvKind::TryComm { rank });
            }
            return;
        }
        self.ranks[rank].comm_timer = f64::NAN;
        self.ranks[rank].comm_q.pop_front();
        self.ranks[rank].comm_occupied = Some(inst);
        self.ranks[rank].comm_accounted = self.now;
        // RCCL kernel now holds CUs on this rank: compute slows down.
        self.rescale_compute(rank);
        let all_arrived = self.colls[inst].arrive(rank, self.now);
        if all_arrived {
            self.active_transfers.push(inst);
            // Transfer contends with compute on every participating rank
            // (every rank, when the collective is world-scoped).
            for pi in 0..self.colls[inst].participants.len() {
                let g = self.colls[inst].participants[pi];
                self.rescale_compute(g);
            }
            self.retune_one(inst);
        }
    }

    /// Recompute the rate of every in-flight transfer `rank` participates
    /// in and reschedule its end event. On one node there is at most one
    /// active transfer and every rank participates — the old single
    /// global retune, unchanged.
    fn retune_transfers(&mut self, rank: usize) {
        for i in 0..self.active_transfers.len() {
            let idx = self.active_transfers[i];
            if self.colls[idx].participants.contains(&rank) {
                self.retune_one(idx);
            }
        }
    }

    /// Recompute one in-flight transfer's rate from the compute activity
    /// of its participants and reschedule its end event.
    fn retune_one(&mut self, idx: usize) {
        debug_assert_eq!(self.colls[idx].phase, CollPhase::Transfer);
        let busy = {
            let c = &self.colls[idx];
            c.participants
                .iter()
                .filter(|&&p| self.ranks[p].inflight.is_some())
                .count() as f64
                / c.participants.len() as f64
        };
        let c = &mut self.colls[idx];
        c.advance(self.now);
        c.rate = 1.0 / (1.0 + self.params.comm_stretch * busy);
        c.gen += 1;
        let gen = c.gen;
        let end = c.projected_end();
        self.push(end, EvKind::CollEnd { coll: idx, gen });
    }

    fn on_coll_end(&mut self, idx: usize, gen: u64) {
        {
            let c = &mut self.colls[idx];
            if c.gen != gen || c.phase != CollPhase::Transfer {
                return;
            }
            c.advance(self.now);
            if c.work_s > 1e-9 {
                // Numerical residue: reschedule rather than deadlock.
                c.gen += 1;
                let gen = c.gen;
                let end = c.projected_end();
                self.push(end, EvKind::CollEnd { coll: idx, gen });
                return;
            }
            c.phase = CollPhase::Done;
            c.end_time = self.now;
        }
        self.active_transfers.retain(|&i| i != idx);
        // Emit one trace event per participant, free their comm streams.
        // Participants are ascending, so on one node this is the old
        // `0..ranks` walk exactly.
        for pi in 0..self.colls[idx].participants.len() {
            let rank = self.colls[idx].participants[pi];
            self.account_inflight(rank);
            debug_assert_eq!(self.ranks[rank].comm_occupied, Some(idx));
            self.ranks[rank].comm_occupied = None;
            self.device_work -= 1;
            let id = self.next_kernel_id;
            self.next_kernel_id += 1;
            let seq = self.ranks[rank].seq_comm;
            self.ranks[rank].seq_comm += 1;
            let freq_mhz = self.ranks[rank].gov.freq_mhz();
            let ev = {
                let c = &self.colls[idx];
                let name = match c.desc.op.op {
                    OpType::AllGather => self.name_allgather,
                    OpType::AllReduce => self.name_allreduce,
                    _ => self.name_reduce_scatter,
                };
                TraceEvent {
                    kernel_id: id,
                    gpu: rank as u32,
                    stream: Stream::Comm,
                    name,
                    op: c.desc.op,
                    layer: c.desc.scope.layer(),
                    iter: c.desc.iter,
                    t_launch: c.t_launch[rank],
                    t_start: c.local_start[rank],
                    t_end: self.now,
                    seq,
                    fwd_link: None,
                    freq_mhz,
                    flops: 0.0,
                    bytes: c.desc.bytes,
                }
            };
            self.record_event(ev);
        }
        // Contention released: compute speeds back up on participants.
        for pi in 0..self.colls[idx].participants.len() {
            let rank = self.colls[idx].participants[pi];
            self.rescale_compute(rank);
        }
        // Wake parked compute kernels and blocked hosts (waiters are
        // always participants — only they rendezvous on this instance).
        let waiters = std::mem::take(&mut self.colls[idx].kernel_waiters);
        for rank in waiters {
            self.ranks[rank].parked = false;
            self.try_compute(rank);
        }
        let hosts = std::mem::take(&mut self.colls[idx].host_waiters);
        for rank in hosts {
            self.wake_host(rank);
        }
        // Next collective may start on every participant.
        for pi in 0..self.colls[idx].participants.len() {
            let rank = self.colls[idx].participants[pi];
            self.try_comm(rank);
            self.wake_host(rank);
        }
    }

    // ------------------------------------------------------------------
    // DVFS tick
    // ------------------------------------------------------------------

    fn on_dvfs_tick(&mut self, rank: usize) {
        self.account_inflight(rank);
        let wn = self.params.dvfs_window_ns;
        let (act, t0, iter) = {
            let r = &mut self.ranks[rank];
            let act = WindowActivity {
                compute_busy: (r.win.compute_busy / wn).min(1.0),
                mfma_util: if r.win.compute_busy > 0.0 {
                    r.win.mfma_util / r.win.compute_busy
                } else {
                    0.0
                },
                hbm_bytes: r.win.hbm_bytes,
                comm_busy: (r.win.comm_busy / wn).min(1.0),
            };
            (act, r.win_start, r.cur_iter)
        };
        let (power, freq) = self.ranks[rank].gov.step(&act);
        // (0.0, 1.0) — the field defaults — when thermal is off, so the
        // disabled sample stream is byte-identical to the pre-thermal one.
        let (temp_c, throttle) =
            self.ranks[rank].gov.thermal_sample().unwrap_or((0.0, 1.0));
        self.power.samples.push(PowerSample {
            gpu: rank as u32,
            t: t0,
            window_ns: wn,
            freq_mhz: freq,
            mem_freq_mhz: self.ranks[rank].gov.mem_freq_mhz(),
            power_w: power,
            iter,
            temp_c,
            throttle,
        });
        {
            let r = &mut self.ranks[rank];
            r.win = WindowActivity::default();
            r.win_start = self.now;
        }
        // New clocks ⇒ new compute rate.
        self.rescale_compute(rank);
        self.push(self.now + wn, EvKind::DvfsTick { rank });
    }

    // ------------------------------------------------------------------
    // Main loop
    // ------------------------------------------------------------------

    pub fn run(mut self) -> SimOutput {
        for rank in 0..self.ranks.len() {
            self.run_host(rank);
        }
        while let Some(ev) = self.heap.pop() {
            self.now = ev.t;
            match ev.kind {
                EvKind::TryCompute { rank } => {
                    self.live_events -= 1;
                    self.ranks[rank].compute_timer = f64::NAN;
                    self.try_compute(rank)
                }
                EvKind::TryComm { rank } => {
                    self.live_events -= 1;
                    self.ranks[rank].comm_timer = f64::NAN;
                    self.try_comm(rank)
                }
                EvKind::KernelEnd { rank, gen } => {
                    self.live_events -= 1;
                    self.on_kernel_end(rank, gen)
                }
                EvKind::CollEnd { coll, gen } => {
                    self.live_events -= 1;
                    self.on_coll_end(coll, gen)
                }
                EvKind::DvfsTick { rank } => {
                    if self.done() {
                        continue; // don't tick forever after the run
                    }
                    self.on_dvfs_tick(rank)
                }
            }
            // Stop once all hosts finished, devices drained, and every
            // non-DVFS event (incl. stale generations) has been popped —
            // the same stopping point as the old O(events × heap) scan,
            // now three integer compares.
            if self.live_events == 0 && self.done() {
                break;
            }
        }
        self.finish()
    }

    /// O(1) termination predicate via outstanding-work counters. The
    /// debug build cross-checks against the exhaustive scan it replaced.
    fn done(&self) -> bool {
        let fast = self.hosts_unfinished == 0 && self.device_work == 0;
        debug_assert_eq!(fast, self.done_scan(), "termination counters drifted");
        fast
    }

    /// Attach a streaming trace sink: events are handed over at emission
    /// and `SimOutput.trace.events` comes back empty (read them from the
    /// sink's store). Dropout-fault runs fall back to buffered feeding —
    /// their global time-shift rewrite in `finish()` needs the vector —
    /// so the sink still receives every (shifted) event, just not
    /// incrementally.
    pub fn set_sink(&mut self, sink: Box<dyn TraceSink>) {
        self.sink_streams = self.faults.dropout().is_none();
        self.sink = Some(sink);
    }

    /// The pre-refactor exhaustive check (kept as the debug-mode oracle).
    fn done_scan(&self) -> bool {
        (0..self.ranks.len()).all(|r| {
            self.ranks[r].item_idx >= self.program.items.len() && self.rank_idle(r)
        })
    }

    fn finish(mut self) -> SimOutput {
        // GPU dropout + checkpoint-restart: the dying rank takes its whole
        // collective group down with it, so the schedule replays from the
        // last checkpoint boundary (start of the iteration in progress at
        // the failure) plus a fixed restart cost. Replayed work is
        // identical to the first attempt (same seeds), so the whole effect
        // is a rigid time shift of everything from that iteration on —
        // which makes time-lost-to-failure an exact, first-class quantity.
        let mut restart_spans: Vec<(f64, f64)> = Vec::new();
        let mut fault_lost_ns = 0.0;
        if let Some(plan) = self.faults.dropout() {
            let hit = self
                .iter_bounds
                .iter()
                .position(|&(_, e)| e > 0.0 && e > plan.at_ns);
            if let Some(k) = hit {
                let ck_start = self.iter_bounds[k].0;
                let delta = (plan.at_ns - ck_start).max(0.0) + plan.restart_ns;
                let k32 = k as u32;
                for e in &mut self.events {
                    if e.iter >= k32 {
                        e.t_launch += delta;
                        e.t_start += delta;
                        e.t_end += delta;
                    }
                }
                for b in &mut self.iter_bounds[k..] {
                    b.0 += delta;
                    b.1 += delta;
                }
                // Power samples shift with their iteration; sampled energy
                // filters by iteration index, so energy accounting is
                // unchanged by the shift.
                for s in &mut self.power.samples {
                    if s.iter >= k32 {
                        s.t += delta;
                    }
                }
                self.now += delta;
                restart_spans.push((ck_start, ck_start + delta));
                fault_lost_ns = delta;
            }
        }
        // total_cmp: NaN timestamps (impossible today) would order
        // deterministically instead of silently comparing Equal.
        self.events.sort_by(|a, b| a.t_start.total_cmp(&b.t_start));
        if let Some(s) = self.sink.as_mut() {
            // Buffered-fallback streaming (dropout runs): feed the sink
            // only now, after the time-shift rewrite and sort. On the
            // streaming path `events` is already empty and this is a no-op
            // apart from the final flush.
            for e in &self.events {
                s.event(e);
            }
            s.advance(u32::MAX);
            self.events = Vec::new();
        }
        self.host.span_ns = self.now;
        let gov_energy_j: Vec<f64> =
            self.ranks.iter().map(|r| r.gov.energy_j()).collect();
        let mut trace = Trace::default();
        trace.meta.workload = self.wl.label();
        trace.meta.fsdp = self.wl.fsdp.to_string();
        // Folded traces carry the *simulated* shape (the events really in
        // the trace) plus the fold factor; logical shape is derivable
        // (`meta.logical_nodes() == num_nodes × fold`). Exact mode stamps
        // fold 1, which serializers omit — byte-identical to the old meta.
        trace.meta.num_gpus = self.topo.sim_world();
        trace.meta.num_nodes = self.topo.sim_nodes();
        trace.meta.gpus_per_node = self.topo.gpus_per_node();
        trace.meta.fold = self.topo.fold_factor();
        trace.meta.sharding = self.wl.sharding.to_string();
        trace.meta.iterations = self.wl.iterations;
        trace.meta.warmup = self.wl.warmup;
        trace.meta.seed = self.wl.seed;
        trace.meta.source = "sim".into();
        trace.meta.serialized = false;
        if !self.faults.is_empty() {
            trace.meta.faults = crate::config::faults::set_label(&self.params.faults);
            trace.meta.fault_slowdown = self.faults.slowdowns();
            trace.meta.restart_spans = restart_spans;
            trace.meta.fault_lost_ns = fault_lost_ns;
        }
        trace.events = self.events;
        SimOutput {
            trace,
            power: self.power,
            host: self.host,
            alloc: self.alloc,
            iter_bounds: self.iter_bounds,
            gov_energy_j,
        }
    }
}
