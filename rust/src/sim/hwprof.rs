//! Hardware-profiling pass: the rocprofv3 constraint model.
//!
//! Section III-B2: "Only a limited number of performance counters can be
//! collected at a time (e.g., we collect two or three at a time). However,
//! collecting performance counters forces GPU kernels to be serialized."
//!
//! So this pass re-executes the dispatch program with *everything
//! serialized* (collectives run inline in the compute stream, no C3
//! overlap, no DVFS pressure → near-peak clocks) and records the requested
//! counters per kernel, a few at a time across multiple passes. Its
//! timestamps are deliberately useless for overlap analysis — exactly the
//! paper's constraint — and the alignment stage joins counters to the
//! runtime trace by (gpu, stream, seq).

use crate::config::{ModelConfig, NodeSpec, Topology, WorkloadConfig};
use crate::counters::{collection_passes, Counter, CounterTrace, CounterValues};
use crate::fsdp::{build_program_topo, DispatchItem};
use crate::sim::duration::DurationModel;
use crate::sim::interconnect::group_collective_base_ns;
use crate::trace::event::Stream;

/// Key a kernel the same way the runtime engine does: per-(gpu, stream)
/// sequence numbers, packed so a single u64 distinguishes the streams.
pub fn align_key(stream: Stream, seq: u64) -> u64 {
    seq * 2
        + match stream {
            Stream::Compute => 0,
            Stream::Comm => 1,
        }
}

/// Run the multi-pass counter collection on a single node. `per_pass`
/// mirrors the paper's "two or three at a time".
pub fn collect_counters(
    node: &NodeSpec,
    cfg: &ModelConfig,
    wl: &WorkloadConfig,
    counters: &[Counter],
    per_pass: usize,
) -> CounterTrace {
    collect_counters_topo(&Topology::single(node.clone()), cfg, wl, counters, per_pass)
}

/// [`collect_counters`] over a full cluster topology: the serialized
/// program matches the runtime program (HSDP included, so comm-stream seq
/// numbers align), and records replicate across all `world_size()` ranks.
pub fn collect_counters_topo(
    topo: &Topology,
    cfg: &ModelConfig,
    wl: &WorkloadConfig,
    counters: &[Counter],
    per_pass: usize,
) -> CounterTrace {
    let node = &topo.node;
    let program = build_program_topo(cfg, wl, topo);
    let dur = DurationModel::new(node.gpu.clone(), wl.batch, cfg.q_heads);
    let mut out = CounterTrace::default();

    for pass in collection_passes(counters, per_pass) {
        // Every rank executes the identical serialized program; counter
        // values are deterministic, so collect rank 0 and replicate.
        let mut seq_compute = 0u64;
        let mut seq_comm = 0u64;
        let mut values: Vec<(u64, CounterValues)> = Vec::new();
        for item in &program.items {
            match item {
                DispatchItem::Kernel(k) => {
                    let t = dur.timing(&k.desc);
                    let key = align_key(Stream::Compute, seq_compute);
                    seq_compute += 1;
                    let mut v = CounterValues::default();
                    for c in &pass {
                        let x = match c {
                            // Work cycles at peak clock: the serialized run
                            // executes uncontended, so C_gpu ≈ nominal
                            // duration × peak frequency (Eq. 10's C_gpu).
                            Counter::GpuCycles => {
                                t.nominal_ns * node.gpu.freq_peak_mhz * 1e-3
                            }
                            Counter::MfmaBusyCycles => {
                                t.nominal_ns
                                    * node.gpu.freq_peak_mhz
                                    * 1e-3
                                    * t.mfma_util
                            }
                            Counter::ValuBusyCycles => {
                                t.nominal_ns
                                    * node.gpu.freq_peak_mhz
                                    * 1e-3
                                    * t.mem_bound_frac.max(0.05)
                            }
                            Counter::TccReadBytes => k.desc.bytes * 0.6,
                            Counter::TccWriteBytes => k.desc.bytes * 0.4,
                            Counter::FlopsPerformed => t.performed_flops,
                            Counter::GridWorkgroups => t.workgroups as f64,
                        };
                        v.set(*c, x);
                    }
                    values.push((key, v));
                }
                DispatchItem::Comm(c) => {
                    // Serialized collectives still execute (and get
                    // counters), but their durations are meaningless for
                    // overlap analysis.
                    let ns = group_collective_base_ns(topo, c.group, c.bytes);
                    let key = align_key(Stream::Comm, seq_comm);
                    seq_comm += 1;
                    let mut v = CounterValues::default();
                    for cn in &pass {
                        let x = match cn {
                            Counter::GpuCycles => {
                                ns * node.gpu.freq_peak_mhz * 1e-3
                            }
                            Counter::TccReadBytes => c.bytes * 0.5,
                            Counter::TccWriteBytes => c.bytes * 0.5,
                            _ => 0.0,
                        };
                        v.set(*cn, x);
                    }
                    values.push((key, v));
                }
                _ => {}
            }
        }
        // Records replicate across the ranks the trace actually holds —
        // the simulated world under replica folding (== world_size() in
        // exact mode).
        for gpu in 0..topo.sim_world() {
            for (key, v) in &values {
                match out.get(gpu, *key) {
                    Some(_) => {
                        // Merge this pass's counters into the record.
                        let mut merged = out.get(gpu, *key).unwrap().clone();
                        merged.merge(v);
                        out.insert(gpu, *key, merged);
                    }
                    None => out.insert(gpu, *key, v.clone()),
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FsdpVersion;

    fn setup() -> (NodeSpec, ModelConfig, WorkloadConfig) {
        let node = NodeSpec::mi300x_node();
        let mut cfg = ModelConfig::llama3_8b();
        cfg.layers = 2;
        let mut wl = WorkloadConfig::new(1, 4096, FsdpVersion::V1);
        wl.iterations = 1;
        wl.warmup = 0;
        (node, cfg, wl)
    }

    #[test]
    fn all_counters_collected_across_passes() {
        let (node, cfg, wl) = setup();
        let trace = collect_counters(&node, &cfg, &wl, &Counter::ALL, 3);
        // First compute kernel of gpu 0 has all 7 counters.
        let v = trace.get(0, align_key(Stream::Compute, 0)).unwrap();
        assert_eq!(v.len(), Counter::ALL.len());
    }

    #[test]
    fn per_pass_limit_respected_by_construction() {
        let passes = collection_passes(&Counter::ALL, 3);
        assert_eq!(passes.len(), 3);
        assert!(passes.iter().all(|p| p.len() <= 3));
    }

    #[test]
    fn gemm_kernels_have_mfma_cycles() {
        let (node, cfg, wl) = setup();
        let trace = collect_counters(&node, &cfg, &wl, &Counter::ALL, 3);
        // Scan for a kernel with MFMA activity.
        let mut found = false;
        for seq in 0..200u64 {
            if let Some(v) = trace.get(0, align_key(Stream::Compute, seq)) {
                if v.get(Counter::MfmaBusyCycles).unwrap_or(0.0) > 0.0 {
                    found = true;
                    break;
                }
            }
        }
        assert!(found, "no MFMA-active kernel in the first 200");
    }

    #[test]
    fn counters_replicated_across_gpus() {
        let (node, cfg, wl) = setup();
        let trace = collect_counters(&node, &cfg, &wl, &[Counter::GpuCycles], 3);
        let a = trace.get(0, align_key(Stream::Compute, 5)).unwrap();
        let b = trace.get(7, align_key(Stream::Compute, 5)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn comm_kernels_have_bytes_but_no_flops() {
        let (node, cfg, wl) = setup();
        let trace = collect_counters(&node, &cfg, &wl, &Counter::ALL, 3);
        let v = trace.get(0, align_key(Stream::Comm, 0)).unwrap();
        assert!(v.get(Counter::TccReadBytes).unwrap() > 0.0);
        assert_eq!(v.get(Counter::FlopsPerformed).unwrap(), 0.0);
        assert_eq!(v.get(Counter::MfmaBusyCycles).unwrap(), 0.0);
    }
}
