//! Kernel timing model: roofline + a rocBLAS-like tile-selection model.
//!
//! For each kernel the model produces the *nominal* duration (at peak clock
//! with no C3 contention) plus the microarchitectural quantities the
//! hardware-profiling pass reports as counters: performed flops (padding →
//! instruction overhead, Eq. 7), MFMA utilization (Eq. 8), workgroup count
//! (occupancy). The event loop then stretches the nominal duration through
//! the fluid contention/DVFS model.
//!
//! The b1 backward-FlashAttention pathology (Insight 1) lives here: at
//! batch·heads below the CU count the backward kernel selection falls back
//! to a non-split-KV variant whose grid cannot fill the GPU.

use crate::config::GpuSpec;
use crate::model::graph::KernelDesc;
use crate::model::ops::{OpKind, OpType, Phase};

/// Timing + counter facts about one kernel instance.
#[derive(Debug, Clone, Copy)]
pub struct KernelTiming {
    /// Nominal duration at peak clock, no contention (ns).
    pub nominal_ns: f64,
    /// Flops actually executed (>= theoretical; padding).
    pub performed_flops: f64,
    /// MFMA busy fraction during the kernel, in [0,1].
    pub mfma_util: f64,
    /// Workgroups launched.
    pub workgroups: u64,
    /// Fraction of nominal time bound by memory (0 = pure compute).
    pub mem_bound_frac: f64,
}

/// One entry of the GEMM tile library.
#[derive(Debug, Clone, Copy)]
struct Tile {
    m: u64,
    n: u64,
    /// MFMA pipeline efficiency when this tile is fully occupied.
    eff: f64,
}

const TILE_LIBRARY: [Tile; 5] = [
    Tile { m: 256, n: 128, eff: 0.90 },
    Tile { m: 128, n: 128, eff: 0.84 },
    Tile { m: 128, n: 64, eff: 0.74 },
    Tile { m: 64, n: 64, eff: 0.58 },
    Tile { m: 64, n: 16, eff: 0.34 },
];

/// Fixed kernel launch/teardown cost on the GPU (ns).
const KERNEL_FIXED_NS: f64 = 3_000.0;
/// Achievable fraction of HBM peak for streaming vector kernels.
const HBM_EFF: f64 = 0.72;
/// Achievable fraction of HBM peak for strided copies.
const COPY_EFF: f64 = 0.55;

#[derive(Debug, Clone)]
pub struct DurationModel {
    pub gpu: GpuSpec,
    /// batch size (kernel-selection inputs).
    pub batch: u64,
    pub q_heads: u64,
}

impl DurationModel {
    pub fn new(gpu: GpuSpec, batch: u64, q_heads: u64) -> Self {
        Self {
            gpu,
            batch,
            q_heads,
        }
    }

    /// Pick the best tile for a GEMM: maximize effective throughput
    /// = tile_eff * wave_efficiency / padding_ratio.
    fn select_gemm_tile(&self, m: u64, n: u64) -> (Tile, f64, u64) {
        let cus = self.gpu.compute_units as u64;
        let mut best: Option<(Tile, f64, u64, f64)> = None;
        for t in TILE_LIBRARY {
            let wgs = m.div_ceil(t.m) * n.div_ceil(t.n);
            // Wave quantization: the last wave may be mostly idle.
            let waves = wgs.div_ceil(cus);
            let wave_eff = wgs as f64 / (waves * cus) as f64;
            let padded = (m.div_ceil(t.m) * t.m) as f64 * (n.div_ceil(t.n) * t.n) as f64;
            let pad_ratio = padded / (m as f64 * n as f64);
            let score = t.eff * wave_eff / pad_ratio;
            if best.map(|b| score > b.3).unwrap_or(true) {
                best = Some((t, pad_ratio, wgs, score));
            }
        }
        let (t, pad, wgs, _) = best.expect("non-empty tile library");
        (t, pad, wgs)
    }

    /// Compute timing for one kernel.
    pub fn timing(&self, k: &KernelDesc) -> KernelTiming {
        match k.kind {
            OpKind::Gemm => self.gemm_timing(k),
            OpKind::FlashAttn => self.fa_timing(k),
            OpKind::Vector => self.vector_timing(k, HBM_EFF),
            OpKind::Copy => self.vector_timing(k, COPY_EFF),
            OpKind::Comm => {
                // Collectives are timed by the interconnect model; this
                // path is only hit for per-kernel accounting.
                KernelTiming {
                    nominal_ns: 0.0,
                    performed_flops: k.flops,
                    mfma_util: 0.0,
                    workgroups: self.gpu.compute_units as u64 / 4,
                    mem_bound_frac: 1.0,
                }
            }
        }
    }

    fn gemm_timing(&self, k: &KernelDesc) -> KernelTiming {
        let (m, n, kk) = k.gemm_mnk.unwrap_or((1, 1, 1));
        let (tile, pad_ratio, wgs) = self.select_gemm_tile(m, n);
        let waves = wgs.div_ceil(self.gpu.compute_units as u64);
        let wave_eff = wgs as f64 / (waves * self.gpu.compute_units as u64) as f64;
        // Deep-K GEMMs amortize prologue better.
        let k_eff = (kk as f64 / (kk as f64 + 512.0)).clamp(0.3, 1.0);
        let util = (tile.eff * wave_eff * k_eff).clamp(0.02, 0.95);
        let performed = k.flops * pad_ratio;
        let compute_ns = performed / (self.gpu.peak_bf16_flops * util) * 1e9;
        let mem_ns = k.bytes / (self.gpu.hbm_bw * HBM_EFF) * 1e9;
        let nominal = compute_ns.max(mem_ns) + KERNEL_FIXED_NS;
        KernelTiming {
            nominal_ns: nominal,
            performed_flops: performed,
            // The counter-visible MFMA busy fraction over the whole kernel.
            mfma_util: (compute_ns / nominal * util).min(util),
            workgroups: wgs,
            mem_bound_frac: (mem_ns / nominal).min(1.0),
        }
    }

    fn fa_timing(&self, k: &KernelDesc) -> KernelTiming {
        // FlashAttention interleaves MFMA with softmax vector work, capping
        // MFMA utilization well below GEMM (Section V-G3).
        let (base_util, grid_scale) = match (k.op.phase, k.name.as_str()) {
            (Phase::Forward, _) => (0.44, 1.0),
            // The FA2 backward splits into delta/dkdv/dq; the delta
            // pre-pass is pure vector work.
            (_, name) if name.contains("delta") => (0.02, 1.0),
            (_, _) => (0.34, 1.0),
        };
        // Kernel-selection pathology (Insight 1): the backward kernels at
        // batch size one select a non-split-KV variant whose grid is only
        // batch*heads workgroups — it cannot fill 304 CUs, so effective
        // utilization collapses. (Forward uses a q-block-parallel grid and
        // is unaffected.)
        let pathological = k.op.phase == Phase::Backward
            && k.op.op == OpType::AttnFa
            && !k.name.as_str().contains("delta")
            && self.batch == 1;
        let util = if pathological {
            let grid = (self.batch * self.q_heads) as f64 * grid_scale;
            let occupancy =
                (grid / self.gpu.compute_units as f64).min(1.0).max(0.08);
            // Partial recovery from multiple waves per CU, but far from full.
            base_util * (0.30 + 0.70 * occupancy)
        } else {
            base_util
        };
        let performed = k.flops;
        let compute_ns = performed / (self.gpu.peak_bf16_flops * util) * 1e9;
        let mem_ns = k.bytes / (self.gpu.hbm_bw * HBM_EFF) * 1e9;
        let nominal = compute_ns.max(mem_ns) + KERNEL_FIXED_NS;
        let wgs = if pathological {
            self.batch * self.q_heads
        } else {
            self.batch * self.q_heads * 32
        };
        KernelTiming {
            nominal_ns: nominal,
            performed_flops: performed,
            mfma_util: (compute_ns / nominal * util).min(util),
            workgroups: wgs,
            mem_bound_frac: (mem_ns / nominal).min(1.0),
        }
    }

    fn vector_timing(&self, k: &KernelDesc, eff: f64) -> KernelTiming {
        // Memory-bound: bytes over effective HBM bandwidth; small kernels
        // are latency-bound via the fixed cost.
        let mem_ns = k.bytes / (self.gpu.hbm_bw * eff) * 1e9;
        let valu_ns = k.flops / self.gpu.peak_vector_flops * 1e9;
        let nominal = mem_ns.max(valu_ns) + KERNEL_FIXED_NS;
        KernelTiming {
            nominal_ns: nominal,
            performed_flops: 0.0, // no MFMA flops
            mfma_util: 0.0,
            workgroups: ((k.bytes / 65536.0) as u64).clamp(1, 4096),
            mem_bound_frac: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::graph::build_iteration;
    use crate::model::ops::OpRef;

    fn model(batch: u64) -> DurationModel {
        DurationModel::new(GpuSpec::mi300x(), batch, 32)
    }

    fn kernels_of(
        batch: u64,
        seq: u64,
        op: OpType,
        phase: Phase,
    ) -> Vec<KernelDesc> {
        let cfg = ModelConfig::llama3_8b();
        let p = build_iteration(&cfg, batch, seq, 8, true);
        let ops: Vec<_> = match phase {
            Phase::Forward => p.fwd,
            Phase::Backward => p.bwd,
            Phase::Optimizer => p.opt,
        };
        ops.into_iter()
            .filter(|o| o.op.op == op)
            .flat_map(|o| o.kernels)
            .collect()
    }

    fn op_nominal(batch: u64, seq: u64, op: OpType, phase: Phase) -> f64 {
        let m = model(batch);
        // Per-layer duration: sum of kernels of one op instance.
        let ks = kernels_of(batch, seq, op, phase);
        let per_layer = ks.len() / 32.max(1);
        ks.iter()
            .take(per_layer.max(1))
            .map(|k| m.timing(k).nominal_ns)
            .sum()
    }

    #[test]
    fn big_gemm_hits_high_utilization() {
        let m = model(2);
        let k = KernelDesc {
            name: "g".into(),
            op: OpRef::fwd(OpType::MlpUp),
            layer: Some(0),
            kind: OpKind::Gemm,
            flops: 2.0 * 8192.0 * 14336.0 * 4096.0,
            bytes: 2.0 * (8192.0 * 4096.0 + 4096.0 * 14336.0 + 8192.0 * 14336.0),
            gemm_mnk: Some((8192, 14336, 4096)),
        };
        let t = m.timing(&k);
        assert!(t.mfma_util > 0.6, "util {}", t.mfma_util);
        // ~9.6e11 flops at ~1e15 flop/s -> ~1 ms.
        assert!(t.nominal_ns > 5e5 && t.nominal_ns < 5e6, "{}", t.nominal_ns);
    }

    #[test]
    fn skinny_gemm_pays_occupancy_and_padding() {
        let m = model(1);
        let k = KernelDesc {
            name: "g".into(),
            op: OpRef::fwd(OpType::AttnOp),
            layer: Some(0),
            kind: OpKind::Gemm,
            flops: 2.0 * 100.0 * 100.0 * 4096.0,
            bytes: 2.0 * (100.0 * 4096.0 * 2.0 + 100.0 * 100.0),
            gemm_mnk: Some((100, 100, 4096)),
        };
        let t = m.timing(&k);
        assert!(t.performed_flops > k.flops, "padding expected");
        assert!(t.mfma_util < 0.3, "util {}", t.mfma_util);
    }

    #[test]
    fn bwd_fa_batch1_slower_than_batch2_despite_fewer_flops() {
        // Insight 1 — the headline pathology.
        let d1 = op_nominal(1, 4096, OpType::AttnFa, Phase::Backward);
        let d2 = op_nominal(2, 4096, OpType::AttnFa, Phase::Backward);
        assert!(
            d1 > d2,
            "b1 bwd FA ({d1:.0} ns) should exceed b2 ({d2:.0} ns)"
        );
        // And at 8k too.
        let d1 = op_nominal(1, 8192, OpType::AttnFa, Phase::Backward);
        let d2 = op_nominal(2, 8192, OpType::AttnFa, Phase::Backward);
        assert!(d1 > d2);
    }

    #[test]
    fn fwd_fa_scales_normally_with_batch() {
        let d1 = op_nominal(1, 4096, OpType::AttnFa, Phase::Forward);
        let d2 = op_nominal(2, 4096, OpType::AttnFa, Phase::Forward);
        assert!(d2 > d1 * 1.6, "fwd FA should ~double: {d1} -> {d2}");
    }

    #[test]
    fn fa_util_below_gemm_util() {
        // Section V-G3: utilization overhead particularly high for FA.
        let m = model(2);
        let fa = kernels_of(2, 4096, OpType::AttnFa, Phase::Forward);
        let gemm = kernels_of(2, 4096, OpType::MlpUp, Phase::Forward);
        let fa_util = m.timing(&fa[0]).mfma_util;
        let gemm_util = m.timing(&gemm[0]).mfma_util;
        assert!(fa_util < gemm_util);
    }

    #[test]
    fn vector_kernels_have_zero_mfma() {
        let m = model(2);
        let norm = kernels_of(2, 4096, OpType::AttnN, Phase::Forward);
        let t = m.timing(&norm[0]);
        assert_eq!(t.mfma_util, 0.0);
        assert!(t.nominal_ns > KERNEL_FIXED_NS);
    }

    #[test]
    fn timing_is_deterministic() {
        let m = model(2);
        let ks = kernels_of(2, 4096, OpType::MlpDp, Phase::Forward);
        let a = m.timing(&ks[0]);
        let b = m.timing(&ks[0]);
        assert_eq!(a.nominal_ns, b.nominal_ns);
    }
}
