//! Host-CPU utilization model (the substrate behind Fig. 13).
//!
//! Mechanisms (DESIGN.md §5.6): one trainer process per GPU whose main
//! Python thread busy-polls the device between dispatches (near-100%
//! logical-core utilization), plus a handful of low-utilization helper
//! threads per rank (RCCL progress threads, dataloader worker, profiler
//! writer). The OS scheduler gives every runnable thread its own physical
//! core while physical cores outnumber runnable threads — SMT siblings are
//! co-scheduled only rarely — which is exactly why the paper sees only
//! 12.5% of physical cores ever active and a heatmap with almost no
//! sibling pairs.

use crate::config::NodeSpec;
use crate::sim::engine::HostActivity;
use crate::trace::event::{CpuSample, CpuTrace};
use crate::util::prng::Rng;

#[derive(Debug, Clone)]
pub struct HostModelParams {
    /// Busy-poll floor of the trainer main thread (fraction of a window it
    /// spins waiting on the device even when not dispatching).
    pub spin_floor: f64,
    /// Helper threads per rank (RCCL progress ×2, dataloader, misc).
    pub helpers_per_rank: u32,
    /// Mean utilization of a helper thread, percent.
    pub helper_util_pct: f64,
    /// Per-window probability that a thread migrates to a new core.
    pub migrate_p: f64,
    /// Emit one CpuSample every `sample_every` host windows.
    pub sample_every: u32,
}

impl Default for HostModelParams {
    fn default() -> Self {
        Self {
            spin_floor: 0.92,
            helpers_per_rank: 2,
            helper_util_pct: 6.0,
            migrate_p: 0.0001,
            sample_every: 10,
        }
    }
}

/// A modelled host thread.
struct Thread {
    /// Rank it belongs to.
    rank: usize,
    /// Main trainer thread (busy-polls) or helper.
    main: bool,
    /// Current logical core.
    core: u32,
}

/// Pick a logical core whose physical core is unoccupied if possible —
/// the SMT-sibling-avoiding placement the paper observes.
fn place(occupied: &mut Vec<bool>, logical: u32, physical: u32, rng: &mut Rng) -> u32 {
    // occupied is indexed by physical core.
    for _ in 0..64 {
        let cand = rng.range_u64(0, logical as u64) as u32;
        let phys = cand % physical;
        if !occupied[phys as usize] {
            occupied[phys as usize] = true;
            return cand;
        }
    }
    // Fall back to sharing a physical core (rare).
    rng.range_u64(0, logical as u64) as u32
}

/// Expand per-rank host busy time into a per-logical-core utilization
/// trace.
pub fn cpu_trace(
    node: &NodeSpec,
    host: &HostActivity,
    seed: u64,
    params: &HostModelParams,
) -> CpuTrace {
    let logical = node.cpu.logical_cores();
    let physical = node.cpu.physical_cores();
    let mut rng = Rng::substream(seed, "hostcpu");
    let mut occupied = vec![false; physical as usize];

    let ranks = host.busy.len();
    let mut threads = Vec::new();
    for r in 0..ranks {
        threads.push(Thread {
            rank: r,
            main: true,
            core: place(&mut occupied, logical, physical, &mut rng),
        });
        for _ in 0..params.helpers_per_rank {
            threads.push(Thread {
                rank: r,
                main: false,
                core: place(&mut occupied, logical, physical, &mut rng),
            });
        }
    }

    let w = host.window_ns;
    let windows = (host.span_ns / w).ceil() as u64;
    let mut out = CpuTrace {
        logical_cores: logical,
        smt: node.cpu.smt,
        samples: Vec::new(),
    };
    let step = params.sample_every.max(1) as u64;
    for widx in (0..windows.max(1)).step_by(step as usize) {
        let mut core_util: Vec<(u32, f64)> = Vec::with_capacity(threads.len());
        for th in threads.iter_mut() {
            // Occasional migration.
            if rng.bool(params.migrate_p) {
                let phys = th.core % physical;
                occupied[phys as usize] = false;
                th.core = place(&mut occupied, logical, physical, &mut rng);
            }
            let util = if th.main {
                let busy = host.busy_ns(th.rank, widx);
                let dispatch_frac = (busy / w).min(1.0);
                ((params.spin_floor + (1.0 - params.spin_floor) * dispatch_frac)
                    * 100.0
                    + rng.normal(0.0, 1.5))
                .clamp(0.0, 100.0)
            } else {
                (params.helper_util_pct * (0.4 + 1.2 * rng.f64())).clamp(0.1, 100.0)
            };
            if util > 0.0 {
                core_util.push((th.core, util));
            }
        }
        // Merge duplicate cores (possible after fallback placement).
        core_util.sort_by_key(|(c, _)| *c);
        let mut merged: Vec<(u32, f64)> = Vec::with_capacity(core_util.len());
        for (c, u) in core_util {
            match merged.last_mut() {
                Some((lc, lu)) if *lc == c => *lu = (*lu + u).min(100.0),
                _ => merged.push((c, u)),
            }
        }
        out.samples.push(CpuSample {
            t: widx as f64 * w,
            core_util: merged,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn host_activity(ranks: usize, windows: u64, busy_frac: f64) -> HostActivity {
        let w = 1_000_000.0;
        let busy = vec![vec![w * busy_frac; windows as usize]; ranks];
        HostActivity {
            window_ns: w,
            busy,
            span_ns: windows as f64 * w,
        }
    }

    #[test]
    fn active_cores_modest_vs_total() {
        let node = NodeSpec::mi300x_node();
        let host = host_activity(8, 100, 0.1);
        let t = cpu_trace(&node, &host, 7, &HostModelParams::default());
        let s = &t.samples[3];
        // 8 mains + 16 helpers = 24-ish active of 384 logical.
        assert!(s.core_util.len() >= 20 && s.core_util.len() <= 26,
                "{} active", s.core_util.len());
    }

    #[test]
    fn main_threads_near_full_utilization() {
        let node = NodeSpec::mi300x_node();
        let host = host_activity(8, 50, 0.5);
        let t = cpu_trace(&node, &host, 7, &HostModelParams::default());
        let s = &t.samples[1];
        let high = s.core_util.iter().filter(|(_, u)| *u > 80.0).count();
        assert_eq!(high, 8, "one near-full core per rank");
    }

    #[test]
    fn smt_siblings_rarely_coscheduled() {
        let node = NodeSpec::mi300x_node();
        let host = host_activity(8, 200, 0.2);
        let t = cpu_trace(&node, &host, 11, &HostModelParams::default());
        let phys = node.cpu.physical_cores();
        let mut sibling_windows = 0usize;
        for s in &t.samples {
            let mut seen = std::collections::HashSet::new();
            for (c, _) in &s.core_util {
                if !seen.insert(c % phys) {
                    sibling_windows += 1;
                    break;
                }
            }
        }
        assert!(
            sibling_windows * 10 <= t.samples.len(),
            "siblings co-scheduled in {}/{} windows",
            sibling_windows,
            t.samples.len()
        );
    }

    #[test]
    fn physical_core_footprint_small() {
        // Insight 7: only ~12.5% of physical cores ever active.
        let node = NodeSpec::mi300x_node();
        let host = host_activity(8, 300, 0.2);
        let t = cpu_trace(&node, &host, 13, &HostModelParams::default());
        let phys = node.cpu.physical_cores();
        let mut ever = std::collections::HashSet::new();
        for s in &t.samples {
            for (c, _) in &s.core_util {
                ever.insert(c % phys);
            }
        }
        let frac = ever.len() as f64 / phys as f64;
        assert!(frac < 0.25, "footprint {frac}");
    }

    #[test]
    fn deterministic() {
        let node = NodeSpec::mi300x_node();
        let host = host_activity(4, 20, 0.3);
        let a = cpu_trace(&node, &host, 3, &HostModelParams::default());
        let b = cpu_trace(&node, &host, 3, &HostModelParams::default());
        assert_eq!(a.samples.len(), b.samples.len());
        assert_eq!(a.samples[1].core_util, b.samples[1].core_util);
    }
}
