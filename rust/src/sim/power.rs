//! The pluggable power-management subsystem: a [`GovernorPolicy`] trait
//! over the per-window frequency/power decision, four concrete policies,
//! and per-policy energy integration.
//!
//! The paper's headline result is that DVFS frequency overhead is the
//! single largest contributor to the theoretical-vs-observed gap, and its
//! stated payoff is *improving power-management strategies* — which makes
//! the governor exactly the mechanism worth making explorable. Before this
//! module the engine hard-coded one policy ([`DvfsGovernor`], the
//! margin-tracking reactive firmware model behind Observation 6 /
//! Insight 8); now the policy is a seeded, deterministic trait object the
//! engine steps once per window, selected per scenario via
//! [`EngineParams::governor`](crate::sim::EngineParams):
//!
//! | [`GovernorKind`] | Models | Clocks |
//! |---|---|---|
//! | `Reactive` | the stock firmware governor (extracted mechanism, byte-identical) | cap-tracking with a σ-proportional margin |
//! | `FixedCap` | a locked-clock deployment (`rocm-smi --setperflevel` style) | pinned at `fixed_cap_ratio` × peak |
//! | `DeterministicAware` | firmware that trusts a quiet power signal | reactive, margin shrunk when the FSDPv2 allocator's deterministic memory behaviour is detected |
//! | `Oracle` | the Eq. 10 `D_peak` denominator made runnable | peak, power cap ignored |
//!
//! Every policy integrates energy (`Σ power × window`) as it steps, so a
//! run's joules are first-class alongside its nanoseconds — the input to
//! `chopper::whatif`'s perf-per-watt frontier.
//!
//! Determinism contract (DESIGN.md §3/§9): a policy's entire stochastic
//! behaviour comes from the `Rng` substream it is seeded with at
//! construction (`(seed, "dvfs<gpu_idx>")` — the same channel the stock
//! governor used, so `Reactive` is bit-identical to the pre-refactor
//! pipeline); policies never read ambient state, so replaying a workload
//! under a policy set is reproducible byte for byte.

use crate::config::GpuSpec;
use crate::sim::dvfs::DvfsGovernor;
pub use crate::sim::dvfs::WindowActivity;
use crate::util::prng::Rng;
use std::fmt;

/// Floor applied to clock ratios before the engine divides by them — a
/// policy bug (or a hostile `fixed_cap_ratio`) must never turn the
/// progress-rate math into a divide-by-zero. Shared by the clamped
/// accessors below; the engine consumes only the clamped forms.
pub const MIN_FREQ_RATIO: f64 = 0.05;

/// Package-power model coefficients (see [`package_power_w`]): dynamic
/// power of a fully-busy MFMA workload / generic VALU compute / the comm
/// engines, the HBM power at saturation, and the f^2.2 voltage-frequency
/// exponent. One source of truth for every policy *and* the reactive
/// governor's closed-form inversion.
pub const MFMA_PEAK_W: f64 = 760.0;
pub const VALU_PEAK_W: f64 = 150.0;
pub const COMM_ENGINE_W: f64 = 40.0;
pub const HBM_PEAK_W: f64 = 200.0;
pub const FREQ_POWER_EXP: f64 = 2.2;

/// Package power at engine clock `f_mhz` for the given window activity.
///
/// The coefficients make a fully-busy MFMA workload *power-limited* at
/// peak clock (≈775 W > the 750 W cap) — the regime the MI300X actually
/// operates in during GEMM-heavy training, and the precondition for DVFS
/// to matter at all (Insight 8). Shared verbatim by every policy.
pub fn package_power_w(
    gpu: &GpuSpec,
    f_mhz: f64,
    window_ns: f64,
    act: &WindowActivity,
    noise_w: f64,
) -> f64 {
    let fr = f_mhz / gpu.freq_peak_mhz;
    // Dynamic power ~ f^2.2 (voltage scales with f); split into MFMA
    // (dominant), generic compute, and comm-engine terms.
    let mfma_w = MFMA_PEAK_W * act.compute_busy * act.mfma_util;
    let valu_w = VALU_PEAK_W * act.compute_busy * (1.0 - act.mfma_util);
    let comm_w = COMM_ENGINE_W * act.comm_busy;
    let hbm_rate = act.hbm_bytes / (window_ns * 1e-9) / gpu.hbm_bw;
    let hbm_w = HBM_PEAK_W * hbm_rate.min(1.2);
    gpu.idle_power_w
        + (mfma_w + valu_w) * fr.powf(FREQ_POWER_EXP)
        + comm_w
        + hbm_w
        + noise_w
}

/// Allocator-driven HBM power noise for one window: bursty page touches
/// mostly *shift* HBM power between windows, with a smaller genuinely-
/// extra component (fresh-page writes); only manifests while the GPU is
/// actually moving memory. The one stochastic term every policy shares —
/// drawing it from the same substream keeps cross-policy replays
/// comparable window for window.
pub fn hbm_noise_draw(rng: &mut Rng, hbm_noise_w: f64, act: &WindowActivity) -> f64 {
    let busy = act.compute_busy.max(act.comm_busy);
    let n = rng.normal(0.0, hbm_noise_w) * busy;
    n + 1.5 * n.abs()
}

// ---------------------------------------------------------------------------
// The policy trait
// ---------------------------------------------------------------------------

/// One GPU's power-management policy: stepped once per DVFS window by the
/// engine, returning the window's package power and the engine clock the
/// *next* window will run at. Object-safe; every implementation must be
/// deterministic given its construction-time seed (DESIGN.md §9).
pub trait GovernorPolicy: fmt::Debug + Send {
    /// Advance one window: observe activity, update telemetry, pick the
    /// next window's clocks. Returns `(power_w, freq_mhz)`.
    fn step(&mut self, act: &WindowActivity) -> (f64, f64);

    /// Current engine clock, MHz.
    fn freq_mhz(&self) -> f64;

    /// Current memory clock, MHz.
    fn mem_freq_mhz(&self) -> f64;

    /// Engine-clock fraction of peak (unclamped — see the `_clamped`
    /// accessors for what the engine's rate math consumes).
    fn freq_ratio(&self) -> f64;

    /// Memory-clock fraction of peak (unclamped).
    fn mem_freq_ratio(&self) -> f64;

    /// Joules integrated so far: the window-sum of `power × dt` over every
    /// [`step`](Self::step) taken. `tests/props.rs` pins the identity.
    fn energy_j(&self) -> f64;

    /// Which [`GovernorKind`] built this policy.
    fn kind(&self) -> GovernorKind;

    /// Engine-clock ratio with the divide-by-zero floor applied — the only
    /// form the engine's compute-rate math is allowed to consume (the old
    /// per-call-site `.max(0.05)` clamps, deduplicated here).
    fn freq_ratio_clamped(&self) -> f64 {
        self.freq_ratio().max(MIN_FREQ_RATIO)
    }

    /// Memory-clock ratio with the divide-by-zero floor applied.
    fn mem_freq_ratio_clamped(&self) -> f64 {
        self.mem_freq_ratio().max(MIN_FREQ_RATIO)
    }

    /// Thermal telemetry for the window most recently stepped:
    /// `(die °C, throttle factor applied)`. `None` — the default for every
    /// policy without thermal coupling — makes the engine record the
    /// neutral `(0.0, 1.0)` columns, keeping thermal-disabled runs
    /// byte-identical (DESIGN.md §14).
    fn thermal_sample(&self) -> Option<(f64, f64)> {
        None
    }
}

/// Everything a [`GovernorKind`] needs to build its policy for one GPU.
/// Assembled by the engine from the workload, the topology's GPU spec and
/// [`EngineParams`](crate::sim::EngineParams).
#[derive(Debug, Clone)]
pub struct GovCtx<'a> {
    pub gpu: &'a GpuSpec,
    pub seed: u64,
    /// Substream index — the engine passes 0 for every rank (HBM power
    /// noise is common-mode: all GPUs run the identical allocator
    /// pattern), matching the pre-refactor governor wiring.
    pub gpu_idx: u32,
    /// HBM power-noise sigma (W) derived from the allocator behaviour.
    pub hbm_noise_w: f64,
    /// Governor window (ns) — `EngineParams::dvfs_window_ns`, the single
    /// source of truth (previously duplicated as a hard-coded 1 ms).
    pub window_ns: f64,
    /// Margin coefficient: required headroom = `margin_k` × power sigma.
    pub margin_k: f64,
    /// Clock ratio `FixedCap` pins (fraction of peak).
    pub fixed_cap_ratio: f64,
    /// Allocator per-iteration peak σ normalized by the layer weight size
    /// — `DeterministicAware`'s determinism signal (≈0 under FSDPv2).
    pub spike_var: f64,
    /// Thermal coupling for this rank (`None` — the default — disables the
    /// subsystem: no decorator, no substream draws, byte-identical runs).
    pub thermal: Option<crate::sim::thermal::ThermalCtx>,
}

/// Spike-variability threshold below which `DeterministicAware` treats
/// the allocator as deterministic (FSDPv2's pre-sized flat buffers sit at
/// exactly 0; FSDPv1's block churn lands well above).
pub const DET_SPIKE_THRESHOLD: f64 = 0.01;

/// Margin shrink `DeterministicAware` applies once determinism is
/// detected: the power signal is trustworthy, so the firmware keeps only
/// a quarter of the reactive σ-margin.
pub const DET_MARGIN_SHRINK: f64 = 0.25;

/// The selectable policy set — the campaign `--governor` axis and the
/// `chopper whatif` replay space.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum GovernorKind {
    /// The stock margin-tracking firmware governor (the pre-refactor
    /// pipeline, byte-identical).
    Reactive,
    /// Engine/memory clocks pinned at `fixed_cap_ratio` × peak.
    FixedCap,
    /// Reactive, with the σ-margin shrunk when the allocator's memory
    /// behaviour is deterministic (Obs. 6 / Insight 8 acted upon).
    DeterministicAware,
    /// Peak clocks, power cap ignored — Eq. 10's `D_peak` denominator.
    Oracle,
    /// Reactive core with the power cap pre-derated to the steady-state
    /// thermal budget — proactively trades clocks for temperature headroom
    /// (`sim::thermal`). Degenerates to `Reactive` when thermal is off.
    ThermalAware,
}

impl GovernorKind {
    pub const ALL: [GovernorKind; 5] = [
        GovernorKind::Reactive,
        GovernorKind::FixedCap,
        GovernorKind::DeterministicAware,
        GovernorKind::Oracle,
        GovernorKind::ThermalAware,
    ];

    /// Stable identifier: scenario name tags, summary JSON, CLI values.
    pub fn name(&self) -> &'static str {
        match self {
            GovernorKind::Reactive => "reactive",
            GovernorKind::FixedCap => "fixed_cap",
            GovernorKind::DeterministicAware => "det_aware",
            GovernorKind::Oracle => "oracle",
            GovernorKind::ThermalAware => "thermal_aware",
        }
    }

    pub fn parse(s: &str) -> Option<GovernorKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "reactive" => Some(GovernorKind::Reactive),
            "fixed_cap" | "fixedcap" | "fixed-cap" => Some(GovernorKind::FixedCap),
            "det_aware" | "detaware" | "det-aware" | "deterministic" => {
                Some(GovernorKind::DeterministicAware)
            }
            "oracle" => Some(GovernorKind::Oracle),
            "thermal_aware" | "thermalaware" | "thermal-aware" | "thermal" => {
                Some(GovernorKind::ThermalAware)
            }
            _ => None,
        }
    }

    /// Build this kind's policy for one GPU. When a thermal context is
    /// present every policy is wrapped in the
    /// [`ThermallyCoupled`](crate::sim::thermal::ThermallyCoupled)
    /// feedback decorator; with `thermal: None` the policies are returned
    /// bare — exactly the pre-thermal construction.
    pub fn build(&self, ctx: &GovCtx<'_>) -> Box<dyn GovernorPolicy> {
        let inner: Box<dyn GovernorPolicy> = match self {
            GovernorKind::Reactive => Box::new(Reactive::new(ctx)),
            GovernorKind::FixedCap => Box::new(FixedCap::new(ctx)),
            GovernorKind::DeterministicAware => {
                Box::new(DeterministicAware::new(ctx))
            }
            GovernorKind::Oracle => Box::new(Oracle::new(ctx)),
            // ThermalAware handles its own wrapping (the derated core must
            // be built before the decorator goes on).
            GovernorKind::ThermalAware => {
                return crate::sim::thermal::ThermalAware::build(ctx)
            }
        };
        match &ctx.thermal {
            Some(tc) => Box::new(crate::sim::thermal::ThermallyCoupled::new(
                inner, tc, ctx,
            )),
            None => inner,
        }
    }
}

impl fmt::Display for GovernorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Parse a comma-separated governor list ("reactive,oracle").
pub fn parse_list_governor(s: &str) -> Result<Vec<GovernorKind>, String> {
    s.split(',')
        .filter(|t| !t.trim().is_empty())
        .map(|t| {
            GovernorKind::parse(t).ok_or_else(|| {
                let names: Vec<&str> =
                    GovernorKind::ALL.iter().map(|g| g.name()).collect();
                format!("bad governor `{t}` (have: {})", names.join(", "))
            })
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Reactive — the extracted stock governor
// ---------------------------------------------------------------------------

/// The stock margin-tracking firmware governor, extracted as a policy: a
/// thin energy-integrating wrapper over the verbatim [`DvfsGovernor`]
/// mechanism (which the pre-refactor engine baseline still constructs
/// directly — `tests/props.rs` pins the two bit-identical).
#[derive(Debug)]
pub struct Reactive {
    gov: DvfsGovernor,
    energy_j: f64,
}

impl Reactive {
    pub fn new(ctx: &GovCtx<'_>) -> Self {
        Self::with_margin(ctx, ctx.margin_k)
    }

    fn with_margin(ctx: &GovCtx<'_>, margin_k: f64) -> Self {
        Self {
            gov: DvfsGovernor::with_window(
                ctx.gpu.clone(),
                ctx.seed,
                ctx.gpu_idx,
                ctx.hbm_noise_w,
                ctx.window_ns,
                margin_k,
            ),
            energy_j: 0.0,
        }
    }
}

impl GovernorPolicy for Reactive {
    fn step(&mut self, act: &WindowActivity) -> (f64, f64) {
        let (p, f) = self.gov.step(act);
        self.energy_j += p * self.gov.window_ns * 1e-9;
        (p, f)
    }

    fn freq_mhz(&self) -> f64 {
        self.gov.freq_mhz
    }

    fn mem_freq_mhz(&self) -> f64 {
        self.gov.mem_freq_mhz
    }

    fn freq_ratio(&self) -> f64 {
        self.gov.freq_ratio()
    }

    fn mem_freq_ratio(&self) -> f64 {
        self.gov.mem_freq_ratio()
    }

    fn energy_j(&self) -> f64 {
        self.energy_j
    }

    fn kind(&self) -> GovernorKind {
        GovernorKind::Reactive
    }
}

// ---------------------------------------------------------------------------
// FixedCap — locked clocks
// ---------------------------------------------------------------------------

/// Engine and memory clocks pinned at a configurable fraction of peak —
/// a locked-clock deployment. The governor makes no decisions at all; the
/// in-window fast regulator still bounds transient power to 10% above the
/// board cap (locking clocks does not disable the hardware limiter).
#[derive(Debug)]
pub struct FixedCap {
    gpu: GpuSpec,
    freq_mhz: f64,
    mem_freq_mhz: f64,
    window_ns: f64,
    hbm_noise_w: f64,
    rng: Rng,
    energy_j: f64,
}

impl FixedCap {
    pub fn new(ctx: &GovCtx<'_>) -> Self {
        let gpu = ctx.gpu.clone();
        let freq_mhz = (gpu.freq_peak_mhz * ctx.fixed_cap_ratio)
            .clamp(gpu.freq_min_mhz, gpu.freq_peak_mhz);
        let mem_freq_mhz =
            (gpu.mem_freq_peak_mhz * ctx.fixed_cap_ratio).min(gpu.mem_freq_peak_mhz);
        Self {
            freq_mhz,
            mem_freq_mhz,
            window_ns: ctx.window_ns,
            hbm_noise_w: ctx.hbm_noise_w,
            rng: Rng::substream(ctx.seed, &format!("dvfs{}", ctx.gpu_idx)),
            energy_j: 0.0,
            gpu,
        }
    }
}

impl GovernorPolicy for FixedCap {
    fn step(&mut self, act: &WindowActivity) -> (f64, f64) {
        let noise = hbm_noise_draw(&mut self.rng, self.hbm_noise_w, act);
        let power =
            package_power_w(&self.gpu, self.freq_mhz, self.window_ns, act, noise)
                .clamp(self.gpu.idle_power_w, self.gpu.power_cap_w * 1.10);
        self.energy_j += power * self.window_ns * 1e-9;
        (power, self.freq_mhz)
    }

    fn freq_mhz(&self) -> f64 {
        self.freq_mhz
    }

    fn mem_freq_mhz(&self) -> f64 {
        self.mem_freq_mhz
    }

    fn freq_ratio(&self) -> f64 {
        self.freq_mhz / self.gpu.freq_peak_mhz
    }

    fn mem_freq_ratio(&self) -> f64 {
        self.mem_freq_mhz / self.gpu.mem_freq_peak_mhz
    }

    fn energy_j(&self) -> f64 {
        self.energy_j
    }

    fn kind(&self) -> GovernorKind {
        GovernorKind::FixedCap
    }
}

// ---------------------------------------------------------------------------
// DeterministicAware — Insight 8 acted upon
// ---------------------------------------------------------------------------

/// The reactive governor, but the σ-margin shrinks when the allocator's
/// memory behaviour is deterministic (FSDPv2's pre-sized flat buffers ⇒
/// quiet power signal ⇒ the firmware can trust its telemetry and run
/// closer to the cap). On a noisy FSDPv1 workload it degenerates to
/// [`Reactive`] exactly — the margin only shrinks when shrinking is safe,
/// which is precisely the paper's Obs. 6 / Insight 8 recommendation.
#[derive(Debug)]
pub struct DeterministicAware {
    inner: Reactive,
    /// Allocator determinism was detected at construction.
    pub detected: bool,
}

impl DeterministicAware {
    pub fn new(ctx: &GovCtx<'_>) -> Self {
        let detected = ctx.spike_var < DET_SPIKE_THRESHOLD;
        let margin_k = if detected {
            ctx.margin_k * DET_MARGIN_SHRINK
        } else {
            ctx.margin_k
        };
        Self {
            inner: Reactive::with_margin(ctx, margin_k),
            detected,
        }
    }
}

impl GovernorPolicy for DeterministicAware {
    fn step(&mut self, act: &WindowActivity) -> (f64, f64) {
        self.inner.step(act)
    }

    fn freq_mhz(&self) -> f64 {
        self.inner.freq_mhz()
    }

    fn mem_freq_mhz(&self) -> f64 {
        self.inner.mem_freq_mhz()
    }

    fn freq_ratio(&self) -> f64 {
        self.inner.freq_ratio()
    }

    fn mem_freq_ratio(&self) -> f64 {
        self.inner.mem_freq_ratio()
    }

    fn energy_j(&self) -> f64 {
        self.inner.energy_j()
    }

    fn kind(&self) -> GovernorKind {
        GovernorKind::DeterministicAware
    }
}

// ---------------------------------------------------------------------------
// Oracle — Eq. 10's D_peak denominator
// ---------------------------------------------------------------------------

/// Peak clocks, power cap ignored: what the run would cost if frequency
/// were never the bottleneck — the runnable form of Eq. 10's `D_peak`
/// denominator. Power is reported honestly (it *exceeds* the board cap on
/// MFMA-heavy windows; that excess is the physical reason the reactive
/// governor must throttle), so the oracle's energy quantifies what
/// peak-clock performance would cost in joules.
#[derive(Debug)]
pub struct Oracle {
    gpu: GpuSpec,
    window_ns: f64,
    hbm_noise_w: f64,
    rng: Rng,
    energy_j: f64,
}

impl Oracle {
    pub fn new(ctx: &GovCtx<'_>) -> Self {
        Self {
            gpu: ctx.gpu.clone(),
            window_ns: ctx.window_ns,
            hbm_noise_w: ctx.hbm_noise_w,
            rng: Rng::substream(ctx.seed, &format!("dvfs{}", ctx.gpu_idx)),
            energy_j: 0.0,
        }
    }
}

impl GovernorPolicy for Oracle {
    fn step(&mut self, act: &WindowActivity) -> (f64, f64) {
        let noise = hbm_noise_draw(&mut self.rng, self.hbm_noise_w, act);
        let power = package_power_w(
            &self.gpu,
            self.gpu.freq_peak_mhz,
            self.window_ns,
            act,
            noise,
        )
        .max(self.gpu.idle_power_w);
        self.energy_j += power * self.window_ns * 1e-9;
        (power, self.gpu.freq_peak_mhz)
    }

    fn freq_mhz(&self) -> f64 {
        self.gpu.freq_peak_mhz
    }

    fn mem_freq_mhz(&self) -> f64 {
        self.gpu.mem_freq_peak_mhz
    }

    fn freq_ratio(&self) -> f64 {
        1.0
    }

    fn mem_freq_ratio(&self) -> f64 {
        1.0
    }

    fn energy_j(&self) -> f64 {
        self.energy_j
    }

    fn kind(&self) -> GovernorKind {
        GovernorKind::Oracle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(gpu: &GpuSpec) -> GovCtx<'_> {
        GovCtx {
            gpu,
            seed: 42,
            gpu_idx: 0,
            hbm_noise_w: 40.0,
            window_ns: 1_000_000.0,
            margin_k: 0.3,
            fixed_cap_ratio: 0.7,
            spike_var: 0.0,
            thermal: None,
        }
    }

    fn busy() -> WindowActivity {
        WindowActivity {
            compute_busy: 0.95,
            mfma_util: 0.6,
            hbm_bytes: 3.5e9,
            comm_busy: 0.3,
        }
    }

    #[test]
    fn kind_name_roundtrip_and_aliases() {
        for k in GovernorKind::ALL {
            assert_eq!(GovernorKind::parse(k.name()), Some(k));
            assert_eq!(format!("{k}"), k.name());
        }
        assert_eq!(GovernorKind::parse("FixedCap"), Some(GovernorKind::FixedCap));
        assert_eq!(
            GovernorKind::parse("deterministic"),
            Some(GovernorKind::DeterministicAware)
        );
        assert_eq!(GovernorKind::parse("nope"), None);
        assert_eq!(
            parse_list_governor("reactive, oracle").unwrap(),
            vec![GovernorKind::Reactive, GovernorKind::Oracle]
        );
        assert!(parse_list_governor("turbo").is_err());
    }

    #[test]
    fn built_policies_report_their_kind() {
        let gpu = GpuSpec::mi300x();
        for k in GovernorKind::ALL {
            let p = k.build(&ctx(&gpu));
            assert_eq!(p.kind(), k, "{k}");
        }
    }

    #[test]
    fn reactive_policy_is_bitwise_the_stock_governor() {
        let gpu = GpuSpec::mi300x();
        let c = ctx(&gpu);
        let mut policy = Reactive::new(&c);
        let mut stock = DvfsGovernor::new(gpu.clone(), c.seed, c.gpu_idx, c.hbm_noise_w);
        let act = busy();
        for _ in 0..300 {
            let (pp, pf) = policy.step(&act);
            let (sp, sf) = stock.step(&act);
            assert_eq!(pp.to_bits(), sp.to_bits());
            assert_eq!(pf.to_bits(), sf.to_bits());
            assert_eq!(policy.mem_freq_mhz().to_bits(), stock.mem_freq_mhz.to_bits());
        }
    }

    #[test]
    fn fixed_cap_pins_clocks_and_respects_regulator() {
        let gpu = GpuSpec::mi300x();
        let c = ctx(&gpu);
        let mut p = FixedCap::new(&c);
        let expect = (gpu.freq_peak_mhz * c.fixed_cap_ratio)
            .clamp(gpu.freq_min_mhz, gpu.freq_peak_mhz);
        for i in 0..200 {
            let act = if i % 3 == 0 { WindowActivity::default() } else { busy() };
            let (pw, f) = p.step(&act);
            assert_eq!(f.to_bits(), expect.to_bits(), "clock moved");
            assert!(pw <= gpu.power_cap_w * 1.10 + 1e-9);
            assert!(pw >= gpu.idle_power_w - 1e-9);
        }
        assert_eq!(p.freq_mhz().to_bits(), expect.to_bits());
    }

    #[test]
    fn fixed_cap_ratio_clamps_to_physical_clock_range() {
        let gpu = GpuSpec::mi300x();
        let mut c = ctx(&gpu);
        c.fixed_cap_ratio = 0.01; // below freq_min — must clamp, not stall
        let p = FixedCap::new(&c);
        assert_eq!(p.freq_mhz(), gpu.freq_min_mhz);
        assert!(p.freq_ratio_clamped() >= MIN_FREQ_RATIO);
        c.fixed_cap_ratio = 3.0; // above peak — pinned at peak
        let p = FixedCap::new(&c);
        assert_eq!(p.freq_mhz(), gpu.freq_peak_mhz);
    }

    #[test]
    fn oracle_holds_peak_and_exceeds_cap_when_mfma_heavy() {
        let gpu = GpuSpec::mi300x();
        let mut p = Oracle::new(&ctx(&gpu));
        let act = busy();
        let mut exceeded = false;
        for _ in 0..200 {
            let (pw, f) = p.step(&act);
            assert_eq!(f.to_bits(), gpu.freq_peak_mhz.to_bits());
            assert!(pw >= gpu.idle_power_w);
            if pw > gpu.power_cap_w {
                exceeded = true;
            }
        }
        assert!(exceeded, "oracle never exceeded the cap — not cap-ignoring");
        assert_eq!(p.freq_ratio(), 1.0);
    }

    #[test]
    fn det_aware_detects_quiet_allocator_and_clocks_higher() {
        let gpu = GpuSpec::mi300x();
        // Quiet (v2-like) allocator: detection fires, clocks beat reactive.
        let mut c = ctx(&gpu);
        c.spike_var = 0.0;
        let da = DeterministicAware::new(&c);
        assert!(da.detected);
        // Noisy (v1-like) allocator: no detection — degenerates to Reactive
        // bit for bit.
        c.spike_var = 0.5;
        c.hbm_noise_w = 150.0;
        let mut da = DeterministicAware::new(&c);
        let mut re = Reactive::new(&c);
        assert!(!da.detected);
        let act = busy();
        for _ in 0..200 {
            let (dp, df) = da.step(&act);
            let (rp, rf) = re.step(&act);
            assert_eq!(dp.to_bits(), rp.to_bits());
            assert_eq!(df.to_bits(), rf.to_bits());
        }

        // Detected case sustains higher clocks at the same cap.
        let mut cq = ctx(&gpu);
        cq.hbm_noise_w = 40.0;
        cq.spike_var = 0.0;
        let mut da = DeterministicAware::new(&cq);
        let mut re = Reactive::new(&cq);
        let (mut fd, mut fr) = (0.0, 0.0);
        for _ in 0..400 {
            fd += da.step(&act).1;
            fr += re.step(&act).1;
        }
        assert!(fd >= fr, "det-aware {fd:.0} !>= reactive {fr:.0}");
    }

    #[test]
    fn energy_is_the_window_sum_of_power_dt() {
        let gpu = GpuSpec::mi300x();
        let act = busy();
        for k in GovernorKind::ALL {
            let mut p = k.build(&ctx(&gpu));
            let mut acc = 0.0;
            for _ in 0..250 {
                let (pw, _) = p.step(&act);
                acc += pw * 1_000_000.0 * 1e-9;
            }
            let got = p.energy_j();
            assert!(
                (got - acc).abs() <= acc * 1e-12,
                "{k}: energy {got} != window-sum {acc}"
            );
            assert!(got > 0.0, "{k}: no energy integrated");
        }
    }

    #[test]
    fn policies_are_deterministic_for_a_seed() {
        let gpu = GpuSpec::mi300x();
        let act = busy();
        for k in GovernorKind::ALL {
            let run = || {
                let mut p = k.build(&ctx(&gpu));
                let mut out = Vec::new();
                for _ in 0..100 {
                    let (pw, f) = p.step(&act);
                    out.push((pw.to_bits(), f.to_bits()));
                }
                (out, p.energy_j().to_bits())
            };
            assert_eq!(run(), run(), "{k} not deterministic");
        }
    }

    #[test]
    fn thermal_aware_without_thermal_is_bitwise_reactive() {
        let gpu = GpuSpec::mi300x();
        let c = ctx(&gpu);
        let mut ta = GovernorKind::ThermalAware.build(&c);
        let mut re = Reactive::new(&c);
        assert!(ta.thermal_sample().is_none());
        let act = busy();
        for _ in 0..300 {
            let (tp, tf) = ta.step(&act);
            let (rp, rf) = re.step(&act);
            assert_eq!(tp.to_bits(), rp.to_bits());
            assert_eq!(tf.to_bits(), rf.to_bits());
        }
        assert_eq!(ta.energy_j().to_bits(), re.energy_j().to_bits());
        assert_eq!(ta.kind(), GovernorKind::ThermalAware);
    }

    #[test]
    fn thermal_coupling_throttles_every_policy_under_low_headroom() {
        use crate::sim::thermal::{ThermalConfig, ThermalCtx};
        let gpu = GpuSpec::mi300x();
        let mut c = ctx(&gpu);
        c.thermal = Some(ThermalCtx {
            cfg: ThermalConfig {
                ambient_c: 85.0,
                tau_s: 0.005,
                ..ThermalConfig::default()
            },
            cool_eff: 1.0,
        });
        let act = busy();
        for k in GovernorKind::ALL {
            let mut p = k.build(&c);
            let mut throttled = false;
            for _ in 0..400 {
                p.step(&act);
                let (temp, th) = p.thermal_sample().expect("coupled policy");
                assert!(temp >= 85.0 - 1e-9, "{k}: below ambient");
                if th < 1.0 {
                    throttled = true;
                }
            }
            assert!(throttled, "{k}: never throttled at 5 °C headroom");
            // Hot runs clock lower than the same policy without thermal.
            let mut bare_ctx = c.clone();
            bare_ctx.thermal = None;
            let mut bare = k.build(&bare_ctx);
            for _ in 0..400 {
                bare.step(&act);
            }
            assert!(
                p.freq_mhz() < bare.freq_mhz() + 1e-9,
                "{k}: thermal run not slower"
            );
        }
    }

    #[test]
    fn thermal_aware_holds_headroom_reactive_oscillates() {
        use crate::sim::thermal::{ThermalConfig, ThermalCtx};
        let gpu = GpuSpec::mi300x();
        let mut c = ctx(&gpu);
        // Moderate headroom: reactive runs hot enough to throttle; a
        // proactive budget should stay below the onset.
        c.thermal = Some(ThermalCtx {
            cfg: ThermalConfig {
                ambient_c: 55.0,
                tau_s: 0.02,
                ..ThermalConfig::default()
            },
            cool_eff: 1.0,
        });
        let act = busy();
        let run = |k: GovernorKind| {
            let mut p = k.build(&c);
            let mut loss = 0.0;
            for _ in 0..600 {
                p.step(&act);
                let (_, th) = p.thermal_sample().unwrap();
                loss += 1.0 - th;
            }
            (loss, p.energy_j())
        };
        let (loss_re, _) = run(GovernorKind::Reactive);
        let (loss_ta, _) = run(GovernorKind::ThermalAware);
        assert!(loss_re > 0.0, "reactive never throttled — scenario too cold");
        assert!(
            loss_ta < loss_re,
            "thermal_aware loss {loss_ta} !< reactive {loss_re}"
        );
    }

    #[test]
    fn oracle_is_fastest_fixed_cap_cheapest_per_window() {
        // The whole point of the policy space: the oracle holds the highest
        // clocks; a conservative fixed cap draws the least power.
        let gpu = GpuSpec::mi300x();
        let act = busy();
        let mut freqs = std::collections::BTreeMap::new();
        let mut powers = std::collections::BTreeMap::new();
        for k in GovernorKind::ALL {
            let mut p = k.build(&ctx(&gpu));
            let (mut fs, mut ps) = (0.0, 0.0);
            for _ in 0..400 {
                let (pw, f) = p.step(&act);
                ps += pw;
                fs += f;
            }
            freqs.insert(k, fs / 400.0);
            powers.insert(k, ps / 400.0);
        }
        for k in GovernorKind::ALL {
            assert!(
                freqs[&GovernorKind::Oracle] >= freqs[&k],
                "oracle not fastest vs {k}"
            );
            assert!(
                powers[&GovernorKind::FixedCap] <= powers[&k] + 1e-9,
                "fixed_cap(0.7) not cheapest vs {k}"
            );
        }
    }
}
