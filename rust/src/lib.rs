//! # Chopper
//!
//! A multi-level GPU characterization tool for LLM training — a full
//! reproduction of *"Chopper: A Multi-Level GPU Characterization Tool &
//! Derived Insights Into LLM Training Inefficiency"* (CS.DC 2025) — plus
//! every substrate the paper profiles: a discrete-event simulator of an
//! eight-GPU AMD Instinct MI300X node training Llama 3 8B under FSDPv1/v2,
//! and a real-execution path that runs a JAX/Pallas mini-Llama AOT-compiled
//! to HLO through PJRT.
//!
//! Layering (see DESIGN.md):
//! * substrates: [`config`], [`model`], [`fsdp`], [`sim`], [`counters`]
//! * workloads:  [`serve`] (open-loop arrivals, continuous batching)
//! * the tool:   [`trace`], [`chopper`]
//! * campaigns:  [`campaign`] (scenario grids, parallel runner, cache)
//! * runtime:    [`runtime`] (PJRT), [`train`] (e2e driver)
//! * glue:       [`cli`], [`util`], [`benchkit`]

pub mod benchkit;
pub mod campaign;
pub mod chopper;
pub mod cli;
pub mod config;
pub mod counters;
pub mod fsdp;
pub mod model;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod trace;
pub mod train;
pub mod util;
