//! Parameter sharding math (Section II-B): FSDP shards weights, gradients
//! and optimizer state across ranks; forward/backward all-gather full
//! layers, reduce-scatter re-shards gradients.

use crate::config::ModelConfig;

/// Sharding layout for one model on `ranks` GPUs.
#[derive(Debug, Clone)]
pub struct ShardLayout {
    pub ranks: u64,
    /// Full (unsharded) bytes of each decoder layer's weights.
    pub layer_bytes: u64,
    /// Full bytes of the embedding table.
    pub embed_bytes: u64,
    /// Full bytes of the head (final norm + logits projection).
    pub head_bytes: u64,
    /// Total parameter bytes.
    pub total_bytes: u64,
}

impl ShardLayout {
    pub fn new(cfg: &ModelConfig, ranks: u64) -> Self {
        assert!(ranks > 0);
        let layer_bytes = cfg.layer_weight_bytes();
        let embed_bytes = cfg.vocab * cfg.hidden * cfg.dtype_bytes;
        let head_bytes = (cfg.hidden + cfg.hidden * cfg.vocab) * cfg.dtype_bytes;
        Self {
            ranks,
            layer_bytes,
            embed_bytes,
            head_bytes,
            total_bytes: cfg.param_count() * cfg.dtype_bytes,
        }
    }

    /// Bytes a single rank holds of one layer (its shard).
    pub fn layer_shard_bytes(&self) -> u64 {
        self.layer_bytes.div_ceil(self.ranks)
    }

    /// Bytes of persistent per-rank state: weight shard + grad shard +
    /// fp32 master + two moments (AdamW) for its shard.
    pub fn optimizer_state_bytes(&self) -> u64 {
        let shard_params = self.total_bytes / 2 / self.ranks; // bf16 -> count
        // fp32 master + m + v = 12 bytes/param, grads bf16 = 2, weights = 2.
        shard_params * (12 + 2 + 2)
    }

    /// Transient bytes alive while a layer is gathered (the full layer).
    pub fn gathered_layer_bytes(&self) -> u64 {
        self.layer_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_bytes_divide_evenly_enough() {
        let l = ShardLayout::new(&ModelConfig::llama3_8b(), 8);
        assert!(l.layer_shard_bytes() * 8 >= l.layer_bytes);
        assert!(l.layer_shard_bytes() * 8 < l.layer_bytes + 8);
    }

    #[test]
    fn totals_are_consistent() {
        let cfg = ModelConfig::llama3_8b();
        let l = ShardLayout::new(&cfg, 8);
        assert_eq!(l.total_bytes, cfg.param_count() * 2);
        assert!(l.embed_bytes > 0 && l.head_bytes > l.embed_bytes / 2);
    }

    #[test]
    fn optimizer_state_fits_hbm() {
        // Sanity: 8B params sharded over 8 ranks with AdamW state must fit
        // well inside 192 GB (it's ~16 GB/rank).
        let l = ShardLayout::new(&ModelConfig::llama3_8b(), 8);
        assert!(l.optimizer_state_bytes() < 64 * (1 << 30));
    }

    #[test]
    #[should_panic]
    fn zero_ranks_rejected() {
        ShardLayout::new(&ModelConfig::mini(), 0);
    }
}
