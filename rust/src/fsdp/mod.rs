//! FSDP substrate: sharding math, the caching-allocator model (v1
//! non-determinism vs v2 determinism), and the dispatch-schedule builder
//! that weaves collectives and FSDPv2 copy kernels into the compute stream.

pub mod allocator;
pub mod schedule;
pub mod shard;

pub use allocator::{
    simulate_gather_pattern, simulate_kv_pattern, AllocStats, CachingAllocator,
    MemEvent,
};
pub use schedule::{
    build_program, build_program_topo, CollectiveDesc, CommGroup, CommScope,
    DispatchItem, HostSync, ProgKernel, Program,
};
pub use shard::ShardLayout;
