//! FSDP dispatch-schedule builder.
//!
//! Expands the per-iteration compute program (model::graph) into the full
//! host dispatch stream for one rank: compute kernels, collectives
//! (all-gather / reduce-scatter with prefetch depth 2), FSDPv2's serialized
//! parameter-copy kernels, host-side bookkeeping work, and the
//! synchronization points. Every rank runs the same program — collective
//! ids therefore align across ranks and become the rendezvous keys in the
//! simulator.
//!
//! Mechanisms encoded here (referenced from DESIGN.md §5):
//!  * pipeline fill: AG(embed), AG(0), AG(1) are enqueued back-to-back
//!    before the first compute kernel of the iteration (Fig. 12);
//!  * pipeline empty: trailing reduce-scatters drain during b_ga and the
//!    optimizer sync (Insight 5);
//!  * FSDPv2 serializes ParamCopy kernels into the compute stream before
//!    f_attn_n, before b_mlp_dp, and before b_ie (Section V-D3);
//!  * FSDPv1 performs per-tensor host work inside the optimizer loop
//!    (bubbles between opt_step kernels, reduced in v2).
//!
//! Topology-aware variants (DESIGN.md §8): [`build_program_topo`] keeps
//! the same dispatch skeleton but retargets the collectives. Under FSDP
//! every collective is world-scoped; under HSDP on a multi-node topology
//! parameters shard *within* the node (intra-node all-gather /
//! reduce-scatter over `gpus_per_node` ranks) and every reduce-scatter is
//! followed by a cross-node all-reduce of the rank's gradient shard. On a
//! one-node topology both strategies produce the identical program.

use crate::config::{FsdpVersion, ModelConfig, Sharding, Topology, WorkloadConfig};
use crate::model::graph::{build_iteration, KernelDesc};
use crate::model::ops::{OpRef, OpType, Phase};

/// What a collective gathers/reduces.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommScope {
    Embed,
    Layer(u32),
    Head,
}

impl CommScope {
    pub fn layer(&self) -> Option<u32> {
        match self {
            CommScope::Layer(l) => Some(*l),
            _ => None,
        }
    }
}

/// Which ranks rendezvous on a collective (the engine expands each
/// program-level collective into one instance per rendezvous group).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommGroup {
    /// Every rank of the cluster — FSDP collectives, and everything on a
    /// single node.
    World,
    /// The dispatching rank's node (HSDP parameter sharding group).
    IntraNode,
    /// The dispatching rank's same-local-index peers across nodes (HSDP
    /// gradient replication group).
    CrossNode,
}

/// One collective operation (same id on every rank).
#[derive(Debug, Clone)]
pub struct CollectiveDesc {
    pub id: u64,
    pub op: OpRef,
    pub scope: CommScope,
    /// Rendezvous group of this collective.
    pub group: CommGroup,
    pub iter: u32,
    /// Full (unsharded) payload bytes.
    pub bytes: f64,
    /// Cross-stream dependency (HIP stream-event semantics): the comm
    /// kernel may not start on a rank until this many compute kernels have
    /// *completed* there — i.e., an event recorded on the compute stream
    /// at the comm's enqueue point. This is what anchors collectives to
    /// device-side progress instead of the (far-ahead) host clock.
    pub wait_seq: u64,
}

/// A compute kernel in dispatch order.
#[derive(Debug, Clone)]
pub struct ProgKernel {
    pub desc: KernelDesc,
    pub iter: u32,
    /// Collective that must complete before this kernel may start.
    pub wait_comm: Option<u64>,
}

/// Host-side synchronization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostSync {
    /// Host blocks until the given collective completes.
    Collective(u64),
    /// Host blocks until both streams fully drain.
    Device,
}

#[derive(Debug, Clone)]
pub enum DispatchItem {
    Kernel(ProgKernel),
    Comm(CollectiveDesc),
    Sync(HostSync),
    /// Pure host CPU time (bookkeeping) before the next dispatch, ns.
    HostWork { ns: f64, tag: &'static str },
}

/// The complete multi-iteration dispatch program of one rank.
#[derive(Debug, Clone)]
pub struct Program {
    pub items: Vec<DispatchItem>,
    pub num_collectives: u64,
    pub iterations: u32,
}

impl Program {
    pub fn kernels(&self) -> impl Iterator<Item = &ProgKernel> {
        self.items.iter().filter_map(|i| match i {
            DispatchItem::Kernel(k) => Some(k),
            _ => None,
        })
    }

    pub fn collectives(&self) -> impl Iterator<Item = &CollectiveDesc> {
        self.items.iter().filter_map(|i| match i {
            DispatchItem::Comm(c) => Some(c),
            _ => None,
        })
    }
}

/// How a program's collectives map onto the topology (internal; selected
/// by [`build_program`] / [`build_program_topo`]).
#[derive(Debug, Clone, Copy)]
struct CommPlan {
    /// Size of the parameter-shard group (divisor for per-rank copy /
    /// optimizer shard sizes): the world under FSDP, one node under HSDP.
    shard_ranks: u64,
    /// Rendezvous group of every all-gather / reduce-scatter.
    group: CommGroup,
    /// Follow each reduce-scatter with a cross-node all-reduce of the
    /// rank's `1/shard_ranks` gradient shard (HSDP replication sync).
    cross_node: bool,
}

impl CommPlan {
    fn fsdp(ranks: u64) -> Self {
        Self {
            shard_ranks: ranks,
            group: CommGroup::World,
            cross_node: false,
        }
    }
}

struct Builder {
    items: Vec<DispatchItem>,
    next_comm_id: u64,
    kernel_count: u64,
    plan: CommPlan,
}

impl Builder {
    fn push_comm(
        &mut self,
        op: OpType,
        scope: CommScope,
        group: CommGroup,
        iter: u32,
        bytes: f64,
    ) -> u64 {
        let id = self.next_comm_id;
        self.next_comm_id += 1;
        self.items.push(DispatchItem::Comm(CollectiveDesc {
            id,
            op: OpRef::new(op, Phase::Forward),
            scope,
            group,
            iter,
            bytes,
            wait_seq: self.kernel_count,
        }));
        id
    }

    /// A sharding-group collective (all-gather or reduce-scatter).
    fn comm(&mut self, op: OpType, scope: CommScope, iter: u32, bytes: f64) -> u64 {
        self.push_comm(op, scope, self.plan.group, iter, bytes)
    }

    /// A gradient reduce-scatter, plus — under HSDP — the cross-node
    /// all-reduce of the resulting shard. The all-reduce is enqueued
    /// immediately behind the reduce-scatter, so the per-rank FIFO comm
    /// stream gives the data dependency for free.
    fn reduce(&mut self, scope: CommScope, iter: u32, bytes: f64) -> u64 {
        let id = self.comm(OpType::ReduceScatter, scope, iter, bytes);
        if self.plan.cross_node {
            self.push_comm(
                OpType::AllReduce,
                scope,
                CommGroup::CrossNode,
                iter,
                bytes / self.plan.shard_ranks as f64,
            );
        }
        id
    }

    fn kernel(&mut self, desc: KernelDesc, iter: u32, wait: Option<u64>) {
        self.kernel_count += 1;
        self.items.push(DispatchItem::Kernel(ProgKernel {
            desc,
            iter,
            wait_comm: wait,
        }));
    }

    fn host(&mut self, ns: f64, tag: &'static str) {
        self.items.push(DispatchItem::HostWork { ns, tag });
    }
}

fn param_copy_kernel(cfg: &ModelConfig, phase: Phase, layer: Option<u32>,
                     ranks: u64) -> KernelDesc {
    let bytes = 2.0 * cfg.layer_weight_bytes() as f64 / ranks as f64;
    KernelDesc {
        name: "fsdp2_param_copy".into(),
        op: OpRef::new(OpType::ParamCopy, phase),
        layer,
        kind: OpType::ParamCopy.kind(),
        flops: 0.0,
        bytes,
        gemm_mnk: None,
    }
}

/// Build the dispatch program for `wl` on a model sharded over `ranks`
/// (flat FSDP — every collective is world-scoped).
pub fn build_program(cfg: &ModelConfig, wl: &WorkloadConfig, ranks: u64) -> Program {
    build_with_plan(cfg, wl, CommPlan::fsdp(ranks))
}

/// Build the dispatch program for `wl` on `topo`, honoring
/// `wl.sharding`. FSDP shards over the whole cluster; HSDP (on more than
/// one node) shards within each node and adds the cross-node gradient
/// all-reduces. On one node both degenerate to [`build_program`].
pub fn build_program_topo(
    cfg: &ModelConfig,
    wl: &WorkloadConfig,
    topo: &Topology,
) -> Program {
    if wl.sharding == Sharding::Hsdp && topo.num_nodes > 1 {
        build_with_plan(
            cfg,
            wl,
            CommPlan {
                shard_ranks: topo.gpus_per_node() as u64,
                group: CommGroup::IntraNode,
                cross_node: true,
            },
        )
    } else {
        build_with_plan(cfg, wl, CommPlan::fsdp(topo.world_size() as u64))
    }
}

fn build_with_plan(cfg: &ModelConfig, wl: &WorkloadConfig, plan: CommPlan) -> Program {
    let ranks = plan.shard_ranks;
    let iter_prog = build_iteration(cfg, wl.batch, wl.seq, ranks, wl.optimizer);
    let layers = cfg.layers as u32;
    let layer_bytes = cfg.layer_weight_bytes() as f64;
    let embed_bytes = (cfg.vocab * cfg.hidden * cfg.dtype_bytes) as f64;
    let head_bytes = ((cfg.hidden + cfg.hidden * cfg.vocab) * cfg.dtype_bytes) as f64;
    let v2 = wl.fsdp == FsdpVersion::V2;

    let mut b = Builder {
        items: Vec::new(),
        next_comm_id: 0,
        kernel_count: 0,
        plan,
    };

    for iter in 0..wl.iterations {
        // --- iteration begin: dataloader + FSDP bookkeeping on the host.
        b.host(120_000.0, "iter_begin");

        // --- forward: fill the AG pipeline (Fig. 12).
        let ag_embed = b.comm(OpType::AllGather, CommScope::Embed, iter, embed_bytes);
        let mut ag_ids: Vec<u64> = Vec::with_capacity(layers as usize);
        for l in 0..2.min(layers) {
            ag_ids.push(b.comm(
                OpType::AllGather,
                CommScope::Layer(l),
                iter,
                layer_bytes,
            ));
        }

        let mut fwd_iter = iter_prog.fwd.iter();
        // i_e waits on the embedding gather.
        let ie = fwd_iter.next().expect("i_e first");
        for k in &ie.kernels {
            b.kernel(k.clone(), iter, Some(ag_embed));
        }

        let mut ag_head: Option<u64> = None;
        for l in 0..layers {
            // Prefetch depth 2.
            if l + 2 < layers {
                ag_ids.push(b.comm(
                    OpType::AllGather,
                    CommScope::Layer(l + 2),
                    iter,
                    layer_bytes,
                ));
            } else if ag_head.is_none() {
                ag_head =
                    Some(b.comm(OpType::AllGather, CommScope::Head, iter, head_bytes));
            }
            let wait = Some(ag_ids[l as usize]);
            if v2 {
                // Per-parameter sharding: copy gathered shards into the
                // flat views, serialized in the compute stream.
                b.kernel(
                    param_copy_kernel(cfg, Phase::Forward, Some(l), ranks),
                    iter,
                    wait,
                );
            }
            let mut first = true;
            for op in iter_prog.fwd.iter().filter(|o| o.layer == Some(l)) {
                for k in &op.kernels {
                    // Only the first kernel of the layer carries the AG
                    // dependency (the rest are ordered behind it anyway).
                    let w = if first && !v2 { wait } else { None };
                    b.kernel(k.clone(), iter, w);
                    first = false;
                }
            }
        }
        let ag_head = ag_head
            .unwrap_or_else(|| b.comm(OpType::AllGather, CommScope::Head, iter, head_bytes));
        // ln + lp wait on the head gather.
        let mut first = true;
        for op in iter_prog.fwd.iter().filter(|o| {
            o.layer.is_none() && matches!(o.op.op, OpType::Ln | OpType::Lp)
        }) {
            for k in &op.kernels {
                b.kernel(k.clone(), iter, if first { Some(ag_head) } else { None });
                first = false;
            }
        }

        // --- backward. Loss/host autograd setup.
        b.host(60_000.0, "bwd_begin");
        // Head ops first (weights still resident), then layers in reverse
        // with re-gather prefetch depth 2.
        for op in iter_prog.bwd.iter().filter(|o| {
            o.layer.is_none() && matches!(o.op.op, OpType::Lp | OpType::Ln)
        }) {
            for k in &op.kernels {
                b.kernel(k.clone(), iter, None);
            }
        }
        let rs_head = b.reduce(CommScope::Head, iter, head_bytes);
        let _ = rs_head;

        let mut bag: Vec<Option<u64>> = vec![None; layers as usize];
        for l in (layers.saturating_sub(2)..layers).rev() {
            bag[l as usize] = Some(b.comm(
                OpType::AllGather,
                CommScope::Layer(l),
                iter,
                layer_bytes,
            ));
        }
        for l in (0..layers).rev() {
            let wait = bag[l as usize];
            let mut first = true;
            for op in iter_prog.bwd.iter().filter(|o| o.layer == Some(l)) {
                if op.op.op == OpType::QkvIp {
                    // Layer-end comm window: FSDPv1's post-backward hook
                    // for layer l+1 fires late (autograd drains that
                    // layer's accumulation nodes lazily), so its
                    // reduce-scatter is dispatched at the b_qkv_ip →
                    // b_attn_n boundary of layer l, together with the
                    // backward prefetch all-gather. The window covers
                    // b_attn_n (the layer's last op) on every rank whose
                    // comm engine is prompt — ~90% overlap on b_attn_n,
                    // ~0% on b_mlp_n under FSDPv1 (Observation 4).
                    if l + 1 < layers {
                        b.reduce(CommScope::Layer(l + 1), iter, layer_bytes);
                    }
                    if l >= 2 {
                        let pl = l - 2;
                        bag[pl as usize] = Some(b.comm(
                            OpType::AllGather,
                            CommScope::Layer(pl),
                            iter,
                            layer_bytes,
                        ));
                    }
                }
                if v2 && op.op.op == OpType::MlpDp {
                    // FSDPv2 serializes the param copy right before
                    // b_mlp_dp (Section V-D3).
                    b.kernel(
                        param_copy_kernel(cfg, Phase::Backward, Some(l), ranks),
                        iter,
                        wait,
                    );
                }
                for k in &op.kernels {
                    let w = if first { wait } else { None };
                    b.kernel(k.clone(), iter, w);
                    first = false;
                }
            }
        }
        // The bottom layer's grads reduce after its backward completes.
        b.reduce(CommScope::Layer(0), iter, layer_bytes);
        // Embedding backward (+ v2 copy before b_ie), then its RS.
        if v2 {
            b.kernel(param_copy_kernel(cfg, Phase::Backward, None, ranks), iter, None);
        }
        for op in iter_prog.bwd.iter().filter(|o| o.op.op == OpType::IE) {
            for k in &op.kernels {
                b.kernel(k.clone(), iter, None);
            }
        }
        b.reduce(CommScope::Embed, iter, embed_bytes);

        // --- optimizer phase: b_ga overlaps the RS drain; opt_step runs
        // after the host synchronizes on all reduce-scatters.
        for op in iter_prog.opt.iter().filter(|o| o.op.op == OpType::GradAccum) {
            for k in &op.kernels {
                b.kernel(k.clone(), iter, None);
            }
        }
        if wl.optimizer {
            b.items.push(DispatchItem::Sync(HostSync::Device));
            b.host(180_000.0, "opt_begin");
            for op in iter_prog.opt.iter().filter(|o| o.op.op == OpType::OptStep) {
                for k in &op.kernels {
                    if wl.fsdp == FsdpVersion::V1 {
                        // Flat-param optimizer: per-tensor host work
                        // (unflatten/view bookkeeping) between kernel
                        // launches — longer than the small vector kernels
                        // themselves, hence bubbles (Section V-D3).
                        b.host(85_000.0, "opt_tensor_loop");
                    }
                    b.kernel(k.clone(), iter, None);
                }
            }
        }
        // End-of-iteration device sync (the trainer's iteration barrier).
        b.items.push(DispatchItem::Sync(HostSync::Device));
    }

    Program {
        num_collectives: b.next_comm_id,
        items: b.items,
        iterations: wl.iterations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FsdpVersion;

    fn small_cfg() -> ModelConfig {
        let mut c = ModelConfig::llama3_8b();
        c.layers = 4;
        c
    }

    fn wl(fsdp: FsdpVersion) -> WorkloadConfig {
        let mut w = WorkloadConfig::new(2, 4096, fsdp);
        w.iterations = 2;
        w.warmup = 1;
        w
    }

    #[test]
    fn collective_count_matches_structure() {
        let cfg = small_cfg();
        let p = build_program(&cfg, &wl(FsdpVersion::V1), 8);
        // Per iteration: AG embed + AG head (fwd) + L fwd AGs + L bwd AGs
        // + L RS + RS embed + RS head.
        let l = cfg.layers as u64;
        let per_iter = 2 + l + l + l + 2;
        assert_eq!(p.num_collectives, per_iter * 2);
    }

    #[test]
    fn v2_adds_copy_kernels() {
        let cfg = small_cfg();
        let v1 = build_program(&cfg, &wl(FsdpVersion::V1), 8);
        let v2 = build_program(&cfg, &wl(FsdpVersion::V2), 8);
        let copies = |p: &Program| {
            p.kernels()
                .filter(|k| k.desc.op.op == OpType::ParamCopy)
                .count()
        };
        assert_eq!(copies(&v1), 0);
        // fwd: 1/layer; bwd: 1/layer + 1 before b_ie; per iteration.
        assert_eq!(copies(&v2), 2 * (cfg.layers as usize * 2 + 1));
    }

    #[test]
    fn first_layer_kernel_waits_on_its_gather() {
        let cfg = small_cfg();
        let p = build_program(&cfg, &wl(FsdpVersion::V1), 8);
        // Find first attn_n fwd kernel of layer 0 / iter 0.
        let k = p
            .kernels()
            .find(|k| {
                k.iter == 0
                    && k.desc.op.op == OpType::AttnN
                    && k.desc.op.phase == Phase::Forward
                    && k.desc.layer == Some(0)
            })
            .unwrap();
        assert!(k.wait_comm.is_some());
        // Its wait target is an AG for layer 0.
        let c = p
            .collectives()
            .find(|c| c.id == k.wait_comm.unwrap())
            .unwrap();
        assert_eq!(c.op.op, OpType::AllGather);
        assert_eq!(c.scope, CommScope::Layer(0));
    }

    #[test]
    fn pipeline_fill_precedes_first_kernel() {
        let cfg = small_cfg();
        let p = build_program(&cfg, &wl(FsdpVersion::V1), 8);
        // Dispatch order: the first three comm items come before the first
        // kernel (AG embed, AG l0, AG l1).
        let mut comms_before = 0;
        for item in &p.items {
            match item {
                DispatchItem::Comm(_) => comms_before += 1,
                DispatchItem::Kernel(_) => break,
                _ => {}
            }
        }
        assert_eq!(comms_before, 3);
    }

    #[test]
    fn v1_has_host_gaps_in_opt_step() {
        let cfg = small_cfg();
        let p1 = build_program(&cfg, &wl(FsdpVersion::V1), 8);
        let p2 = build_program(&cfg, &wl(FsdpVersion::V2), 8);
        let gaps = |p: &Program| {
            p.items
                .iter()
                .filter(|i| matches!(i, DispatchItem::HostWork { tag, .. } if *tag == "opt_tensor_loop"))
                .count()
        };
        assert!(gaps(&p1) > 0);
        assert_eq!(gaps(&p2), 0);
    }

    #[test]
    fn reduce_scatters_drain_after_backward() {
        let cfg = small_cfg();
        let p = build_program(&cfg, &wl(FsdpVersion::V1), 8);
        // The last collective of iteration 0 is the embed RS.
        let last_comm_iter0 = p.collectives().filter(|c| c.iter == 0).last().unwrap();
        assert_eq!(last_comm_iter0.op.op, OpType::ReduceScatter);
        assert_eq!(last_comm_iter0.scope, CommScope::Embed);
    }

    #[test]
    fn collective_ids_are_dense_and_unique() {
        let cfg = small_cfg();
        let p = build_program(&cfg, &wl(FsdpVersion::V2), 8);
        let mut ids: Vec<u64> = p.collectives().map(|c| c.id).collect();
        ids.sort();
        for (i, id) in ids.iter().enumerate() {
            assert_eq!(*id, i as u64);
        }
    }

    #[test]
    fn topo_fsdp_single_node_matches_flat_build() {
        use crate::config::Topology;
        let cfg = small_cfg();
        let w = wl(FsdpVersion::V1);
        let flat = build_program(&cfg, &w, 8);
        let topo = build_program_topo(&cfg, &w, &Topology::mi300x_cluster(1));
        assert_eq!(flat.items.len(), topo.items.len());
        assert_eq!(flat.num_collectives, topo.num_collectives);
        for (a, b) in flat.collectives().zip(topo.collectives()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.op, b.op);
            assert_eq!(a.scope, b.scope);
            assert_eq!(a.group, b.group);
            assert_eq!(a.bytes, b.bytes);
            assert_eq!(a.wait_seq, b.wait_seq);
        }
    }

    #[test]
    fn hsdp_adds_cross_node_allreduce_per_reduce_scatter() {
        use crate::config::{Sharding, Topology};
        let cfg = small_cfg();
        let mut w = wl(FsdpVersion::V1);
        w.sharding = Sharding::Hsdp;
        let topo = Topology::mi300x_cluster(2);
        let p = build_program_topo(&cfg, &w, &topo);
        let rs: Vec<_> = p
            .collectives()
            .filter(|c| c.op.op == OpType::ReduceScatter)
            .collect();
        let ar: Vec<_> = p
            .collectives()
            .filter(|c| c.op.op == OpType::AllReduce)
            .collect();
        assert!(!rs.is_empty());
        assert_eq!(rs.len(), ar.len(), "one all-reduce per reduce-scatter");
        for (r, a) in rs.iter().zip(&ar) {
            assert_eq!(a.id, r.id + 1, "AR immediately follows its RS");
            assert_eq!(a.scope, r.scope);
            assert_eq!(r.group, CommGroup::IntraNode);
            assert_eq!(a.group, CommGroup::CrossNode);
            // AR moves the rank's 1/G shard of what the RS reduced.
            let g = topo.gpus_per_node() as f64;
            assert!((a.bytes - r.bytes / g).abs() < 1e-6);
        }
        // All-gathers shard within the node too.
        assert!(p
            .collectives()
            .filter(|c| c.op.op == OpType::AllGather)
            .all(|c| c.group == CommGroup::IntraNode));
    }

    #[test]
    fn hsdp_one_node_degenerates_to_fsdp() {
        use crate::config::{Sharding, Topology};
        let cfg = small_cfg();
        let mut w = wl(FsdpVersion::V2);
        w.sharding = Sharding::Hsdp;
        let topo = Topology::mi300x_cluster(1);
        let hsdp = build_program_topo(&cfg, &w, &topo);
        let mut w2 = w.clone();
        w2.sharding = Sharding::Fsdp;
        let fsdp = build_program_topo(&cfg, &w2, &topo);
        assert_eq!(hsdp.items.len(), fsdp.items.len());
        assert_eq!(hsdp.num_collectives, fsdp.num_collectives);
        assert!(hsdp.collectives().all(|c| c.group == CommGroup::World));
    }

    #[test]
    fn hsdp_shards_copies_by_node_group() {
        use crate::config::{Sharding, Topology};
        let cfg = small_cfg();
        let mut w = wl(FsdpVersion::V2);
        w.sharding = Sharding::Hsdp;
        let p2 = build_program_topo(&cfg, &w, &Topology::mi300x_cluster(2));
        let p_flat = build_program(&cfg, &w, 16);
        let copy_bytes = |p: &Program| {
            p.kernels()
                .find(|k| k.desc.op.op == OpType::ParamCopy)
                .map(|k| k.desc.bytes)
                .unwrap()
        };
        // HSDP shards over 8 (one node), flat FSDP over all 16 ranks:
        // per-rank copies are twice as large under HSDP.
        assert!((copy_bytes(&p2) - 2.0 * copy_bytes(&p_flat)).abs() < 1e-6);
    }
}
