//! Caching-allocator model — the mechanism behind Observation 6.
//!
//! FSDPv1 (flat-param) frees a gathered layer only when the autograd graph
//! drops its last reference, which races against the prefetch all-gather of
//! upcoming layers; when the race is lost the allocator cannot reuse the
//! block and must carve a fresh one (memory spike + extra page-touch
//! traffic) [Section II-B, ref 39]. FSDPv2's per-parameter sharding frees
//! deterministically, so every all-gather reuses the same cached block.
//!
//! The output that matters downstream is (a) the allocated-bytes timeline
//! (memory spikes) and (b) the *variability* of allocation behaviour per
//! iteration, which the DVFS governor consumes as HBM power-noise sigma:
//! deterministic memory behaviour -> stable power -> higher sustained
//! clocks (Insight 8 / Observation 6).

use crate::config::FsdpVersion;
use crate::util::prng::Rng;
use crate::util::stats::Welford;

/// One allocation event in dispatch order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MemEvent {
    /// Reused a cached block (cheap, no extra traffic).
    Reuse { bytes: u64 },
    /// Carved a fresh block (page touches => extra HBM traffic).
    Fresh { bytes: u64 },
    /// Freed a block back to the cache.
    Free { bytes: u64 },
}

/// Simple size-bucketed caching allocator.
#[derive(Debug)]
pub struct CachingAllocator {
    version: FsdpVersion,
    /// Free-list of cached block sizes.
    cache: Vec<u64>,
    /// Currently live bytes (allocated to tensors).
    pub live_bytes: u64,
    /// High-water mark.
    pub peak_bytes: u64,
    /// Blocks whose free has been deferred (v1 race).
    deferred: Vec<u64>,
    /// Probability that a v1 free is deferred past the next alloc.
    defer_p: f64,
    /// Events log.
    pub events: Vec<MemEvent>,
    fresh_allocs: u64,
    total_allocs: u64,
    rng: Rng,
}

impl CachingAllocator {
    pub fn new(version: FsdpVersion, seed: u64) -> Self {
        Self {
            version,
            cache: Vec::new(),
            live_bytes: 0,
            peak_bytes: 0,
            deferred: Vec::new(),
            defer_p: 0.35,
            events: Vec::new(),
            fresh_allocs: 0,
            total_allocs: 0,
            rng: Rng::substream(seed, "allocator"),
        }
    }

    /// Allocate a gather buffer. Returns true if served from cache.
    pub fn alloc(&mut self, bytes: u64) -> bool {
        self.total_allocs += 1;
        // Best-fit from cache.
        let pos = self
            .cache
            .iter()
            .enumerate()
            .filter(|(_, &sz)| sz >= bytes)
            .min_by_key(|(_, &sz)| sz)
            .map(|(i, _)| i);
        self.live_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.live_bytes + self.deferred_bytes());
        match pos {
            Some(i) => {
                self.cache.swap_remove(i);
                self.events.push(MemEvent::Reuse { bytes });
                true
            }
            None => {
                self.fresh_allocs += 1;
                self.events.push(MemEvent::Fresh { bytes });
                false
            }
        }
    }

    /// Free a gather buffer. Under FSDPv1 the free may be deferred past the
    /// next allocation (the allocator race); FSDPv2 frees immediately.
    pub fn free(&mut self, bytes: u64) {
        match self.version {
            FsdpVersion::V2 => self.complete_free(bytes),
            FsdpVersion::V1 => {
                if self.rng.bool(self.defer_p) {
                    self.deferred.push(bytes);
                } else {
                    self.complete_free(bytes);
                }
            }
        }
    }

    /// Flush deferred frees (autograd finally dropped the references).
    pub fn flush_deferred(&mut self) {
        let pending: Vec<u64> = self.deferred.drain(..).collect();
        for bytes in pending {
            self.complete_free(bytes);
        }
    }

    fn complete_free(&mut self, bytes: u64) {
        self.live_bytes = self.live_bytes.saturating_sub(bytes);
        self.cache.push(bytes);
        self.events.push(MemEvent::Free { bytes });
    }

    fn deferred_bytes(&self) -> u64 {
        self.deferred.iter().sum()
    }

    /// Fraction of allocations that required fresh blocks — extra HBM
    /// page-touch traffic, and the driver of power variability.
    pub fn fresh_ratio(&self) -> f64 {
        if self.total_allocs == 0 {
            0.0
        } else {
            self.fresh_allocs as f64 / self.total_allocs as f64
        }
    }

    /// Reset the high-water mark (between iterations).
    pub fn reset_peak(&mut self) {
        self.peak_bytes = self.live_bytes + self.deferred_bytes();
    }
}

/// Run the allocator through `iters` iterations of `layers` gather/free
/// pairs and report the power-noise statistics the DVFS model consumes.
#[derive(Debug, Clone, Copy, Default)]
pub struct AllocStats {
    /// Mean fresh-allocation ratio.
    pub fresh_ratio: f64,
    /// Std-dev of per-iteration peak bytes (the memory-spike variability).
    pub peak_sigma_bytes: f64,
    /// Mean per-iteration peak bytes.
    pub peak_mean_bytes: f64,
}

pub fn simulate_gather_pattern(
    version: FsdpVersion,
    layer_bytes: u64,
    layers: u32,
    iters: u32,
    seed: u64,
) -> AllocStats {
    let mut a = CachingAllocator::new(version, seed);
    let mut peaks = Welford::default();
    for _ in 0..iters {
        a.reset_peak();
        // Forward: prefetch depth 2 — alloc l, l+1 live together, free l-1.
        for l in 0..layers {
            a.alloc(layer_bytes);
            if l >= 1 {
                a.free(layer_bytes);
            }
            if l % 4 == 3 {
                a.flush_deferred();
            }
        }
        a.free(layer_bytes);
        // Backward: same pattern reversed.
        for l in 0..layers {
            a.alloc(layer_bytes);
            if l >= 1 {
                a.free(layer_bytes);
            }
            if l % 4 == 3 {
                a.flush_deferred();
            }
        }
        a.free(layer_bytes);
        a.flush_deferred();
        peaks.push(a.peak_bytes as f64);
    }
    AllocStats {
        fresh_ratio: a.fresh_ratio(),
        peak_sigma_bytes: peaks.std(),
        peak_mean_bytes: peaks.mean(),
    }
}

/// Replay a serving KV-cache residency timeline (bytes resident per
/// scheduler step, from the continuous batcher) through the allocator and
/// report the same power-noise statistics as the training gather pattern.
///
/// KV memory is paged: growth and shrink happen in fixed `block_bytes`
/// pages, and the serving runtime frees deterministically at request
/// completion — FSDPv2 allocator semantics, so reuse is near-total once
/// the pool is warm. What *does* vary is the per-step resident level
/// itself (requests admit and complete continuously), and that level
/// variability is what reaches the DVFS governor as HBM power noise.
pub fn simulate_kv_pattern(
    resident_bytes: &[f64],
    block_bytes: u64,
    seed: u64,
) -> AllocStats {
    let block = block_bytes.max(1);
    let mut a = CachingAllocator::new(FsdpVersion::V2, seed);
    let mut peaks = Welford::default();
    let mut blocks = 0u64;
    for &target in resident_bytes {
        a.reset_peak();
        let want = (target.max(0.0) / block as f64).ceil() as u64;
        while blocks < want {
            a.alloc(block);
            blocks += 1;
        }
        while blocks > want {
            a.free(block);
            blocks -= 1;
        }
        peaks.push(a.peak_bytes as f64);
    }
    AllocStats {
        fresh_ratio: a.fresh_ratio(),
        peak_sigma_bytes: peaks.std(),
        peak_mean_bytes: peaks.mean(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v2_is_deterministic_and_reuses() {
        let s = simulate_gather_pattern(FsdpVersion::V2, 1 << 20, 32, 10, 1);
        // After the first iteration everything comes from cache.
        assert!(s.fresh_ratio < 0.05, "fresh_ratio {}", s.fresh_ratio);
        assert_eq!(s.peak_sigma_bytes, 0.0);
    }

    #[test]
    fn v1_spikes_and_varies() {
        let v1 = simulate_gather_pattern(FsdpVersion::V1, 1 << 20, 32, 10, 1);
        let v2 = simulate_gather_pattern(FsdpVersion::V2, 1 << 20, 32, 10, 1);
        assert!(v1.fresh_ratio > v2.fresh_ratio);
        assert!(v1.peak_sigma_bytes > 0.0);
        assert!(v1.peak_mean_bytes > v2.peak_mean_bytes);
    }

    #[test]
    fn no_leak_at_iteration_end() {
        let mut a = CachingAllocator::new(FsdpVersion::V1, 7);
        for _ in 0..3 {
            for _ in 0..8 {
                a.alloc(100);
                a.free(100);
            }
            a.flush_deferred();
            assert_eq!(a.live_bytes, 0);
        }
    }

    #[test]
    fn peak_counts_deferred_blocks() {
        let mut a = CachingAllocator::new(FsdpVersion::V1, 3);
        // Force a deferral by trying repeatedly.
        let mut deferred_seen = false;
        for _ in 0..64 {
            a.alloc(10);
            a.free(10);
            if !a.deferred.is_empty() {
                deferred_seen = true;
                a.alloc(10);
                assert!(a.peak_bytes >= 20);
                a.free(10);
                break;
            }
        }
        a.flush_deferred();
        assert!(deferred_seen, "v1 never deferred in 64 tries (p=0.35)");
    }

    #[test]
    fn cache_best_fit_prefers_smallest_sufficient() {
        let mut a = CachingAllocator::new(FsdpVersion::V2, 1);
        a.alloc(100);
        a.alloc(50);
        a.free(100);
        a.free(50);
        // Now cache has [100, 50]; alloc(40) should take the 50 block.
        assert!(a.alloc(40));
        assert_eq!(a.cache, vec![100]);
    }

    #[test]
    fn kv_pattern_varying_residency_has_sigma() {
        // Ramp up then down: resident level varies per step.
        let timeline: Vec<f64> =
            (0..16).map(|i| (8 - (i as i64 - 8).abs()) as f64 * 4096.0).collect();
        let s = simulate_kv_pattern(&timeline, 1024, 7);
        assert!(s.peak_sigma_bytes > 0.0);
        assert!(s.peak_mean_bytes > 0.0);
        // Deterministic (V2) frees: shrink-reuse keeps fresh ratio modest.
        assert!(s.fresh_ratio <= 1.0);
    }

    #[test]
    fn kv_pattern_flat_residency_is_quiet_and_deterministic() {
        let flat = vec![64.0 * 1024.0; 12];
        let a = simulate_kv_pattern(&flat, 1024, 7);
        let b = simulate_kv_pattern(&flat, 1024, 7);
        assert_eq!(a.peak_sigma_bytes, 0.0);
        assert_eq!(a.peak_mean_bytes, b.peak_mean_bytes);
        assert_eq!(a.fresh_ratio, b.fresh_ratio);
    }

    #[test]
    fn kv_pattern_empty_timeline_is_zero() {
        let s = simulate_kv_pattern(&[], 1024, 1);
        assert_eq!(s.peak_mean_bytes, 0.0);
        assert_eq!(s.peak_sigma_bytes, 0.0);
        assert_eq!(s.fresh_ratio, 0.0);
    }
}
