//! Counterfactual what-if replay: one workload, many power-management
//! policies, a ranked advisor report.
//!
//! The per-op Eq. 6–10 breakdown (fig15) says *where* the
//! theoretical-vs-observed gap comes from — and DVFS frequency overhead is
//! its single largest term — but never answers the operator's question:
//! "what would iteration time and energy be under a different policy?".
//! This module closes that loop: it replays the identical workload (same
//! seed, same program, same jitter draws) under a set of
//! [`GovernorKind`]s and reports Δ iteration time, Δ energy and the
//! perf-per-watt frontier per policy — the end-to-end "what you would
//! gain" numbers the paper's power-management insight calls for.
//!
//! Replays are engine-only (no counter passes, no CPU model — policies
//! affect neither), fan out over the deterministic campaign runner, and
//! are reproducible byte for byte (`tests/pipeline.rs` and the CI what-if
//! smoke pin two invocations identical).

use crate::campaign::runner::run_ordered;
use crate::chopper::index::TraceIndex;
use crate::chopper::report::Figure;
use crate::chopper::throughput::throughput;
use crate::config::{ModelConfig, NodeSpec, WorkloadConfig};
use crate::sim::{Engine, EngineParams, GovernorKind};
use crate::util::{ascii, stats};
use std::fmt::Write as _;

/// One policy's replay outcome. Durations in ms, energy in joules per
/// iteration (cluster-wide, sampled iterations), deltas in percent
/// relative to the baseline policy (negative Δ = better).
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyOutcome {
    pub governor: GovernorKind,
    /// Median per-iteration wall cost of the slowest GPU.
    pub iter_ms: f64,
    pub delta_iter_pct: f64,
    /// Joules per sampled iteration, summed over every rank.
    pub energy_per_iter_j: f64,
    pub delta_energy_pct: f64,
    /// Mean per-GPU package power over active windows (> 400 W).
    pub power_w: f64,
    /// Mean engine clock over active windows.
    pub freq_mhz: f64,
    pub tokens_per_sec: f64,
    /// Perf per watt, expressed as tokens per joule.
    pub tokens_per_j: f64,
    /// Clock capacity lost to thermal throttling per sampled iteration,
    /// cluster-wide ms (0 for thermal-disabled replays).
    pub throttle_loss_ms: f64,
    /// On the (iteration time, energy) Pareto frontier: no other policy
    /// is at least as fast *and* at least as cheap (strictly better in
    /// one).
    pub frontier: bool,
}

/// The ranked advisor report for one workload.
#[derive(Debug, Clone, PartialEq)]
pub struct WhatIfReport {
    /// The policy deltas are measured against ([`EngineParams::governor`]
    /// of the replayed parameter set).
    pub baseline: GovernorKind,
    /// Outcomes ranked fastest-first (iteration time ascending, policy
    /// name breaking exact ties) — the "Δ iteration time" ranking.
    pub rows: Vec<PolicyOutcome>,
    /// Whether the replayed parameter set had thermal coupling enabled —
    /// gates the throttle-loss column so thermal-disabled reports render
    /// byte-identically to pre-thermal builds.
    pub thermal: bool,
}

impl WhatIfReport {
    pub fn row(&self, g: GovernorKind) -> Option<&PolicyOutcome> {
        self.rows.iter().find(|r| r.governor == g)
    }

    /// The fastest policy (rank 1).
    pub fn fastest(&self) -> &PolicyOutcome {
        &self.rows[0]
    }

    /// The best perf-per-watt policy.
    pub fn best_perf_per_watt(&self) -> &PolicyOutcome {
        self.rows
            .iter()
            .max_by(|a, b| a.tokens_per_j.total_cmp(&b.tokens_per_j))
            .expect("report has rows")
    }
}

/// Replay `wl` under every governor in `governors` (the baseline
/// `params.governor` is added automatically if absent, so deltas always
/// have a referent) and rank the outcomes. `jobs` fans replays out over
/// the deterministic ordered runner; results are byte-identical to a
/// serial replay.
pub fn replay(
    node: &NodeSpec,
    cfg: &ModelConfig,
    wl: &WorkloadConfig,
    params: &EngineParams,
    governors: &[GovernorKind],
    jobs: usize,
) -> WhatIfReport {
    replay_topo(
        &crate::config::Topology::single(node.clone()),
        cfg,
        wl,
        params,
        governors,
        jobs,
    )
}

/// [`replay`] over a full cluster topology, including folded ones
/// (DESIGN.md §13): a `--fold` replay runs each policy over the
/// representative nodes only and reports logical-cluster totals, so the
/// advisor scales to 10k-GPU what-ifs.
pub fn replay_topo(
    topo: &crate::config::Topology,
    cfg: &ModelConfig,
    wl: &WorkloadConfig,
    params: &EngineParams,
    governors: &[GovernorKind],
    jobs: usize,
) -> WhatIfReport {
    let baseline = params.governor;
    let mut kinds: Vec<GovernorKind> = Vec::new();
    if !governors.contains(&baseline) {
        kinds.push(baseline);
    }
    for &g in governors {
        if !kinds.contains(&g) {
            kinds.push(g);
        }
    }

    let mut rows = run_ordered(&kinds, jobs, |_, &g| {
        let mut p = params.clone();
        p.governor = g;
        measure(topo, cfg, wl, p, g)
    });

    // Rank by Δ iteration time (ascending), names breaking exact ties so
    // the ordering is total and stable across runs.
    rows.sort_by(|a, b| {
        a.iter_ms
            .total_cmp(&b.iter_ms)
            .then_with(|| a.governor.name().cmp(b.governor.name()))
    });

    // Deltas vs the baseline policy's row.
    let (base_iter, base_energy) = rows
        .iter()
        .find(|r| r.governor == baseline)
        .map(|r| (r.iter_ms, r.energy_per_iter_j))
        .expect("baseline policy was replayed");
    for r in &mut rows {
        r.delta_iter_pct = 100.0 * (r.iter_ms / base_iter.max(1e-12) - 1.0);
        r.delta_energy_pct =
            100.0 * (r.energy_per_iter_j / base_energy.max(1e-12) - 1.0);
    }

    // Pareto frontier on (iteration time, energy), both minimized.
    for i in 0..rows.len() {
        let dominated = (0..rows.len()).any(|j| {
            j != i
                && rows[j].iter_ms <= rows[i].iter_ms
                && rows[j].energy_per_iter_j <= rows[i].energy_per_iter_j
                && (rows[j].iter_ms < rows[i].iter_ms
                    || rows[j].energy_per_iter_j < rows[i].energy_per_iter_j)
        });
        rows[i].frontier = !dominated;
    }

    WhatIfReport {
        baseline,
        rows,
        thermal: params.thermal.is_some(),
    }
}

/// Engine-only replay of one policy, reduced to its outcome row (deltas
/// and frontier are filled in by [`replay`] once every row exists).
fn measure(
    topo: &crate::config::Topology,
    cfg: &ModelConfig,
    wl: &WorkloadConfig,
    params: EngineParams,
    g: GovernorKind,
) -> PolicyOutcome {
    let out = Engine::with_topology(topo.clone(), cfg, wl, params).run();
    let idx = TraceIndex::build(&out.trace);

    // Logical-cluster accounting, mirroring campaign::runner::summarize:
    // a folded trace holds the representative ranks only, so tokens come
    // from the logical world and per-rank energy totals expand by the
    // fold factor (both the identity in exact mode).
    let fold = out.trace.meta.fold_factor() as f64;
    let tokens =
        wl.tokens_per_iteration(out.trace.meta.logical_gpus() as u64) as f64;
    let tp = throughput(&idx, tokens);
    // Same energy reduction as campaign::runner::summarize — one code
    // path for "joules per sampled iteration" everywhere.
    let sampled_iters = wl.iterations.saturating_sub(wl.warmup).max(1) as f64;
    let energy_per_iter_j =
        out.power.sampled_energy_j(wl.warmup) * fold / sampled_iters;

    // Active-window telemetry, the paper's Fig. 14 averaging — the same
    // `PowerTrace::active_samples` reduction campaign summaries use.
    let freqs: Vec<f64> = out.power.active_samples().map(|s| s.freq_mhz).collect();
    let powers: Vec<f64> = out.power.active_samples().map(|s| s.power_w).collect();

    let tokens_per_j = if energy_per_iter_j > 0.0 {
        tokens / energy_per_iter_j
    } else {
        0.0
    };
    // Same logical-cluster expansion as energy: representative ranks'
    // sampled throttle loss × fold, per sampled iteration.
    let throttle_loss_ms =
        out.power.sampled_throttle_loss_ns(wl.warmup) * fold
            / sampled_iters
            / 1e6;
    PolicyOutcome {
        governor: g,
        iter_ms: finite(tp.iter_ns / 1e6),
        delta_iter_pct: 0.0,
        energy_per_iter_j: finite(energy_per_iter_j),
        delta_energy_pct: 0.0,
        power_w: finite(stats::mean(&powers)),
        freq_mhz: finite(stats::mean(&freqs)),
        tokens_per_sec: finite(tp.tokens_per_sec),
        tokens_per_j: finite(tokens_per_j),
        throttle_loss_ms: finite(throttle_loss_ms),
        frontier: false,
    }
}

fn finite(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

// ---------------------------------------------------------------------------
// Serving replay (DESIGN.md §10): same counterfactual machinery, serving
// metrics — policies ranked by joules per request, tokens-per-joule shown
// alongside. A separate report/render pair so the training advisor output
// stays byte-identical.
// ---------------------------------------------------------------------------

/// One policy's serving replay outcome. Deltas in percent vs the baseline
/// policy (negative = better).
#[derive(Debug, Clone, PartialEq)]
pub struct ServingPolicyOutcome {
    pub governor: GovernorKind,
    /// Joules per request, cluster-wide — the ranking key.
    pub joules_per_request: f64,
    pub delta_j_req_pct: f64,
    /// Generated tokens per joule.
    pub tok_per_joule: f64,
    pub ttft_p99_ms: f64,
    pub e2e_p99_ms: f64,
    pub delta_p99_pct: f64,
    pub goodput_rps: f64,
    /// On the (e2e p99, joules/request) Pareto frontier.
    pub frontier: bool,
}

/// The ranked serving advisor report.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingWhatIfReport {
    pub baseline: GovernorKind,
    /// Outcomes ranked cheapest-first (joules per request ascending,
    /// policy name breaking exact ties).
    pub rows: Vec<ServingPolicyOutcome>,
}

impl ServingWhatIfReport {
    pub fn row(&self, g: GovernorKind) -> Option<&ServingPolicyOutcome> {
        self.rows.iter().find(|r| r.governor == g)
    }

    /// The cheapest policy per request (rank 1).
    pub fn cheapest(&self) -> &ServingPolicyOutcome {
        &self.rows[0]
    }
}

/// Replay one serving scenario under every governor in `governors` and
/// rank the outcomes by joules per request. Fan-out and determinism
/// contract match [`replay`].
pub fn replay_serving(
    topo: &crate::config::Topology,
    model: &ModelConfig,
    scfg: &crate::config::ServingConfig,
    params: &EngineParams,
    governors: &[GovernorKind],
    jobs: usize,
) -> ServingWhatIfReport {
    let baseline = params.governor;
    let mut kinds: Vec<GovernorKind> = Vec::new();
    if !governors.contains(&baseline) {
        kinds.push(baseline);
    }
    for &g in governors {
        if !kinds.contains(&g) {
            kinds.push(g);
        }
    }

    let mut rows = run_ordered(&kinds, jobs, |_, &g| {
        let mut p = params.clone();
        p.governor = g;
        let out = crate::serve::run_serving(topo, model, scfg, p);
        let r = &out.report;
        ServingPolicyOutcome {
            governor: g,
            joules_per_request: finite(r.energy_per_request_j),
            delta_j_req_pct: 0.0,
            tok_per_joule: finite(r.tok_per_joule),
            ttft_p99_ms: finite(r.ttft_ms.p99),
            e2e_p99_ms: finite(r.e2e_ms.p99),
            delta_p99_pct: 0.0,
            goodput_rps: finite(r.goodput_rps),
            frontier: false,
        }
    });

    rows.sort_by(|a, b| {
        a.joules_per_request
            .total_cmp(&b.joules_per_request)
            .then_with(|| a.governor.name().cmp(b.governor.name()))
    });

    let (base_j, base_p99) = rows
        .iter()
        .find(|r| r.governor == baseline)
        .map(|r| (r.joules_per_request, r.e2e_p99_ms))
        .expect("baseline policy was replayed");
    for r in &mut rows {
        r.delta_j_req_pct =
            100.0 * (r.joules_per_request / base_j.max(1e-12) - 1.0);
        r.delta_p99_pct = 100.0 * (r.e2e_p99_ms / base_p99.max(1e-12) - 1.0);
    }

    // Pareto frontier on (e2e p99 latency, joules per request).
    for i in 0..rows.len() {
        let dominated = (0..rows.len()).any(|j| {
            j != i
                && rows[j].e2e_p99_ms <= rows[i].e2e_p99_ms
                && rows[j].joules_per_request <= rows[i].joules_per_request
                && (rows[j].e2e_p99_ms < rows[i].e2e_p99_ms
                    || rows[j].joules_per_request < rows[i].joules_per_request)
        });
        rows[i].frontier = !dominated;
    }

    ServingWhatIfReport { baseline, rows }
}

// ---------------------------------------------------------------------------
// Fault replay (DESIGN.md §11): same counterfactual machinery, a fault
// dimension instead of a policy dimension — "what does one straggler /
// degraded link / dropout cost this workload?". The healthy (empty) fault
// set is always replayed as the baseline referent.
// ---------------------------------------------------------------------------

/// One fault set's replay outcome. Deltas in percent vs the healthy
/// (`none`) baseline row; `lost_ms` is checkpoint-restart time, `blocked_ms`
/// the collective time ranks spent waiting on slower peers.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultOutcome {
    /// Canonical fault-set label (`none` for the healthy baseline).
    pub label: String,
    /// Median per-iteration wall cost of the slowest GPU.
    pub iter_ms: f64,
    pub delta_iter_pct: f64,
    /// Joules per sampled iteration, summed over every rank.
    pub energy_per_iter_j: f64,
    pub delta_energy_pct: f64,
    /// Time lost to dropout + checkpoint-restart, ms.
    pub lost_ms: f64,
    /// Collective time spent blocked on slower peers, ms (sampled iters).
    pub blocked_ms: f64,
    pub tokens_per_sec: f64,
    pub tokens_per_j: f64,
}

/// The ranked fault-impact report for one workload.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultWhatIfReport {
    /// Outcomes ranked fastest-first (iteration time ascending, label
    /// breaking exact ties). The `none` baseline is always present.
    pub rows: Vec<FaultOutcome>,
}

impl FaultWhatIfReport {
    pub fn row(&self, label: &str) -> Option<&FaultOutcome> {
        self.rows.iter().find(|r| r.label == label)
    }

    /// The healthy baseline row.
    pub fn baseline(&self) -> &FaultOutcome {
        self.row("none").expect("healthy baseline was replayed")
    }
}

/// Replay `wl` under every fault set in `fault_sets` (the healthy empty
/// set is added automatically if absent, so deltas always have a referent)
/// and rank the outcomes by iteration time. Fan-out and determinism
/// contract match [`replay`]: each fault set's engine run draws the same
/// base seed, so a fault row differs from the baseline only by the fault.
pub fn replay_faults(
    node: &NodeSpec,
    cfg: &ModelConfig,
    wl: &WorkloadConfig,
    params: &EngineParams,
    fault_sets: &[Vec<crate::config::FaultSpec>],
    jobs: usize,
) -> FaultWhatIfReport {
    let mut sets: Vec<Vec<crate::config::FaultSpec>> = Vec::new();
    if !fault_sets.iter().any(|s| s.is_empty()) {
        sets.push(Vec::new());
    }
    for s in fault_sets {
        if !sets.contains(s) {
            sets.push(s.clone());
        }
    }

    let mut rows = run_ordered(&sets, jobs, |_, set| {
        let mut p = params.clone();
        p.faults = set.clone();
        let out = Engine::new(node, cfg, wl, p).run();
        let idx = TraceIndex::build(&out.trace);
        let tokens =
            wl.tokens_per_iteration(out.trace.meta.num_gpus as u64) as f64;
        let tp = throughput(&idx, tokens);
        let sampled_iters =
            wl.iterations.saturating_sub(wl.warmup).max(1) as f64;
        let energy_per_iter_j =
            out.power.sampled_energy_j(wl.warmup) / sampled_iters;
        let tokens_per_j = if energy_per_iter_j > 0.0 {
            tokens / energy_per_iter_j
        } else {
            0.0
        };
        let blocked_ms = if set.is_empty() {
            0.0
        } else {
            finite(idx.blocked_on_straggler_ns() / 1e6)
        };
        FaultOutcome {
            label: crate::config::faults::set_label(set),
            iter_ms: finite(tp.iter_ns / 1e6),
            delta_iter_pct: 0.0,
            energy_per_iter_j: finite(energy_per_iter_j),
            delta_energy_pct: 0.0,
            lost_ms: finite(out.trace.meta.fault_lost_ns / 1e6),
            blocked_ms,
            tokens_per_sec: finite(tp.tokens_per_sec),
            tokens_per_j: finite(tokens_per_j),
        }
    });

    rows.sort_by(|a, b| {
        a.iter_ms
            .total_cmp(&b.iter_ms)
            .then_with(|| a.label.cmp(&b.label))
    });

    let (base_iter, base_energy) = rows
        .iter()
        .find(|r| r.label == "none")
        .map(|r| (r.iter_ms, r.energy_per_iter_j))
        .expect("healthy baseline was replayed");
    for r in &mut rows {
        r.delta_iter_pct = 100.0 * (r.iter_ms / base_iter.max(1e-12) - 1.0);
        r.delta_energy_pct =
            100.0 * (r.energy_per_iter_j / base_energy.max(1e-12) - 1.0);
    }

    FaultWhatIfReport { rows }
}

/// Render the fault-impact report (the robustness sibling of [`render`]).
pub fn render_faults(report: &FaultWhatIfReport) -> Figure {
    let mut csv = String::from(
        "rank,faults,iter_ms,delta_iter_pct,energy_per_iter_j,\
         delta_energy_pct,lost_ms,blocked_ms,tokens_per_sec,tokens_per_j\n",
    );
    let mut rows: Vec<Vec<String>> = Vec::with_capacity(report.rows.len());
    for (rank, r) in report.rows.iter().enumerate() {
        rows.push(vec![
            format!("{}", rank + 1),
            r.label.clone(),
            format!("{:.2}", r.iter_ms),
            format!("{:+.1}%", r.delta_iter_pct),
            format!("{:.1}", r.energy_per_iter_j),
            format!("{:+.1}%", r.delta_energy_pct),
            format!("{:.2}", r.lost_ms),
            format!("{:.2}", r.blocked_ms),
            format!("{:.0}", r.tokens_per_sec),
            format!("{:.2}", r.tokens_per_j),
        ]);
        let _ = writeln!(
            csv,
            "{},{},{:.4},{:.2},{:.4},{:.2},{:.4},{:.4},{:.2},{:.4}",
            rank + 1,
            r.label,
            r.iter_ms,
            r.delta_iter_pct,
            r.energy_per_iter_j,
            r.delta_energy_pct,
            r.lost_ms,
            r.blocked_ms,
            r.tokens_per_sec,
            r.tokens_per_j
        );
    }
    let mut out = String::from(
        "What-if — fault injection replay (Δ vs healthy `none` baseline)\n\n",
    );
    out.push_str(&ascii::table(
        &[
            "#", "faults", "iter ms", "Δiter", "J/iter", "ΔJ", "lost ms",
            "blocked ms", "tok/s", "tok/J",
        ],
        &rows,
    ));
    let worst = report.rows.last().expect("report has rows");
    let _ = write!(
        out,
        "\n  worst case: {} ({:+.1}% iteration time, {:+.1}% energy, \
         {:.2} ms lost to restarts)\n",
        worst.label, worst.delta_iter_pct, worst.delta_energy_pct,
        worst.lost_ms
    );
    Figure {
        id: "whatif_faults",
        title: "What-if — fault injection replay".into(),
        ascii: out,
        csv,
        svg: None,
    }
}

/// Render the serving advisor report (the serving sibling of [`render`]).
pub fn render_serving(report: &ServingWhatIfReport) -> Figure {
    let mut csv = String::from(
        "rank,governor,joules_per_request,delta_j_req_pct,tok_per_joule,\
         ttft_p99_ms,e2e_p99_ms,delta_p99_pct,goodput_rps,frontier\n",
    );
    let mut rows: Vec<Vec<String>> = Vec::with_capacity(report.rows.len());
    for (rank, r) in report.rows.iter().enumerate() {
        rows.push(vec![
            format!("{}", rank + 1),
            r.governor.name().to_string(),
            format!("{:.2}", r.joules_per_request),
            format!("{:+.1}%", r.delta_j_req_pct),
            format!("{:.4}", r.tok_per_joule),
            format!("{:.1}", r.ttft_p99_ms),
            format!("{:.1}", r.e2e_p99_ms),
            format!("{:+.1}%", r.delta_p99_pct),
            format!("{:.2}", r.goodput_rps),
            if r.frontier { "*".into() } else { String::new() },
        ]);
        let _ = writeln!(
            csv,
            "{},{},{:.4},{:.2},{:.6},{:.4},{:.4},{:.2},{:.4},{}",
            rank + 1,
            r.governor.name(),
            r.joules_per_request,
            r.delta_j_req_pct,
            r.tok_per_joule,
            r.ttft_p99_ms,
            r.e2e_p99_ms,
            r.delta_p99_pct,
            r.goodput_rps,
            r.frontier as u8
        );
    }
    let mut out = format!(
        "What-if — governor policy replay, serving (baseline: {}, ranked by J/request)\n\n",
        report.baseline.name()
    );
    out.push_str(&ascii::table(
        &[
            "#", "governor", "J/req", "ΔJ/req", "tok/J", "ttft p99",
            "e2e p99", "Δp99", "rps", "pareto",
        ],
        &rows,
    ));
    let cheap = report.cheapest();
    let frontier: Vec<&str> = report
        .rows
        .iter()
        .filter(|r| r.frontier)
        .map(|r| r.governor.name())
        .collect();
    let _ = write!(
        out,
        "\n  cheapest per request: {} ({:+.1}% J/request, {:+.1}% e2e p99)\n\
         \x20 pareto frontier (p99 × J/request): {}\n",
        cheap.governor.name(),
        cheap.delta_j_req_pct,
        cheap.delta_p99_pct,
        frontier.join(", ")
    );
    Figure {
        id: "whatif_serving",
        title: "What-if — governor policy replay (serving)".into(),
        ascii: out,
        csv,
        svg: None,
    }
}

/// Render the advisor report: the ranked policy table plus the headline
/// recommendations. Pure function of the report, so two replays of the
/// same workload render byte-identically.
pub fn render(report: &WhatIfReport) -> Figure {
    // The throttle-loss column exists only for thermal-enabled replays —
    // a disabled report's bytes are pinned by the pipeline goldens.
    let mut csv = String::from(
        "rank,governor,iter_ms,delta_iter_pct,energy_per_iter_j,\
         delta_energy_pct,power_w,freq_mhz,tokens_per_sec,tokens_per_j,",
    );
    if report.thermal {
        csv.push_str("throttle_loss_ms,");
    }
    csv.push_str("frontier\n");
    let mut rows: Vec<Vec<String>> = Vec::with_capacity(report.rows.len());
    for (rank, r) in report.rows.iter().enumerate() {
        let mut cells = vec![
            format!("{}", rank + 1),
            r.governor.name().to_string(),
            format!("{:.2}", r.iter_ms),
            format!("{:+.1}%", r.delta_iter_pct),
            format!("{:.1}", r.energy_per_iter_j),
            format!("{:+.1}%", r.delta_energy_pct),
            format!("{:.0}", r.power_w),
            format!("{:.0}", r.freq_mhz),
            format!("{:.0}", r.tokens_per_sec),
            format!("{:.2}", r.tokens_per_j),
        ];
        if report.thermal {
            cells.push(format!("{:.2}", r.throttle_loss_ms));
        }
        cells.push(if r.frontier { "*".into() } else { String::new() });
        rows.push(cells);
        let _ = write!(
            csv,
            "{},{},{:.4},{:.2},{:.4},{:.2},{:.1},{:.1},{:.2},{:.4},",
            rank + 1,
            r.governor.name(),
            r.iter_ms,
            r.delta_iter_pct,
            r.energy_per_iter_j,
            r.delta_energy_pct,
            r.power_w,
            r.freq_mhz,
            r.tokens_per_sec,
            r.tokens_per_j,
        );
        if report.thermal {
            let _ = write!(csv, "{:.4},", r.throttle_loss_ms);
        }
        let _ = writeln!(csv, "{}", r.frontier as u8);
    }
    let mut out = format!(
        "What-if — governor policy replay (baseline: {}, Δ vs baseline)\n\n",
        report.baseline.name()
    );
    let mut headers = vec![
        "#", "governor", "iter ms", "Δiter", "J/iter", "ΔJ", "W", "MHz",
        "tok/s", "tok/J",
    ];
    if report.thermal {
        headers.push("thr ms");
    }
    headers.push("pareto");
    out.push_str(&ascii::table(&headers, &rows));
    let fast = report.fastest();
    let ppw = report.best_perf_per_watt();
    let frontier: Vec<&str> = report
        .rows
        .iter()
        .filter(|r| r.frontier)
        .map(|r| r.governor.name())
        .collect();
    let _ = write!(
        out,
        "\n  fastest:        {} ({:+.1}% iteration time, {:+.1}% energy)\n\
         \x20 best perf/watt: {} ({:.2} tok/J)\n\
         \x20 pareto frontier (time × energy): {}\n",
        fast.governor.name(),
        fast.delta_iter_pct,
        fast.delta_energy_pct,
        ppw.governor.name(),
        ppw.tokens_per_j,
        frontier.join(", ")
    );
    if report.thermal {
        let hot = report
            .rows
            .iter()
            .max_by(|a, b| a.throttle_loss_ms.total_cmp(&b.throttle_loss_ms))
            .expect("report has rows");
        let _ = writeln!(
            out,
            "\x20 most throttled:  {} ({:.2} ms/iter lost to thermal limits)",
            hot.governor.name(),
            hot.throttle_loss_ms
        );
    }
    Figure {
        id: "whatif",
        title: "What-if — governor policy replay".into(),
        ascii: out,
        csv,
        svg: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FsdpVersion;

    fn small() -> (NodeSpec, ModelConfig, WorkloadConfig) {
        let node = NodeSpec::mi300x_node();
        let mut cfg = ModelConfig::llama3_8b();
        cfg.layers = 2;
        let mut wl = WorkloadConfig::new(2, 4096, FsdpVersion::V1);
        wl.iterations = 2;
        wl.warmup = 1;
        (node, cfg, wl)
    }

    fn report() -> WhatIfReport {
        let (node, cfg, wl) = small();
        replay(
            &node,
            &cfg,
            &wl,
            &EngineParams::default(),
            &GovernorKind::ALL,
            2,
        )
    }

    #[test]
    fn ranks_all_policies_with_baseline_deltas() {
        let r = report();
        assert_eq!(r.rows.len(), GovernorKind::ALL.len());
        assert_eq!(r.baseline, GovernorKind::Reactive);
        // Ranked ascending by iteration time.
        for w in r.rows.windows(2) {
            assert!(w[0].iter_ms <= w[1].iter_ms);
        }
        // Baseline row's deltas are exactly zero.
        let base = r.row(GovernorKind::Reactive).unwrap();
        assert_eq!(base.delta_iter_pct, 0.0);
        assert_eq!(base.delta_energy_pct, 0.0);
        // Every row carries real signal.
        for row in &r.rows {
            assert!(row.iter_ms > 0.0, "{}", row.governor);
            assert!(row.energy_per_iter_j > 0.0, "{}", row.governor);
            assert!(row.tokens_per_j > 0.0, "{}", row.governor);
        }
    }

    #[test]
    fn oracle_is_at_least_as_fast_as_reactive() {
        let r = report();
        let oracle = r.row(GovernorKind::Oracle).unwrap();
        let reactive = r.row(GovernorKind::Reactive).unwrap();
        assert!(
            oracle.iter_ms <= reactive.iter_ms,
            "peak clocks slower than throttled clocks: {} vs {}",
            oracle.iter_ms,
            reactive.iter_ms
        );
        assert!(oracle.freq_mhz >= reactive.freq_mhz);
    }

    #[test]
    fn frontier_contains_extremes_and_report_is_deterministic() {
        let a = report();
        let b = report();
        assert_eq!(a, b, "replay not deterministic");
        let fa = render(&a);
        let fb = render(&b);
        assert_eq!(fa.ascii, fb.ascii);
        assert_eq!(fa.csv, fb.csv);
        // The fastest policy and the lowest-energy policy can never be
        // dominated, so the frontier holds ≥ 1 row and includes both.
        let fastest = a.fastest();
        assert!(fastest.frontier, "fastest policy off the frontier");
        let cheapest = a
            .rows
            .iter()
            .min_by(|x, y| x.energy_per_iter_j.total_cmp(&y.energy_per_iter_j))
            .unwrap();
        assert!(cheapest.frontier, "cheapest policy off the frontier");
        // Rendering mentions every policy in the CSV.
        for g in GovernorKind::ALL {
            assert!(fa.csv.contains(g.name()), "{g} missing from CSV");
        }
    }

    #[test]
    fn parallel_replay_matches_serial() {
        let (node, cfg, wl) = small();
        let p = EngineParams::default();
        let serial = replay(&node, &cfg, &wl, &p, &GovernorKind::ALL, 1);
        let parallel = replay(&node, &cfg, &wl, &p, &GovernorKind::ALL, 4);
        assert_eq!(serial, parallel);
        assert_eq!(render(&serial).csv, render(&parallel).csv);
    }

    #[test]
    fn thermal_replay_prices_throttle_loss() {
        let (node, cfg, wl) = small();
        let base = replay(
            &node,
            &cfg,
            &wl,
            &EngineParams::default(),
            &GovernorKind::ALL,
            1,
        );
        assert!(!base.thermal);
        let disabled = render(&base);
        assert!(!disabled.csv.contains("throttle_loss_ms"));
        assert!(!disabled.ascii.contains("most throttled"));

        // Low ambient headroom: steady state far above the throttle knee,
        // tau a handful of governor windows.
        let mut p = EngineParams::default();
        p.thermal = Some(crate::sim::thermal::ThermalConfig {
            ambient_c: 85.0,
            tau_s: 0.005,
            ..Default::default()
        });
        let r = replay(&node, &cfg, &wl, &p, &GovernorKind::ALL, 2);
        assert!(r.thermal);
        let reactive = r.row(GovernorKind::Reactive).unwrap();
        assert!(
            reactive.throttle_loss_ms > 0.0,
            "no throttle loss under 85 C ambient"
        );
        let f = render(&r);
        assert!(f.csv.contains("throttle_loss_ms"));
        assert!(f.ascii.contains("most throttled"));
        // Deterministic like every other replay.
        assert_eq!(r, replay(&node, &cfg, &wl, &p, &GovernorKind::ALL, 1));
    }

    #[test]
    fn baseline_added_when_absent() {
        let (node, cfg, wl) = small();
        let p = EngineParams::default();
        let r = replay(&node, &cfg, &wl, &p, &[GovernorKind::Oracle], 1);
        assert_eq!(r.rows.len(), 2);
        assert!(r.row(GovernorKind::Reactive).is_some());
        assert!(r.row(GovernorKind::Oracle).is_some());
    }

    fn serving_report(jobs: usize) -> ServingWhatIfReport {
        let topo =
            crate::config::Topology::single(crate::config::NodeSpec::mi300x_node());
        let model = ModelConfig::mini();
        let mut scfg = crate::config::ServingConfig::new(16.0, 10);
        scfg.seed = 77;
        scfg.prompt = crate::config::LengthDist::lognormal(64, 0.4, 16, 256);
        scfg.output = crate::config::LengthDist::lognormal(12, 0.4, 2, 48);
        replay_serving(
            &topo,
            &model,
            &scfg,
            &EngineParams::default(),
            &GovernorKind::ALL,
            jobs,
        )
    }

    #[test]
    fn serving_replay_ranks_by_joules_per_request() {
        let r = serving_report(2);
        assert_eq!(r.rows.len(), GovernorKind::ALL.len());
        for w in r.rows.windows(2) {
            assert!(w[0].joules_per_request <= w[1].joules_per_request);
        }
        let base = r.row(r.baseline).unwrap();
        assert_eq!(base.delta_j_req_pct, 0.0);
        assert_eq!(base.delta_p99_pct, 0.0);
        for row in &r.rows {
            assert!(row.joules_per_request > 0.0, "{}", row.governor);
            assert!(row.tok_per_joule > 0.0, "{}", row.governor);
            assert!(row.e2e_p99_ms > 0.0, "{}", row.governor);
        }
        // The cheapest row can never be dominated.
        assert!(r.cheapest().frontier);
    }

    #[test]
    fn fault_replay_adds_baseline_and_ranks_by_iter_time() {
        use crate::config::FaultSpec;
        let (node, cfg, wl) = small();
        let p = EngineParams::default();
        let sets = vec![vec![FaultSpec::Straggler {
            rank: Some(0),
            factor: 0.7,
        }]];
        let r = replay_faults(&node, &cfg, &wl, &p, &sets, 1);
        // The healthy baseline was added automatically.
        assert_eq!(r.rows.len(), 2);
        let base = r.baseline();
        assert_eq!(base.delta_iter_pct, 0.0);
        assert_eq!(base.delta_energy_pct, 0.0);
        assert_eq!(base.blocked_ms, 0.0);
        // A 0.7× straggler makes iteration time strictly worse.
        let strag = r.row("strag_f0_7").unwrap();
        assert!(strag.iter_ms > base.iter_ms, "{} vs {}", strag.iter_ms, base.iter_ms);
        assert!(strag.delta_iter_pct > 0.0);
        assert!(strag.blocked_ms > 0.0, "straggler shows no blocked time");
        // Ranked ascending: the baseline is row 0.
        assert_eq!(r.rows[0].label, "none");
    }

    #[test]
    fn fault_replay_parallel_matches_serial_and_renders() {
        use crate::config::FaultSpec;
        let (node, cfg, wl) = small();
        let p = EngineParams::default();
        let sets = vec![
            Vec::new(),
            vec![FaultSpec::Straggler {
                rank: Some(1),
                factor: 0.8,
            }],
            vec![FaultSpec::Stalls {
                rate: 0.05,
                mean_us: 200.0,
            }],
        ];
        let serial = replay_faults(&node, &cfg, &wl, &p, &sets, 1);
        let parallel = replay_faults(&node, &cfg, &wl, &p, &sets, 4);
        assert_eq!(serial, parallel);
        let f = render_faults(&serial);
        assert_eq!(f.id, "whatif_faults");
        assert_eq!(f.csv, render_faults(&parallel).csv);
        assert!(f.csv.contains("none"));
        assert!(f.csv.contains("strag_f0_8"));
        assert!(f.ascii.contains("worst case"));
    }

    #[test]
    fn serving_replay_parallel_matches_serial_and_renders() {
        let serial = serving_report(1);
        let parallel = serving_report(4);
        assert_eq!(serial, parallel);
        let f = render_serving(&serial);
        assert_eq!(f.id, "whatif_serving");
        assert_eq!(f.csv, render_serving(&parallel).csv);
        for g in GovernorKind::ALL {
            assert!(f.csv.contains(g.name()), "{g} missing from CSV");
        }
    }
}
