//! CPU utilization analysis — the paper's Section V-E, Eqs. (4)–(5).
//!
//! C_active = number of logical cores with non-zero utilization;
//! C_min    = Σ util_i / 100, the theoretical lower bound on active cores;
//! plus the logical→physical (SMT) mapping statistics behind Insight 7.

use crate::trace::event::CpuTrace;
use crate::util::stats;
use std::collections::BTreeSet;

/// Per-window core statistics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoreWindow {
    pub t: f64,
    /// Eq. (4).
    pub active: u32,
    /// Eq. (5).
    pub min_cores: f64,
    /// Physical cores with ≥2 active logical siblings this window.
    pub smt_pairs: u32,
}

/// Full CPU-utilization analysis of one training run.
#[derive(Debug, Clone)]
pub struct CpuUtilAnalysis {
    pub windows: Vec<CoreWindow>,
    pub logical_cores: u32,
    pub physical_cores: u32,
    /// Physical cores that were ever active over the whole run.
    pub ever_active_physical: u32,
}

impl CpuUtilAnalysis {
    pub fn analyze(trace: &CpuTrace) -> Self {
        let physical = trace.logical_cores / trace.smt.max(1);
        let mut windows = Vec::with_capacity(trace.samples.len());
        let mut ever: BTreeSet<u32> = BTreeSet::new();
        for s in &trace.samples {
            let mut active = 0u32;
            let mut min_cores = 0.0;
            let mut phys_seen: BTreeSet<u32> = BTreeSet::new();
            let mut smt_pairs = 0u32;
            for &(core, util) in &s.core_util {
                if util > 0.0 {
                    active += 1;
                    min_cores += util / 100.0;
                    let p = trace.physical_of(core);
                    ever.insert(p);
                    if !phys_seen.insert(p) {
                        smt_pairs += 1;
                    }
                }
            }
            windows.push(CoreWindow {
                t: s.t,
                active,
                min_cores,
                smt_pairs,
            });
        }
        Self {
            windows,
            logical_cores: trace.logical_cores,
            physical_cores: physical,
            ever_active_physical: ever.len() as u32,
        }
    }

    pub fn median_active(&self) -> f64 {
        stats::median(&self.windows.iter().map(|w| w.active as f64).collect::<Vec<_>>())
    }

    pub fn median_min_cores(&self) -> f64 {
        stats::median(&self.windows.iter().map(|w| w.min_cores).collect::<Vec<_>>())
    }

    /// Fraction of physical cores ever active (the paper reports 12.5%).
    pub fn physical_footprint(&self) -> f64 {
        self.ever_active_physical as f64 / self.physical_cores.max(1) as f64
    }

    /// Fraction of windows in which any SMT sibling pair was co-scheduled.
    pub fn smt_cosched_rate(&self) -> f64 {
        if self.windows.is_empty() {
            return 0.0;
        }
        self.windows.iter().filter(|w| w.smt_pairs > 0).count() as f64
            / self.windows.len() as f64
    }

    /// Heatmap matrix for Fig. 13's bottom row: rows = physical cores that
    /// were ever active, columns = windows, value = number of active
    /// logical cores mapped there (0, 1 or 2).
    pub fn physical_heatmap(&self, trace: &CpuTrace) -> (Vec<u32>, Vec<Vec<f64>>) {
        let mut rows: Vec<u32> = Vec::new();
        let mut seen = BTreeSet::new();
        for s in &trace.samples {
            for &(core, util) in &s.core_util {
                if util > 0.0 && seen.insert(trace.physical_of(core)) {
                    rows.push(trace.physical_of(core));
                }
            }
        }
        rows.sort_unstable();
        let idx_of = |p: u32| rows.binary_search(&p).ok();
        let mut m = vec![vec![0.0; trace.samples.len()]; rows.len()];
        for (wi, s) in trace.samples.iter().enumerate() {
            for &(core, util) in &s.core_util {
                if util > 0.0 {
                    if let Some(ri) = idx_of(trace.physical_of(core)) {
                        m[ri][wi] += 1.0;
                    }
                }
            }
        }
        (rows, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::CpuSample;

    fn trace_with(samples: Vec<Vec<(u32, f64)>>) -> CpuTrace {
        CpuTrace {
            logical_cores: 384,
            smt: 2,
            samples: samples
                .into_iter()
                .enumerate()
                .map(|(i, core_util)| CpuSample {
                    t: i as f64 * 1e6,
                    core_util,
                })
                .collect(),
        }
    }

    #[test]
    fn eq4_eq5_basic() {
        let t = trace_with(vec![vec![(0, 100.0), (1, 50.0), (2, 0.0)]]);
        let a = CpuUtilAnalysis::analyze(&t);
        assert_eq!(a.windows[0].active, 2); // util > 0 only
        assert!((a.windows[0].min_cores - 1.5).abs() < 1e-12);
    }

    #[test]
    fn smt_pair_detection() {
        // Logical 5 and 197 map to physical 5 (384/2 = 192 offset).
        let t = trace_with(vec![vec![(5, 80.0), (197, 20.0)]]);
        let a = CpuUtilAnalysis::analyze(&t);
        assert_eq!(a.windows[0].smt_pairs, 1);
        assert_eq!(a.ever_active_physical, 1);
    }

    #[test]
    fn footprint_counts_distinct_physical() {
        let t = trace_with(vec![
            vec![(0, 50.0), (1, 50.0)],
            vec![(192, 50.0), (2, 50.0)], // 192 is sibling of 0
        ]);
        let a = CpuUtilAnalysis::analyze(&t);
        assert_eq!(a.ever_active_physical, 3); // phys 0, 1, 2
        assert!((a.physical_footprint() - 3.0 / 192.0).abs() < 1e-12);
    }

    #[test]
    fn medians_over_windows() {
        let t = trace_with(vec![
            vec![(0, 100.0)],
            vec![(0, 100.0), (1, 100.0)],
            vec![(0, 100.0), (1, 100.0), (2, 100.0)],
        ]);
        let a = CpuUtilAnalysis::analyze(&t);
        assert_eq!(a.median_active(), 2.0);
        assert_eq!(a.median_min_cores(), 2.0);
    }

    #[test]
    fn heatmap_shape_matches_rows_and_windows() {
        let t = trace_with(vec![
            vec![(0, 50.0), (5, 50.0)],
            vec![(0, 50.0), (197, 50.0)],
        ]);
        let a = CpuUtilAnalysis::analyze(&t);
        let (rows, m) = a.physical_heatmap(&t);
        assert_eq!(rows, vec![0, 5]);
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].len(), 2);
        // physical 5 active in window 0 (logical 5) and window 1 (197).
        assert_eq!(m[1][0], 1.0);
        assert_eq!(m[1][1], 1.0);
    }

    #[test]
    fn paper_scale_model_matches_insight7() {
        // End-to-end with the host model: active cores well above the
        // lower bound, tiny physical footprint.
        use crate::chopper::fixtures;
        use crate::config::FsdpVersion;
        let cap = fixtures::runtime(2, 1, 1, 0, FsdpVersion::V2);
        let a = CpuUtilAnalysis::analyze(&cap.cpu);
        assert!(a.median_active() >= 20.0 && a.median_active() <= 30.0);
        assert!(a.median_min_cores() >= 7.0 && a.median_min_cores() <= 12.0);
        assert!(a.median_active() > a.median_min_cores() * 2.0);
        assert!(a.physical_footprint() < 0.25);
        assert!(a.smt_cosched_rate() < 0.2);
    }
}
