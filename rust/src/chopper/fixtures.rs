//! Shared, cached unit-test fixtures for the analysis modules.
//!
//! Before this module every `chopper::*` test file carried its own
//! copy-pasted `RuntimeProfiler::new(node.clone()).capture(..)` /
//! `HardwareProfiler::new(node).capture(..)` preamble, so the same
//! workload was re-simulated once per test. Fixtures are keyed by their
//! full configuration and leaked (`Box::leak`) into `'static`, so each
//! distinct configuration is simulated **once per test binary** and every
//! index/aligned view can borrow it for as long as the test runs.

use crate::config::{FsdpVersion, ModelConfig, NodeSpec, WorkloadConfig};
use crate::counters::{Counter, CounterTrace};
use crate::trace::collect::{HardwareProfiler, RuntimeCapture, RuntimeProfiler};
use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

type Key = (u64, u64, u32, u32, FsdpVersion);

fn workload(key: Key) -> (ModelConfig, WorkloadConfig) {
    let (layers, batch, iters, warmup, fsdp) = key;
    let mut cfg = ModelConfig::llama3_8b();
    cfg.layers = layers;
    let mut wl = WorkloadConfig::new(batch, 4096, fsdp);
    wl.iterations = iters;
    wl.warmup = warmup;
    (cfg, wl)
}

/// Runtime-profiled capture (trace + power + CPU telemetry) at s=4096.
pub fn runtime(
    layers: u64,
    batch: u64,
    iters: u32,
    warmup: u32,
    fsdp: FsdpVersion,
) -> &'static RuntimeCapture {
    static CACHE: OnceLock<Mutex<HashMap<Key, &'static RuntimeCapture>>> =
        OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let key = (layers, batch, iters, warmup, fsdp);
    if let Some(cap) = cache.lock().unwrap().get(&key) {
        return cap;
    }
    // Simulate with the lock released so tests needing *different*
    // configurations stay parallel; a racing duplicate build of the same
    // key just loses the insert (one leaked extra, correctness unharmed).
    let (cfg, wl) = workload(key);
    let cap: &'static RuntimeCapture = Box::leak(Box::new(
        RuntimeProfiler::new(NodeSpec::mi300x_node()).capture(&cfg, &wl),
    ));
    *cache.lock().unwrap().entry(key).or_insert(cap)
}

/// Hardware-counter trace (all counters) for the same workload grid.
pub fn counters(
    layers: u64,
    batch: u64,
    iters: u32,
    warmup: u32,
    fsdp: FsdpVersion,
) -> &'static CounterTrace {
    static CACHE: OnceLock<Mutex<HashMap<Key, &'static CounterTrace>>> =
        OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let key = (layers, batch, iters, warmup, fsdp);
    if let Some(c) = cache.lock().unwrap().get(&key) {
        return c;
    }
    let (cfg, wl) = workload(key);
    let c: &'static CounterTrace = Box::leak(Box::new(
        HardwareProfiler::new(NodeSpec::mi300x_node())
            .capture(&cfg, &wl, &Counter::ALL),
    ));
    *cache.lock().unwrap().entry(key).or_insert(c)
}
