//! Figure generators: one function per table/figure of the paper's
//! evaluation (Section V). Each returns a [`Figure`] holding an ASCII
//! rendering (for the CLI), a CSV of the underlying rows (for regression
//! diffing in benches), and optionally an SVG.
//!
//! Generators are pure functions of profiled runs, so the benches, the CLI
//! and the tests all drive the same code; `run_sweep` produces the paper's
//! b×s × {v1,v2} input set at any scale. Every generator consumes the
//! shared per-run [`TraceIndex`] (wrapped in [`IndexedRun`]) — the trace
//! is scanned once per run, not once per figure — and [`render_all`] fans
//! the independent generators out over the campaign runner with
//! deterministic ordered collection, so a scenario's figures render in
//! parallel yet byte-identically to a serial pass.

use crate::chopper::aggregate::{op_duration_samples, phase_kind_duration_samples};
use crate::chopper::align::AlignedTrace;
use crate::chopper::breakdown::all_breakdowns;
use crate::chopper::cpuutil::CpuUtilAnalysis;
use crate::chopper::index::TraceIndex;
use crate::chopper::launch::{op_launch_overheads, phase_kind_launch_samples};
use crate::chopper::overlap::{per_gpu_overlap_cdf, summarize_op_overlap};
use crate::chopper::throughput::throughput;
use crate::config::{FsdpVersion, ModelConfig, NodeSpec, WorkloadConfig};
use crate::model::ops::{OpKind, OpRef, OpType, Phase};
use crate::sim::ProfiledRun;
use crate::trace::event::Stream;
use crate::util::intern::{intern, Sym};
use crate::util::{ascii, fmt, stats};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Label of a flat rank for figure rows: "GPU3" on a single node, node-
/// grouped "N0G3" on a multi-node trace (single-node output stays
/// byte-identical to the pre-topology figures).
pub fn gpu_label(meta: &crate::trace::event::TraceMeta, gpu: u32) -> String {
    if meta.multi_node() {
        format!("N{}G{}", meta.node_of(gpu), meta.local_of(gpu))
    } else {
        format!("GPU{gpu}")
    }
}

/// One regenerated table/figure.
#[derive(Debug, Clone)]
pub struct Figure {
    /// "fig4", "table2", ...
    pub id: &'static str,
    pub title: String,
    pub ascii: String,
    /// The raw rows behind the plot.
    pub csv: String,
    pub svg: Option<String>,
}

impl Figure {
    /// Write ascii/csv/svg files into `dir` as `<id>.{txt,csv,svg}`.
    /// Each file lands atomically ([`crate::util::atomic_write`]): a
    /// figure regenerated over an existing one can never be observed
    /// half-written, even if the process dies mid-save.
    pub fn save(&self, dir: &std::path::Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let w = |name: String, bytes: &[u8]| {
            crate::util::atomic_write(&dir.join(name), bytes)
        };
        w(format!("{}.txt", self.id), self.ascii.as_bytes())?;
        w(format!("{}.csv", self.id), self.csv.as_bytes())?;
        if let Some(svg) = &self.svg {
            w(format!("{}.svg", self.id), svg.as_bytes())?;
        }
        Ok(())
    }
}

/// One profiled workload of a sweep.
#[derive(Debug)]
pub struct SweepRun {
    pub wl: WorkloadConfig,
    pub run: ProfiledRun,
}

impl SweepRun {
    pub fn label(&self) -> String {
        self.wl.label_with_fsdp()
    }
}

/// A sweep run plus its shared analysis index (counters joined), built
/// once and consumed by every figure generator.
#[derive(Debug)]
pub struct IndexedRun<'t> {
    pub sr: &'t SweepRun,
    pub aligned: AlignedTrace<'t>,
}

impl<'t> IndexedRun<'t> {
    pub fn new(sr: &'t SweepRun) -> Self {
        Self {
            sr,
            aligned: AlignedTrace::align(&sr.run.trace, &sr.run.counters),
        }
    }

    pub fn idx(&self) -> &TraceIndex<'t> {
        &self.aligned.index
    }

    pub fn wl(&self) -> &WorkloadConfig {
        &self.sr.wl
    }

    pub fn label(&self) -> String {
        self.sr.label()
    }
}

/// Index every run of a sweep, fanning the (independent) index builds out
/// over the campaign runner in deterministic order.
pub fn index_runs(runs: &[SweepRun]) -> Vec<IndexedRun<'_>> {
    index_runs_with(runs, crate::campaign::runner::default_jobs())
}

/// [`index_runs`] with an explicit worker count (`jobs <= 1` is fully
/// serial — the analysis A/B bench relies on it).
///
/// The fan-out runs over run *indices*: the result borrows from `runs`
/// itself (captured by the worker closure), not from the per-call `&I`
/// argument — which `run_ordered`'s higher-ranked `Fn` bound could not
/// express.
pub fn index_runs_with(runs: &[SweepRun], jobs: usize) -> Vec<IndexedRun<'_>> {
    let ids: Vec<usize> = (0..runs.len()).collect();
    crate::campaign::runner::run_ordered(&ids, jobs, |_, &i| {
        IndexedRun::new(&runs[i])
    })
}

/// Profile the paper's configuration sweep (b1s4, b2s4, b4s4, b1s8, b2s8)
/// for the given FSDP versions. `iterations`/`warmup` let tests/benches
/// trade fidelity for speed (the paper uses 20/10).
///
/// Workloads fan out over the campaign runner (one worker per hardware
/// thread); each simulation is independently seeded, so the results are
/// identical to the old serial loop, in the same order.
pub fn run_sweep(
    node: &NodeSpec,
    cfg: &ModelConfig,
    versions: &[FsdpVersion],
    iterations: u32,
    warmup: u32,
) -> Vec<SweepRun> {
    run_sweep_topo(
        &crate::config::Topology::single(node.clone()),
        cfg,
        versions,
        iterations,
        warmup,
    )
}

/// [`run_sweep`] over a full cluster [`Topology`](crate::config::Topology)
/// — the same workload set FSDP/HSDP-sharded across the cluster
/// (`wl.sharding` defaults to FSDP; `Topology::single` is the
/// byte-identical single-node case).
pub fn run_sweep_topo(
    topo: &crate::config::Topology,
    cfg: &ModelConfig,
    versions: &[FsdpVersion],
    iterations: u32,
    warmup: u32,
) -> Vec<SweepRun> {
    run_sweep_topo_params(
        topo,
        cfg,
        versions,
        iterations,
        warmup,
        &crate::sim::EngineParams::default(),
    )
}

/// [`run_sweep_topo`] with explicit engine parameters — how `sweep
/// --thermal` profiles the paper workloads under the RC thermal model
/// (DESIGN.md §14). Default parameters are byte-identical to
/// [`run_sweep_topo`].
pub fn run_sweep_topo_params(
    topo: &crate::config::Topology,
    cfg: &ModelConfig,
    versions: &[FsdpVersion],
    iterations: u32,
    warmup: u32,
    params: &crate::sim::EngineParams,
) -> Vec<SweepRun> {
    let mut wls = Vec::new();
    for &v in versions {
        for mut wl in WorkloadConfig::paper_sweep(v) {
            wl.iterations = iterations;
            wl.warmup = warmup;
            wls.push(wl);
        }
    }
    let jobs = crate::campaign::runner::default_jobs();
    let runs =
        crate::campaign::runner::run_ordered(&wls, jobs, |_, wl| {
            crate::sim::run_workload_topo_with(topo, cfg, wl, params.clone())
        });
    wls.into_iter()
        .zip(runs)
        .map(|(wl, run)| SweepRun { wl, run })
        .collect()
}

// ---------------------------------------------------------------------------
// Table II — model configuration
// ---------------------------------------------------------------------------

pub fn table2(cfg: &ModelConfig) -> Figure {
    let rows = vec![vec![
        cfg.layers.to_string(),
        "4,096".to_string(),
        cfg.ffn.to_string(),
        format!("{}/{}", cfg.q_heads, cfg.kv_heads),
    ]];
    let ascii = ascii::table(
        &["Layer count", "Token size", "Hidden dim", "Attn/KV heads"],
        &rows,
    );
    let csv = format!(
        "layers,token_size,hidden,ffn,q_heads,kv_heads,params\n{},{},{},{},{},{},{}\n",
        cfg.layers, 4096, cfg.hidden, cfg.ffn, cfg.q_heads, cfg.kv_heads,
        cfg.param_count()
    );
    Figure {
        id: "table2",
        title: format!("Table II: {} model configuration", cfg.name),
        ascii,
        csv,
        svg: None,
    }
}

// ---------------------------------------------------------------------------
// Fig. 4 — end-to-end breakdown
// ---------------------------------------------------------------------------

pub fn fig4(runs: &[IndexedRun]) -> Figure {
    let mut csv = String::from(
        "config,fsdp,throughput_tok_s,rel_throughput,phase,kind,median_duration_ms,median_launch_ms\n",
    );
    let mut ascii = String::from(
        "Fig. 4 — end-to-end: throughput, duration by phase x op-type, launch overhead\n\n",
    );
    // Baseline for the normalized row: b1s4 with FSDPv1 if present.
    let base_tp = runs
        .iter()
        .find(|r| r.wl().label() == "b1s4" && r.wl().fsdp == FsdpVersion::V1)
        .map(|r| {
            throughput(
                r.idx(),
                r.wl().tokens_per_iteration(
                    r.sr.run.trace.meta.num_gpus as u64,
                ) as f64,
            )
            .tokens_per_sec
        });

    for sr in runs {
        let tokens =
            sr.wl().tokens_per_iteration(sr.sr.run.trace.meta.num_gpus as u64)
                as f64;
        let tp = throughput(sr.idx(), tokens);
        let rel = base_tp.map(|b| tp.tokens_per_sec / b).unwrap_or(1.0);
        let _ = writeln!(
            ascii,
            "{:>14}: {:>9.0} tok/s ({}x b1s4-v1)   iter {} (launch {})",
            sr.label(),
            tp.tokens_per_sec,
            format_args!("{rel:.2}"),
            fmt::dur_ns(tp.iter_ns),
            fmt::dur_ns(tp.launch_ns),
        );
        let durs = phase_kind_duration_samples(sr.idx());
        let launches = phase_kind_launch_samples(sr.idx());
        let max_total: f64 = Phase::ALL
            .iter()
            .map(|ph| {
                durs.iter()
                    .filter(|((p, _), _)| p == ph)
                    .map(|(_, v)| stats::median(v))
                    .sum::<f64>()
            })
            .fold(0.0, f64::max);
        for phase in Phase::ALL {
            let mut segs: Vec<(String, f64)> = Vec::new();
            for kind in [OpKind::FlashAttn, OpKind::Vector, OpKind::Gemm, OpKind::Copy]
            {
                let d = durs.get(&(phase, kind)).map(|v| stats::median(v));
                let l = launches.get(&(phase, kind)).map(|v| stats::median(v));
                if d.is_none() && l.is_none() {
                    continue;
                }
                let dm = d.unwrap_or(0.0);
                let lm = l.unwrap_or(0.0);
                let _ = writeln!(
                    csv,
                    "{},{},{:.0},{:.3},{},{},{:.3},{:.3}",
                    sr.wl().label(),
                    sr.wl().fsdp,
                    tp.tokens_per_sec,
                    rel,
                    phase,
                    kind,
                    dm / 1e6,
                    lm / 1e6
                );
                segs.push((kind.to_string(), dm));
            }
            ascii.push_str(&ascii::stacked_bar(
                &format!("  {phase:>4}"),
                &segs,
                48,
                max_total,
            ));
        }
        // Node-grouped rollup rows (multi-node traces only, so the
        // single-node figure stays byte-identical).
        if sr.sr.run.trace.meta.multi_node() {
            for (n, med) in sr.idx().node_iter_medians().iter().enumerate() {
                let _ = writeln!(
                    ascii,
                    "  node{n}: iter median {}",
                    fmt::dur_ns(*med)
                );
                let _ = writeln!(
                    csv,
                    "{},{},{:.0},{:.3},node{n},rollup,{:.3},0.000",
                    sr.wl().label(),
                    sr.wl().fsdp,
                    tp.tokens_per_sec,
                    rel,
                    med / 1e6
                );
            }
        }
        ascii.push('\n');
    }
    Figure {
        id: "fig4",
        title: "Fig. 4 — end-to-end performance breakdown".into(),
        ascii,
        csv,
        svg: None,
    }
}

// ---------------------------------------------------------------------------
// Fig. 5 — operation durations (a: GEMM+FA, b: vector)
// ---------------------------------------------------------------------------

const FIG5A_OPS: [(&str, Phase, OpType); 10] = [
    ("f_qkv_ip", Phase::Forward, OpType::QkvIp),
    ("f_attn_fa", Phase::Forward, OpType::AttnFa),
    ("f_attn_op", Phase::Forward, OpType::AttnOp),
    ("f_mlp_gp", Phase::Forward, OpType::MlpGp),
    ("f_mlp_up", Phase::Forward, OpType::MlpUp),
    ("f_mlp_dp", Phase::Forward, OpType::MlpDp),
    ("b_attn_fa", Phase::Backward, OpType::AttnFa),
    ("b_mlp_gp", Phase::Backward, OpType::MlpGp),
    ("b_mlp_up", Phase::Backward, OpType::MlpUp),
    ("b_mlp_dp", Phase::Backward, OpType::MlpDp),
];

const FIG5B_OPS: [(&str, Phase, OpType); 8] = [
    ("f_attn_n", Phase::Forward, OpType::AttnN),
    ("f_mlp_n", Phase::Forward, OpType::MlpN),
    ("f_qkv_re", Phase::Forward, OpType::QkvRe),
    ("b_attn_n", Phase::Backward, OpType::AttnN),
    ("b_mlp_n", Phase::Backward, OpType::MlpN),
    ("b_mlp_gu", Phase::Backward, OpType::MlpGu),
    ("b_ga", Phase::Optimizer, OpType::GradAccum),
    ("opt_step", Phase::Optimizer, OpType::OptStep),
];

pub fn fig5(runs: &[IndexedRun]) -> Figure {
    let mut csv =
        String::from("panel,op,config,fsdp,min,q25,median,q75,max\n");
    let mut ascii = String::from(
        "Fig. 5 — operation duration distributions (normalized to global max)\n",
    );
    for (panel, ops) in [
        ("a", &FIG5A_OPS[..]),
        ("b", &FIG5B_OPS[..]),
    ] {
        // Collect everything first to find the normalization max. Row
        // labels are interned handles: the render loop below compares
        // 4-byte ids instead of cloning a String per row.
        let mut rows: Vec<(Sym, String, [f64; 5])> = Vec::new();
        for (name, phase, op) in ops {
            let opref = OpRef::new(*op, *phase);
            for sr in runs {
                let samples = op_duration_samples(sr.idx(), opref);
                if samples.is_empty() {
                    continue;
                }
                let q = [
                    stats::min(&samples),
                    stats::quantile(&samples, 0.25),
                    stats::median(&samples),
                    stats::quantile(&samples, 0.75),
                    stats::max(&samples),
                ];
                rows.push((intern(name), sr.label(), q));
            }
        }
        let global_max = rows
            .iter()
            .map(|r| r.2[4])
            .fold(0.0_f64, f64::max)
            .max(1e-9);
        let _ = writeln!(ascii, "\n(5{panel})");
        let mut last_op: Option<Sym> = None;
        for (name, cfg_label, q) in &rows {
            if last_op != Some(*name) {
                let _ = writeln!(ascii, " {name}");
                last_op = Some(*name);
            }
            ascii.push_str(&ascii::quantile_row(
                &format!("   {cfg_label:>12}"),
                q[0],
                q[1],
                q[2],
                q[3],
                q[4],
                0.0,
                global_max,
                44,
            ));
            let (cfg_part, fsdp_part) =
                cfg_label.split_once('-').unwrap_or((cfg_label.as_str(), ""));
            let _ = writeln!(
                csv,
                "{panel},{name},{cfg_part},{fsdp_part},{:.6},{:.6},{:.6},{:.6},{:.6}",
                q[0] / global_max,
                q[1] / global_max,
                q[2] / global_max,
                q[3] / global_max,
                q[4] / global_max
            );
        }
    }
    Figure {
        id: "fig5",
        title: "Fig. 5 — operation durations by type and configuration".into(),
        ascii,
        csv,
        svg: None,
    }
}

// ---------------------------------------------------------------------------
// Fig. 6 — communication kernel durations per iteration
// ---------------------------------------------------------------------------

pub fn fig6(runs: &[IndexedRun]) -> Figure {
    let mut csv = String::from(
        "config,fsdp,op,median_ms,q25_ms,q75_ms,max_ms,iter_median_ms\n",
    );
    let mut ascii =
        String::from("Fig. 6 — per-iteration communication kernel duration\n\n");
    for sr in runs {
        let warmup = sr.sr.run.trace.meta.warmup;
        // Iteration duration (for the compute-scaling comparison).
        let spans = crate::chopper::aggregate::iteration_spans(sr.idx());
        let iter_durs: Vec<f64> = spans
            .iter()
            .filter(|((_, it), _)| *it >= warmup)
            .map(|(_, (s, e))| e - s)
            .collect();
        let iter_med = stats::median(&iter_durs);
        // AllReduce only appears in HSDP traces; its empty column is
        // skipped everywhere else, keeping single-node output identical.
        for op in [OpType::AllGather, OpType::ReduceScatter, OpType::AllReduce] {
            let durs = sr.idx().comm_durations(op);
            if durs.is_empty() {
                continue;
            }
            let med = stats::median(durs);
            let _ = writeln!(
                ascii,
                "{:>14} {:>3}: median {:>9} q75 {:>9} max {:>9}   (iter {:>9})",
                sr.label(),
                op.short(),
                fmt::dur_ns(med),
                fmt::dur_ns(stats::quantile(durs, 0.75)),
                fmt::dur_ns(stats::max(durs)),
                fmt::dur_ns(iter_med),
            );
            let _ = writeln!(
                csv,
                "{},{},{},{:.4},{:.4},{:.4},{:.4},{:.4}",
                sr.wl().label(),
                sr.wl().fsdp,
                op.short(),
                med / 1e6,
                stats::quantile(durs, 0.25) / 1e6,
                stats::quantile(durs, 0.75) / 1e6,
                stats::max(durs) / 1e6,
                iter_med / 1e6
            );
        }
    }
    Figure {
        id: "fig6",
        title: "Fig. 6 — communication kernel durations".into(),
        ascii,
        csv,
        svg: None,
    }
}

// ---------------------------------------------------------------------------
// Fig. 7 — overlap ratio vs duration for dominant ops
// ---------------------------------------------------------------------------

const FIG7_OPS: [(&str, Phase, OpType); 6] = [
    ("b_attn_n", Phase::Backward, OpType::AttnN),
    ("b_mlp_n", Phase::Backward, OpType::MlpN),
    ("b_mlp_gp", Phase::Backward, OpType::MlpGp),
    ("b_mlp_up", Phase::Backward, OpType::MlpUp),
    ("b_mlp_dp", Phase::Backward, OpType::MlpDp),
    ("f_attn_fa", Phase::Forward, OpType::AttnFa),
];

pub fn fig7(v1: &IndexedRun, v2: &IndexedRun) -> Figure {
    let mut csv = String::from(
        "op,fsdp,n,ratio_min,ratio_q25,ratio_med,ratio_q75,ratio_max,dur_med_ms,correlation\n",
    );
    let mut ascii = String::from(
        "Fig. 7 — overlap ratio vs duration, dominant ops (b2s4)\n\n",
    );
    for (name, phase, op) in FIG7_OPS {
        let opref = OpRef::new(op, phase);
        for sr in [v1, v2] {
            let s = summarize_op_overlap(sr.idx(), opref);
            let corr = s
                .correlation
                .map(|c| format!("{c:+.2}"))
                .unwrap_or_else(|| "nan".into());
            let _ = writeln!(
                ascii,
                "{:>9} {:>6}: overlap [{:.2} {:.2} {:.2} {:.2} {:.2}]  dur med {:>9}  corr {}",
                name,
                sr.wl().fsdp.to_string(),
                s.ratio_q[0],
                s.ratio_q[1],
                s.ratio_q[2],
                s.ratio_q[3],
                s.ratio_q[4],
                fmt::dur_ns(s.duration_q[2]),
                corr
            );
            let _ = writeln!(
                csv,
                "{},{},{},{:.3},{:.3},{:.3},{:.3},{:.3},{:.4},{}",
                name,
                sr.wl().fsdp,
                s.n,
                s.ratio_q[0],
                s.ratio_q[1],
                s.ratio_q[2],
                s.ratio_q[3],
                s.ratio_q[4],
                s.duration_q[2] / 1e6,
                corr
            );
        }
    }
    Figure {
        id: "fig7",
        title: "Fig. 7 — overlap vs duration correlations".into(),
        ascii,
        csv,
        svg: None,
    }
}

// ---------------------------------------------------------------------------
// Fig. 8 — CDF of overlap vs duration per GPU (f_attn_op, b2s4)
// ---------------------------------------------------------------------------

pub fn fig8(run: &IndexedRun) -> Figure {
    let per = per_gpu_overlap_cdf(run.idx(), OpRef::fwd(OpType::AttnOp));
    let meta = &run.sr.run.trace.meta;
    let mut csv = String::from("gpu,overlap_ratio,duration_norm\n");
    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    for (gpu, pts) in &per {
        for (r, d) in pts {
            let _ = writeln!(csv, "{gpu},{r:.4},{d:.5}");
        }
        series.push((
            gpu_label(meta, *gpu),
            pts.iter().map(|(_, d)| *d).collect(),
        ));
    }
    let mut ascii = String::from(
        "Fig. 8 — f_attn_op across GPUs (b2s4): duration CDF (normalized to per-GPU min)\n",
    );
    ascii.push_str(&ascii::cdf_plot("", &series, 56, 12));
    // Per-GPU medians table.
    let mut rows = Vec::new();
    for (gpu, pts) in &per {
        let ratios: Vec<f64> = pts.iter().map(|(r, _)| *r).collect();
        let durs: Vec<f64> = pts.iter().map(|(_, d)| *d).collect();
        rows.push(vec![
            gpu_label(meta, *gpu),
            format!("{:.2}", stats::median(&ratios)),
            format!("{:.3}", stats::median(&durs)),
        ]);
    }
    ascii.push_str(&ascii::table(
        &["gpu", "median overlap", "median dur (norm)"],
        &rows,
    ));
    Figure {
        id: "fig8",
        title: "Fig. 8 — per-GPU overlap/duration CDF of f_attn_op".into(),
        ascii,
        csv,
        svg: Some(crate::util::svg::cdf_lines(
            "f_attn_op duration CDF per GPU (b2s4)",
            "duration (normalized to per-GPU min)",
            &series,
        )),
    }
}

// ---------------------------------------------------------------------------
// Fig. 9 — f_attn_fa overlap across configurations
// ---------------------------------------------------------------------------

pub fn fig9(runs: &[IndexedRun]) -> Figure {
    let mut csv =
        String::from("config,fsdp,ratio_min,q25,median,q75,max,dur_med_ms\n");
    let mut ascii =
        String::from("Fig. 9 — f_attn_fa overlap ratio vs configuration\n\n");
    for sr in runs {
        let s = summarize_op_overlap(sr.idx(), OpRef::fwd(OpType::AttnFa));
        ascii.push_str(&ascii::quantile_row(
            &format!("{:>14}", sr.label()),
            s.ratio_q[0],
            s.ratio_q[1],
            s.ratio_q[2],
            s.ratio_q[3],
            s.ratio_q[4],
            0.0,
            1.0,
            44,
        ));
        let _ = writeln!(
            csv,
            "{},{},{:.3},{:.3},{:.3},{:.3},{:.3},{:.4}",
            sr.wl().label(),
            sr.wl().fsdp,
            s.ratio_q[0],
            s.ratio_q[1],
            s.ratio_q[2],
            s.ratio_q[3],
            s.ratio_q[4],
            s.duration_q[2] / 1e6
        );
    }
    Figure {
        id: "fig9",
        title: "Fig. 9 — f_attn_fa overlap across configurations".into(),
        ascii,
        csv,
        svg: None,
    }
}

// ---------------------------------------------------------------------------
// Fig. 10 — launch-overhead definition (static diagram + doc-tested eqs)
// ---------------------------------------------------------------------------

pub fn fig10() -> Figure {
    let ascii = r#"Fig. 10 — launch overhead definition (Eqs. 1-3)

   CPU   ──────┤dispatch(i)├─────────────────────────────
                  t_l(i)
   GPU   ──┤kernel i-1├ ░░░░░░░ ▒▒▒▒▒▒▒ ┤kernel i├──────
              t_ke(i-1)  O_prep  O_call   t_ks(i)

   O_prep  = max(t_l(i) - t_ke(i-1), 0)      "CPU launched too late"
   O_call  = min(t_ks(i) - t_l(i),
                 t_ks(i) - t_ke(i-1))        dispatch -> start latency
   O_launch = O_prep + O_call

   Bubbles spanned by serialized communication kernels count as launch
   overhead too (Section V-D1) — which is how FSDPv2's serialized copy
   kernels become visible.
"#;
    Figure {
        id: "fig10",
        title: "Fig. 10 — launch overhead definition".into(),
        ascii: ascii.to_string(),
        csv: "quantity,definition\nO_prep,max(t_l - t_ke_prev; 0)\nO_call,min(t_ks - t_l; t_ks - t_ke_prev)\nO_launch,O_prep + O_call\n".into(),
        svg: None,
    }
}

// ---------------------------------------------------------------------------
// Fig. 11 — mean prep/call overhead for top operations
// ---------------------------------------------------------------------------

pub fn fig11(v1: &IndexedRun, v2: &IndexedRun) -> Figure {
    let mut csv = String::from("op,fsdp,prep_us,call_us\n");
    let mut ascii =
        String::from("Fig. 11 — mean preparation / call overhead, top ops\n\n");
    let interesting = [
        OpRef::fwd(OpType::IE),
        OpRef::new(OpType::OptStep, Phase::Optimizer),
        OpRef::new(OpType::GradAccum, Phase::Optimizer),
        OpRef::fwd(OpType::AttnN),
        OpRef::bwd(OpType::MlpDp),
        OpRef::bwd(OpType::IE),
    ];
    for sr in [v1, v2] {
        let per_op = op_launch_overheads(sr.idx());
        let _ = writeln!(ascii, "{}", sr.wl().fsdp);
        let mut rows: Vec<(String, f64, f64)> = interesting
            .iter()
            .filter_map(|op| {
                per_op
                    .get(op)
                    .map(|o| (op.paper_name(), o.prep / 1e3, o.call / 1e3))
            })
            .collect();
        rows.sort_by(|a, b| (b.1 + b.2).total_cmp(&(a.1 + a.2)));
        let maxv = rows
            .iter()
            .map(|r| r.1 + r.2)
            .fold(0.0_f64, f64::max)
            .max(1e-9);
        for (name, prep, call) in &rows {
            ascii.push_str(&ascii::stacked_bar(
                &format!("  {name:>9}"),
                &[("prep".into(), *prep), ("call".into(), *call)],
                40,
                maxv,
            ));
            let _ = writeln!(csv, "{},{},{:.2},{:.2}", name, sr.wl().fsdp, prep, call);
        }
        ascii.push('\n');
    }
    Figure {
        id: "fig11",
        title: "Fig. 11 — launch overhead by operation".into(),
        ascii,
        csv,
        svg: None,
    }
}

// ---------------------------------------------------------------------------
// Fig. 12 — comm pipeline fill/empty (trace excerpt)
// ---------------------------------------------------------------------------

pub fn fig12(run: &IndexedRun) -> Figure {
    // Render gpu 0's first sampled iteration: comm vs compute lanes around
    // the iteration boundary. The index's per-(gpu, stream) lanes are
    // already t_start-sorted, so this is a filtered walk, not a scan+sort.
    let idx = run.idx();
    let trace = idx.trace;
    let warmup = trace.meta.warmup;
    let lane_entries = |stream: Stream| -> Vec<(f64, f64, String)> {
        idx.lane(0, stream)
            .iter()
            .map(|&i| &trace.events[i as usize])
            .filter(|e| e.iter == warmup)
            .map(|e| (e.t_start, e.t_end, e.op.paper_name()))
            .collect()
    };
    let comm = lane_entries(Stream::Comm);
    let compute = lane_entries(Stream::Compute);
    let mut csv = String::from("lane,op,t_start_ms,t_end_ms\n");
    for (s, e, n) in &comm {
        let _ = writeln!(csv, "comm,{n},{:.4},{:.4}", s / 1e6, e / 1e6);
    }
    for (s, e, n) in &compute {
        let _ = writeln!(csv, "compute,{n},{:.4},{:.4}", s / 1e6, e / 1e6);
    }
    let mut ascii = String::from(
        "Fig. 12 — filling/emptying the communication pipeline (gpu 0, first sampled iteration)\n\n  comm   : ",
    );
    for (_, _, n) in comm.iter().take(6) {
        let _ = write!(ascii, "[{n}] ");
    }
    ascii.push_str("...\n  compute: ");
    for (_, _, n) in compute.iter().take(4) {
        let _ = write!(ascii, "[{n}] ");
    }
    ascii.push_str("...\n\n");
    if let (Some(first_comm), Some(first_compute)) = (comm.first(), compute.first())
    {
        let _ = writeln!(
            ascii,
            "  first collective starts {} before the first compute kernel —\n  the pipeline-fill window that puts prep overhead on f_ie (Insight 5).",
            fmt::dur_ns(first_compute.0 - first_comm.0)
        );
    }
    Figure {
        id: "fig12",
        title: "Fig. 12 — comm pipeline fill/empty".into(),
        ascii,
        csv,
        svg: None,
    }
}

// ---------------------------------------------------------------------------
// Fig. 13 — CPU cores
// ---------------------------------------------------------------------------

pub fn fig13(run: &IndexedRun) -> Figure {
    let a = CpuUtilAnalysis::analyze(&run.sr.run.cpu);
    let mut csv = String::from("window_t_ms,active_cores,min_cores,smt_pairs\n");
    for w in &a.windows {
        let _ = writeln!(
            csv,
            "{:.2},{},{:.2},{}",
            w.t / 1e6,
            w.active,
            w.min_cores,
            w.smt_pairs
        );
    }
    let mut ascii = String::from("Fig. 13 — CPU logical/physical core usage\n\n");
    let _ = writeln!(
        ascii,
        "  median active cores : {:.0}   (of {} logical)",
        a.median_active(),
        a.logical_cores
    );
    let _ = writeln!(
        ascii,
        "  median minimum cores: {:.1}  (Eq. 5 lower bound)",
        a.median_min_cores()
    );
    let _ = writeln!(
        ascii,
        "  physical footprint  : {:.1}% of {} physical cores ever active",
        a.physical_footprint() * 100.0,
        a.physical_cores
    );
    let _ = writeln!(
        ascii,
        "  SMT sibling windows : {:.1}%",
        a.smt_cosched_rate() * 100.0
    );
    let (rows, m) = a.physical_heatmap(&run.sr.run.cpu);
    // Downsample columns for terminal width.
    let step = (m.first().map(|r| r.len()).unwrap_or(1) / 64).max(1);
    let small: Vec<Vec<f64>> = m
        .iter()
        .map(|r| {
            r.chunks(step)
                .map(|c| c.iter().sum::<f64>() / c.len() as f64 / 2.0)
                .collect()
        })
        .collect();
    ascii.push_str(&format!(
        "\n  logical→physical heatmap ({} active physical cores × time):\n",
        rows.len()
    ));
    ascii.push_str(&ascii::heatmap("", &small));
    Figure {
        id: "fig13",
        title: "Fig. 13 — CPU core utilization".into(),
        ascii,
        csv,
        svg: None,
    }
}

// ---------------------------------------------------------------------------
// Fig. 14 — frequency and power v1 vs v2
// ---------------------------------------------------------------------------

pub fn fig14(v1: &IndexedRun, v2: &IndexedRun) -> Figure {
    let mut csv = String::from(
        "fsdp,gpu_freq_mhz,mem_freq_mhz,power_w,freq_sigma,power_sigma\n",
    );
    let mut ascii =
        String::from("Fig. 14 — average frequency and power, FSDPv1 vs FSDPv2 (active windows)\n\n");
    for sr in [v1, v2] {
        // Active windows only (compute in flight), like the paper's
        // during-training averages.
        let samples: Vec<_> = sr
            .sr
            .run
            .power
            .samples
            .iter()
            .filter(|s| s.power_w > 400.0)
            .collect();
        let f: Vec<f64> = samples.iter().map(|s| s.freq_mhz).collect();
        let m: Vec<f64> = samples.iter().map(|s| s.mem_freq_mhz).collect();
        let p: Vec<f64> = samples.iter().map(|s| s.power_w).collect();
        let _ = writeln!(
            ascii,
            "  {:>6}: GPU {:.0}±{:.0} MHz   MEM {:.0} MHz   power {:.0}±{:.0} W",
            sr.wl().fsdp.to_string(),
            stats::mean(&f),
            stats::std(&f),
            stats::mean(&m),
            stats::mean(&p),
            stats::std(&p),
        );
        let _ = writeln!(
            csv,
            "{},{:.1},{:.1},{:.1},{:.2},{:.2}",
            sr.wl().fsdp,
            stats::mean(&f),
            stats::mean(&m),
            stats::mean(&p),
            stats::std(&f),
            stats::std(&p)
        );
    }
    let f1: Vec<f64> = v1
        .sr
        .run
        .power
        .samples
        .iter()
        .filter(|s| s.power_w > 400.0)
        .map(|s| s.freq_mhz)
        .collect();
    let f2: Vec<f64> = v2
        .sr
        .run
        .power
        .samples
        .iter()
        .filter(|s| s.power_w > 400.0)
        .map(|s| s.freq_mhz)
        .collect();
    let _ = writeln!(
        ascii,
        "\n  v2/v1 frequency ratio: {:.2}x at matched power (Observation 6)",
        stats::mean(&f2) / stats::mean(&f1).max(1.0)
    );
    Figure {
        id: "fig14",
        title: "Fig. 14 — frequency & power by FSDP version".into(),
        ascii,
        csv,
        svg: None,
    }
}

// ---------------------------------------------------------------------------
// Fig. 15 — overhead breakdown
// ---------------------------------------------------------------------------

pub fn fig15(runs: &[IndexedRun], node: &NodeSpec) -> Figure {
    let mut csv = String::from(
        "config,fsdp,op,d_act_ms,d_thr_ms,inst,util,overlap,freq,total\n",
    );
    let mut ascii = String::from(
        "Fig. 15 — overhead breakdown for GEMMs and FlashAttention\n  (multiplicative: D_act ≈ D_thr × inst × util × overlap × freq)\n\n",
    );
    for sr in runs {
        // The counter metrics are already joined onto the shared index —
        // no per-figure alignment pass, no trace clone.
        let breakdowns = all_breakdowns(&sr.aligned, &node.gpu);
        let _ = writeln!(ascii, "{}", sr.label());
        for (op, b) in &breakdowns {
            let _ = writeln!(
                ascii,
                "  {:>10}: act {:>9}  thr {:>9}  inst {:>5.2} util {:>5.2} overlap {:>5.2} freq {:>5.2}",
                op.paper_name(),
                fmt::dur_ns(b.d_act),
                fmt::dur_ns(b.d_thr),
                b.inst,
                b.util,
                b.overlap,
                b.freq
            );
            let _ = writeln!(
                csv,
                "{},{},{},{:.4},{:.4},{:.3},{:.3},{:.3},{:.3},{:.3}",
                sr.wl().label(),
                sr.wl().fsdp,
                op.paper_name(),
                b.d_act / 1e6,
                b.d_thr / 1e6,
                b.inst,
                b.util,
                b.overlap,
                b.freq,
                b.total_overhead()
            );
        }
        ascii.push('\n');
    }
    Figure {
        id: "fig15",
        title: "Fig. 15 — theoretical-vs-actual duration breakdown".into(),
        ascii,
        csv,
        svg: None,
    }
}

// ---------------------------------------------------------------------------
// Node rollup — per-node iteration/phase medians (multi-node topologies)
// ---------------------------------------------------------------------------

/// Per-node rollup figure: median iteration span and phase durations of
/// every node of every run, node-grouped. The multi-node counterpart of
/// Fig. 4's per-workload rows; on a single-node run it degenerates to one
/// row per run. Not part of [`ALL_FIGURES`] (the paper set) — rendered by
/// `chopper campaign` on multi-node grids and `examples/multinode.rs`.
pub fn node_rollup(runs: &[IndexedRun]) -> Figure {
    let mut csv = String::from(
        "run,sharding,nodes,node,iter_median_ms,fwd_ms,bwd_ms,opt_ms\n",
    );
    let mut ascii = String::from(
        "Node rollup — median iteration span and phase durations per node\n\n",
    );
    for sr in runs {
        let idx = sr.idx();
        let meta = &sr.sr.run.trace.meta;
        let sharding = if meta.sharding.is_empty() {
            "FSDP"
        } else {
            meta.sharding.as_str()
        };
        let medians = idx.node_iter_medians();
        let _ = writeln!(
            ascii,
            "{} [{sharding}, {} node(s) x {} gpu(s)]",
            sr.label(),
            meta.nodes(),
            meta.node_gpus()
        );
        let max_med = medians.iter().cloned().fold(0.0_f64, f64::max).max(1e-9);
        for (n, med) in medians.iter().enumerate() {
            let phase_med = |ph: Phase| -> f64 {
                idx.node_phase_dur()
                    .get(&(ph, n as u32))
                    .map(|v| stats::median(v))
                    .unwrap_or(0.0)
            };
            let (fwd, bwd, opt) = (
                phase_med(Phase::Forward),
                phase_med(Phase::Backward),
                phase_med(Phase::Optimizer),
            );
            ascii.push_str(&ascii::stacked_bar(
                &format!("  node{n:<2}"),
                &[
                    ("fwd".into(), fwd),
                    ("bwd".into(), bwd),
                    ("opt".into(), opt),
                ],
                44,
                max_med.max(fwd + bwd + opt),
            ));
            let _ = writeln!(
                ascii,
                "         iter median {}",
                fmt::dur_ns(*med)
            );
            let _ = writeln!(
                csv,
                "{},{},{},{},{:.4},{:.4},{:.4},{:.4}",
                sr.label(),
                sharding,
                meta.nodes(),
                n,
                med / 1e6,
                fwd / 1e6,
                bwd / 1e6,
                opt / 1e6
            );
        }
        ascii.push('\n');
    }
    Figure {
        id: "nodes",
        title: "Node rollup — per-node iteration and phase medians".into(),
        ascii,
        csv,
        svg: None,
    }
}

// ---------------------------------------------------------------------------
// Thermal figures — temperature timeline and throttle-loss breakdown
// ---------------------------------------------------------------------------

/// Per-GPU die-temperature timeline. Each GPU's governor-window samples
/// are bucketed into at most 48 equal index ranges (mean temperature, min
/// throttle per bucket) so the ascii sparkline and the CSV stay bounded
/// regardless of run length. Like [`node_rollup`], not part of
/// [`ALL_FIGURES`] — rendered only for thermal-enabled runs
/// (`PowerTrace::has_thermal`), so thermal-disabled report output is
/// byte-identical to builds without this figure.
pub fn thermal_timeline(runs: &[IndexedRun]) -> Figure {
    const BUCKETS: usize = 48;
    const RAMP: &[u8] = b" .:-=+*#%@";
    let mut csv =
        String::from("run,gpu,bucket,t_ms,temp_c,min_throttle\n");
    let mut ascii = String::from(
        "Thermal timeline — per-GPU die temperature (bucketed governor windows)\n\n",
    );
    for sr in runs {
        let power = &sr.sr.run.power;
        if !power.has_thermal() {
            continue;
        }
        let mut per_gpu: BTreeMap<u32, Vec<&crate::trace::event::PowerSample>> =
            BTreeMap::new();
        for s in &power.samples {
            per_gpu.entry(s.gpu).or_default().push(s);
        }
        let peak = power.peak_temp_c().max(1e-9);
        let floor: f64 = power
            .samples
            .iter()
            .map(|s| s.temp_c)
            .fold(f64::INFINITY, f64::min)
            .min(peak);
        let span = (peak - floor).max(1e-9);
        let _ = writeln!(ascii, "{} (peak {:.1} C)", sr.label(), peak);
        for (gpu, samples) in &per_gpu {
            let n = samples.len();
            let buckets = n.min(BUCKETS).max(1);
            let mut line = String::new();
            for b in 0..buckets {
                let (lo, hi) = (b * n / buckets, ((b + 1) * n / buckets).max(b * n / buckets + 1));
                let slice = &samples[lo..hi.min(n)];
                let temp = slice.iter().map(|s| s.temp_c).sum::<f64>()
                    / slice.len() as f64;
                let thr = slice
                    .iter()
                    .map(|s| s.throttle)
                    .fold(f64::INFINITY, f64::min);
                let lvl = ((temp - floor) / span * (RAMP.len() - 1) as f64)
                    .round()
                    .clamp(0.0, (RAMP.len() - 1) as f64)
                    as usize;
                line.push(RAMP[lvl] as char);
                let _ = writeln!(
                    csv,
                    "{},{},{},{:.3},{:.2},{:.3}",
                    sr.label(),
                    gpu,
                    b,
                    slice[0].t / 1e6,
                    temp,
                    thr
                );
            }
            let g_peak = samples
                .iter()
                .map(|s| s.temp_c)
                .fold(0.0_f64, f64::max);
            let _ = writeln!(
                ascii,
                "  {:>8} |{line}| peak {g_peak:>6.1} C",
                gpu_label(&sr.sr.run.trace.meta, *gpu),
            );
        }
        ascii.push('\n');
    }
    let _ = writeln!(
        ascii,
        "  scale: ' ' = coolest sampled, '@' = hottest sampled"
    );
    Figure {
        id: "thermal",
        title: "Thermal timeline — per-GPU die temperature".into(),
        ascii,
        csv,
        svg: None,
    }
}

/// Throttle-loss breakdown: per-GPU clock capacity lost to thermal
/// throttling next to its peak temperature — the thermal companion of
/// Fig. 14's frequency/power averages. Like [`thermal_timeline`], not part
/// of [`ALL_FIGURES`] and rendered only for thermal-enabled runs.
pub fn throttle_breakdown(runs: &[IndexedRun]) -> Figure {
    let mut csv = String::from(
        "run,gpu,peak_temp_c,throttle_loss_ms,window_ms,loss_pct\n",
    );
    let mut ascii = String::from(
        "Throttle loss — per-GPU clock capacity lost to thermal throttling\n\n",
    );
    for sr in runs {
        let power = &sr.sr.run.power;
        if !power.has_thermal() {
            continue;
        }
        let mut loss: BTreeMap<u32, f64> = BTreeMap::new();
        let mut window: BTreeMap<u32, f64> = BTreeMap::new();
        let mut peak: BTreeMap<u32, f64> = BTreeMap::new();
        for s in &power.samples {
            *loss.entry(s.gpu).or_insert(0.0) += s.throttle_loss_ns();
            *window.entry(s.gpu).or_insert(0.0) += s.window_ns;
            let p = peak.entry(s.gpu).or_insert(0.0);
            *p = p.max(s.temp_c);
        }
        let total_loss: f64 = loss.values().sum();
        let max_loss = loss.values().cloned().fold(0.0_f64, f64::max);
        let _ = writeln!(
            ascii,
            "{} (total {:.2} ms lost)",
            sr.label(),
            total_loss / 1e6
        );
        for (gpu, &l) in &loss {
            let w = window[gpu].max(1e-9);
            ascii.push_str(&ascii::stacked_bar(
                &format!("  {:>8}", gpu_label(&sr.sr.run.trace.meta, *gpu)),
                &[("lost".into(), l)],
                44,
                max_loss.max(1e-9),
            ));
            let _ = writeln!(
                ascii,
                "           peak {:>6.1} C   lost {} ({:.2}% of windows)",
                peak[gpu],
                fmt::dur_ns(l),
                l / w * 100.0
            );
            let _ = writeln!(
                csv,
                "{},{},{:.2},{:.4},{:.4},{:.3}",
                sr.label(),
                gpu,
                peak[gpu],
                l / 1e6,
                window[gpu] / 1e6,
                l / w * 100.0
            );
        }
        ascii.push('\n');
    }
    Figure {
        id: "throttle",
        title: "Throttle loss — thermal clock-capacity breakdown".into(),
        ascii,
        csv,
        svg: None,
    }
}

/// All figure ids this module can regenerate.
pub const ALL_FIGURES: [&str; 13] = [
    "table2", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10",
    "fig11", "fig12", "fig13", "fig14", "fig15",
];

/// Render every figure of a sweep, fanning the generators out over the
/// campaign runner on `jobs` workers with ordered collection — the output
/// vector is byte-identical to a serial pass (`jobs <= 1`), in
/// [`ALL_FIGURES`] order. The per-run indexes are built once (also in
/// parallel) and shared by all generators.
pub fn render_all(
    node: &NodeSpec,
    cfg: &ModelConfig,
    runs: &[SweepRun],
    jobs: usize,
) -> Result<Vec<Figure>, String> {
    let indexed = index_runs_with(runs, jobs);
    let find = |label: &str| {
        indexed
            .iter()
            .find(|r| r.label() == label)
            .ok_or_else(|| format!("sweep missing {label}"))
    };
    let v1 = find("b2s4-FSDPv1")?;
    let v2 = find("b2s4-FSDPv2")?;
    let idxs = &indexed;
    let tasks: Vec<Box<dyn Fn() -> Figure + Sync + '_>> = vec![
        Box::new(|| table2(cfg)),
        Box::new(|| fig4(idxs)),
        Box::new(|| fig5(idxs)),
        Box::new(|| fig6(idxs)),
        Box::new(|| fig7(v1, v2)),
        Box::new(|| fig8(v1)),
        Box::new(|| fig9(idxs)),
        Box::new(fig10),
        Box::new(|| fig11(v1, v2)),
        Box::new(|| fig12(v1)),
        Box::new(|| fig13(v2)),
        Box::new(|| fig14(v1, v2)),
        Box::new(|| fig15(idxs, node)),
    ];
    Ok(crate::campaign::runner::run_ordered(&tasks, jobs, |_, t| {
        t()
    }))
}

/// Render the thermal figures ([`thermal_timeline`], [`throttle_breakdown`])
/// for a sweep. Returns an empty vector when no run carries thermal
/// telemetry, so thermal-disabled invocations emit exactly the
/// [`ALL_FIGURES`] set and nothing else.
pub fn render_thermal(runs: &[SweepRun], jobs: usize) -> Vec<Figure> {
    let indexed = index_runs_with(runs, jobs);
    if !indexed.iter().any(|r| r.sr.run.power.has_thermal()) {
        return Vec::new();
    }
    vec![thermal_timeline(&indexed), throttle_breakdown(&indexed)]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Small sweep for generator tests: 2 layers, 2 iterations.
    fn small_sweep() -> (NodeSpec, Vec<SweepRun>) {
        let node = NodeSpec::mi300x_node();
        let mut cfg = ModelConfig::llama3_8b();
        cfg.layers = 2;
        let runs = run_sweep(
            &node,
            &cfg,
            &[FsdpVersion::V1, FsdpVersion::V2],
            2,
            1,
        );
        (node, runs)
    }

    fn by_label<'a, 't>(
        runs: &'a [IndexedRun<'t>],
        label: &str,
    ) -> &'a IndexedRun<'t> {
        runs.iter().find(|r| r.label() == label).unwrap()
    }

    #[test]
    fn sweep_covers_paper_configs() {
        let (_, runs) = small_sweep();
        assert_eq!(runs.len(), 10);
        assert!(runs.iter().any(|r| r.label() == "b4s4-FSDPv1"));
        assert!(runs.iter().any(|r| r.label() == "b2s8-FSDPv2"));
    }

    #[test]
    fn every_figure_generates_nonempty_output() {
        let (node, runs) = small_sweep();
        let indexed = index_runs(&runs);
        let v1 = by_label(&indexed, "b2s4-FSDPv1");
        let v2 = by_label(&indexed, "b2s4-FSDPv2");
        let figs = vec![
            table2(&ModelConfig::llama3_8b()),
            fig4(&indexed),
            fig5(&indexed),
            fig6(&indexed),
            fig7(v1, v2),
            fig8(v1),
            fig9(&indexed),
            fig10(),
            fig11(v1, v2),
            fig12(v1),
            fig13(v2),
            fig14(v1, v2),
            fig15(&indexed[..2], &node),
        ];
        for f in &figs {
            assert!(!f.ascii.trim().is_empty(), "{} ascii empty", f.id);
            assert!(f.csv.lines().count() >= 2, "{} csv empty", f.id);
        }
        let ids: Vec<&str> = figs.iter().map(|f| f.id).collect();
        assert_eq!(ids, ALL_FIGURES.to_vec());
    }

    #[test]
    fn figures_save_to_disk() {
        let f = fig10();
        let dir = std::env::temp_dir().join("chopper_fig_test");
        f.save(&dir).unwrap();
        assert!(dir.join("fig10.txt").exists());
        assert!(dir.join("fig10.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fig4_csv_has_relative_throughput_column() {
        let (_, runs) = small_sweep();
        let indexed = index_runs(&runs);
        let f = fig4(&indexed);
        let header = f.csv.lines().next().unwrap();
        assert!(header.contains("rel_throughput"));
        // b1s4-v1 row should have rel == 1.0.
        let row = f
            .csv
            .lines()
            .find(|l| l.starts_with("b1s4,FSDPv1"))
            .unwrap();
        let rel: f64 = row.split(',').nth(3).unwrap().parse().unwrap();
        assert!((rel - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fig8_svg_is_valid_xml_fragment() {
        let (_, runs) = small_sweep();
        let indexed = index_runs(&runs);
        let f = fig8(by_label(&indexed, "b2s4-FSDPv1"));
        let svg = f.svg.unwrap();
        assert!(svg.starts_with("<svg"));
        assert!(svg.trim_end().ends_with("</svg>"));
    }

    #[test]
    fn gpu_labels_flat_vs_node_grouped() {
        let mut meta = crate::trace::event::TraceMeta::default();
        meta.num_gpus = 8;
        assert_eq!(gpu_label(&meta, 3), "GPU3");
        meta.num_nodes = 2;
        meta.gpus_per_node = 8;
        meta.num_gpus = 16;
        assert_eq!(gpu_label(&meta, 3), "N0G3");
        assert_eq!(gpu_label(&meta, 11), "N1G3");
    }

    #[test]
    fn node_rollup_renders_one_row_per_node() {
        let (_, runs) = small_sweep();
        let indexed = index_runs(&runs);
        let f = node_rollup(&indexed[..1]);
        assert_eq!(f.id, "nodes");
        assert!(f.ascii.contains("node0"));
        // Single-node run: header + exactly one data row.
        assert_eq!(f.csv.lines().count(), 2);
        let row = f.csv.lines().nth(1).unwrap();
        assert!(row.contains("FSDP"));
    }

    #[test]
    fn render_all_produces_all_figures_in_order() {
        let (node, runs) = small_sweep();
        let cfg = ModelConfig::llama3_8b();
        let figs = render_all(&node, &cfg, &runs, 1).unwrap();
        let ids: Vec<&str> = figs.iter().map(|f| f.id).collect();
        assert_eq!(ids, ALL_FIGURES.to_vec());
    }

    #[test]
    fn render_thermal_gated_on_telemetry() {
        // Thermal-disabled sweep: no thermal figures at all.
        let (_, runs) = small_sweep();
        assert!(render_thermal(&runs, 1).is_empty());

        // Thermal-enabled sweep with no headroom: both figures, and the
        // breakdown prices a nonzero loss.
        let node = NodeSpec::mi300x_node();
        let mut cfg = ModelConfig::llama3_8b();
        cfg.layers = 2;
        let mut params = crate::sim::EngineParams::default();
        params.thermal = Some(crate::sim::thermal::ThermalConfig {
            ambient_c: 85.0,
            tau_s: 0.005,
            ..Default::default()
        });
        let hot = run_sweep_topo_params(
            &crate::config::Topology::single(node),
            &cfg,
            &[FsdpVersion::V1],
            2,
            1,
            &params,
        );
        let figs = render_thermal(&hot, 1);
        let ids: Vec<&str> = figs.iter().map(|f| f.id).collect();
        assert_eq!(ids, vec!["thermal", "throttle"]);
        assert!(figs[0].csv.lines().count() > 1);
        let total: f64 = figs[1]
            .csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').nth(3).unwrap().parse::<f64>().unwrap())
            .sum();
        assert!(total > 0.0, "no throttle loss under 85C ambient: {total}");
    }
}
