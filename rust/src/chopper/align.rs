//! Trace alignment — the paper's Section III-C1.
//!
//! The runtime trace has valid concurrent timestamps but no counters; the
//! hardware trace has counters but serialized (useless) timestamps. The
//! two are joined by (gpu, stream, dispatch-sequence), which is stable
//! across runs because every pass dispatches the identical program. After
//! alignment every kernel event carries its derived metrics, so the
//! aggregation stage can roll hardware counters up to operations, layers,
//! phases, iterations, and GPUs.
//!
//! `AlignedTrace` **borrows** the trace (the pre-index version took it by
//! value, which forced a deep clone at every call site that still needed
//! the trace) and stores the joined metrics as a column on the shared
//! [`TraceIndex`], so the downstream breakdown queries reuse the same
//! instance partition and overlap intervals as every other analysis.

use crate::chopper::index::TraceIndex;
use crate::counters::{CounterTrace, DerivedMetrics};
use crate::trace::event::{Trace, TraceEvent};

/// A runtime trace index with hardware counters attached to each kernel.
#[derive(Debug)]
pub struct AlignedTrace<'t> {
    pub trace: &'t Trace,
    /// The shared analysis index, with the counter-derived metrics column
    /// attached (one insert + one lookup per kernel event, fast
    /// deterministic hashing for the id join).
    pub index: TraceIndex<'t>,
    /// Kernels that had no counter record (reported, not fatal).
    pub unmatched: usize,
}

impl<'t> AlignedTrace<'t> {
    /// Join a runtime trace with a hardware-counter trace.
    pub fn align(trace: &'t Trace, counters: &CounterTrace) -> Self {
        let mut index = TraceIndex::build(trace);
        let unmatched = index.attach_counters(counters);
        Self {
            trace,
            index,
            unmatched,
        }
    }

    /// Metrics of one kernel, if its counters were collected.
    pub fn metrics_of(&self, e: &TraceEvent) -> Option<&DerivedMetrics> {
        self.index.metrics_of(e)
    }

    pub fn metrics_by_id(&self, kernel_id: u64) -> Option<&DerivedMetrics> {
        self.index.metrics_by_id(kernel_id)
    }

    /// Fraction of kernels successfully aligned.
    pub fn coverage(&self) -> f64 {
        self.index.coverage()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chopper::fixtures;
    use crate::config::*;
    use crate::model::ops::OpKind;

    fn aligned() -> AlignedTrace<'static> {
        let rt = fixtures::runtime(2, 1, 1, 0, FsdpVersion::V1);
        let hw = fixtures::counters(2, 1, 1, 0, FsdpVersion::V1);
        AlignedTrace::align(&rt.trace, hw)
    }

    #[test]
    fn full_coverage_on_matching_runs() {
        let a = aligned();
        assert_eq!(a.unmatched, 0);
        assert!((a.coverage() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gemm_kernels_get_mfma_utilization() {
        let a = aligned();
        let mut checked = 0;
        for e in &a.trace.events {
            if e.kind() == OpKind::Gemm {
                let m = a.metrics_of(e).expect("aligned");
                assert!(m.mfma_util > 0.0, "{}", e.name);
                assert!(m.flops_performed >= e.flops * 0.999, "{}", e.name);
                checked += 1;
            }
        }
        assert!(checked > 0);
    }

    #[test]
    fn vector_kernels_have_zero_mfma() {
        let a = aligned();
        let e = a
            .trace
            .events
            .iter()
            .find(|e| e.kind() == OpKind::Vector)
            .unwrap();
        assert_eq!(a.metrics_of(e).unwrap().mfma_util, 0.0);
    }

    #[test]
    fn counters_come_from_serialized_pass_not_runtime_duration() {
        // The derived freq uses the runtime duration but hardware cycles:
        // kernels stretched by contention/DVFS at runtime show *lower*
        // derived frequency than peak — that is Eq. 10's signal.
        let a = aligned();
        let below_peak = a
            .trace
            .events
            .iter()
            .filter_map(|e| a.metrics_of(e))
            .filter(|m| m.freq_mhz < 2100.0 - 1.0)
            .count();
        assert!(below_peak > 0, "no kernel shows sub-peak derived frequency");
    }
}
