//! Trace alignment — the paper's Section III-C1.
//!
//! The runtime trace has valid concurrent timestamps but no counters; the
//! hardware trace has counters but serialized (useless) timestamps. The
//! two are joined by (gpu, stream, dispatch-sequence), which is stable
//! across runs because every pass dispatches the identical program. After
//! alignment every kernel event carries its derived metrics, so the
//! aggregation stage can roll hardware counters up to operations, layers,
//! phases, iterations, and GPUs.

use crate::counters::{CounterTrace, DerivedMetrics};
use crate::sim::align_key;
use crate::trace::event::{Trace, TraceEvent};
use crate::util::hash::FxHashMap;

/// A runtime trace with hardware counters attached to each kernel.
#[derive(Debug)]
pub struct AlignedTrace {
    pub trace: Trace,
    /// kernel_id → derived metrics (from the hardware pass). Fast
    /// deterministic hasher: this map takes one insert + one lookup per
    /// kernel event and is never iterated.
    metrics: FxHashMap<u64, DerivedMetrics>,
    /// Kernels that had no counter record (reported, not fatal).
    pub unmatched: usize,
}

impl AlignedTrace {
    /// Join a runtime trace with a hardware-counter trace.
    pub fn align(trace: Trace, counters: &CounterTrace) -> Self {
        let mut metrics = FxHashMap::with_capacity_and_hasher(
            trace.events.len(),
            Default::default(),
        );
        let mut unmatched = 0;
        for e in &trace.events {
            match counters
                .get(e.gpu, align_key(e.stream, e.seq))
                .and_then(|v| DerivedMetrics::from_counters(v, e.duration()))
            {
                Some(m) => {
                    metrics.insert(e.kernel_id, m);
                }
                None => unmatched += 1,
            }
        }
        Self {
            trace,
            metrics,
            unmatched,
        }
    }

    /// Metrics of one kernel, if its counters were collected.
    pub fn metrics_of(&self, e: &TraceEvent) -> Option<&DerivedMetrics> {
        self.metrics.get(&e.kernel_id)
    }

    pub fn metrics_by_id(&self, kernel_id: u64) -> Option<&DerivedMetrics> {
        self.metrics.get(&kernel_id)
    }

    /// Fraction of kernels successfully aligned.
    pub fn coverage(&self) -> f64 {
        if self.trace.events.is_empty() {
            return 1.0;
        }
        self.metrics.len() as f64 / self.trace.events.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::*;
    use crate::counters::Counter;
    use crate::model::ops::OpKind;
    use crate::trace::collect::{HardwareProfiler, RuntimeProfiler};

    fn aligned() -> AlignedTrace {
        let node = NodeSpec::mi300x_node();
        let mut cfg = ModelConfig::llama3_8b();
        cfg.layers = 2;
        let mut wl = WorkloadConfig::new(1, 4096, FsdpVersion::V1);
        wl.iterations = 1;
        wl.warmup = 0;
        let rt = RuntimeProfiler::new(node.clone()).capture(&cfg, &wl);
        let hw = HardwareProfiler::new(node).capture(&cfg, &wl, &Counter::ALL);
        AlignedTrace::align(rt.trace, &hw)
    }

    #[test]
    fn full_coverage_on_matching_runs() {
        let a = aligned();
        assert_eq!(a.unmatched, 0);
        assert!((a.coverage() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gemm_kernels_get_mfma_utilization() {
        let a = aligned();
        let mut checked = 0;
        for e in &a.trace.events {
            if e.kind() == OpKind::Gemm {
                let m = a.metrics_of(e).expect("aligned");
                assert!(m.mfma_util > 0.0, "{}", e.name);
                assert!(m.flops_performed >= e.flops * 0.999, "{}", e.name);
                checked += 1;
            }
        }
        assert!(checked > 0);
    }

    #[test]
    fn vector_kernels_have_zero_mfma() {
        let a = aligned();
        let e = a
            .trace
            .events
            .iter()
            .find(|e| e.kind() == OpKind::Vector)
            .unwrap();
        assert_eq!(a.metrics_of(e).unwrap().mfma_util, 0.0);
    }

    #[test]
    fn counters_come_from_serialized_pass_not_runtime_duration() {
        // The derived freq uses the runtime duration but hardware cycles:
        // kernels stretched by contention/DVFS at runtime show *lower*
        // derived frequency than peak — that is Eq. 10's signal.
        let a = aligned();
        let below_peak = a
            .trace
            .events
            .iter()
            .filter_map(|e| a.metrics_of(e))
            .filter(|m| m.freq_mhz < 2100.0 - 1.0)
            .count();
        assert!(below_peak > 0, "no kernel shows sub-peak derived frequency");
    }
}
