//! The Chopper tool itself — the paper's contribution (Fig. 3): trace
//! alignment, multi-granularity aggregation, overlap / launch-overhead /
//! CPU-utilization / duration-breakdown analyses, throughput, the figure
//! generators, and the counterfactual what-if policy replay ([`whatif`],
//! DESIGN.md §9).
//!
//! Every analysis consumes the shared build-once/query-many
//! [`TraceIndex`] (DESIGN.md §7) instead of re-scanning the raw event
//! vector.

pub mod aggregate;
pub mod align;
pub mod breakdown;
pub mod cpuutil;
#[cfg(test)]
pub mod fixtures;
pub mod index;
pub mod launch;
pub mod overlap;
pub mod report;
pub mod serving;
pub mod throughput;
pub mod whatif;

pub use aggregate::{op_duration_samples, op_instances, Filter, OpInstanceAgg};
pub use align::AlignedTrace;
pub use breakdown::{all_breakdowns, op_breakdown, OpBreakdown};
pub use cpuutil::CpuUtilAnalysis;
pub use index::{IndexBuilder, RequestColumn, TraceIndex};
pub use serving::{serving_energy, serving_goodput, serving_latency};
pub use launch::{launch_overhead, op_launch_overheads, LaunchOverhead};
pub use overlap::{
    duration_at_overlap, overlap_samples, per_gpu_overlap_cdf,
    summarize_op_overlap, CommIntervals, OpOverlapSummary, OverlapSample,
};
pub use throughput::{throughput, Throughput};
pub use whatif::{
    FaultOutcome, FaultWhatIfReport, PolicyOutcome, ServingPolicyOutcome,
    ServingWhatIfReport, WhatIfReport,
};
