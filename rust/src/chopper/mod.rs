//! The Chopper tool itself — the paper's contribution (Fig. 3): trace
//! alignment, multi-granularity aggregation, overlap / launch-overhead /
//! CPU-utilization / duration-breakdown analyses, throughput, and the
//! figure generators.

pub mod aggregate;
pub mod align;
pub mod breakdown;
pub mod cpuutil;
pub mod launch;
pub mod overlap;
pub mod report;
pub mod throughput;

pub use aggregate::{op_duration_samples, op_instances, Filter, OpInstanceAgg};
pub use align::AlignedTrace;
pub use breakdown::{all_breakdowns, op_breakdown, OpBreakdown};
pub use cpuutil::CpuUtilAnalysis;
pub use launch::{launch_overhead, op_launch_overheads, LaunchOverhead};
pub use overlap::{
    duration_at_overlap, overlap_samples, per_gpu_overlap_cdf,
    summarize_op_overlap, CommIntervals, OpOverlapSummary,
};
pub use throughput::{throughput, Throughput};
