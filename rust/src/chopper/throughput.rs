//! End-to-end throughput — the paper's Fig. 4 headline row.
//!
//! "Throughput is calculated with the maximum duration plus launch
//! overhead across GPUs": for each sampled iteration, every GPU's cost is
//! its summed kernel duration plus its summed launch overhead; the
//! iteration cost is the slowest GPU's; tokens/s is tokens-per-iteration
//! over the median iteration cost. Both per-(gpu, iter) rollups are
//! precomputed by the shared [`TraceIndex`], so this is a pure map merge.

use crate::chopper::index::TraceIndex;
use crate::util::stats;
use std::collections::BTreeMap;

/// Throughput summary of one profiled run.
#[derive(Debug, Clone, Copy)]
pub struct Throughput {
    pub tokens_per_sec: f64,
    /// Median per-iteration cost (ns) of the slowest GPU.
    pub iter_ns: f64,
    /// Median per-iteration kernel duration (slowest GPU), ns.
    pub duration_ns: f64,
    /// Median per-iteration launch overhead (slowest GPU), ns.
    pub launch_ns: f64,
}

/// Compute throughput for a run of `tokens_per_iter` tokens (across all
/// GPUs' micro-batches) per iteration.
pub fn throughput(idx: &TraceIndex, tokens_per_iter: f64) -> Throughput {
    let durs = idx.compute_ns();
    let launch = idx.launch_ns();
    let warmup = idx.trace.meta.warmup;
    // Per iteration: max across GPUs of duration + launch overhead.
    let mut per_iter: BTreeMap<u32, (f64, f64, f64)> = BTreeMap::new();
    for (&(gpu, iter), &d) in durs {
        if iter < warmup {
            continue;
        }
        let l = launch.get(&(gpu, iter)).copied().unwrap_or(0.0);
        let e = per_iter.entry(iter).or_insert((0.0, 0.0, 0.0));
        if d + l > e.0 {
            *e = (d + l, d, l);
        }
    }
    let totals: Vec<f64> = per_iter.values().map(|v| v.0).collect();
    let durations: Vec<f64> = per_iter.values().map(|v| v.1).collect();
    let launches: Vec<f64> = per_iter.values().map(|v| v.2).collect();
    let iter_ns = stats::median(&totals);
    Throughput {
        tokens_per_sec: tokens_per_iter / (iter_ns * 1e-9),
        iter_ns,
        duration_ns: stats::median(&durations),
        launch_ns: stats::median(&launches),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chopper::fixtures;
    use crate::config::*;

    fn run(batch: u64, layers: u64) -> (TraceIndex<'static>, f64) {
        let cap = fixtures::runtime(layers, batch, 2, 1, FsdpVersion::V1);
        let tokens = {
            let mut wl = WorkloadConfig::new(batch, 4096, FsdpVersion::V1);
            wl.iterations = 2;
            wl.warmup = 1;
            wl.tokens_per_iteration(8) as f64
        };
        (TraceIndex::build(&cap.trace), tokens)
    }

    #[test]
    fn throughput_is_positive_and_sane() {
        let (idx, tokens) = run(2, 4);
        let tp = throughput(&idx, tokens);
        assert!(tp.tokens_per_sec > 1_000.0, "{}", tp.tokens_per_sec);
        assert!(tp.tokens_per_sec < 10_000_000.0);
        assert!(tp.iter_ns >= tp.duration_ns);
        assert!((tp.iter_ns - tp.duration_ns - tp.launch_ns).abs() < 1.0);
    }

    #[test]
    fn batch2_beats_batch1_tokens_per_sec() {
        // Observation 1: batch one underutilizes.
        let (i1, tok1) = run(1, 4);
        let (i2, tok2) = run(2, 4);
        let tp1 = throughput(&i1, tok1);
        let tp2 = throughput(&i2, tok2);
        assert!(
            tp2.tokens_per_sec > tp1.tokens_per_sec * 1.1,
            "b2 {:.0} !>> b1 {:.0}",
            tp2.tokens_per_sec,
            tp1.tokens_per_sec
        );
    }

    #[test]
    fn launch_share_shrinks_with_scale() {
        // Insight 6: launch overhead's share decreases with b·s.
        let (i1, _) = run(1, 4);
        let (i2, _) = run(4, 4);
        let tp1 = throughput(&i1, 1.0);
        let tp2 = throughput(&i2, 1.0);
        let share1 = tp1.launch_ns / tp1.iter_ns;
        let share2 = tp2.launch_ns / tp2.iter_ns;
        assert!(share1 > share2, "{share1} !> {share2}");
    }
}
