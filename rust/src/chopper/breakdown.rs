//! Duration breakdown — the gap between theoretical and actual performance
//! (the paper's Section V-G, Eqs. (6)–(10), Fig. 15).
//!
//! For a GEMM/FlashAttention operation the actual duration factorizes as
//!
//!   D_act ≈ D_thr · Ovr_inst · Ovr_util · Ovr_overlap · Ovr_freq
//!
//! where D_thr = F_gemm / TPT_peak (Eq. 6), Ovr_inst = F_perf / F_gemm
//! (padding, Eq. 7), Ovr_util = 1 / MFMA_util (Eq. 8), Ovr_overlap =
//! D_50% / D_0% from the overlap-vs-duration profile (Eq. 9), and
//! Ovr_freq = (D_act / D_peak) / Ovr_overlap with D_peak = C_gpu /
//! Freq_peak (Eq. 10) — the residual DVFS term, which the paper finds
//! dominates.
//!
//! Consumes the counter-joined [`AlignedTrace`]: instances, overlap
//! intervals, and metrics all come from its shared index — nothing here
//! re-scans the events or rebuilds the interval set per op.

use crate::chopper::aggregate::{op_instances, Filter};
use crate::chopper::align::AlignedTrace;
use crate::chopper::overlap::{duration_at_overlap, overlap_samples};
use crate::config::GpuSpec;
use crate::model::ops::{OpKind, OpRef};
use crate::util::stats;
use std::collections::BTreeMap;

/// The Eq. (6)–(10) decomposition of one operation.
#[derive(Debug, Clone, Copy)]
pub struct OpBreakdown {
    pub op: OpRef,
    /// Median actual duration (ns) across sampled instances.
    pub d_act: f64,
    /// Eq. (6): theoretical duration at peak FLOPS (ns).
    pub d_thr: f64,
    /// Eq. (7): performed/theoretical flops, ≥ 1.
    pub inst: f64,
    /// Eq. (8): 1 / MFMA utilization, ≥ 1.
    pub util: f64,
    /// Eq. (9): D_50% / D_0%.
    pub overlap: f64,
    /// Eq. (10): residual frequency (DVFS) overhead.
    pub freq: f64,
    pub n: usize,
}

impl OpBreakdown {
    /// Product of all overheads — should reconstruct D_act / D_thr.
    pub fn total_overhead(&self) -> f64 {
        self.inst * self.util * self.overlap * self.freq
    }

    /// Relative reconstruction error of the factorization.
    pub fn residual(&self) -> f64 {
        if self.d_thr <= 0.0 || self.d_act <= 0.0 {
            return 0.0;
        }
        (self.d_thr * self.total_overhead() / self.d_act - 1.0).abs()
    }
}

/// Compute the breakdown of one GEMM/FA op from an aligned trace.
/// Returns None for ops with no MFMA work (vector/copy/comm).
pub fn op_breakdown(
    aligned: &AlignedTrace,
    gpu_spec: &GpuSpec,
    op: OpRef,
) -> Option<OpBreakdown> {
    if !matches!(op.op.kind(), OpKind::Gemm | OpKind::FlashAttn) {
        return None;
    }
    let idx = &aligned.index;
    let mut f = Filter::sampled();
    f.op = Some(op);
    let insts = op_instances(idx, &f);
    if insts.is_empty() {
        return None;
    }

    // Median actual duration + per-instance counter sums.
    let mut d_acts = Vec::with_capacity(insts.len());
    let mut insts_ovr = Vec::new();
    let mut utils = Vec::new();
    let mut d_peaks = Vec::new();
    for inst in &insts {
        d_acts.push(inst.duration());
        let mut f_perf = 0.0;
        let mut cycles = 0.0;
        let mut mfma_cycles = 0.0;
        for &kid in &inst.kernel_ids {
            if let Some(m) = aligned.metrics_by_id(kid) {
                f_perf += m.flops_performed;
                cycles += m.gpu_cycles;
                mfma_cycles += m.gpu_cycles * m.mfma_util;
            }
        }
        if inst.flops > 0.0 && f_perf > 0.0 {
            insts_ovr.push(f_perf / inst.flops);
        }
        if cycles > 0.0 && mfma_cycles > 0.0 {
            utils.push(cycles / mfma_cycles); // 1 / MFMA_util
        }
        if cycles > 0.0 {
            // D_peak = C_gpu / Freq_peak (Eq. 10), in ns.
            d_peaks.push(cycles / (gpu_spec.freq_peak_mhz * 1e-3));
        }
    }
    if d_acts.is_empty() || d_peaks.is_empty() {
        return None;
    }
    let d_act = stats::median(&d_acts);
    let d_peak = stats::median(&d_peaks);
    let flops_med = stats::median(&insts.iter().map(|i| i.flops).collect::<Vec<_>>());
    let d_thr = flops_med / gpu_spec.peak_bf16_flops * 1e9;
    let inst_ovr = if insts_ovr.is_empty() {
        1.0
    } else {
        stats::median(&insts_ovr).max(1.0)
    };
    let util_ovr = if utils.is_empty() {
        1.0
    } else {
        stats::median(&utils).max(1.0)
    };

    // Eq. (9): overlap overhead from the overlap-duration profile.
    let ovl = overlap_samples(idx, &f);
    let profile: Vec<(f64, f64)> =
        ovl.iter().map(|s| (s.ratio, s.inst.duration())).collect();
    let d50 = duration_at_overlap(&profile, 0.5);
    let d0 = duration_at_overlap(&profile, 0.0);
    let overlap_ovr = if d0 > 0.0 && d50.is_finite() {
        (d50 / d0).max(1.0)
    } else {
        1.0
    };

    // Eq. (10): frequency overhead, adjusted by the overlap term.
    let freq_ovr = ((d_act / d_peak) / overlap_ovr).max(1.0);

    Some(OpBreakdown {
        op,
        d_act,
        d_thr,
        inst: inst_ovr,
        util: util_ovr,
        overlap: overlap_ovr,
        freq: freq_ovr,
        n: insts.len(),
    })
}

/// Breakdown of every GEMM + FA op present in the trace (Fig. 15's rows).
/// The op set comes straight off the index's per-op partition — already
/// sorted and deduplicated.
pub fn all_breakdowns(
    aligned: &AlignedTrace,
    gpu_spec: &GpuSpec,
) -> BTreeMap<OpRef, OpBreakdown> {
    aligned
        .index
        .ops()
        .filter(|op| matches!(op.op.kind(), OpKind::Gemm | OpKind::FlashAttn))
        .filter_map(|op| op_breakdown(aligned, gpu_spec, op).map(|b| (op, b)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chopper::fixtures;
    use crate::config::*;
    use crate::model::ops::OpType;

    fn aligned(batch: u64) -> AlignedTrace<'static> {
        let rt = fixtures::runtime(4, batch, 2, 1, FsdpVersion::V1);
        let hw = fixtures::counters(4, batch, 2, 1, FsdpVersion::V1);
        AlignedTrace::align(&rt.trace, hw)
    }

    #[test]
    fn gemm_breakdown_has_all_factors_ge_one() {
        let a = aligned(2);
        let b = op_breakdown(&a, &GpuSpec::mi300x(), OpRef::fwd(OpType::MlpUp))
            .expect("gemm breakdown");
        assert!(b.d_thr > 0.0);
        assert!(b.inst >= 1.0);
        assert!(b.util >= 1.0);
        assert!(b.overlap >= 1.0);
        assert!(b.freq >= 1.0);
        assert!(b.d_act >= b.d_thr, "actual can't beat theoretical");
    }

    #[test]
    fn factorization_reconstructs_actual_duration() {
        let a = aligned(2);
        for op in [
            OpRef::fwd(OpType::MlpUp),
            OpRef::fwd(OpType::MlpDp),
            OpRef::bwd(OpType::MlpGp),
        ] {
            let b = op_breakdown(&a, &GpuSpec::mi300x(), op).unwrap();
            assert!(
                b.residual() < 0.35,
                "{op}: residual {:.2} (act {:.0} thr {:.0} tot {:.2})",
                b.residual(),
                b.d_act,
                b.d_thr,
                b.total_overhead()
            );
        }
    }

    #[test]
    fn fa_has_higher_util_overhead_than_gemm() {
        // Section V-G3: utilization overhead particularly high for FA.
        let a = aligned(2);
        let fa = op_breakdown(&a, &GpuSpec::mi300x(), OpRef::fwd(OpType::AttnFa))
            .unwrap();
        let gemm = op_breakdown(&a, &GpuSpec::mi300x(), OpRef::fwd(OpType::MlpUp))
            .unwrap();
        assert!(fa.util > gemm.util, "fa {} !> gemm {}", fa.util, gemm.util);
    }

    #[test]
    fn vector_ops_have_no_breakdown() {
        let a = aligned(1);
        assert!(op_breakdown(&a, &GpuSpec::mi300x(), OpRef::fwd(OpType::AttnN))
            .is_none());
    }

    #[test]
    fn all_breakdowns_cover_gemm_and_fa() {
        let a = aligned(2);
        let all = all_breakdowns(&a, &GpuSpec::mi300x());
        assert!(all.contains_key(&OpRef::fwd(OpType::AttnFa)));
        assert!(all.contains_key(&OpRef::bwd(OpType::MlpUp)));
        assert!(all.len() >= 10);
    }

    #[test]
    fn frequency_overhead_dominates_for_gemm() {
        // Insight 8, at the mechanism level: with the power-capped DVFS
        // governor, freq overhead exceeds instruction overhead and overlap
        // overhead for the big MLP GEMMs.
        let a = aligned(2);
        let b = op_breakdown(&a, &GpuSpec::mi300x(), OpRef::fwd(OpType::MlpUp))
            .unwrap();
        assert!(b.freq > b.inst, "freq {} !> inst {}", b.freq, b.inst);
        assert!(b.freq > b.overlap, "freq {} !> overlap {}", b.freq, b.overlap);
    }
}
