//! Serving report figures (DESIGN.md §10) — the serving counterparts of
//! the paper figure set: latency percentiles per scenario, the
//! goodput-vs-offered-load curve, and energy-per-request. Like
//! [`node_rollup`](crate::chopper::report::node_rollup) these are *extra*
//! figures, not part of [`ALL_FIGURES`](crate::chopper::report::ALL_FIGURES)
//! (the paper's training set stays byte-identical); `chopper serve`
//! renders them over a QPS sweep.

use crate::chopper::report::Figure;
use crate::serve::ServingReport;
use crate::util::svg;
use std::fmt::Write;

/// Latency percentiles (TTFT / TPOT / e2e, p50 and p99) per scenario.
pub fn serving_latency(reports: &[ServingReport]) -> Figure {
    let mut csv = String::from(
        "label,offered_qps,ttft_p50_ms,ttft_p99_ms,tpot_p50_ms,tpot_p99_ms,\
         e2e_p50_ms,e2e_p99_ms\n",
    );
    let mut ascii = String::from(
        "Serving latency percentiles\n\n\
         label                 qps    ttft p50/p99 ms    tpot p50/p99 ms    e2e p50/p99 ms\n",
    );
    for r in reports {
        let _ = writeln!(
            csv,
            "{},{:.3},{:.4},{:.4},{:.4},{:.4},{:.4},{:.4}",
            r.label,
            r.offered_qps,
            r.ttft_ms.p50,
            r.ttft_ms.p99,
            r.tpot_ms.p50,
            r.tpot_ms.p99,
            r.e2e_ms.p50,
            r.e2e_ms.p99,
        );
        let _ = writeln!(
            ascii,
            "{:<20} {:>6.2}    {:>7.2} / {:<7.2}   {:>7.3} / {:<7.3}   {:>8.2} / {:<8.2}",
            r.label,
            r.offered_qps,
            r.ttft_ms.p50,
            r.ttft_ms.p99,
            r.tpot_ms.p50,
            r.tpot_ms.p99,
            r.e2e_ms.p50,
            r.e2e_ms.p99,
        );
    }
    let groups: Vec<String> = reports.iter().map(|r| r.label.clone()).collect();
    let series = vec![
        "ttft_p50_ms".to_string(),
        "ttft_p99_ms".to_string(),
        "e2e_p99_ms".to_string(),
    ];
    let data: Vec<Vec<f64>> = reports
        .iter()
        .map(|r| vec![r.ttft_ms.p50, r.ttft_ms.p99, r.e2e_ms.p99])
        .collect();
    Figure {
        id: "serving_latency",
        title: "Serving latency percentiles (p50/p99)".into(),
        ascii,
        csv,
        svg: Some(svg::grouped_bars(
            "Serving latency percentiles (ms)",
            &groups,
            &series,
            &data,
        )),
    }
}

/// Goodput (and SLO-gated goodput) against offered load — the serving
/// saturation curve. Meaningful over a QPS sweep; a single report yields a
/// one-point curve.
pub fn serving_goodput(reports: &[ServingReport]) -> Figure {
    let mut csv = String::from(
        "offered_qps,goodput_rps,slo_goodput_rps,output_tok_s,makespan_s\n",
    );
    let mut ascii = String::from(
        "Goodput vs offered load\n\n\
         offered qps    goodput rps    SLO goodput rps    output tok/s\n",
    );
    for r in reports {
        let _ = writeln!(
            csv,
            "{:.3},{:.4},{:.4},{:.2},{:.4}",
            r.offered_qps, r.goodput_rps, r.slo_goodput_rps, r.output_tok_s, r.makespan_s,
        );
        let _ = writeln!(
            ascii,
            "{:>11.3}    {:>11.3}    {:>15.3}    {:>12.1}",
            r.offered_qps, r.goodput_rps, r.slo_goodput_rps, r.output_tok_s,
        );
    }
    let good: Vec<(f64, f64)> = reports
        .iter()
        .map(|r| (r.offered_qps, r.goodput_rps))
        .collect();
    let slo: Vec<(f64, f64)> = reports
        .iter()
        .map(|r| (r.offered_qps, r.slo_goodput_rps))
        .collect();
    Figure {
        id: "serving_goodput",
        title: "Goodput vs offered load".into(),
        ascii,
        csv,
        svg: Some(svg::scatter(
            "Goodput vs offered load",
            "offered qps",
            "goodput rps",
            &[("goodput".to_string(), good), ("slo_goodput".to_string(), slo)],
        )),
    }
}

/// Energy per request and generated tokens per joule per scenario (the PR 5
/// power plumbing, serving-shaped).
pub fn serving_energy(reports: &[ServingReport]) -> Figure {
    let mut csv = String::from(
        "label,offered_qps,energy_per_request_j,tok_per_joule,kv_peak_frac\n",
    );
    let mut ascii = String::from(
        "Serving energy\n\n\
         label                 qps    J/request    tok/J      KV peak\n",
    );
    for r in reports {
        let _ = writeln!(
            csv,
            "{},{:.3},{:.4},{:.6},{:.4}",
            r.label, r.offered_qps, r.energy_per_request_j, r.tok_per_joule, r.kv_peak_frac,
        );
        let _ = writeln!(
            ascii,
            "{:<20} {:>6.2}    {:>9.2}    {:>7.4}    {:>6.1}%",
            r.label,
            r.offered_qps,
            r.energy_per_request_j,
            r.tok_per_joule,
            r.kv_peak_frac * 100.0,
        );
    }
    let groups: Vec<String> = reports.iter().map(|r| r.label.clone()).collect();
    let series = vec!["energy_per_request_j".to_string()];
    let data: Vec<Vec<f64>> = reports
        .iter()
        .map(|r| vec![r.energy_per_request_j])
        .collect();
    Figure {
        id: "serving_energy",
        title: "Energy per request".into(),
        ascii,
        csv,
        svg: Some(svg::grouped_bars(
            "Energy per request (J)",
            &groups,
            &series,
            &data,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chopper::TraceIndex;
    use crate::config::{ModelConfig, NodeSpec, ServingConfig, Topology};
    use crate::serve::run_serving;
    use crate::sim::EngineParams;

    fn reports() -> Vec<ServingReport> {
        [4.0, 64.0]
            .iter()
            .map(|&q| {
                let mut s = ServingConfig::new(q, 10);
                s.seed = 21;
                s.prompt = crate::config::LengthDist::lognormal(64, 0.4, 16, 256);
                s.output = crate::config::LengthDist::lognormal(12, 0.4, 2, 48);
                run_serving(
                    &Topology::single(NodeSpec::mi300x_node()),
                    &ModelConfig::mini(),
                    &s,
                    EngineParams::default(),
                )
                .report
            })
            .collect()
    }

    #[test]
    fn figures_have_one_row_per_scenario() {
        let rs = reports();
        for f in [
            serving_latency(&rs),
            serving_goodput(&rs),
            serving_energy(&rs),
        ] {
            assert_eq!(f.csv.lines().count(), 1 + rs.len(), "{}", f.id);
            assert!(f.svg.is_some(), "{}", f.id);
            assert!(!f.ascii.is_empty());
        }
    }

    #[test]
    fn goodput_curve_is_ordered_by_offered_load() {
        let rs = reports();
        let f = serving_goodput(&rs);
        let qps: Vec<f64> = f
            .csv
            .lines()
            .skip(1)
            .map(|l| l.split(',').next().unwrap().parse().unwrap())
            .collect();
        assert!(qps.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn index_request_column_matches_serving_report() {
        let mut s = ServingConfig::new(16.0, 12);
        s.seed = 33;
        s.prompt = crate::config::LengthDist::lognormal(64, 0.4, 16, 256);
        s.output = crate::config::LengthDist::lognormal(12, 0.4, 2, 48);
        let out = run_serving(
            &Topology::single(NodeSpec::mi300x_node()),
            &ModelConfig::mini(),
            &s,
            EngineParams::default(),
        );
        let mut idx = TraceIndex::build(&out.trace);
        assert!(idx.requests().is_none());
        idx.attach_requests(&out.schedule.records);
        let col = idx.requests().expect("attached");
        assert_eq!(col.ids.len(), 12);
        // The index's trace-derived column agrees with the engine-derived
        // latencies (same events, same bounds).
        for (i, l) in out.latencies.iter().enumerate() {
            assert!((col.ttft_ms[i] - l.ttft_ns * 1e-6).abs() < 1e-6);
            assert!((col.e2e_ms[i] - l.e2e_ns * 1e-6).abs() < 1e-6);
            assert!(col.span_ns[i].0 <= col.span_ns[i].1);
        }
    }
}
