//! Kernel-launch overhead — the paper's Section V-D, Eqs. (1)–(3).
//!
//! Launch overhead is the bubble between consecutive *compute* kernels
//! (communication kernels are ignored; a serialized collective in the
//! compute stream shows up as launch overhead, which Section V-D3 exploits
//! to spot FSDPv2's serialized copies). The bubble splits into:
//!
//!   O_prep = max(t_l(i) − t_ke(i−1), 0)   — the CPU launched "too late";
//!   O_call = min(t_ks(i) − t_l(i), t_ks(i) − t_ke(i−1)) — dispatch→start;
//!   O_launch = O_prep + O_call.
//!
//! Per-kernel overheads are precomputed once per trace on the shared
//! [`TraceIndex`] (per-GPU dispatch-ordered compute lanes); the rollups
//! here iterate those lists instead of re-filtering and re-sorting the
//! full event vector per GPU per call.

use crate::chopper::index::TraceIndex;
use crate::model::ops::{OpKind, OpRef, Phase};
use crate::trace::event::TraceEvent;
use crate::util::stats;
use std::collections::BTreeMap;

/// Launch-overhead components of one kernel (ns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LaunchOverhead {
    pub prep: f64,
    pub call: f64,
}

impl LaunchOverhead {
    pub fn total(&self) -> f64 {
        self.prep + self.call
    }
}

/// Eqs. (1)–(2) for a kernel given the previous compute kernel's end.
pub fn launch_overhead(e: &TraceEvent, prev_end: f64) -> LaunchOverhead {
    let prep = (e.t_launch - prev_end).max(0.0);
    let call = (e.t_start - e.t_launch).min(e.t_start - prev_end);
    LaunchOverhead {
        prep,
        call: call.max(0.0),
    }
}

/// Per-kernel overheads of one GPU's compute stream, in dispatch order.
/// The first kernel of the trace has no predecessor and is skipped.
/// FSDPv2's serialized parameter copies are treated like communication
/// kernels (excluded): the time they occupy becomes a bubble attributed to
/// the next real operation — exactly how the paper spots them as call
/// overhead on f_attn_n / b_mlp_dp / b_ie (Section V-D3).
pub fn per_kernel_overheads<'i>(
    idx: &'i TraceIndex,
    gpu: u32,
) -> &'i [(usize, LaunchOverhead)] {
    idx.gpu_launch(gpu)
}

/// Mean prep/call overhead per operation across sampled iterations and all
/// GPUs — Fig. 11's bars. The overhead of a kernel is attributed to the
/// operation that kernel belongs to, so intra-op bubbles count too.
pub fn op_launch_overheads(idx: &TraceIndex) -> BTreeMap<OpRef, LaunchOverhead> {
    let trace = idx.trace;
    let warmup = trace.meta.warmup;
    let mut acc: BTreeMap<OpRef, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    for gpu in 0..trace.meta.num_gpus {
        for &(i, o) in per_kernel_overheads(idx, gpu) {
            let e = &trace.events[i];
            if e.iter < warmup {
                continue;
            }
            let entry = acc.entry(e.op).or_default();
            entry.0.push(o.prep);
            entry.1.push(o.call);
        }
    }
    acc.into_iter()
        .map(|(op, (preps, calls))| {
            (
                op,
                LaunchOverhead {
                    prep: stats::mean(&preps),
                    call: stats::mean(&calls),
                },
            )
        })
        .collect()
}

/// Total launch overhead per (phase, kind) per (gpu, iteration) — the
/// Fig. 4 launch-overhead row. Samples for median-taking, precomputed by
/// the index.
pub fn phase_kind_launch_samples<'i>(
    idx: &'i TraceIndex,
) -> &'i BTreeMap<(Phase, OpKind), Vec<f64>> {
    idx.phase_kind_launch()
}

/// Total launch overhead of one (gpu, iteration) — used by the throughput
/// definition ("maximum duration plus launch overhead across GPUs").
pub fn iteration_launch_overhead<'i>(
    idx: &'i TraceIndex,
) -> &'i BTreeMap<(u32, u32), f64> {
    idx.launch_ns()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chopper::fixtures;
    use crate::config::*;
    use crate::model::ops::OpType;
    use crate::trace::event::Stream;

    fn ev(seq: u64, t_l: f64, t_s: f64, t_e: f64) -> TraceEvent {
        TraceEvent {
            kernel_id: seq,
            gpu: 0,
            stream: Stream::Compute,
            name: "k".into(),
            op: OpRef::fwd(OpType::MlpUp),
            layer: Some(0),
            iter: 0,
            t_launch: t_l,
            t_start: t_s,
            t_end: t_e,
            seq,
            fwd_link: None,
            freq_mhz: 2100.0,
            flops: 0.0,
            bytes: 0.0,
        }
    }

    #[test]
    fn eq1_eq2_match_fig10_cases() {
        // Case A: CPU launched before the previous kernel ended — no prep,
        // call = start - prev_end.
        let e = ev(1, 90.0, 110.0, 120.0);
        let o = launch_overhead(&e, 100.0);
        assert_eq!(o.prep, 0.0);
        assert_eq!(o.call, 10.0);
        // Case B: CPU launched late — prep = launch - prev_end,
        // call = start - launch.
        let e = ev(1, 130.0, 140.0, 150.0);
        let o = launch_overhead(&e, 100.0);
        assert_eq!(o.prep, 30.0);
        assert_eq!(o.call, 10.0);
        assert_eq!(o.total(), 40.0);
    }

    #[test]
    fn back_to_back_kernels_have_no_overhead() {
        let e = ev(1, 50.0, 100.0, 120.0);
        let o = launch_overhead(&e, 100.0);
        assert_eq!(o.prep, 0.0);
        assert_eq!(o.call, 0.0);
    }

    fn idx() -> TraceIndex<'static> {
        TraceIndex::build(&fixtures::runtime(4, 2, 2, 1, FsdpVersion::V1).trace)
    }

    #[test]
    fn fie_has_prep_overhead_from_pipeline_fill() {
        // Insight 5: f_ie waits for the embedding all-gather at iteration
        // start — large prep+call overhead, not a CPU bottleneck.
        let idx = idx();
        let per_op = op_launch_overheads(&idx);
        let ie = per_op[&OpRef::fwd(OpType::IE)];
        let mid_gemm = per_op[&OpRef::fwd(OpType::MlpUp)];
        assert!(
            ie.total() > mid_gemm.total() * 5.0,
            "f_ie {:.0} !>> f_mlp_up {:.0}",
            ie.total(),
            mid_gemm.total()
        );
    }

    #[test]
    fn opt_step_has_large_call_overhead_v1() {
        let idx = idx();
        let per_op = op_launch_overheads(&idx);
        let opt = per_op[&OpRef::new(OpType::OptStep, Phase::Optimizer)];
        assert!(opt.call > 0.0);
        let gemm = per_op[&OpRef::fwd(OpType::MlpDp)];
        assert!(opt.total() > gemm.total());
    }

    #[test]
    fn overheads_are_nonnegative() {
        let idx = idx();
        for gpu in 0..8 {
            for &(_, o) in per_kernel_overheads(&idx, gpu) {
                assert!(o.prep >= 0.0 && o.call >= 0.0);
            }
        }
    }

    #[test]
    fn fig4_launch_rollup_has_fwd_vec_entry() {
        let idx = idx();
        let m = phase_kind_launch_samples(&idx);
        let v = &m[&(Phase::Forward, OpKind::Vector)];
        assert_eq!(v.len(), 8, "8 gpus × 1 sampled iter");
        assert!(v.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn iteration_overhead_conserves_op_sums() {
        // Sum over op-attributed overheads == sum over iterations (same
        // kernels, different group-by) for sampled iters.
        let idx = idx();
        let warmup = idx.trace.meta.warmup;
        let per_iter = iteration_launch_overhead(&idx);
        let total_iter: f64 = per_iter
            .iter()
            .filter(|((_, it), _)| *it >= warmup)
            .map(|(_, v)| v)
            .sum();
        let mut total_ops = 0.0;
        for gpu in 0..8 {
            for &(i, o) in per_kernel_overheads(&idx, gpu) {
                if idx.trace.events[i].iter >= warmup {
                    total_ops += o.total();
                }
            }
        }
        assert!((total_iter - total_ops).abs() / total_ops.max(1.0) < 1e-9);
    }
}
