//! `TraceIndex` — a build-once / query-many columnar index over a trace.
//!
//! Every analysis in this crate asks one of a handful of questions: "the
//! kernels of operation X", "what overlapped this interval", "the bubble
//! before each compute kernel", "this rollup per (gpu, iteration)". Before
//! this module each question re-scanned the full `Vec<TraceEvent>` (and
//! the alignment stage deep-cloned the trace), so a 12-figure report paid
//! for a dozen full passes per scenario. The index performs **one** pass
//! over the events plus a few per-bucket sorts and precomputes:
//!
//! * per-(gpu, stream) event lanes sorted by `t_start`;
//! * the full operation-instance partition (kernels grouped by
//!   (gpu, iter, op, layer, stream)) in the exact deterministic order the
//!   old `BTreeMap` grouping produced, plus a per-`OpRef` sub-partition
//!   with duration prefix sums;
//! * merged communication-occupancy intervals per GPU ([`CommIntervals`]);
//! * per-GPU compute-lane launch overheads (Eqs. 1–3) and their
//!   per-iteration / per-(phase, kind) rollups;
//! * per-(gpu, iteration) compute spans and summed kernel durations;
//! * optionally, the counter-derived metrics column (the alignment join of
//!   Section III-C1) via [`TraceIndex::attach_counters`].
//!
//! Determinism contract (DESIGN.md §3/§7): every precomputed aggregate
//! accumulates in the same order as the event-order scan it replaced, and
//! every partition is sorted by the same `Ord` keys the old `BTreeMap`s
//! used — so analyses and figure generators that consume the index are
//! **byte-identical** to the pre-index implementations kept verbatim in
//! `rust/benches/analysis_baseline.rs` (asserted by `tests/pipeline.rs`
//! and `benches/analysis_hot.rs`).

use crate::chopper::aggregate::{Filter, OpInstanceAgg};
use crate::chopper::launch::{launch_overhead, LaunchOverhead};
use crate::chopper::overlap::CommIntervals;
use crate::counters::{CounterTrace, DerivedMetrics};
use crate::model::ops::{OpKind, OpRef, OpType, Phase};
use crate::sim::align_key;
use crate::trace::event::{PowerTrace, Stream, Trace, TraceEvent};
use crate::util::hash::FxHashMap;
use std::collections::BTreeMap;
use std::ops::Range;

/// Grouping key of one operation instance: (gpu, iter, op, layer, stream
/// tag). Identical to the old `aggregate::op_instances` `BTreeMap` key, so
/// sorting by it reproduces the old output order exactly.
type InstKey = (u32, u32, OpRef, Option<u32>, u8);

#[derive(Debug, Default)]
struct MetricsColumn {
    /// Parallel to `trace.events`: the derived metrics of each kernel, or
    /// `None` when no counter record matched.
    per_event: Vec<Option<DerivedMetrics>>,
    unmatched: usize,
}

/// Energy rollups joined from a [`PowerTrace`] (the power-management
/// subsystem's telemetry) — attached on demand like the counter column.
#[derive(Debug, Default)]
struct EnergyColumn {
    /// (gpu, iter) → joules (windows tagged by iteration at window start).
    per_gpu_iter: BTreeMap<(u32, u32), f64>,
    /// gpu → total joules.
    per_gpu: BTreeMap<u32, f64>,
    /// (phase, gpu) → joules attributed by proportional overlap of each
    /// power window with the gpu's per-(iter, phase) compute spans.
    per_phase: BTreeMap<(Phase, u32), f64>,
    total_j: f64,
}

/// Thermal rollups joined from a [`PowerTrace`] whose samples carry
/// thermal telemetry — attached by [`TraceIndex::attach_power`] alongside
/// the energy column, and only for thermal-enabled runs (`temp_c > 0`), so
/// thermal-disabled analysis paths stay untouched.
#[derive(Debug, Default)]
struct ThermalColumn {
    /// gpu → peak die temperature, °C.
    peak_temp: BTreeMap<u32, f64>,
    /// gpu → nanoseconds of clock capacity lost to throttling
    /// (`Σ window × (1 − throttle)`).
    loss_ns: BTreeMap<u32, f64>,
    peak_temp_c: f64,
    total_loss_ns: f64,
}

/// Per-request serving column (DESIGN.md §10), joined from the batcher's
/// [`RequestRecord`](crate::serve::RequestRecord)s against the index's own
/// per-step spans — attached on demand like the counter/energy columns.
/// All vectors are parallel, in request-id order.
#[derive(Debug, Default, Clone)]
pub struct RequestColumn {
    pub ids: Vec<u32>,
    /// Wall-clock span of each request on the device timeline:
    /// admit-step start → completion-step end (ns).
    pub span_ns: Vec<(f64, f64)>,
    /// Time to first token, ms.
    pub ttft_ms: Vec<f64>,
    /// Time per output token after the first, ms (0 for 1-token outputs).
    pub tpot_ms: Vec<f64>,
    /// End-to-end latency, ms.
    pub e2e_ms: Vec<f64>,
}

/// The shared analysis index. Borrows the trace — nothing is cloned.
#[derive(Debug)]
pub struct TraceIndex<'t> {
    pub trace: &'t Trace,
    /// Comm-occupancy intervals per GPU (the C3 overlap oracle).
    pub comm: CommIntervals,
    /// All operation instances, sorted by [`InstKey`].
    instances: Vec<OpInstanceAgg>,
    /// Stream tag of each instance (0 = compute, 1 = comm), parallel to
    /// `instances`.
    inst_stream: Vec<u8>,
    /// Instance indices re-sorted by op (stable), i.e. by
    /// (op, gpu, iter, layer, stream) — the per-operation partition.
    by_op: Vec<u32>,
    /// Contiguous range of each op inside `by_op`.
    op_ranges: BTreeMap<OpRef, Range<usize>>,
    /// Prefix sums of instance wall durations in `by_op` order:
    /// `dur_prefix[i+1] - dur_prefix[i] == instances[by_op[i]].duration()`.
    dur_prefix: Vec<f64>,
    /// Event indices per (gpu, stream), sorted by `t_start` (stable).
    lanes: BTreeMap<(u32, Stream), Vec<u32>>,
    /// Per-GPU compute-lane launch overheads in dispatch (seq) order:
    /// (event index, overhead) for every compute kernel with a
    /// predecessor, ParamCopy excluded (Section V-D1). Keyed by gpu id —
    /// imported traces may carry arbitrary (even huge) gpu ids, so no
    /// dense per-id storage anywhere in the index.
    launch: BTreeMap<u32, Vec<(usize, LaunchOverhead)>>,
    /// (gpu, iter) → (first start, last end) over compute events.
    iter_spans: BTreeMap<(u32, u32), (f64, f64)>,
    /// (gpu, iter) → summed compute-kernel duration.
    compute_ns: BTreeMap<(u32, u32), f64>,
    /// (gpu, iter) → summed launch overhead (all iterations).
    launch_ns: BTreeMap<(u32, u32), f64>,
    /// (phase, gpu, iter) → summed compute duration, sampled iters only.
    phase_dur: BTreeMap<(Phase, u32, u32), f64>,
    /// (phase, kind) → per-(gpu, iter) duration samples, sampled only.
    phase_kind_dur: BTreeMap<(Phase, OpKind), Vec<f64>>,
    /// (phase, kind) → per-(gpu, iter) launch-overhead samples, sampled.
    phase_kind_launch: BTreeMap<(Phase, OpKind), Vec<f64>>,
    /// (node, iter) → (first start, last end) over the node's compute
    /// events — the per-node rollup behind node-grouped figure rows.
    node_iter_spans: BTreeMap<(u32, u32), (f64, f64)>,
    /// (phase, node) → per-(gpu, iter) summed compute durations, sampled
    /// iters only, in (phase, gpu, iter) order.
    node_phase_dur: BTreeMap<(Phase, u32), Vec<f64>>,
    /// Comm-kernel durations per collective op, sampled iters, event order.
    comm_durs: BTreeMap<OpType, Vec<f64>>,
    /// kernel_id → event index; built with the metrics column (it only
    /// serves the counter joins, so counter-less builds skip it).
    id_idx: FxHashMap<u64, u32>,
    /// Counter-derived metrics column (attached on demand).
    metrics: Option<MetricsColumn>,
    /// Energy rollups from the power trace (attached on demand).
    energy: Option<EnergyColumn>,
    /// Thermal rollups from the power trace (attached with the energy
    /// column, thermal-enabled runs only).
    thermal: Option<ThermalColumn>,
    /// Per-request serving column (attached on demand, serving traces).
    requests: Option<RequestColumn>,
}

/// Incremental first pass of [`TraceIndex::build`]: the per-event
/// accumulators, fed one event at a time. The chunk-wise store reader
/// (`trace::store::for_each_chunk`) can drive this a chunk at a time while
/// the trace materializes, instead of re-walking a finished event vector;
/// [`TraceIndex::build`] itself is a feed-everything use of the same
/// builder, so both paths aggregate identically. Events must arrive in the
/// trace's canonical event order — `(t_start, kernel_id)` for engine and
/// store-read traces.
pub struct IndexBuilder {
    warmup: u32,
    next: u32,
    lanes: BTreeMap<(u32, Stream), Vec<u32>>,
    inst_map: FxHashMap<InstKey, u32>,
    instances: Vec<OpInstanceAgg>,
    inst_keys: Vec<InstKey>,
    iter_spans: BTreeMap<(u32, u32), (f64, f64)>,
    compute_ns: BTreeMap<(u32, u32), f64>,
    phase_dur: BTreeMap<(Phase, u32, u32), f64>,
    pk_dur: BTreeMap<(Phase, OpKind, u32, u32), f64>,
    comm_durs: BTreeMap<OpType, Vec<f64>>,
    /// Compute-lane event indices per gpu, ParamCopy excluded.
    launch_seq: BTreeMap<u32, Vec<u32>>,
}

impl IndexBuilder {
    pub fn new(warmup: u32) -> Self {
        IndexBuilder {
            warmup,
            next: 0,
            lanes: BTreeMap::new(),
            inst_map: FxHashMap::default(),
            instances: Vec::new(),
            inst_keys: Vec::new(),
            iter_spans: BTreeMap::new(),
            compute_ns: BTreeMap::new(),
            phase_dur: BTreeMap::new(),
            pk_dur: BTreeMap::new(),
            comm_durs: BTreeMap::new(),
            launch_seq: BTreeMap::new(),
        }
    }

    /// Events folded so far.
    pub fn events_seen(&self) -> u32 {
        self.next
    }

    /// Fold one event (the i-th pushed overall).
    pub fn push(&mut self, e: &TraceEvent) {
        let warmup = self.warmup;
        let i = self.next;
        self.next += 1;
        self.lanes.entry((e.gpu, e.stream)).or_default().push(i);

        let stream_tag = match e.stream {
            Stream::Compute => 0u8,
            Stream::Comm => 1,
        };
        let key = (e.gpu, e.iter, e.op, e.layer, stream_tag);
        let instances = &mut self.instances;
        let inst_keys = &mut self.inst_keys;
        let slot = *self.inst_map.entry(key).or_insert_with(|| {
            instances.push(OpInstanceAgg {
                gpu: e.gpu,
                iter: e.iter,
                op: e.op,
                layer: e.layer,
                t_start: f64::INFINITY,
                t_end: f64::NEG_INFINITY,
                kernel_ns: 0.0,
                kernels: 0,
                flops: 0.0,
                bytes: 0.0,
                kernel_ids: Vec::new(),
            });
            inst_keys.push(key);
            (instances.len() - 1) as u32
        });
        let inst = &mut self.instances[slot as usize];
        inst.t_start = inst.t_start.min(e.t_start);
        inst.t_end = inst.t_end.max(e.t_end);
        inst.kernel_ns += e.duration();
        inst.kernels += 1;
        inst.flops += e.flops;
        inst.bytes += e.bytes;
        inst.kernel_ids.push(e.kernel_id);

        match e.stream {
            Stream::Comm => {
                if e.iter >= warmup {
                    self.comm_durs.entry(e.op.op).or_default().push(e.duration());
                }
            }
            Stream::Compute => {
                let s = self
                    .iter_spans
                    .entry((e.gpu, e.iter))
                    .or_insert((f64::INFINITY, f64::NEG_INFINITY));
                s.0 = s.0.min(e.t_start);
                s.1 = s.1.max(e.t_end);
                *self.compute_ns.entry((e.gpu, e.iter)).or_insert(0.0) +=
                    e.duration();
                if e.iter >= warmup {
                    *self
                        .phase_dur
                        .entry((e.op.phase, e.gpu, e.iter))
                        .or_insert(0.0) += e.duration();
                    *self
                        .pk_dur
                        .entry((e.op.phase, e.kind(), e.gpu, e.iter))
                        .or_insert(0.0) += e.duration();
                }
                if e.op.op != OpType::ParamCopy {
                    self.launch_seq.entry(e.gpu).or_default().push(i);
                }
            }
        }
    }

    /// Finishing pass: per-bucket sorts and rollups that need the whole
    /// trace. `trace` must hold exactly the pushed events, in push order.
    pub fn finish<'t>(self, trace: &'t Trace) -> TraceIndex<'t> {
        let IndexBuilder {
            warmup,
            next: _,
            mut lanes,
            inst_map: _,
            instances,
            inst_keys,
            iter_spans,
            compute_ns,
            phase_dur,
            pk_dur,
            comm_durs,
            mut launch_seq,
        } = self;

        // Instance partition in the old BTreeMap-grouping order.
        let mut perm: Vec<u32> = (0..instances.len() as u32).collect();
        perm.sort_by_key(|&i| inst_keys[i as usize]);
        let mut slots: Vec<Option<OpInstanceAgg>> =
            instances.into_iter().map(Some).collect();
        let mut sorted = Vec::with_capacity(slots.len());
        let mut inst_stream = Vec::with_capacity(slots.len());
        for &i in &perm {
            sorted.push(slots[i as usize].take().expect("unique permutation"));
            inst_stream.push(inst_keys[i as usize].4);
        }
        let instances = sorted;

        // Per-op sub-partition: stable re-sort by op keeps the
        // (gpu, iter, layer, stream) order inside each op's range.
        let mut by_op: Vec<u32> = (0..instances.len() as u32).collect();
        by_op.sort_by_key(|&i| instances[i as usize].op);
        let mut op_ranges: BTreeMap<OpRef, Range<usize>> = BTreeMap::new();
        let mut dur_prefix = Vec::with_capacity(by_op.len() + 1);
        dur_prefix.push(0.0);
        let mut start = 0usize;
        for (pos, &i) in by_op.iter().enumerate() {
            let inst = &instances[i as usize];
            let total = dur_prefix[pos] + inst.duration();
            dur_prefix.push(total);
            let next_op = by_op
                .get(pos + 1)
                .map(|&j| instances[j as usize].op);
            if next_op != Some(inst.op) {
                op_ranges.insert(inst.op, start..pos + 1);
                start = pos + 1;
            }
        }

        // Lanes sorted by t_start (stable, so equal starts keep event
        // order — filtering a lane then equals filter-then-stable-sort).
        for v in lanes.values_mut() {
            v.sort_by(|&a, &b| {
                trace.events[a as usize]
                    .t_start
                    .total_cmp(&trace.events[b as usize].t_start)
            });
        }

        // Comm occupancy from the already-sorted comm lanes.
        let mut per_gpu: BTreeMap<u32, Vec<(f64, f64)>> = BTreeMap::new();
        for ((gpu, stream), v) in &lanes {
            if *stream != Stream::Comm {
                continue;
            }
            per_gpu.insert(
                *gpu,
                v.iter()
                    .map(|&i| {
                        let e = &trace.events[i as usize];
                        (e.t_start, e.t_end)
                    })
                    .collect(),
            );
        }
        let comm = CommIntervals::from_sorted(per_gpu);

        // Launch overheads per gpu, in dispatch order (Eqs. 1–3).
        let mut launch: BTreeMap<u32, Vec<(usize, LaunchOverhead)>> =
            BTreeMap::new();
        for (gpu, evs) in &mut launch_seq {
            evs.sort_by(|&a, &b| {
                trace.events[a as usize]
                    .seq
                    .cmp(&trace.events[b as usize].seq)
            });
            let mut out = Vec::with_capacity(evs.len().saturating_sub(1));
            for w in evs.windows(2) {
                let prev = &trace.events[w[0] as usize];
                let cur = &trace.events[w[1] as usize];
                out.push((w[1] as usize, launch_overhead(cur, prev.t_end)));
            }
            launch.insert(*gpu, out);
        }

        // Launch rollups iterate gpu 0..num_gpus like the pre-index code
        // (a trace with meta.num_gpus == 0 rolls up to nothing).
        let mut launch_ns: BTreeMap<(u32, u32), f64> = BTreeMap::new();
        let mut pk_launch: BTreeMap<(Phase, OpKind, u32, u32), f64> =
            BTreeMap::new();
        for gpu in 0..trace.meta.num_gpus {
            let Some(list) = launch.get(&gpu) else {
                continue;
            };
            for &(idx, o) in list {
                let e = &trace.events[idx];
                *launch_ns.entry((e.gpu, e.iter)).or_insert(0.0) += o.total();
                if e.iter >= warmup {
                    *pk_launch
                        .entry((e.op.phase, e.kind(), e.gpu, e.iter))
                        .or_insert(0.0) += o.total();
                }
            }
        }

        let mut phase_kind_dur: BTreeMap<(Phase, OpKind), Vec<f64>> =
            BTreeMap::new();
        for ((phase, kind, _, _), v) in pk_dur {
            phase_kind_dur.entry((phase, kind)).or_default().push(v);
        }
        let mut phase_kind_launch: BTreeMap<(Phase, OpKind), Vec<f64>> =
            BTreeMap::new();
        for ((phase, kind, _, _), v) in pk_launch {
            phase_kind_launch.entry((phase, kind)).or_default().push(v);
        }

        // Per-node rollups, folded from the per-GPU rollups above using
        // the trace's rank → node mapping (legacy traces fold to node 0).
        let mut node_iter_spans: BTreeMap<(u32, u32), (f64, f64)> =
            BTreeMap::new();
        for (&(gpu, iter), &(s, e)) in &iter_spans {
            let n = trace.meta.node_of(gpu);
            let v = node_iter_spans
                .entry((n, iter))
                .or_insert((f64::INFINITY, f64::NEG_INFINITY));
            v.0 = v.0.min(s);
            v.1 = v.1.max(e);
        }
        let mut node_phase_dur: BTreeMap<(Phase, u32), Vec<f64>> = BTreeMap::new();
        for (&(phase, gpu, _), &v) in &phase_dur {
            node_phase_dur
                .entry((phase, trace.meta.node_of(gpu)))
                .or_default()
                .push(v);
        }

        TraceIndex {
            trace,
            comm,
            instances,
            inst_stream,
            by_op,
            op_ranges,
            dur_prefix,
            lanes,
            launch,
            iter_spans,
            compute_ns,
            launch_ns,
            phase_dur,
            phase_kind_dur,
            phase_kind_launch,
            node_iter_spans,
            node_phase_dur,
            comm_durs,
            id_idx: FxHashMap::default(),
            metrics: None,
            energy: None,
            thermal: None,
            requests: None,
        }
    }
}

impl<'t> TraceIndex<'t> {
    /// Build the index: one pass over the events (an [`IndexBuilder`]
    /// fold), then per-bucket sorts.
    pub fn build(trace: &'t Trace) -> Self {
        let mut b = IndexBuilder::new(trace.meta.warmup);
        for e in &trace.events {
            b.push(e);
        }
        b.finish(trace)
    }

    /// Build and immediately attach the counter-derived metrics column.
    pub fn with_counters(trace: &'t Trace, counters: &CounterTrace) -> Self {
        let mut idx = Self::build(trace);
        idx.attach_counters(counters);
        idx
    }

    // -- instance partition ------------------------------------------------

    /// All operation instances, sorted by (gpu, iter, op, layer, stream).
    pub fn all_instances(&self) -> &[OpInstanceAgg] {
        &self.instances
    }

    /// Stream of instance `i` of [`all_instances`](Self::all_instances).
    pub fn instance_stream(&self, i: usize) -> Stream {
        if self.inst_stream[i] == 0 {
            Stream::Compute
        } else {
            Stream::Comm
        }
    }

    /// Instances matching `filter`, in the same order the old event-level
    /// grouping produced. An op-constrained filter touches only that op's
    /// sub-partition instead of scanning everything.
    pub fn instances(&self, filter: &Filter) -> Vec<&OpInstanceAgg> {
        let warmup = self.trace.meta.warmup;
        let mut out = Vec::new();
        match filter.op {
            Some(op) => {
                if let Some(r) = self.op_ranges.get(&op) {
                    for &i in &self.by_op[r.clone()] {
                        let inst = &self.instances[i as usize];
                        if filter.accepts_instance(inst, warmup) {
                            out.push(inst);
                        }
                    }
                }
            }
            None => {
                for inst in &self.instances {
                    if filter.accepts_instance(inst, warmup) {
                        out.push(inst);
                    }
                }
            }
        }
        out
    }

    /// Every distinct op present in the trace, ascending.
    pub fn ops(&self) -> impl Iterator<Item = OpRef> + '_ {
        self.op_ranges.keys().copied()
    }

    /// Total wall duration (ns) of every instance of `op` — O(1) via the
    /// duration prefix sums over the per-op partition.
    pub fn op_total_duration(&self, op: OpRef) -> f64 {
        match self.op_ranges.get(&op) {
            Some(r) => self.dur_prefix[r.end] - self.dur_prefix[r.start],
            None => 0.0,
        }
    }

    // -- lanes and launch --------------------------------------------------

    /// Event indices of one (gpu, stream) lane, sorted by `t_start`.
    pub fn lane(&self, gpu: u32, stream: Stream) -> &[u32] {
        self.lanes
            .get(&(gpu, stream))
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Launch overheads of one GPU's compute lane in dispatch order:
    /// (event index, overhead) per kernel with a predecessor.
    pub fn gpu_launch(&self, gpu: u32) -> &[(usize, LaunchOverhead)] {
        self.launch
            .get(&gpu)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    // -- precomputed rollups -----------------------------------------------

    /// (gpu, iter) → (first start, last end) over compute events.
    pub fn iter_spans(&self) -> &BTreeMap<(u32, u32), (f64, f64)> {
        &self.iter_spans
    }

    /// (gpu, iter) → summed compute-kernel duration (ns).
    pub fn compute_ns(&self) -> &BTreeMap<(u32, u32), f64> {
        &self.compute_ns
    }

    /// (gpu, iter) → summed launch overhead (ns), all iterations.
    pub fn launch_ns(&self) -> &BTreeMap<(u32, u32), f64> {
        &self.launch_ns
    }

    /// (phase, gpu, iter) → summed compute duration, sampled iters only.
    pub fn phase_dur(&self) -> &BTreeMap<(Phase, u32, u32), f64> {
        &self.phase_dur
    }

    /// (phase, kind) → per-(gpu, iter) duration samples, sampled only.
    pub fn phase_kind_dur(&self) -> &BTreeMap<(Phase, OpKind), Vec<f64>> {
        &self.phase_kind_dur
    }

    /// (phase, kind) → per-(gpu, iter) launch samples, sampled only.
    pub fn phase_kind_launch(&self) -> &BTreeMap<(Phase, OpKind), Vec<f64>> {
        &self.phase_kind_launch
    }

    // -- per-node rollups ---------------------------------------------------

    /// Nodes in the trace's topology (1 for legacy/single-node traces).
    pub fn num_nodes(&self) -> u32 {
        self.trace.meta.nodes()
    }

    /// Node hosting flat rank `gpu` (trace-metadata mapping).
    pub fn node_of(&self, gpu: u32) -> u32 {
        self.trace.meta.node_of(gpu)
    }

    /// (node, iter) → (first start, last end) over compute events.
    pub fn node_iter_spans(&self) -> &BTreeMap<(u32, u32), (f64, f64)> {
        &self.node_iter_spans
    }

    /// (phase, node) → per-(gpu, iter) summed compute durations, sampled
    /// iterations only.
    pub fn node_phase_dur(&self) -> &BTreeMap<(Phase, u32), Vec<f64>> {
        &self.node_phase_dur
    }

    /// Median per-iteration wall span of each node, sampled iterations
    /// only, in node order — the headline per-node rollup the campaign
    /// summaries and node-grouped figure rows report.
    pub fn node_iter_medians(&self) -> Vec<f64> {
        let warmup = self.trace.meta.warmup;
        let mut out = Vec::with_capacity(self.num_nodes() as usize);
        for n in 0..self.num_nodes() {
            let spans: Vec<f64> = self
                .node_iter_spans
                .range((n, 0)..(n + 1, 0))
                .filter(|((_, it), _)| *it >= warmup)
                .map(|(_, (s, e))| e - s)
                .collect();
            out.push(if spans.is_empty() {
                0.0
            } else {
                crate::util::stats::median(&spans)
            });
        }
        out
    }

    /// Sampled-iteration durations of one collective op, in event order.
    pub fn comm_durations(&self, op: OpType) -> &[f64] {
        self.comm_durs
            .get(&op)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Time ranks spent blocked on a slower peer inside collectives (ns),
    /// summed over sampled iterations. Comm events are grouped into
    /// synchronized collective instances — the engine gives every rank of
    /// one collective the same end time, so (end-time bits, op, layer,
    /// iter) identifies an instance — and each rank's duration in excess
    /// of the group's fastest rank counts as blocked. Healthy traces
    /// report a small nonzero value too (compute jitter skews arrival);
    /// campaign summaries surface it only for faulted runs, where a
    /// straggler or degraded link dominates the skew.
    pub fn blocked_on_straggler_ns(&self) -> f64 {
        let warmup = self.trace.meta.warmup;
        let mut groups: BTreeMap<(u64, OpRef, u32, u32), (f64, f64, u32)> =
            BTreeMap::new();
        for e in &self.trace.events {
            if e.stream != Stream::Comm || e.iter < warmup {
                continue;
            }
            let key =
                (e.t_end.to_bits(), e.op, e.layer.unwrap_or(u32::MAX), e.iter);
            let g = groups.entry(key).or_insert((f64::INFINITY, 0.0, 0));
            g.0 = g.0.min(e.duration());
            g.1 += e.duration();
            g.2 += 1;
        }
        groups
            .values()
            .map(|&(min, sum, n)| sum - n as f64 * min)
            .sum()
    }

    // -- energy rollups -----------------------------------------------------

    /// Join a [`PowerTrace`] onto the index: per-(gpu, iter) and per-GPU
    /// joule rollups (windows tagged by the iteration at window start) and
    /// a per-(phase, gpu) attribution by proportional overlap of each
    /// window with the GPU's per-iteration phase spans. Deterministic:
    /// accumulates in sample order, spans in `BTreeMap` order.
    pub fn attach_power(&mut self, power: &PowerTrace) {
        // Per-(gpu, iter, phase) compute spans, one scan over the events.
        let mut spans: BTreeMap<(u32, u32, Phase), (f64, f64)> = BTreeMap::new();
        for e in &self.trace.events {
            if e.stream != Stream::Compute {
                continue;
            }
            let s = spans
                .entry((e.gpu, e.iter, e.op.phase))
                .or_insert((f64::INFINITY, f64::NEG_INFINITY));
            s.0 = s.0.min(e.t_start);
            s.1 = s.1.max(e.t_end);
        }
        let mut per_gpu_spans: BTreeMap<u32, Vec<(Phase, f64, f64)>> =
            BTreeMap::new();
        for (&(gpu, _, phase), &(s, e)) in &spans {
            per_gpu_spans.entry(gpu).or_default().push((phase, s, e));
        }

        let mut col = EnergyColumn::default();
        for s in &power.samples {
            let e_j = s.energy_j();
            *col.per_gpu_iter.entry((s.gpu, s.iter)).or_insert(0.0) += e_j;
            *col.per_gpu.entry(s.gpu).or_insert(0.0) += e_j;
            col.total_j += e_j;
            let (w0, w1) = (s.t, s.t + s.window_ns);
            if let Some(sp) = per_gpu_spans.get(&s.gpu) {
                for &(phase, ps, pe) in sp {
                    let ov = w1.min(pe) - w0.max(ps);
                    if ov > 0.0 {
                        *col.per_phase.entry((phase, s.gpu)).or_insert(0.0) +=
                            e_j * ov / s.window_ns;
                    }
                }
            }
        }
        self.energy = Some(col);

        // Thermal rollups ride the same join, but only for traces that
        // actually carry thermal telemetry — disabled runs keep
        // `thermal: None` and every accessor below returns its default.
        if power.has_thermal() {
            let mut tc = ThermalColumn::default();
            for s in &power.samples {
                let peak = tc.peak_temp.entry(s.gpu).or_insert(0.0);
                *peak = peak.max(s.temp_c);
                tc.peak_temp_c = tc.peak_temp_c.max(s.temp_c);
                let loss = s.throttle_loss_ns();
                *tc.loss_ns.entry(s.gpu).or_insert(0.0) += loss;
                tc.total_loss_ns += loss;
            }
            self.thermal = Some(tc);
        }
    }

    pub fn has_energy(&self) -> bool {
        self.energy.is_some()
    }

    /// Total joules in the attached power trace (0 when none attached).
    pub fn total_energy_j(&self) -> f64 {
        self.energy.as_ref().map(|e| e.total_j).unwrap_or(0.0)
    }

    /// (gpu, iter) → joules; empty map when no power trace is attached.
    pub fn energy_per_gpu_iter(&self) -> BTreeMap<(u32, u32), f64> {
        self.energy
            .as_ref()
            .map(|e| e.per_gpu_iter.clone())
            .unwrap_or_default()
    }

    /// gpu → total joules.
    pub fn energy_per_gpu(&self) -> BTreeMap<u32, f64> {
        self.energy
            .as_ref()
            .map(|e| e.per_gpu.clone())
            .unwrap_or_default()
    }

    /// (phase, gpu) → joules attributed by window/phase-span overlap. The
    /// attribution is partial by construction: idle window time (and any
    /// time outside every phase span) stays unattributed, so summing this
    /// map yields **at most** `total_energy_j`.
    pub fn energy_by_phase(&self) -> BTreeMap<(Phase, u32), f64> {
        self.energy
            .as_ref()
            .map(|e| e.per_phase.clone())
            .unwrap_or_default()
    }

    // -- thermal rollups ----------------------------------------------------

    /// Whether the attached power trace carried thermal telemetry.
    pub fn has_thermal(&self) -> bool {
        self.thermal.is_some()
    }

    /// Peak die temperature across all GPUs, °C (0 when no thermal data).
    pub fn peak_temp_c(&self) -> f64 {
        self.thermal.as_ref().map(|t| t.peak_temp_c).unwrap_or(0.0)
    }

    /// gpu → peak die temperature, °C.
    pub fn peak_temp_by_gpu(&self) -> BTreeMap<u32, f64> {
        self.thermal
            .as_ref()
            .map(|t| t.peak_temp.clone())
            .unwrap_or_default()
    }

    /// gpu → nanoseconds of clock capacity lost to thermal throttling.
    pub fn throttle_loss_by_gpu(&self) -> BTreeMap<u32, f64> {
        self.thermal
            .as_ref()
            .map(|t| t.loss_ns.clone())
            .unwrap_or_default()
    }

    /// Total throttle loss across the cluster, ns (0 when no thermal data).
    pub fn total_throttle_loss_ns(&self) -> f64 {
        self.thermal.as_ref().map(|t| t.total_loss_ns).unwrap_or(0.0)
    }

    // -- serving request column --------------------------------------------

    /// Join the continuous batcher's per-request scheduling records against
    /// the trace's own per-step spans (serving steps are `iter`s), yielding
    /// the per-request TTFT / TPOT / e2e column. Works purely off the
    /// trace: the step bounds are the cluster-wide min start / max end of
    /// each step's events, so imported serving traces index identically to
    /// fresh runs.
    pub fn attach_requests(&mut self, records: &[crate::serve::RequestRecord]) {
        // Cluster-wide step bounds from the per-(gpu, iter) spans.
        let mut bounds: BTreeMap<u32, (f64, f64)> = BTreeMap::new();
        for (&(_gpu, iter), &(s, e)) in &self.iter_spans {
            let b = bounds.entry(iter).or_insert((f64::INFINITY, 0.0));
            b.0 = b.0.min(s);
            b.1 = b.1.max(e);
        }
        let end_of = |step: u32| bounds.get(&step).map(|b| b.1).unwrap_or(0.0);
        let start_of = |step: u32| bounds.get(&step).map(|b| b.0).unwrap_or(0.0);
        let mut col = RequestColumn::default();
        for r in records {
            let ttft_ns = end_of(r.first_token_step) - r.req.arrival_ns;
            let e2e_ns = end_of(r.completion_step) - r.req.arrival_ns;
            let tpot_ms = if r.req.output_tokens > 1 {
                (e2e_ns - ttft_ns) * 1e-6 / (r.req.output_tokens - 1) as f64
            } else {
                0.0
            };
            col.ids.push(r.req.id);
            col.span_ns
                .push((start_of(r.admit_step), end_of(r.completion_step)));
            col.ttft_ms.push(ttft_ns * 1e-6);
            col.tpot_ms.push(tpot_ms);
            col.e2e_ms.push(e2e_ns * 1e-6);
        }
        self.requests = Some(col);
    }

    /// The attached per-request column, if any.
    pub fn requests(&self) -> Option<&RequestColumn> {
        self.requests.as_ref()
    }

    // -- counter metrics column --------------------------------------------

    /// Join the hardware-counter trace onto the events (Section III-C1):
    /// one column entry per event. Returns the number of kernels with no
    /// matching counter record. The kernel-id join map is built here too —
    /// counter-less index builds never pay for it.
    pub fn attach_counters(&mut self, counters: &CounterTrace) -> usize {
        let nev = self.trace.events.len();
        let mut id_idx: FxHashMap<u64, u32> =
            FxHashMap::with_capacity_and_hasher(nev, Default::default());
        let mut per_event = Vec::with_capacity(nev);
        let mut unmatched = 0;
        for (i, e) in self.trace.events.iter().enumerate() {
            id_idx.insert(e.kernel_id, i as u32);
            match counters
                .get(e.gpu, align_key(e.stream, e.seq))
                .and_then(|v| DerivedMetrics::from_counters(v, e.duration()))
            {
                Some(m) => per_event.push(Some(m)),
                None => {
                    per_event.push(None);
                    unmatched += 1;
                }
            }
        }
        self.id_idx = id_idx;
        self.metrics = Some(MetricsColumn {
            per_event,
            unmatched,
        });
        unmatched
    }

    pub fn has_metrics(&self) -> bool {
        self.metrics.is_some()
    }

    /// Kernels that had no counter record (0 when no column is attached).
    pub fn unmatched(&self) -> usize {
        self.metrics.as_ref().map(|m| m.unmatched).unwrap_or(0)
    }

    /// Derived metrics of the event at `event_idx`, if aligned.
    pub fn metrics_at(&self, event_idx: usize) -> Option<&DerivedMetrics> {
        self.metrics.as_ref()?.per_event.get(event_idx)?.as_ref()
    }

    /// Derived metrics of a kernel by its id.
    pub fn metrics_by_id(&self, kernel_id: u64) -> Option<&DerivedMetrics> {
        let &i = self.id_idx.get(&kernel_id)?;
        self.metrics_at(i as usize)
    }

    /// Derived metrics of one event.
    pub fn metrics_of(&self, e: &TraceEvent) -> Option<&DerivedMetrics> {
        self.metrics_by_id(e.kernel_id)
    }

    /// Fraction of kernels with an aligned counter record. 1.0 for an
    /// empty trace (nothing to align).
    pub fn coverage(&self) -> f64 {
        let n = self.trace.events.len();
        if n == 0 {
            return 1.0;
        }
        (n - self.unmatched()) as f64 / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chopper::fixtures;
    use crate::config::FsdpVersion;
    use std::collections::BTreeMap;

    fn trace() -> &'static Trace {
        &fixtures::runtime(2, 2, 2, 1, FsdpVersion::V1).trace
    }

    #[test]
    fn instance_partition_matches_btreemap_grouping() {
        let t = trace();
        let idx = TraceIndex::build(t);
        // Reference: the pre-index event-order BTreeMap grouping.
        let mut map: BTreeMap<InstKey, (f64, f64, f64, u32)> = BTreeMap::new();
        for e in &t.events {
            let tag = match e.stream {
                Stream::Compute => 0u8,
                Stream::Comm => 1,
            };
            let k = (e.gpu, e.iter, e.op, e.layer, tag);
            let v = map.entry(k).or_insert((
                f64::INFINITY,
                f64::NEG_INFINITY,
                0.0,
                0,
            ));
            v.0 = v.0.min(e.t_start);
            v.1 = v.1.max(e.t_end);
            v.2 += e.duration();
            v.3 += 1;
        }
        assert_eq!(idx.all_instances().len(), map.len());
        for (inst, (k, v)) in idx.all_instances().iter().zip(map.iter()) {
            assert_eq!((inst.gpu, inst.iter, inst.op, inst.layer), (k.0, k.1, k.2, k.3));
            assert_eq!(inst.t_start.to_bits(), v.0.to_bits());
            assert_eq!(inst.t_end.to_bits(), v.1.to_bits());
            assert_eq!(inst.kernel_ns.to_bits(), v.2.to_bits());
            assert_eq!(inst.kernels, v.3);
        }
    }

    #[test]
    fn op_partition_equals_filtered_full_scan() {
        let t = trace();
        let idx = TraceIndex::build(t);
        for op in idx.ops().collect::<Vec<_>>() {
            let mut f = Filter::default();
            f.op = Some(op);
            let fast = idx.instances(&f);
            let slow: Vec<&OpInstanceAgg> = idx
                .all_instances()
                .iter()
                .filter(|i| i.op == op)
                .collect();
            assert_eq!(fast.len(), slow.len(), "{op}");
            for (a, b) in fast.iter().zip(&slow) {
                assert!(std::ptr::eq(*a, *b), "{op}: order diverged");
            }
        }
    }

    #[test]
    fn comm_intervals_match_from_trace() {
        let t = trace();
        let idx = TraceIndex::build(t);
        let direct = CommIntervals::from_trace(t);
        for gpu in 0..t.meta.num_gpus {
            for e in &t.events {
                let a = idx.comm.covered_ns(gpu, e.t_start, e.t_end);
                let b = direct.covered_ns(gpu, e.t_start, e.t_end);
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn launch_lane_matches_manual_recompute() {
        let t = trace();
        let idx = TraceIndex::build(t);
        for gpu in 0..t.meta.num_gpus {
            // Pre-index algorithm: filter, stable-sort by seq, window.
            let mut evs: Vec<(usize, &TraceEvent)> = t
                .events
                .iter()
                .enumerate()
                .filter(|(_, e)| {
                    e.gpu == gpu
                        && e.stream == Stream::Compute
                        && e.op.op != OpType::ParamCopy
                })
                .collect();
            evs.sort_by(|a, b| a.1.seq.cmp(&b.1.seq));
            let manual: Vec<(usize, LaunchOverhead)> = evs
                .windows(2)
                .map(|w| (w[1].0, launch_overhead(w[1].1, w[0].1.t_end)))
                .collect();
            assert_eq!(idx.gpu_launch(gpu), manual.as_slice(), "gpu {gpu}");
        }
    }

    #[test]
    fn lanes_are_sorted_and_complete() {
        let t = trace();
        let idx = TraceIndex::build(t);
        let mut total = 0;
        for gpu in 0..t.meta.num_gpus {
            for stream in [Stream::Compute, Stream::Comm] {
                let lane = idx.lane(gpu, stream);
                total += lane.len();
                for w in lane.windows(2) {
                    let a = &t.events[w[0] as usize];
                    let b = &t.events[w[1] as usize];
                    assert!(a.t_start <= b.t_start);
                    assert_eq!((a.gpu, a.stream), (gpu, stream));
                }
            }
        }
        assert_eq!(total, t.events.len());
    }

    #[test]
    fn prefix_sums_give_op_totals() {
        let t = trace();
        let idx = TraceIndex::build(t);
        for op in idx.ops().collect::<Vec<_>>() {
            let mut f = Filter::default();
            f.op = Some(op);
            let direct: f64 =
                idx.instances(&f).iter().map(|i| i.duration()).sum();
            assert!(
                (idx.op_total_duration(op) - direct).abs() < 1e-6,
                "{op}"
            );
        }
    }

    #[test]
    fn metrics_column_joins_every_kernel() {
        let cap = fixtures::runtime(2, 1, 1, 0, FsdpVersion::V1);
        let counters = fixtures::counters(2, 1, 1, 0, FsdpVersion::V1);
        let idx = TraceIndex::with_counters(&cap.trace, counters);
        assert!(idx.has_metrics());
        assert_eq!(idx.unmatched(), 0);
        assert!((idx.coverage() - 1.0).abs() < 1e-12);
        for e in &cap.trace.events {
            assert!(idx.metrics_of(e).is_some(), "{}", e.name);
        }
    }

    #[test]
    fn node_rollups_fold_per_gpu_rollups() {
        let t = trace();
        let idx = TraceIndex::build(t);
        // Single-node trace: node 0's rollups equal the fold over all gpus.
        assert_eq!(idx.num_nodes(), 1);
        for (&(n, iter), &(s, e)) in idx.node_iter_spans() {
            assert_eq!(n, 0);
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for (&(_, it), &(gs, ge)) in idx.iter_spans() {
                if it == iter {
                    lo = lo.min(gs);
                    hi = hi.max(ge);
                }
            }
            assert_eq!(s.to_bits(), lo.to_bits());
            assert_eq!(e.to_bits(), hi.to_bits());
        }
        let medians = idx.node_iter_medians();
        assert_eq!(medians.len(), 1);
        assert!(medians[0] > 0.0);
    }

    #[test]
    fn node_rollups_split_by_metadata_mapping() {
        // Relabel the 8-gpu trace as 2 nodes × 4 gpus: rollups split.
        let mut t = fixtures::runtime(2, 2, 2, 1, FsdpVersion::V1).trace.clone();
        t.meta.num_nodes = 2;
        t.meta.gpus_per_node = 4;
        let idx = TraceIndex::build(&t);
        assert_eq!(idx.num_nodes(), 2);
        assert_eq!(idx.node_of(3), 0);
        assert_eq!(idx.node_of(4), 1);
        let medians = idx.node_iter_medians();
        assert_eq!(medians.len(), 2);
        assert!(medians.iter().all(|&m| m > 0.0));
        // Per-phase rollups cover both nodes.
        use crate::model::ops::Phase;
        for n in 0..2 {
            assert!(idx
                .node_phase_dur()
                .contains_key(&(Phase::Forward, n)));
        }
    }

    #[test]
    fn energy_rollups_conserve_the_power_trace() {
        let cap = fixtures::runtime(2, 2, 2, 1, FsdpVersion::V1);
        let mut idx = TraceIndex::build(&cap.trace);
        assert!(!idx.has_energy());
        assert_eq!(idx.total_energy_j(), 0.0);
        idx.attach_power(&cap.power);
        assert!(idx.has_energy());
        let total = idx.total_energy_j();
        assert!(total > 0.0);
        assert!((total - cap.power.total_energy_j()).abs() <= total * 1e-12);
        // Per-gpu and per-(gpu, iter) rollups partition the total.
        let by_gpu: f64 = idx.energy_per_gpu().values().sum();
        let by_gi: f64 = idx.energy_per_gpu_iter().values().sum();
        assert!((by_gpu - total).abs() <= total * 1e-9);
        assert!((by_gi - total).abs() <= total * 1e-9);
        // Phase attribution is partial (idle windows stay unattributed)
        // but positive and bounded by the total.
        let by_phase: f64 = idx.energy_by_phase().values().sum();
        assert!(by_phase > 0.0);
        assert!(by_phase <= total * (1.0 + 1e-9), "{by_phase} > {total}");
    }

    #[test]
    fn blocked_on_straggler_is_finite_and_nonnegative() {
        let t = trace();
        let idx = TraceIndex::build(t);
        let blocked = idx.blocked_on_straggler_ns();
        assert!(blocked.is_finite());
        // Per-group (sum − n·min) is ≥ 0 by construction, so the total is.
        assert!(blocked >= 0.0, "{blocked}");
        // An empty trace reports zero blocked time.
        let empty = Trace::default();
        assert_eq!(TraceIndex::build(&empty).blocked_on_straggler_ns(), 0.0);
    }

    #[test]
    fn empty_trace_builds() {
        let t = Trace::default();
        let idx = TraceIndex::build(&t);
        assert!(idx.all_instances().is_empty());
        assert_eq!(idx.coverage(), 1.0);
        assert_eq!(idx.op_total_duration(OpRef::fwd(OpType::MlpUp)), 0.0);
    }
}
