//! Multi-granularity metric aggregation — the paper's Section III-D1.
//!
//! The hierarchy is kernel → operation → layer → phase → iteration → GPU →
//! workload. An *operation instance* is the set of kernels sharing
//! (gpu, iteration, op, layer); its duration includes the bubbles between
//! its kernels (Section V-B: "duration is defined as the sum of bubbles
//! between, and runtime of all spawned kernels corresponding to a given
//! operation"). A small filter struct constrains any aggregation to a
//! granularity slice (specific GPUs, iterations, op types, phases).

use crate::model::ops::{OpKind, OpRef, Phase};
use crate::trace::event::{Stream, Trace, TraceEvent};
use crate::util::stats;
use std::collections::BTreeMap;

/// One operation instance: kernels grouped by (gpu, iter, op, layer).
#[derive(Debug, Clone)]
pub struct OpInstanceAgg {
    pub gpu: u32,
    pub iter: u32,
    pub op: OpRef,
    pub layer: Option<u32>,
    pub t_start: f64,
    pub t_end: f64,
    /// Sum of kernel runtimes (excludes intra-op bubbles).
    pub kernel_ns: f64,
    pub kernels: u32,
    pub flops: f64,
    pub bytes: f64,
    /// kernel_ids of the member kernels (for metric joins).
    pub kernel_ids: Vec<u64>,
}

impl OpInstanceAgg {
    /// Wall duration including intra-op bubbles.
    pub fn duration(&self) -> f64 {
        self.t_end - self.t_start
    }

    pub fn bubble_ns(&self) -> f64 {
        (self.duration() - self.kernel_ns).max(0.0)
    }
}

/// Granularity filter: None = don't constrain that axis.
#[derive(Debug, Clone, Default)]
pub struct Filter {
    pub gpu: Option<u32>,
    pub iter: Option<u32>,
    pub phase: Option<Phase>,
    pub op: Option<OpRef>,
    pub kind: Option<OpKind>,
    pub layer: Option<u32>,
    /// Keep only sampled (post-warmup) iterations.
    pub sampled_only: bool,
}

impl Filter {
    pub fn sampled() -> Self {
        Filter {
            sampled_only: true,
            ..Default::default()
        }
    }

    pub fn accepts(&self, e: &TraceEvent, warmup: u32) -> bool {
        if self.sampled_only && e.iter < warmup {
            return false;
        }
        self.gpu.map(|g| e.gpu == g).unwrap_or(true)
            && self.iter.map(|i| e.iter == i).unwrap_or(true)
            && self.phase.map(|p| e.op.phase == p).unwrap_or(true)
            && self.op.map(|o| e.op == o).unwrap_or(true)
            && self.kind.map(|k| e.kind() == k).unwrap_or(true)
            && self.layer.map(|l| e.layer == Some(l)).unwrap_or(true)
    }
}

/// Group the compute kernels of a trace into operation instances.
/// Comm events become single-kernel instances of their collective op.
pub fn op_instances(trace: &Trace, filter: &Filter) -> Vec<OpInstanceAgg> {
    let warmup = trace.meta.warmup;
    let mut map: BTreeMap<(u32, u32, OpRef, Option<u32>, u8), OpInstanceAgg> =
        BTreeMap::new();
    for e in trace.events.iter() {
        if !filter.accepts(e, warmup) {
            continue;
        }
        let stream_tag = match e.stream {
            Stream::Compute => 0u8,
            Stream::Comm => 1,
        };
        let key = (e.gpu, e.iter, e.op, e.layer, stream_tag);
        let inst = map.entry(key).or_insert_with(|| OpInstanceAgg {
            gpu: e.gpu,
            iter: e.iter,
            op: e.op,
            layer: e.layer,
            t_start: f64::INFINITY,
            t_end: f64::NEG_INFINITY,
            kernel_ns: 0.0,
            kernels: 0,
            flops: 0.0,
            bytes: 0.0,
            kernel_ids: Vec::new(),
        });
        inst.t_start = inst.t_start.min(e.t_start);
        inst.t_end = inst.t_end.max(e.t_end);
        inst.kernel_ns += e.duration();
        inst.kernels += 1;
        inst.flops += e.flops;
        inst.bytes += e.bytes;
        inst.kernel_ids.push(e.kernel_id);
    }
    map.into_values().collect()
}

/// Fig-5-style samples: per (gpu, iter), the durations of all instances of
/// `op` summed across layers ("Duration is summed across layers and
/// includes bubbles between the kernels of each operation").
pub fn op_duration_samples(trace: &Trace, op: OpRef) -> Vec<f64> {
    let mut filter = Filter::sampled();
    filter.op = Some(op);
    let mut per: BTreeMap<(u32, u32), f64> = BTreeMap::new();
    for inst in op_instances(trace, &filter) {
        *per.entry((inst.gpu, inst.iter)).or_insert(0.0) += inst.duration();
    }
    per.into_values().collect()
}

/// Duration rollup per (phase, op-kind), summed over an iteration on one
/// GPU — the Fig-4 stacked-bar quantity. Returns samples across
/// (gpu, iteration) for median-taking.
pub fn phase_kind_duration_samples(
    trace: &Trace,
) -> BTreeMap<(Phase, OpKind), Vec<f64>> {
    let mut per: BTreeMap<(Phase, OpKind, u32, u32), f64> = BTreeMap::new();
    let warmup = trace.meta.warmup;
    for e in trace.events.iter().filter(|e| e.iter >= warmup) {
        if e.stream == Stream::Comm {
            continue; // comm kernels are not part of the compute breakdown
        }
        *per.entry((e.op.phase, e.kind(), e.gpu, e.iter)).or_insert(0.0) +=
            e.duration();
    }
    let mut out: BTreeMap<(Phase, OpKind), Vec<f64>> = BTreeMap::new();
    for ((phase, kind, _, _), v) in per {
        out.entry((phase, kind)).or_default().push(v);
    }
    out
}

/// Total duration of one full iteration per (gpu, iter): last end − first
/// start over compute events of that iteration.
pub fn iteration_spans(trace: &Trace) -> BTreeMap<(u32, u32), (f64, f64)> {
    let mut spans: BTreeMap<(u32, u32), (f64, f64)> = BTreeMap::new();
    for e in &trace.events {
        if e.stream == Stream::Comm {
            continue;
        }
        let s = spans
            .entry((e.gpu, e.iter))
            .or_insert((f64::INFINITY, f64::NEG_INFINITY));
        s.0 = s.0.min(e.t_start);
        s.1 = s.1.max(e.t_end);
    }
    spans
}

/// Median duration of each op across all sampled (gpu, iter, layer)
/// instances — the per-operation summary table.
pub fn op_medians(trace: &Trace) -> BTreeMap<OpRef, f64> {
    let mut by_op: BTreeMap<OpRef, Vec<f64>> = BTreeMap::new();
    for inst in op_instances(trace, &Filter::sampled()) {
        by_op.entry(inst.op).or_default().push(inst.duration());
    }
    by_op
        .into_iter()
        .map(|(op, v)| (op, stats::median(&v)))
        .collect()
}

/// Conservation check used by property tests: at every granularity, the
/// sum of kernel durations of the children equals the parent's.
pub fn kernel_time_by<K: Ord>(
    trace: &Trace,
    filter: &Filter,
    key: impl Fn(&TraceEvent) -> K,
) -> BTreeMap<K, f64> {
    let warmup = trace.meta.warmup;
    let mut out = BTreeMap::new();
    for e in &trace.events {
        if filter.accepts(e, warmup) {
            *out.entry(key(e)).or_insert(0.0) += e.duration();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::*;
    use crate::model::ops::OpType;
    use crate::trace::collect::RuntimeProfiler;

    fn trace() -> Trace {
        let mut cfg = ModelConfig::llama3_8b();
        cfg.layers = 2;
        let mut wl = WorkloadConfig::new(1, 4096, FsdpVersion::V1);
        wl.iterations = 2;
        wl.warmup = 1;
        RuntimeProfiler::new(NodeSpec::mi300x_node())
            .capture(&cfg, &wl)
            .trace
    }

    #[test]
    fn instances_group_kernels_of_one_op() {
        let t = trace();
        let mut f = Filter::sampled();
        f.op = Some(OpRef::bwd(OpType::AttnFa));
        let insts = op_instances(&t, &f);
        // 8 gpus × 1 sampled iter × 2 layers
        assert_eq!(insts.len(), 16);
        for i in &insts {
            assert_eq!(i.kernels, 3, "FA backward is a 3-kernel op");
            assert!(i.duration() >= i.kernel_ns - 1e-6);
        }
    }

    #[test]
    fn duration_includes_bubbles() {
        // Needs enough layers that the optimizer's per-kernel host work
        // exceeds the (shard-size-dependent) kernel durations.
        let mut cfg = ModelConfig::llama3_8b();
        cfg.layers = 8;
        let mut wl = WorkloadConfig::new(1, 4096, FsdpVersion::V1);
        wl.iterations = 2;
        wl.warmup = 1;
        let t = RuntimeProfiler::new(NodeSpec::mi300x_node())
            .capture(&cfg, &wl)
            .trace;
        let mut f = Filter::sampled();
        f.op = Some(OpRef::new(OpType::OptStep, Phase::Optimizer));
        let insts = op_instances(&t, &f);
        assert!(!insts.is_empty());
        // opt_step under FSDPv1 has host gaps between kernels -> bubbles.
        let with_bubbles = insts.iter().filter(|i| i.bubble_ns() > 0.0).count();
        assert!(with_bubbles > 0, "opt_step should show bubbles under v1");
    }

    #[test]
    fn filter_slices_by_gpu_and_phase() {
        let t = trace();
        let mut f = Filter::sampled();
        f.gpu = Some(3);
        f.phase = Some(Phase::Forward);
        let insts = op_instances(&t, &f);
        assert!(insts.iter().all(|i| i.gpu == 3));
        assert!(insts.iter().all(|i| i.op.phase == Phase::Forward));
    }

    #[test]
    fn conservation_kernel_time() {
        // Sum over per-op groups == total over the same filter.
        let t = trace();
        let f = Filter::sampled();
        let by_op = kernel_time_by(&t, &f, |e| e.op);
        let total: f64 = by_op.values().sum();
        let direct: f64 = t
            .events
            .iter()
            .filter(|e| e.iter >= t.meta.warmup)
            .map(|e| e.duration())
            .sum();
        assert!((total - direct).abs() < 1e-3);
    }

    #[test]
    fn fig5_samples_sum_layers() {
        let t = trace();
        let samples = op_duration_samples(&t, OpRef::fwd(OpType::MlpUp));
        // one per (gpu, sampled iter) = 8
        assert_eq!(samples.len(), 8);
        assert!(samples.iter().all(|&d| d > 0.0));
    }

    #[test]
    fn phase_kind_rollup_covers_all_phases() {
        let t = trace();
        let m = phase_kind_duration_samples(&t);
        assert!(m.contains_key(&(Phase::Forward, OpKind::Gemm)));
        assert!(m.contains_key(&(Phase::Backward, OpKind::FlashAttn)));
        assert!(m.contains_key(&(Phase::Optimizer, OpKind::Vector)));
        // Samples: 8 gpus × 1 sampled iteration.
        assert_eq!(m[&(Phase::Forward, OpKind::Gemm)].len(), 8);
    }

    #[test]
    fn iteration_spans_cover_every_gpu() {
        let t = trace();
        let spans = iteration_spans(&t);
        assert_eq!(spans.len(), 8 * 2);
        for ((_, _), (s, e)) in &spans {
            assert!(e > s);
        }
    }

    #[test]
    fn op_medians_nonempty_and_positive() {
        let t = trace();
        let m = op_medians(&t);
        assert!(m.len() > 20);
        assert!(m.values().all(|&d| d > 0.0));
    }
}
