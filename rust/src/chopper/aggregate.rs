//! Multi-granularity metric aggregation — the paper's Section III-D1.
//!
//! The hierarchy is kernel → operation → layer → phase → iteration → GPU →
//! workload. An *operation instance* is the set of kernels sharing
//! (gpu, iteration, op, layer); its duration includes the bubbles between
//! its kernels (Section V-B: "duration is defined as the sum of bubbles
//! between, and runtime of all spawned kernels corresponding to a given
//! operation"). A small filter struct constrains any aggregation to a
//! granularity slice (specific GPUs, iterations, op types, phases).
//!
//! All aggregations are queries over the shared [`TraceIndex`] — the
//! instance partition and the rollups are computed once per trace and
//! borrowed here, never recomputed per call (see DESIGN.md §7).

use crate::chopper::index::TraceIndex;
use crate::model::ops::{OpKind, OpRef, Phase};
use crate::trace::event::{Trace, TraceEvent};
use crate::util::stats;
use std::collections::BTreeMap;

/// One operation instance: kernels grouped by (gpu, iter, op, layer).
#[derive(Debug, Clone)]
pub struct OpInstanceAgg {
    pub gpu: u32,
    pub iter: u32,
    pub op: OpRef,
    pub layer: Option<u32>,
    pub t_start: f64,
    pub t_end: f64,
    /// Sum of kernel runtimes (excludes intra-op bubbles).
    pub kernel_ns: f64,
    pub kernels: u32,
    pub flops: f64,
    pub bytes: f64,
    /// kernel_ids of the member kernels (for metric joins).
    pub kernel_ids: Vec<u64>,
}

impl OpInstanceAgg {
    /// Wall duration including intra-op bubbles.
    pub fn duration(&self) -> f64 {
        self.t_end - self.t_start
    }

    pub fn bubble_ns(&self) -> f64 {
        (self.duration() - self.kernel_ns).max(0.0)
    }
}

/// Granularity filter: None = don't constrain that axis.
#[derive(Debug, Clone, Default)]
pub struct Filter {
    pub gpu: Option<u32>,
    pub iter: Option<u32>,
    pub phase: Option<Phase>,
    pub op: Option<OpRef>,
    pub kind: Option<OpKind>,
    pub layer: Option<u32>,
    /// Keep only sampled (post-warmup) iterations.
    pub sampled_only: bool,
}

impl Filter {
    pub fn sampled() -> Self {
        Filter {
            sampled_only: true,
            ..Default::default()
        }
    }

    pub fn accepts(&self, e: &TraceEvent, warmup: u32) -> bool {
        if self.sampled_only && e.iter < warmup {
            return false;
        }
        self.gpu.map(|g| e.gpu == g).unwrap_or(true)
            && self.iter.map(|i| e.iter == i).unwrap_or(true)
            && self.phase.map(|p| e.op.phase == p).unwrap_or(true)
            && self.op.map(|o| e.op == o).unwrap_or(true)
            && self.kind.map(|k| e.kind() == k).unwrap_or(true)
            && self.layer.map(|l| e.layer == Some(l)).unwrap_or(true)
    }

    /// Instance-level acceptance. Every filter axis is a function of the
    /// instance grouping key, so an instance either contains only accepted
    /// events or only rejected ones — filtering the precomputed partition
    /// is exactly equivalent to filtering events before grouping.
    pub fn accepts_instance(&self, inst: &OpInstanceAgg, warmup: u32) -> bool {
        if self.sampled_only && inst.iter < warmup {
            return false;
        }
        self.gpu.map(|g| inst.gpu == g).unwrap_or(true)
            && self.iter.map(|i| inst.iter == i).unwrap_or(true)
            && self.phase.map(|p| inst.op.phase == p).unwrap_or(true)
            && self.op.map(|o| inst.op == o).unwrap_or(true)
            && self.kind.map(|k| inst.op.op.kind() == k).unwrap_or(true)
            && self.layer.map(|l| inst.layer == Some(l)).unwrap_or(true)
    }
}

/// The operation instances matching `filter`, borrowed from the index's
/// precomputed partition (comm events are single-kernel instances of their
/// collective op, exactly as before).
pub fn op_instances<'i>(
    idx: &'i TraceIndex,
    filter: &Filter,
) -> Vec<&'i OpInstanceAgg> {
    idx.instances(filter)
}

/// Fig-5-style samples: per (gpu, iter), the durations of all instances of
/// `op` summed across layers ("Duration is summed across layers and
/// includes bubbles between the kernels of each operation").
pub fn op_duration_samples(idx: &TraceIndex, op: OpRef) -> Vec<f64> {
    let mut filter = Filter::sampled();
    filter.op = Some(op);
    let mut per: BTreeMap<(u32, u32), f64> = BTreeMap::new();
    for inst in idx.instances(&filter) {
        *per.entry((inst.gpu, inst.iter)).or_insert(0.0) += inst.duration();
    }
    per.into_values().collect()
}

/// Duration rollup per (phase, op-kind), summed over an iteration on one
/// GPU — the Fig-4 stacked-bar quantity. Samples across (gpu, iteration)
/// for median-taking, precomputed by the index.
pub fn phase_kind_duration_samples<'i>(
    idx: &'i TraceIndex,
) -> &'i BTreeMap<(Phase, OpKind), Vec<f64>> {
    idx.phase_kind_dur()
}

/// Total duration of one full iteration per (gpu, iter): last end − first
/// start over compute events of that iteration.
pub fn iteration_spans<'i>(
    idx: &'i TraceIndex,
) -> &'i BTreeMap<(u32, u32), (f64, f64)> {
    idx.iter_spans()
}

/// Median duration of each op across all sampled (gpu, iter, layer)
/// instances — the per-operation summary table.
pub fn op_medians(idx: &TraceIndex) -> BTreeMap<OpRef, f64> {
    let mut by_op: BTreeMap<OpRef, Vec<f64>> = BTreeMap::new();
    for inst in idx.instances(&Filter::sampled()) {
        by_op.entry(inst.op).or_default().push(inst.duration());
    }
    by_op
        .into_iter()
        .map(|(op, v)| (op, stats::median(&v)))
        .collect()
}

/// Conservation check used by property tests: at every granularity, the
/// sum of kernel durations of the children equals the parent's. This is
/// the one aggregation that deliberately reads the raw events — it is the
/// oracle the index is cross-checked against, so it must not consume it.
pub fn kernel_time_by<K: Ord>(
    trace: &Trace,
    filter: &Filter,
    key: impl Fn(&TraceEvent) -> K,
) -> BTreeMap<K, f64> {
    let warmup = trace.meta.warmup;
    let mut out = BTreeMap::new();
    for e in &trace.events {
        if filter.accepts(e, warmup) {
            *out.entry(key(e)).or_insert(0.0) += e.duration();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chopper::fixtures;
    use crate::config::*;
    use crate::model::ops::OpType;

    fn idx() -> TraceIndex<'static> {
        TraceIndex::build(&fixtures::runtime(2, 1, 2, 1, FsdpVersion::V1).trace)
    }

    #[test]
    fn instances_group_kernels_of_one_op() {
        let idx = idx();
        let mut f = Filter::sampled();
        f.op = Some(OpRef::bwd(OpType::AttnFa));
        let insts = op_instances(&idx, &f);
        // 8 gpus × 1 sampled iter × 2 layers
        assert_eq!(insts.len(), 16);
        for i in &insts {
            assert_eq!(i.kernels, 3, "FA backward is a 3-kernel op");
            assert!(i.duration() >= i.kernel_ns - 1e-6);
        }
    }

    #[test]
    fn duration_includes_bubbles() {
        // Needs enough layers that the optimizer's per-kernel host work
        // exceeds the (shard-size-dependent) kernel durations.
        let cap = fixtures::runtime(8, 1, 2, 1, FsdpVersion::V1);
        let idx = TraceIndex::build(&cap.trace);
        let mut f = Filter::sampled();
        f.op = Some(OpRef::new(OpType::OptStep, Phase::Optimizer));
        let insts = op_instances(&idx, &f);
        assert!(!insts.is_empty());
        // opt_step under FSDPv1 has host gaps between kernels -> bubbles.
        let with_bubbles = insts.iter().filter(|i| i.bubble_ns() > 0.0).count();
        assert!(with_bubbles > 0, "opt_step should show bubbles under v1");
    }

    #[test]
    fn filter_slices_by_gpu_and_phase() {
        let idx = idx();
        let mut f = Filter::sampled();
        f.gpu = Some(3);
        f.phase = Some(Phase::Forward);
        let insts = op_instances(&idx, &f);
        assert!(insts.iter().all(|i| i.gpu == 3));
        assert!(insts.iter().all(|i| i.op.phase == Phase::Forward));
    }

    #[test]
    fn conservation_kernel_time() {
        // Sum over per-op groups == total over the same filter.
        let t = &fixtures::runtime(2, 1, 2, 1, FsdpVersion::V1).trace;
        let f = Filter::sampled();
        let by_op = kernel_time_by(t, &f, |e| e.op);
        let total: f64 = by_op.values().sum();
        let direct: f64 = t
            .events
            .iter()
            .filter(|e| e.iter >= t.meta.warmup)
            .map(|e| e.duration())
            .sum();
        assert!((total - direct).abs() < 1e-3);
    }

    #[test]
    fn fig5_samples_sum_layers() {
        let idx = idx();
        let samples = op_duration_samples(&idx, OpRef::fwd(OpType::MlpUp));
        // one per (gpu, sampled iter) = 8
        assert_eq!(samples.len(), 8);
        assert!(samples.iter().all(|&d| d > 0.0));
    }

    #[test]
    fn phase_kind_rollup_covers_all_phases() {
        let idx = idx();
        let m = phase_kind_duration_samples(&idx);
        assert!(m.contains_key(&(Phase::Forward, OpKind::Gemm)));
        assert!(m.contains_key(&(Phase::Backward, OpKind::FlashAttn)));
        assert!(m.contains_key(&(Phase::Optimizer, OpKind::Vector)));
        // Samples: 8 gpus × 1 sampled iteration.
        assert_eq!(m[&(Phase::Forward, OpKind::Gemm)].len(), 8);
    }

    #[test]
    fn iteration_spans_cover_every_gpu() {
        let idx = idx();
        let spans = iteration_spans(&idx);
        assert_eq!(spans.len(), 8 * 2);
        for ((_, _), (s, e)) in spans.iter() {
            assert!(e > s);
        }
    }

    #[test]
    fn op_medians_nonempty_and_positive() {
        let idx = idx();
        let m = op_medians(&idx);
        assert!(m.len() > 20);
        assert!(m.values().all(|&d| d > 0.0));
    }
}
