//! C3 overlap analysis — the paper's Section V-C.
//!
//! The overlap ratio of a compute operation instance is the fraction of its
//! wall duration during which a communication kernel was resident on the
//! same GPU. Variation in overlap across GPUs explains variation in
//! duration (Insight 3); identical operations with different overlap have
//! different durations (Observation 4).
//!
//! The merged comm-occupancy intervals live on the shared [`TraceIndex`]
//! (built once per trace); the queries here borrow them instead of
//! re-deriving the interval set per call like the pre-index code did.

use crate::chopper::aggregate::{Filter, OpInstanceAgg};
use crate::chopper::index::TraceIndex;
use crate::model::ops::OpRef;
use crate::trace::event::{Stream, Trace};
use crate::util::stats;
use std::collections::BTreeMap;

/// Sorted, merged comm-occupancy intervals per GPU.
#[derive(Debug, Clone, Default)]
pub struct CommIntervals {
    /// gpu → sorted non-overlapping (start, end).
    per_gpu: BTreeMap<u32, Vec<(f64, f64)>>,
}

impl CommIntervals {
    pub fn from_trace(trace: &Trace) -> Self {
        let mut per_gpu: BTreeMap<u32, Vec<(f64, f64)>> = BTreeMap::new();
        for e in trace.events.iter().filter(|e| e.stream == Stream::Comm) {
            per_gpu
                .entry(e.gpu)
                .or_default()
                .push((e.t_start, e.t_end));
        }
        for v in per_gpu.values_mut() {
            v.sort_by(|a, b| a.0.total_cmp(&b.0));
        }
        Self::from_sorted(per_gpu)
    }

    /// Build from per-GPU interval lists already sorted by start — the
    /// index hands its sorted comm lanes straight in, skipping the
    /// event-scan + re-sort of [`from_trace`](Self::from_trace).
    pub(crate) fn from_sorted(per_gpu: BTreeMap<u32, Vec<(f64, f64)>>) -> Self {
        let mut out: BTreeMap<u32, Vec<(f64, f64)>> = BTreeMap::new();
        for (gpu, v) in per_gpu {
            // Merge overlapping/adjacent intervals.
            let mut merged: Vec<(f64, f64)> = Vec::with_capacity(v.len());
            for (s, e) in v {
                match merged.last_mut() {
                    Some(last) if s <= last.1 => last.1 = last.1.max(e),
                    _ => merged.push((s, e)),
                }
            }
            out.insert(gpu, merged);
        }
        Self { per_gpu: out }
    }

    /// Nanoseconds of [s, e) covered by comm activity on `gpu`.
    /// Binary-searches the merged interval list.
    pub fn covered_ns(&self, gpu: u32, s: f64, e: f64) -> f64 {
        let Some(iv) = self.per_gpu.get(&gpu) else {
            return 0.0;
        };
        // First interval that could intersect: last with start <= e.
        let start_idx = iv.partition_point(|&(_, end)| end <= s);
        let mut acc = 0.0;
        for &(is, ie) in &iv[start_idx..] {
            if is >= e {
                break;
            }
            let lo = is.max(s);
            let hi = ie.min(e);
            if hi > lo {
                acc += hi - lo;
            }
        }
        acc
    }

    /// Overlap ratio of an interval in [0, 1].
    pub fn ratio(&self, gpu: u32, s: f64, e: f64) -> f64 {
        if e <= s {
            return 0.0;
        }
        (self.covered_ns(gpu, s, e) / (e - s)).clamp(0.0, 1.0)
    }
}

/// One (instance, overlap-ratio) observation. Borrows the instance from
/// the index's partition — no per-sample clone.
#[derive(Debug, Clone)]
pub struct OverlapSample<'a> {
    pub inst: &'a OpInstanceAgg,
    pub ratio: f64,
}

/// Overlap ratio of every compute instance matching `filter`.
pub fn overlap_samples<'i>(
    idx: &'i TraceIndex,
    filter: &Filter,
) -> Vec<OverlapSample<'i>> {
    idx.instances(filter)
        .into_iter()
        .filter(|i| !i.op.op.is_comm())
        .map(|inst| {
            let ratio = idx.comm.ratio(inst.gpu, inst.t_start, inst.t_end);
            OverlapSample { inst, ratio }
        })
        .collect()
}

/// Per-op overlap/duration summary (Fig. 7 rows): quantiles of the overlap
/// ratio, quantiles of duration, and the Pearson correlation between them.
#[derive(Debug, Clone)]
pub struct OpOverlapSummary {
    pub op: OpRef,
    pub n: usize,
    pub ratio_q: [f64; 5],    // min, q25, median, q75, max
    pub duration_q: [f64; 5], // min, q25, median, q75, max
    /// Pearson correlation between overlap ratio and duration; None when
    /// either side is constant (the paper's "nan" cells).
    pub correlation: Option<f64>,
}

pub fn summarize_op_overlap(idx: &TraceIndex, op: OpRef) -> OpOverlapSummary {
    let mut f = Filter::sampled();
    f.op = Some(op);
    let samples = overlap_samples(idx, &f);
    let ratios: Vec<f64> = samples.iter().map(|s| s.ratio).collect();
    let durs: Vec<f64> = samples.iter().map(|s| s.inst.duration()).collect();
    let q = |xs: &[f64]| {
        [
            stats::min(xs),
            stats::quantile(xs, 0.25),
            stats::median(xs),
            stats::quantile(xs, 0.75),
            stats::max(xs),
        ]
    };
    OpOverlapSummary {
        op,
        n: samples.len(),
        ratio_q: q(&ratios),
        duration_q: q(&durs),
        correlation: stats::pearson(&ratios, &durs),
    }
}

/// Per-GPU (overlap ratio, duration) pairs for one op — Fig. 8's CDFs.
/// Durations are normalized to the per-GPU minimum like the paper.
pub fn per_gpu_overlap_cdf(
    idx: &TraceIndex,
    op: OpRef,
) -> BTreeMap<u32, Vec<(f64, f64)>> {
    let mut f = Filter::sampled();
    f.op = Some(op);
    let samples = overlap_samples(idx, &f);
    let mut per: BTreeMap<u32, Vec<(f64, f64)>> = BTreeMap::new();
    for s in samples {
        per.entry(s.inst.gpu)
            .or_default()
            .push((s.ratio, s.inst.duration()));
    }
    for v in per.values_mut() {
        let dmin = v
            .iter()
            .map(|(_, d)| *d)
            .fold(f64::INFINITY, f64::min)
            .max(1e-9);
        for p in v.iter_mut() {
            p.1 /= dmin;
        }
        v.sort_by(|a, b| a.0.total_cmp(&b.0));
    }
    per
}

/// Interpolated duration at a target overlap ratio, from the sorted
/// (ratio, duration) profile — the D_x% of Eq. 9. Falls back to the edge
/// values when the target lies outside the observed overlap range.
pub fn duration_at_overlap(samples: &[(f64, f64)], target: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.0.total_cmp(&b.0));
    if target <= sorted[0].0 {
        // Mean duration of the lowest-overlap decile.
        let k = (sorted.len() / 10).max(1);
        return stats::mean(&sorted[..k].iter().map(|p| p.1).collect::<Vec<_>>());
    }
    if target >= sorted[sorted.len() - 1].0 {
        let k = (sorted.len() / 10).max(1);
        let tail = &sorted[sorted.len() - k..];
        return stats::mean(&tail.iter().map(|p| p.1).collect::<Vec<_>>());
    }
    // Linear interpolation between bracketing samples.
    for w in sorted.windows(2) {
        let (r0, d0) = w[0];
        let (r1, d1) = w[1];
        if r0 <= target && target <= r1 {
            if (r1 - r0).abs() < 1e-12 {
                return 0.5 * (d0 + d1);
            }
            let t = (target - r0) / (r1 - r0);
            return d0 + t * (d1 - d0);
        }
    }
    sorted[sorted.len() - 1].1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chopper::fixtures;
    use crate::config::*;
    use crate::model::ops::{OpType, Phase};

    fn idx(layers: u64) -> TraceIndex<'static> {
        TraceIndex::build(&fixtures::runtime(layers, 2, 2, 1, FsdpVersion::V1).trace)
    }

    #[test]
    fn interval_coverage_math() {
        let mut per = BTreeMap::new();
        per.insert(0u32, vec![(10.0, 20.0), (30.0, 40.0)]);
        let c = CommIntervals::from_sorted(per);
        assert_eq!(c.covered_ns(0, 0.0, 50.0), 20.0);
        assert_eq!(c.covered_ns(0, 15.0, 35.0), 10.0);
        assert_eq!(c.covered_ns(0, 20.0, 30.0), 0.0);
        assert_eq!(c.ratio(0, 10.0, 20.0), 1.0);
        assert_eq!(c.ratio(1, 10.0, 20.0), 0.0);
    }

    #[test]
    fn merging_handles_overlapping_comm_events() {
        let mut t = Trace::default();
        use crate::trace::event::TraceEvent;
        for (s, e) in [(0.0, 10.0), (5.0, 15.0), (14.0, 20.0)] {
            t.events.push(TraceEvent {
                kernel_id: 0,
                gpu: 0,
                stream: Stream::Comm,
                name: "rccl".into(),
                op: OpRef::fwd(OpType::AllGather),
                layer: None,
                iter: 0,
                t_launch: s,
                t_start: s,
                t_end: e,
                seq: 0,
                fwd_link: None,
                freq_mhz: 0.0,
                flops: 0.0,
                bytes: 0.0,
            });
        }
        let c = CommIntervals::from_trace(&t);
        assert_eq!(c.covered_ns(0, 0.0, 20.0), 20.0);
    }

    #[test]
    fn ratios_are_in_unit_interval() {
        let idx = idx(2);
        for s in overlap_samples(&idx, &Filter::sampled()) {
            assert!((0.0..=1.0).contains(&s.ratio), "{}", s.ratio);
        }
    }

    #[test]
    fn overlap_exists_and_varies() {
        let idx = idx(4);
        let samples = overlap_samples(&idx, &Filter::sampled());
        let overlapped = samples.iter().filter(|s| s.ratio > 0.5).count();
        let clear = samples.iter().filter(|s| s.ratio < 0.05).count();
        assert!(overlapped > 0, "nothing overlapped");
        assert!(clear > 0, "everything overlapped");
    }

    #[test]
    fn summary_has_correlation_for_varying_ops() {
        let idx = idx(4);
        let s = summarize_op_overlap(&idx, OpRef::bwd(OpType::MlpUp));
        assert!(s.n > 0);
        assert!(s.ratio_q[0] <= s.ratio_q[4]);
        assert!(s.duration_q[0] <= s.duration_q[4]);
    }

    #[test]
    fn fig8_cdf_normalizes_per_gpu() {
        let idx = idx(4);
        let per = per_gpu_overlap_cdf(&idx, OpRef::fwd(OpType::AttnOp));
        assert_eq!(per.len(), 8);
        for v in per.values() {
            let dmin = v.iter().map(|(_, d)| *d).fold(f64::INFINITY, f64::min);
            assert!((dmin - 1.0).abs() < 1e-9, "normalized min must be 1.0");
        }
    }

    #[test]
    fn duration_at_overlap_interpolates() {
        let samples = vec![(0.0, 100.0), (1.0, 200.0)];
        let d = duration_at_overlap(&samples, 0.5);
        assert!((d - 150.0).abs() < 1e-9);
        // Edges.
        assert!((duration_at_overlap(&samples, -0.1) - 100.0).abs() < 1e-9);
        assert!((duration_at_overlap(&samples, 1.5) - 200.0).abs() < 1e-9);
        assert!(duration_at_overlap(&[], 0.5).is_nan());
    }

    #[test]
    fn identical_vec_ops_differ_by_overlap() {
        // Observation 4: b_attn_n vs b_mlp_n — identical computation,
        // different overlap, different duration.
        let idx = idx(8);
        let attn = summarize_op_overlap(&idx, OpRef::bwd(OpType::AttnN));
        let mlp = summarize_op_overlap(&idx, OpRef::bwd(OpType::MlpN));
        // attn_n (last op of a backward layer, next to the RS/AG window)
        // sees more overlap than mlp_n.
        assert!(
            attn.ratio_q[2] > mlp.ratio_q[2],
            "b_attn_n overlap {:.2} !> b_mlp_n {:.2}",
            attn.ratio_q[2],
            mlp.ratio_q[2]
        );
    }

    #[test]
    fn forward_phase_only_filter() {
        let idx = idx(2);
        let mut f = Filter::sampled();
        f.phase = Some(Phase::Forward);
        let samples = overlap_samples(&idx, &f);
        assert!(samples.iter().all(|s| s.inst.op.phase == Phase::Forward));
    }
}
