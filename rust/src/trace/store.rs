//! Crash-safe out-of-core columnar trace store (DESIGN.md §12).
//!
//! The in-memory `Vec<TraceEvent>` path stays the default — this module is
//! the spill format for runs that should survive a crash or outlive RAM:
//! a compact binary struct-of-arrays layout, split into per-iteration
//! chunks, each length-prefixed and CRC32-checksummed, committed with the
//! same tmp+rename discipline as every other artifact.
//!
//! ## On-disk layout (version 1)
//!
//! ```text
//! [ 8] magic  b"CHOPTRC1"
//! [ 4] u32 LE version = 1
//! [ 4] u32 LE flags   = 0
//! frames, each:
//!   [ 4] u32 LE tag       ("META" | "EVNT" | "PWRC" | "THRM" | "FOOT")
//!   [ 4] u32 LE payload length
//!   [ 4] u32 LE CRC32 of the payload
//!   [ n] payload
//! [ 8] u64 LE file offset of the FOOT frame
//! [ 8] magic  b"CHOPEND1"
//! ```
//!
//! `META` (JSON) snapshots the provisional [`TraceMeta`] when the writer is
//! created, so even a torn file identifies its run. `EVNT` frames are
//! columnar event chunks (one training iteration each, split when an
//! iteration exceeds [`CHUNK_EVENTS`]). `PWRC` frames are columnar power
//! samples. `THRM` frames carry the thermal columns (die °C, throttle) of
//! the immediately preceding `PWRC` block — written only when the run had
//! thermal coupling enabled, so thermal-disabled stores are byte-identical
//! to the pre-thermal format (no tag, no wire key). `FOOT` (JSON) is
//! written at finalize and carries the *final*
//! metadata (fault fields only settle at the end of a run), iteration
//! bounds, and frame counts; the reader prefers it over `META`.
//!
//! ## Robustness contract
//!
//! The writer streams to `<path>.tmp` and renames only after the footer,
//! trailer and fsync — a finalized `.ctrc` is always complete. The reader
//! never panics on damage: it walks frames until the first truncated or
//! checksum-invalid one, salvages the longest valid prefix, and reports
//! exactly what was lost in a [`SalvageReport`] (mirroring the campaign's
//! `status`/`lost_ms` fault reporting). `chopper fsck` prints that report
//! and `--repair` rewrites the valid prefix as a finalized store whose
//! footer is flagged `salvaged` — analysis accepts such files, but the
//! campaign cache refuses to rebuild summaries from them.
//!
//! Event order is not stored: the engine's canonical order is
//! `(t_start, kernel_id)` (kernel ids are emission-monotone and the engine
//! stable-sorts by start time), so the reader re-sorts and a roundtrip is
//! bitwise identical to the in-memory trace.

use crate::model::ops::{OpRef, OpType, Phase};
use crate::trace::event::{PowerSample, PowerTrace, Stream, Trace, TraceEvent, TraceMeta};
use crate::util::atomic_write::tmp_sibling;
use crate::util::crc32::crc32;
use crate::util::hash::FxHashMap;
use crate::util::intern::intern;
use crate::util::json::{self, Json};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};
use std::rc::Rc;

pub const STORE_MAGIC: &[u8; 8] = b"CHOPTRC1";
pub const STORE_END: &[u8; 8] = b"CHOPEND1";
pub const STORE_VERSION: u32 = 1;
/// Default store file extension (campaign cache uses `<name>-<fp>.ctrc`).
pub const STORE_EXT: &str = "ctrc";

pub const TAG_META: u32 = u32::from_le_bytes(*b"META");
pub const TAG_EVNT: u32 = u32::from_le_bytes(*b"EVNT");
pub const TAG_PWRC: u32 = u32::from_le_bytes(*b"PWRC");
pub const TAG_THRM: u32 = u32::from_le_bytes(*b"THRM");
pub const TAG_FOOT: u32 = u32::from_le_bytes(*b"FOOT");

/// Memory bound: an iteration's pending events are flushed as a chunk once
/// they reach this count, even before the iteration completes. Chunk
/// boundaries are a memory knob, never a correctness one — the reader
/// re-sorts globally.
pub const CHUNK_EVENTS: usize = 32 * 1024;
/// Power samples per PWRC frame.
const PWRC_SAMPLES: usize = 64 * 1024;
/// Frames larger than this are rejected as corrupt before allocation.
const MAX_FRAME: u32 = 1 << 30;

// ---------------------------------------------------------------------------
// Discriminant tables (explicit — `OpRef::parse` is lossy, so the binary
// format carries its own codes; adding an OpType extends the end).
// ---------------------------------------------------------------------------

fn op_code(op: OpType) -> u8 {
    match op {
        OpType::IE => 0,
        OpType::AttnN => 1,
        OpType::QkvIp => 2,
        OpType::QkvS => 3,
        OpType::QkvT => 4,
        OpType::QkvRe => 5,
        OpType::QkvC => 6,
        OpType::AttnFa => 7,
        OpType::AttnOr => 8,
        OpType::AttnOp => 9,
        OpType::AttnRa => 10,
        OpType::MlpN => 11,
        OpType::MlpGp => 12,
        OpType::MlpGs => 13,
        OpType::MlpUp => 14,
        OpType::MlpGu => 15,
        OpType::MlpDp => 16,
        OpType::MlpRa => 17,
        OpType::Ln => 18,
        OpType::Lp => 19,
        OpType::GradAccum => 20,
        OpType::OptStep => 21,
        OpType::AllGather => 22,
        OpType::ReduceScatter => 23,
        OpType::AllReduce => 24,
        OpType::ParamCopy => 25,
        OpType::Prefill => 26,
        OpType::Decode => 27,
    }
}

fn code_op(code: u8) -> Option<OpType> {
    Some(match code {
        0 => OpType::IE,
        1 => OpType::AttnN,
        2 => OpType::QkvIp,
        3 => OpType::QkvS,
        4 => OpType::QkvT,
        5 => OpType::QkvRe,
        6 => OpType::QkvC,
        7 => OpType::AttnFa,
        8 => OpType::AttnOr,
        9 => OpType::AttnOp,
        10 => OpType::AttnRa,
        11 => OpType::MlpN,
        12 => OpType::MlpGp,
        13 => OpType::MlpGs,
        14 => OpType::MlpUp,
        15 => OpType::MlpGu,
        16 => OpType::MlpDp,
        17 => OpType::MlpRa,
        18 => OpType::Ln,
        19 => OpType::Lp,
        20 => OpType::GradAccum,
        21 => OpType::OptStep,
        22 => OpType::AllGather,
        23 => OpType::ReduceScatter,
        24 => OpType::AllReduce,
        25 => OpType::ParamCopy,
        26 => OpType::Prefill,
        27 => OpType::Decode,
        _ => return None,
    })
}

fn phase_code(p: Phase) -> u8 {
    match p {
        Phase::Forward => 0,
        Phase::Backward => 1,
        Phase::Optimizer => 2,
    }
}

fn code_phase(code: u8) -> Option<Phase> {
    Some(match code {
        0 => Phase::Forward,
        1 => Phase::Backward,
        2 => Phase::Optimizer,
        _ => return None,
    })
}

fn stream_code(s: Stream) -> u8 {
    match s {
        Stream::Compute => 0,
        Stream::Comm => 1,
    }
}

fn code_stream(code: u8) -> Option<Stream> {
    Some(match code {
        0 => Stream::Compute,
        1 => Stream::Comm,
        _ => return None,
    })
}

// ---------------------------------------------------------------------------
// Little-endian encode/decode helpers
// ---------------------------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// Bounds-checked cursor over a frame payload; every read is total, so a
/// corrupt length can never cause a panic or over-read.
struct Cur<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, p: 0 }
    }
    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        let end = self.p.checked_add(n)?;
        if end > self.b.len() {
            return None;
        }
        let s = &self.b[self.p..end];
        self.p = end;
        Some(s)
    }
    fn u8(&mut self) -> Option<u8> {
        self.take(1).map(|s| s[0])
    }
    fn u16(&mut self) -> Option<u16> {
        self.take(2).map(|s| u16::from_le_bytes(s.try_into().unwrap()))
    }
    fn u32(&mut self) -> Option<u32> {
        self.take(4).map(|s| u32::from_le_bytes(s.try_into().unwrap()))
    }
    fn u64(&mut self) -> Option<u64> {
        self.take(8).map(|s| u64::from_le_bytes(s.try_into().unwrap()))
    }
    fn f64(&mut self) -> Option<f64> {
        self.u64().map(f64::from_bits)
    }
    fn done(&self) -> bool {
        self.p == self.b.len()
    }
}

// ---------------------------------------------------------------------------
// Metadata / footer JSON
// ---------------------------------------------------------------------------

/// f64 as bit-exact hex (JSON numbers would lose -0.0 and non-finite
/// values; the salvage contract demands bitwise roundtrips).
fn f64_hex(x: f64) -> Json {
    Json::str(format!("{:016x}", x.to_bits()))
}

fn hex_f64(j: &Json) -> Option<f64> {
    u64::from_str_radix(j.as_str()?, 16).ok().map(f64::from_bits)
}

fn spans_json(spans: &[(f64, f64)]) -> Json {
    Json::Arr(
        spans
            .iter()
            .map(|(a, b)| Json::Arr(vec![f64_hex(*a), f64_hex(*b)]))
            .collect(),
    )
}

fn json_spans(j: Option<&Json>) -> Option<Vec<(f64, f64)>> {
    let mut out = Vec::new();
    for pair in j?.as_arr()? {
        let p = pair.as_arr()?;
        out.push((hex_f64(p.first()?)?, hex_f64(p.get(1)?)?));
    }
    Some(out)
}

fn meta_to_json(m: &TraceMeta) -> Json {
    let mut fields = vec![
        ("workload", Json::str(&m.workload)),
        ("fsdp", Json::str(&m.fsdp)),
        ("model", Json::str(&m.model)),
        ("num_gpus", Json::num(m.num_gpus)),
        ("num_nodes", Json::num(m.num_nodes)),
        ("gpus_per_node", Json::num(m.gpus_per_node)),
        ("sharding", Json::str(&m.sharding)),
        ("iterations", Json::num(m.iterations)),
        ("warmup", Json::num(m.warmup)),
        ("seed", Json::str(format!("{:016x}", m.seed))),
        ("source", Json::str(&m.source)),
        ("serialized", Json::Bool(m.serialized)),
        ("faults", Json::str(&m.faults)),
        (
            "fault_slowdown",
            Json::Arr(m.fault_slowdown.iter().map(|x| f64_hex(*x)).collect()),
        ),
        ("restart_spans", spans_json(&m.restart_spans)),
        ("fault_lost_ns", f64_hex(m.fault_lost_ns)),
    ];
    // Only folded traces carry the fold factor — exact-mode stores stay
    // byte-identical to the pre-folding format (and parse everywhere).
    if m.is_folded() {
        fields.push(("fold", Json::num(m.fold_factor())));
    }
    Json::obj(fields)
}

fn meta_from_json(j: &Json) -> Option<TraceMeta> {
    let s = |k: &str| j.get(k).and_then(Json::as_str).map(String::from);
    let n = |k: &str| j.get(k).and_then(Json::as_f64);
    Some(TraceMeta {
        workload: s("workload")?,
        fsdp: s("fsdp")?,
        model: s("model")?,
        num_gpus: n("num_gpus")? as u32,
        num_nodes: n("num_nodes")? as u32,
        gpus_per_node: n("gpus_per_node")? as u32,
        sharding: s("sharding")?,
        iterations: n("iterations")? as u32,
        warmup: n("warmup")? as u32,
        seed: u64::from_str_radix(j.get("seed")?.as_str()?, 16).ok()?,
        source: s("source")?,
        serialized: j.get("serialized")?.as_bool()?,
        faults: s("faults")?,
        fault_slowdown: j
            .get("fault_slowdown")?
            .as_arr()?
            .iter()
            .map(hex_f64)
            .collect::<Option<Vec<f64>>>()?,
        restart_spans: json_spans(j.get("restart_spans"))?,
        fault_lost_ns: hex_f64(j.get("fault_lost_ns")?)?,
        // Absent on exact/legacy stores ⇒ 0 ⇒ unfolded.
        fold: n("fold").unwrap_or(0.0) as u32,
    })
}

// ---------------------------------------------------------------------------
// Chunk encode/decode
// ---------------------------------------------------------------------------

/// Columnar EVNT payload: iteration tag, local string table (first-appearance
/// order, so identical runs serialize identically), then one column per
/// `TraceEvent` field. `layer` uses `u32::MAX` = None, `fwd_link` uses
/// `u64::MAX` = None.
fn encode_chunk(iter: u32, evs: &[TraceEvent]) -> Vec<u8> {
    let mut names: Vec<&'static str> = Vec::new();
    let mut idx: FxHashMap<&'static str, u32> = FxHashMap::default();
    let mut name_col: Vec<u32> = Vec::with_capacity(evs.len());
    for e in evs {
        let s = e.name.as_str();
        let i = *idx.entry(s).or_insert_with(|| {
            names.push(s);
            names.len() as u32 - 1
        });
        name_col.push(i);
    }
    let mut out = Vec::with_capacity(32 + evs.len() * 78);
    put_u32(&mut out, iter);
    put_u32(&mut out, evs.len() as u32);
    put_u32(&mut out, names.len() as u32);
    for s in &names {
        put_u16(&mut out, s.len() as u16);
        out.extend_from_slice(s.as_bytes());
    }
    for e in evs {
        put_u64(&mut out, e.kernel_id);
    }
    for e in evs {
        put_u32(&mut out, e.gpu);
    }
    for e in evs {
        out.push(stream_code(e.stream));
    }
    for i in &name_col {
        put_u32(&mut out, *i);
    }
    for e in evs {
        out.push(op_code(e.op.op));
    }
    for e in evs {
        out.push(phase_code(e.op.phase));
    }
    for e in evs {
        put_u32(&mut out, e.layer.unwrap_or(u32::MAX));
    }
    for e in evs {
        put_u32(&mut out, e.iter);
    }
    for e in evs {
        put_f64(&mut out, e.t_launch);
    }
    for e in evs {
        put_f64(&mut out, e.t_start);
    }
    for e in evs {
        put_f64(&mut out, e.t_end);
    }
    for e in evs {
        put_u64(&mut out, e.seq);
    }
    for e in evs {
        put_u64(&mut out, e.fwd_link.unwrap_or(u64::MAX));
    }
    for e in evs {
        put_f64(&mut out, e.freq_mhz);
    }
    for e in evs {
        put_f64(&mut out, e.flops);
    }
    for e in evs {
        put_f64(&mut out, e.bytes);
    }
    out
}

/// Parse an EVNT payload, appending events to `out` when given (fsck
/// validates without materializing). Returns the event count.
fn decode_chunk(payload: &[u8], mut out: Option<&mut Vec<TraceEvent>>) -> Result<u32, String> {
    let mut c = Cur::new(payload);
    let bad = |what: &str| format!("EVNT chunk: {what}");
    let _iter = c.u32().ok_or_else(|| bad("missing iteration tag"))?;
    let n = c.u32().ok_or_else(|| bad("missing event count"))? as usize;
    let n_names = c.u32().ok_or_else(|| bad("missing name count"))? as usize;
    if n_names > payload.len() {
        return Err(bad("name table larger than payload"));
    }
    let mut names = Vec::with_capacity(n_names);
    for _ in 0..n_names {
        let len = c.u16().ok_or_else(|| bad("truncated name length"))? as usize;
        let raw = c.take(len).ok_or_else(|| bad("truncated name bytes"))?;
        let s = std::str::from_utf8(raw).map_err(|_| bad("non-UTF8 name"))?;
        names.push(intern(s));
    }
    // Column sizes are fixed per event; verify the payload holds them all
    // before decoding (1 over-length check instead of 17n).
    let per_event = 8 + 4 + 1 + 4 + 1 + 1 + 4 + 4 + 8 * 3 + 8 + 8 + 8 * 3;
    let need = n.checked_mul(per_event).ok_or_else(|| bad("event count overflow"))?;
    if payload.len() - c.p != need {
        return Err(bad("column size mismatch"));
    }
    let mut kernel_id = Vec::with_capacity(n);
    for _ in 0..n {
        kernel_id.push(c.u64().ok_or_else(|| bad("truncated kernel_id"))?);
    }
    let mut gpu = Vec::with_capacity(n);
    for _ in 0..n {
        gpu.push(c.u32().ok_or_else(|| bad("truncated gpu"))?);
    }
    let mut stream = Vec::with_capacity(n);
    for _ in 0..n {
        let code = c.u8().ok_or_else(|| bad("truncated stream"))?;
        stream.push(code_stream(code).ok_or_else(|| bad("invalid stream code"))?);
    }
    let mut name = Vec::with_capacity(n);
    for _ in 0..n {
        let i = c.u32().ok_or_else(|| bad("truncated name index"))? as usize;
        name.push(*names.get(i).ok_or_else(|| bad("name index out of range"))?);
    }
    let mut op = Vec::with_capacity(n);
    for _ in 0..n {
        let code = c.u8().ok_or_else(|| bad("truncated op"))?;
        op.push(code_op(code).ok_or_else(|| bad("invalid op code"))?);
    }
    let mut phase = Vec::with_capacity(n);
    for _ in 0..n {
        let code = c.u8().ok_or_else(|| bad("truncated phase"))?;
        phase.push(code_phase(code).ok_or_else(|| bad("invalid phase code"))?);
    }
    let col_u32 = |c: &mut Cur, what: &str| -> Result<Vec<u32>, String> {
        (0..n).map(|_| c.u32().ok_or_else(|| bad(what))).collect()
    };
    let col_u64 = |c: &mut Cur, what: &str| -> Result<Vec<u64>, String> {
        (0..n).map(|_| c.u64().ok_or_else(|| bad(what))).collect()
    };
    let col_f64 = |c: &mut Cur, what: &str| -> Result<Vec<f64>, String> {
        (0..n).map(|_| c.f64().ok_or_else(|| bad(what))).collect()
    };
    let layer = col_u32(&mut c, "truncated layer")?;
    let iter = col_u32(&mut c, "truncated iter")?;
    let t_launch = col_f64(&mut c, "truncated t_launch")?;
    let t_start = col_f64(&mut c, "truncated t_start")?;
    let t_end = col_f64(&mut c, "truncated t_end")?;
    let seq = col_u64(&mut c, "truncated seq")?;
    let fwd_link = col_u64(&mut c, "truncated fwd_link")?;
    let freq_mhz = col_f64(&mut c, "truncated freq_mhz")?;
    let flops = col_f64(&mut c, "truncated flops")?;
    let bytes = col_f64(&mut c, "truncated bytes")?;
    if !c.done() {
        return Err(bad("trailing bytes"));
    }
    if let Some(out) = out.as_deref_mut() {
        out.reserve(n);
        for i in 0..n {
            out.push(TraceEvent {
                kernel_id: kernel_id[i],
                gpu: gpu[i],
                stream: stream[i],
                name: name[i],
                op: OpRef {
                    op: op[i],
                    phase: phase[i],
                },
                layer: if layer[i] == u32::MAX { None } else { Some(layer[i]) },
                iter: iter[i],
                t_launch: t_launch[i],
                t_start: t_start[i],
                t_end: t_end[i],
                seq: seq[i],
                fwd_link: if fwd_link[i] == u64::MAX {
                    None
                } else {
                    Some(fwd_link[i])
                },
                freq_mhz: freq_mhz[i],
                flops: flops[i],
                bytes: bytes[i],
            });
        }
    }
    Ok(n as u32)
}

fn encode_power(samples: &[PowerSample]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + samples.len() * 48);
    put_u32(&mut out, samples.len() as u32);
    for s in samples {
        put_u32(&mut out, s.gpu);
    }
    for s in samples {
        put_f64(&mut out, s.t);
    }
    for s in samples {
        put_f64(&mut out, s.window_ns);
    }
    for s in samples {
        put_f64(&mut out, s.freq_mhz);
    }
    for s in samples {
        put_f64(&mut out, s.mem_freq_mhz);
    }
    for s in samples {
        put_f64(&mut out, s.power_w);
    }
    for s in samples {
        put_u32(&mut out, s.iter);
    }
    out
}

/// Thermal columns of one PWRC block: `n`, then `temp_c[]` and
/// `throttle[]`. Emitted right after the block it annotates, and only when
/// the run recorded thermal telemetry.
fn encode_thermal(samples: &[PowerSample]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + samples.len() * 16);
    put_u32(&mut out, samples.len() as u32);
    for s in samples {
        put_f64(&mut out, s.temp_c);
    }
    for s in samples {
        put_f64(&mut out, s.throttle);
    }
    out
}

/// Apply a THRM frame to the trailing `n` samples (its PWRC block). With
/// `out: None` (fsck validation) only the column sizes are checked.
fn decode_thermal(payload: &[u8], out: Option<&mut Vec<PowerSample>>) -> Result<u32, String> {
    let mut c = Cur::new(payload);
    let bad = |what: &str| format!("THRM frame: {what}");
    let n = c.u32().ok_or_else(|| bad("missing sample count"))? as usize;
    let need = n.checked_mul(16).ok_or_else(|| bad("sample count overflow"))?;
    if payload.len() - c.p != need {
        return Err(bad("column size mismatch"));
    }
    let temp_c: Vec<f64> = (0..n).filter_map(|_| c.f64()).collect();
    let throttle: Vec<f64> = (0..n).filter_map(|_| c.f64()).collect();
    if throttle.len() != n || !c.done() {
        return Err(bad("truncated columns"));
    }
    if let Some(out) = out {
        if out.len() < n {
            return Err(bad("no matching power block"));
        }
        let base = out.len() - n;
        for i in 0..n {
            out[base + i].temp_c = temp_c[i];
            out[base + i].throttle = throttle[i];
        }
    }
    Ok(n as u32)
}

fn decode_power(payload: &[u8], mut out: Option<&mut Vec<PowerSample>>) -> Result<u32, String> {
    let mut c = Cur::new(payload);
    let bad = |what: &str| format!("PWRC frame: {what}");
    let n = c.u32().ok_or_else(|| bad("missing sample count"))? as usize;
    let need = n.checked_mul(4 + 8 * 5 + 4).ok_or_else(|| bad("sample count overflow"))?;
    if payload.len() - c.p != need {
        return Err(bad("column size mismatch"));
    }
    let gpu: Vec<u32> = (0..n).filter_map(|_| c.u32()).collect();
    let t: Vec<f64> = (0..n).filter_map(|_| c.f64()).collect();
    let window_ns: Vec<f64> = (0..n).filter_map(|_| c.f64()).collect();
    let freq_mhz: Vec<f64> = (0..n).filter_map(|_| c.f64()).collect();
    let mem_freq_mhz: Vec<f64> = (0..n).filter_map(|_| c.f64()).collect();
    let power_w: Vec<f64> = (0..n).filter_map(|_| c.f64()).collect();
    let iter: Vec<u32> = (0..n).filter_map(|_| c.u32()).collect();
    if iter.len() != n || !c.done() {
        return Err(bad("truncated columns"));
    }
    if let Some(out) = out.as_deref_mut() {
        out.reserve(n);
        for i in 0..n {
            out.push(PowerSample {
                gpu: gpu[i],
                t: t[i],
                window_ns: window_ns[i],
                freq_mhz: freq_mhz[i],
                mem_freq_mhz: mem_freq_mhz[i],
                power_w: power_w[i],
                iter: iter[i],
                // Neutral defaults; a trailing THRM frame (present only
                // for thermal-enabled runs) overwrites them in place.
                temp_c: 0.0,
                throttle: 1.0,
            });
        }
    }
    Ok(n as u32)
}

// ---------------------------------------------------------------------------
// Streaming writer
// ---------------------------------------------------------------------------

/// A sink the engine can stream trace events into as they are emitted.
/// Infallible by contract — implementations latch IO errors internally and
/// surface them when the run finishes, so the hot emission path never has
/// to unwind the simulation.
pub trait TraceSink {
    fn event(&mut self, ev: &TraceEvent);
    /// All future events have `iter >= watermark`; buffered iterations
    /// below it may be flushed.
    fn advance(&mut self, watermark: u32);
}

/// What a finalized store contains, returned by [`StoreWriter::finalize`].
#[derive(Debug, Clone)]
pub struct StoreInfo {
    pub path: PathBuf,
    pub bytes: u64,
    pub events: u64,
    pub chunks: u64,
    pub samples: u64,
}

/// Streaming store writer: bounded memory, chunks flushed at iteration
/// boundaries (or at [`CHUNK_EVENTS`], whichever comes first). Bytes go to
/// `<path>.tmp`; only [`finalize`](StoreWriter::finalize) renames to the
/// real path, so the destination is always either absent or complete.
pub struct StoreWriter {
    w: io::BufWriter<std::fs::File>,
    tmp: PathBuf,
    path: PathBuf,
    offset: u64,
    pending: BTreeMap<u32, Vec<TraceEvent>>,
    events: u64,
    chunks: u64,
    samples: u64,
    err: Option<io::Error>,
}

impl StoreWriter {
    /// Open `<path>.tmp` and write the header + provisional META frame.
    pub fn create(path: impl Into<PathBuf>, meta: &TraceMeta) -> io::Result<StoreWriter> {
        let path = path.into();
        let tmp = tmp_sibling(&path);
        let f = std::fs::File::create(&tmp)?;
        let mut sw = StoreWriter {
            w: io::BufWriter::new(f),
            tmp,
            path,
            offset: 0,
            pending: BTreeMap::new(),
            events: 0,
            chunks: 0,
            samples: 0,
            err: None,
        };
        sw.w.write_all(STORE_MAGIC)?;
        sw.w.write_all(&STORE_VERSION.to_le_bytes())?;
        sw.w.write_all(&0u32.to_le_bytes())?;
        sw.offset = 16;
        sw.frame(TAG_META, meta_to_json(meta).to_string().as_bytes())?;
        Ok(sw)
    }

    fn frame(&mut self, tag: u32, payload: &[u8]) -> io::Result<()> {
        self.w.write_all(&tag.to_le_bytes())?;
        self.w.write_all(&(payload.len() as u32).to_le_bytes())?;
        self.w.write_all(&crc32(payload).to_le_bytes())?;
        self.w.write_all(payload)?;
        self.offset += 12 + payload.len() as u64;
        Ok(())
    }

    fn latch(&mut self, r: io::Result<()>) {
        if let (Err(e), None) = (r, self.err.as_ref()) {
            self.err = Some(e);
        }
    }

    fn write_chunk(&mut self, iter: u32, evs: &[TraceEvent]) {
        if self.err.is_some() || evs.is_empty() {
            return;
        }
        let payload = encode_chunk(iter, evs);
        let r = self.frame(TAG_EVNT, &payload);
        self.latch(r);
        self.chunks += 1;
        self.events += evs.len() as u64;
    }

    /// First IO error hit so far, if any (also returned by `finalize`).
    pub fn error(&self) -> Option<&io::Error> {
        self.err.as_ref()
    }

    /// Events currently buffered (bounded by the flush watermark).
    pub fn buffered(&self) -> usize {
        self.pending.values().map(Vec::len).sum()
    }

    fn flush_complete(&mut self, watermark: u32) {
        while let Some((&it, _)) = self.pending.iter().next() {
            if it >= watermark {
                break;
            }
            let evs = self.pending.remove(&it).unwrap_or_default();
            self.write_chunk(it, &evs);
        }
    }

    fn flush_all(&mut self) {
        let pending = std::mem::take(&mut self.pending);
        for (it, evs) in pending {
            self.write_chunk(it, &evs);
        }
    }

    /// Flush buffered chunks, append power samples, footer and trailer,
    /// fsync, and atomically rename `<path>.tmp` → `path`. Consumes the
    /// writer; any latched or new IO error is returned and the tmp file is
    /// left behind as a salvage target.
    pub fn finalize(
        mut self,
        meta: &TraceMeta,
        power: &PowerTrace,
        iter_bounds: &[(f64, f64)],
    ) -> io::Result<StoreInfo> {
        self.flush_all();
        let thermal = power.has_thermal();
        for block in power.samples.chunks(PWRC_SAMPLES) {
            if self.err.is_some() {
                break;
            }
            let payload = encode_power(block);
            let r = self.frame(TAG_PWRC, &payload);
            self.latch(r);
            if thermal {
                let payload = encode_thermal(block);
                let r = self.frame(TAG_THRM, &payload);
                self.latch(r);
            }
            self.samples += block.len() as u64;
        }
        if let Some(e) = self.err.take() {
            return Err(e);
        }
        let foot_offset = self.offset;
        let foot = footer_json(meta, iter_bounds, self.events, self.chunks, self.samples, false, 0);
        self.frame(TAG_FOOT, foot.to_string().as_bytes())?;
        self.w.write_all(&foot_offset.to_le_bytes())?;
        self.w.write_all(STORE_END)?;
        self.offset += 16;
        self.w.flush()?;
        self.w.get_ref().sync_all()?;
        std::fs::rename(&self.tmp, &self.path)?;
        Ok(StoreInfo {
            path: self.path.clone(),
            bytes: self.offset,
            events: self.events,
            chunks: self.chunks,
            samples: self.samples,
        })
    }
}

impl TraceSink for StoreWriter {
    fn event(&mut self, ev: &TraceEvent) {
        if self.err.is_some() {
            return;
        }
        let v = self.pending.entry(ev.iter).or_default();
        v.push(ev.clone());
        if v.len() >= CHUNK_EVENTS {
            let evs = std::mem::take(v);
            self.write_chunk(ev.iter, &evs);
        }
    }

    fn advance(&mut self, watermark: u32) {
        self.flush_complete(watermark);
    }
}

/// `Rc<RefCell<StoreWriter>>` adapter so a caller can hand the engine a
/// sink and keep the writer for [`StoreWriter::finalize`] afterwards.
/// Single-threaded by construction (the engine runs on one thread).
pub struct SharedSink(pub Rc<RefCell<StoreWriter>>);

impl TraceSink for SharedSink {
    fn event(&mut self, ev: &TraceEvent) {
        self.0.borrow_mut().event(ev);
    }
    fn advance(&mut self, watermark: u32) {
        self.0.borrow_mut().advance(watermark);
    }
}

fn footer_json(
    meta: &TraceMeta,
    iter_bounds: &[(f64, f64)],
    events: u64,
    chunks: u64,
    samples: u64,
    salvaged: bool,
    lost_bytes: u64,
) -> Json {
    Json::obj(vec![
        ("meta", meta_to_json(meta)),
        ("iter_bounds", spans_json(iter_bounds)),
        ("events", Json::num(events as f64)),
        ("chunks", Json::num(chunks as f64)),
        ("samples", Json::num(samples as f64)),
        ("salvaged", Json::Bool(salvaged)),
        ("lost_bytes", Json::num(lost_bytes as f64)),
    ])
}

/// One-shot store write of an already-materialized trace (the non-streaming
/// path: `fsck --repair` tests, golden fixtures, ad-hoc exports).
pub fn write_store(
    path: impl Into<PathBuf>,
    trace: &Trace,
    power: &PowerTrace,
    iter_bounds: &[(f64, f64)],
) -> io::Result<StoreInfo> {
    let mut w = StoreWriter::create(path, &trace.meta)?;
    for ev in &trace.events {
        w.event(ev);
    }
    w.finalize(&trace.meta, power, iter_bounds)
}

// ---------------------------------------------------------------------------
// Reader / salvage
// ---------------------------------------------------------------------------

/// What a scan of a store file found — the salvage contract's receipt.
/// Produced for every read; `clean()` distinguishes a pristine finalized
/// store from anything that lost bytes.
#[derive(Debug, Clone, Default)]
pub struct SalvageReport {
    pub file_bytes: u64,
    /// Bytes of the valid prefix (header + intact frames [+ trailer]).
    pub valid_bytes: u64,
    /// Bytes after the valid prefix that could not be used.
    pub lost_bytes: u64,
    pub frames: u64,
    pub chunks: u64,
    pub events: u64,
    pub samples: u64,
    pub meta_present: bool,
    pub footer_present: bool,
    /// Trailer magic present and pointing at the FOOT frame.
    pub finalized: bool,
    /// The footer says this file was already produced by `fsck --repair`.
    pub salvaged_upstream: bool,
    /// First failure was a checksum/decode error (bit-rot) rather than a
    /// clean truncation.
    pub corrupt: bool,
    /// Human-readable description of the first failure ("" when clean).
    pub note: String,
}

impl SalvageReport {
    /// Finalized, nothing lost, not itself a repair product.
    pub fn clean(&self) -> bool {
        self.finalized && self.lost_bytes == 0 && !self.corrupt
    }

    /// One-line status for CLI/stderr reporting.
    pub fn describe(&self) -> String {
        if self.clean() && !self.salvaged_upstream {
            format!(
                "clean ({} events, {} chunks, {} power samples, {} bytes)",
                self.events, self.chunks, self.samples, self.file_bytes
            )
        } else if self.clean() {
            format!(
                "salvaged upstream ({} events, {} chunks retained)",
                self.events, self.chunks
            )
        } else {
            let kind = if self.corrupt { "corrupt" } else { "torn" };
            format!(
                "{kind}: salvaged {} events in {} chunks ({} of {} bytes valid, {} lost{})",
                self.events,
                self.chunks,
                self.valid_bytes,
                self.file_bytes,
                self.lost_bytes,
                if self.note.is_empty() {
                    String::new()
                } else {
                    format!("; {}", self.note)
                }
            )
        }
    }
}

/// A store read back into memory, plus the salvage receipt.
#[derive(Debug, Clone)]
pub struct LoadedStore {
    pub trace: Trace,
    pub power: PowerTrace,
    pub iter_bounds: Vec<(f64, f64)>,
    pub report: SalvageReport,
}

#[derive(Default)]
struct ScanOut<'a> {
    meta: Option<TraceMeta>,
    foot_meta: Option<TraceMeta>,
    iter_bounds: Vec<(f64, f64)>,
    salvaged_upstream: bool,
    events: Vec<TraceEvent>,
    samples: Vec<PowerSample>,
    /// Raw (tag, payload) frames, kept only in repair mode.
    raw: Option<Vec<(u32, Vec<u8>)>>,
    materialize: bool,
    /// Chunk-wise delivery (out-of-core path): each decoded EVNT chunk is
    /// handed over and dropped instead of accumulating in `events`.
    chunk_visit: Option<&'a mut dyn FnMut(Vec<TraceEvent>)>,
}

/// Walk the file frame by frame, validating lengths + CRCs and decoding
/// payloads. Stops at the first damage and reports the salvaged prefix.
/// `Err` is reserved for "this is not a store at all" (or the file cannot
/// be opened) — damage to a real store always returns `Ok`.
fn scan(path: &Path, out: &mut ScanOut<'_>) -> Result<SalvageReport, String> {
    let mut rep = SalvageReport::default();
    let mut f = std::fs::File::open(path)
        .map_err(|e| format!("opening {}: {e}", path.display()))?;
    let len = f
        .metadata()
        .map_err(|e| format!("stat {}: {e}", path.display()))?
        .len();
    rep.file_bytes = len;

    let mut expect_header = [0u8; 16];
    expect_header[..8].copy_from_slice(STORE_MAGIC);
    expect_header[8..12].copy_from_slice(&STORE_VERSION.to_le_bytes());
    // flags = 0 already

    let head_n = len.min(16) as usize;
    let mut head = vec![0u8; head_n];
    f.read_exact(&mut head)
        .map_err(|e| format!("reading {}: {e}", path.display()))?;
    if head_n >= 8 && head[..8] != STORE_MAGIC[..] {
        return Err(format!("{}: not a chopper trace store (bad magic)", path.display()));
    }
    if head[..] != expect_header[..head_n] {
        if head_n >= 12 && head[8..12] != STORE_VERSION.to_le_bytes() {
            return Err(format!(
                "{}: unsupported store version {}",
                path.display(),
                u32::from_le_bytes(head[8..12].try_into().unwrap())
            ));
        }
        return Err(format!("{}: not a chopper trace store (bad header)", path.display()));
    }
    if head_n < 16 {
        // A prefix of a real header: torn before any data.
        rep.note = "truncated inside the file header".into();
        rep.lost_bytes = len;
        return Ok(rep);
    }

    let mut r = io::BufReader::new(f);
    let mut pos: u64 = 16;
    rep.valid_bytes = 16;
    let mut foot_at: Option<u64> = None;

    loop {
        let remaining = len - pos;
        if remaining == 0 {
            rep.note = "missing trailer".into();
            break;
        }
        if remaining == 16 {
            let mut t = [0u8; 16];
            if r.read_exact(&mut t).is_err() {
                rep.note = format!("short read at offset {pos}");
                break;
            }
            if t[8..] == STORE_END[..] {
                let off = u64::from_le_bytes(t[..8].try_into().unwrap());
                if rep.footer_present && Some(off) == foot_at {
                    rep.finalized = true;
                    pos += 16;
                    rep.valid_bytes = pos;
                } else {
                    rep.corrupt = true;
                    rep.note = "trailer does not point at a valid footer".into();
                }
                break;
            }
            rep.note = format!("truncated frame at offset {pos}");
            break;
        }
        if remaining < 12 {
            rep.note = format!("truncated frame header at offset {pos}");
            break;
        }
        let mut h = [0u8; 12];
        if r.read_exact(&mut h).is_err() {
            rep.note = format!("short read at offset {pos}");
            break;
        }
        let tag = u32::from_le_bytes(h[..4].try_into().unwrap());
        let plen = u32::from_le_bytes(h[4..8].try_into().unwrap());
        let crc = u32::from_le_bytes(h[8..12].try_into().unwrap());
        if !matches!(tag, TAG_META | TAG_EVNT | TAG_PWRC | TAG_THRM | TAG_FOOT) {
            rep.corrupt = true;
            rep.note = format!("unknown frame tag at offset {pos}");
            break;
        }
        if plen > MAX_FRAME || plen as u64 + 12 > remaining {
            // Longer than the file: either a torn final frame or a corrupt
            // length field. Indistinguishable; treat as truncation.
            rep.note = format!("truncated frame payload at offset {pos}");
            break;
        }
        let mut payload = vec![0u8; plen as usize];
        if r.read_exact(&mut payload).is_err() {
            rep.note = format!("short read at offset {pos}");
            break;
        }
        if crc32(&payload) != crc {
            rep.corrupt = true;
            rep.note = format!(
                "checksum mismatch in {} frame at offset {pos}",
                tag_name(tag)
            );
            break;
        }
        let decoded = match tag {
            TAG_META => parse_meta_frame(&payload).map(|m| {
                rep.meta_present = true;
                if out.meta.is_none() {
                    out.meta = Some(m);
                }
                0
            }),
            TAG_EVNT => {
                if let Some(visit) = out.chunk_visit.as_mut() {
                    let mut evs = Vec::new();
                    let r = decode_chunk(&payload, Some(&mut evs));
                    if r.is_ok() {
                        visit(evs);
                    }
                    r
                } else {
                    decode_chunk(
                        &payload,
                        if out.materialize { Some(&mut out.events) } else { None },
                    )
                }
                .map(|n| {
                    rep.chunks += 1;
                    rep.events += n as u64;
                    n
                })
            }
            TAG_PWRC => decode_power(
                &payload,
                if out.materialize { Some(&mut out.samples) } else { None },
            )
            .map(|n| {
                rep.samples += n as u64;
                n
            }),
            TAG_THRM => decode_thermal(
                &payload,
                if out.materialize { Some(&mut out.samples) } else { None },
            ),
            TAG_FOOT => parse_foot_frame(&payload).map(|(m, ib, salv)| {
                rep.footer_present = true;
                rep.salvaged_upstream = salv;
                out.foot_meta = Some(m);
                out.iter_bounds = ib;
                out.salvaged_upstream = salv;
                foot_at = Some(pos);
                0
            }),
            _ => unreachable!(),
        };
        if let Err(e) = decoded {
            rep.corrupt = true;
            rep.note = format!("{e} (frame at offset {pos})");
            break;
        }
        if let Some(raw) = out.raw.as_mut() {
            raw.push((tag, payload));
        }
        rep.frames += 1;
        pos += 12 + plen as u64;
        rep.valid_bytes = pos;
    }
    rep.lost_bytes = len - rep.valid_bytes;
    Ok(rep)
}

fn tag_name(tag: u32) -> &'static str {
    match tag {
        TAG_META => "META",
        TAG_EVNT => "EVNT",
        TAG_PWRC => "PWRC",
        TAG_THRM => "THRM",
        TAG_FOOT => "FOOT",
        _ => "????",
    }
}

fn parse_meta_frame(payload: &[u8]) -> Result<TraceMeta, String> {
    let s = std::str::from_utf8(payload).map_err(|_| "META frame: non-UTF8".to_string())?;
    let j = json::parse(s).map_err(|e| format!("META frame: {e}"))?;
    meta_from_json(&j).ok_or_else(|| "META frame: missing fields".to_string())
}

fn parse_foot_frame(payload: &[u8]) -> Result<(TraceMeta, Vec<(f64, f64)>, bool), String> {
    let s = std::str::from_utf8(payload).map_err(|_| "FOOT frame: non-UTF8".to_string())?;
    let j = json::parse(s).map_err(|e| format!("FOOT frame: {e}"))?;
    let meta = j
        .get("meta")
        .and_then(meta_from_json)
        .ok_or_else(|| "FOOT frame: missing meta".to_string())?;
    let ib = json_spans(j.get("iter_bounds"))
        .ok_or_else(|| "FOOT frame: bad iter_bounds".to_string())?;
    let salvaged = j.get("salvaged").and_then(Json::as_bool).unwrap_or(false);
    Ok((meta, ib, salvaged))
}

/// Validate a store without materializing events (what `chopper fsck`
/// runs). Never panics on damage; `Err` only for not-a-store/unopenable.
pub fn check_store(path: &Path) -> Result<SalvageReport, String> {
    let mut out = ScanOut::default();
    scan(path, &mut out)
}

/// Read a store back into memory, salvaging the longest valid prefix of a
/// damaged file. Events are returned in the engine's canonical
/// `(t_start, kernel_id)` order, making a roundtrip of an engine trace
/// bitwise identical. Never panics on damage — inspect `report`.
pub fn read_store(path: &Path) -> Result<LoadedStore, String> {
    let mut out = ScanOut {
        materialize: true,
        ..ScanOut::default()
    };
    let report = scan(path, &mut out)?;
    let meta = out
        .foot_meta
        .or(out.meta)
        .unwrap_or_default();
    let mut events = out.events;
    events.sort_by(|a, b| {
        a.t_start
            .total_cmp(&b.t_start)
            .then(a.kernel_id.cmp(&b.kernel_id))
    });
    Ok(LoadedStore {
        trace: Trace { meta, events },
        power: PowerTrace {
            samples: out.samples,
        },
        iter_bounds: out.iter_bounds,
        report,
    })
}

/// Read a store like [`read_store`] while streaming every event through
/// `visit` in the engine's canonical `(t_start, kernel_id)` order — the
/// chunk-wise indexing path: `chopper::index::IndexBuilder` consumes the
/// callback (it requires canonical arrival order for bit-stable float
/// accumulation) in the same pass that materializes the trace, so the
/// index exists the moment the file is read, with no second scan.
///
/// Instead of one global sort over the full vector, each per-iteration
/// chunk is sorted as it is decoded and the sorted chunks are k-way
/// merged; equal keys resolve to the earlier chunk in file order, then to
/// the earlier event within it — exactly the stable sort [`read_store`]
/// performs, so the materialized trace (and therefore everything derived
/// from it) is byte-identical between the two paths (`tests/store.rs`
/// pins this). Exhausted chunk buffers are dropped as the merge drains
/// them, so peak memory is the final vector plus the undrained chunks.
pub fn read_store_visit(
    path: &Path,
    mut visit: impl FnMut(&TraceMeta, &TraceEvent),
) -> Result<LoadedStore, String> {
    let mut chunks: Vec<Vec<TraceEvent>> = Vec::new();
    let mut cb = |mut evs: Vec<TraceEvent>| {
        evs.sort_by(|a, b| {
            a.t_start
                .total_cmp(&b.t_start)
                .then(a.kernel_id.cmp(&b.kernel_id))
        });
        chunks.push(evs);
    };
    let mut out = ScanOut {
        // Power samples still materialize; events route to `cb` instead.
        materialize: true,
        chunk_visit: Some(&mut cb),
        ..ScanOut::default()
    };
    let report = scan(path, &mut out)?;
    let meta = out.foot_meta.or(out.meta).unwrap_or_default();

    let total: usize = chunks.iter().map(Vec::len).sum();
    let mut events: Vec<TraceEvent> = Vec::with_capacity(total);
    let mut iters: Vec<std::iter::Peekable<std::vec::IntoIter<TraceEvent>>> =
        chunks.into_iter().map(|c| c.into_iter().peekable()).collect();
    loop {
        // Linear head scan per event: the chunk count is small (one per
        // iteration plus CHUNK_EVENTS splits), so this beats a heap.
        let mut best: Option<(usize, f64, u64)> = None;
        for ci in 0..iters.len() {
            if let Some(e) = iters[ci].peek() {
                let better = match &best {
                    None => true,
                    Some((_, bt, bk)) => match e.t_start.total_cmp(bt) {
                        std::cmp::Ordering::Less => true,
                        std::cmp::Ordering::Equal => e.kernel_id < *bk,
                        std::cmp::Ordering::Greater => false,
                    },
                };
                if better {
                    best = Some((ci, e.t_start, e.kernel_id));
                }
            }
        }
        let Some((bi, _, _)) = best else { break };
        let ev = iters[bi].next().expect("peeked head exists");
        if iters[bi].peek().is_none() {
            // Free the exhausted chunk's buffer now, not at function end.
            iters[bi] = Vec::new().into_iter().peekable();
        }
        visit(&meta, &ev);
        events.push(ev);
    }
    Ok(LoadedStore {
        trace: Trace { meta, events },
        power: PowerTrace {
            samples: out.samples,
        },
        iter_bounds: out.iter_bounds,
        report,
    })
}

/// Visit a store chunk-by-chunk without materializing the full event
/// vector (the out-of-core analysis path: `TraceIndex` folds each chunk
/// and drops it). Returns the salvage report. Chunks arrive in file
/// order, *not* globally time-sorted.
pub fn for_each_chunk(
    path: &Path,
    mut visit: impl FnMut(Vec<TraceEvent>),
) -> Result<(TraceMeta, SalvageReport), String> {
    let mut cb = |evs: Vec<TraceEvent>| visit(evs);
    let mut out = ScanOut {
        chunk_visit: Some(&mut cb),
        ..ScanOut::default()
    };
    let rep = scan(path, &mut out)?;
    let meta = out.foot_meta.take().or(out.meta.take()).unwrap_or_default();
    Ok((meta, rep))
}

/// Outcome of [`repair_store`].
#[derive(Debug, Clone)]
pub struct RepairInfo {
    pub dst: PathBuf,
    pub events: u64,
    pub chunks: u64,
    pub samples: u64,
    pub lost_bytes: u64,
}

/// Rewrite the valid prefix of a damaged store as a finalized store at
/// `dst` (atomically). The new footer is flagged `salvaged`, which marks
/// the trace as a partial record: analysis accepts it, the campaign cache
/// will not rebuild summaries from it.
pub fn repair_store(src: &Path, dst: &Path) -> Result<RepairInfo, String> {
    let mut out = ScanOut {
        raw: Some(Vec::new()),
        ..ScanOut::default()
    };
    let rep = scan(src, &mut out)?;
    let raw = out.raw.take().unwrap_or_default();
    let meta = out.foot_meta.clone().or(out.meta.clone()).unwrap_or_default();

    let mut buf = Vec::new();
    buf.extend_from_slice(STORE_MAGIC);
    buf.extend_from_slice(&STORE_VERSION.to_le_bytes());
    buf.extend_from_slice(&0u32.to_le_bytes());
    let mut wrote_meta = false;
    let mut push_frame = |buf: &mut Vec<u8>, tag: u32, payload: &[u8]| {
        buf.extend_from_slice(&tag.to_le_bytes());
        buf.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        buf.extend_from_slice(&crc32(payload).to_le_bytes());
        buf.extend_from_slice(payload);
    };
    if !rep.meta_present {
        // Damaged before META survived: synthesize one so the repaired
        // file is self-describing.
        push_frame(&mut buf, TAG_META, meta_to_json(&meta).to_string().as_bytes());
        wrote_meta = true;
    }
    for (tag, payload) in &raw {
        if *tag == TAG_FOOT || (*tag == TAG_META && wrote_meta) {
            continue;
        }
        push_frame(&mut buf, *tag, payload);
    }
    let foot_offset = buf.len() as u64;
    let foot = footer_json(
        &meta,
        &out.iter_bounds,
        rep.events,
        rep.chunks,
        rep.samples,
        true,
        rep.lost_bytes,
    );
    push_frame(&mut buf, TAG_FOOT, foot.to_string().as_bytes());
    buf.extend_from_slice(&foot_offset.to_le_bytes());
    buf.extend_from_slice(STORE_END);

    crate::util::atomic_write(dst, &buf)
        .map_err(|e| format!("writing {}: {e}", dst.display()))?;
    Ok(RepairInfo {
        dst: dst.to_path_buf(),
        events: rep.events,
        chunks: rep.chunks,
        samples: rep.samples,
        lost_bytes: rep.lost_bytes,
    })
}

/// Cheap sniff: does this path start with the store magic? Lets the CLI
/// route `.ctrc` files to the store reader and JSON to the chrome reader.
pub fn is_store_file(path: &Path) -> bool {
    let mut head = [0u8; 8];
    match std::fs::File::open(path).and_then(|mut f| f.read_exact(&mut head)) {
        Ok(()) => head == *STORE_MAGIC,
        Err(_) => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(id: u64, iter: u32, t0: f64) -> TraceEvent {
        TraceEvent {
            kernel_id: id,
            gpu: (id % 4) as u32,
            stream: if id % 5 == 0 { Stream::Comm } else { Stream::Compute },
            name: intern(if id % 2 == 0 { "Cijk_gemm" } else { "elementwise" }),
            op: OpRef {
                op: code_op((id % 28) as u8).unwrap(),
                phase: code_phase((id % 3) as u8).unwrap(),
            },
            layer: if id % 7 == 0 { None } else { Some((id % 32) as u32) },
            iter,
            t_launch: t0 - 1.5,
            t_start: t0,
            t_end: t0 + 10.0 + id as f64,
            seq: id * 3,
            fwd_link: if id % 3 == 0 { Some(id / 2) } else { None },
            freq_mhz: 1900.0 + id as f64,
            flops: 1e9 + id as f64,
            bytes: 4096.0 * id as f64,
        }
    }

    fn sample_trace(n: u64) -> (Trace, PowerTrace, Vec<(f64, f64)>) {
        let mut t = Trace::default();
        t.meta.workload = "llama31_8b".into();
        t.meta.fsdp = "v2".into();
        t.meta.num_gpus = 4;
        t.meta.num_nodes = 1;
        t.meta.gpus_per_node = 4;
        t.meta.sharding = "FSDP".into();
        t.meta.iterations = 3;
        t.meta.warmup = 1;
        t.meta.seed = 0xDEAD_BEEF_0BAD_F00D;
        t.meta.source = "sim".into();
        for id in 0..n {
            t.events.push(ev(id, (id / (n / 3).max(1)) as u32, id as f64 * 7.0));
        }
        let mut p = PowerTrace::default();
        for i in 0..32u64 {
            p.samples.push(PowerSample {
                gpu: (i % 4) as u32,
                t: i as f64 * 1e6,
                window_ns: 1e6,
                freq_mhz: 1980.0,
                mem_freq_mhz: 2600.0,
                power_w: 450.0 + i as f64,
                iter: (i % 3) as u32,
                temp_c: 0.0,
                throttle: 1.0,
            });
        }
        let ib = vec![(0.0, 100.0), (100.0, 220.0), (220.0, 347.5)];
        (t, p, ib)
    }

    fn tdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("chopper-store-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_is_bitwise_identical() {
        let (t, p, ib) = sample_trace(200);
        let d = tdir("rt");
        let path = d.join("t.ctrc");
        let info = write_store(&path, &t, &p, &ib).unwrap();
        assert_eq!(info.events, 200);
        assert!(!tmp_sibling(&path).exists());
        let l = read_store(&path).unwrap();
        assert!(l.report.clean(), "{}", l.report.describe());
        assert_eq!(format!("{:?}", l.trace), format!("{:?}", t));
        assert_eq!(format!("{:?}", l.power), format!("{:?}", p));
        assert_eq!(format!("{:?}", l.iter_bounds), format!("{:?}", ib));
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn thermal_columns_roundtrip_and_disabled_stores_have_no_thrm_frame() {
        let (t, mut p, ib) = sample_trace(50);
        let d = tdir("thrm");

        // Disabled run: the serialized bytes must contain no THRM frame.
        let off = d.join("off.ctrc");
        write_store(&off, &t, &p, &ib).unwrap();
        let bytes = std::fs::read(&off).unwrap();
        assert!(
            !bytes.windows(4).any(|w| w == b"THRM"),
            "thermal-disabled store grew a THRM frame"
        );

        // Enabled run: columns roundtrip bitwise.
        for (i, s) in p.samples.iter_mut().enumerate() {
            s.temp_c = 60.0 + i as f64 * 0.25;
            s.throttle = if i % 4 == 0 { 0.85 } else { 1.0 };
        }
        let on = d.join("on.ctrc");
        write_store(&on, &t, &p, &ib).unwrap();
        let bytes = std::fs::read(&on).unwrap();
        assert!(bytes.windows(4).any(|w| w == b"THRM"));
        let l = read_store(&on).unwrap();
        assert!(l.report.clean(), "{}", l.report.describe());
        assert_eq!(format!("{:?}", l.power), format!("{:?}", p));
        assert!(l.power.has_thermal());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn visit_read_is_bitwise_identical_to_materialized_read() {
        let (mut t, p, ib) = sample_trace(200);
        // Force merge tie-breaks: equal t_start values landing in
        // different per-iteration chunks, resolved by kernel_id alone.
        for (id, iter) in [(500u64, 0u32), (501, 1), (502, 2)] {
            let mut e = ev(id, iter, 91.0);
            e.t_start = 91.0;
            t.events.push(e);
        }
        let d = tdir("visit");
        let path = d.join("t.ctrc");
        write_store(&path, &t, &p, &ib).unwrap();
        let a = read_store(&path).unwrap();
        let mut seen: Vec<TraceEvent> = Vec::new();
        let mut metas = 0usize;
        let b = read_store_visit(&path, |m, e| {
            assert_eq!(m.workload, "llama31_8b");
            metas += 1;
            seen.push(e.clone());
        })
        .unwrap();
        assert!(b.report.clean(), "{}", b.report.describe());
        // The chunk-sort + k-way-merge path reproduces the global stable
        // sort exactly: trace, power, and bounds are all byte-identical.
        assert_eq!(format!("{:?}", a.trace), format!("{:?}", b.trace));
        assert_eq!(format!("{:?}", a.power), format!("{:?}", b.power));
        assert_eq!(format!("{:?}", a.iter_bounds), format!("{:?}", b.iter_bounds));
        // The visitor saw every event, in canonical order.
        assert_eq!(metas, a.trace.events.len());
        assert_eq!(format!("{seen:?}"), format!("{:?}", a.trace.events));
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn op_phase_stream_codes_roundtrip_exhaustively() {
        for code in 0u8..=255 {
            if let Some(op) = code_op(code) {
                assert_eq!(op_code(op), code);
            } else {
                assert!(code >= 28);
            }
            if let Some(p) = code_phase(code) {
                assert_eq!(phase_code(p), code);
            }
            if let Some(s) = code_stream(code) {
                assert_eq!(stream_code(s), code);
            }
        }
        assert!(code_op(27).is_some() && code_op(28).is_none());
    }

    #[test]
    fn truncation_at_every_offset_salvages_without_panic() {
        let (t, p, ib) = sample_trace(60);
        let d = tdir("trunc");
        let path = d.join("t.ctrc");
        write_store(&path, &t, &p, &ib).unwrap();
        let full = std::fs::read(&path).unwrap();
        let cut = d.join("cut.ctrc");
        // Every offset would be O(n²); sample densely incl. all boundaries.
        for at in (0..full.len()).step_by(7).chain([0, 1, 7, 8, 15, 16, full.len() - 17, full.len() - 16, full.len() - 1]) {
            std::fs::write(&cut, &full[..at]).unwrap();
            match read_store(&cut) {
                Ok(l) => {
                    assert!(!l.report.finalized || at == full.len());
                    assert!(l.trace.events.len() <= t.events.len());
                    assert_eq!(
                        l.report.valid_bytes + l.report.lost_bytes,
                        at as u64,
                        "at {at}"
                    );
                }
                Err(e) => {
                    // Only acceptable for cuts inside the magic itself —
                    // and ours match the real prefix, so never here.
                    panic!("truncation at {at} must not hard-fail: {e}");
                }
            }
        }
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn single_byte_corruption_is_detected() {
        let (t, p, ib) = sample_trace(40);
        let d = tdir("flip");
        let path = d.join("t.ctrc");
        write_store(&path, &t, &p, &ib).unwrap();
        let full = std::fs::read(&path).unwrap();
        let flip = d.join("flip.ctrc");
        // Flip one byte inside the first EVNT payload (after header+META).
        let mut m = full.clone();
        let meta_len = u32::from_le_bytes(m[20..24].try_into().unwrap()) as usize;
        let evnt_payload_at = 16 + 12 + meta_len + 12 + 40;
        m[evnt_payload_at] ^= 0x40;
        std::fs::write(&flip, &m).unwrap();
        let l = read_store(&flip).unwrap();
        assert!(l.report.corrupt, "{}", l.report.describe());
        assert!(l.report.note.contains("checksum mismatch"));
        assert!(l.trace.events.is_empty());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn repair_produces_finalized_salvaged_store() {
        let (t, p, ib) = sample_trace(90);
        let d = tdir("repair");
        let path = d.join("t.ctrc");
        write_store(&path, &t, &p, &ib).unwrap();
        let full = std::fs::read(&path).unwrap();
        let torn = d.join("torn.ctrc");
        std::fs::write(&torn, &full[..full.len() * 2 / 3]).unwrap();
        let pre = check_store(&torn).unwrap();
        assert!(!pre.finalized && pre.lost_bytes > 0);
        let fixed = d.join("fixed.ctrc");
        let info = repair_store(&torn, &fixed).unwrap();
        assert_eq!(info.events, pre.events);
        let l = read_store(&fixed).unwrap();
        assert!(l.report.finalized && l.report.salvaged_upstream);
        assert_eq!(l.report.lost_bytes, 0);
        assert_eq!(l.trace.events.len(), pre.events as usize);
        assert_eq!(l.trace.meta.workload, "llama31_8b");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn non_store_files_are_rejected_cleanly() {
        let d = tdir("sniff");
        let j = d.join("x.json");
        std::fs::write(&j, b"{\"not\":\"a store\"}").unwrap();
        assert!(!is_store_file(&j));
        assert!(read_store(&j).unwrap_err().contains("not a chopper trace store"));
        assert!(check_store(Path::new("/nonexistent/x.ctrc")).is_err());
        std::fs::remove_dir_all(&d).ok();
    }
}
