//! Trace collection front-ends — the paper's Section III-B as an API.
//!
//! `RuntimeProfiler` is the roctracer/PyTorch-profiler analogue: accurate
//! concurrent timestamps, full annotations, no counters. `HardwareProfiler`
//! is the rocprofv3 analogue: counters a few at a time, kernels serialized,
//! timestamps useless for overlap. Both run against the simulator substrate
//! here; the PJRT runtime path produces the same `Trace` schema through
//! `runtime::traced` — the tool downstream cannot tell them apart.

use crate::config::{ModelConfig, NodeSpec, Topology, WorkloadConfig};
use crate::counters::{Counter, CounterTrace};
use crate::sim::{self, EngineParams};
use crate::trace::event::{CpuTrace, PowerTrace, Trace};

/// Runtime profiling: timestamps + annotations (+ power/CPU telemetry,
/// which the paper collects alongside via rocm-smi-style sampling).
/// Profiles a full cluster [`Topology`]; [`RuntimeProfiler::new`] wraps a
/// single node, byte-identical to the pre-topology path.
#[derive(Debug, Clone)]
pub struct RuntimeProfiler {
    pub topo: Topology,
    pub params: EngineParams,
}

/// What one runtime-profiling session returns.
#[derive(Debug)]
pub struct RuntimeCapture {
    pub trace: Trace,
    pub power: PowerTrace,
    pub cpu: CpuTrace,
    pub iter_bounds: Vec<(f64, f64)>,
    pub alloc: crate::fsdp::AllocStats,
}

impl RuntimeProfiler {
    pub fn new(node: NodeSpec) -> Self {
        Self::with_topology(Topology::single(node))
    }

    pub fn with_topology(topo: Topology) -> Self {
        Self {
            topo,
            params: EngineParams::default(),
        }
    }

    /// Profile one training run.
    pub fn capture(&self, cfg: &ModelConfig, wl: &WorkloadConfig) -> RuntimeCapture {
        let out =
            sim::Engine::with_topology(self.topo.clone(), cfg, wl, self.params.clone())
                .run();
        // CPU telemetry models node 0's host (identical to the full
        // activity on a single node).
        let host0 = out.host.node0(self.topo.gpus_per_node() as usize);
        let cpu = sim::cpu_trace(
            &self.topo.node,
            &host0,
            wl.seed,
            &sim::HostModelParams::default(),
        );
        RuntimeCapture {
            trace: out.trace,
            power: out.power,
            cpu,
            iter_bounds: out.iter_bounds,
            alloc: out.alloc,
        }
    }
}

/// Hardware profiling: performance counters, collected `per_pass` at a
/// time, with kernels serialized (Section III-B2).
#[derive(Debug, Clone)]
pub struct HardwareProfiler {
    pub topo: Topology,
    /// How many counters one pass may collect (paper: 2–3).
    pub per_pass: usize,
}

impl HardwareProfiler {
    pub fn new(node: NodeSpec) -> Self {
        Self::with_topology(Topology::single(node))
    }

    pub fn with_topology(topo: Topology) -> Self {
        Self { topo, per_pass: 3 }
    }

    /// Collect `counters` for every kernel of the workload, re-running the
    /// workload once per pass.
    pub fn capture(
        &self,
        cfg: &ModelConfig,
        wl: &WorkloadConfig,
        counters: &[Counter],
    ) -> CounterTrace {
        sim::collect_counters_topo(&self.topo, cfg, wl, counters, self.per_pass)
    }

    /// Number of serialized re-runs `capture` will perform.
    pub fn passes(&self, counters: &[Counter]) -> usize {
        counters.len().div_ceil(self.per_pass.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FsdpVersion;
    use crate::sim::align_key;
    use crate::trace::event::Stream;

    fn setup() -> (ModelConfig, WorkloadConfig) {
        let mut cfg = ModelConfig::llama3_8b();
        cfg.layers = 2;
        let mut wl = WorkloadConfig::new(1, 4096, FsdpVersion::V2);
        wl.iterations = 1;
        wl.warmup = 0;
        (cfg, wl)
    }

    #[test]
    fn runtime_capture_has_annotations_and_telemetry() {
        let (cfg, wl) = setup();
        let cap = RuntimeProfiler::new(NodeSpec::mi300x_node()).capture(&cfg, &wl);
        assert!(!cap.trace.events.is_empty());
        assert!(!cap.power.samples.is_empty());
        assert!(!cap.cpu.samples.is_empty());
        assert!(cap.trace.events.iter().any(|e| e.layer.is_some()));
        assert_eq!(cap.trace.meta.source, "sim");
        assert!(!cap.trace.meta.serialized);
    }

    #[test]
    fn hardware_capture_covers_every_kernel() {
        let (cfg, wl) = setup();
        let hw = HardwareProfiler::new(NodeSpec::mi300x_node());
        let counters = hw.capture(&cfg, &wl, &Counter::ALL);
        let cap = RuntimeProfiler::new(NodeSpec::mi300x_node()).capture(&cfg, &wl);
        for e in cap.trace.events.iter().filter(|e| e.gpu == 0) {
            let v = counters.get(0, align_key(e.stream, e.seq));
            assert!(v.is_some(), "no counters for {} seq {}", e.name, e.seq);
        }
    }

    #[test]
    fn pass_count_follows_per_pass_limit() {
        let hw = HardwareProfiler::new(NodeSpec::mi300x_node());
        assert_eq!(hw.passes(&Counter::ALL), 3); // 7 counters / 3 per pass
        let hw2 = HardwareProfiler {
            per_pass: 2,
            ..hw.clone()
        };
        assert_eq!(hw2.passes(&Counter::ALL), 4);
    }

    #[test]
    fn runtime_trace_has_concurrent_streams() {
        // The runtime profiler sees overlap; that's its whole point.
        let (cfg, wl) = setup();
        let cap = RuntimeProfiler::new(NodeSpec::mi300x_node()).capture(&cfg, &wl);
        let has_comm = cap
            .trace
            .events
            .iter()
            .any(|e| e.stream == Stream::Comm);
        assert!(has_comm);
    }
}
