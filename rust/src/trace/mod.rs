//! Trace schema and collectors — the paper's Section III-B.
//!
//! [`event`] defines the schema (timestamped annotated kernels, power and
//! CPU samples); [`collect`] wraps the simulator and the PJRT runtime
//! behind the same two profiler interfaces the paper uses (runtime
//! profiling vs hardware profiling); [`chrome`] round-trips traces through
//! chrome://tracing JSON so they can be inspected in Perfetto; [`store`]
//! is the crash-safe out-of-core binary columnar format (checksummed
//! chunks, truncation salvage, `chopper fsck`).

pub mod chrome;
pub mod collect;
pub mod event;
pub mod store;

pub use event::{
    CpuSample, CpuTrace, PowerSample, PowerTrace, Stream, Trace, TraceEvent,
    TraceMeta,
};
pub use store::{
    read_store, read_store_visit, write_store, LoadedStore, SalvageReport,
    SharedSink, StoreWriter, TraceSink,
};
