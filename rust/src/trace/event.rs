//! Trace schema: what the profilers record.
//!
//! Mirrors the paper's Section III-B: runtime profiling records accurate
//! launch/start/end timestamps of concurrently-executing kernels plus
//! annotations (op, layer, phase, iteration, fwd→bwd mapping); hardware
//! profiling records counters but serializes kernels, so its timestamps are
//! not valid for overlap analysis — alignment joins the two.

use crate::model::ops::{OpKind, OpRef, Phase};
use crate::util::intern::Sym;
use std::fmt;

/// GPU execution stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Stream {
    Compute,
    Comm,
}

impl fmt::Display for Stream {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Stream::Compute => write!(f, "compute"),
            Stream::Comm => write!(f, "comm"),
        }
    }
}

/// One kernel execution, with the full annotation set.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Unique id within the trace.
    pub kernel_id: u64,
    pub gpu: u32,
    pub stream: Stream,
    /// Kernel symbol name (interned handle; resolves at serialization —
    /// events are emitted on the engine's hottest path and must not
    /// allocate). `TraceEvent` is also `Copy`-cheap to clone now.
    pub name: Sym,
    /// Operation annotation (paper Fig. 1 taxonomy + phase).
    pub op: OpRef,
    /// Decoder layer, when applicable.
    pub layer: Option<u32>,
    /// Training iteration.
    pub iter: u32,
    /// Host dispatch timestamp t_l (ns).
    pub t_launch: f64,
    /// Kernel start t_ks (ns).
    pub t_start: f64,
    /// Kernel end t_ke (ns).
    pub t_end: f64,
    /// Dispatch sequence number within (gpu, stream) — the alignment key.
    pub seq: u64,
    /// For backward kernels: the kernel_id of the forward counterpart
    /// ("backward kernels are spawned from their forward counterparts").
    pub fwd_link: Option<u64>,
    /// Engine clock at kernel start, MHz (what rocprof would derive).
    pub freq_mhz: f64,
    /// Theoretical flops of this kernel instance (annotation from the
    /// framework, F_gemm in Eq. 6).
    pub flops: f64,
    /// HBM bytes moved.
    pub bytes: f64,
}

impl TraceEvent {
    pub fn duration(&self) -> f64 {
        self.t_end - self.t_start
    }

    pub fn kind(&self) -> OpKind {
        self.op.op.kind()
    }

    pub fn phase(&self) -> Phase {
        self.op.phase
    }

    pub fn is_comm(&self) -> bool {
        self.stream == Stream::Comm
    }
}

/// Trace-wide metadata.
#[derive(Debug, Clone, Default)]
pub struct TraceMeta {
    pub workload: String,
    pub fsdp: String,
    pub model: String,
    /// Total flat ranks in the trace (cluster-wide on multi-node runs).
    pub num_gpus: u32,
    /// Nodes in the topology (0 in legacy traces ⇒ treat as 1).
    pub num_nodes: u32,
    /// GPUs per node (0 in legacy traces ⇒ treat as `num_gpus`).
    pub gpus_per_node: u32,
    /// Sharding strategy label ("FSDP"/"HSDP"; empty in legacy traces).
    pub sharding: String,
    pub iterations: u32,
    pub warmup: u32,
    pub seed: u64,
    /// "sim" or "pjrt" — which collector produced this trace.
    pub source: String,
    /// Kernels were serialized (hardware-profiling pass).
    pub serialized: bool,
    /// Injected fault-set label (`config::faults::set_label`); "" = no
    /// faults (healthy run — none of the fault fields are serialized).
    pub faults: String,
    /// Per-rank persistent compute multiplier under faults (empty when
    /// no faults; 1.0 = healthy rank, < 1.0 = straggler).
    pub fault_slowdown: Vec<f64>,
    /// Checkpoint-restart replay spans (start ns, end ns) inserted by
    /// GPU-dropout faults.
    pub restart_spans: Vec<(f64, f64)>,
    /// Wall-clock lost to dropout + checkpoint-restart (ns).
    pub fault_lost_ns: f64,
    /// Replica fold factor (DESIGN.md §13): every simulated node in this
    /// trace stands for `fold` statistically-identical logical nodes.
    /// 0/1 (legacy/exact traces — never serialized) ⇒ unfolded; the
    /// logical shape is `num_nodes × fold` nodes.
    pub fold: u32,
}

impl TraceMeta {
    /// Node count, tolerating legacy traces without topology metadata.
    pub fn nodes(&self) -> u32 {
        self.num_nodes.max(1)
    }

    /// GPUs per node, tolerating legacy traces (flat = one node).
    pub fn node_gpus(&self) -> u32 {
        if self.gpus_per_node > 0 {
            self.gpus_per_node
        } else {
            self.num_gpus.max(1)
        }
    }

    /// Node hosting flat rank `gpu`.
    pub fn node_of(&self, gpu: u32) -> u32 {
        gpu / self.node_gpus()
    }

    /// Local GPU index of flat rank `gpu` within its node.
    pub fn local_of(&self, gpu: u32) -> u32 {
        gpu % self.node_gpus()
    }

    /// True when the trace spans more than one node.
    pub fn multi_node(&self) -> bool {
        self.nodes() > 1
    }

    // -- replica folding (DESIGN.md §13) ------------------------------------

    /// Replica fold factor, tolerating legacy traces (0 ⇒ exact mode).
    pub fn fold_factor(&self) -> u32 {
        self.fold.max(1)
    }

    /// True when each simulated node stands for several logical replicas.
    pub fn is_folded(&self) -> bool {
        self.fold_factor() > 1
    }

    /// Logical node count the simulated nodes stand for (`nodes()` in
    /// exact mode).
    pub fn logical_nodes(&self) -> u32 {
        self.nodes() * self.fold_factor()
    }

    /// Logical rank count (`num_gpus` in exact mode).
    pub fn logical_gpus(&self) -> u32 {
        self.num_gpus * self.fold_factor()
    }
}

/// A full runtime-profiling trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub meta: TraceMeta,
    pub events: Vec<TraceEvent>,
}

impl Trace {
    pub fn sampled_events(&self) -> impl Iterator<Item = &TraceEvent> {
        let warmup = self.meta.warmup;
        self.events.iter().filter(move |e| e.iter >= warmup)
    }

    /// Events of one GPU in (stream, seq) order.
    pub fn gpu_events(&self, gpu: u32) -> Vec<&TraceEvent> {
        let mut v: Vec<&TraceEvent> =
            self.events.iter().filter(|e| e.gpu == gpu).collect();
        v.sort_by(|a, b| (a.stream, a.seq).cmp(&(b.stream, b.seq)));
        v
    }

    pub fn span_ns(&self) -> f64 {
        let start = self
            .events
            .iter()
            .map(|e| e.t_start)
            .fold(f64::INFINITY, f64::min);
        let end = self
            .events
            .iter()
            .map(|e| e.t_end)
            .fold(f64::NEG_INFINITY, f64::max);
        if end > start {
            end - start
        } else {
            0.0
        }
    }
}

/// Per-window frequency/power sample of one GPU (Fig. 14's data).
#[derive(Debug, Clone, Copy)]
pub struct PowerSample {
    pub gpu: u32,
    /// Window start, ns.
    pub t: f64,
    /// Window length, ns.
    pub window_ns: f64,
    pub freq_mhz: f64,
    pub mem_freq_mhz: f64,
    pub power_w: f64,
    pub iter: u32,
    /// Die temperature at window end, °C — 0.0 when the thermal subsystem
    /// is disabled (a physical die is never at 0.0 °C, so the zero doubles
    /// as the "no thermal data" marker across the pipeline).
    pub temp_c: f64,
    /// Thermal throttle factor that governed this window's clocks
    /// (1.0 = unthrottled; always 1.0 with thermal disabled).
    pub throttle: f64,
}

impl PowerSample {
    /// Joules this window accounts for: power × window length.
    pub fn energy_j(&self) -> f64 {
        self.power_w * self.window_ns * 1e-9
    }

    /// Nanoseconds of clock capacity this window lost to thermal
    /// throttling: `window × (1 − throttle)`. Zero when unthrottled.
    pub fn throttle_loss_ns(&self) -> f64 {
        self.window_ns * (1.0 - self.throttle)
    }
}

#[derive(Debug, Clone, Default)]
pub struct PowerTrace {
    pub samples: Vec<PowerSample>,
}

/// Power threshold (W) above which a window counts as *active* — the
/// paper's Fig. 14 averages frequency/power over training activity only
/// (idle fill/empty windows would dilute the comparison). One constant
/// shared by campaign summaries, the what-if replay and the figures.
pub const ACTIVE_POWER_W: f64 = 400.0;

impl PowerTrace {
    /// Samples from active windows (power above [`ACTIVE_POWER_W`]), in
    /// emission order.
    pub fn active_samples(&self) -> impl Iterator<Item = &PowerSample> {
        self.samples.iter().filter(|s| s.power_w > ACTIVE_POWER_W)
    }

    /// Total joules across every GPU and window, in sample order (the
    /// order the engine emitted them — bit-stable across runs).
    pub fn total_energy_j(&self) -> f64 {
        self.samples.iter().map(|s| s.energy_j()).sum()
    }

    /// Joules per GPU, in sample order within each GPU.
    pub fn gpu_energy_j(&self) -> std::collections::BTreeMap<u32, f64> {
        let mut out = std::collections::BTreeMap::new();
        for s in &self.samples {
            *out.entry(s.gpu).or_insert(0.0) += s.energy_j();
        }
        out
    }

    /// Joules per training iteration (windows tagged by the iteration the
    /// rank was executing at window start), all GPUs summed.
    pub fn iter_energy_j(&self) -> std::collections::BTreeMap<u32, f64> {
        let mut out = std::collections::BTreeMap::new();
        for s in &self.samples {
            *out.entry(s.iter).or_insert(0.0) += s.energy_j();
        }
        out
    }

    /// Total joules over sampled iterations only (`iter >= warmup`),
    /// summed in sample order — the quantity campaign summaries persist.
    pub fn sampled_energy_j(&self, warmup: u32) -> f64 {
        self.samples
            .iter()
            .filter(|s| s.iter >= warmup)
            .map(|s| s.energy_j())
            .sum()
    }

    /// Whether any window carries thermal telemetry (die temp recorded).
    /// The gate every thermal column/figure/summary hangs off — false for
    /// thermal-disabled runs, keeping their outputs byte-identical.
    pub fn has_thermal(&self) -> bool {
        self.samples.iter().any(|s| s.temp_c > 0.0)
    }

    /// Peak die temperature (°C) across every GPU and window; 0.0 when
    /// thermal is disabled.
    pub fn peak_temp_c(&self) -> f64 {
        self.samples.iter().map(|s| s.temp_c).fold(0.0, f64::max)
    }

    /// Total nanoseconds of clock capacity lost to thermal throttling over
    /// sampled iterations (`iter >= warmup`), summed in sample order.
    pub fn sampled_throttle_loss_ns(&self, warmup: u32) -> f64 {
        self.samples
            .iter()
            .filter(|s| s.iter >= warmup)
            .map(|s| s.throttle_loss_ns())
            .sum()
    }
}

/// Per-window logical-core utilization sample (Fig. 13's data).
#[derive(Debug, Clone)]
pub struct CpuSample {
    /// Window start, ns.
    pub t: f64,
    /// Utilization [0,100] per logical core (sparse: only non-zero cores).
    pub core_util: Vec<(u32, f64)>,
}

#[derive(Debug, Clone, Default)]
pub struct CpuTrace {
    pub logical_cores: u32,
    pub smt: u32,
    pub samples: Vec<CpuSample>,
}

impl CpuTrace {
    /// Map a logical core id to its physical core (Linux-style: logical
    /// core p and p + physical_count share a physical core).
    pub fn physical_of(&self, logical: u32) -> u32 {
        logical % (self.logical_cores / self.smt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ops::{OpRef, OpType};

    fn ev(id: u64, gpu: u32, stream: Stream, seq: u64, t0: f64, t1: f64) -> TraceEvent {
        TraceEvent {
            kernel_id: id,
            gpu,
            stream,
            name: "k".into(),
            op: OpRef::fwd(OpType::AttnN),
            layer: Some(0),
            iter: 0,
            t_launch: t0 - 1.0,
            t_start: t0,
            t_end: t1,
            seq,
            fwd_link: None,
            freq_mhz: 2100.0,
            flops: 0.0,
            bytes: 0.0,
        }
    }

    #[test]
    fn duration_and_span() {
        let mut t = Trace::default();
        t.events.push(ev(0, 0, Stream::Compute, 0, 10.0, 20.0));
        t.events.push(ev(1, 1, Stream::Compute, 0, 15.0, 40.0));
        assert_eq!(t.events[0].duration(), 10.0);
        assert_eq!(t.span_ns(), 30.0);
    }

    #[test]
    fn gpu_events_sorted_by_stream_then_seq() {
        let mut t = Trace::default();
        t.events.push(ev(0, 0, Stream::Comm, 0, 0.0, 1.0));
        t.events.push(ev(1, 0, Stream::Compute, 1, 0.0, 1.0));
        t.events.push(ev(2, 0, Stream::Compute, 0, 0.0, 1.0));
        let v = t.gpu_events(0);
        assert_eq!(v[0].kernel_id, 2);
        assert_eq!(v[1].kernel_id, 1);
        assert_eq!(v[2].kernel_id, 0);
    }

    #[test]
    fn sampled_events_respect_warmup() {
        let mut t = Trace::default();
        t.meta.warmup = 1;
        let mut e0 = ev(0, 0, Stream::Compute, 0, 0.0, 1.0);
        e0.iter = 0;
        let mut e1 = ev(1, 0, Stream::Compute, 1, 2.0, 3.0);
        e1.iter = 1;
        t.events.push(e0);
        t.events.push(e1);
        assert_eq!(t.sampled_events().count(), 1);
    }

    #[test]
    fn meta_node_mapping_and_legacy_fallback() {
        let mut m = TraceMeta::default();
        m.num_gpus = 8;
        // Legacy trace: no topology fields ⇒ one node of num_gpus.
        assert_eq!(m.nodes(), 1);
        assert_eq!(m.node_gpus(), 8);
        assert!(!m.multi_node());
        assert_eq!(m.node_of(5), 0);
        m.num_nodes = 2;
        m.gpus_per_node = 8;
        m.num_gpus = 16;
        assert!(m.multi_node());
        assert_eq!(m.node_of(11), 1);
        assert_eq!(m.local_of(11), 3);
    }

    #[test]
    fn power_energy_rollups_partition_the_total() {
        let mut p = PowerTrace::default();
        for (gpu, iter, w) in [(0u32, 0u32, 500.0), (0, 1, 700.0), (1, 0, 600.0)] {
            p.samples.push(PowerSample {
                gpu,
                t: 0.0,
                window_ns: 1e6,
                freq_mhz: 2000.0,
                mem_freq_mhz: 2500.0,
                power_w: w,
                iter,
                temp_c: 0.0,
                throttle: 1.0,
            });
        }
        // One 1 ms window at 500 W = 0.5 J.
        assert!((p.samples[0].energy_j() - 0.5).abs() < 1e-12);
        let total = p.total_energy_j();
        assert!((total - 1.8).abs() < 1e-12, "{total}");
        let by_gpu: f64 = p.gpu_energy_j().values().sum();
        let by_iter: f64 = p.iter_energy_j().values().sum();
        assert!((by_gpu - total).abs() < 1e-12);
        assert!((by_iter - total).abs() < 1e-12);
        assert!((p.sampled_energy_j(1) - 0.7).abs() < 1e-12);
        assert_eq!(p.sampled_energy_j(0), total);
        // Neutral thermal columns: no thermal data, zero throttle loss.
        assert!(!p.has_thermal());
        assert_eq!(p.peak_temp_c(), 0.0);
        assert_eq!(p.sampled_throttle_loss_ns(0), 0.0);
        // A throttled window reports its lost capacity.
        p.samples[1].temp_c = 96.0;
        p.samples[1].throttle = 0.8;
        assert!(p.has_thermal());
        assert_eq!(p.peak_temp_c(), 96.0);
        assert!((p.sampled_throttle_loss_ns(0) - 0.2e6).abs() < 1e-3);
    }

    #[test]
    fn smt_mapping() {
        let c = CpuTrace {
            logical_cores: 384,
            smt: 2,
            samples: vec![],
        };
        assert_eq!(c.physical_of(0), 0);
        assert_eq!(c.physical_of(192), 0);
        assert_eq!(c.physical_of(191), 191);
        assert_eq!(c.physical_of(383), 191);
    }
}
