//! Chrome-trace (chrome://tracing / Perfetto) JSON export + import.
//!
//! One "process" per GPU; two "threads" per GPU (compute / comm stream).
//! Process/thread metadata rows name each pid "node<N>/gpu<L>" (and each
//! tid "compute"/"comm") with a node-major sort index, so multi-node
//! traces group by node when imported into Perfetto instead of showing a
//! flat anonymous pid list. Every event carries the Chopper annotations in
//! `args`, so a trace written here round-trips losslessly back into a
//! [`Trace`] — the on-disk interchange format between `chopper collect`
//! and `chopper analyze`.

use crate::model::ops::OpRef;
use crate::trace::event::{Stream, Trace, TraceEvent, TraceMeta};
use crate::util::json::{parse, Json};

fn stream_tid(stream: Stream) -> f64 {
    match stream {
        Stream::Compute => 0.0,
        Stream::Comm => 1.0,
    }
}

/// Serialize a trace to chrome-trace JSON ("X" complete events, µs units).
pub fn to_chrome_json(trace: &Trace) -> String {
    let mut events = Vec::with_capacity(trace.events.len() + 1);
    // Metadata record first. Fault fields ride along only on faulted
    // traces, so healthy exports stay byte-identical to the pre-fault
    // format (and legacy traces import with the fields defaulted).
    let mut meta_args = vec![
        ("workload", Json::str(trace.meta.workload.clone())),
        ("fsdp", Json::str(trace.meta.fsdp.clone())),
        ("model", Json::str(trace.meta.model.clone())),
        ("num_gpus", Json::num(trace.meta.num_gpus as f64)),
        ("num_nodes", Json::num(trace.meta.nodes() as f64)),
        ("gpus_per_node", Json::num(trace.meta.node_gpus() as f64)),
        ("sharding", Json::str(trace.meta.sharding.clone())),
        ("iterations", Json::num(trace.meta.iterations as f64)),
        ("warmup", Json::num(trace.meta.warmup as f64)),
        ("seed", Json::num(trace.meta.seed as f64)),
        ("source", Json::str(trace.meta.source.clone())),
        ("serialized", Json::Bool(trace.meta.serialized)),
    ];
    if trace.meta.is_folded() {
        // Only folded traces carry the fold factor — exact exports stay
        // byte-identical to the pre-folding format.
        meta_args.push(("fold", Json::num(trace.meta.fold_factor() as f64)));
    }
    if !trace.meta.faults.is_empty() {
        meta_args.push(("faults", Json::str(trace.meta.faults.clone())));
        meta_args.push((
            "fault_slowdown",
            Json::Arr(
                trace
                    .meta
                    .fault_slowdown
                    .iter()
                    .map(|&f| Json::num(f))
                    .collect(),
            ),
        ));
        meta_args.push((
            "restart_spans",
            Json::Arr(
                trace
                    .meta
                    .restart_spans
                    .iter()
                    .map(|&(s, e)| Json::Arr(vec![Json::num(s), Json::num(e)]))
                    .collect(),
            ),
        ));
        meta_args.push(("fault_lost_ns", Json::num(trace.meta.fault_lost_ns)));
    }
    events.push(Json::obj(vec![
        ("name", Json::str("chopper_meta")),
        ("ph", Json::str("M")),
        ("args", Json::obj(meta_args)),
    ]));
    // Process/thread naming rows: without these Perfetto shows a flat
    // anonymous pid list (pid == flat gpu rank); with them every process
    // reads "node<N>/gpu<L>" and sorts node-major, and each pid's two
    // threads are labeled compute/comm. The importer below ignores every
    // "M" record except chopper_meta, so round-tripping is unaffected.
    for gpu in 0..trace.meta.num_gpus {
        let (node, local) = (trace.meta.node_of(gpu), trace.meta.local_of(gpu));
        events.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(gpu as f64)),
            (
                "args",
                Json::obj(vec![("name", Json::str(format!("node{node}/gpu{local}")))]),
            ),
        ]));
        events.push(Json::obj(vec![
            ("name", Json::str("process_sort_index")),
            ("ph", Json::str("M")),
            ("pid", Json::num(gpu as f64)),
            ("args", Json::obj(vec![("sort_index", Json::num(gpu as f64))])),
        ]));
        for stream in [Stream::Compute, Stream::Comm] {
            events.push(Json::obj(vec![
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(gpu as f64)),
                ("tid", Json::num(stream_tid(stream))),
                (
                    "args",
                    Json::obj(vec![("name", Json::str(stream.to_string()))]),
                ),
            ]));
        }
    }
    for e in &trace.events {
        let mut args = vec![
            ("op", Json::str(e.op.paper_name())),
            ("iter", Json::num(e.iter as f64)),
            ("seq", Json::num(e.seq as f64)),
            ("kernel_id", Json::num(e.kernel_id as f64)),
            ("t_launch_us", Json::num(e.t_launch / 1000.0)),
            ("freq_mhz", Json::num(e.freq_mhz)),
            ("flops", Json::num(e.flops)),
            ("bytes", Json::num(e.bytes)),
        ];
        if let Some(l) = e.layer {
            args.push(("layer", Json::num(l as f64)));
        }
        if let Some(f) = e.fwd_link {
            args.push(("fwd_link", Json::num(f as f64)));
        }
        events.push(Json::obj(vec![
            ("name", Json::str(e.name.as_str())),
            ("ph", Json::str("X")),
            ("pid", Json::num(e.gpu as f64)),
            ("tid", Json::num(stream_tid(e.stream))),
            ("ts", Json::num(e.t_start / 1000.0)),
            ("dur", Json::num(e.duration() / 1000.0)),
            ("args", Json::obj(args)),
        ]));
    }
    // Pre-reserve the output buffer: one event serializes to ~300 bytes,
    // and growing a multi-MB String by doubling re-copies the whole trace
    // several times over.
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
    .to_string_with_capacity(1024 + trace.events.len() * 320)
}

/// Parse chrome-trace JSON produced by [`to_chrome_json`] back into a
/// [`Trace`]. Events missing Chopper annotations are skipped.
///
/// Kernel names are interned into the process-global symbol table
/// (`util::intern`), whose entries live for the process lifetime. That is
/// bounded for chopper-generated traces (tiny name vocabulary) but means a
/// long-running process importing many foreign traces with high-cardinality
/// names (e.g. per-dispatch-suffixed rocprof symbols) retains one table
/// entry per distinct name — use short-lived processes for bulk imports of
/// untrusted traces.
pub fn from_chrome_json(text: &str) -> Result<Trace, String> {
    let root = parse(text)?;
    let events = root
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or("missing traceEvents")?;
    let mut trace = Trace::default();
    for ev in events {
        let ph = ev.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        match ph {
            "M" => {
                if ev.get("name").and_then(|n| n.as_str()) == Some("chopper_meta") {
                    let a = ev.get("args").ok_or("meta without args")?;
                    let s = |k: &str| {
                        a.get(k).and_then(|v| v.as_str()).unwrap_or("").to_string()
                    };
                    let n = |k: &str| a.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
                    trace.meta = TraceMeta {
                        workload: s("workload"),
                        fsdp: s("fsdp"),
                        model: s("model"),
                        num_gpus: n("num_gpus") as u32,
                        // 0 when absent: TraceMeta's accessors treat that
                        // as the legacy flat single-node layout.
                        num_nodes: n("num_nodes") as u32,
                        gpus_per_node: n("gpus_per_node") as u32,
                        sharding: s("sharding"),
                        iterations: n("iterations") as u32,
                        warmup: n("warmup") as u32,
                        seed: n("seed") as u64,
                        source: s("source"),
                        serialized: a
                            .get("serialized")
                            .and_then(|v| v.as_bool())
                            .unwrap_or(false),
                        // Fault fields: absent on healthy/legacy traces.
                        faults: s("faults"),
                        fault_slowdown: a
                            .get("fault_slowdown")
                            .and_then(|v| v.as_arr())
                            .map(|xs| {
                                xs.iter().filter_map(|v| v.as_f64()).collect()
                            })
                            .unwrap_or_default(),
                        restart_spans: a
                            .get("restart_spans")
                            .and_then(|v| v.as_arr())
                            .map(|xs| {
                                xs.iter()
                                    .filter_map(|p| {
                                        let pa = p.as_arr()?;
                                        Some((
                                            pa.first()?.as_f64()?,
                                            pa.get(1)?.as_f64()?,
                                        ))
                                    })
                                    .collect()
                            })
                            .unwrap_or_default(),
                        fault_lost_ns: a
                            .get("fault_lost_ns")
                            .and_then(|v| v.as_f64())
                            .unwrap_or(0.0),
                        // Absent on exact/legacy exports ⇒ 0 ⇒ unfolded.
                        fold: n("fold") as u32,
                    };
                }
            }
            "X" => {
                let args = ev.get("args").ok_or("event without args")?;
                let Some(op) = args
                    .get("op")
                    .and_then(|o| o.as_str())
                    .and_then(OpRef::parse)
                else {
                    continue; // not a Chopper-annotated event
                };
                let num = |j: &Json, k: &str| j.get(k).and_then(|v| v.as_f64());
                let ts = num(ev, "ts").ok_or("missing ts")? * 1000.0;
                let dur = num(ev, "dur").ok_or("missing dur")? * 1000.0;
                let gpu = num(ev, "pid").ok_or("missing pid")? as u32;
                let tid = num(ev, "tid").unwrap_or(0.0);
                trace.events.push(TraceEvent {
                    kernel_id: num(args, "kernel_id").unwrap_or(0.0) as u64,
                    gpu,
                    stream: if tid >= 1.0 { Stream::Comm } else { Stream::Compute },
                    name: ev
                        .get("name")
                        .and_then(|n| n.as_str())
                        .unwrap_or("")
                        .into(),
                    op,
                    layer: num(args, "layer").map(|l| l as u32),
                    iter: num(args, "iter").unwrap_or(0.0) as u32,
                    t_launch: num(args, "t_launch_us").unwrap_or(ts / 1000.0) * 1000.0,
                    t_start: ts,
                    t_end: ts + dur,
                    seq: num(args, "seq").unwrap_or(0.0) as u64,
                    fwd_link: num(args, "fwd_link").map(|f| f as u64),
                    freq_mhz: num(args, "freq_mhz").unwrap_or(0.0),
                    flops: num(args, "flops").unwrap_or(0.0),
                    bytes: num(args, "bytes").unwrap_or(0.0),
                });
            }
            _ => {}
        }
    }
    Ok(trace)
}

/// Write a trace to a file, atomically (tmp sibling + fsync + rename):
/// an interrupted export never leaves a truncated JSON under `path`.
pub fn write_chrome_trace(trace: &Trace, path: &std::path::Path) -> std::io::Result<()> {
    crate::util::atomic_write(path, to_chrome_json(trace).as_bytes())
}

/// Read a trace from a file. Errors carry the offending path.
pub fn read_chrome_trace(path: &std::path::Path) -> Result<Trace, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| crate::util::io_ctx("reading", path, e))?;
    from_chrome_json(&text)
        .map_err(|e| format!("{}: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ops::{OpRef, OpType};

    fn sample_trace() -> Trace {
        let mut t = Trace::default();
        t.meta.workload = "b2s4".into();
        t.meta.fsdp = "FSDPv2".into();
        t.meta.num_gpus = 8;
        t.meta.iterations = 20;
        t.meta.warmup = 10;
        t.meta.seed = 42;
        t.meta.source = "sim".into();
        t.events.push(TraceEvent {
            kernel_id: 7,
            gpu: 3,
            stream: Stream::Compute,
            name: "rmsnorm_fwd_kernel".into(),
            op: OpRef::fwd(OpType::AttnN),
            layer: Some(5),
            iter: 11,
            t_launch: 900.0,
            t_start: 1000.0,
            t_end: 3000.0,
            seq: 4,
            fwd_link: None,
            freq_mhz: 1900.0,
            flops: 1e9,
            bytes: 2e8,
        });
        t.events.push(TraceEvent {
            kernel_id: 8,
            gpu: 3,
            stream: Stream::Comm,
            name: "rccl_AllGather_bf16".into(),
            op: OpRef::fwd(OpType::AllGather),
            layer: None,
            iter: 11,
            t_launch: 500.0,
            t_start: 800.0,
            t_end: 4000.0,
            seq: 0,
            fwd_link: Some(7),
            freq_mhz: 1900.0,
            flops: 0.0,
            bytes: 4e8,
        });
        t
    }

    #[test]
    fn roundtrip_preserves_events_and_meta() {
        let t = sample_trace();
        let json = to_chrome_json(&t);
        let back = from_chrome_json(&json).unwrap();
        assert_eq!(back.meta.workload, "b2s4");
        assert_eq!(back.meta.fsdp, "FSDPv2");
        assert_eq!(back.meta.num_gpus, 8);
        assert_eq!(back.meta.warmup, 10);
        assert_eq!(back.events.len(), 2);
        let e = &back.events[0];
        assert_eq!(e.kernel_id, 7);
        assert_eq!(e.gpu, 3);
        assert_eq!(e.op, OpRef::fwd(OpType::AttnN));
        assert_eq!(e.layer, Some(5));
        assert_eq!(e.iter, 11);
        assert!((e.t_start - 1000.0).abs() < 1e-6);
        assert!((e.t_end - 3000.0).abs() < 1e-6);
        assert!((e.t_launch - 900.0).abs() < 1e-6);
        let c = &back.events[1];
        assert_eq!(c.stream, Stream::Comm);
        assert_eq!(c.fwd_link, Some(7));
    }

    #[test]
    fn file_roundtrip() {
        let t = sample_trace();
        let dir = std::env::temp_dir().join("chopper_chrome_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        write_chrome_trace(&t, &path).unwrap();
        let back = read_chrome_trace(&path).unwrap();
        assert_eq!(back.events.len(), t.events.len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn process_metadata_rows_name_node_and_gpu() {
        let mut t = sample_trace();
        t.meta.num_gpus = 4;
        t.meta.num_nodes = 2;
        t.meta.gpus_per_node = 2;
        t.meta.sharding = "HSDP".into();
        let json = to_chrome_json(&t);
        // pid 3 is node 1 / local gpu 1; threads are named per stream.
        assert!(json.contains("\"process_name\""));
        assert!(json.contains("node1/gpu1"));
        assert!(json.contains("\"thread_name\""));
        assert!(json.contains("\"process_sort_index\""));
        // Topology meta round-trips.
        let back = from_chrome_json(&json).unwrap();
        assert_eq!(back.meta.num_nodes, 2);
        assert_eq!(back.meta.gpus_per_node, 2);
        assert_eq!(back.meta.sharding, "HSDP");
        assert_eq!(back.meta.node_of(3), 1);
        // The naming rows did not leak into the event stream.
        assert_eq!(back.events.len(), t.events.len());
    }

    #[test]
    fn legacy_traces_import_as_single_node() {
        // A trace written before topology metadata existed has no
        // num_nodes/gpus_per_node keys; the accessors fall back to flat.
        let json = r#"{"traceEvents":[
            {"name":"chopper_meta","ph":"M","args":{
                "workload":"b1s4","fsdp":"FSDPv1","model":"m",
                "num_gpus":8,"iterations":2,"warmup":1,"seed":1,
                "source":"sim","serialized":false}}
        ]}"#;
        let t = from_chrome_json(json).unwrap();
        assert_eq!(t.meta.num_nodes, 0);
        assert_eq!(t.meta.nodes(), 1);
        assert_eq!(t.meta.node_gpus(), 8);
        assert!(!t.meta.multi_node());
    }

    #[test]
    fn foreign_events_are_skipped() {
        let json = r#"{"traceEvents":[
            {"name":"x","ph":"X","pid":0,"tid":0,"ts":1,"dur":2,"args":{}},
            {"name":"b","ph":"B","pid":0,"tid":0,"ts":1}
        ]}"#;
        let t = from_chrome_json(json).unwrap();
        assert!(t.events.is_empty());
    }

    #[test]
    fn sim_trace_roundtrips() {
        use crate::config::*;
        let mut cfg = ModelConfig::llama3_8b();
        cfg.layers = 1;
        let mut wl = WorkloadConfig::new(1, 4096, FsdpVersion::V1);
        wl.iterations = 1;
        wl.warmup = 0;
        let cap = crate::trace::collect::RuntimeProfiler::new(NodeSpec::mi300x_node())
            .capture(&cfg, &wl);
        let back = from_chrome_json(&to_chrome_json(&cap.trace)).unwrap();
        assert_eq!(back.events.len(), cap.trace.events.len());
        // Spot-check a late event survives with full fidelity.
        let i = back.events.len() - 1;
        assert_eq!(back.events[i].op, cap.trace.events[i].op);
        assert!((back.events[i].t_end - cap.trace.events[i].t_end).abs() < 1e-3);
    }
}
