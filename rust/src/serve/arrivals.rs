//! Seeded open-loop request generation (DESIGN.md §10).
//!
//! Arrival timestamps are drawn by inverse-transform sampling of the
//! arrival process: homogeneous Poisson arrivals use plain exponential
//! inter-arrival times; trace-driven (piecewise-constant rate) arrivals
//! integrate the rate function until the accumulated unit-rate exposure
//! matches the drawn exponential. Both depend only on
//! `(seed, process parameters)` — adding draws elsewhere can never perturb
//! them (the `serve_arrivals` / `serve_lens` substream labels).

use crate::config::{ArrivalProcess, ServingConfig};
use crate::util::prng::Rng;

/// One request of the open-loop stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    /// Arrival order (also the stream index).
    pub id: u32,
    /// Open-loop arrival timestamp, ns.
    pub arrival_ns: f64,
    pub prompt_tokens: u64,
    pub output_tokens: u64,
}

impl Request {
    pub fn total_tokens(&self) -> u64 {
        self.prompt_tokens + self.output_tokens
    }
}

/// Advance one arrival from `t_ns` under the process. Returns the next
/// arrival timestamp (ns).
fn next_arrival_ns(proc: &ArrivalProcess, t_ns: f64, rng: &mut Rng) -> f64 {
    // Exponential with unit rate; guard ln(0).
    let e = -(1.0 - rng.f64()).max(1e-300).ln();
    match proc {
        ArrivalProcess::Poisson { qps } => {
            assert!(*qps > 0.0, "Poisson arrivals need qps > 0");
            t_ns + e / qps * 1e9
        }
        ArrivalProcess::Trace { qps_per_sec } => {
            assert!(
                !qps_per_sec.is_empty() && qps_per_sec.iter().any(|&q| q > 0.0),
                "trace-driven arrivals need a non-empty rate trace with \
                 some positive rate"
            );
            // Walk second-sized buckets, spending the exposure `e` against
            // the piecewise-constant rate (thinning-free inversion).
            let mut remaining = e;
            let mut t = t_ns;
            loop {
                let bucket = (t / 1e9) as usize % qps_per_sec.len();
                let rate = qps_per_sec[bucket];
                let bucket_end = ((t / 1e9).floor() + 1.0) * 1e9;
                let span_s = (bucket_end - t) * 1e-9;
                let exposure = rate * span_s;
                if rate > 0.0 && exposure >= remaining {
                    return t + remaining / rate * 1e9;
                }
                remaining -= exposure;
                t = bucket_end;
            }
        }
    }
}

/// Generate the full seeded request stream for `cfg`: arrival timestamps
/// from the arrival process, prompt/output lengths from their
/// distributions, each on its own substream.
pub fn generate_requests(cfg: &ServingConfig) -> Vec<Request> {
    let mut arr = Rng::substream(cfg.seed, "serve_arrivals");
    let mut lens = Rng::substream(cfg.seed, "serve_lens");
    let mut out = Vec::with_capacity(cfg.num_requests as usize);
    let mut t = 0.0f64;
    for id in 0..cfg.num_requests {
        t = next_arrival_ns(&cfg.arrival, t, &mut arr);
        let prompt_tokens = cfg.prompt.sample(&mut lens);
        let output_tokens = cfg.output.sample(&mut lens).max(1);
        out.push(Request {
            id,
            arrival_ns: t,
            prompt_tokens,
            output_tokens,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LengthDist;

    fn cfg(qps: f64) -> ServingConfig {
        let mut c = ServingConfig::new(qps, 64);
        c.seed = 42;
        c
    }

    #[test]
    fn arrivals_are_monotone_and_positive() {
        let reqs = generate_requests(&cfg(8.0));
        assert_eq!(reqs.len(), 64);
        assert!(reqs[0].arrival_ns > 0.0);
        for w in reqs.windows(2) {
            assert!(w[1].arrival_ns > w[0].arrival_ns);
        }
    }

    #[test]
    fn arrivals_deterministic_per_seed() {
        let a = generate_requests(&cfg(8.0));
        let b = generate_requests(&cfg(8.0));
        assert_eq!(a, b);
        let mut other = cfg(8.0);
        other.seed = 43;
        let c = generate_requests(&other);
        assert_ne!(a, c, "different seed must give a different stream");
    }

    #[test]
    fn poisson_mean_rate_roughly_matches_qps() {
        let mut c = cfg(20.0);
        c.num_requests = 4000;
        let reqs = generate_requests(&c);
        let span_s = reqs.last().unwrap().arrival_ns * 1e-9;
        let rate = reqs.len() as f64 / span_s;
        assert!(
            (rate - 20.0).abs() / 20.0 < 0.1,
            "empirical rate {rate} vs 20"
        );
    }

    #[test]
    fn trace_rate_concentrates_arrivals_in_hot_seconds() {
        let mut c = cfg(1.0);
        c.num_requests = 2000;
        // 10 rps in even seconds, 0 in odd seconds.
        c.arrival = crate::config::ArrivalProcess::Trace {
            qps_per_sec: vec![10.0, 0.0],
        };
        let reqs = generate_requests(&c);
        for r in &reqs {
            let sec = (r.arrival_ns / 1e9) as u64;
            assert_eq!(sec % 2, 0, "arrival in a zero-rate second");
        }
        for w in reqs.windows(2) {
            assert!(w[1].arrival_ns > w[0].arrival_ns);
        }
    }

    #[test]
    fn lengths_respect_distribution_bounds() {
        let mut c = cfg(4.0);
        c.prompt = LengthDist::lognormal(100, 1.0, 50, 150);
        c.output = LengthDist::fixed(7);
        let reqs = generate_requests(&c);
        assert!(reqs
            .iter()
            .all(|r| (50..=150).contains(&r.prompt_tokens)));
        assert!(reqs.iter().all(|r| r.output_tokens == 7));
    }
}
