//! The continuous-batching scheduler (DESIGN.md §10).
//!
//! The batcher plans the serving run as a sequence of *steps* — the
//! scheduling quantum of a continuous-batching engine. Each step ingests up
//! to `prefill_chunk` prompt tokens (chunked prefill, FIFO by arrival) and
//! decodes one token for every in-flight request; a request's first output
//! token is produced by the step that finishes its prompt, and the request
//! leaves the batch at the step that produces its last token. Admission is
//! gated by the KV-cache budget — a request reserves
//! `kv_bytes_per_token × (prompt + output)` at admission (no preemption) —
//! and by the decode-batch cap.
//!
//! The plan is a *pure function* of (requests, model, gpu, config): the
//! batcher uses an analytic roofline estimate of step cost only to decide
//! which step each open-loop arrival can first be admitted into. The
//! authoritative timestamps come from the engine replaying the lowered
//! program ([`super::lower`]); per-request latencies are then measured off
//! the ordinary trace.

use crate::config::{GpuSpec, ModelConfig, ServingConfig};
use crate::serve::arrivals::Request;
use std::collections::VecDeque;

/// One planned scheduler step.
#[derive(Debug, Clone, PartialEq)]
pub struct StepPlan {
    /// Step index (becomes the trace `iter`).
    pub step: u32,
    /// Open-loop wait before this step (ns): time the host sat idle
    /// because no admitted request had work — 0 under load.
    pub idle_gap_ns: f64,
    /// Absolute wall-clock deadline of that wait (the next arrival's
    /// timestamp); 0 when there is no wait. Lowered as an absolute
    /// host wait so the engine's clock re-anchors to the open-loop
    /// arrival timeline at every idle point.
    pub wait_until_ns: f64,
    /// Prompt tokens ingested this step, per request: (request id, tokens).
    pub prefill: Vec<(u32, u64)>,
    /// Requests decoding one token this step (in-flight before this step).
    pub decode: Vec<u32>,
    /// KV bytes read by this step's decode batch (full contexts).
    pub decode_kv_bytes: f64,
    /// KV bytes resident at this step (reserved by admitted requests).
    pub kv_resident_bytes: f64,
}

impl StepPlan {
    pub fn prefill_tokens(&self) -> u64 {
        self.prefill.iter().map(|(_, t)| t).sum()
    }

    pub fn decode_batch(&self) -> u32 {
        self.decode.len() as u32
    }
}

/// Per-request scheduling record: which steps bound the request's life.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestRecord {
    pub req: Request,
    /// Step that first ingested prompt tokens.
    pub admit_step: u32,
    /// Step whose end produces the first output token (TTFT anchor).
    pub first_token_step: u32,
    /// Step whose end produces the last output token (e2e anchor).
    pub completion_step: u32,
}

/// The full planned serving run.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchSchedule {
    pub steps: Vec<StepPlan>,
    /// One record per request, in request-id order.
    pub records: Vec<RequestRecord>,
    /// Aggregate KV budget (bytes, across the whole tensor-parallel group).
    pub kv_capacity_bytes: f64,
    /// High-water mark of reserved KV bytes.
    pub kv_peak_bytes: f64,
}

/// Analytic roofline estimate of step cost — the batcher's internal clock
/// for placing open-loop arrivals. Deliberately *optimistic* (pure
/// roofline at nominal peaks, no collective cost): the estimated clock
/// must run behind the engine's wall clock, so a request admitted at
/// estimated time `t` has already arrived when the engine replays the
/// step — that is what keeps measured TTFT positive. The engine's fluid
/// model (contention, DVFS, host jitter, collectives) decides the real
/// timeline; idle points re-anchor the two clocks via absolute waits.
#[derive(Debug, Clone)]
pub struct StepCost {
    gpu: GpuSpec,
    model: ModelConfig,
    /// Tensor-parallel world size sharing the step's work.
    world: f64,
}

/// Fixed scheduler + dispatch overhead per step in the estimate (ns) —
/// below the engine's real per-step overhead, by design (see above).
const STEP_FIXED_NS: f64 = 25_000.0;

impl StepCost {
    pub fn new(gpu: GpuSpec, model: ModelConfig, world: u32) -> Self {
        Self {
            gpu,
            model,
            world: world.max(1) as f64,
        }
    }

    /// Dense-model flops to process `tokens` tokens in parallel.
    fn linear_flops(&self, tokens: f64) -> f64 {
        2.0 * self.model.param_count() as f64 * tokens
    }

    /// Estimated wall time of one step (ns).
    pub fn step_ns(&self, prefill_tokens: u64, decode_batch: u32, kv_read_bytes: f64) -> f64 {
        let mut ns = STEP_FIXED_NS;
        if prefill_tokens > 0 {
            // Compute-bound, at full nominal peak (optimistic).
            let fl = self.linear_flops(prefill_tokens as f64) / self.world;
            ns += fl / self.gpu.peak_bf16_flops * 1e9;
        }
        if decode_batch > 0 {
            // Bandwidth-bound: one full weight read plus the batch's KV,
            // at full nominal bandwidth (optimistic).
            let w = self.model.param_count() as f64 * self.model.dtype_bytes as f64;
            let bytes = (w + kv_read_bytes) / self.world;
            ns += bytes / self.gpu.hbm_bw * 1e9;
        }
        ns
    }
}

/// Plan the serving run. `world` is the tensor-parallel group size (the
/// cluster's world size — every rank runs every step). Panics if any
/// single request's KV reservation exceeds the whole budget (it could
/// never be admitted).
pub fn plan_schedule(
    requests: &[Request],
    model: &ModelConfig,
    gpu: &GpuSpec,
    cfg: &ServingConfig,
    world: u32,
) -> BatchSchedule {
    let kv_tok = ServingConfig::kv_bytes_per_token(model);
    let kv_cap = cfg.kv_frac * gpu.hbm_bytes as f64 * world.max(1) as f64;
    for r in requests {
        assert!(
            r.total_tokens() as f64 * kv_tok <= kv_cap,
            "request {} reserves more KV than the whole budget",
            r.id
        );
    }
    let cost = StepCost::new(gpu.clone(), model.clone(), world);

    // Per-request in-flight state.
    #[derive(Clone, Copy)]
    struct Inflight {
        id: u32,
        prompt_left: u64,
        generated: u64,
        output: u64,
        context: u64, // tokens materialized in KV so far
    }

    let mut waiting: VecDeque<&Request> = VecDeque::new();
    let mut next_arrival = 0usize; // index into `requests`
    let mut prefilling: VecDeque<Inflight> = VecDeque::new();
    let mut decoding: Vec<Inflight> = Vec::new();
    let mut kv_used = 0.0f64;
    let mut kv_peak = 0.0f64;

    let mut records: Vec<RequestRecord> = requests
        .iter()
        .map(|&req| RequestRecord {
            req,
            admit_step: u32::MAX,
            first_token_step: u32::MAX,
            completion_step: u32::MAX,
        })
        .collect();
    let mut steps: Vec<StepPlan> = Vec::new();
    let mut t = 0.0f64; // estimated wall clock, ns
    let mut done = 0usize;

    // Generous termination bound: every request needs at most
    // ceil(prompt/chunk) + output steps, plus one idle step each.
    let max_steps: u64 = requests
        .iter()
        .map(|r| r.prompt_tokens.div_ceil(cfg.prefill_chunk.max(1)) + r.output_tokens + 2)
        .sum::<u64>()
        .max(16);

    while done < requests.len() {
        assert!(
            (steps.len() as u64) < max_steps,
            "batcher failed to converge (step bound {max_steps})"
        );
        // Open-loop: pull every arrival at or before the estimated clock.
        while next_arrival < requests.len()
            && requests[next_arrival].arrival_ns <= t
        {
            waiting.push_back(&requests[next_arrival]);
            next_arrival += 1;
        }
        // Nothing in flight and nothing waiting: idle until next arrival.
        let mut idle_gap_ns = 0.0;
        let mut wait_until_ns = 0.0;
        if prefilling.is_empty() && decoding.is_empty() && waiting.is_empty() {
            let next = requests[next_arrival].arrival_ns;
            idle_gap_ns = next - t;
            wait_until_ns = next;
            t = next;
            waiting.push_back(&requests[next_arrival]);
            next_arrival += 1;
        }

        let step = steps.len() as u32;
        // Admission: FIFO while KV and batch slots allow.
        while let Some(&r) = waiting.front() {
            let in_flight = (prefilling.len() + decoding.len()) as u32;
            let demand = r.total_tokens() as f64 * kv_tok;
            if in_flight >= cfg.max_batch || kv_used + demand > kv_cap {
                break;
            }
            waiting.pop_front();
            kv_used += demand;
            kv_peak = kv_peak.max(kv_used);
            records[r.id as usize].admit_step = step;
            prefilling.push_back(Inflight {
                id: r.id,
                prompt_left: r.prompt_tokens,
                generated: 0,
                output: r.output_tokens,
                context: 0,
            });
        }

        // Decode lane: every in-flight decoded request emits one token.
        let mut decode_ids = Vec::with_capacity(decoding.len());
        let mut decode_kv_bytes = 0.0;
        let mut still_decoding = Vec::with_capacity(decoding.len());
        for mut f in decoding.drain(..) {
            decode_ids.push(f.id);
            decode_kv_bytes += f.context as f64 * kv_tok;
            f.generated += 1;
            f.context += 1;
            if f.generated == f.output {
                records[f.id as usize].completion_step = step;
                let r = &records[f.id as usize].req;
                kv_used -= r.total_tokens() as f64 * kv_tok;
                done += 1;
            } else {
                still_decoding.push(f);
            }
        }
        decoding = still_decoding;

        // Prefill lane: chunked, FIFO.
        let mut budget = cfg.prefill_chunk.max(1);
        let mut prefill = Vec::new();
        while budget > 0 {
            let Some(f) = prefilling.front_mut() else { break };
            let take = f.prompt_left.min(budget);
            prefill.push((f.id, take));
            f.prompt_left -= take;
            f.context += take;
            budget -= take;
            if f.prompt_left == 0 {
                // The prompt's last chunk produces the first output token.
                let mut f = prefilling.pop_front().expect("front exists");
                f.generated = 1;
                f.context += 1;
                records[f.id as usize].first_token_step = step;
                if f.generated == f.output {
                    records[f.id as usize].completion_step = step;
                    let r = &records[f.id as usize].req;
                    kv_used -= r.total_tokens() as f64 * kv_tok;
                    done += 1;
                } else {
                    decoding.push(f);
                }
            }
        }

        let prefill_tokens: u64 = prefill.iter().map(|(_, t)| t).sum();
        t += cost.step_ns(prefill_tokens, decode_ids.len() as u32, decode_kv_bytes);
        steps.push(StepPlan {
            step,
            idle_gap_ns,
            wait_until_ns,
            prefill,
            decode: decode_ids,
            decode_kv_bytes,
            kv_resident_bytes: kv_used,
        });
    }

    debug_assert!(records
        .iter()
        .all(|r| r.completion_step != u32::MAX && r.first_token_step != u32::MAX));
    BatchSchedule {
        steps,
        records,
        kv_capacity_bytes: kv_cap,
        kv_peak_bytes: kv_peak,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::arrivals::generate_requests;

    fn plan(qps: f64, n: u32) -> BatchSchedule {
        let mut cfg = ServingConfig::new(qps, n);
        cfg.seed = 11;
        let model = ModelConfig::mini();
        let reqs = generate_requests(&cfg);
        plan_schedule(&reqs, &model, &GpuSpec::mi300x(), &cfg, 8)
    }

    #[test]
    fn every_request_is_scheduled_in_order() {
        let s = plan(16.0, 48);
        assert_eq!(s.records.len(), 48);
        for r in &s.records {
            assert!(r.admit_step <= r.first_token_step);
            assert!(r.first_token_step <= r.completion_step);
            assert!((r.completion_step as usize) < s.steps.len());
        }
        // FIFO admission: admit steps are monotone in arrival order.
        for w in s.records.windows(2) {
            assert!(w[0].admit_step <= w[1].admit_step);
        }
    }

    #[test]
    fn step_accounting_is_consistent() {
        let s = plan(16.0, 48);
        let total_prefill: u64 = s.steps.iter().map(|p| p.prefill_tokens()).sum();
        let total_prompt: u64 =
            s.records.iter().map(|r| r.req.prompt_tokens).sum();
        assert_eq!(total_prefill, total_prompt);
        // Every decode slot corresponds to one generated token beyond the
        // prefill-produced first token.
        let total_decode: u64 =
            s.steps.iter().map(|p| p.decode_batch() as u64).sum();
        let total_output: u64 =
            s.records.iter().map(|r| r.req.output_tokens).sum();
        assert_eq!(total_decode, total_output - s.records.len() as u64);
        assert!(s.kv_peak_bytes <= s.kv_capacity_bytes);
    }

    #[test]
    fn schedule_is_deterministic() {
        assert_eq!(plan(16.0, 48), plan(16.0, 48));
    }

    #[test]
    fn low_load_leaves_idle_gaps_high_load_does_not() {
        let lo = plan(0.5, 12);
        let hi = plan(500.0, 12);
        let gaps = |s: &BatchSchedule| {
            s.steps.iter().filter(|p| p.idle_gap_ns > 0.0).count()
        };
        assert!(gaps(&lo) > gaps(&hi));
        // At 500 qps all requests are present almost immediately: at most
        // the initial gap remains.
        assert!(gaps(&hi) <= 1);
    }

    #[test]
    fn batch_cap_limits_inflight() {
        let mut cfg = ServingConfig::new(1000.0, 32);
        cfg.seed = 5;
        cfg.max_batch = 4;
        let model = ModelConfig::mini();
        let reqs = generate_requests(&cfg);
        let s = plan_schedule(&reqs, &model, &GpuSpec::mi300x(), &cfg, 8);
        for p in &s.steps {
            let prefill_reqs: std::collections::BTreeSet<u32> =
                p.prefill.iter().map(|&(id, _)| id).collect();
            assert!(prefill_reqs.len() + p.decode.len() <= 4 + 4);
            assert!(p.decode_batch() <= 4);
        }
    }
}
