//! Per-request serving metrics (DESIGN.md §10): TTFT / TPOT / end-to-end
//! latency measured off the engine trace, latency percentiles, goodput and
//! energy-per-request.
//!
//! Time base: every latency is `step-end wall clock − open-loop arrival
//! timestamp`, both in the engine's nanosecond clock (the open-loop waits
//! are replayed as host work, so arrivals and step bounds share one
//! timeline). TTFT anchors on the step that finishes the request's prompt;
//! end-to-end on the step that emits its last token — TTFT ≤ e2e by
//! construction.

use crate::config::ServingConfig;
use crate::serve::batcher::{BatchSchedule, RequestRecord};

/// Linearly-interpolated percentile (type-7, like `stats::quantile`) over
/// an unsorted slice, ordered by `f64::total_cmp` so NaN payloads and
/// signed zeros have a defined, deterministic order. Returns 0.0 for an
/// empty slice; a single element is every percentile of itself.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    percentile_sorted(&v, q)
}

/// [`percentile`] over an already `total_cmp`-sorted slice.
pub fn percentile_sorted(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (xs.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        return xs[lo];
    }
    let frac = pos - lo as f64;
    xs[lo] + (xs[hi] - xs[lo]) * frac
}

/// p50 / p99 / mean / max of a latency population.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub p50: f64,
    pub p99: f64,
    pub mean: f64,
    pub max: f64,
}

impl LatencySummary {
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self {
                p50: 0.0,
                p99: 0.0,
                mean: 0.0,
                max: 0.0,
            };
        }
        let mut v = xs.to_vec();
        v.sort_by(f64::total_cmp);
        Self {
            p50: percentile_sorted(&v, 0.50),
            p99: percentile_sorted(&v, 0.99),
            mean: crate::util::stats::mean(&v),
            max: *v.last().expect("non-empty"),
        }
    }
}

/// One request's measured latencies (all ns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestLatency {
    pub id: u32,
    pub arrival_ns: f64,
    /// Time to first token: first-token step end − arrival.
    pub ttft_ns: f64,
    /// End-to-end: completion step end − arrival.
    pub e2e_ns: f64,
    /// Time per output token after the first: (e2e − ttft)/(out − 1);
    /// 0 for single-token outputs.
    pub tpot_ns: f64,
    pub output_tokens: u64,
}

/// Join the scheduler's per-request records against the engine's per-step
/// wall-clock bounds (`iter_bounds[step] = (start, end)`).
pub fn request_latencies(
    records: &[RequestRecord],
    iter_bounds: &[(f64, f64)],
) -> Vec<RequestLatency> {
    records
        .iter()
        .map(|r| {
            let ttft_ns = iter_bounds[r.first_token_step as usize].1 - r.req.arrival_ns;
            let e2e_ns = iter_bounds[r.completion_step as usize].1 - r.req.arrival_ns;
            let tpot_ns = if r.req.output_tokens > 1 {
                (e2e_ns - ttft_ns) / (r.req.output_tokens - 1) as f64
            } else {
                0.0
            };
            RequestLatency {
                id: r.req.id,
                arrival_ns: r.req.arrival_ns,
                ttft_ns,
                e2e_ns,
                tpot_ns,
                output_tokens: r.req.output_tokens,
            }
        })
        .collect()
}

/// The aggregate serving report for one run — what the figures, campaign
/// summaries and what-if rankings consume.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingReport {
    pub label: String,
    pub offered_qps: f64,
    pub num_requests: u32,
    pub steps: u32,
    /// Wall-clock span of the run (first step start → last step end), s.
    pub makespan_s: f64,
    pub ttft_ms: LatencySummary,
    pub tpot_ms: LatencySummary,
    pub e2e_ms: LatencySummary,
    /// Completed requests per second of makespan.
    pub goodput_rps: f64,
    /// Completed requests meeting the TTFT SLO, per second of makespan.
    pub slo_goodput_rps: f64,
    /// Generated (output) tokens per second of makespan.
    pub output_tok_s: f64,
    /// Whole-cluster energy over the run divided by requests, J.
    pub energy_per_request_j: f64,
    /// Generated tokens per joule (the serving twin of tokens-per-joule).
    pub tok_per_joule: f64,
    /// KV high-water mark as a fraction of the KV budget.
    pub kv_peak_frac: f64,
}

impl ServingReport {
    pub fn build(
        cfg: &ServingConfig,
        sched: &BatchSchedule,
        lats: &[RequestLatency],
        iter_bounds: &[(f64, f64)],
        energy_j: f64,
    ) -> Self {
        let to_ms = |ns: f64| ns * 1e-6;
        let ttft: Vec<f64> = lats.iter().map(|l| to_ms(l.ttft_ns)).collect();
        let tpot: Vec<f64> = lats.iter().map(|l| to_ms(l.tpot_ns)).collect();
        let e2e: Vec<f64> = lats.iter().map(|l| to_ms(l.e2e_ns)).collect();
        let makespan_s = iter_bounds
            .last()
            .map(|b| (b.1 - iter_bounds[0].0) * 1e-9)
            .unwrap_or(0.0)
            .max(1e-12);
        let n = lats.len() as f64;
        let met_slo = ttft.iter().filter(|&&t| t <= cfg.slo_ttft_ms).count() as f64;
        let out_tokens: u64 = lats.iter().map(|l| l.output_tokens).sum();
        Self {
            label: cfg.label(),
            offered_qps: cfg.arrival.mean_qps(),
            num_requests: lats.len() as u32,
            steps: sched.steps.len() as u32,
            makespan_s,
            ttft_ms: LatencySummary::of(&ttft),
            tpot_ms: LatencySummary::of(&tpot),
            e2e_ms: LatencySummary::of(&e2e),
            goodput_rps: n / makespan_s,
            slo_goodput_rps: met_slo / makespan_s,
            output_tok_s: out_tokens as f64 / makespan_s,
            energy_per_request_j: if n > 0.0 { energy_j / n } else { 0.0 },
            tok_per_joule: if energy_j > 0.0 {
                out_tokens as f64 / energy_j
            } else {
                0.0
            },
            kv_peak_frac: if sched.kv_capacity_bytes > 0.0 {
                sched.kv_peak_bytes / sched.kv_capacity_bytes
            } else {
                0.0
            },
        }
    }

    /// Hand-rolled JSON object (the repo has no serde; mirrors the
    /// campaign summary / benchkit idiom).
    pub fn to_json(&self) -> String {
        let s = |l: &LatencySummary| {
            format!(
                "{{\"p50\":{:.6},\"p99\":{:.6},\"mean\":{:.6},\"max\":{:.6}}}",
                l.p50, l.p99, l.mean, l.max
            )
        };
        format!(
            "{{\"label\":\"{}\",\"offered_qps\":{:.6},\"num_requests\":{},\
             \"steps\":{},\"makespan_s\":{:.6},\"ttft_ms\":{},\"tpot_ms\":{},\
             \"e2e_ms\":{},\"goodput_rps\":{:.6},\"slo_goodput_rps\":{:.6},\
             \"output_tok_s\":{:.3},\"energy_per_request_j\":{:.6},\
             \"tok_per_joule\":{:.6},\"kv_peak_frac\":{:.6}}}",
            self.label,
            self.offered_qps,
            self.num_requests,
            self.steps,
            self.makespan_s,
            s(&self.ttft_ms),
            s(&self.tpot_ms),
            s(&self.e2e_ms),
            self.goodput_rps,
            self.slo_goodput_rps,
            self.output_tok_s,
            self.energy_per_request_j,
            self.tok_per_joule,
            self.kv_peak_frac,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_exact_on_known_inputs() {
        // 1..=100: p50 interpolates to 50.5, p99 to 99.01.
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 0.50) - 50.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.99) - 99.01).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        // Five elements: p50 is the middle element exactly.
        let v = [9.0, 1.0, 5.0, 3.0, 7.0];
        assert_eq!(percentile(&v, 0.50), 5.0);
        assert!((percentile(&v, 0.25) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_edge_cases() {
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert_eq!(percentile(&[42.0], 0.5), 42.0);
        assert_eq!(percentile(&[42.0], 0.99), 42.0);
        // Quantiles outside [0,1] clamp instead of panicking.
        assert_eq!(percentile(&[1.0, 2.0], -0.5), 1.0);
        assert_eq!(percentile(&[1.0, 2.0], 1.5), 2.0);
    }

    #[test]
    fn percentile_total_cmp_handles_signed_zero() {
        // total_cmp orders -0.0 before +0.0; partial_cmp sorts would leave
        // them wherever they started.
        let xs = [0.0, -0.0, -1.0, 1.0];
        let mut v = xs.to_vec();
        v.sort_by(f64::total_cmp);
        assert_eq!(v[0], -1.0);
        assert!(v[1].is_sign_negative() && v[1] == 0.0);
        assert_eq!(percentile(&xs, 1.0), 1.0);
    }

    #[test]
    fn summary_of_empty_and_single() {
        let e = LatencySummary::of(&[]);
        assert_eq!((e.p50, e.p99, e.mean, e.max), (0.0, 0.0, 0.0, 0.0));
        let s = LatencySummary::of(&[3.5]);
        assert_eq!((s.p50, s.p99, s.mean, s.max), (3.5, 3.5, 3.5, 3.5));
    }

    #[test]
    fn tpot_zero_for_single_token_outputs() {
        use crate::serve::arrivals::Request;
        let rec = RequestRecord {
            req: Request {
                id: 0,
                arrival_ns: 100.0,
                prompt_tokens: 8,
                output_tokens: 1,
            },
            admit_step: 0,
            first_token_step: 0,
            completion_step: 0,
        };
        let bounds = [(0.0, 1_000.0)];
        let l = request_latencies(&[rec], &bounds);
        assert_eq!(l[0].ttft_ns, 900.0);
        assert_eq!(l[0].e2e_ns, 900.0);
        assert_eq!(l[0].tpot_ns, 0.0);
    }
}
