//! Lower a [`BatchSchedule`] to an engine [`Program`] (DESIGN.md §10).
//!
//! Each scheduler step becomes a short dispatch burst on every rank: an
//! optional open-loop wait (host idles until the next arrival), scheduler
//! bookkeeping, a fused compute-bound prefill kernel over the step's
//! prompt-token chunk, a fused bandwidth-bound decode kernel over the
//! in-flight batch, one tensor-parallel all-reduce combining the step's
//! partial activations, and a device sync (the step barrier). The engine
//! then replays this program under its ordinary fluid-flow contention /
//! DVFS / host-jitter machinery, so serving runs produce ordinary traces
//! and per-step `iter_bounds` — the step's wall-clock bounds from which
//! TTFT and end-to-end latency are measured.

use crate::config::{ModelConfig, ServingConfig};
use crate::fsdp::schedule::{
    CollectiveDesc, CommGroup, CommScope, DispatchItem, HostSync, Program, ProgKernel,
};
use crate::model::graph::KernelDesc;
use crate::model::ops::{OpRef, OpType, Phase};
use crate::serve::batcher::BatchSchedule;

/// Host-side scheduler bookkeeping per step (admission, block allocation,
/// sampler bookkeeping), ns.
const SCHED_HOST_NS: f64 = 30_000.0;

/// Lower the planned schedule onto `world` tensor-parallel ranks. Every
/// rank runs the same program (TP replicates the dispatch stream; the
/// engine's rendezvous machinery aligns collective ids across ranks).
pub fn lower_schedule(
    sched: &BatchSchedule,
    model: &ModelConfig,
    _cfg: &ServingConfig,
    world: u32,
) -> Program {
    let world_f = world.max(1) as f64;
    let weight_bytes = (model.param_count() * model.dtype_bytes) as f64;
    let act_row_bytes = (model.hidden * model.dtype_bytes) as f64;

    let mut items: Vec<DispatchItem> = Vec::with_capacity(sched.steps.len() * 6);
    let mut next_comm_id = 0u64;
    let mut kernel_count = 0u64;

    for p in &sched.steps {
        if p.idle_gap_ns > 0.0 {
            // Absolute open-loop wait: the engine advances the host clock
            // to the arrival's wall-clock deadline (unscaled, not CPU
            // time), re-anchoring the engine timeline to the arrival
            // timeline at every idle point.
            items.push(DispatchItem::HostWork {
                ns: p.wait_until_ns,
                tag: "serve_wait_until",
            });
        }
        items.push(DispatchItem::HostWork {
            ns: SCHED_HOST_NS,
            tag: "serve_sched",
        });

        let prefill_tokens = p.prefill_tokens();
        if prefill_tokens > 0 {
            // Compute-bound prompt ingestion: the step's chunk runs the
            // whole dense stack, 1/world of it per TP rank.
            let flops = 2.0 * model.param_count() as f64 * prefill_tokens as f64 / world_f;
            let bytes =
                (weight_bytes + prefill_tokens as f64 * act_row_bytes) / world_f;
            kernel_count += 1;
            items.push(DispatchItem::Kernel(ProgKernel {
                desc: KernelDesc {
                    name: "serve_prefill_chunk".into(),
                    op: OpRef::new(OpType::Prefill, Phase::Forward),
                    layer: None,
                    kind: OpType::Prefill.kind(),
                    flops,
                    bytes,
                    gemm_mnk: Some((prefill_tokens, model.ffn, model.hidden)),
                },
                iter: p.step,
                wait_comm: None,
            }));
        }

        let decode_batch = p.decode_batch();
        if decode_batch > 0 {
            // Bandwidth-bound token generation: one full weight sweep plus
            // the batch's accumulated KV reads, 1/world per rank.
            let bytes = (weight_bytes + p.decode_kv_bytes) / world_f;
            let flops =
                2.0 * model.param_count() as f64 * decode_batch as f64 / world_f;
            kernel_count += 1;
            items.push(DispatchItem::Kernel(ProgKernel {
                desc: KernelDesc {
                    name: "serve_decode_step".into(),
                    op: OpRef::new(OpType::Decode, Phase::Forward),
                    layer: None,
                    kind: OpType::Decode.kind(),
                    flops,
                    bytes,
                    gemm_mnk: None,
                },
                iter: p.step,
                wait_comm: None,
            }));
        }

        let step_tokens = prefill_tokens + decode_batch as u64;
        if step_tokens > 0 && world > 1 {
            // One fused TP all-reduce of the step's activations (per-layer
            // all-reduces folded into a single payload: layers × hidden ×
            // tokens). Anchored behind the step's compute via wait_seq.
            let bytes = (model.layers * model.hidden * step_tokens * model.dtype_bytes)
                as f64;
            items.push(DispatchItem::Comm(CollectiveDesc {
                id: next_comm_id,
                op: OpRef::new(OpType::AllReduce, Phase::Forward),
                scope: CommScope::Head,
                group: CommGroup::World,
                iter: p.step,
                bytes,
                wait_seq: kernel_count,
            }));
            next_comm_id += 1;
        }

        // Step barrier: the sampler needs the step's logits on the host.
        items.push(DispatchItem::Sync(HostSync::Device));
    }

    Program {
        items,
        num_collectives: next_comm_id,
        iterations: sched.steps.len() as u32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;
    use crate::serve::arrivals::generate_requests;
    use crate::serve::batcher::plan_schedule;

    fn lowered(world: u32) -> (BatchSchedule, Program) {
        let mut cfg = ServingConfig::new(16.0, 24);
        cfg.seed = 3;
        let model = ModelConfig::mini();
        let reqs = generate_requests(&cfg);
        let sched = plan_schedule(&reqs, &model, &GpuSpec::mi300x(), &cfg, world);
        let prog = lower_schedule(&sched, &model, &cfg, world);
        (sched, prog)
    }

    #[test]
    fn one_sync_and_sched_per_step() {
        let (sched, prog) = lowered(8);
        let syncs = prog
            .items
            .iter()
            .filter(|i| matches!(i, DispatchItem::Sync(HostSync::Device)))
            .count();
        assert_eq!(syncs, sched.steps.len());
        let scheds = prog
            .items
            .iter()
            .filter(|i| {
                matches!(i, DispatchItem::HostWork { tag, .. } if *tag == "serve_sched")
            })
            .count();
        assert_eq!(scheds, sched.steps.len());
        assert_eq!(prog.iterations as usize, sched.steps.len());
    }

    #[test]
    fn kernels_match_step_structure() {
        let (sched, prog) = lowered(8);
        let prefills = prog
            .kernels()
            .filter(|k| k.desc.op.op == OpType::Prefill)
            .count();
        let decodes = prog
            .kernels()
            .filter(|k| k.desc.op.op == OpType::Decode)
            .count();
        assert_eq!(
            prefills,
            sched.steps.iter().filter(|p| p.prefill_tokens() > 0).count()
        );
        assert_eq!(
            decodes,
            sched.steps.iter().filter(|p| p.decode_batch() > 0).count()
        );
        // Prefill is a GEMM with honest shape; decode is bandwidth-bound.
        for k in prog.kernels() {
            match k.desc.op.op {
                OpType::Prefill => assert!(k.desc.gemm_mnk.is_some()),
                OpType::Decode => assert!(k.desc.gemm_mnk.is_none()),
                other => panic!("unexpected serving op {other:?}"),
            }
        }
    }

    #[test]
    fn collectives_are_dense_world_allreduces_behind_compute() {
        let (_, prog) = lowered(8);
        let mut expect = 0u64;
        for c in prog.collectives() {
            assert_eq!(c.id, expect);
            expect += 1;
            assert_eq!(c.op.op, OpType::AllReduce);
            assert_eq!(c.group, CommGroup::World);
            assert!(c.bytes > 0.0);
            assert!(c.wait_seq > 0, "TP all-reduce must anchor behind compute");
        }
        assert_eq!(prog.num_collectives, expect);
        assert!(expect > 0);
    }

    #[test]
    fn single_rank_emits_no_collectives() {
        let (_, prog) = lowered(1);
        assert_eq!(prog.num_collectives, 0);
        assert_eq!(prog.collectives().count(), 0);
    }

    #[test]
    fn open_loop_waits_survive_lowering() {
        let (sched, prog) = lowered(8);
        let gaps = sched
            .steps
            .iter()
            .filter(|p| p.idle_gap_ns > 0.0)
            .count();
        let waits: Vec<f64> = prog
            .items
            .iter()
            .filter_map(|i| match i {
                DispatchItem::HostWork { ns, tag } if *tag == "serve_wait_until" => {
                    Some(*ns)
                }
                _ => None,
            })
            .collect();
        assert_eq!(gaps, waits.len());
        // Deadlines are the absolute arrival timestamps: positive and
        // strictly increasing.
        assert!(waits.iter().all(|&w| w > 0.0));
        for w in waits.windows(2) {
            assert!(w[1] > w[0]);
        }
    }
}
