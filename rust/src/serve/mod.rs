//! Inference/serving subsystem (DESIGN.md §10): seeded open-loop request
//! arrivals ([`arrivals`]), the continuous-batching step planner
//! ([`batcher`]), lowering of the plan to an ordinary engine dispatch
//! program ([`lower`]), and per-request latency / goodput / energy metrics
//! ([`metrics`]).
//!
//! Serving runs reuse the whole training stack: the lowered program
//! executes on [`Engine::with_program`] under the same fluid-flow
//! contention, DVFS-governor and host-jitter machinery, produces an
//! ordinary [`Trace`] (steps are `iter`s), and the KV-cache residency
//! timeline drives the allocator's HBM power-noise statistics exactly like
//! the training gather pattern does.

pub mod arrivals;
pub mod batcher;
pub mod lower;
pub mod metrics;

pub use arrivals::{generate_requests, Request};
pub use batcher::{plan_schedule, BatchSchedule, RequestRecord, StepCost, StepPlan};
pub use lower::lower_schedule;
pub use metrics::{
    percentile, percentile_sorted, request_latencies, LatencySummary,
    RequestLatency, ServingReport,
};

use crate::config::{
    FsdpVersion, ModelConfig, ServingConfig, Topology, WorkloadConfig,
};
use crate::fsdp::{simulate_kv_pattern, AllocStats};
use crate::sim::{Engine, EngineParams};
use crate::trace::event::{PowerTrace, Trace};
use std::sync::Arc;

/// Paged KV-cache block size (bytes) for the allocator replay.
const KV_BLOCK_BYTES: u64 = 2 << 20;

/// One complete serving run: the ordinary engine trace plus the serving
/// overlays (schedule, per-request latencies, aggregate report).
#[derive(Debug)]
pub struct ServingOutput {
    pub trace: Trace,
    pub power: PowerTrace,
    pub schedule: BatchSchedule,
    pub latencies: Vec<RequestLatency>,
    pub report: ServingReport,
    /// Per-step wall-clock bounds (the engine's iter bounds).
    pub iter_bounds: Vec<(f64, f64)>,
    pub alloc: AllocStats,
    /// Per-rank governor-integrated joules (PR 5 power plumbing).
    pub gov_energy_j: Vec<f64>,
}

/// The synthetic [`WorkloadConfig`] a serving run drives the engine with:
/// one "iteration" per scheduler step, no warmup, no optimizer phase.
/// FSDPv2 allocator semantics match the paged KV pool (deterministic
/// frees).
pub fn serving_workload(scfg: &ServingConfig, steps: u32) -> WorkloadConfig {
    let mut wl = WorkloadConfig::new(scfg.max_batch as u64, scfg.prompt.mean, FsdpVersion::V2);
    wl.iterations = steps;
    wl.warmup = 0;
    wl.optimizer = false;
    wl.seed = scfg.seed;
    wl
}

/// Run one serving scenario end to end on `topo`: generate the seeded
/// request stream, plan the continuous-batching schedule, lower it to a
/// dispatch program, execute it on the engine, and measure per-request
/// latencies off the trace. Deterministic: byte-identical outputs for
/// identical `(topo, model, scfg, params)`.
pub fn run_serving(
    topo: &Topology,
    model: &ModelConfig,
    scfg: &ServingConfig,
    params: EngineParams,
) -> ServingOutput {
    let world = topo.world_size();
    let requests = generate_requests(scfg);
    let schedule = plan_schedule(&requests, model, &topo.node.gpu, scfg, world);
    let program = Arc::new(lower_schedule(&schedule, model, scfg, world));

    // Per-GPU KV residency timeline -> allocator -> HBM power noise.
    let resident: Vec<f64> = schedule
        .steps
        .iter()
        .map(|p| p.kv_resident_bytes / world.max(1) as f64)
        .collect();
    let alloc = simulate_kv_pattern(&resident, KV_BLOCK_BYTES, scfg.seed);

    let wl = serving_workload(scfg, schedule.steps.len() as u32);
    let out =
        Engine::with_program(topo.clone(), model, &wl, params, program, alloc).run();

    let mut trace = out.trace;
    trace.meta.workload = scfg.label();
    trace.meta.fsdp = "serving".into();

    let latencies = request_latencies(&schedule.records, &out.iter_bounds);
    let energy_j = out.power.sampled_energy_j(0);
    let report =
        ServingReport::build(scfg, &schedule, &latencies, &out.iter_bounds, energy_j);
    ServingOutput {
        trace,
        power: out.power,
        schedule,
        latencies,
        report,
        iter_bounds: out.iter_bounds,
        alloc: out.alloc,
        gov_energy_j: out.gov_energy_j,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ops::OpType;

    fn small_scfg() -> ServingConfig {
        let mut s = ServingConfig::new(24.0, 16);
        s.seed = 9;
        s.prompt = crate::config::LengthDist::lognormal(96, 0.5, 16, 512);
        s.output = crate::config::LengthDist::lognormal(24, 0.5, 2, 96);
        s
    }

    fn run_small() -> ServingOutput {
        run_serving(
            &Topology::single(crate::config::NodeSpec::mi300x_node()),
            &ModelConfig::mini(),
            &small_scfg(),
            EngineParams::default(),
        )
    }

    #[test]
    fn serving_trace_is_ordinary_and_labeled() {
        let out = run_small();
        assert_eq!(out.trace.meta.workload, "serve-q24.000-r16");
        assert_eq!(out.trace.meta.fsdp, "serving");
        assert_eq!(out.trace.meta.warmup, 0);
        assert_eq!(
            out.trace.meta.iterations as usize,
            out.schedule.steps.len()
        );
        assert!(!out.trace.events.is_empty());
        assert!(out
            .trace
            .events
            .iter()
            .any(|e| e.op.op == OpType::Prefill));
        assert!(out.trace.events.iter().any(|e| e.op.op == OpType::Decode));
    }

    #[test]
    fn ttft_positive_and_bounded_by_e2e() {
        let out = run_small();
        assert_eq!(out.latencies.len(), 16);
        for l in &out.latencies {
            assert!(l.ttft_ns > 0.0, "req {} TTFT {}", l.id, l.ttft_ns);
            assert!(
                l.ttft_ns <= l.e2e_ns,
                "req {} TTFT {} > e2e {}",
                l.id,
                l.ttft_ns,
                l.e2e_ns
            );
            assert!(l.tpot_ns >= 0.0);
        }
        assert!(out.report.goodput_rps > 0.0);
        assert!(out.report.energy_per_request_j > 0.0);
    }

    #[test]
    fn serving_run_is_deterministic() {
        let a = run_small();
        let b = run_small();
        assert_eq!(a.report, b.report);
        assert_eq!(a.trace.events.len(), b.trace.events.len());
        for (x, y) in a.trace.events.iter().zip(&b.trace.events) {
            assert_eq!(x.t_start.to_bits(), y.t_start.to_bits());
            assert_eq!(x.t_end.to_bits(), y.t_end.to_bits());
        }
    }

    #[test]
    fn engine_steps_end_no_earlier_than_estimate_admits() {
        // The estimate is optimistic by construction: each request's
        // first-token step must end after its arrival (TTFT > 0 above is
        // the per-request form; here we check the step clock re-anchors).
        let out = run_small();
        for p in &out.schedule.steps {
            if p.wait_until_ns > 0.0 {
                let (start, _) = out.iter_bounds[p.step as usize];
                assert!(start >= p.wait_until_ns - 1e-6);
            }
        }
    }
}
