//! PJRT runtime: loads the AOT artifacts produced by `make artifacts`
//! (python runs once, never on the request path) and executes them on the
//! xla crate's CPU client. `traced` adds the real-execution trace path that
//! feeds the same Chopper pipeline the simulator feeds.

pub mod executor;
pub mod manifest;
pub mod traced;

pub use executor::{artifacts_available, default_artifact_dir, Runtime, Tensor};
pub use manifest::{ArtifactSpec, BuildConfig, DType, Manifest, TensorSpec};
pub use traced::{traced_forward, ParamIndex, TracedForward};
