//! Real-execution trace path: run the mini-Llama forward pass *op by op*
//! through the per-operation AOT artifacts, timestamping each execution,
//! and emit the same [`Trace`] schema the simulator emits — proving the
//! Chopper pipeline is not married to the simulator (DESIGN.md §2).
//!
//! The op chain mirrors the paper's Fig. 1 exactly; the composed result is
//! validated against the monolithic `fwd.hlo.txt` graph in tests.

use crate::model::ops::{OpRef, OpType, Phase};
use crate::runtime::executor::{Runtime, Tensor};
use crate::trace::event::{Stream, Trace, TraceEvent};
use anyhow::Result;
use std::time::Instant;

/// Output of one traced forward execution.
pub struct TracedForward {
    pub logits: Tensor,
    pub trace: Trace,
}

struct Tracer {
    t0: Instant,
    events: Vec<TraceEvent>,
    seq: u64,
    iter: u32,
}

impl Tracer {
    fn run_op(
        &mut self,
        rt: &mut Runtime,
        op: OpType,
        layer: Option<u32>,
        inputs: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        let rel = format!("ops/{}.hlo.txt", op.short());
        // Ensure compilation happens outside the timed region: we measure
        // the execution, as runtime profiling would.
        rt.compile(&rel)?;
        let t_launch = self.t0.elapsed().as_nanos() as f64;
        let t_start = self.t0.elapsed().as_nanos() as f64;
        let out = rt.run(&rel, inputs)?;
        let t_end = self.t0.elapsed().as_nanos() as f64;
        self.events.push(TraceEvent {
            kernel_id: self.seq,
            gpu: 0,
            stream: Stream::Compute,
            name: format!("pjrt_{}", op.short()).into(),
            op: OpRef::new(op, Phase::Forward),
            layer,
            iter: self.iter,
            t_launch,
            t_start,
            t_end,
            seq: self.seq,
            fwd_link: None,
            freq_mhz: 0.0,
            flops: 0.0,
            bytes: inputs.iter().map(|t| t.len() as f64 * 4.0).sum(),
        });
        self.seq += 1;
        Ok(out)
    }
}

/// Parameter indices within the flat init/train_step tuple.
pub struct ParamIndex {
    pub layers: usize,
}

impl ParamIndex {
    pub const PER_LAYER: usize = 9; // attn_n, wq, wk, wv, wo, mlp_n, wg, wu, wd

    pub fn embed(&self) -> usize {
        0
    }
    pub fn layer(&self, l: usize, tensor: usize) -> usize {
        1 + l * Self::PER_LAYER + tensor
    }
    pub fn ln(&self) -> usize {
        1 + self.layers * Self::PER_LAYER
    }
    pub fn lp(&self) -> usize {
        self.ln() + 1
    }
    pub fn total(&self) -> usize {
        self.lp() + 1
    }
}

/// Run one forward pass op-by-op, producing logits + a runtime trace.
pub fn traced_forward(
    rt: &mut Runtime,
    params: &[Tensor],
    tokens: &Tensor,
    iter: u32,
) -> Result<TracedForward> {
    let cfg = rt.manifest().config.clone();
    let idx = ParamIndex { layers: cfg.layers };
    anyhow::ensure!(
        params.len() == idx.total(),
        "expected {} params, got {}",
        idx.total(),
        params.len()
    );
    let mut tr = Tracer {
        t0: Instant::now(),
        events: Vec::new(),
        seq: 0,
        iter,
    };

    // i_e
    let mut x = tr
        .run_op(
            rt,
            OpType::IE,
            None,
            &[params[idx.embed()].clone(), tokens.clone()],
        )?
        .remove(0);

    for l in 0..cfg.layers {
        let li = l as u32;
        let p = |t: usize| params[idx.layer(l, t)].clone();
        // attention block
        let normed = tr
            .run_op(rt, OpType::AttnN, Some(li), &[x.clone(), p(0)])?
            .remove(0);
        let qkv = tr.run_op(
            rt,
            OpType::QkvIp,
            Some(li),
            &[normed, p(1), p(2), p(3)],
        )?;
        let qkv = tr.run_op(rt, OpType::QkvS, Some(li), &qkv)?;
        let qkv = tr.run_op(rt, OpType::QkvT, Some(li), &qkv)?;
        let mut qk = tr.run_op(
            rt,
            OpType::QkvRe,
            Some(li),
            &[qkv[0].clone(), qkv[1].clone()],
        )?;
        qk.push(qkv[2].clone());
        let qkv = tr.run_op(rt, OpType::QkvC, Some(li), &qk)?;
        let a = tr.run_op(rt, OpType::AttnFa, Some(li), &qkv)?.remove(0);
        let a = tr.run_op(rt, OpType::AttnOr, Some(li), &[a])?.remove(0);
        let a = tr
            .run_op(rt, OpType::AttnOp, Some(li), &[a, p(4)])?
            .remove(0);
        x = tr
            .run_op(rt, OpType::AttnRa, Some(li), &[a, x])?
            .remove(0);
        // mlp block
        let normed = tr
            .run_op(rt, OpType::MlpN, Some(li), &[x.clone(), p(5)])?
            .remove(0);
        let g = tr
            .run_op(rt, OpType::MlpGp, Some(li), &[normed.clone(), p(6)])?
            .remove(0);
        let g = tr.run_op(rt, OpType::MlpGs, Some(li), &[g])?.remove(0);
        let u = tr
            .run_op(rt, OpType::MlpUp, Some(li), &[normed, p(7)])?
            .remove(0);
        let m = tr.run_op(rt, OpType::MlpGu, Some(li), &[g, u])?.remove(0);
        let m = tr
            .run_op(rt, OpType::MlpDp, Some(li), &[m, p(8)])?
            .remove(0);
        x = tr.run_op(rt, OpType::MlpRa, Some(li), &[m, x])?.remove(0);
    }

    let x = tr
        .run_op(rt, OpType::Ln, None, &[x, params[idx.ln()].clone()])?
        .remove(0);
    let logits = tr
        .run_op(rt, OpType::Lp, None, &[x, params[idx.lp()].clone()])?
        .remove(0);

    let mut trace = Trace::default();
    trace.meta.workload = format!("mini-b{}s{}", cfg.batch, cfg.seq);
    trace.meta.model = "mini".into();
    trace.meta.num_gpus = 1;
    trace.meta.iterations = iter + 1;
    trace.meta.warmup = 0;
    trace.meta.source = "pjrt".into();
    trace.events = tr.events;
    Ok(TracedForward { logits, trace })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::executor::{artifacts_available, default_artifact_dir};

    fn setup() -> Option<(Runtime, Vec<Tensor>, Tensor)> {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        let mut rt = Runtime::open(&default_artifact_dir()).unwrap();
        let params = rt.run("init.hlo.txt", &[Tensor::scalar_i32(3)]).unwrap();
        let cfg = rt.manifest().config.clone();
        let tokens: Vec<i32> = (0..cfg.batch * cfg.seq)
            .map(|i| ((i * 37 + 11) % cfg.vocab) as i32)
            .collect();
        let tok = Tensor::S32(tokens, vec![cfg.batch, cfg.seq]);
        Some((rt, params, tok))
    }

    #[test]
    fn traced_forward_matches_monolithic_graph() {
        // The composed per-op chain must produce the same logits as the
        // single lowered fwd graph — all three layers compose.
        let Some((mut rt, params, tok)) = setup() else { return };
        let traced = traced_forward(&mut rt, &params, &tok, 0).unwrap();
        let mut inputs = params.clone();
        inputs.push(tok.clone());
        let mono = rt.run("fwd.hlo.txt", &inputs).unwrap().remove(0);
        let a = traced.logits.as_f32().unwrap();
        let b = mono.as_f32().unwrap();
        assert_eq!(a.len(), b.len());
        let max_abs = a
            .iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_abs < 2e-3, "max abs diff {max_abs}");
    }

    #[test]
    fn trace_covers_fig1_taxonomy() {
        let Some((mut rt, params, tok)) = setup() else { return };
        let traced = traced_forward(&mut rt, &params, &tok, 0).unwrap();
        let t = &traced.trace;
        assert_eq!(t.meta.source, "pjrt");
        // i_e + 4 layers × 17 ops + ln + lp.
        let layers = rt.manifest().config.layers;
        assert_eq!(t.events.len(), 1 + layers * 17 + 2);
        // Timestamps monotone per seq; durations positive.
        for w in t.events.windows(2) {
            assert!(w[1].t_start >= w[0].t_end);
        }
        assert!(t.events.iter().all(|e| e.t_end > e.t_start));
    }

    #[test]
    fn chopper_pipeline_accepts_pjrt_traces() {
        // The tool cannot tell sim and pjrt traces apart.
        let Some((mut rt, params, tok)) = setup() else { return };
        let traced = traced_forward(&mut rt, &params, &tok, 0).unwrap();
        let idx = crate::chopper::TraceIndex::build(&traced.trace);
        let insts = crate::chopper::op_instances(
            &idx,
            &crate::chopper::Filter::default(),
        );
        assert!(!insts.is_empty());
        let medians = crate::chopper::aggregate::op_medians(&idx);
        assert!(medians.contains_key(&OpRef::fwd(OpType::AttnFa)));
        // Chrome-trace roundtrip too.
        let json = crate::trace::chrome::to_chrome_json(&traced.trace);
        let back = crate::trace::chrome::from_chrome_json(&json).unwrap();
        assert_eq!(back.events.len(), traced.trace.events.len());
    }
}
