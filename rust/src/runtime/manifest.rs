//! Artifact manifest parser.
//!
//! `python/compile/aot.py` writes `artifacts/MANIFEST.txt`, a line-based
//! index of every lowered HLO artifact: its path, kind, and input/output
//! tensor specs (`name:f32[2048,256]`). The Rust runtime reads this to know
//! what to feed each executable without ever importing Python.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Element type of a tensor spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    S32,
}

impl DType {
    fn parse(s: &str) -> Option<DType> {
        match s {
            "f32" => Some(DType::F32),
            "s32" => Some(DType::S32),
            _ => None,
        }
    }
}

/// One named tensor of an artifact signature.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }

    /// Parse `name:f32[2048,256]` (scalar = `name:f32[]`).
    pub fn parse(s: &str) -> Option<TensorSpec> {
        let (name, rest) = s.split_once(':')?;
        let (ty, dims) = rest.split_once('[')?;
        let dims = dims.strip_suffix(']')?;
        let dims: Vec<usize> = if dims.is_empty() {
            vec![]
        } else {
            dims.split(',').map(|d| d.parse().ok()).collect::<Option<_>>()?
        };
        Some(TensorSpec {
            name: name.to_string(),
            dtype: DType::parse(ty)?,
            dims,
        })
    }
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Path relative to the artifact dir, e.g. `ops/attn_fa.hlo.txt`.
    pub rel_path: String,
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The mini-model build configuration recorded in the manifest.
#[derive(Debug, Clone, Default)]
pub struct BuildConfig {
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub q_heads: usize,
    pub kv_heads: usize,
    pub ffn: usize,
    pub seq: usize,
    pub batch: usize,
    pub head_dim: usize,
    pub params: usize,
}

/// Parsed MANIFEST.txt.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: BuildConfig,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn parse(dir: &Path, text: &str) -> Result<Manifest, String> {
        let mut m = Manifest {
            dir: dir.to_path_buf(),
            ..Default::default()
        };
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            match parts.next() {
                Some("config") => {
                    for kv in parts {
                        let Some((k, v)) = kv.split_once('=') else {
                            continue;
                        };
                        let n: usize =
                            v.parse().map_err(|_| format!("bad config value {kv}"))?;
                        match k {
                            "vocab" => m.config.vocab = n,
                            "hidden" => m.config.hidden = n,
                            "layers" => m.config.layers = n,
                            "q_heads" => m.config.q_heads = n,
                            "kv_heads" => m.config.kv_heads = n,
                            "ffn" => m.config.ffn = n,
                            "seq" => m.config.seq = n,
                            "batch" => m.config.batch = n,
                            "head_dim" => m.config.head_dim = n,
                            "params" => m.config.params = n,
                            _ => {}
                        }
                    }
                }
                Some("artifact") => {
                    let rel = parts
                        .next()
                        .ok_or_else(|| format!("artifact line without path: {line}"))?
                        .to_string();
                    let mut kind = String::new();
                    let mut inputs = Vec::new();
                    let mut outputs = Vec::new();
                    for kv in parts {
                        let Some((k, v)) = kv.split_once('=') else {
                            continue;
                        };
                        match k {
                            "kind" => kind = v.to_string(),
                            "inputs" | "outputs" => {
                                let specs: Option<Vec<TensorSpec>> =
                                    v.split(',').map(assemble_spec_piece).collect::<Vec<_>>()
                                        .into_iter()
                                        .collect();
                                // `v.split(',')` breaks dims apart; re-join.
                                let specs = match specs {
                                    Some(s) => s,
                                    None => parse_spec_list(v)
                                        .ok_or_else(|| format!("bad specs: {v}"))?,
                                };
                                if k == "inputs" {
                                    inputs = specs;
                                } else {
                                    outputs = specs;
                                }
                            }
                            _ => {}
                        }
                    }
                    m.artifacts.insert(
                        rel.clone(),
                        ArtifactSpec {
                            rel_path: rel,
                            kind,
                            inputs,
                            outputs,
                        },
                    );
                }
                _ => {}
            }
        }
        if m.artifacts.is_empty() {
            return Err("manifest has no artifacts".into());
        }
        Ok(m)
    }

    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("MANIFEST.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn get(&self, rel: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(rel)
    }

    pub fn abs_path(&self, rel: &str) -> PathBuf {
        self.dir.join(rel)
    }
}

/// Naive piece parse — fails when the spec contains multi-dim commas; used
/// only as the fast path.
fn assemble_spec_piece(_s: &str) -> Option<TensorSpec> {
    None
}

/// Correct spec-list parser: split on commas *outside* brackets.
fn parse_spec_list(v: &str) -> Option<Vec<TensorSpec>> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    for ch in v.chars() {
        match ch {
            '[' => {
                depth += 1;
                cur.push(ch);
            }
            ']' => {
                depth = depth.saturating_sub(1);
                cur.push(ch);
            }
            ',' if depth == 0 => {
                out.push(TensorSpec::parse(&cur)?);
                cur.clear();
            }
            _ => cur.push(ch),
        }
    }
    if !cur.is_empty() {
        out.push(TensorSpec::parse(&cur)?);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_spec_parsing() {
        let t = TensorSpec::parse("embed:f32[2048,256]").unwrap();
        assert_eq!(t.name, "embed");
        assert_eq!(t.dtype, DType::F32);
        assert_eq!(t.dims, vec![2048, 256]);
        assert_eq!(t.elements(), 2048 * 256);
        let s = TensorSpec::parse("seed:s32[]").unwrap();
        assert_eq!(s.dims, Vec::<usize>::new());
        assert_eq!(s.elements(), 1);
        assert!(TensorSpec::parse("junk").is_none());
    }

    #[test]
    fn spec_list_with_bracketed_commas() {
        let v = "a:f32[2,3],b:s32[],c:f32[4]";
        let specs = parse_spec_list(v).unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].dims, vec![2, 3]);
        assert_eq!(specs[1].dims, Vec::<usize>::new());
        assert_eq!(specs[2].dims, vec![4]);
    }

    #[test]
    fn manifest_parse_minimal() {
        let text = "\
# comment
config vocab=2048 hidden=256 layers=4 q_heads=8 kv_heads=4 ffn=896 seq=128 batch=4 head_dim=32 params=4589824
artifact fwd.hlo.txt kind=fwd inputs=x:f32[4,128] outputs=logits:f32[4,128,2048]
";
        let m = Manifest::parse(Path::new("/tmp/a"), text).unwrap();
        assert_eq!(m.config.vocab, 2048);
        assert_eq!(m.config.batch, 4);
        let a = m.get("fwd.hlo.txt").unwrap();
        assert_eq!(a.kind, "fwd");
        assert_eq!(a.inputs.len(), 1);
        assert_eq!(a.outputs[0].dims, vec![4, 128, 2048]);
    }

    #[test]
    fn real_manifest_loads_when_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("MANIFEST.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifacts.len() >= 20, "{}", m.artifacts.len());
        assert!(m.get("train_step.hlo.txt").is_some());
        assert!(m.get("ops/attn_fa.hlo.txt").is_some());
        // train_step: params + tokens + targets + lr in; params + loss out.
        let ts = m.get("train_step.hlo.txt").unwrap();
        assert_eq!(ts.inputs.len(), ts.outputs.len() + 2);
    }
}
