//! PJRT executor: load AOT HLO-text artifacts and run them on the CPU
//! client — the request-path side of the three-layer architecture. Python
//! never runs here; the artifacts under `artifacts/` are the only contract.
//!
//! HLO *text* is the interchange format (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while the
//! text parser reassigns ids (see python/compile/aot.py and
//! /opt/xla-example/README.md).

use crate::runtime::manifest::{DType, Manifest};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// A host-side tensor (what flows in/out of executables).
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    S32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    pub fn scalar_f32(x: f32) -> Tensor {
        Tensor::F32(vec![x], vec![])
    }

    pub fn scalar_i32(x: i32) -> Tensor {
        Tensor::S32(vec![x], vec![])
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32(_, d) | Tensor::S32(_, d) => d,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v, _) => v.len(),
            Tensor::S32(v, _) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v, _) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::S32(v, _) => Ok(v),
            _ => Err(anyhow!("tensor is not s32")),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Tensor::F32(v, dims) => {
                let l = xla::Literal::vec1(v.as_slice());
                let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
                l.reshape(&d)?
            }
            Tensor::S32(v, dims) => {
                let l = xla::Literal::vec1(v.as_slice());
                let d: Vec<i64> = dims.iter().map(|&x| x as i64).collect();
                l.reshape(&d)?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal, spec: &ArtifactOut) -> Result<Tensor> {
        Ok(match spec.dtype {
            DType::F32 => Tensor::F32(lit.to_vec::<f32>()?, spec.dims.clone()),
            DType::S32 => Tensor::S32(lit.to_vec::<i32>()?, spec.dims.clone()),
        })
    }
}

struct ArtifactOut {
    dtype: DType,
    dims: Vec<usize>,
}

/// PJRT runtime: one CPU client + a compile cache keyed by artifact path.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open the artifact directory (reads MANIFEST.txt, creates the PJRT
    /// CPU client; compilation is lazy per artifact).
    pub fn open(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            cache: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) one artifact.
    pub fn compile(&mut self, rel: &str) -> Result<()> {
        if self.cache.contains_key(rel) {
            return Ok(());
        }
        let path = self.manifest.abs_path(rel);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("loading {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {rel}"))?;
        self.cache.insert(rel.to_string(), exe);
        Ok(())
    }

    /// Number of artifacts compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }

    /// Execute one artifact with host tensors; returns the output tuple.
    pub fn run(&mut self, rel: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let spec = self
            .manifest
            .get(rel)
            .ok_or_else(|| anyhow!("unknown artifact {rel}"))?;
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "{rel}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        for (t, s) in inputs.iter().zip(&spec.inputs) {
            if t.len() != s.elements() {
                return Err(anyhow!(
                    "{rel}: input {} has {} elements, expected {}",
                    s.name,
                    t.len(),
                    s.elements()
                ));
            }
        }
        let outs: Vec<ArtifactOut> = spec
            .outputs
            .iter()
            .map(|o| ArtifactOut {
                dtype: o.dtype,
                dims: o.dims.clone(),
            })
            .collect();
        self.compile(rel)?;
        let exe = self.cache.get(rel).expect("compiled above");
        let lits: Result<Vec<xla::Literal>> =
            inputs.iter().map(|t| t.to_literal()).collect();
        let result = exe.execute::<xla::Literal>(&lits?)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        if parts.len() != outs.len() {
            return Err(anyhow!(
                "{rel}: got {} outputs, manifest says {}",
                parts.len(),
                outs.len()
            ));
        }
        parts
            .iter()
            .zip(&outs)
            .map(|(l, o)| Tensor::from_literal(l, o))
            .collect()
    }
}

/// Locate the workspace artifact directory (CARGO_MANIFEST_DIR/artifacts or
/// `CHOPPER_ARTIFACTS`).
pub fn default_artifact_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("CHOPPER_ARTIFACTS") {
        return p.into();
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// True when the AOT artifacts have been built (used by tests to skip
/// gracefully before `make artifacts`).
pub fn artifacts_available() -> bool {
    default_artifact_dir().join("MANIFEST.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Runtime::open(&default_artifact_dir()).unwrap())
    }

    #[test]
    fn open_and_platform() {
        let Some(rt) = runtime() else { return };
        assert_eq!(rt.platform().to_lowercase(), "cpu");
        assert!(rt.manifest().artifacts.len() >= 20);
    }

    #[test]
    fn init_produces_params() {
        let Some(mut rt) = runtime() else { return };
        let outs = rt.run("init.hlo.txt", &[Tensor::scalar_i32(42)]).unwrap();
        let spec = rt.manifest().get("init.hlo.txt").unwrap().clone();
        assert_eq!(outs.len(), spec.outputs.len());
        // Embedding is f32[vocab, hidden] with non-trivial values.
        let embed = outs[0].as_f32().unwrap();
        assert_eq!(
            embed.len(),
            rt.manifest().config.vocab * rt.manifest().config.hidden
        );
        let nonzero = embed.iter().filter(|x| **x != 0.0).count();
        assert!(nonzero > embed.len() / 2);
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let Some(mut rt) = runtime() else { return };
        let a = rt.run("init.hlo.txt", &[Tensor::scalar_i32(7)]).unwrap();
        let b = rt.run("init.hlo.txt", &[Tensor::scalar_i32(7)]).unwrap();
        let c = rt.run("init.hlo.txt", &[Tensor::scalar_i32(8)]).unwrap();
        assert_eq!(a[0], b[0]);
        assert_ne!(a[0], c[0]);
    }

    #[test]
    fn fwd_runs_and_produces_logits() {
        let Some(mut rt) = runtime() else { return };
        let cfg = rt.manifest().config.clone();
        let mut inputs = rt.run("init.hlo.txt", &[Tensor::scalar_i32(1)]).unwrap();
        let tokens: Vec<i32> = (0..cfg.batch * cfg.seq)
            .map(|i| (i % cfg.vocab) as i32)
            .collect();
        inputs.push(Tensor::S32(tokens, vec![cfg.batch, cfg.seq]));
        let outs = rt.run("fwd.hlo.txt", &inputs).unwrap();
        assert_eq!(outs.len(), 1);
        let logits = outs[0].as_f32().unwrap();
        assert_eq!(logits.len(), cfg.batch * cfg.seq * cfg.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn input_validation_errors() {
        let Some(mut rt) = runtime() else { return };
        assert!(rt.run("nope.hlo.txt", &[]).is_err());
        assert!(rt.run("init.hlo.txt", &[]).is_err()); // missing seed
        let bad = Tensor::F32(vec![0.0; 3], vec![3]);
        assert!(rt.run("init.hlo.txt", &[bad]).is_err()); // wrong dtype/shape
    }

    #[test]
    fn compile_cache_reuses_executables() {
        let Some(mut rt) = runtime() else { return };
        rt.run("init.hlo.txt", &[Tensor::scalar_i32(1)]).unwrap();
        assert_eq!(rt.compiled_count(), 1);
        rt.run("init.hlo.txt", &[Tensor::scalar_i32(2)]).unwrap();
        assert_eq!(rt.compiled_count(), 1);
    }
}
