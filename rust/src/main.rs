//! `chopper` binary — see `chopper help`.

fn main() {
    let code = chopper::cli::run(std::env::args().collect());
    std::process::exit(code);
}
