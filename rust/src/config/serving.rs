//! Serving-workload configuration (DESIGN.md §10): the open-loop request
//! arrival process, the prompt/output length distributions, and the
//! continuous-batching scheduler knobs. Everything is seeded — two runs
//! with the same `ServingConfig` produce byte-identical request streams,
//! schedules, and traces (the serving determinism contract).

use crate::util::prng::Rng;

/// Open-loop request arrival process. Open-loop means arrivals never wait
/// for the server: a request's arrival timestamp depends only on the seed
/// and the process parameters, so offered load is an independent variable
/// and latency under overload is honestly unbounded.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson arrivals at `qps` requests per second
    /// (exponential inter-arrival times).
    Poisson { qps: f64 },
    /// Trace-driven offered load: a piecewise-constant rate (requests per
    /// second), one entry per wall-clock second, cycled when the request
    /// stream outlives the trace. Arrivals are drawn from the
    /// inhomogeneous Poisson process with this rate function.
    Trace { qps_per_sec: Vec<f64> },
}

impl ArrivalProcess {
    /// Mean offered load (requests per second) of the process.
    pub fn mean_qps(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { qps } => *qps,
            ArrivalProcess::Trace { qps_per_sec } => {
                crate::util::stats::mean(qps_per_sec)
            }
        }
    }
}

/// A clamped lognormal-ish token-length distribution: `mean × exp(σ·N)`
/// rounded and clamped into `[min, max]`. σ is derived from the coefficient
/// of variation `cv`, so `cv = 0` pins every draw to `mean`.
#[derive(Debug, Clone, PartialEq)]
pub struct LengthDist {
    pub mean: u64,
    pub cv: f64,
    pub min: u64,
    pub max: u64,
}

impl LengthDist {
    pub fn fixed(mean: u64) -> Self {
        Self {
            mean,
            cv: 0.0,
            min: mean,
            max: mean,
        }
    }

    pub fn lognormal(mean: u64, cv: f64, min: u64, max: u64) -> Self {
        Self { mean, cv, min, max }
    }

    /// One draw from the distribution (consumes two uniforms via the
    /// Box-Muller pair inside `Rng::jitter`).
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        if self.cv <= 0.0 {
            return self.mean.clamp(self.min, self.max);
        }
        // ln(1 + cv²) is the lognormal σ² matching the requested cv.
        let sigma = (1.0 + self.cv * self.cv).ln().sqrt();
        let v = self.mean as f64 * rng.jitter(sigma);
        (v.round() as u64).clamp(self.min, self.max)
    }
}

/// The full serving-scenario description. `Debug` is part of the campaign
/// cache fingerprint — any field change invalidates cached summaries.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingConfig {
    pub arrival: ArrivalProcess,
    /// Requests in the (finite) open-loop stream.
    pub num_requests: u32,
    pub prompt: LengthDist,
    pub output: LengthDist,
    /// Decode-batch cap of the continuous batcher.
    pub max_batch: u32,
    /// Prefill token budget per scheduler step (chunked prefill): at most
    /// this many prompt tokens are ingested per step, so a long prompt
    /// cannot starve in-flight decodes for many steps.
    pub prefill_chunk: u64,
    /// Fraction of HBM available to the KV cache (weights, activations
    /// and allocator headroom take the rest).
    pub kv_frac: f64,
    /// TTFT service-level objective (ms) — the goodput cutoff.
    pub slo_ttft_ms: f64,
    pub seed: u64,
}

impl ServingConfig {
    /// A small default scenario: Poisson arrivals, chat-shaped lengths.
    pub fn new(qps: f64, num_requests: u32) -> Self {
        Self {
            arrival: ArrivalProcess::Poisson { qps },
            num_requests,
            prompt: LengthDist::lognormal(512, 0.6, 16, 8192),
            output: LengthDist::lognormal(128, 0.5, 4, 2048),
            max_batch: 64,
            prefill_chunk: 8192,
            kv_frac: 0.30,
            slo_ttft_ms: 200.0,
            seed: 0xC0FFEE,
        }
    }

    /// Scenario label used in figure rows, campaign names and trace
    /// metadata: `serve-q{qps}-r{requests}`.
    pub fn label(&self) -> String {
        format!("serve-q{:.3}-r{}", self.arrival.mean_qps(), self.num_requests)
    }

    /// KV-cache bytes per token for `model` (K and V per layer, all KV
    /// heads) — what one decoded or prefilled token pins in HBM until the
    /// request completes.
    pub fn kv_bytes_per_token(model: &crate::config::ModelConfig) -> f64 {
        2.0 * model.layers as f64
            * model.kv_heads as f64
            * model.head_dim() as f64
            * model.dtype_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn length_dist_fixed_is_constant() {
        let d = LengthDist::fixed(128);
        let mut r = Rng::new(1);
        for _ in 0..32 {
            assert_eq!(d.sample(&mut r), 128);
        }
    }

    #[test]
    fn length_dist_respects_bounds_and_varies() {
        let d = LengthDist::lognormal(256, 0.8, 32, 1024);
        let mut r = Rng::new(7);
        let xs: Vec<u64> = (0..256).map(|_| d.sample(&mut r)).collect();
        assert!(xs.iter().all(|&x| (32..=1024).contains(&x)));
        assert!(xs.iter().any(|&x| x != xs[0]), "cv>0 must vary");
    }

    #[test]
    fn mean_qps_of_trace_is_mean_of_buckets() {
        let p = ArrivalProcess::Trace {
            qps_per_sec: vec![2.0, 4.0, 6.0],
        };
        assert!((p.mean_qps() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn label_is_stable() {
        assert_eq!(ServingConfig::new(4.0, 64).label(), "serve-q4.000-r64");
    }

    #[test]
    fn kv_bytes_per_token_matches_formula() {
        let m = crate::config::ModelConfig::llama3_8b();
        // 2 (K+V) × 32 layers × 8 kv heads × 128 head dim × 2 bytes.
        let expect = 2.0 * 32.0 * 8.0 * 128.0 * 2.0;
        assert_eq!(ServingConfig::kv_bytes_per_token(&m), expect);
    }
}
