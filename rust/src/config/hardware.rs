//! Hardware description of the simulated node.
//!
//! Defaults model the paper's testbed (Section IV-C): eight AMD Instinct
//! MI300X GPUs (1.3 BF16 PFLOPS peak @ 2.1 GHz, 192 GB HBM3 @ 5.3 TB/s,
//! 304 CUs / 1216 matrix cores) fully connected by 128 GB/s bidirectional
//! Infinity Fabric links, hosted by two 96-core AMD EPYC 9684X CPUs with
//! SMT (384 logical cores) and 2.3 TB of DRAM.

/// Description of a single GPU.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    /// Peak BF16 matrix throughput at `freq_peak_mhz`, in FLOP/s.
    pub peak_bf16_flops: f64,
    /// Peak vector (non-MFMA) throughput in FLOP/s.
    pub peak_vector_flops: f64,
    /// HBM capacity in bytes.
    pub hbm_bytes: u64,
    /// HBM peak bandwidth in bytes/s.
    pub hbm_bw: f64,
    /// Number of compute units (workgroup occupancy model).
    pub compute_units: u32,
    /// Number of matrix cores.
    pub matrix_cores: u32,
    /// Peak (boost) engine clock in MHz; DVFS scales below this.
    pub freq_peak_mhz: f64,
    /// Minimum sustainable engine clock in MHz.
    pub freq_min_mhz: f64,
    /// Peak memory clock in MHz.
    pub mem_freq_peak_mhz: f64,
    /// Board power cap in watts (GPU package).
    pub power_cap_w: f64,
    /// Idle power in watts.
    pub idle_power_w: f64,
}

impl GpuSpec {
    pub fn mi300x() -> Self {
        Self {
            name: "AMD Instinct MI300X".into(),
            peak_bf16_flops: 1.3e15,
            peak_vector_flops: 163.4e12,
            hbm_bytes: 192 * (1u64 << 30),
            hbm_bw: 5.3e12,
            compute_units: 304,
            matrix_cores: 1216,
            freq_peak_mhz: 2100.0,
            freq_min_mhz: 800.0,
            mem_freq_peak_mhz: 2525.0,
            power_cap_w: 750.0,
            idle_power_w: 140.0,
        }
    }

    /// FLOP per engine cycle at peak (used to convert counters <-> time).
    pub fn flops_per_cycle(&self) -> f64 {
        self.peak_bf16_flops / (self.freq_peak_mhz * 1e6)
    }
}

/// Description of the host CPU complex.
#[derive(Debug, Clone, PartialEq)]
pub struct CpuSpec {
    pub name: String,
    pub sockets: u32,
    pub cores_per_socket: u32,
    /// SMT ways (2 on EPYC).
    pub smt: u32,
    /// Host memory in bytes.
    pub dram_bytes: u64,
    /// Mean cost for the host to dispatch one kernel, in ns.
    pub dispatch_ns: f64,
    /// Additional per-kernel launch latency (ring doorbell -> GPU start) ns.
    pub launch_latency_ns: f64,
}

impl CpuSpec {
    pub fn epyc_9684x_x2() -> Self {
        Self {
            name: "2x AMD EPYC 9684X".into(),
            sockets: 2,
            cores_per_socket: 96,
            smt: 2,
            dram_bytes: 2300 * (1u64 << 30),
            dispatch_ns: 3_000.0,
            launch_latency_ns: 8_000.0,
        }
    }

    pub fn physical_cores(&self) -> u32 {
        self.sockets * self.cores_per_socket
    }

    pub fn logical_cores(&self) -> u32 {
        self.physical_cores() * self.smt
    }
}

/// Interconnect between GPUs (fully connected Infinity Fabric mesh).
#[derive(Debug, Clone, PartialEq)]
pub struct LinkSpec {
    /// Per-direction bandwidth of one peer link, bytes/s.
    pub link_bw: f64,
    /// Link latency per hop in ns.
    pub latency_ns: f64,
    /// PCIe host link bandwidth, bytes/s (Gen5 x16).
    pub host_bw: f64,
    /// RCCL protocol efficiency over the parallel rings (fraction of the
    /// aggregate link bandwidth actually achieved; ~0.5 observed for
    /// large collectives on IF meshes).
    pub rccl_eff: f64,
}

impl LinkSpec {
    pub fn infinity_fabric() -> Self {
        Self {
            link_bw: 64e9, // 128 GB/s bidirectional => 64 GB/s per direction
            latency_ns: 1_500.0,
            host_bw: 64e9,
            rccl_eff: 0.65,
        }
    }
}

/// The whole node.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    pub gpu: GpuSpec,
    pub num_gpus: u32,
    pub cpu: CpuSpec,
    pub link: LinkSpec,
}

impl NodeSpec {
    /// The paper's testbed: 8x MI300X + 2x EPYC 9684X.
    pub fn mi300x_node() -> Self {
        Self {
            gpu: GpuSpec::mi300x(),
            num_gpus: 8,
            cpu: CpuSpec::epyc_9684x_x2(),
            link: LinkSpec::infinity_fabric(),
        }
    }

    /// Effective ring all-gather time for `bytes` of full payload: RCCL
    /// builds (R−1) parallel rings over the fully connected mesh, so each
    /// of the (R−1) steps moves one 1/R chunk split across *all* links;
    /// `rccl_eff` captures protocol overhead. Used by the interconnect
    /// model as the base (uncontended) duration.
    pub fn ring_collective_ns(&self, full_bytes: f64) -> f64 {
        let r = self.num_gpus as f64;
        let steps = (r - 1.0).max(1.0);
        let chunk = full_bytes / r;
        let eff_bw = self.link.link_bw * steps * self.link.rccl_eff;
        steps * (chunk / eff_bw * 1e9 + self.link.latency_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mi300x_peaks_match_paper() {
        let g = GpuSpec::mi300x();
        assert_eq!(g.peak_bf16_flops, 1.3e15); // 1.3 PFLOPS (Section II-D)
        assert_eq!(g.hbm_bytes, 192 * (1u64 << 30)); // 192 GB
        assert_eq!(g.hbm_bw, 5.3e12); // 5.3 TB/s
        assert_eq!(g.matrix_cores, 1216);
    }

    #[test]
    fn node_logical_cores() {
        let n = NodeSpec::mi300x_node();
        assert_eq!(n.cpu.physical_cores(), 192);
        assert_eq!(n.cpu.logical_cores(), 384);
    }

    #[test]
    fn ring_collective_scales_with_bytes() {
        let n = NodeSpec::mi300x_node();
        let t1 = n.ring_collective_ns(1e9);
        let t2 = n.ring_collective_ns(2e9);
        assert!(t2 > t1 * 1.8 && t2 < t1 * 2.2);
    }

    #[test]
    fn flops_per_cycle_sane() {
        let g = GpuSpec::mi300x();
        // 1.3e15 / 2.1e9 cycles ~ 619k flop/cycle across 1216 matrix cores.
        let fpc = g.flops_per_cycle();
        assert!(fpc > 5e5 && fpc < 7e5);
    }
}
