//! Workload configuration: the paper's batch-size/sequence-length sweep and
//! profiling protocol (Section IV-A/IV-D).

use crate::config::topology::Sharding;
use std::fmt;

/// FSDP flavor under test (Section II-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum FsdpVersion {
    /// Flat-parameter FSDP: non-deterministic caching-allocator reuse.
    V1,
    /// Per-parameter-sharding FSDP: deterministic allocation, extra copies.
    V2,
}

impl fmt::Display for FsdpVersion {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FsdpVersion::V1 => write!(f, "FSDPv1"),
            FsdpVersion::V2 => write!(f, "FSDPv2"),
        }
    }
}

/// One training workload configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    pub batch: u64,
    /// Sequence length in tokens.
    pub seq: u64,
    pub fsdp: FsdpVersion,
    /// Cross-topology sharding strategy. [`Sharding::Fsdp`] shards over
    /// every rank of the cluster (the single-node default); on a
    /// multi-node [`Topology`](crate::config::Topology),
    /// [`Sharding::Hsdp`] shards within each node and replicates across
    /// nodes. Ignored (equivalent to FSDP) on one node.
    pub sharding: Sharding,
    /// Total iterations to run.
    pub iterations: u32,
    /// Leading iterations discarded as warmup (paper: 10 of 20).
    pub warmup: u32,
    /// Whether iterations include the optimizer phase. The paper runs once
    /// with an optimizer step at iteration 15 and once without.
    pub optimizer: bool,
    /// Simulator seed.
    pub seed: u64,
}

impl WorkloadConfig {
    pub fn new(batch: u64, seq: u64, fsdp: FsdpVersion) -> Self {
        Self {
            batch,
            seq,
            fsdp,
            sharding: Sharding::Fsdp,
            iterations: 20,
            warmup: 10,
            optimizer: true,
            seed: 0xC0FFEE,
        }
    }

    /// Paper naming: b1s4 = batch 1, seq 4K.
    pub fn label(&self) -> String {
        format!("b{}s{}", self.batch, self.seq / 1024)
    }

    pub fn label_with_fsdp(&self) -> String {
        format!("{}-{}", self.label(), self.fsdp)
    }

    /// Parse "b2s4" style labels.
    pub fn parse_label(label: &str, fsdp: FsdpVersion) -> Option<Self> {
        let rest = label.strip_prefix('b')?;
        let sidx = rest.find('s')?;
        let batch: u64 = rest[..sidx].parse().ok()?;
        let seq_k: u64 = rest[sidx + 1..].parse().ok()?;
        if batch == 0 || seq_k == 0 {
            return None;
        }
        Some(Self::new(batch, seq_k * 1024, fsdp))
    }

    /// Tokens processed per iteration per GPU (data parallel: each rank has
    /// its own micro-batch).
    pub fn tokens_per_iteration(&self, num_gpus: u64) -> u64 {
        self.batch * self.seq * num_gpus
    }

    /// The paper's evaluated sweep: all configurations that fit in memory —
    /// b1s4, b2s4, b4s4, b1s8, b2s8 (Section IV-A).
    pub fn paper_sweep(fsdp: FsdpVersion) -> Vec<Self> {
        ["b1s4", "b2s4", "b4s4", "b1s8", "b2s8"]
            .iter()
            .map(|l| Self::parse_label(l, fsdp).expect("static label"))
            .collect()
    }

    /// Sampled (non-warmup) iteration indices.
    pub fn sampled_iterations(&self) -> impl Iterator<Item = u32> + '_ {
        self.warmup..self.iterations
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_roundtrip() {
        for l in ["b1s4", "b2s4", "b4s4", "b1s8", "b2s8"] {
            let w = WorkloadConfig::parse_label(l, FsdpVersion::V1).unwrap();
            assert_eq!(w.label(), l);
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(WorkloadConfig::parse_label("x1s4", FsdpVersion::V1).is_none());
        assert!(WorkloadConfig::parse_label("b0s4", FsdpVersion::V1).is_none());
        assert!(WorkloadConfig::parse_label("b1", FsdpVersion::V1).is_none());
    }

    #[test]
    fn paper_sweep_has_five_configs() {
        let sweep = WorkloadConfig::paper_sweep(FsdpVersion::V2);
        assert_eq!(sweep.len(), 5);
        assert!(sweep.iter().all(|w| w.fsdp == FsdpVersion::V2));
    }

    #[test]
    fn tokens_per_iteration() {
        let w = WorkloadConfig::parse_label("b2s4", FsdpVersion::V1).unwrap();
        assert_eq!(w.tokens_per_iteration(8), 2 * 4096 * 8);
    }

    #[test]
    fn sampled_iterations_skip_warmup() {
        let w = WorkloadConfig::new(1, 4096, FsdpVersion::V1);
        let v: Vec<u32> = w.sampled_iterations().collect();
        assert_eq!(v.len(), 10);
        assert_eq!(v[0], 10);
    }
}
