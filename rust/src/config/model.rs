//! Model configuration (the paper's Table II plus the executable mini
//! config used by the real-execution path).

/// Llama-style decoder configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: u64,
    pub hidden: u64,
    pub layers: u64,
    pub q_heads: u64,
    pub kv_heads: u64,
    pub ffn: u64,
    /// Bytes per element of weights/activations (BF16 in the paper).
    pub dtype_bytes: u64,
}

impl ModelConfig {
    /// Table II: Llama 3 8B — 32 layers, 4096 hidden, 14336 FFN, 32/8 heads.
    pub fn llama3_8b() -> Self {
        Self {
            name: "llama3-8b".into(),
            vocab: 128_256,
            hidden: 4096,
            layers: 32,
            q_heads: 32,
            kv_heads: 8,
            ffn: 14_336,
            dtype_bytes: 2, // BF16 (Section IV-B)
        }
    }

    /// The CPU-executable mini config matching python/compile/model.py.
    pub fn mini() -> Self {
        Self {
            name: "mini".into(),
            vocab: 2048,
            hidden: 256,
            layers: 4,
            q_heads: 8,
            kv_heads: 4,
            ffn: 896,
            dtype_bytes: 4, // f32 on the CPU PJRT path
        }
    }

    pub fn head_dim(&self) -> u64 {
        self.hidden / self.q_heads
    }

    /// Parameters of one decoder layer (attention + MLP + 2 norms).
    pub fn params_per_layer(&self) -> u64 {
        let h = self.hidden;
        let hd = self.head_dim();
        let kv = self.kv_heads * hd;
        h * h                // wq
            + 2 * h * kv     // wk, wv
            + h * h          // wo
            + 3 * h * self.ffn // wg, wu, wd
            + 2 * h          // norms
    }

    pub fn param_count(&self) -> u64 {
        self.vocab * self.hidden            // embed
            + self.layers * self.params_per_layer()
            + self.hidden                   // final norm
            + self.hidden * self.vocab      // logits projection
    }

    /// Weight bytes of one decoder layer (what FSDP all-gathers).
    pub fn layer_weight_bytes(&self) -> u64 {
        self.params_per_layer() * self.dtype_bytes
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "llama3-8b" | "llama3_8b" => Some(Self::llama3_8b()),
            "mini" => Some(Self::mini()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama3_8b_is_roughly_8b_params() {
        let c = ModelConfig::llama3_8b();
        let p = c.param_count();
        assert!(p > 7_000_000_000 && p < 9_000_000_000, "{p}");
    }

    #[test]
    fn table_ii_fields() {
        let c = ModelConfig::llama3_8b();
        assert_eq!(c.layers, 32);
        assert_eq!(c.hidden, 4096);
        assert_eq!(c.ffn, 14_336);
        assert_eq!(c.q_heads, 32);
        assert_eq!(c.kv_heads, 8);
        assert_eq!(c.head_dim(), 128);
    }

    #[test]
    fn layer_weight_bytes_bf16() {
        let c = ModelConfig::llama3_8b();
        // ~218M params/layer * 2 bytes ~ 437 MB all-gathered per layer.
        let b = c.layer_weight_bytes();
        assert!(b > 350_000_000 && b < 500_000_000, "{b}");
    }

    #[test]
    fn lookup_by_name() {
        assert!(ModelConfig::by_name("llama3-8b").is_some());
        assert!(ModelConfig::by_name("mini").is_some());
        assert!(ModelConfig::by_name("gpt-oss").is_none());
    }
}
