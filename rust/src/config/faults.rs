//! Fault-injection declarations: what can go wrong in a simulated run.
//!
//! A [`FaultSpec`] names one injected fault; a scenario carries a list of
//! them (`EngineParams::faults`). The specs are pure *declarations* — all
//! randomness (which rank straggles, when a stall fires) is derived by
//! `sim::faults` from `(seed, "fault<idx>")` substreams, so the same
//! `(config, seed)` always replays the same failures and the empty list
//! reproduces the healthy pipeline byte for byte.
//!
//! CLI grammar (campaign `--faults`, `whatif --faults`):
//!
//! ```text
//! set      := "none" | fault ("+" fault)*
//! sets     := set (";" set)*
//! fault    := kind | kind "(" key "=" value ("," key "=" value)* ")"
//! ```
//!
//! e.g. `--faults 'none;straggler(factor=0.8)+stalls(rate=0.02)'` sweeps
//! the healthy baseline against a straggler-plus-ECC-stall scenario.

use std::fmt;

use super::parse::{num_label, parse_kv, reject_leftovers, split_kind, take};

/// The grammar noun faults pass to the shared spec parser — keeps every
/// error message naming the thing the user typed (`bad fault …`).
const WHAT: &str = "fault";

/// One declared fault. Optional ranks/nodes (`None`) are resolved
/// deterministically by the fault model from the fault's seeded substream.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultSpec {
    /// A persistently slow rank: its compute throughput is multiplied by
    /// `factor` (< 1.0 = slower) for the whole run.
    Straggler { rank: Option<u32>, factor: f64 },
    /// A degraded link on one node: every collective whose rendezvous
    /// group touches that node pays `1/bw` extra transfer time (`bw` is
    /// the remaining bandwidth fraction of the slow xGMI/NIC link).
    LinkDown { node: Option<u32>, bw: f64 },
    /// Transient ECC-retry-style stalls: each kernel start stalls with
    /// probability `rate`, for an exponentially distributed `mean_us`.
    Stalls { rate: f64, mean_us: f64 },
    /// GPU dropout: a rank dies at `at_ms`; the schedule replays from the
    /// last checkpoint boundary (iteration start) plus `restart_ms` of
    /// restart cost. Time lost to the failure is reported first-class.
    Dropout {
        rank: Option<u32>,
        at_ms: f64,
        restart_ms: f64,
    },
    /// Deliberate engine panic at model-build time — a test hook for the
    /// campaign runner's per-scenario panic isolation. Only meaningful
    /// under `chopper campaign` (which catches it and marks the scenario
    /// `failed`); rejected by `chopper whatif`.
    Panic,
}

impl FaultSpec {
    /// Whether this fault composes with replica folding (DESIGN.md §13).
    /// Faults that resolve to a specific rank or node — explicitly
    /// targeted or seeded-random (`rank: None` still lands on exactly one
    /// rank) — break replica symmetry: simulating them on a folded
    /// representative would silently multiply the fault across every
    /// replica it stands for. Rate-based transient stalls hit every rank
    /// statistically alike, and `panic` is a campaign-runner test hook
    /// that never reaches the engine's rank state, so both stay allowed.
    pub fn fold_compatible(&self) -> bool {
        match self {
            FaultSpec::Straggler { .. }
            | FaultSpec::LinkDown { .. }
            | FaultSpec::Dropout { .. } => false,
            FaultSpec::Stalls { .. } | FaultSpec::Panic => true,
        }
    }

    /// The grammar keyword of this fault kind.
    pub fn kind(&self) -> &'static str {
        match self {
            FaultSpec::Straggler { .. } => "straggler",
            FaultSpec::LinkDown { .. } => "linkdown",
            FaultSpec::Stalls { .. } => "stalls",
            FaultSpec::Dropout { .. } => "dropout",
            FaultSpec::Panic => "panic",
        }
    }

    /// Compact filesystem-safe label (scenario-name tag material):
    /// `strag_f0_8`, `link_n1_b0_5`, `stall_p0_01_m500`, `drop_a50_rs250`.
    pub fn label(&self) -> String {
        let num = num_label;
        match self {
            FaultSpec::Straggler { rank, factor } => {
                let mut s = String::from("strag");
                if let Some(r) = rank {
                    s.push_str(&format!("_r{r}"));
                }
                s.push_str(&format!("_f{}", num(*factor)));
                s
            }
            FaultSpec::LinkDown { node, bw } => {
                let mut s = String::from("link");
                if let Some(n) = node {
                    s.push_str(&format!("_n{n}"));
                }
                s.push_str(&format!("_b{}", num(*bw)));
                s
            }
            FaultSpec::Stalls { rate, mean_us } => {
                format!("stall_p{}_m{}", num(*rate), num(*mean_us))
            }
            FaultSpec::Dropout {
                rank,
                at_ms,
                restart_ms,
            } => {
                let mut s = String::from("drop");
                if let Some(r) = rank {
                    s.push_str(&format!("_r{r}"));
                }
                s.push_str(&format!("_a{}_rs{}", num(*at_ms), num(*restart_ms)));
                s
            }
            FaultSpec::Panic => "panic".into(),
        }
    }
}

impl fmt::Display for FaultSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.label())
    }
}

/// Compact label of a whole fault set (`+`-joined; "none" when empty) —
/// the scenario-name tag and `TraceMeta::faults` value.
pub fn set_label(faults: &[FaultSpec]) -> String {
    if faults.is_empty() {
        return "none".into();
    }
    faults
        .iter()
        .map(|f| f.label())
        .collect::<Vec<_>>()
        .join("+")
}

/// Parse one fault: `kind` or `kind(key=value,...)`. Ranks/nodes are u32;
/// every numeric parameter is validated into its sane range so a typo'd
/// flag errors here, not as a NaN three layers down. Tokenization rides the
/// shared spec grammar in `config::parse`.
pub fn parse_fault(s: &str) -> Result<FaultSpec, String> {
    let s = s.trim();
    let (kind, body) = split_kind(s, WHAT)?;
    let mut kvs = parse_kv(body, s, WHAT)?;
    let as_rank = |v: f64, key: &str| -> Result<u32, String> {
        if v.is_finite() && v >= 0.0 && v.fract() == 0.0 && v <= u32::MAX as f64
        {
            Ok(v as u32)
        } else {
            Err(format!("bad value `{v}` for `{key}` in `{s}` (want integer)"))
        }
    };
    let spec = match kind {
        "straggler" | "strag" => {
            let rank = take(&mut kvs, "rank")
                .map(|v| as_rank(v, "rank"))
                .transpose()?;
            let factor = take(&mut kvs, "factor").unwrap_or(0.8);
            if !(factor > 0.0 && factor <= 1.0) {
                return Err(format!(
                    "bad value `{factor}` for `factor` in `{s}` (want 0 < f <= 1)"
                ));
            }
            reject_leftovers(&kvs, s, WHAT, &["rank", "factor"])?;
            FaultSpec::Straggler { rank, factor }
        }
        "linkdown" | "link" => {
            let node = take(&mut kvs, "node")
                .map(|v| as_rank(v, "node"))
                .transpose()?;
            let bw = take(&mut kvs, "bw").unwrap_or(0.5);
            if !(bw > 0.0 && bw <= 1.0) {
                return Err(format!(
                    "bad value `{bw}` for `bw` in `{s}` (want 0 < bw <= 1)"
                ));
            }
            reject_leftovers(&kvs, s, WHAT, &["node", "bw"])?;
            FaultSpec::LinkDown { node, bw }
        }
        "stalls" | "stall" => {
            let rate = take(&mut kvs, "rate").unwrap_or(0.01);
            let mean_us = take(&mut kvs, "mean_us").unwrap_or(500.0);
            if !(rate >= 0.0 && rate <= 1.0) {
                return Err(format!(
                    "bad value `{rate}` for `rate` in `{s}` (want 0 <= p <= 1)"
                ));
            }
            if !(mean_us > 0.0 && mean_us.is_finite()) {
                return Err(format!(
                    "bad value `{mean_us}` for `mean_us` in `{s}` (want > 0)"
                ));
            }
            reject_leftovers(&kvs, s, WHAT, &["rate", "mean_us"])?;
            FaultSpec::Stalls { rate, mean_us }
        }
        "dropout" | "drop" => {
            let rank = take(&mut kvs, "rank")
                .map(|v| as_rank(v, "rank"))
                .transpose()?;
            let at_ms = take(&mut kvs, "at_ms").unwrap_or(50.0);
            let restart_ms = take(&mut kvs, "restart_ms").unwrap_or(250.0);
            for (key, v) in [("at_ms", at_ms), ("restart_ms", restart_ms)] {
                if !(v >= 0.0 && v.is_finite()) {
                    return Err(format!(
                        "bad value `{v}` for `{key}` in `{s}` (want >= 0)"
                    ));
                }
            }
            reject_leftovers(&kvs, s, WHAT, &["rank", "at_ms", "restart_ms"])?;
            FaultSpec::Dropout {
                rank,
                at_ms,
                restart_ms,
            }
        }
        "panic" => {
            reject_leftovers(&kvs, s, WHAT, &[])?;
            FaultSpec::Panic
        }
        other => {
            return Err(format!(
                "unknown fault `{other}` (have: straggler, linkdown, stalls, dropout, panic)"
            ))
        }
    };
    Ok(spec)
}

/// Parse one fault set: `none` (empty) or `fault+fault+...`.
pub fn parse_fault_set(s: &str) -> Result<Vec<FaultSpec>, String> {
    let s = s.trim();
    if s.is_empty() || s == "none" {
        return Ok(Vec::new());
    }
    s.split('+')
        .filter(|t| !t.trim().is_empty())
        .map(parse_fault)
        .collect()
}

/// Parse a `;`-separated list of fault sets — the campaign `--faults`
/// axis. `none;straggler(factor=0.8)` sweeps healthy vs one straggler.
pub fn parse_list_faults(s: &str) -> Result<Vec<Vec<FaultSpec>>, String> {
    let sets: Vec<Vec<FaultSpec>> = s
        .split(';')
        .filter(|t| !t.trim().is_empty())
        .map(parse_fault_set)
        .collect::<Result<_, _>>()?;
    if sets.is_empty() {
        return Err(format!("empty fault list `{s}` (use `none`)"));
    }
    Ok(sets)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_bare_kinds_with_defaults() {
        assert_eq!(
            parse_fault("straggler").unwrap(),
            FaultSpec::Straggler {
                rank: None,
                factor: 0.8
            }
        );
        assert_eq!(
            parse_fault("stalls").unwrap(),
            FaultSpec::Stalls {
                rate: 0.01,
                mean_us: 500.0
            }
        );
        assert_eq!(parse_fault("panic").unwrap(), FaultSpec::Panic);
    }

    #[test]
    fn parses_keyed_parameters() {
        assert_eq!(
            parse_fault("straggler(rank=2,factor=0.7)").unwrap(),
            FaultSpec::Straggler {
                rank: Some(2),
                factor: 0.7
            }
        );
        assert_eq!(
            parse_fault("linkdown(node=1,bw=0.25)").unwrap(),
            FaultSpec::LinkDown {
                node: Some(1),
                bw: 0.25
            }
        );
        assert_eq!(
            parse_fault("dropout(at_ms=10,restart_ms=40)").unwrap(),
            FaultSpec::Dropout {
                rank: None,
                at_ms: 10.0,
                restart_ms: 40.0
            }
        );
    }

    #[test]
    fn rejects_malformed_input_with_offending_token() {
        let e = parse_fault("straggler(factor=2.0)").unwrap_err();
        assert!(e.contains("factor"), "{e}");
        let e = parse_fault("straggler(rank=1.5)").unwrap_err();
        assert!(e.contains("rank"), "{e}");
        let e = parse_fault("straggler(speed=0.5)").unwrap_err();
        assert!(e.contains("speed"), "{e}");
        let e = parse_fault("meteor").unwrap_err();
        assert!(e.contains("meteor"), "{e}");
        assert!(parse_fault("straggler(factor=0.8").is_err());
        assert!(parse_fault("stalls(rate=x)").is_err());
    }

    #[test]
    fn set_and_list_grammar() {
        assert!(parse_fault_set("none").unwrap().is_empty());
        let set =
            parse_fault_set("straggler(factor=0.8)+stalls(rate=0.02)").unwrap();
        assert_eq!(set.len(), 2);
        let sets = parse_list_faults("none;straggler(factor=0.8)").unwrap();
        assert_eq!(sets.len(), 2);
        assert!(sets[0].is_empty());
        assert_eq!(sets[1].len(), 1);
        assert!(parse_list_faults(";").is_err());
        assert!(parse_list_faults("none;bogus").is_err());
    }

    #[test]
    fn labels_are_compact_and_filesystem_safe() {
        assert_eq!(
            parse_fault("straggler(factor=0.8)").unwrap().label(),
            "strag_f0_8"
        );
        assert_eq!(
            parse_fault("linkdown(node=1,bw=0.5)").unwrap().label(),
            "link_n1_b0_5"
        );
        assert_eq!(parse_fault("stalls").unwrap().label(), "stall_p0_01_m500");
        assert_eq!(
            parse_fault("dropout(rank=2,at_ms=50,restart_ms=250)")
                .unwrap()
                .label(),
            "drop_r2_a50_rs250"
        );
        assert_eq!(set_label(&[]), "none");
        let set = parse_fault_set("straggler+panic").unwrap();
        assert_eq!(set_label(&set), "strag_f0_8+panic");
        for spec in &set {
            for c in spec.label().chars() {
                assert!(
                    c.is_ascii_alphanumeric() || c == '_' || c == '+',
                    "unsafe label char {c}"
                );
            }
        }
    }
}
