//! Multi-node cluster topology: N identical nodes of G GPUs each, with a
//! two-level interconnect — intra-node xGMI (Infinity Fabric, `LinkSpec`)
//! and inter-node RDMA NICs (`NicSpec`, rail-optimized: one NIC per GPU).
//!
//! The topology is the contract every layer shares (DESIGN.md §8):
//!
//! * **Rank mapping.** Global ("flat") ranks are dense `0..world_size()`;
//!   rank `r` lives on node `r / gpus_per_node()` as local GPU
//!   `r % gpus_per_node()`. Traces, figures and counters keep flat ranks,
//!   so every single-node analysis works unchanged on multi-node traces.
//! * **Two-level collectives.** A world-scoped collective costs the
//!   intra-node ring **plus** an inter-node phase over the NICs
//!   (`sim::interconnect::hierarchical_collective_ns`); node-scoped and
//!   cross-node-scoped collectives (HSDP) cost exactly their level.
//! * **Degenerate case.** `Topology::single(node)` (one node) must be
//!   indistinguishable — byte for byte in figures, summaries and traces —
//!   from the plain `NodeSpec` path. The inter-node phase is exactly zero
//!   at one node, and `tests/pipeline.rs` pins the whole pipeline.

use crate::config::NodeSpec;
use std::fmt;

/// Parameter-sharding strategy across the topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Sharding {
    /// Fully Sharded Data Parallel over every rank of the cluster: one
    /// shard group of `world_size()` ranks, world-scoped collectives.
    Fsdp,
    /// Hybrid Sharded Data Parallel: shard *within* each node, replicate
    /// *across* nodes — intra-node all-gather / reduce-scatter plus a
    /// cross-node all-reduce of each rank's gradient shard.
    Hsdp,
}

impl fmt::Display for Sharding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Sharding::Fsdp => write!(f, "FSDP"),
            Sharding::Hsdp => write!(f, "HSDP"),
        }
    }
}

impl Sharding {
    pub fn parse(s: &str) -> Option<Sharding> {
        match s {
            "fsdp" | "FSDP" => Some(Sharding::Fsdp),
            "hsdp" | "HSDP" => Some(Sharding::Hsdp),
            _ => None,
        }
    }
}

/// Inter-node NIC, rail-optimized: one NIC per GPU, so the G concurrent
/// cross-node rings of a hierarchical collective each get a full NIC.
#[derive(Debug, Clone, PartialEq)]
pub struct NicSpec {
    /// Per-direction bandwidth of one GPU's NIC, bytes/s.
    pub nic_bw: f64,
    /// Inter-node (switch + wire) latency per ring step, ns.
    pub latency_ns: f64,
    /// RDMA/RCCL protocol efficiency over the NIC (fraction achieved).
    pub eff: f64,
}

impl NicSpec {
    /// 400 Gb/s RoCE per GPU — the rail-optimized fabric MI300X clusters
    /// ship with. Noticeably slower than the 64 GB/s per-direction xGMI
    /// links once protocol efficiency is applied, which is exactly the
    /// bandwidth divergence that makes multi-node scheduling interesting.
    pub fn roce_400g() -> Self {
        Self {
            nic_bw: 50e9,
            latency_ns: 5_000.0,
            eff: 0.8,
        }
    }
}

impl Default for NicSpec {
    fn default() -> Self {
        Self::roce_400g()
    }
}

/// The whole cluster: `num_nodes` identical [`NodeSpec`]s joined by
/// [`NicSpec`] rails.
///
/// **Replica folding (DESIGN.md §13).** Under HSDP every node runs the
/// same schedule and talks to its peers through the same symmetric
/// collectives — replica nodes are statistically identical up to seeded
/// jitter. `fold` exploits that: the engine simulates only
/// `num_nodes / fold` *representative* nodes (one per equivalence class
/// of `fold` consecutive replicas, each representative keeping the
/// jitter substreams of the class's first logical node) while collective
/// *pricing* still sees the full logical `num_nodes`/`world_size()`.
/// `fold == 1` is exact mode and must reproduce the unfolded pipeline
/// byte for byte.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    /// Per-node hardware (GPUs, host CPU, intra-node links).
    pub node: NodeSpec,
    /// **Logical** node count — what collectives are priced against and
    /// what summaries report, independent of how many nodes the engine
    /// actually simulates.
    pub num_nodes: u32,
    pub nic: NicSpec,
    /// Replica fold factor: 1 = exact (simulate every node); F > 1 =
    /// simulate `num_nodes / F` representative nodes and fold results
    /// across the remaining replicas. Must divide `num_nodes`.
    pub fold: u32,
}

impl Topology {
    /// The degenerate single-node topology — the paper's testbed. Must
    /// reproduce the plain `NodeSpec` path byte for byte.
    pub fn single(node: NodeSpec) -> Self {
        Self {
            node,
            num_nodes: 1,
            nic: NicSpec::default(),
            fold: 1,
        }
    }

    /// `n` MI300X nodes on the default 400 Gb/s rails.
    pub fn mi300x_cluster(num_nodes: u32) -> Self {
        Self {
            node: NodeSpec::mi300x_node(),
            num_nodes: num_nodes.max(1),
            nic: NicSpec::default(),
            fold: 1,
        }
    }

    /// Same topology with a replica fold factor.
    pub fn with_fold(mut self, fold: u32) -> Self {
        self.fold = fold.max(1);
        self
    }

    pub fn gpus_per_node(&self) -> u32 {
        self.node.num_gpus
    }

    /// Total flat ranks in the cluster.
    pub fn world_size(&self) -> u32 {
        self.num_nodes * self.node.num_gpus
    }

    /// Node hosting flat rank `rank`.
    pub fn node_of(&self, rank: u32) -> u32 {
        rank / self.gpus_per_node().max(1)
    }

    /// Local GPU index of flat rank `rank` within its node.
    pub fn local_of(&self, rank: u32) -> u32 {
        rank % self.gpus_per_node().max(1)
    }

    /// Flat rank of (node, local GPU).
    pub fn rank_of(&self, node: u32, local: u32) -> u32 {
        node * self.gpus_per_node() + local
    }

    /// Flat ranks of one node, ascending.
    pub fn node_ranks(&self, node: u32) -> std::ops::Range<u32> {
        let g = self.gpus_per_node();
        node * g..(node + 1) * g
    }

    /// Compact tag for names/fingerprints: "N2x8".
    pub fn tag(&self) -> String {
        format!("N{}x{}", self.num_nodes, self.gpus_per_node())
    }

    // -- replica folding (DESIGN.md §13) ------------------------------------

    /// Replica fold factor, normalized (0 behaves as 1 = exact mode).
    pub fn fold_factor(&self) -> u32 {
        self.fold.max(1)
    }

    /// Whether this topology folds replicas (fold factor > 1).
    pub fn is_folded(&self) -> bool {
        self.fold_factor() > 1
    }

    /// Nodes the engine actually simulates: one representative node per
    /// equivalence class of `fold_factor()` consecutive logical nodes.
    /// Equal to `num_nodes` in exact mode.
    pub fn sim_nodes(&self) -> u32 {
        (self.num_nodes / self.fold_factor()).max(1)
    }

    /// Ranks the engine actually simulates (`sim_nodes()` × GPUs/node).
    pub fn sim_world(&self) -> u32 {
        self.sim_nodes() * self.gpus_per_node()
    }

    /// First **logical** node of the equivalence class represented by
    /// simulated node `sim_node` — the node whose jitter substreams the
    /// representative draws from, so fold-1 representatives are bitwise
    /// the nodes they stand for.
    pub fn logical_node_of(&self, sim_node: u32) -> u32 {
        sim_node * self.fold_factor()
    }

    /// Logical flat rank represented by simulated flat rank `sim_rank`.
    pub fn logical_rank_of(&self, sim_rank: u32) -> u32 {
        let g = self.gpus_per_node().max(1);
        self.rank_of(self.logical_node_of(sim_rank / g), sim_rank % g)
    }

    /// Structural validity of the fold spec. Callers layer their own
    /// policy on top (campaign/whatif additionally reject folding with
    /// FSDP sharding, serving workloads, and rank-targeted faults).
    pub fn validate_fold(&self) -> Result<(), String> {
        let f = self.fold_factor();
        if f == 1 {
            return Ok(());
        }
        if self.num_nodes % f != 0 {
            return Err(format!(
                "fold factor {f} does not divide num_nodes {}",
                self.num_nodes
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_is_one_node() {
        let t = Topology::single(NodeSpec::mi300x_node());
        assert_eq!(t.num_nodes, 1);
        assert_eq!(t.world_size(), 8);
        assert_eq!(t.gpus_per_node(), 8);
        assert_eq!(t.tag(), "N1x8");
    }

    #[test]
    fn rank_mapping_roundtrips() {
        let t = Topology::mi300x_cluster(4);
        assert_eq!(t.world_size(), 32);
        for rank in 0..t.world_size() {
            let (n, l) = (t.node_of(rank), t.local_of(rank));
            assert!(n < 4 && l < 8);
            assert_eq!(t.rank_of(n, l), rank);
        }
        assert_eq!(t.node_of(8), 1);
        assert_eq!(t.local_of(8), 0);
        assert_eq!(t.node_ranks(1).collect::<Vec<_>>(), (8..16).collect::<Vec<_>>());
    }

    #[test]
    fn sharding_parse_display() {
        assert_eq!(Sharding::parse("fsdp"), Some(Sharding::Fsdp));
        assert_eq!(Sharding::parse("HSDP"), Some(Sharding::Hsdp));
        assert_eq!(Sharding::parse("zero3"), None);
        assert_eq!(Sharding::Fsdp.to_string(), "FSDP");
        assert_eq!(Sharding::Hsdp.to_string(), "HSDP");
    }

    #[test]
    fn fold_defaults_to_exact() {
        let t = Topology::mi300x_cluster(4);
        assert_eq!(t.fold, 1);
        assert!(!t.is_folded());
        assert_eq!(t.sim_nodes(), 4);
        assert_eq!(t.sim_world(), 32);
        assert!(t.validate_fold().is_ok());
        // Normalized: fold 0 behaves as exact mode.
        let z = Topology::mi300x_cluster(4).with_fold(0);
        assert_eq!(z.fold_factor(), 1);
    }

    #[test]
    fn fold_maps_representatives_to_class_leaders() {
        let t = Topology::mi300x_cluster(8).with_fold(4);
        assert!(t.is_folded());
        assert_eq!(t.sim_nodes(), 2);
        assert_eq!(t.sim_world(), 16);
        // Logical pricing still sees the full cluster.
        assert_eq!(t.world_size(), 64);
        // Representative 0 is logical node 0; representative 1 leads the
        // second class (logical node 4).
        assert_eq!(t.logical_node_of(0), 0);
        assert_eq!(t.logical_node_of(1), 4);
        assert_eq!(t.logical_rank_of(0), 0);
        assert_eq!(t.logical_rank_of(7), 7);
        assert_eq!(t.logical_rank_of(8), 32);
        assert_eq!(t.logical_rank_of(15), 39);
        assert!(t.validate_fold().is_ok());
        assert!(Topology::mi300x_cluster(6).with_fold(4).validate_fold().is_err());
    }

    #[test]
    fn nic_slower_than_xgmi() {
        // The premise of the two-level model: effective NIC bandwidth is
        // below the per-direction xGMI link bandwidth.
        let t = Topology::mi300x_cluster(2);
        assert!(t.nic.nic_bw * t.nic.eff < t.node.link.link_bw);
    }
}
