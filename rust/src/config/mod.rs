//! Configuration: hardware (the simulated MI300X node), model (Table II),
//! workload (the b×s sweep and profiling protocol), and a small config-file
//! parser for the CLI.

pub mod faults;
pub mod hardware;
pub mod model;
pub mod parse;
pub mod serving;
pub mod topology;
pub mod workload;

pub use faults::{parse_list_faults, FaultSpec};
pub use hardware::{CpuSpec, GpuSpec, LinkSpec, NodeSpec};
pub use model::ModelConfig;
pub use parse::{ConfigError, ConfigMap};
pub use serving::{ArrivalProcess, LengthDist, ServingConfig};
pub use topology::{NicSpec, Sharding, Topology};
pub use workload::{FsdpVersion, WorkloadConfig};
