//! Line-based `key = value` config-file parser (clap/serde are not vendored
//! in this environment; a small deterministic parser is all the CLI needs),
//! plus the shared `kind(key=value,…)` spec grammar used by every CLI
//! mini-language (`--faults`, `--thermal`).
//!
//! Config-file format: one `key = value` per line, `#` comments, blank
//! lines ignored. Keys are dotted paths (`sim.seed`, `workload.batch`).

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ConfigError {}

#[derive(Debug, Clone, Default)]
pub struct ConfigMap {
    values: BTreeMap<String, String>,
}

impl ConfigMap {
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                ConfigError(format!("line {}: expected 'key = value'", lineno + 1))
            })?;
            let key = k.trim();
            if key.is_empty() {
                return Err(ConfigError(format!("line {}: empty key", lineno + 1)));
            }
            values.insert(key.to_string(), v.trim().to_string());
        }
        Ok(Self { values })
    }

    pub fn load(path: &Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError(format!("{}: {e}", path.display())))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>, ConfigError> {
        self.typed(key, "u64", |s| s.parse::<u64>().ok())
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, ConfigError> {
        self.typed(key, "f64", |s| s.parse::<f64>().ok())
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>, ConfigError> {
        self.typed(key, "bool", |s| match s {
            "true" | "1" | "yes" | "on" => Some(true),
            "false" | "0" | "no" | "off" => Some(false),
            _ => None,
        })
    }

    fn typed<T>(
        &self,
        key: &str,
        ty: &str,
        f: impl Fn(&str) -> Option<T>,
    ) -> Result<Option<T>, ConfigError> {
        match self.get(key) {
            None => Ok(None),
            Some(s) => f(s)
                .map(Some)
                .ok_or_else(|| ConfigError(format!("key '{key}': '{s}' is not a {ty}"))),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }
}

// ---------------------------------------------------------------------------
// Shared `kind(key=value,…)` spec grammar.
//
// Every CLI mini-language built on this shape (fault sets, thermal specs)
// shares one tokenizer and one error-naming convention, parameterized by a
// `what` noun ("fault", "thermal spec") so messages keep naming the grammar
// the user actually typed into.
// ---------------------------------------------------------------------------

/// Split `kind` or `kind(body)` into `(kind, body)`. The bare form yields an
/// empty body; an unclosed paren is an error naming the whole token.
pub fn split_kind<'a>(s: &'a str, what: &str) -> Result<(&'a str, &'a str), String> {
    match s.split_once('(') {
        Some((k, rest)) => {
            let body = rest
                .strip_suffix(')')
                .ok_or_else(|| format!("bad {what} `{s}` (missing `)`)"))?;
            Ok((k.trim(), body))
        }
        None => Ok((s, "")),
    }
}

/// Tokenize a `key=value,key=value` body into `(key, f64)` pairs. `ctx` is
/// the full spec string the user typed (for error messages); `what` the
/// grammar noun.
pub fn parse_kv(body: &str, ctx: &str, what: &str) -> Result<Vec<(String, f64)>, String> {
    let mut out = Vec::new();
    for part in body.split(',').filter(|p| !p.trim().is_empty()) {
        let (k, v) = part.split_once('=').ok_or_else(|| {
            format!("bad {what} parameter `{part}` in `{ctx}` (want key=value)")
        })?;
        let val: f64 = v.trim().parse().map_err(|_| {
            format!("bad value `{}` for `{}` in `{ctx}`", v.trim(), k.trim())
        })?;
        out.push((k.trim().to_string(), val));
    }
    Ok(out)
}

/// Remove and return the value for `key`, if present.
pub fn take(kvs: &mut Vec<(String, f64)>, key: &str) -> Option<f64> {
    let pos = kvs.iter().position(|(k, _)| k == key)?;
    Some(kvs.remove(pos).1)
}

/// Error on any unconsumed key, listing the keys this kind understands.
pub fn reject_leftovers(
    kvs: &[(String, f64)],
    ctx: &str,
    what: &str,
    known: &[&str],
) -> Result<(), String> {
    if let Some((k, _)) = kvs.first() {
        return Err(format!(
            "unknown key `{k}` in {what} `{ctx}` (have: {})",
            known.join(", ")
        ));
    }
    Ok(())
}

/// Compact filesystem-safe rendering of a numeric spec parameter for
/// scenario-name tags: `.` → `_`, `-` → `m` (`0.8` → `0_8`, `-3` → `m3`).
pub fn num_label(v: f64) -> String {
    format!("{v}").replace('.', "_").replace('-', "m")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_splits_kinds_and_bodies() {
        assert_eq!(split_kind("foo", "spec").unwrap(), ("foo", ""));
        assert_eq!(
            split_kind("foo(a=1,b=2)", "spec").unwrap(),
            ("foo", "a=1,b=2")
        );
        let e = split_kind("foo(a=1", "widget").unwrap_err();
        assert!(e.contains("widget") && e.contains("missing"), "{e}");
    }

    #[test]
    fn spec_grammar_tokenizes_and_rejects() {
        let mut kvs = parse_kv("a=1, b=0.5", "foo(a=1, b=0.5)", "spec").unwrap();
        assert_eq!(take(&mut kvs, "a"), Some(1.0));
        assert_eq!(take(&mut kvs, "a"), None);
        assert_eq!(take(&mut kvs, "b"), Some(0.5));
        assert!(reject_leftovers(&kvs, "ctx", "spec", &["a", "b"]).is_ok());

        let e = parse_kv("a", "foo(a)", "widget").unwrap_err();
        assert!(e.contains("widget parameter"), "{e}");
        let e = parse_kv("a=x", "foo(a=x)", "widget").unwrap_err();
        assert!(e.contains("bad value `x`"), "{e}");
        let kvs = parse_kv("z=1", "foo(z=1)", "widget").unwrap();
        let e = reject_leftovers(&kvs, "foo(z=1)", "widget", &["a", "b"]).unwrap_err();
        assert!(e.contains("`z`") && e.contains("widget") && e.contains("a, b"), "{e}");
    }

    #[test]
    fn num_labels_are_filesystem_safe() {
        assert_eq!(num_label(0.8), "0_8");
        assert_eq!(num_label(-3.5), "m3_5");
        assert_eq!(num_label(500.0), "500");
    }

    #[test]
    fn parses_basic_file() {
        let c = ConfigMap::parse(
            "# comment\nsim.seed = 42\n\nworkload.label= b2s4 \nflag = true\n",
        )
        .unwrap();
        assert_eq!(c.get_u64("sim.seed").unwrap(), Some(42));
        assert_eq!(c.get("workload.label"), Some("b2s4"));
        assert_eq!(c.get_bool("flag").unwrap(), Some(true));
        assert_eq!(c.get("missing"), None);
    }

    #[test]
    fn type_errors_are_reported() {
        let c = ConfigMap::parse("x = notanumber\n").unwrap();
        assert!(c.get_u64("x").is_err());
        assert!(c.get_bool("x").is_err());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(ConfigMap::parse("just a line\n").is_err());
        assert!(ConfigMap::parse("= value\n").is_err());
    }

    #[test]
    fn later_keys_override() {
        let c = ConfigMap::parse("a = 1\na = 2\n").unwrap();
        assert_eq!(c.get_u64("a").unwrap(), Some(2));
    }
}
